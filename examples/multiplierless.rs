//! Fully multiplier-less networks (paper §2 naming + appendix A):
//! train LUT-Q pow-2 with multiplier-less batch norm, export, and execute
//! with the shift-only plan, asserting ZERO floating multiplications in
//! every quantized layer and BN — then compare quasi vs fully
//! multiplier-less accuracy. (For the serving front end over these
//! compiled plans see `serve::Server` and the quickstart example.)
//!
//!   cargo run --release --example multiplierless -- [steps]

use anyhow::Result;

use lutq::infer::{ExecMode, Plan, PlanOptions, Tensor};
use lutq::params::export::QuantizedModel;
use lutq::{Runtime, TrainConfig, Trainer};

fn main() -> Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(200);
    let rt = Runtime::new(&lutq::artifacts_dir())?;

    let mut rows = Vec::new();
    for (label, artifact) in [
        ("unconstrained fp32", "cifar_fp32"),
        ("quasi multiplier-less (LUT-Q pow2 + std BN)", "cifar_lutq4"),
        ("fully multiplier-less (LUT-Q pow2 + ML-BN)", "cifar_lutq4_ml"),
    ] {
        let trainer =
            Trainer::new(&rt, TrainConfig::new(artifact).steps(steps)
                .seed(11))?;
        let res = trainer.run()?;
        rows.push((label, artifact, res));
    }

    println!("\n| network | val error | dict pow-2 | engine mults | engine shifts |");
    println!("|---|---|---|---|---|");
    for (label, artifact, res) in &rows {
        let (mults, shifts, pow2) = if res.manifest.quant_method() == "lutq"
        {
            let model = QuantizedModel::from_state(&res.state,
                                                   &res.manifest.qlayers);
            let mode = if model.is_multiplierless() && res.manifest.mlbn() {
                ExecMode::ShiftOnly
            } else {
                ExecMode::LutTrick
            };
            let plan = Plan::compile(&res.manifest.graph, &model,
                                     PlanOptions {
                                         mode,
                                         act_bits: res.manifest.act_bits(),
                                         mlbn: res.manifest.mlbn(),
                                         threads: 0,
                                         ..PlanOptions::default()
                                     },
                                     &res.manifest.meta.input)?;
            let mut scratch = plan.scratch_for(1);
            let mut dims = vec![1usize];
            dims.extend_from_slice(&res.manifest.meta.input);
            let counts =
                plan.run_into(&Tensor::zeros(dims), &mut scratch)?;
            if mode == ExecMode::ShiftOnly {
                // the paper's claim, enforced: zero multiplies in all
                // affine/conv layers AND batch norm
                assert!(counts.is_multiplierless(),
                        "fully multiplier-less model executed multiplies!");
            }
            (counts.mults, counts.shifts, model.is_multiplierless())
        } else {
            (0, 0, false)
        };
        println!(
            "| {label} | {:.2}% | {pow2} | {mults} | {shifts} |",
            res.eval_error * 100.0
        );
        let _ = artifact;
    }
    println!("\n(fully multiplier-less executes with 0 multiplications — \
              verified by the shift-only engine, paper appendix A)");
    Ok(())
}
