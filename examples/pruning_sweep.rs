//! Fig-2 style pruning sweep (short version of the fig2_pruning bench):
//! LUT-Q with the zero-pinned dictionary entry, sweeping the pruning
//! fraction at one bitwidth and reporting error increase + measured
//! sparsity of the exported model.
//!
//!   cargo run --release --example pruning_sweep -- [steps]

use anyhow::Result;

use lutq::coordinator::sweep::Sweep;
use lutq::params::export::QuantizedModel;
use lutq::{Runtime, TrainConfig};

fn main() -> Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(150);
    let rt = Runtime::new(&lutq::artifacts_dir())?;
    let mut sweep = Sweep::new(&rt);

    // fp32 reference first
    let base = sweep
        .run("fp32", TrainConfig::new("cifar_fp32").steps(steps).seed(3))?
        .eval_error;

    for prune_pct in [0usize, 30, 50, 70] {
        let label = format!("lutq4 prune {prune_pct}%");
        let mut cfg = TrainConfig::new("cifar_prune4").steps(steps).seed(3);
        if prune_pct > 0 {
            cfg = cfg.prune(prune_pct as f32 / 100.0);
        }
        let res = sweep.run(&label, cfg)?;
        let model =
            QuantizedModel::from_state(&res.state, &res.manifest.qlayers);
        let sparsity: f32 = model
            .lut_layers
            .iter()
            .map(|l| l.sparsity() * l.n() as f32)
            .sum::<f32>()
            / model.lut_layers.iter().map(|l| l.n() as f32).sum::<f32>();
        sweep.annotate_last("sparsity",
                            format!("{:.1}%", sparsity * 100.0));
        sweep.annotate_last(
            "err increase",
            format!("{:+.2}%", (res.eval_error - base) * 100.0),
        );
    }
    println!("{}", sweep.to_markdown(
        "Pruning + quantization (paper Fig. 2, scaled)"));
    Ok(())
}
