//! Fig-2 style pruning sweep (short version of the fig2_pruning bench):
//! LUT-Q with the zero-pinned dictionary entry, sweeping the pruning
//! fraction at one bitwidth and reporting error increase + measured
//! sparsity of the exported model.
//!
//! The paper's loop is iterative, so a sweep is exactly the kind of
//! version stream the serving stack's model lifecycle exists for: each
//! sweep point is exported as a `cifar_prune4@p{pct}` model file and
//! then every pruning level is served *concurrently* behind one
//! router — version-qualified predicts pick a level, the bare name
//! serves the default.
//!
//!   cargo run --release --example pruning_sweep -- [steps]

use std::sync::Arc;

use anyhow::{anyhow, Result};

use lutq::coordinator::sweep::Sweep;
use lutq::infer::{ExecMode, Plan, PlanOptions};
use lutq::params::export::QuantizedModel;
use lutq::serve::{
    InProcessReplica, Registry, Replica, Router, RouterConfig,
    ServeBackend, Server, ServerConfig,
};
use lutq::{Runtime, TrainConfig};

fn main() -> Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(150);
    let rt = Runtime::new(&lutq::artifacts_dir())?;
    let mut sweep = Sweep::new(&rt);

    // fp32 reference first
    let base = sweep
        .run("fp32", TrainConfig::new("cifar_fp32").steps(steps).seed(3))?
        .eval_error;

    // every sweep point becomes one version of one served model
    let mut versions: Vec<(String, Arc<Plan>, std::path::PathBuf)> =
        Vec::new();
    let mut input_dims: Vec<usize> = Vec::new();
    for prune_pct in [0usize, 30, 50, 70] {
        let label = format!("lutq4 prune {prune_pct}%");
        let mut cfg = TrainConfig::new("cifar_prune4").steps(steps).seed(3);
        if prune_pct > 0 {
            cfg = cfg.prune(prune_pct as f32 / 100.0);
        }
        let res = sweep.run(&label, cfg)?;
        let model =
            QuantizedModel::from_state(&res.state, &res.manifest.qlayers);
        let sparsity: f32 = model
            .lut_layers
            .iter()
            .map(|l| l.sparsity() * l.n() as f32)
            .sum::<f32>()
            / model.lut_layers.iter().map(|l| l.n() as f32).sum::<f32>();
        sweep.annotate_last("sparsity",
                            format!("{:.1}%", sparsity * 100.0));
        sweep.annotate_last(
            "err increase",
            format!("{:+.2}%", (res.eval_error - base) * 100.0),
        );

        // export this point as a `name@version` model file: a running
        // `lutq serve` hot-loads it with
        //   POST /v1/models/cifar_prune4:load
        //   {"version":"p30","artifact":"cifar_prune4","model":"<path>"}
        let version = format!("p{prune_pct}");
        let path = std::env::temp_dir()
            .join(format!("cifar_prune4@{version}.bin"));
        model.save(&path)?;
        let plan = Arc::new(Plan::compile(
            &res.manifest.graph,
            &model,
            PlanOptions {
                mode: ExecMode::LutTrick,
                act_bits: res.manifest.act_bits(),
                mlbn: res.manifest.mlbn(),
                threads: 1,
                ..PlanOptions::default()
            },
            &res.manifest.meta.input,
        )?);
        input_dims = res.manifest.meta.input.clone();
        versions.push((version, plan, path));
    }
    println!("{}", sweep.to_markdown(
        "Pruning + quantization (paper Fig. 2, scaled)"));

    // ------- every sweep point served concurrently behind one router
    // One versioned catalog per replica (the plans themselves are
    // shared `Arc`s, compiled once above), two in-process replicas,
    // one router. The first version loaded for a name becomes its
    // default, so the bare `cifar_prune4` serves p0 until a
    // `setDefault` cutover says otherwise.
    let mut backends: Vec<Box<dyn Replica>> = Vec::new();
    let mut servers = Vec::new();
    for r in 0..2 {
        let registry = Registry::new();
        for (version, plan, _) in &versions {
            registry
                .load("cifar_prune4", version, Arc::clone(plan))
                .map_err(|e| anyhow!("{e}"))?;
        }
        let server = Arc::new(Server::start(registry, ServerConfig {
            workers: 2,
            ..Default::default()
        })?);
        backends.push(Box::new(InProcessReplica::new(
            &format!("r{r}"),
            Arc::clone(&server),
        )));
        servers.push(server);
    }
    let router = Router::new(backends, RouterConfig::default())?;

    let input = vec![0.5f32; input_dims.iter().product()];
    println!("\nEvery pruning level live behind one router:");
    for (version, _, path) in &versions {
        let target = format!("cifar_prune4@{version}");
        let out = router
            .predict(&target, &input, None)
            .map_err(|e| anyhow!("{e}"))?;
        let argmax = out
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap_or(0);
        println!("  {target:<20} -> {} logits, argmax {argmax:<2} \
                  (exported: {})",
                 out.len(), path.display());
    }
    let dflt = router
        .predict("cifar_prune4", &input, None)
        .map_err(|e| anyhow!("{e}"))?;
    println!("  cifar_prune4 (default, p0) -> {} logits", dflt.len());

    drop(router);
    for (i, server) in servers.into_iter().enumerate() {
        let server = Arc::try_unwrap(server)
            .map_err(|_| anyhow!("replica {i} still referenced"))?;
        server.shutdown();
    }
    Ok(())
}
