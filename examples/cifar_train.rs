//! End-to-end driver (DESIGN.md §4, experiment C10): train the CIFAR-scale
//! ResNet with LUT-Q pow-2 4-bit + 8-bit activations for a few hundred
//! steps on the synthetic CIFAR stand-in, logging the loss curve, then
//! evaluate, export, and verify the multiplier-less property end to end.
//!
//!   cargo run --release --example cifar_train -- [steps] [artifact]
//!
//! The loss curve and final numbers are recorded in EXPERIMENTS.md.

use anyhow::Result;

use lutq::infer::{ExecMode, Plan, PlanOptions, Tensor};
use lutq::params::export::QuantizedModel;
use lutq::util::human_bytes;
use lutq::{Runtime, TrainConfig, Trainer};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: usize =
        args.first().and_then(|s| s.parse().ok()).unwrap_or(300);
    let artifact = args
        .get(1)
        .cloned()
        .unwrap_or_else(|| "cifar_lutq4".to_string());

    let rt = Runtime::new(&lutq::artifacts_dir())?;
    let cfg = TrainConfig::new(&artifact)
        .steps(steps)
        .seed(1)
        .eval_every((steps / 4).max(1))
        .data_lens(8192, 1024);
    let trainer = Trainer::new(&rt, cfg)?;
    println!(
        "# {} | {} params | method={} bits={} pow2={} act={} mlbn={}",
        artifact,
        trainer.manifest.param_count(),
        trainer.manifest.quant_method(),
        trainer.manifest.quant_bits(),
        trainer.manifest.pow2(),
        trainer.manifest.act_bits(),
        trainer.manifest.mlbn(),
    );
    let result = trainer.run()?;

    // loss curve, decimated to ~20 points for the log
    println!("\n## loss curve (step, loss)");
    let h = &result.loss_history;
    let stride = (h.len() / 20).max(1);
    for (s, l) in h.iter().step_by(stride) {
        println!("{s:>6} {l:.4}");
    }
    println!(
        "\nfinal: loss {:.4} | val error {:.2}% | {:.2} steps/s",
        result.final_loss,
        result.eval_error * 100.0,
        result.steps_per_sec
    );

    if trainer.manifest.quant_method() == "lutq" {
        let model = QuantizedModel::from_state(&result.state,
                                               &result.manifest.qlayers);
        println!(
            "export: {} vs dense {} ({:.2}x), multiplier-less dicts: {}",
            human_bytes(model.stored_bytes()),
            human_bytes(model.dense_bytes()),
            model.compression_ratio(),
            model.is_multiplierless()
        );

        // plan sanity: compile once, run one synthetic image
        let opts = PlanOptions {
            mode: if model.is_multiplierless() {
                ExecMode::ShiftOnly
            } else {
                ExecMode::LutTrick
            },
            act_bits: trainer.manifest.act_bits(),
            mlbn: trainer.manifest.mlbn(),
            threads: 0,
            ..PlanOptions::default()
        };
        let input = trainer.manifest.meta.input.clone();
        let plan =
            Plan::compile(&result.manifest.graph, &model, opts, &input)?;
        let mut scratch = plan.scratch();
        let mut dims = vec![1usize];
        dims.extend_from_slice(&input);
        let (out, counts) = plan.run(&Tensor::zeros(dims), &mut scratch)?;
        println!(
            "plan ({:?}): out dims {:?}, {counts}, multiplier-less \
             execution: {}",
            opts.mode,
            out.dims,
            counts.is_multiplierless()
        );
    }
    Ok(())
}
