//! Quickstart: train a tiny MLP with LUT-Q (4-bit dictionary) on a
//! synthetic 10-class task, export the packed quantized model, run the
//! pure-Rust inference engine on it and serve it through the coalescing
//! multi-model Server.
//!
//!   make artifacts && cargo run --release --example quickstart

use std::sync::Arc;

use anyhow::Result;

use lutq::infer::{ExecMode, Plan, PlanOptions, Tensor};
use lutq::params::export::QuantizedModel;
use lutq::serve::{Registry, Server, ServerConfig};
use lutq::util::human_bytes;
use lutq::{Runtime, TrainConfig, Trainer};

fn main() -> Result<()> {
    let rt = Runtime::new(&lutq::artifacts_dir())?;
    println!("PJRT platform: {}", rt.platform());

    // 1. Train: the whole paper-Table-1 algorithm (forward/backward, SGD,
    //    per-minibatch k-means on dictionary + assignments) runs inside the
    //    AOT artifact; Rust drives batches and schedules.
    let cfg = TrainConfig::new("quickstart_mlp")
        .steps(120)
        .seed(42)
        .eval_every(60)
        .data_lens(2048, 512);
    let trainer = Trainer::new(&rt, cfg)?;
    let result = trainer.run()?;
    println!(
        "trained: final loss {:.4}, val error {:.2}%",
        result.final_loss,
        result.eval_error * 100.0
    );

    // 2. Export: dictionary + bit-packed assignments per layer — the
    //    paper's K*B_float + N*ceil(log2 K) memory layout.
    let model =
        QuantizedModel::from_state(&result.state, &result.manifest.qlayers);
    println!(
        "export: {} (dense {}) = {:.2}x compression",
        human_bytes(model.stored_bytes()),
        human_bytes(model.dense_bytes()),
        model.compression_ratio()
    );

    // 3. Inference with the K-multiplication LUT trick, counting ops:
    //    compile the graph into a Plan once, then serve batches from a
    //    reusable scratch arena (the steady state allocates nothing).
    let input = result.manifest.meta.input[0];
    let plan = Arc::new(Plan::compile(
        &result.manifest.graph,
        &model,
        PlanOptions { mode: ExecMode::LutTrick, act_bits: 0, mlbn: false,
                      threads: 0, ..PlanOptions::default() },
        &[input],
    )?);
    let mut scratch = plan.scratch_for(1);
    let x = Tensor::zeros(vec![1, input]);
    let (logits, counts) = plan.run(&x, &mut scratch)?;
    println!("plan logits: {:?}", &logits.data[..logits.data.len().min(10)]);
    println!("plan ops: {counts}");

    // Dense comparison: the mult reduction the paper §1 promises. Counts
    // are static properties of a plan — no execution needed.
    let dense = Plan::compile(
        &result.manifest.graph,
        &model,
        PlanOptions { mode: ExecMode::Dense, act_bits: 0, mlbn: false,
                      threads: 0, ..PlanOptions::default() },
        &[input],
    )?;
    let dense_counts = dense.counts(1);
    println!(
        "dense ops:  {dense_counts}  -> {:.1}x fewer multiplications via LUT",
        dense_counts.mults as f64 / counts.mults.max(1) as f64
    );

    // 4. Serving: register the compiled plan once and front it with the
    //    coalescing Server — the production inference API. Responses are
    //    bit-identical to the direct plan run.
    let mut registry = Registry::new();
    registry.register_shared("quickstart_mlp", Arc::clone(&plan))?;
    let server = Server::start(
        registry,
        ServerConfig { workers: 2, ..Default::default() },
    )?;
    let served = server.infer("quickstart_mlp", &x.data)?;
    assert_eq!(served, logits.data,
               "served logits must match the direct plan run bitwise");
    let reports = server.shutdown();
    println!(
        "serve: {} request(s) in {} batch(es), mean exec {:.3} ms",
        reports[0].requests, reports[0].batches, reports[0].mean_batch_ms
    );
    Ok(())
}
