//! Quickstart: train a tiny MLP with LUT-Q (4-bit dictionary) on a
//! synthetic 10-class task, export the packed quantized model and run the
//! pure-Rust inference engine on it.
//!
//!   make artifacts && cargo run --release --example quickstart

use anyhow::Result;

use lutq::infer::{Engine, EngineOptions, ExecMode, Tensor};
use lutq::params::export::QuantizedModel;
use lutq::util::human_bytes;
use lutq::{Runtime, TrainConfig, Trainer};

fn main() -> Result<()> {
    let rt = Runtime::new(&lutq::artifacts_dir())?;
    println!("PJRT platform: {}", rt.platform());

    // 1. Train: the whole paper-Table-1 algorithm (forward/backward, SGD,
    //    per-minibatch k-means on dictionary + assignments) runs inside the
    //    AOT artifact; Rust drives batches and schedules.
    let cfg = TrainConfig::new("quickstart_mlp")
        .steps(120)
        .seed(42)
        .eval_every(60)
        .data_lens(2048, 512);
    let trainer = Trainer::new(&rt, cfg)?;
    let result = trainer.run()?;
    println!(
        "trained: final loss {:.4}, val error {:.2}%",
        result.final_loss,
        result.eval_error * 100.0
    );

    // 2. Export: dictionary + bit-packed assignments per layer — the
    //    paper's K*B_float + N*ceil(log2 K) memory layout.
    let model =
        QuantizedModel::from_state(&result.state, &result.manifest.qlayers);
    println!(
        "export: {} (dense {}) = {:.2}x compression",
        human_bytes(model.stored_bytes()),
        human_bytes(model.dense_bytes()),
        model.compression_ratio()
    );

    // 3. Inference with the K-multiplication LUT trick, counting ops.
    let engine = Engine::new(
        &result.manifest.graph,
        &model,
        EngineOptions { mode: ExecMode::LutTrick, act_bits: 0, mlbn: false },
    );
    let x = Tensor::zeros(vec![1, result.manifest.meta.input[0]]);
    let (logits, counts) = engine.run(&x)?;
    println!("engine logits: {:?}", &logits.data[..logits.data.len().min(10)]);
    println!("engine ops: {counts}");

    // Dense comparison: the mult reduction the paper §1 promises.
    let dense = Engine::new(
        &result.manifest.graph,
        &model,
        EngineOptions { mode: ExecMode::Dense, act_bits: 0, mlbn: false },
    );
    let (_, dense_counts) = dense.run(&x)?;
    println!(
        "dense ops:  {dense_counts}  -> {:.1}x fewer multiplications via LUT",
        dense_counts.mults as f64 / counts.mults.max(1) as f64
    );
    Ok(())
}
