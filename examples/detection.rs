//! Detection workload (the Pascal VOC stand-in, paper §2): train the
//! tiny-YOLO grid detector with LUT-Q, then compute mAP with the Rust
//! detection stack (decode -> NMS -> PASCAL AP) via the AOT `infer`
//! program, and report the memory-footprint-vs-mAP tradeoff.
//!
//!   cargo run --release --example detection -- [steps]

use anyhow::Result;

use lutq::data::{Batcher, SyntheticShapes};
use lutq::detect::{decode_yolo, mean_average_precision, nms, ImageEval};
use lutq::params::export::QuantizedModel;
use lutq::runtime::{self, Runtime};
use lutq::util::human_bytes;
use lutq::{TrainConfig, Trainer};

fn main() -> Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(400);
    let rt = Runtime::new(&lutq::artifacts_dir())?;

    println!("| model | mAP@0.5 | params stored | vs fp32 |");
    println!("|---|---|---|---|");
    for artifact in ["voc_fp32", "voc_lutq8", "voc_lutq4"] {
        let cfg = TrainConfig::new(artifact)
            .steps(steps)
            .seed(5)
            .data_lens(4096, 256);
        let trainer = Trainer::new(&rt, cfg)?;
        let res = trainer.run()?;

        let map = evaluate_map(&rt, &trainer, &res)?;
        let (stored, dense) = if res.manifest.quant_method() == "lutq" {
            let model = QuantizedModel::from_state(&res.state,
                                                   &res.manifest.qlayers);
            (model.stored_bytes(), model.dense_bytes())
        } else {
            let dense: u64 = res.manifest.param_count() * 4;
            (dense, dense)
        };
        println!(
            "| {artifact} | {:.1}% | {} | {:.2}x |",
            map * 100.0,
            human_bytes(stored),
            dense as f64 / stored as f64
        );
    }
    Ok(())
}

/// Run the AOT infer program over the eval split, decode + NMS + mAP.
fn evaluate_map(rt: &Runtime, trainer: &Trainer,
                res: &lutq::TrainResult) -> Result<f32> {
    let man = &res.manifest;
    let infer = rt.load_program(man, "infer")?;
    let grid = man.meta.grid;
    let ncls = man.meta.num_classes;
    // same world as training; eval window starts past the train indices
    let full = SyntheticShapes::with_dims(
        trainer.cfg.train_len + trainer.cfg.eval_len, trainer.cfg.seed,
        man.meta.input[0], grid, ncls);
    let offset = trainer.eval_offset();
    let eval = lutq::data::Slice::new(std::sync::Arc::new(full.clone()),
                                      offset, trainer.cfg.eval_len);
    let batch_size = infer.spec.inputs[0].shape[0];
    let mut images = Vec::new();
    for (batch, valid) in Batcher::eval_batches(&eval, batch_size) {
        let x = runtime::literal_f32(&infer.spec.inputs[0].shape, &batch.x)?;
        let mut args = vec![x];
        for e in &man.state {
            let t = res.state.get(&e.name).unwrap();
            args.push(runtime::host_to_literal(t)?);
        }
        let out = infer.run(&args)?;
        let pred = out.f32_vec(0)?;
        let per = grid * grid * (5 + ncls);
        for (j, &idx) in batch.indices.iter().take(valid).enumerate() {
            let dets = nms(
                decode_yolo(&pred[j * per..(j + 1) * per], grid, ncls, 0.5),
                0.45,
            );
            images.push(ImageEval {
                dets,
                gts: full.ground_truth(idx + offset),
            });
        }
    }
    Ok(mean_average_precision(&images, ncls, 0.5))
}
