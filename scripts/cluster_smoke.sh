#!/usr/bin/env bash
# Cluster smoke: the sharding router's bitwise parity + fault-injection
# suites pinned to the scalar kernel (the bit-exact reference), then two
# quick `serve-bench --transport cluster` runs — one with in-process
# shard hops (the historical BENCH_cluster.json scaling rows) and one
# with binary wire hops, where each replica sits behind its own
# WireServer and the router sends one batched frame per shard. Mirrors
# the `cluster-smoke` CI job; run locally via `make cluster-smoke`.
set -euo pipefail
cd "$(dirname "$0")/.."

# parity + fault-injection integration suites: the cluster tests and
# the wire-transport tests both assert bitwise-identical outputs under
# replica kills, so both belong to the cluster gate
(cd rust && LUTQ_KERNEL=scalar cargo test --release --test cluster -q)
(cd rust && LUTQ_KERNEL=scalar cargo test --release --test wire_serve -q)

# 1-vs-N replica scaling rows, in-process hops (committed artifact name
# kept stable for the CI upload step)
(cd rust && LUTQ_KERNEL=scalar cargo run --release --bin lutq -- \
  serve-bench --artifact synthetic --transport cluster --replicas 3 \
  --iters 5 --warmup 1 --json reports/BENCH_cluster.json)

# same sweep over binary wire shard hops: every replica behind its own
# WireServer, labels carry the -binary suffix so the rows coexist
(cd rust && LUTQ_KERNEL=scalar cargo run --release --bin lutq -- \
  serve-bench --artifact synthetic --transport cluster \
  --shard-transport binary --replicas 3 --iters 5 --warmup 1 \
  --json reports/BENCH_cluster_binary.json)

echo "cluster-smoke OK (parity suites + in-process and binary-hop" \
     "scaling rows)"
