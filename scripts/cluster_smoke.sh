#!/usr/bin/env bash
# Cluster smoke: the sharding router's bitwise parity + fault-injection
# suites pinned to the scalar kernel (the bit-exact reference), then two
# quick `serve-bench --transport cluster` runs — one with in-process
# shard hops (the historical BENCH_cluster.json scaling rows) and one
# with binary wire hops, where each replica sits behind its own
# WireServer and the router sends one batched frame per shard. A final
# open-loop leg drives Poisson arrivals at a fault-injected 2-replica
# cluster and asserts hedging + breaker counters fired and the sample
# accounting reconciles. Mirrors the `cluster-smoke` CI job; run
# locally via `make cluster-smoke`.
set -euo pipefail
cd "$(dirname "$0")/.."

# parity + fault-injection integration suites: the cluster tests and
# the wire-transport tests both assert bitwise-identical outputs under
# replica kills, so both belong to the cluster gate
(cd rust && LUTQ_KERNEL=scalar cargo test --release --test cluster -q)
(cd rust && LUTQ_KERNEL=scalar cargo test --release --test wire_serve -q)

# 1-vs-N replica scaling rows, in-process hops (committed artifact name
# kept stable for the CI upload step)
(cd rust && LUTQ_KERNEL=scalar cargo run --release --bin lutq -- \
  serve-bench --artifact synthetic --transport cluster --replicas 3 \
  --iters 5 --warmup 1 --json reports/BENCH_cluster.json)

# same sweep over binary wire shard hops: every replica behind its own
# WireServer, labels carry the -binary suffix so the rows coexist
(cd rust && LUTQ_KERNEL=scalar cargo run --release --bin lutq -- \
  serve-bench --artifact synthetic --transport cluster \
  --shard-transport binary --replicas 3 --iters 5 --warmup 1 \
  --json reports/BENCH_cluster_binary.json)

# open-loop leg: Poisson arrivals against a 2-replica cluster with one
# fault-injected replica. Hedged dispatch and the circuit breaker must
# both fire at least once, and the router's per-sample accounting must
# reconcile — asserted on the greppable counters line serve-bench
# prints after the open-loop run
OPEN_OUT=$(mktemp /tmp/lutq_cluster_open.XXXXXX.log)
(cd rust && LUTQ_KERNEL=scalar cargo run --release --bin lutq -- \
  serve-bench --artifact synthetic --transport cluster --replicas 2 \
  --iters 2 --warmup 1 --arrival poisson --rate 300 \
  --open-requests 600 --slo-ms 5,25,100 \
  --flaky-replica 0 --flaky-drop-p 0.2 --flaky-error-p 0.2 \
  --flaky-delay-p 0.4 --flaky-delay-ms 50 --hedge-threshold 1.2 \
  --json reports/BENCH_cluster_open_loop.json) | tee "$OPEN_OUT"
grep -E 'open-loop cluster counters: hedges=[1-9]' "$OPEN_OUT" \
  >/dev/null || { echo "cluster-smoke: no hedges fired" >&2; exit 1; }
grep -E 'breaker_trips=[1-9]' "$OPEN_OUT" >/dev/null \
  || { echo "cluster-smoke: breaker never tripped" >&2; exit 1; }
grep -q 'reconciles=true' "$OPEN_OUT" \
  || { echo "cluster-smoke: accounting does not reconcile" >&2; exit 1; }
rm -f "$OPEN_OUT"

# autoscaler leg: a burst of concurrent predicts against a 1..4-worker
# server must grow the pool, and the pool must shrink back toward the
# floor once the burst drains — with both decisions visible as
# `serve_scale` events in the metrics JSONL the server writes on exit
AS_ADDR="${LUTQ_SMOKE_AS:-127.0.0.1:18451}"
AS_LOG=$(mktemp /tmp/lutq_autoscale.XXXXXX.jsonl)
AS_BODY=$(mktemp /tmp/lutq_autoscale_body.XXXXXX.json)
python3 -c 'print("{\"input\":[" + ",".join(["0.5"]*3072) + "]}")' \
  > "$AS_BODY"
rust/target/release/lutq serve --artifact synthetic --addr "$AS_ADDR" \
  --min-workers 1 --max-workers 4 --metrics-jsonl "$AS_LOG" \
  --max-seconds 10 &
AS_PID=$!
for _ in $(seq 1 100); do
  if curl -fsS "http://$AS_ADDR/healthz" >/dev/null 2>&1; then break; fi
  if ! kill -0 "$AS_PID" 2>/dev/null; then
    echo "cluster-smoke: autoscale server exited before healthy" >&2
    exit 1
  fi
  sleep 0.1
done
# 150 concurrent predicts pile the single worker's queue past the grow
# threshold (queue depth per worker, plus the EWMA backlog signal);
# afterwards the server idles out its remaining seconds so the shrink
# half of the hysteresis fires before the JSONL is written
for _ in $(seq 1 150); do
  curl -s -o /dev/null -H 'content-type: application/json' \
    --data @"$AS_BODY" "http://$AS_ADDR/v1/models/synth_lut4:predict" &
done
wait "$AS_PID"
grep -q '"event":"serve_scale"' "$AS_LOG" \
  || { echo "cluster-smoke: no serve_scale events logged" >&2; exit 1; }
grep -q '"action":"grow"' "$AS_LOG" \
  || { echo "cluster-smoke: autoscaler never grew the pool" >&2; exit 1; }
grep -q '"action":"shrink"' "$AS_LOG" \
  || { echo "cluster-smoke: autoscaler never shrank the pool" >&2; exit 1; }
rm -f "$AS_LOG" "$AS_BODY"

echo "cluster-smoke OK (parity suites + in-process and binary-hop" \
     "scaling rows + fault-injected open-loop run + autoscaler" \
     "grow/shrink)"
