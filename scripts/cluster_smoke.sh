#!/usr/bin/env bash
# Cluster smoke: the sharding router's bitwise parity + fault-injection
# suites pinned to the scalar kernel (the bit-exact reference), then two
# quick `serve-bench --transport cluster` runs — one with in-process
# shard hops (the historical BENCH_cluster.json scaling rows) and one
# with binary wire hops, where each replica sits behind its own
# WireServer and the router sends one batched frame per shard. A final
# open-loop leg drives Poisson arrivals at a fault-injected 2-replica
# cluster and asserts hedging + breaker counters fired and the sample
# accounting reconciles. Mirrors the `cluster-smoke` CI job; run
# locally via `make cluster-smoke`.
set -euo pipefail
cd "$(dirname "$0")/.."

# parity + fault-injection integration suites: the cluster tests and
# the wire-transport tests both assert bitwise-identical outputs under
# replica kills, so both belong to the cluster gate
(cd rust && LUTQ_KERNEL=scalar cargo test --release --test cluster -q)
(cd rust && LUTQ_KERNEL=scalar cargo test --release --test wire_serve -q)

# 1-vs-N replica scaling rows, in-process hops (committed artifact name
# kept stable for the CI upload step)
(cd rust && LUTQ_KERNEL=scalar cargo run --release --bin lutq -- \
  serve-bench --artifact synthetic --transport cluster --replicas 3 \
  --iters 5 --warmup 1 --json reports/BENCH_cluster.json)

# same sweep over binary wire shard hops: every replica behind its own
# WireServer, labels carry the -binary suffix so the rows coexist
(cd rust && LUTQ_KERNEL=scalar cargo run --release --bin lutq -- \
  serve-bench --artifact synthetic --transport cluster \
  --shard-transport binary --replicas 3 --iters 5 --warmup 1 \
  --json reports/BENCH_cluster_binary.json)

# open-loop leg: Poisson arrivals against a 2-replica cluster with one
# fault-injected replica. Hedged dispatch and the circuit breaker must
# both fire at least once, and the router's per-sample accounting must
# reconcile — asserted on the greppable counters line serve-bench
# prints after the open-loop run
OPEN_OUT=$(mktemp /tmp/lutq_cluster_open.XXXXXX.log)
(cd rust && LUTQ_KERNEL=scalar cargo run --release --bin lutq -- \
  serve-bench --artifact synthetic --transport cluster --replicas 2 \
  --iters 2 --warmup 1 --arrival poisson --rate 300 \
  --open-requests 600 --slo-ms 5,25,100 \
  --flaky-replica 0 --flaky-drop-p 0.2 --flaky-error-p 0.2 \
  --flaky-delay-p 0.4 --flaky-delay-ms 50 --hedge-threshold 1.2 \
  --json reports/BENCH_cluster_open_loop.json) | tee "$OPEN_OUT"
grep -E 'open-loop cluster counters: hedges=[1-9]' "$OPEN_OUT" \
  >/dev/null || { echo "cluster-smoke: no hedges fired" >&2; exit 1; }
grep -E 'breaker_trips=[1-9]' "$OPEN_OUT" >/dev/null \
  || { echo "cluster-smoke: breaker never tripped" >&2; exit 1; }
grep -q 'reconciles=true' "$OPEN_OUT" \
  || { echo "cluster-smoke: accounting does not reconcile" >&2; exit 1; }
rm -f "$OPEN_OUT"

echo "cluster-smoke OK (parity suites + in-process and binary-hop" \
     "scaling rows + fault-injected open-loop run)"
