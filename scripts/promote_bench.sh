#!/usr/bin/env bash
# Promote a fresh perf-gate artifact to the committed baseline.
#
# The CI perf-gate job uploads the bench JSON it measured as the
# `bench-infer-plan` artifact. When a perf change is legitimate (a
# faster kernel, a new row), download that artifact and run this script
# to copy it over rust/reports/BENCH_baseline.json, then commit the
# result. `lutq bench-check` gates every row present in the baseline,
# so promoting a file that contains the {lut4,dense4}/kernel-int/1t
# rows puts the integer backend under the 15% regression gate too.
#
# Usage: scripts/promote_bench.sh [path/to/BENCH_infer_plan.json]
#   (default: rust/reports/BENCH_infer_plan.json, i.e. a local
#    `make bench` run)
set -euo pipefail
cd "$(dirname "$0")/.."

SRC="${1:-rust/reports/BENCH_infer_plan.json}"
DST="rust/reports/BENCH_baseline.json"

if [ ! -f "$SRC" ]; then
  echo "promote-bench: $SRC not found" >&2
  echo "  run 'make bench' first, or pass the path to a downloaded" >&2
  echo "  bench-infer-plan CI artifact" >&2
  exit 1
fi

# refuse to promote a file that is not a JSON array of bench rows
rows=$(python3 -c '
import json, sys
rows = json.load(open(sys.argv[1]))
assert isinstance(rows, list) and rows, "expected a non-empty JSON array"
assert all("label" in r and "images_per_sec" in r for r in rows)
print(len(rows))
' "$SRC")

# refuse to promote an artifact older than the committed baseline:
# a row schema that predates the baseline means the candidate was
# measured by an older lutq, and promoting it would silently drop the
# fields (and gates) the newer schema added. Rows that predate the
# schema_version field count as version 1.
if [ -f "$DST" ]; then
  python3 -c '
import json, sys
ver = lambda p: max(r.get("schema_version", 1) for r in json.load(open(p)))
src, dst = ver(sys.argv[1]), ver(sys.argv[2])
if src < dst:
    sys.exit(
        f"promote-bench: refusing to promote: candidate rows carry "
        f"schema_version {src}, but the committed baseline is already "
        f"at {dst}. Re-measure with the current lutq (make bench, or "
        f"a fresh CI perf-gate artifact) instead of rolling the "
        f"baseline schema back."
    )
' "$SRC" "$DST"
fi

cp "$SRC" "$DST"
echo "promote-bench: $SRC -> $DST ($rows rows)"
echo "promote-bench: review 'git diff $DST', then commit it; every row"
echo "  in the new baseline (including any {lut4,dense4}/kernel-int/1t"
echo "  rows) is now gated by bench-check at --max-regress 0.15"
