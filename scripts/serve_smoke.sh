#!/usr/bin/env bash
# Smoke-test the HTTP serving stack end to end: build, start `lutq serve`
# on the built-in synthetic models, hit healthz / models / predict with
# curl, assert an expired deadline is rejected with 429 and counted,
# bitwise-compare one predict over HTTP vs the binary wire port
# (`lutq wire-check`), repeat one predict round-trip under
# LUTQ_KERNEL=int (the quantized multiplier-less backend), then drive a
# 2-replica cluster round trip through `lutq route` — once over HTTP
# shard hops and once over binary wire hops — including failover after
# one backend is killed. Mirrors the `serve-smoke` CI job; run locally
# via `make serve-smoke`.
#
# Every child process is reaped by the EXIT trap whatever step fails,
# and the script's real exit code survives the cleanup.
set -euo pipefail
cd "$(dirname "$0")/.."

ADDR="${LUTQ_SMOKE_ADDR:-127.0.0.1:18437}"
WIRE="${LUTQ_SMOKE_WIRE:-127.0.0.1:18438}"
ADDR_INT="${LUTQ_SMOKE_INT:-127.0.0.1:18439}"
B1="${LUTQ_SMOKE_B1:-127.0.0.1:18441}"
B2="${LUTQ_SMOKE_B2:-127.0.0.1:18442}"
RT="${LUTQ_SMOKE_ROUTER:-127.0.0.1:18443}"
W1="${LUTQ_SMOKE_W1:-127.0.0.1:18444}"
W2="${LUTQ_SMOKE_W2:-127.0.0.1:18445}"
RT_BIN="${LUTQ_SMOKE_ROUTER_BIN:-127.0.0.1:18446}"
BH1="${LUTQ_SMOKE_BH1:-127.0.0.1:18447}"
BH2="${LUTQ_SMOKE_BH2:-127.0.0.1:18448}"
BODY=$(mktemp /tmp/lutq_smoke_body.XXXXXX.json)
OUT=$(mktemp /tmp/lutq_smoke_out.XXXXXX.json)
PIDS=()

cleanup() {
  status=$?
  # kill every child we started, even mid-failure, then propagate the
  # real exit code (a failed grep/curl must fail the job, not linger)
  for pid in ${PIDS[@]+"${PIDS[@]}"}; do
    kill "$pid" 2>/dev/null || true
  done
  wait 2>/dev/null || true
  rm -f "$BODY" "$OUT"
  exit "$status"
}
trap cleanup EXIT

# wait_healthy <addr> <pid>: poll /healthz until it answers or the
# process dies
wait_healthy() {
  local addr="$1" pid="$2"
  for _ in $(seq 1 100); do
    if curl -fsS "http://$addr/healthz" >/dev/null 2>&1; then return 0; fi
    if ! kill -0 "$pid" 2>/dev/null; then
      echo "serve-smoke: process $pid for $addr exited before healthy" >&2
      return 1
    fi
    sleep 0.2
  done
  echo "serve-smoke: $addr never became healthy" >&2
  return 1
}

(cd rust && cargo build --release)
BIN=rust/target/release/lutq

# ---------------------------------------------------------- single front
"$BIN" serve --artifact synthetic --addr "$ADDR" --wire-addr "$WIRE" \
  --max-seconds 120 &
PIDS+=($!)
wait_healthy "$ADDR" "${PIDS[-1]}"

curl -fsS "http://$ADDR/healthz" | grep -q '"status":"ok"'
curl -fsS "http://$ADDR/v1/models" | grep -q '"synth_lut4"'

# synthetic conv models take a 32*32*3 input
python3 -c 'print("{\"input\":[" + ",".join(["0.5"]*3072) + "]}")' > "$BODY"

code=$(curl -s -o "$OUT" -w '%{http_code}' \
  -H 'content-type: application/json' \
  --data @"$BODY" "http://$ADDR/v1/models/synth_lut4:predict")
if [ "$code" != 200 ]; then
  echo "serve-smoke: predict returned $code: $(cat "$OUT")" >&2
  exit 1
fi
grep -q '"output"' "$OUT"

# an already-expired deadline must be rejected with 429, not queued
code=$(curl -s -o "$OUT" -w '%{http_code}' \
  -H 'content-type: application/json' \
  -H 'x-lutq-deadline-ms: 0' \
  --data @"$BODY" "http://$ADDR/v1/models/synth_lut4:predict")
if [ "$code" != 429 ]; then
  echo "serve-smoke: expired deadline returned $code, want 429" >&2
  exit 1
fi
grep -q '"deadline_exceeded"' "$OUT"
curl -fsS "http://$ADDR/metrics" | grep -q '"rejected":1'

# the binary wire port must answer the same predict with bitwise-
# identical outputs (single request and a 3-sample batched frame)
"$BIN" wire-check --http-addr "$ADDR" --wire-addr "$WIRE" \
  --model synth_lut4 --input-json "$BODY" --batch 3

# ------------------------------------------------ model lifecycle leg
# hot-load a second version of synth_lut4 through the admin API while
# the front keeps serving, predict both versions side by side, flip
# the default (blue-green cutover), and confirm /metrics carries a row
# per version; unloading the default must be refused with 409
code=$(curl -s -o "$OUT" -w '%{http_code}' \
  -H 'content-type: application/json' \
  --data '{"version":"v2","artifact":"synthetic","arch":"conv","k":8}' \
  "http://$ADDR/v1/models/synth_lut4:load")
if [ "$code" != 200 ]; then
  echo "serve-smoke: admin :load returned $code: $(cat "$OUT")" >&2
  exit 1
fi
grep -q '"version":"v2"' "$OUT"

# bare (default v1), @v1 and the freshly loaded @v2 must all answer
for target in synth_lut4 synth_lut4@v1 synth_lut4@v2; do
  code=$(curl -s -o "$OUT" -w '%{http_code}' \
    -H 'content-type: application/json' \
    --data @"$BODY" "http://$ADDR/v1/models/$target:predict")
  if [ "$code" != 200 ]; then
    echo "serve-smoke: predict $target returned $code: $(cat "$OUT")" >&2
    exit 1
  fi
  grep -q '"output"' "$OUT"
done

# blue-green cutover: v2 becomes the default and the catalog says so
code=$(curl -s -o "$OUT" -w '%{http_code}' \
  -H 'content-type: application/json' --data '{"version":"v2"}' \
  "http://$ADDR/v1/models/synth_lut4:setDefault")
if [ "$code" != 200 ]; then
  echo "serve-smoke: :setDefault returned $code: $(cat "$OUT")" >&2
  exit 1
fi
curl -fsS "http://$ADDR/v1/models" \
  | grep -q '"name":"synth_lut4","version":"v2","default":true'

# predicts keep succeeding after the cutover, and /metrics now reports
# one row per served version
code=$(curl -s -o "$OUT" -w '%{http_code}' \
  -H 'content-type: application/json' \
  --data @"$BODY" "http://$ADDR/v1/models/synth_lut4:predict")
if [ "$code" != 200 ]; then
  echo "serve-smoke: post-cutover predict returned $code" >&2
  exit 1
fi
curl -fsS "http://$ADDR/metrics" > "$OUT"
grep -q '"model":"synth_lut4","version":"v1"' "$OUT"
grep -q '"model":"synth_lut4","version":"v2"' "$OUT"

# the default version is load-bearing: unload must be a typed conflict
code=$(curl -s -o "$OUT" -w '%{http_code}' \
  -H 'content-type: application/json' --data '{"version":"v2"}' \
  "http://$ADDR/v1/models/synth_lut4:unload")
if [ "$code" != 409 ]; then
  echo "serve-smoke: unloading the default returned $code, want 409" >&2
  exit 1
fi
grep -q '"conflict"' "$OUT"

# retiring the old version is fine — and it stops answering
code=$(curl -s -o "$OUT" -w '%{http_code}' \
  -H 'content-type: application/json' --data '{"version":"v1"}' \
  "http://$ADDR/v1/models/synth_lut4:unload")
if [ "$code" != 200 ]; then
  echo "serve-smoke: unloading v1 returned $code: $(cat "$OUT")" >&2
  exit 1
fi
code=$(curl -s -o /dev/null -w '%{http_code}' \
  -H 'content-type: application/json' \
  --data @"$BODY" "http://$ADDR/v1/models/synth_lut4@v1:predict")
if [ "$code" != 404 ]; then
  echo "serve-smoke: unloaded version predict returned $code, want" \
       "404" >&2
  exit 1
fi

# ------------------------------------- integer multiplier-less backend
# the same front under LUTQ_KERNEL=int: one predict round-trip through
# the quantized product-table path, and /metrics must name the
# *resolved* backend (`int` auto-dispatches to int-avx2 on AVX2 hosts
# and int-portable elsewhere; int-scalar only when pinned)
LUTQ_KERNEL=int "$BIN" serve --artifact synthetic --addr "$ADDR_INT" \
  --max-seconds 120 &
PIDS+=($!)
wait_healthy "$ADDR_INT" "${PIDS[-1]}"

code=$(curl -s -o "$OUT" -w '%{http_code}' \
  -H 'content-type: application/json' \
  --data @"$BODY" "http://$ADDR_INT/v1/models/synth_lut4:predict")
if [ "$code" != 200 ]; then
  echo "serve-smoke: int-kernel predict returned $code: $(cat "$OUT")" >&2
  exit 1
fi
grep -q '"output"' "$OUT"
curl -fsS "http://$ADDR_INT/metrics" \
  | grep -Eq '"backend":"int-(scalar|avx2|portable)"'

# a non-finite activation is a 400 at the predict boundary, never a
# number the int kernels quantize (JSON has no literal inf, but 1e999
# overflows to it in any parser); the full-size body keeps the length
# check from masking the finiteness check
INF_BODY=$(mktemp /tmp/lutq_smoke_inf.XXXXXX.json)
python3 -c \
  'print("{\"input\":[1e999," + ",".join(["0.5"]*3071) + "]}")' \
  > "$INF_BODY"
code=$(curl -s -o "$OUT" -w '%{http_code}' \
  -H 'content-type: application/json' \
  --data @"$INF_BODY" "http://$ADDR_INT/v1/models/synth_lut4:predict")
rm -f "$INF_BODY"
if [ "$code" != 400 ]; then
  echo "serve-smoke: non-finite predict returned $code, want 400" >&2
  exit 1
fi
grep -q 'not finite' "$OUT"

# ----------------------------------------------- 2-replica cluster trip
"$BIN" serve --artifact synthetic --addr "$B1" --max-seconds 120 &
B1_PID=$!
PIDS+=("$B1_PID")
"$BIN" serve --artifact synthetic --addr "$B2" --max-seconds 120 &
PIDS+=($!)
wait_healthy "$B1" "$B1_PID"
wait_healthy "$B2" "${PIDS[-1]}"

"$BIN" route --replicas "$B1,$B2" --addr "$RT" \
  --health-every-ms 200 --max-seconds 120 &
PIDS+=($!)
wait_healthy "$RT" "${PIDS[-1]}"

curl -fsS "http://$RT/healthz" | grep -q '"replicas_healthy":2'
curl -fsS "http://$RT/v1/models" | grep -q '"synth_lut4"'

code=$(curl -s -o "$OUT" -w '%{http_code}' \
  -H 'content-type: application/json' \
  --data @"$BODY" "http://$RT/v1/models/synth_lut4:predict")
if [ "$code" != 200 ]; then
  echo "serve-smoke: routed predict returned $code: $(cat "$OUT")" >&2
  exit 1
fi
grep -q '"output"' "$OUT"

# kill replica 1: the router must fail over to replica 2 on the spot
kill "$B1_PID" 2>/dev/null || true
wait "$B1_PID" 2>/dev/null || true
code=$(curl -s -o "$OUT" -w '%{http_code}' \
  -H 'content-type: application/json' \
  --data @"$BODY" "http://$RT/v1/models/synth_lut4:predict")
if [ "$code" != 200 ]; then
  echo "serve-smoke: predict after replica kill returned $code:" \
       "$(cat "$OUT")" >&2
  exit 1
fi
grep -q '"output"' "$OUT"
curl -fsS "http://$RT/metrics" | grep -q '"event":"serve_cluster"'
curl -fsS "http://$RT/metrics" | grep -q '"event":"serve_replica"'

# ----------------------------------- 2-replica cluster, binary hops
# same trip but the router reaches its replicas over the framed wire
# protocol: the @binary replica specs name the WIRE ports, one batched
# frame per shard hop (each replica still exposes HTTP so we can
# health-poll it)
"$BIN" serve --artifact synthetic --addr "$BH1" --wire-addr "$W1" \
  --max-seconds 120 &
BW1_PID=$!
PIDS+=("$BW1_PID")
"$BIN" serve --artifact synthetic --addr "$BH2" --wire-addr "$W2" \
  --max-seconds 120 &
PIDS+=($!)
wait_healthy "$BH1" "$BW1_PID"
wait_healthy "$BH2" "${PIDS[-1]}"

"$BIN" route --replicas "$W1@binary,$W2@binary" \
  --addr "$RT_BIN" --health-every-ms 200 --max-seconds 120 &
PIDS+=($!)
wait_healthy "$RT_BIN" "${PIDS[-1]}"

curl -fsS "http://$RT_BIN/healthz" | grep -q '"replicas_healthy":2'
curl -fsS "http://$RT_BIN/v1/models" | grep -q '"synth_lut4"'

code=$(curl -s -o "$OUT" -w '%{http_code}' \
  -H 'content-type: application/json' \
  --data @"$BODY" "http://$RT_BIN/v1/models/synth_lut4:predict")
if [ "$code" != 200 ]; then
  echo "serve-smoke: binary-hop routed predict returned $code:" \
       "$(cat "$OUT")" >&2
  exit 1
fi
grep -q '"output"' "$OUT"

# kill replica 1: the wire-hop router must fail over to replica 2
kill "$BW1_PID" 2>/dev/null || true
wait "$BW1_PID" 2>/dev/null || true
code=$(curl -s -o "$OUT" -w '%{http_code}' \
  -H 'content-type: application/json' \
  --data @"$BODY" "http://$RT_BIN/v1/models/synth_lut4:predict")
if [ "$code" != 200 ]; then
  echo "serve-smoke: binary-hop predict after replica kill returned" \
       "$code: $(cat "$OUT")" >&2
  exit 1
fi
grep -q '"output"' "$OUT"
curl -fsS "http://$RT_BIN/metrics" | grep -q '"event":"serve_cluster"'

echo "serve-smoke OK (single front + wire-check + int kernel +" \
     "2-replica cluster over http and binary hops)"
