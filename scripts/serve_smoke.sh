#!/usr/bin/env bash
# Smoke-test the HTTP serving front end to end: build, start `lutq serve`
# on the built-in synthetic models, hit healthz / models / predict with
# curl, assert an expired deadline is rejected with 429 and counted, then
# shut down. Mirrors the `serve-smoke` CI job; run locally via
# `make serve-smoke`.
set -euo pipefail
cd "$(dirname "$0")/.."

ADDR="${LUTQ_SMOKE_ADDR:-127.0.0.1:18437}"
BODY=$(mktemp /tmp/lutq_smoke_body.XXXXXX.json)
OUT=$(mktemp /tmp/lutq_smoke_out.XXXXXX.json)
SERVE_PID=""
trap 'kill "${SERVE_PID:-}" 2>/dev/null || true; rm -f "$BODY" "$OUT"' EXIT

(cd rust && cargo build --release)
BIN=rust/target/release/lutq

"$BIN" serve --artifact synthetic --addr "$ADDR" --max-seconds 120 &
SERVE_PID=$!

# wait for the front to come up
for _ in $(seq 1 100); do
  if curl -fsS "http://$ADDR/healthz" >/dev/null 2>&1; then break; fi
  if ! kill -0 "$SERVE_PID" 2>/dev/null; then
    echo "serve-smoke: lutq serve exited before becoming healthy" >&2
    exit 1
  fi
  sleep 0.2
done

curl -fsS "http://$ADDR/healthz" | grep -q '"status":"ok"'
curl -fsS "http://$ADDR/v1/models" | grep -q '"synth_lut4"'

# synthetic conv models take a 32*32*3 input
python3 -c 'print("{\"input\":[" + ",".join(["0.5"]*3072) + "]}")' > "$BODY"

code=$(curl -s -o "$OUT" -w '%{http_code}' \
  -H 'content-type: application/json' \
  --data @"$BODY" "http://$ADDR/v1/models/synth_lut4:predict")
if [ "$code" != 200 ]; then
  echo "serve-smoke: predict returned $code: $(cat "$OUT")" >&2
  exit 1
fi
grep -q '"output"' "$OUT"

# an already-expired deadline must be rejected with 429, not queued
code=$(curl -s -o "$OUT" -w '%{http_code}' \
  -H 'content-type: application/json' \
  -H 'x-lutq-deadline-ms: 0' \
  --data @"$BODY" "http://$ADDR/v1/models/synth_lut4:predict")
if [ "$code" != 429 ]; then
  echo "serve-smoke: expired deadline returned $code, want 429" >&2
  exit 1
fi
grep -q '"deadline_exceeded"' "$OUT"
curl -fsS "http://$ADDR/metrics" | grep -q '"rejected":1'

kill "$SERVE_PID" 2>/dev/null || true
echo "serve-smoke OK"
