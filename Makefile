# Top-level driver for the LUT-Q reproduction.
#
#   make verify     tier-1 gate: release build + full test suite
#   make build      release build only
#   make test       test suite only
#   make bench      plan/execute inference bench (writes reports/BENCH_*.json)
#   make perf-gate  bench + gate images/s against reports/BENCH_baseline.json
#   make serve-smoke  end-to-end HTTP front smoke test (curl + lutq serve)
#   make cluster-smoke  cluster + wire parity / fault-injection tests
#                   (scalar kernel) + 1-vs-N replica scaling rows in
#                   reports/BENCH_cluster.json (in-process hops) and
#                   reports/BENCH_cluster_binary.json (wire hops)
#   make fmt lint   style gates (hard in CI; see .github/workflows/ci.yml)
#   make artifacts  AOT-lower the python artifact set (needs jax; optional)

CARGO_DIR := rust

.PHONY: verify build test bench perf-gate serve-smoke cluster-smoke \
	fmt lint artifacts

verify:
	cd $(CARGO_DIR) && cargo build --release && cargo test -q

build:
	cd $(CARGO_DIR) && cargo build --release

test:
	cd $(CARGO_DIR) && cargo test -q

bench:
	cd $(CARGO_DIR) && cargo bench --bench infer_engine

perf-gate:
	cd $(CARGO_DIR) && cargo bench --bench infer_engine && \
	cargo run --release --bin lutq -- bench-check \
	  --current reports/BENCH_infer_plan.json \
	  --baseline reports/BENCH_baseline.json --max-regress 0.15

serve-smoke:
	bash scripts/serve_smoke.sh

cluster-smoke:
	bash scripts/cluster_smoke.sh

fmt:
	cd $(CARGO_DIR) && cargo fmt --check

lint:
	cd $(CARGO_DIR) && cargo clippy --all-targets -- -D warnings

artifacts:
	python3 python/compile/aot.py
