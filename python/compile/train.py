"""L2 training/eval/inference step builders — the functions that get
AOT-lowered into artifacts.

The whole per-minibatch LUT-Q algorithm (paper Table 1) is ONE jitted
function: tie weights (Step 1), forward/backward (Step 2), SGD on the
full-precision shadows (Step 3), M k-means iterations on dictionary +
assignments (Step 4). Rust only shuttles buffers.

Artifact calling conventions (all arrays f32 unless noted):
  init:        (seed i32[])                      -> state...
  train_step:  (x, t, lr f32[], aux f32[], pfrac f32[], state...)
                                                 -> (loss f32[], state'...)
               aux carries the INQ freeze fraction; pfrac the LUT-Q pruning
               fraction (both L3-driven schedules; unused otherwise)
  eval_step:   (x, t, state...)                  -> (loss_sum, correct)
  infer:       (x, state...)                     -> out (logits / det grid)

`t` is one-hot (B, num_classes) for classification, the YOLO target grid
(B, S, S, 5+C) for detection. State order is defined by StateDef and
recorded in the manifest.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers as L
from . import lutq

MOMENTUM = 0.9


class StateDef:
    """Ordered, named, typed flat state layout shared with the manifest."""

    def __init__(self, graph, qcfg):
        self.graph = graph
        self.qcfg = qcfg
        self.entries = []  # (name, shape, dtype, role)
        self.pspecs = L.param_specs(graph)
        for name, shape, kind in self.pspecs:
            self.entries.append(("p:" + name, shape, "f32", "param"))
        if qcfg.get("method") == "lutq":
            k = lutq.dict_size(qcfg)
            shapes = {n: s for n, s, _ in self.pspecs}
            for layer in qcfg["qlayers"]:
                self.entries.append((f"q:{layer}.d", (k,), "f32", "dict"))
                self.entries.append((f"q:{layer}.A", shapes[layer + ".w"],
                                     "i32", "assign"))
        for name, shape in L.bn_specs(graph):
            self.entries.append(("bn:" + name, shape, "f32", "bnstate"))
        for name, shape, _ in self.pspecs:
            self.entries.append(("m:" + name, shape, "f32", "momentum"))

    def unpack(self, flat):
        """flat tuple -> (params, lut_state, bnstate, momentum) dicts."""
        params, lut, bn, mom = {}, {}, {}, {}
        for (name, _, _, role), arr in zip(self.entries, flat):
            key = name.split(":", 1)[1]
            if role == "param":
                params[key] = arr
            elif role == "dict":
                lut.setdefault(key.rsplit(".", 1)[0], {})["d"] = arr
            elif role == "assign":
                lut.setdefault(key.rsplit(".", 1)[0], {})["A"] = arr
            elif role == "bnstate":
                bn[key] = arr
            else:
                mom[key] = arr
        return params, lut, bn, mom

    def pack(self, params, lut, bn, mom):
        out = []
        for name, _, _, role in self.entries:
            key = name.split(":", 1)[1]
            if role == "param":
                out.append(params[key])
            elif role == "dict":
                out.append(lut[key.rsplit(".", 1)[0]]["d"])
            elif role == "assign":
                out.append(lut[key.rsplit(".", 1)[0]]["A"])
            elif role == "bnstate":
                out.append(bn[key])
            else:
                out.append(mom[key])
        return tuple(out)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def softmax_xent(logits, t_onehot):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.sum(t_onehot * logp, axis=-1))


def yolo_loss(pred, target, num_classes, lam_coord=5.0, lam_noobj=0.5):
    """YOLOv1-style single-box-per-cell loss.

    pred:   (B, S, S, 5+C) raw net output, channels (tx,ty,tw,th,obj,cls..)
    target: (B, S, S, 5+C) channels (obj, tx, ty, tw, th, onehot-cls..)
    """
    obj = target[..., 0]
    txy_t = target[..., 1:3]
    twh_t = target[..., 3:5]
    cls_t = target[..., 5:]

    txy_p = jax.nn.sigmoid(pred[..., 0:2])
    twh_p = pred[..., 2:4]
    obj_logit = pred[..., 4]
    cls_logit = pred[..., 5:]

    coord = jnp.sum(obj[..., None] * ((txy_p - txy_t) ** 2
                                      + (twh_p - twh_t) ** 2))
    obj_p = jax.nn.sigmoid(obj_logit)
    objloss = jnp.sum(obj * (obj_p - 1.0) ** 2
                      + lam_noobj * (1.0 - obj) * obj_p ** 2)
    logp = jax.nn.log_softmax(cls_logit)
    clsloss = -jnp.sum(obj[..., None] * cls_t * logp)
    b = pred.shape[0]
    return (lam_coord * coord + objloss + clsloss) / b


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

def _loss_fn(sd, meta, params, lut, bn, x, t, qcfg, inq_frac, train):
    qw = lutq.make_weight_quantizer(qcfg, lut, inq_frac=inq_frac)
    out, new_bn = L.forward(sd.graph, params, bn, x, train=train,
                            quantize_w=qw,
                            act_bits=qcfg.get("act_bits", 0),
                            mlbn=qcfg.get("mlbn", False))
    if meta["head"] == "classify":
        loss = softmax_xent(out, t)
    else:
        loss = yolo_loss(out, t, meta["num_classes"])
    # weight decay on conv/affine weights only
    wd = qcfg.get("weight_decay", 1e-4)
    if wd > 0:
        reg = sum(jnp.sum(params[n] ** 2) for n, _, k in sd.pspecs
                  if k in ("conv_w", "affine_w"))
        loss = loss + 0.5 * wd * reg
    return loss, (new_bn, out)


def make_train_step(sd: StateDef, meta, qcfg):
    method = qcfg.get("method", "none")

    def train_step(x, t, lr, aux, pfrac, *state):
        params, lut, bn, mom = sd.unpack(state)
        grad_fn = jax.value_and_grad(
            lambda p: _loss_fn(sd, meta, p, lut, bn, x, t, qcfg, aux, True),
            has_aux=True)
        (loss, (new_bn, _)), grads = grad_fn(params)

        # Step 3: SGD-with-momentum on the full-precision shadow weights.
        new_params, new_mom = {}, {}
        for name, _, kind in sd.pspecs:
            g = grads[name]
            if method == "inq" and kind in ("conv_w", "affine_w") \
                    and name[:-2] in qcfg["qlayers"]:
                g = g * (1.0 - lutq.inq_frozen_mask(params[name], aux))
            v = MOMENTUM * mom[name] + g
            new_mom[name] = v
            new_params[name] = params[name] - lr * v

        # Step 4: M k-means iterations on (d, A) from the updated shadows.
        if method == "lutq":
            lut = lutq.kmeans_update(new_params, lut, qcfg, pfrac=pfrac)

        return (loss,) + sd.pack(new_params, lut, new_bn, new_mom)

    return train_step


def make_eval_step(sd: StateDef, meta, qcfg):
    def eval_step(x, t, *state):
        params, lut, bn, mom = sd.unpack(state)
        qw = lutq.make_weight_quantizer(qcfg, lut,
                                        inq_frac=jnp.float32(1.0))
        out, _ = L.forward(sd.graph, params, bn, x, train=False,
                           quantize_w=qw,
                           act_bits=qcfg.get("act_bits", 0),
                           mlbn=qcfg.get("mlbn", False))
        if meta["head"] == "classify":
            loss = softmax_xent(out, t) * x.shape[0]
            correct = jnp.sum(
                (jnp.argmax(out, -1) == jnp.argmax(t, -1)).astype(jnp.float32))
            return loss, correct
        loss = yolo_loss(out, t, meta["num_classes"]) * x.shape[0]
        return loss, jnp.float32(0.0)

    return eval_step


def make_infer(sd: StateDef, meta, qcfg):
    def infer(x, *state):
        params, lut, bn, _ = sd.unpack(state)
        qw = lutq.make_weight_quantizer(qcfg, lut,
                                        inq_frac=jnp.float32(1.0))
        out, _ = L.forward(sd.graph, params, bn, x, train=False,
                           quantize_w=qw,
                           act_bits=qcfg.get("act_bits", 0),
                           mlbn=qcfg.get("mlbn", False))
        return (out,)

    return infer


def make_init(sd: StateDef, meta, qcfg):
    def init(seed):
        key = jax.random.PRNGKey(seed)
        params = L.init_params(sd.graph, key)
        bn = L.init_bnstate(sd.graph)
        lut = {}
        if qcfg.get("method") == "lutq":
            for layer in qcfg["qlayers"]:
                lut[layer] = lutq.init_lut_layer(params[layer + ".w"], qcfg)
        mom = {n: jnp.zeros(s, jnp.float32) for n, s, _ in sd.pspecs}
        return sd.pack(params, lut, bn, mom)

    return init
