"""The paper's contribution: LUT-Q weight tying + every variant it subsumes.

Methods (quant config "method"):
  lutq     — trained dictionary + trained assignments, updated by k-means
             after every minibatch (paper Table 1). Options: pow-2
             dictionary, simultaneous pruning (d[0]=0 pinned).
  uniform  — fixed symmetric uniform grid, STE (the apprentice-style [15]
             fixed-quantization baseline).
  inq      — incremental network quantization [24]: a growing fraction of
             the largest-magnitude weights is frozen to powers of two while
             the rest keeps training (schedule driven by the Rust L3 via the
             inq_frac input).
  bc       — Binary Connect [4]: dictionary {-1, 1} (scaled by mean |W|).
  twn      — Ternary Weight Networks [13]: {-a, 0, a}, threshold 0.7·E|W|.
  none     — full precision.

All forward quantizers return the *effective* weight with STE applied, so
backward gradients land on the full-precision shadow W (paper Step 3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.gather import lutq_gather
from .kernels.kmeans import kmeans_step
from .kernels.pow2 import pow2_quant

POW2_EXP_MIN = -8
POW2_EXP_MAX = 8


def ste(w, q):
    """Straight-through: value q, gradient w."""
    return w + jax.lax.stop_gradient(q - w)


# ---------------------------------------------------------------------------
# forward-pass effective weights
# ---------------------------------------------------------------------------

def tie_weights(w, d, a, interpret=True):
    """Step 1: Q = d[A] via the Pallas gather kernel, with STE onto W."""
    q = lutq_gather(d, a.reshape(-1), interpret=interpret).reshape(w.shape)
    return ste(w, q)


def uniform_weight(w, bits):
    scale = jnp.max(jnp.abs(w)) / float(2 ** (bits - 1) - 1)
    q = ref.uniform_quant_ref(w, scale, bits)
    return ste(w, q)


def bc_weight(w):
    alpha = jnp.mean(jnp.abs(w))
    q = jnp.where(w >= 0, alpha, -alpha)
    return ste(w, q)


def twn_weight(w):
    thr = 0.7 * jnp.mean(jnp.abs(w))
    mask = jnp.abs(w) > thr
    alpha = jnp.sum(jnp.abs(w) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    q = jnp.where(mask, jnp.sign(w) * alpha, 0.0)
    return ste(w, q)


def inq_weight(w, frac, interpret=True):
    """Freeze the `frac` largest-|w| weights at pow-2 values, train the rest.

    The frozen part is exact (no STE needed: its gradient is zeroed by the
    same mask in the optimizer); the free part passes through."""
    frozen = inq_frozen_mask(w, frac)
    # stop_gradient on the *input*: the Pallas call must not see a tangent
    # (interpret-mode pallas_call has no JVP rule).
    q = pow2_quant(jax.lax.stop_gradient(w).reshape(-1), POW2_EXP_MIN,
                   POW2_EXP_MAX, interpret=interpret).reshape(w.shape)
    return jnp.where(frozen, q, w)


def inq_frozen_mask(w, frac):
    """Boolean mask of the `frac` largest-magnitude weights.

    Computed under stop_gradient with an explicit sort+take (jnp.quantile
    with a traced q inside value_and_grad trips a gather bug in this
    jax/jaxlib pin); the mask is a schedule decision, not a differentiable
    quantity.
    """
    absw = jax.lax.stop_gradient(jnp.abs(w).reshape(-1))
    n = absw.shape[0]
    frac = jnp.clip(frac, 0.0, 1.0)
    srt = jnp.sort(absw)
    idx = jnp.clip(jnp.round((1.0 - frac) * (n - 1)), 0, n - 1).astype(
        jnp.int32)
    thr = jnp.take(srt, idx)
    return ((jnp.abs(w) >= thr) & (frac > 0.0))


def make_weight_quantizer(qcfg, lut_state, inq_frac=None, interpret=True):
    """Return quantize_w(name, W) for layers.forward.

    lut_state: {layer: {"d": (K,), "A": int32 same shape as W}} for "lutq".
    """
    method = qcfg.get("method", "none")

    def quantize_w(name, w):
        if name not in qcfg.get("qlayers", ()):  # not quantized (e.g. first/last fp)
            return w
        if method == "lutq":
            st = lut_state[name]
            return tie_weights(w, st["d"], st["A"], interpret=interpret)
        if method == "uniform":
            return uniform_weight(w, qcfg["bits"])
        if method == "inq":
            return inq_weight(w, inq_frac, interpret=interpret)
        if method == "bc":
            return bc_weight(w)
        if method == "twn":
            return twn_weight(w)
        return w

    return quantize_w


# ---------------------------------------------------------------------------
# LUT-Q state init + per-minibatch k-means update (paper Step 4)
# ---------------------------------------------------------------------------

def dict_size(qcfg) -> int:
    return 2 ** int(qcfg["bits"])


def init_lut_layer(w, qcfg, interpret=True):
    """Initial dictionary (spread over the weight range; d[0]=0 when the
    pruning variant is enabled) and nearest-entry assignments.

    The *amount* of pruning is a runtime input (pfrac) so the Rust L3 can
    drive pruning schedules; qcfg["prune"] statically enables the variant
    (it pins dictionary entry 0 to exactly zero). qcfg["prune_frac"] is
    only the init-time fraction.
    """
    k = dict_size(qcfg)
    flat = w.reshape(-1)
    lim = jnp.maximum(jnp.max(jnp.abs(flat)), 1e-3)
    if qcfg.get("prune", False):
        # entry 0 pinned to exactly zero; rest spread symmetrically
        rest = jnp.linspace(-lim, lim, k - 1) if k > 1 else jnp.zeros((0,))
        d = jnp.concatenate([jnp.zeros((1,)), rest]).astype(jnp.float32)
    else:
        d = jnp.linspace(-lim, lim, k).astype(jnp.float32)
    if qcfg.get("pow2", False):
        d = _pow2_dict(d, qcfg, interpret)
    a = ref.kmeans_assign_ref(flat, d).reshape(w.shape)
    if qcfg.get("prune", False):
        pfrac = jnp.float32(qcfg.get("prune_frac", 0.0))
        a = _apply_prune(flat, a.reshape(-1), pfrac).reshape(w.shape)
    return {"d": d, "A": a}


def _pow2_dict(d, qcfg, interpret):
    """Round dictionary entries to powers of two (paper section 1: the
    'rounding the output of the k-means algorithm' variant). Exact zeros
    (the pruning entry) stay zero via the kernel's underflow rule."""
    return pow2_quant(d, POW2_EXP_MIN, POW2_EXP_MAX, interpret=interpret)


def _prune_threshold(flat, pfrac):
    return jnp.quantile(jnp.abs(flat), jnp.clip(pfrac, 0.0, 1.0))


def _apply_prune(flat, a_flat, pfrac):
    """Pin the pfrac smallest-|w| weights to dictionary entry 0 (=0)."""
    thr = _prune_threshold(flat, pfrac)
    return jnp.where(jnp.abs(flat) <= thr, 0, a_flat).astype(jnp.int32)


def kmeans_update_layer(w, st, qcfg, pfrac=None, interpret=True):
    """One LUT-Q Step-4 iteration for one layer: returns new {"d","A"}.

    Pruning variant: entry 0 is pinned at 0 and the smallest-|w| fraction
    (runtime scalar pfrac) is hard-assigned to it; those weights are masked
    out of the statistics of the trainable entries. Pow-2 variant: centroids
    are rounded to powers of two after the mean update.
    """
    flat = w.reshape(-1)
    d = st["d"]
    prune = qcfg.get("prune", False)
    if prune:
        thr = _prune_threshold(flat, pfrac)
        keep = (jnp.abs(flat) > thr).astype(flat.dtype)
    else:
        keep = jnp.ones_like(flat)

    a, sums, counts = kmeans_step(flat, keep, d, interpret=interpret)
    d_new = jnp.where(counts > 0, sums / jnp.maximum(counts, 1.0), d)
    if prune:
        a = _apply_prune(flat, a, pfrac)
        d_new = d_new.at[0].set(0.0)
    if qcfg.get("pow2", False):
        d_new = _pow2_dict(d_new, qcfg, interpret)
        if prune:
            d_new = d_new.at[0].set(0.0)
    return {"d": d_new, "A": a.reshape(w.shape)}


def kmeans_update(params, lut_state, qcfg, pfrac=None, interpret=True):
    """Step 4 over every quantized layer, M = qcfg['kmeans_iters'] times."""
    m = int(qcfg.get("kmeans_iters", 1))
    new_state = dict(lut_state)
    for name in qcfg["qlayers"]:
        st = new_state[name]
        for _ in range(m):
            st = kmeans_update_layer(params[name + ".w"], st, qcfg,
                                     pfrac=pfrac, interpret=interpret)
        new_state[name] = st
    return new_state
