"""Model builders over the layer IR.

Builders return (graph, meta) where meta records input shape / class count /
head kind — everything the manifest needs so the Rust side can interpret the
exported model.

The paper's reference nets map to:
  * CIFAR ResNet-20            -> resnet(depth=20, width=16)
  * ImageNet ResNet-18/34/50   -> resnet-s/m/l = depth 20/32/44 at CIFAR
    scale (relative capacity ordering preserved; see DESIGN.md §2)
  * YOLOv2                     -> tiny_yolo grid detector
"""
from __future__ import annotations

from . import layers as L


def mlp(input_dim=256, hidden=(128, 128), num_classes=10):
    g = [L.flatten()]
    cin = input_dim
    for i, h in enumerate(hidden):
        g.append(L.affine(f"fc{i}", cin, h))
        g.append(L.relu())
        cin = h
    g.append(L.affine("head", cin, num_classes))
    meta = {"arch": "mlp", "input": [input_dim], "num_classes": num_classes,
            "head": "classify"}
    return g, meta


def convnet(hw=32, cin=3, width=16, num_classes=10):
    """Small VGG-ish stack: 3 conv/bn/relu + maxpool stages + linear head."""
    g = []
    c = cin
    for i, w in enumerate((width, 2 * width, 4 * width)):
        g += [L.conv(f"c{i}", c, w, 3), L.bn(f"b{i}", w), L.relu(),
              L.maxpool(2, 2)]
        c = w
    g += [L.gap(), L.affine("head", c, num_classes)]
    meta = {"arch": "convnet", "input": [hw, hw, cin],
            "num_classes": num_classes, "head": "classify"}
    return g, meta


def resnet(depth=20, width=16, hw=32, cin=3, num_classes=10):
    """CIFAR-style ResNet (He et al. 2016): depth = 6n+2, stages w/2w/4w."""
    assert (depth - 2) % 6 == 0, "depth must be 6n+2"
    n = (depth - 2) // 6
    g = [L.conv("stem", cin, width, 3), L.bn("stem_bn", width), L.relu()]
    c = width
    bid = 0
    for stage, w in enumerate((width, 2 * width, 4 * width)):
        for blk in range(n):
            stride = 2 if (stage > 0 and blk == 0) else 1
            tag = f"res{bid}"
            proj = None
            if stride != 1 or c != w:
                proj = {"name": f"proj{bid}", "cin": c, "cout": w, "k": 1,
                        "stride": stride}
            g += [L.save(tag),
                  L.conv(f"conv{bid}a", c, w, 3, stride),
                  L.bn(f"bn{bid}a", w), L.relu(),
                  L.conv(f"conv{bid}b", w, w, 3, 1),
                  L.bn(f"bn{bid}b", w),
                  L.add(tag, proj), L.relu()]
            c = w
            bid += 1
    g += [L.gap(), L.affine("head", c, num_classes)]
    meta = {"arch": f"resnet{depth}", "input": [hw, hw, cin],
            "num_classes": num_classes, "head": "classify"}
    return g, meta


def tiny_yolo(hw=32, cin=3, width=16, grid=4, num_classes=4):
    """Grid detector: conv backbone downsampling to (grid, grid), per-cell
    prediction of (tx, ty, tw, th, obj, class...) — a YOLOv1-style head at
    toy scale. hw must be grid * 8."""
    assert hw == grid * 8
    g = [L.conv("stem", cin, width, 3), L.bn("stem_bn", width), L.relu(),
         L.maxpool(2, 2),                                     # hw/2
         L.conv("c1", width, 2 * width, 3), L.bn("b1", 2 * width), L.relu(),
         L.maxpool(2, 2),                                     # hw/4
         L.conv("c2", 2 * width, 4 * width, 3), L.bn("b2", 4 * width),
         L.relu(),
         L.maxpool(2, 2),                                     # hw/8 = grid
         L.conv("c3", 4 * width, 4 * width, 3), L.bn("b3", 4 * width),
         L.relu(),
         L.conv("det", 4 * width, 5 + num_classes, 1)]
    meta = {"arch": "tiny_yolo", "input": [hw, hw, cin], "grid": grid,
            "num_classes": num_classes, "head": "detect"}
    return g, meta


BUILDERS = {
    "mlp": mlp,
    "convnet": convnet,
    "resnet": resnet,
    "tiny_yolo": tiny_yolo,
}


def build(cfg: dict):
    """Build from a model config dict, e.g.
    {"arch": "resnet", "depth": 20, "width": 16, "hw": 32,
     "num_classes": 10}."""
    cfg = dict(cfg)
    arch = cfg.pop("arch")
    return BUILDERS[arch](**cfg)
