"""AOT compiler: lower every artifact program to HLO *text* + manifest.

python runs ONCE here (``make artifacts``); the Rust coordinator loads the
HLO text via PJRT and never touches python again.

Interchange is HLO TEXT, not a serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids which the xla crate's xla_extension
0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

Usage:
  python -m compile.aot --preset cifar_lutq4 --out ../artifacts
  python -m compile.aot --all --out ../artifacts      # every preset
  python -m compile.aot --list
  python -m compile.aot --config my.json --out ../artifacts

Each artifact directory contains:
  init.hlo.txt  train_step.hlo.txt  eval_step.hlo.txt  infer.hlo.txt
  manifest.json   — program I/O signatures, the ordered state layout, the
                    model graph IR (for the Rust inference engine), and the
                    full config. A sha256 stamp makes rebuilds incremental.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import layers, models, train

DTYPES = {"f32": jnp.float32, "i32": jnp.int32}


# ---------------------------------------------------------------------------
# presets — every experiment in DESIGN.md §4 maps to one of these
# ---------------------------------------------------------------------------

def _q(method="none", bits=32, pow2=False, act_bits=0, mlbn=False,
       prune=False, prune_frac=0.0, first_last_fp=False, kmeans_iters=1,
       weight_decay=1e-4):
    return {"method": method, "bits": bits, "pow2": pow2,
            "act_bits": act_bits, "mlbn": mlbn, "prune": prune,
            "prune_frac": prune_frac, "first_last_fp": first_last_fp,
            "kmeans_iters": kmeans_iters, "weight_decay": weight_decay}


_CIFAR = {"arch": "resnet", "depth": 8, "width": 8, "hw": 32,
          "num_classes": 10}
# ImageNet stand-ins: three capacities (see DESIGN.md §2) on a 20-class task
_IMNET = lambda d, w: {"arch": "resnet", "depth": d, "width": w, "hw": 32,
                       "num_classes": 20}
_YOLO = {"arch": "tiny_yolo", "hw": 32, "width": 16, "grid": 4,
         "num_classes": 4}


def presets():
    p = {}
    # quickstart: tiny MLP
    p["quickstart_mlp"] = {
        "model": {"arch": "mlp", "input_dim": 64, "hidden": [64, 64],
                  "num_classes": 10},
        "quant": _q("lutq", 4), "batch_size": 32}

    # C10 experiment family (paper §2 CIFAR text + Fig 2)
    p["cifar_fp32"] = {"model": _CIFAR, "quant": _q(), "batch_size": 64}
    for bits in (2, 4):
        p[f"cifar_lutq{bits}"] = {
            "model": _CIFAR, "quant": _q("lutq", bits, pow2=True, act_bits=8),
            "batch_size": 64}
        p[f"cifar_lutq{bits}_ml"] = {
            "model": _CIFAR,
            "quant": _q("lutq", bits, pow2=True, act_bits=8, mlbn=True),
            "batch_size": 64}
        # Fig 2: pruning-enabled artifacts; pfrac is a runtime input
        p[f"cifar_prune{bits}"] = {
            "model": _CIFAR,
            "quant": _q("lutq", bits, act_bits=8, prune=True,
                        prune_frac=0.0),
            "batch_size": 64}
    p["cifar_prune8"] = {
        "model": _CIFAR,
        "quant": _q("lutq", 8, act_bits=8, prune=True, prune_frac=0.0),
        "batch_size": 64}

    # T2 experiment family (paper Table 2): 3 model sizes x methods
    sizes = {"s": _IMNET(8, 8), "m": _IMNET(14, 8), "l": _IMNET(20, 8)}
    for sz, mcfg in sizes.items():
        p[f"imnet_{sz}_fp32"] = {"model": mcfg, "quant": _q(),
                                 "batch_size": 32}
        for bits in (2, 4):
            p[f"imnet_{sz}_lutq{bits}"] = {
                "model": mcfg,
                "quant": _q("lutq", bits, pow2=True, act_bits=8),
                "batch_size": 32}
            p[f"imnet_{sz}_lutq{bits}_ml"] = {
                "model": mcfg,
                "quant": _q("lutq", bits, pow2=True, act_bits=8, mlbn=True),
                "batch_size": 32}
            # apprentice-style fixed uniform grid (acts 8-bit)
            p[f"imnet_{sz}_uniform{bits}"] = {
                "model": mcfg, "quant": _q("uniform", bits, act_bits=8),
                "batch_size": 32}
            # INQ: pow-2 freeze schedule via aux input, fp32 activations
            p[f"imnet_{sz}_inq{bits}"] = {
                "model": mcfg, "quant": _q("inq", bits), "batch_size": 32}
        p[f"imnet_{sz}_inq5"] = {
            "model": mcfg, "quant": _q("inq", 5), "batch_size": 32}
        # BC / TWN degenerate dictionaries (LUT-Q special cases, §1)
        p[f"imnet_{sz}_bc"] = {"model": mcfg, "quant": _q("bc", 1),
                               "batch_size": 32}
        p[f"imnet_{sz}_twn"] = {"model": mcfg, "quant": _q("twn", 2),
                                "batch_size": 32}

    # VOC stand-in (paper §2 detection text)
    p["voc_fp32"] = {"model": _YOLO, "quant": _q(), "batch_size": 16}
    p["voc_lutq8"] = {"model": _YOLO,
                      "quant": _q("lutq", 8, act_bits=8), "batch_size": 16}
    p["voc_lutq4"] = {"model": _YOLO,
                      "quant": _q("lutq", 4, act_bits=8), "batch_size": 16}
    return p


# ---------------------------------------------------------------------------
# lowering
# ---------------------------------------------------------------------------

def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _iospec(args, results):
    def one(x):
        return {"shape": list(x.shape), "dtype": ("i32" if x.dtype ==
                jnp.int32 else "f32")}
    return [one(a) for a in args], [one(r) for r in results]


def compile_artifact(name: str, cfg: dict, out_root: str,
                     force: bool = False) -> str:
    out_dir = os.path.join(out_root, name)
    os.makedirs(out_dir, exist_ok=True)

    stamp = hashlib.sha256(json.dumps(cfg, sort_keys=True).encode()
                           + _sources_digest()).hexdigest()
    stamp_path = os.path.join(out_dir, ".stamp")
    if not force and os.path.exists(stamp_path):
        if open(stamp_path).read().strip() == stamp and \
                os.path.exists(os.path.join(out_dir, "manifest.json")):
            return "cached"

    graph, meta = models.build(cfg["model"])
    qcfg = dict(cfg["quant"])
    qcfg["qlayers"] = layers.quantizable(graph, qcfg.get("first_last_fp",
                                                         False))
    sd = train.StateDef(graph, qcfg)
    b = cfg["batch_size"]

    if meta["head"] == "classify":
        if meta["arch"] == "mlp":
            x_spec = jax.ShapeDtypeStruct((b, meta["input"][0]), jnp.float32)
        else:
            x_spec = jax.ShapeDtypeStruct((b, *meta["input"]), jnp.float32)
        t_spec = jax.ShapeDtypeStruct((b, meta["num_classes"]), jnp.float32)
    else:
        x_spec = jax.ShapeDtypeStruct((b, *meta["input"]), jnp.float32)
        s = meta["grid"]
        t_spec = jax.ShapeDtypeStruct((b, s, s, 5 + meta["num_classes"]),
                                      jnp.float32)

    state_specs = tuple(jax.ShapeDtypeStruct(sh, DTYPES[dt])
                        for _, sh, dt, _ in sd.entries)
    scalar_f = jax.ShapeDtypeStruct((), jnp.float32)
    scalar_i = jax.ShapeDtypeStruct((), jnp.int32)

    programs = {}

    def lower(pname, fn, specs, in_names, out_names):
        t0 = time.time()
        # keep_unused: the artifact ABI is positional — every manifest input
        # must stay an HLO parameter even if a program ignores it (e.g.
        # pfrac in non-pruning variants, momentum in eval/infer).
        low = jax.jit(fn, keep_unused=True).lower(*specs)
        text = to_hlo_text(low)
        fname = pname + ".hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        ins, outs = _iospec(specs, low.out_info)
        for d, n in zip(ins, in_names):
            d["name"] = n
        for d, n in zip(outs, out_names):
            d["name"] = n
        programs[pname] = {"file": fname, "inputs": ins, "outputs": outs}
        print(f"  {name}/{pname}: {len(text)} chars "
              f"({time.time() - t0:.1f}s)")

    state_names = [n for n, _, _, _ in sd.entries]
    lower("init", train.make_init(sd, meta, qcfg), (scalar_i,),
          ["seed"], list(state_names))
    lower("train_step", train.make_train_step(sd, meta, qcfg),
          (x_spec, t_spec, scalar_f, scalar_f, scalar_f, *state_specs),
          ["x", "t", "lr", "aux", "pfrac"] + state_names,
          ["loss"] + state_names)
    lower("eval_step", train.make_eval_step(sd, meta, qcfg),
          (x_spec, t_spec, *state_specs),
          ["x", "t"] + state_names, ["loss_sum", "correct"])
    lower("infer", train.make_infer(sd, meta, qcfg),
          (x_spec, *state_specs), ["x"] + state_names, ["out"])

    manifest = {
        "name": name,
        "config": cfg,
        "meta": meta,
        "qlayers": qcfg["qlayers"],
        "graph": graph,
        "state": [{"name": n, "shape": list(sh), "dtype": dt, "role": role}
                  for n, sh, dt, role in sd.entries],
        "programs": programs,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    with open(stamp_path, "w") as f:
        f.write(stamp)
    return "built"


def _sources_digest() -> bytes:
    h = hashlib.sha256()
    root = os.path.dirname(__file__)
    for base, _, files in sorted(os.walk(root)):
        for fn in sorted(files):
            if fn.endswith(".py"):
                with open(os.path.join(base, fn), "rb") as f:
                    h.update(f.read())
    return h.digest()


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", action="append", default=[],
                    help="preset name (repeatable)")
    ap.add_argument("--all", action="store_true", help="build every preset")
    ap.add_argument("--core", action="store_true",
                    help="build the core set used by tests/examples")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--config", help="path to a custom artifact config json")
    ap.add_argument("--name", help="artifact name for --config")
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    reg = presets()
    if args.list:
        for k in sorted(reg):
            print(k)
        return

    todo = []
    if args.config:
        with open(args.config) as f:
            cfg = json.load(f)
        todo.append((args.name or os.path.splitext(
            os.path.basename(args.config))[0], cfg))
    core = ["quickstart_mlp", "cifar_fp32", "cifar_lutq4", "cifar_lutq2",
            "cifar_lutq4_ml", "cifar_prune4", "voc_fp32", "voc_lutq4"]
    if args.core:
        todo += [(k, reg[k]) for k in core]
    for k in args.preset:
        todo.append((k, reg[k]))
    if args.all:
        todo = sorted(reg.items())
    if not todo:
        todo = [(k, reg[k]) for k in core]

    t0 = time.time()
    for name, cfg in todo:
        status = compile_artifact(name, cfg, args.out, force=args.force)
        print(f"{name}: {status}")
    print(f"total {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
