"""L2 layer zoo + a tiny graph IR.

Models are built as a flat list of op descriptors (see models.py). The same
IR is (a) interpreted here by `forward` to define the JAX computation that
gets AOT-lowered, and (b) serialized into the artifact manifest so the Rust
inference engine (`rust/src/infer/`) can execute the exported quantized
model with exact multiply/shift/add accounting.

Ops:
  conv    {name, cin, cout, k, stride}          NHWC, SAME padding, no bias
  bn      {name, c}                             batch norm (train/eval/mlbn)
  relu    {}                                    + optional activation quant
  maxpool {k, stride}
  gap     {}                                    global average pool -> (B, C)
  flatten {}
  affine  {name, cin, cout}                     bias included
  save    {tag}                                 stash tensor for a residual
  add     {tag, proj: conv-desc|None}           x += maybe_proj(saved[tag])
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.mlbn import mlbn_fold
from .kernels import ref

BN_EPS = 1e-5
BN_MOMENTUM = 0.9


# ---------------------------------------------------------------------------
# op constructors (used by models.py)
# ---------------------------------------------------------------------------

def conv(name, cin, cout, k, stride=1):
    return {"op": "conv", "name": name, "cin": cin, "cout": cout,
            "k": k, "stride": stride}


def bn(name, c):
    return {"op": "bn", "name": name, "c": c}


def relu():
    return {"op": "relu"}


def maxpool(k=2, stride=2):
    return {"op": "maxpool", "k": k, "stride": stride}


def gap():
    return {"op": "gap"}


def flatten():
    return {"op": "flatten"}


def affine(name, cin, cout):
    return {"op": "affine", "name": name, "cin": cin, "cout": cout}


def save(tag):
    return {"op": "save", "tag": tag}


def add(tag, proj=None):
    return {"op": "add", "tag": tag, "proj": proj}


# ---------------------------------------------------------------------------
# primitive layer computations
# ---------------------------------------------------------------------------

def conv2d(x, w, stride):
    """NHWC conv with SAME padding; w is (kh, kw, cin, cout)."""
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def batchnorm_train(x, gamma, beta, rmean, rvar, mlbn=False):
    """Training-mode BN over NHWC (channel last). Returns (y, rmean', rvar').

    With `mlbn` the folded scale gamma/sqrt(var+eps) is pow-2-quantized in
    the forward pass with a straight-through estimator, so the full
    precision gamma keeps learning (paper appendix A): inference then only
    needs shifts and adds.
    """
    axes = tuple(range(x.ndim - 1))
    mean = jnp.mean(x, axis=axes)
    var = jnp.var(x, axis=axes)
    new_rmean = BN_MOMENTUM * rmean + (1.0 - BN_MOMENTUM) * mean
    new_rvar = BN_MOMENTUM * rvar + (1.0 - BN_MOMENTUM) * var
    a = gamma * jax.lax.rsqrt(var + BN_EPS)
    if mlbn:
        a = a + jax.lax.stop_gradient(ref.pow2_quant_ref(a, -12, 12) - a)
    y = a * (x - mean) + beta
    return y, new_rmean, new_rvar


def batchnorm_eval(x, gamma, beta, rmean, rvar, mlbn=False):
    """Inference-mode BN: y = a*x + b with folded constants.

    With `mlbn` the fold goes through the Pallas mlbn kernel (pow-2 scale)."""
    a = gamma * jax.lax.rsqrt(rvar + BN_EPS)
    b = beta - a * rmean
    if mlbn:
        shp = x.shape
        y = mlbn_fold(x.reshape(-1, shp[-1]), a, b)
        return y.reshape(shp)
    return a * x + b


def act_quant(x, bits):
    """Dynamic symmetric uniform activation fake-quant (paper: 8-bit)."""
    if bits <= 0:
        return x
    scale = jnp.max(jnp.abs(x)) / float(2 ** (bits - 1) - 1)
    q = ref.uniform_quant_ref(x, scale, bits)
    return x + jax.lax.stop_gradient(q - x)


# ---------------------------------------------------------------------------
# graph interpreter
# ---------------------------------------------------------------------------

def forward(graph, params, bnstate, x, *, train, quantize_w, act_bits=0,
            mlbn=False):
    """Run the op-list `graph` on input x.

    quantize_w: callable (name, W) -> effective weight used in the forward
      (identity for fp32; LUT-Q tying / pow2 / uniform / ... otherwise —
      see lutq.py). STE is the caller's responsibility.
    Returns (out, new_bnstate) — new_bnstate == bnstate when train=False.
    """
    saved = {}
    new_bn = dict(bnstate)
    for op in graph:
        kind = op["op"]
        if kind == "conv":
            w = quantize_w(op["name"], params[op["name"] + ".w"])
            x = conv2d(x, w, op["stride"])
        elif kind == "bn":
            g = params[op["name"] + ".gamma"]
            b = params[op["name"] + ".beta"]
            rm = bnstate[op["name"] + ".rmean"]
            rv = bnstate[op["name"] + ".rvar"]
            if train:
                x, nrm, nrv = batchnorm_train(x, g, b, rm, rv, mlbn=mlbn)
                new_bn[op["name"] + ".rmean"] = nrm
                new_bn[op["name"] + ".rvar"] = nrv
            else:
                x = batchnorm_eval(x, g, b, rm, rv, mlbn=mlbn)
        elif kind == "relu":
            x = jnp.maximum(x, 0.0)
            x = act_quant(x, act_bits)
        elif kind == "maxpool":
            x = jax.lax.reduce_window(
                x, -jnp.inf, jax.lax.max,
                (1, op["k"], op["k"], 1), (1, op["stride"], op["stride"], 1),
                "VALID")
        elif kind == "gap":
            x = jnp.mean(x, axis=(1, 2))
        elif kind == "flatten":
            x = x.reshape(x.shape[0], -1)
        elif kind == "affine":
            w = quantize_w(op["name"], params[op["name"] + ".w"])
            x = x @ w + params[op["name"] + ".b"]
        elif kind == "save":
            saved[op["tag"]] = x
        elif kind == "add":
            h = saved[op["tag"]]
            if op.get("proj") is not None:
                p = op["proj"]
                w = quantize_w(p["name"], params[p["name"] + ".w"])
                h = conv2d(h, w, p["stride"])
            x = x + h
        else:
            raise ValueError(f"unknown op {kind}")
    return x, new_bn


# ---------------------------------------------------------------------------
# parameter enumeration / init
# ---------------------------------------------------------------------------

def param_specs(graph):
    """Ordered (name, shape, kind) for every trainable parameter.

    kind ∈ {conv_w, affine_w, gamma, beta, affine_b}; conv_w/affine_w are
    the quantizable ones."""
    specs = []
    for op in graph:
        if op["op"] == "conv":
            specs.append((op["name"] + ".w",
                          (op["k"], op["k"], op["cin"], op["cout"]), "conv_w"))
        elif op["op"] == "bn":
            specs.append((op["name"] + ".gamma", (op["c"],), "gamma"))
            specs.append((op["name"] + ".beta", (op["c"],), "beta"))
        elif op["op"] == "affine":
            specs.append((op["name"] + ".w",
                          (op["cin"], op["cout"]), "affine_w"))
            specs.append((op["name"] + ".b", (op["cout"],), "affine_b"))
        elif op["op"] == "add" and op.get("proj") is not None:
            p = op["proj"]
            specs.append((p["name"] + ".w",
                          (p["k"], p["k"], p["cin"], p["cout"]), "conv_w"))
    return specs


def bn_specs(graph):
    specs = []
    for op in graph:
        if op["op"] == "bn":
            specs.append((op["name"] + ".rmean", (op["c"],)))
            specs.append((op["name"] + ".rvar", (op["c"],)))
    return specs


def init_params(graph, key):
    """He-normal init for conv/affine weights, BN gamma=1 beta=0."""
    params = {}
    for name, shape, kind in param_specs(graph):
        if kind in ("conv_w", "affine_w"):
            key, sub = jax.random.split(key)
            fan_in = 1
            for s in shape[:-1]:
                fan_in *= s
            std = jnp.sqrt(2.0 / fan_in)
            params[name] = std * jax.random.normal(sub, shape, jnp.float32)
        elif kind == "gamma":
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            params[name] = jnp.zeros(shape, jnp.float32)
    return params


def init_bnstate(graph):
    state = {}
    for name, shape in bn_specs(graph):
        state[name] = (jnp.zeros(shape, jnp.float32) if name.endswith("rmean")
                       else jnp.ones(shape, jnp.float32))
    return state


def quantizable(graph, first_last_fp=False):
    """Names of layers whose weights get quantized (conv + affine).

    With first_last_fp, the first conv and the last affine stay full
    precision (the apprentice [15] convention; the paper quantizes all)."""
    names = [op["name"] for op in graph if op["op"] in ("conv", "affine")]
    names += [op["proj"]["name"] for op in graph
              if op["op"] == "add" and op.get("proj") is not None]
    # keep graph order for the conv/affine part
    ordered = []
    for op in graph:
        if op["op"] in ("conv", "affine"):
            ordered.append(op["name"])
        elif op["op"] == "add" and op.get("proj") is not None:
            ordered.append(op["proj"]["name"])
    if first_last_fp and len(ordered) >= 2:
        ordered = ordered[1:-1]
    return ordered
