"""Multiplier-less batch-norm fold kernel (paper appendix A).

Inference BN is ``y = a*x + b`` with folded per-channel scale
``a = gamma / sqrt(var + eps)``. For a fully multiplier-less network the
scale must be a power of two so the multiply becomes a shift. The kernel
quantizes ``a`` to pow-2 and applies scale+offset in one pass, tiling rows
of the channels-last activation matrix; ``a``/``b`` stay VMEM-resident.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import ceil_div

ROW_TILE = 8


def _mlbn_kernel(x_ref, a_ref, b_ref, o_ref, *, exp_min: int, exp_max: int):
    x = x_ref[...]   # (ROW_TILE, C)
    a = a_ref[...]   # (1, C)
    b = b_ref[...]   # (1, C)
    absa = jnp.abs(a)
    safe = jnp.maximum(absa, 1e-30)
    e = jnp.clip(jnp.round(jnp.log2(safe)), exp_min, exp_max)
    a_hat = jnp.sign(a) * jnp.exp2(e)
    a_hat = jnp.where(absa < jnp.exp2(float(exp_min) - 1.0), 0.0, a_hat)
    o_ref[...] = (x * a_hat + b).astype(x.dtype)


@functools.partial(jax.jit, static_argnames=("exp_min", "exp_max", "interpret"))
def mlbn_fold(x: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray,
              exp_min: int = -12, exp_max: int = 12, interpret: bool = True):
    """Apply multiplier-less BN to a (rows, C) channels-last matrix."""
    rows, c = x.shape
    rp = (-rows) % ROW_TILE
    xp = jnp.pad(x, ((0, rp), (0, 0))) if rp else x
    tiles = ceil_div(xp.shape[0], ROW_TILE)

    y = pl.pallas_call(
        functools.partial(_mlbn_kernel, exp_min=exp_min, exp_max=exp_max),
        grid=(tiles,),
        in_specs=[
            pl.BlockSpec((ROW_TILE, c), lambda i: (i, 0)),
            pl.BlockSpec((1, c), lambda i: (0, 0)),
            pl.BlockSpec((1, c), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((ROW_TILE, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], c), x.dtype),
        interpret=interpret,
    )(xp, a.reshape(1, c), b.reshape(1, c))

    return y[:rows]
