"""Symmetric uniform fake-quantization kernel (8-bit activations, and the
apprentice-style fixed-grid weight baseline).

The scale is a runtime scalar (dynamic per-batch max-abs for activations),
passed as a (1,1) SMEM-resident block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import TILE, ceil_div, pad_to


def _uniform_kernel(x_ref, s_ref, o_ref, *, lo: float, hi: float):
    x = x_ref[...]
    s = jnp.maximum(s_ref[0, 0], 1e-12)
    o_ref[...] = (jnp.clip(jnp.round(x / s), lo, hi) * s).astype(x.dtype)


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def uniform_quant(x_flat: jnp.ndarray, scale: jnp.ndarray, bits: int = 8,
                  interpret: bool = True):
    """q = clip(round(x/s), -2^{b-1}, 2^{b-1}-1) * s over a flat vector."""
    lo = float(-(2 ** (bits - 1)))
    hi = float(2 ** (bits - 1) - 1)
    n = x_flat.shape[0]
    xp = pad_to(x_flat, TILE)
    tiles = ceil_div(xp.shape[0], TILE)
    x2 = xp.reshape(tiles, TILE)
    s2 = jnp.asarray(scale, x_flat.dtype).reshape(1, 1)

    q = pl.pallas_call(
        functools.partial(_uniform_kernel, lo=lo, hi=hi),
        grid=(tiles,),
        in_specs=[
            pl.BlockSpec((1, TILE), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, TILE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((tiles, TILE), x_flat.dtype),
        interpret=interpret,
    )(x2, s2)

    return q.reshape(-1)[:n]
