"""LUT-Q inference-trick matmul: ``y = x @ d[A]`` with K multiplications.

Paper section 1: an affine layer whose weights are tied to a K-entry
dictionary needs only K multiplications per output accumulator —
``y_bo = sum_k d_k * (sum_{i: A_io=k} x_bi)``. The inner sum is a *binary*
masked matmul (selection + adds, no multiplies); only the outer K-term
combination multiplies.

TPU mapping: per (B-tile, O-tile) grid step the kernel runs K binary-mask
matmuls on the MXU (bf16 ones/zeros) and K scalar-vector multiply-adds on
the VPU. The CUDA analog would bucket inputs in shared memory with atomics;
on TPU the mask-matmul form keeps everything systolic (DESIGN.md
§Hardware-Adaptation).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

B_TILE = 8
O_TILE = 128


def _lutq_mm_kernel(x_ref, a_ref, d_ref, o_ref, *, k: int):
    x = x_ref[...]           # (B_TILE, I)
    a = a_ref[...]           # (I, O_TILE) int32
    d = d_ref[...]           # (1, K)
    acc = jnp.zeros((x.shape[0], a.shape[1]), jnp.float32)
    for kk in range(k):      # K is tiny and static: unrolled
        mask = (a == kk).astype(x.dtype)     # binary -> adds only
        acc = acc + d[0, kk] * jnp.dot(x, mask, preferred_element_type=jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def lutq_matmul(x: jnp.ndarray, d: jnp.ndarray, a: jnp.ndarray,
                interpret: bool = True):
    """Compute y = x @ Q where Q = d[A], x: (B, I), A: (I, O), d: (K,)."""
    bsz, i = x.shape
    _, o = a.shape
    k = d.shape[0]
    bp = (-bsz) % B_TILE
    op = (-o) % O_TILE
    xp = jnp.pad(x, ((0, bp), (0, 0))) if bp else x
    ap = jnp.pad(a, ((0, 0), (0, op))) if op else a
    gb = xp.shape[0] // B_TILE
    go = ap.shape[1] // O_TILE

    y = pl.pallas_call(
        functools.partial(_lutq_mm_kernel, k=k),
        grid=(gb, go),
        in_specs=[
            pl.BlockSpec((B_TILE, i), lambda ib, io: (ib, 0)),
            pl.BlockSpec((i, O_TILE), lambda ib, io: (0, io)),
            pl.BlockSpec((1, k), lambda ib, io: (0, 0)),
        ],
        out_specs=pl.BlockSpec((B_TILE, O_TILE), lambda ib, io: (ib, io)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], ap.shape[1]), x.dtype),
        interpret=interpret,
    )(xp, ap.astype(jnp.int32), d.reshape(1, k))

    return y[:bsz, :o]
