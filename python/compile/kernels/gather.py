"""LUT-Q Step 1 kernel: tied weights ``Q = d[A]``.

On TPU a 1-of-K gather with tiny K is best expressed as a one-hot matmul
``Q = onehot(A) @ d`` — a (TILE, K) x (K, 1) MXU op per tile with the
dictionary VMEM-resident — instead of a serialized dynamic-gather. That is
exactly what this kernel does per grid step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import TILE, ceil_div, pad_to


def _gather_kernel(a_ref, d_ref, q_ref):
    a = a_ref[...].reshape(-1, 1)  # (TILE, 1) int32
    d = d_ref[...]                 # (1, K)
    k = d.shape[-1]
    onehot = (a == jax.lax.broadcasted_iota(jnp.int32, (1, k), 1)).astype(d.dtype)
    q = onehot @ d.reshape(-1, 1)  # (TILE, 1) — MXU on real TPU
    q_ref[...] = q.reshape(1, -1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def lutq_gather(d: jnp.ndarray, a_flat: jnp.ndarray, interpret: bool = True):
    """Expand assignments to tied weights: returns (N,) f32 with Q = d[A]."""
    n = a_flat.shape[0]
    k = d.shape[0]
    ap = pad_to(a_flat.astype(jnp.int32), TILE)
    tiles = ceil_div(ap.shape[0], TILE)
    a2 = ap.reshape(tiles, TILE)
    d2 = d.reshape(1, k)

    q = pl.pallas_call(
        _gather_kernel,
        grid=(tiles,),
        in_specs=[
            pl.BlockSpec((1, TILE), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, TILE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((tiles, TILE), d.dtype),
        interpret=interpret,
    )(a2, d2)

    return q.reshape(-1)[:n]
