"""Power-of-two rounding kernel: ``sign(x) * 2^round(log2|x|)``.

Used (a) to round LUT-Q dictionary entries so affine/conv layers become
multiplier-less (paper section 1), (b) inside the multiplier-less batch norm
(appendix A), and (c) by the INQ baseline. Pure VPU elementwise work — one
(8,128)-shaped VREG tile per step on real TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import TILE, ceil_div, pad_to


def _pow2_kernel(x_ref, o_ref, *, exp_min: int, exp_max: int):
    x = x_ref[...]
    absx = jnp.abs(x)
    safe = jnp.maximum(absx, 1e-30)
    e = jnp.clip(jnp.round(jnp.log2(safe)), exp_min, exp_max)
    q = jnp.sign(x) * jnp.exp2(e)
    underflow = absx < jnp.exp2(float(exp_min) - 1.0)
    o_ref[...] = jnp.where(underflow, 0.0, q).astype(x.dtype)


@functools.partial(jax.jit, static_argnames=("exp_min", "exp_max", "interpret"))
def pow2_quant(x_flat: jnp.ndarray, exp_min: int = -8, exp_max: int = 8,
               interpret: bool = True):
    """Round a flat vector to signed powers of two with clamped exponents."""
    n = x_flat.shape[0]
    xp = pad_to(x_flat, TILE)
    tiles = ceil_div(xp.shape[0], TILE)
    x2 = xp.reshape(tiles, TILE)

    q = pl.pallas_call(
        functools.partial(_pow2_kernel, exp_min=exp_min, exp_max=exp_max),
        grid=(tiles,),
        in_specs=[pl.BlockSpec((1, TILE), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, TILE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((tiles, TILE), x_flat.dtype),
        interpret=interpret,
    )(x2)

    return q.reshape(-1)[:n]
