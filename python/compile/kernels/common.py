"""Shared tiling helpers for the Pallas kernels.

All kernels are lowered with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, so interpret mode is the correctness target and
the BlockSpec structure is the TPU performance story (see DESIGN.md
§Hardware-Adaptation and §Perf-model).
"""
from __future__ import annotations

import jax.numpy as jnp

# Flat elementwise kernels tile the (padded) weight vector in LANE-aligned
# rows: 8 sublanes x 128 lanes is the native f32 VREG shape on TPU.
LANES = 128
SUBLANES = 8
TILE = LANES * SUBLANES  # 1024 elements per grid step


def pad_to(x: jnp.ndarray, multiple: int, value: float = 0.0) -> jnp.ndarray:
    """Pad a 1-D array up to a multiple of `multiple` with `value`."""
    n = x.shape[0]
    rem = (-n) % multiple
    if rem == 0:
        return x
    return jnp.concatenate([x, jnp.full((rem,), value, x.dtype)])


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)
