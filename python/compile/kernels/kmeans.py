"""Fused k-means step kernel — the hot spot of LUT-Q Step 4 (paper Table 1).

Per grid step over a weight tile:
  * assignment: ``A = argmin_k |w - d_k|`` (distance matrix lives in VMEM;
    K <= 256 so the dictionary is VMEM-resident across the whole grid)
  * reduce: per-cluster partial sums and counts via the one-hot trick
    ``sums += onehot(A)^T w`` — on real TPU this is an MXU matmul per tile
    instead of a scatter (TPUs have no fast scatter; see DESIGN.md
    §Hardware-Adaptation).

The partial sums/counts accumulate into a single output block across the
grid (the output BlockSpec maps every step to block 0), which is the
canonical Pallas reduction pattern.

A validity mask makes the padded tail of the flattened weight vector inert:
padded elements still receive an (ignored) assignment but contribute zero to
sums and counts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import TILE, ceil_div, pad_to


def _kmeans_kernel(w_ref, mask_ref, d_ref, a_ref, sums_ref, counts_ref):
    i = pl.program_id(0)

    w = w_ref[...]          # (1, TILE)
    m = mask_ref[...]       # (1, TILE)
    d = d_ref[...]          # (1, K)

    # assignment: (TILE, K) distance matrix
    dist = jnp.abs(w.reshape(-1, 1) - d.reshape(1, -1))
    a = jnp.argmin(dist, axis=-1).astype(jnp.int32)  # (TILE,)
    a_ref[...] = a.reshape(1, -1)

    # one-hot reduce (MXU-shaped: (TILE,K) masked matmul with the weights)
    k = d.shape[-1]
    onehot = (a.reshape(-1, 1) == jax.lax.broadcasted_iota(jnp.int32, (1, k), 1))
    onehot = onehot.astype(w.dtype) * m.reshape(-1, 1)  # mask out padding
    part_sums = jnp.sum(onehot * w.reshape(-1, 1), axis=0).reshape(1, -1)
    part_counts = jnp.sum(onehot, axis=0).reshape(1, -1)

    @pl.when(i == 0)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        counts_ref[...] = jnp.zeros_like(counts_ref)

    sums_ref[...] += part_sums
    counts_ref[...] += part_counts


@functools.partial(jax.jit, static_argnames=("interpret",))
def kmeans_step(w_flat: jnp.ndarray, mask: jnp.ndarray, d: jnp.ndarray,
                interpret: bool = True):
    """One fused assign+reduce over a flat weight vector.

    Args:
      w_flat: (N,) f32 weights (any N; padded internally to TILE multiples)
      mask:   (N,) f32 validity mask (1 = real weight, 0 = e.g. pruned-out
              slot handled by the caller)
      d:      (K,) f32 dictionary

    Returns:
      (a, sums, counts): a is (N,) int32 assignments; sums/counts are (K,)
      masked per-cluster statistics. The centroid update
      ``d_k <- sums_k / counts_k`` (empty clusters keep d_k) is done by the
      caller so pruning / pow-2 constraints can hook in between.
    """
    n = w_flat.shape[0]
    k = d.shape[0]
    wp = pad_to(w_flat, TILE)
    mp = pad_to(mask, TILE)  # pads with 0 -> padded tail is inert
    tiles = ceil_div(wp.shape[0], TILE)
    w2 = wp.reshape(tiles, TILE)
    m2 = mp.reshape(tiles, TILE)
    d2 = d.reshape(1, k)

    a, sums, counts = pl.pallas_call(
        _kmeans_kernel,
        grid=(tiles,),
        in_specs=[
            pl.BlockSpec((1, TILE), lambda i: (i, 0)),
            pl.BlockSpec((1, TILE), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, TILE), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((tiles, TILE), jnp.int32),
            jax.ShapeDtypeStruct((1, k), w_flat.dtype),
            jax.ShapeDtypeStruct((1, k), w_flat.dtype),
        ],
        interpret=interpret,
    )(w2, m2, d2)

    return a.reshape(-1)[:n], sums.reshape(-1), counts.reshape(-1)
