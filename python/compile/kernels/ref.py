"""Pure-jnp reference oracles for every Pallas kernel.

These are the CORE correctness signal: each kernel in this package must match
its `*_ref` here to float tolerance (pytest + hypothesis sweeps in
python/tests/). They are also what the L2 model falls back to for shapes the
tiled kernels do not cover (tiny remainder tiles).
"""
from __future__ import annotations

import jax.numpy as jnp


def kmeans_assign_ref(w: jnp.ndarray, d: jnp.ndarray) -> jnp.ndarray:
    """Paper Table 1, Step 4a: A_ij = argmin_k |W_ij - d_k| (0-based)."""
    dist = jnp.abs(w[..., None] - d)  # (..., K)
    return jnp.argmin(dist, axis=-1).astype(jnp.int32)


def kmeans_stats_ref(w: jnp.ndarray, a: jnp.ndarray, k: int):
    """Per-cluster sums and counts: the reduce half of Step 4b.

    Returns (sums (K,), counts (K,)) with sums_k = sum_{ij: A_ij = k} W_ij.
    """
    onehot = (a[..., None] == jnp.arange(k)).astype(w.dtype)  # (..., K)
    sums = jnp.sum(w[..., None] * onehot, axis=tuple(range(w.ndim)))
    counts = jnp.sum(onehot, axis=tuple(range(w.ndim)))
    return sums, counts


def kmeans_update_ref(w: jnp.ndarray, d: jnp.ndarray):
    """One full k-means iteration (Step 4): returns (A, d_new).

    Empty clusters keep their previous centroid (the standard fix; the
    kernel does the same).
    """
    a = kmeans_assign_ref(w, d)
    sums, counts = kmeans_stats_ref(w, a, d.shape[0])
    d_new = jnp.where(counts > 0, sums / jnp.maximum(counts, 1.0), d)
    return a, d_new


def lutq_gather_ref(d: jnp.ndarray, a: jnp.ndarray) -> jnp.ndarray:
    """Step 1: tied weights Q = d[A]."""
    return d[a]


def pow2_quant_ref(x: jnp.ndarray, exp_min: int = -8, exp_max: int = 8) -> jnp.ndarray:
    """Round to signed powers of two: sign(x) * 2^round(log2 |x|).

    Exponents are clamped to [exp_min, exp_max]; exact zeros stay zero, and
    values with |x| < 2^(exp_min-1) underflow to zero (they would need a
    smaller shift than the hardware budget allows).
    """
    absx = jnp.abs(x)
    safe = jnp.maximum(absx, 1e-30)
    e = jnp.round(jnp.log2(safe))
    e = jnp.clip(e, exp_min, exp_max)
    q = jnp.sign(x) * jnp.exp2(e)
    underflow = absx < jnp.exp2(float(exp_min) - 1.0)
    return jnp.where(underflow, 0.0, q).astype(x.dtype)


def uniform_quant_ref(x: jnp.ndarray, scale: jnp.ndarray, bits: int = 8) -> jnp.ndarray:
    """Symmetric uniform fake-quantization with a given positive scale.

    q = clip(round(x/s), -2^{b-1}, 2^{b-1}-1) * s — the paper's 8-bit
    activation quantization (and the `uniform` / apprentice-style weight
    baseline).
    """
    lo = float(-(2 ** (bits - 1)))
    hi = float(2 ** (bits - 1) - 1)
    s = jnp.maximum(scale, 1e-12)
    return (jnp.clip(jnp.round(x / s), lo, hi) * s).astype(x.dtype)


def mlbn_fold_ref(x: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray,
                  exp_min: int = -12, exp_max: int = 12) -> jnp.ndarray:
    """Multiplier-less BN (paper appendix A): y = pow2(a) * x + b.

    `a` is the folded scale gamma/sqrt(var+eps) per channel (last axis),
    quantized to powers of two so inference needs only shifts and adds.
    """
    a_hat = pow2_quant_ref(a, exp_min, exp_max)
    return x * a_hat + b


def lutq_matmul_ref(x: jnp.ndarray, d: jnp.ndarray, a: jnp.ndarray) -> jnp.ndarray:
    """Inference-trick matmul: y = x @ Q with Q = d[A], computed as
    y_bo = sum_k d_k * (sum_{i: A_io = k} x_bi) — K multiplications per
    output accumulator instead of I (paper section 1).
    """
    k = d.shape[0]
    out = jnp.zeros((x.shape[0], a.shape[1]), x.dtype)
    for kk in range(k):
        mask = (a == kk).astype(x.dtype)  # (I, O) binary -> adds only
        out = out + d[kk] * (x @ mask)
    return out
