"""Kernel-vs-oracle correctness: every Pallas kernel against its pure-jnp
ref, across shapes/dtypes via hypothesis. This is the L1 correctness gate."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.gather import lutq_gather
from compile.kernels.kmeans import kmeans_step
from compile.kernels.lutq_mm import lutq_matmul
from compile.kernels.mlbn import mlbn_fold
from compile.kernels.pow2 import pow2_quant
from compile.kernels.uniform import uniform_quant

RNG = np.random.default_rng(1234)


def randn(*shape):
    return jnp.asarray(RNG.normal(size=shape).astype(np.float32))


# ---------------------------------------------------------------------------
# k-means
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 7, 1024, 1025, 4096, 30000])
@pytest.mark.parametrize("k", [2, 4, 16])
def test_kmeans_matches_ref(n, k):
    w = randn(n)
    d = jnp.sort(randn(k))
    mask = jnp.ones(n, jnp.float32)
    a, sums, counts = kmeans_step(w, mask, d)
    a_ref = ref.kmeans_assign_ref(w, d)
    s_ref, c_ref = ref.kmeans_stats_ref(w, a_ref, k)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(a_ref))
    np.testing.assert_allclose(sums, s_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(counts, c_ref)


def test_kmeans_mask_excludes_elements():
    w = randn(2048)
    d = jnp.array([-1.0, 0.0, 1.0, 2.0])
    mask = (jnp.arange(2048) % 2).astype(jnp.float32)
    _, sums, counts = kmeans_step(w, mask, d)
    assert float(jnp.sum(counts)) == 1024.0
    a_ref = ref.kmeans_assign_ref(w, d)
    sel = np.asarray(mask) > 0
    for k in range(4):
        expect = np.asarray(w)[sel & (np.asarray(a_ref) == k)].sum()
        np.testing.assert_allclose(float(sums[k]), expect, atol=1e-3)


def test_kmeans_iteration_reduces_quantization_error():
    """Step 4 is k-means: each full iteration cannot increase the tying
    MSE sum |w - d[A]|^2 (the Lloyd monotonicity invariant)."""
    w = randn(5000)
    d = jnp.linspace(-2, 2, 8)
    mask = jnp.ones_like(w)

    def mse(w, d, a):
        return float(jnp.mean((w - d[a]) ** 2))

    prev = None
    for _ in range(5):
        a, sums, counts = kmeans_step(w, mask, d)
        d = jnp.where(counts > 0, sums / jnp.maximum(counts, 1.0), d)
        cur = mse(w, d, a)
        if prev is not None:
            assert cur <= prev + 1e-6
        prev = cur


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 5000), k=st.sampled_from([2, 3, 4, 8, 16, 32]),
       seed=st.integers(0, 2**31 - 1))
def test_kmeans_hypothesis(n, k, seed):
    r = np.random.default_rng(seed)
    w = jnp.asarray(r.normal(size=n).astype(np.float32))
    d = jnp.asarray(np.sort(r.normal(size=k)).astype(np.float32))
    a, sums, counts = kmeans_step(w, jnp.ones(n, jnp.float32), d)
    a_ref = ref.kmeans_assign_ref(w, d)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(a_ref))
    assert float(jnp.sum(counts)) == n


# ---------------------------------------------------------------------------
# gather
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,k", [(1, 2), (1000, 4), (1024, 16), (5000, 256)])
def test_gather_matches_ref(n, k):
    d = randn(k)
    a = jnp.asarray(RNG.integers(0, k, size=n).astype(np.int32))
    q = lutq_gather(d, a)
    np.testing.assert_allclose(q, ref.lutq_gather_ref(d, a), rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 4000), k=st.sampled_from([2, 4, 8, 64]),
       seed=st.integers(0, 2**31 - 1))
def test_gather_hypothesis(n, k, seed):
    r = np.random.default_rng(seed)
    d = jnp.asarray(r.normal(size=k).astype(np.float32))
    a = jnp.asarray(r.integers(0, k, size=n).astype(np.int32))
    np.testing.assert_allclose(lutq_gather(d, a), ref.lutq_gather_ref(d, a),
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# pow2
# ---------------------------------------------------------------------------

def test_pow2_exact_values():
    x = jnp.array([0.0, 1.0, -1.0, 0.75, 3.0, -0.126, 1e-12, 300.0])
    q = np.asarray(pow2_quant(x, exp_min=-8, exp_max=8))
    assert q[0] == 0.0
    assert q[1] == 1.0 and q[2] == -1.0
    assert q[3] in (0.5, 1.0)
    assert q[4] == 4.0  # round(log2 3)=round(1.58)=2
    assert q[6] == 0.0  # underflow below 2^-9
    assert q[7] == 256.0  # clamped at exp_max=8

    nz = q[q != 0]
    assert np.all(np.log2(np.abs(nz)) == np.round(np.log2(np.abs(nz))))


@settings(max_examples=30, deadline=None)
@given(n=st.integers(1, 3000), seed=st.integers(0, 2**31 - 1),
       emin=st.integers(-10, -2), emax=st.integers(0, 10))
def test_pow2_hypothesis(n, seed, emin, emax):
    r = np.random.default_rng(seed)
    x = jnp.asarray((r.normal(size=n) * 4).astype(np.float32))
    q = pow2_quant(x, exp_min=emin, exp_max=emax)
    np.testing.assert_allclose(q, ref.pow2_quant_ref(x, emin, emax),
                               rtol=1e-6)
    qn = np.asarray(q)
    nz = qn[qn != 0]
    if nz.size:
        exps = np.log2(np.abs(nz))
        assert np.all(exps == np.round(exps))
        assert exps.min() >= emin and exps.max() <= emax


# ---------------------------------------------------------------------------
# uniform
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [2, 4, 8])
def test_uniform_matches_ref(bits):
    x = randn(3000) * 3
    s = jnp.float32(0.05)
    q = uniform_quant(x, s, bits=bits)
    np.testing.assert_allclose(q, ref.uniform_quant_ref(x, s, bits),
                               rtol=1e-6)
    # grid property: q/s are integers in [-2^{b-1}, 2^{b-1}-1]
    grid = np.asarray(q) / 0.05
    assert np.all(np.abs(grid - np.round(grid)) < 1e-4)
    assert grid.min() >= -(2 ** (bits - 1)) - 1e-4
    assert grid.max() <= 2 ** (bits - 1) - 1 + 1e-4


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 2000), bits=st.sampled_from([2, 3, 4, 8]),
       scale=st.floats(1e-3, 1.0), seed=st.integers(0, 2**31 - 1))
def test_uniform_hypothesis(n, bits, scale, seed):
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.normal(size=n).astype(np.float32))
    s = jnp.float32(scale)
    np.testing.assert_allclose(uniform_quant(x, s, bits=bits),
                               ref.uniform_quant_ref(x, s, bits), rtol=1e-5,
                               atol=1e-6)


# ---------------------------------------------------------------------------
# mlbn
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rows,c", [(1, 8), (16, 32), (100, 17), (8, 128)])
def test_mlbn_matches_ref(rows, c):
    x, a, b = randn(rows, c), randn(c), randn(c)
    y = mlbn_fold(x, a, b)
    np.testing.assert_allclose(y, ref.mlbn_fold_ref(x, a, b), rtol=1e-5,
                               atol=1e-5)


def test_mlbn_scale_is_pow2():
    """The effective scale (y-b)/x must be a power of two per channel."""
    c = 24
    x = jnp.ones((4, c))
    a, b = randn(c), randn(c)
    y = np.asarray(mlbn_fold(x, a, b))
    eff = y[0] - np.asarray(b)
    nz = eff[np.abs(eff) > 1e-9]
    exps = np.log2(np.abs(nz))
    assert np.all(np.abs(exps - np.round(exps)) < 1e-5)


# ---------------------------------------------------------------------------
# lutq matmul (inference K-mult trick)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,i,o,k", [(1, 4, 4, 2), (8, 24, 40, 4),
                                     (13, 64, 129, 16), (8, 128, 128, 8)])
def test_lutq_matmul_matches_ref(b, i, o, k):
    x = randn(b, i)
    d = randn(k)
    a = jnp.asarray(RNG.integers(0, k, size=(i, o)).astype(np.int32))
    y = lutq_matmul(x, d, a)
    np.testing.assert_allclose(y, ref.lutq_matmul_ref(x, d, a), rtol=1e-4,
                               atol=1e-4)


def test_lutq_matmul_equals_dense():
    """The K-mult factorization must equal the dense matmul with Q=d[A]."""
    x = randn(6, 32)
    d = randn(8)
    a = jnp.asarray(RNG.integers(0, 8, size=(32, 20)).astype(np.int32))
    q = d[a]
    np.testing.assert_allclose(lutq_matmul(x, d, a), x @ q, rtol=1e-4,
                               atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(b=st.integers(1, 12), i=st.integers(1, 48), o=st.integers(1, 160),
       k=st.sampled_from([2, 4, 8]), seed=st.integers(0, 2**31 - 1))
def test_lutq_matmul_hypothesis(b, i, o, k, seed):
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.normal(size=(b, i)).astype(np.float32))
    d = jnp.asarray(r.normal(size=k).astype(np.float32))
    a = jnp.asarray(r.integers(0, k, size=(i, o)).astype(np.int32))
    np.testing.assert_allclose(lutq_matmul(x, d, a),
                               ref.lutq_matmul_ref(x, d, a),
                               rtol=1e-3, atol=1e-3)
