"""AOT pipeline tests: artifact structure, manifest consistency, caching."""
import json
import os

import pytest

from compile import aot, layers, models, train


def test_presets_buildable_configs():
    reg = aot.presets()
    assert len(reg) >= 30
    for name, cfg in reg.items():
        g, meta = models.build(cfg["model"])
        assert len(g) > 0, name
        assert cfg["batch_size"] > 0


def test_compile_artifact_and_manifest(tmp_path):
    cfg = {"model": {"arch": "mlp", "input_dim": 8, "hidden": [8],
                     "num_classes": 3},
           "quant": {"method": "lutq", "bits": 2, "pow2": True,
                     "prune": False, "prune_frac": 0.0, "act_bits": 0,
                     "mlbn": False, "first_last_fp": False,
                     "kmeans_iters": 1, "weight_decay": 0.0},
           "batch_size": 4}
    status = aot.compile_artifact("t", cfg, str(tmp_path), force=True)
    assert status == "built"

    d = tmp_path / "t"
    for f in ("init.hlo.txt", "train_step.hlo.txt", "eval_step.hlo.txt",
              "infer.hlo.txt", "manifest.json"):
        assert (d / f).exists()
        assert (d / f).stat().st_size > 0

    m = json.loads((d / "manifest.json").read_text())
    # manifest state layout matches a freshly built StateDef
    g, meta = models.build(cfg["model"])
    qcfg = dict(cfg["quant"])
    qcfg["qlayers"] = layers.quantizable(g, False)
    sd = train.StateDef(g, qcfg)
    assert [e["name"] for e in m["state"]] == [n for n, _, _, _ in sd.entries]

    # program I/O: train_step inputs = x,t,lr,aux,pfrac + state;
    # outputs = loss + state
    ts = m["programs"]["train_step"]
    assert [i["name"] for i in ts["inputs"][:5]] == \
        ["x", "t", "lr", "aux", "pfrac"]
    assert len(ts["inputs"]) == 5 + len(m["state"])
    assert len(ts["outputs"]) == 1 + len(m["state"])
    for i, e in zip(ts["inputs"][5:], m["state"]):
        assert i["shape"] == e["shape"] and i["dtype"] == e["dtype"]

    # init outputs match state
    init = m["programs"]["init"]
    assert len(init["outputs"]) == len(m["state"])

    # eval/infer
    assert [o["name"] for o in m["programs"]["eval_step"]["outputs"]] == \
        ["loss_sum", "correct"]
    assert len(m["programs"]["infer"]["outputs"]) == 1

    # HLO text must start with an HloModule and be id-parseable text
    txt = (d / "train_step.hlo.txt").read_text()
    assert txt.startswith("HloModule")

    # second build is cached; forced rebuild is not
    assert aot.compile_artifact("t", cfg, str(tmp_path)) == "cached"
    assert aot.compile_artifact("t", cfg, str(tmp_path), force=True) == "built"


def test_stamp_invalidates_on_config_change(tmp_path):
    cfg = {"model": {"arch": "mlp", "input_dim": 8, "hidden": [8],
                     "num_classes": 3},
           "quant": {"method": "none", "bits": 32, "pow2": False,
                     "prune": False, "prune_frac": 0.0, "act_bits": 0,
                     "mlbn": False, "first_last_fp": False,
                     "kmeans_iters": 1, "weight_decay": 0.0},
           "batch_size": 4}
    assert aot.compile_artifact("t2", cfg, str(tmp_path)) == "built"
    cfg2 = json.loads(json.dumps(cfg))
    cfg2["batch_size"] = 8
    assert aot.compile_artifact("t2", cfg2, str(tmp_path)) == "built"
