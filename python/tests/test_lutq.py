"""Invariants of the LUT-Q quantizer logic (compile/lutq.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import lutq
from compile.kernels import ref

RNG = np.random.default_rng(7)


def randn(*shape):
    return jnp.asarray(RNG.normal(size=shape).astype(np.float32))


QBASE = {"method": "lutq", "bits": 2, "pow2": False, "prune": False,
         "prune_frac": 0.0, "act_bits": 0, "kmeans_iters": 1,
         "weight_decay": 0.0}


def test_tie_weights_value_and_gradient():
    """Forward value is Q = d[A]; gradient is straight-through to W."""
    w = randn(6, 5)
    d = jnp.array([-1.0, -0.25, 0.25, 1.0])
    a = jnp.asarray(RNG.integers(0, 4, size=(6, 5)).astype(np.int32))

    q = lutq.tie_weights(w, d, a)
    np.testing.assert_allclose(q, d[a], rtol=1e-6)

    g = jax.grad(lambda w_: jnp.sum(lutq.tie_weights(w_, d, a) ** 2))(w)
    # d/dW of sum(Q^2) with STE = 2*Q
    np.testing.assert_allclose(g, 2 * d[a], rtol=1e-5)


def test_init_lut_layer_assigns_nearest():
    qcfg = dict(QBASE, bits=3)
    w = randn(40, 30)
    st = lutq.init_lut_layer(w, qcfg)
    assert st["d"].shape == (8,)
    a_ref = ref.kmeans_assign_ref(w.reshape(-1), st["d"])
    np.testing.assert_array_equal(np.asarray(st["A"]).reshape(-1),
                                  np.asarray(a_ref))


def test_kmeans_update_decreases_tying_mse():
    qcfg = dict(QBASE, bits=4)
    w = randn(2000)
    st = lutq.init_lut_layer(w, qcfg)
    mse0 = float(jnp.mean((w - st["d"][st["A"]]) ** 2))
    for _ in range(4):
        st = lutq.kmeans_update_layer(w, st, qcfg)
    mse1 = float(jnp.mean((w - st["d"][st["A"]]) ** 2))
    assert mse1 <= mse0 + 1e-7


def test_pow2_dict_entries_are_powers_of_two():
    qcfg = dict(QBASE, bits=3, pow2=True)
    w = randn(3000)
    st = lutq.init_lut_layer(w, qcfg)
    st = lutq.kmeans_update_layer(w, st, qcfg)
    d = np.asarray(st["d"])
    nz = d[d != 0]
    exps = np.log2(np.abs(nz))
    assert np.all(np.abs(exps - np.round(exps)) < 1e-5)


@pytest.mark.parametrize("pfrac", [0.3, 0.5, 0.7, 0.9])
def test_prune_pins_fraction_to_zero(pfrac):
    qcfg = dict(QBASE, bits=2, prune=True, prune_frac=pfrac)
    w = randn(4000)
    st = lutq.init_lut_layer(w, qcfg)
    st = lutq.kmeans_update_layer(w, st, qcfg, pfrac=jnp.float32(pfrac))
    a = np.asarray(st["A"])
    d = np.asarray(st["d"])
    assert d[0] == 0.0
    # at least pfrac of weights must be assigned to the zero entry
    assert (a == 0).mean() >= pfrac - 0.01
    # tied weights of pruned entries are exactly zero
    q = d[a]
    assert np.all(q[a == 0] == 0.0)


def test_prune_with_pow2_keeps_zero_entry():
    qcfg = dict(QBASE, bits=3, prune=True, pow2=True, prune_frac=0.5)
    w = randn(2048)
    st = lutq.init_lut_layer(w, qcfg)
    st = lutq.kmeans_update_layer(w, st, qcfg, pfrac=jnp.float32(0.5))
    d = np.asarray(st["d"])
    assert d[0] == 0.0
    nz = d[d != 0]
    exps = np.log2(np.abs(nz))
    assert np.all(np.abs(exps - np.round(exps)) < 1e-5)


def test_bc_weight_is_binary():
    # STE output is w + (q - w), which equals q only to 1 ulp — round before
    # checking uniqueness.
    w = randn(500)
    q = np.round(np.asarray(jax.lax.stop_gradient(lutq.bc_weight(w))), 5)
    vals = np.unique(q)
    assert len(vals) == 2
    np.testing.assert_allclose(vals, [-vals[1], vals[1]])
    np.testing.assert_allclose(vals[1], np.abs(np.asarray(w)).mean(),
                               rtol=1e-4)


def test_twn_weight_is_ternary():
    w = randn(500)
    q = np.round(np.asarray(jax.lax.stop_gradient(lutq.twn_weight(w))), 5)
    vals = np.unique(q)
    assert len(vals) <= 3
    assert 0.0 in vals


def test_inq_freezes_largest_weights():
    w = randn(1000)
    frac = jnp.float32(0.5)
    frozen = np.asarray(lutq.inq_frozen_mask(w, frac))
    absw = np.abs(np.asarray(w))
    # frozen half must all be >= the magnitude of any free weight
    assert absw[frozen].min() >= absw[~frozen].max() - 1e-6
    assert abs(frozen.mean() - 0.5) < 0.02

    q = np.asarray(lutq.inq_weight(w, frac))
    nzf = q[frozen & (q != 0)]
    exps = np.log2(np.abs(nzf))
    assert np.all(np.abs(exps - np.round(exps)) < 1e-5)
    np.testing.assert_allclose(q[~frozen], np.asarray(w)[~frozen])


def test_inq_frac_zero_freezes_nothing():
    w = randn(400)
    frozen = np.asarray(lutq.inq_frozen_mask(w, jnp.float32(0.0)))
    assert not frozen.any()


def test_uniform_weight_grid():
    w = randn(800)
    q = np.asarray(jax.lax.stop_gradient(lutq.uniform_weight(w, 4)))
    scale = np.abs(np.asarray(w)).max() / 7.0
    grid = q / scale
    assert np.all(np.abs(grid - np.round(grid)) < 1e-4)
    assert len(np.unique(np.round(grid))) <= 16


def test_empty_cluster_keeps_centroid():
    qcfg = dict(QBASE, bits=2)
    w = jnp.asarray(np.full(100, 5.0, np.float32))
    st = {"d": jnp.array([-100.0, 0.0, 5.0, 100.0]),
          "A": jnp.full((100,), 2, jnp.int32)}
    st2 = lutq.kmeans_update_layer(w, st, qcfg)
    d2 = np.asarray(st2["d"])
    # clusters 0,1,3 are empty -> keep old centroids; cluster 2 -> mean = 5
    np.testing.assert_allclose(d2, [-100.0, 0.0, 5.0, 100.0])
