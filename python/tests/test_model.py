"""L2 model/graph/train-step tests: shapes, state layout, learning."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import layers, lutq, models, train

RNG = np.random.default_rng(99)


def make(model_cfg, qover=None):
    g, meta = models.build(model_cfg)
    qcfg = {"method": "none", "bits": 32, "pow2": False, "prune": False,
            "prune_frac": 0.0, "act_bits": 0, "mlbn": False,
            "kmeans_iters": 1, "weight_decay": 0.0}
    if qover:
        qcfg.update(qover)
    qcfg["qlayers"] = layers.quantizable(g, qcfg.get("first_last_fp", False))
    sd = train.StateDef(g, qcfg)
    return g, meta, qcfg, sd


ARCHS = [
    {"arch": "mlp", "input_dim": 32, "hidden": [16], "num_classes": 5},
    {"arch": "convnet", "hw": 16, "width": 4, "num_classes": 3},
    {"arch": "resnet", "depth": 8, "width": 4, "hw": 16, "num_classes": 4},
    {"arch": "tiny_yolo", "hw": 32, "width": 4, "grid": 4, "num_classes": 4},
]


@pytest.mark.parametrize("mcfg", ARCHS, ids=lambda c: c["arch"])
def test_init_and_forward_shapes(mcfg):
    g, meta, qcfg, sd = make(mcfg)
    st = jax.jit(train.make_init(sd, meta, qcfg))(jnp.int32(0))
    assert len(st) == len(sd.entries)
    for arr, (name, shape, dtype, _) in zip(st, sd.entries):
        assert tuple(arr.shape) == tuple(shape), name
    b = 2
    if meta["arch"] == "mlp":
        x = jnp.zeros((b, meta["input"][0]))
    else:
        x = jnp.zeros((b, *meta["input"]))
    out, = jax.jit(train.make_infer(sd, meta, qcfg))(x, *st)
    if meta["head"] == "classify":
        assert out.shape == (b, meta["num_classes"])
    else:
        s = meta["grid"]
        assert out.shape == (b, s, s, 5 + meta["num_classes"])


def test_resnet_depth_asserts():
    with pytest.raises(AssertionError):
        models.resnet(depth=9)


def test_param_count_resnet20():
    """ResNet-20 (width 16) has ~0.27M params — the paper's CIFAR net."""
    g, _ = models.resnet(depth=20, width=16)
    n = sum(int(np.prod(s)) for _, s, _ in layers.param_specs(g))
    assert 0.25e6 < n < 0.30e6


@pytest.mark.parametrize("method,qover", [
    ("none", {}),
    ("lutq", {"method": "lutq", "bits": 2, "pow2": True, "act_bits": 8}),
    ("lutq_prune", {"method": "lutq", "bits": 2, "prune": True,
                    "prune_frac": 0.3}),
    ("lutq_mlbn", {"method": "lutq", "bits": 4, "mlbn": True}),
    ("uniform", {"method": "uniform", "bits": 4}),
    ("inq", {"method": "inq", "bits": 4}),
    ("bc", {"method": "bc", "bits": 1}),
    ("twn", {"method": "twn", "bits": 2}),
], ids=lambda x: x if isinstance(x, str) else "")
def test_train_step_learns_every_method(method, qover):
    """A few steps on one fixed batch must reduce the loss (overfit test)
    for every quantization method — this exercises the full Table-1 loop."""
    mcfg = {"arch": "mlp", "input_dim": 16, "hidden": [32], "num_classes": 4}
    g, meta, qcfg, sd = make(mcfg, qover)
    st = jax.jit(train.make_init(sd, meta, qcfg))(jnp.int32(3))
    ts = jax.jit(train.make_train_step(sd, meta, qcfg))
    x = jnp.asarray(RNG.normal(size=(32, 16)).astype(np.float32))
    labels = jnp.asarray(RNG.integers(0, 4, size=32))
    t = jax.nn.one_hot(labels, 4)
    aux = jnp.float32(0.5 if method == "inq" else 0.0)
    pfrac = jnp.float32(qover.get("prune_frac", 0.0))

    losses = []
    state = st
    for i in range(30):
        out = ts(x, t, jnp.float32(0.1), aux, pfrac, *state)
        losses.append(float(out[0]))
        state = out[1:]
    assert losses[-1] < losses[0] * 0.8, losses


def test_eval_step_counts_correct():
    mcfg = {"arch": "mlp", "input_dim": 8, "hidden": [8], "num_classes": 2}
    g, meta, qcfg, sd = make(mcfg)
    st = jax.jit(train.make_init(sd, meta, qcfg))(jnp.int32(0))
    es = jax.jit(train.make_eval_step(sd, meta, qcfg))
    x = jnp.asarray(RNG.normal(size=(16, 8)).astype(np.float32))
    t = jax.nn.one_hot(jnp.zeros(16, jnp.int32), 2)
    loss_sum, correct = es(x, t, *st)
    assert 0.0 <= float(correct) <= 16.0
    assert float(loss_sum) > 0.0


def test_bn_running_stats_update():
    mcfg = {"arch": "convnet", "hw": 8, "width": 4, "num_classes": 2}
    g, meta, qcfg, sd = make(mcfg)
    st = jax.jit(train.make_init(sd, meta, qcfg))(jnp.int32(0))
    ts = jax.jit(train.make_train_step(sd, meta, qcfg))
    x = jnp.asarray(RNG.normal(size=(8, 8, 8, 3)).astype(np.float32) + 3.0)
    t = jax.nn.one_hot(jnp.zeros(8, jnp.int32), 2)
    out = ts(x, t, jnp.float32(0.0), jnp.float32(0.0), jnp.float32(0.0), *st)
    # find a bn rmean entry and verify the running stats moved
    idx = [i for i, (n, _, _, r) in enumerate(sd.entries)
           if r == "bnstate" and n.endswith("rmean")][0]
    before = np.asarray(st[idx])
    after = np.asarray(out[1 + idx])
    assert not np.allclose(before, after)
    # momentum form: new = 0.9*old + 0.1*batch_mean, old = 0 -> |new| <= |bm|
    assert np.all(np.abs(after) <= np.abs(before) + 1e3)


def test_quantizable_first_last_fp():
    g, _ = models.resnet(depth=8, width=4)
    all_q = layers.quantizable(g, False)
    trimmed = layers.quantizable(g, True)
    assert all_q[0] == "stem" and all_q[-1] == "head"
    assert trimmed == all_q[1:-1]


def test_statedef_pack_unpack_roundtrip():
    mcfg = {"arch": "resnet", "depth": 8, "width": 4, "hw": 16,
            "num_classes": 4}
    g, meta, qcfg, sd = make(mcfg, {"method": "lutq", "bits": 2})
    st = jax.jit(train.make_init(sd, meta, qcfg))(jnp.int32(0))
    params, lut, bn, mom = sd.unpack(st)
    repacked = sd.pack(params, lut, bn, mom)
    for a, b in zip(st, repacked):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_yolo_loss_decreases_on_fixed_batch():
    mcfg = {"arch": "tiny_yolo", "hw": 32, "width": 4, "grid": 4,
            "num_classes": 4}
    g, meta, qcfg, sd = make(mcfg, {"method": "lutq", "bits": 4})
    st = jax.jit(train.make_init(sd, meta, qcfg))(jnp.int32(0))
    ts = jax.jit(train.make_train_step(sd, meta, qcfg))
    x = jnp.asarray(RNG.normal(size=(4, 32, 32, 3)).astype(np.float32))
    tgt = np.zeros((4, 4, 4, 9), np.float32)
    tgt[:, 1, 2, 0] = 1.0   # one object per image
    tgt[:, 1, 2, 1:5] = 0.5
    tgt[:, 1, 2, 5] = 1.0
    t = jnp.asarray(tgt)
    losses = []
    state = st
    for _ in range(20):
        # lr 0.05 diverges on the YOLO loss (unbounded twh MSE); 0.01 learns
        out = ts(x, t, jnp.float32(0.01), jnp.float32(0.0), jnp.float32(0.0),
                 *state)
        losses.append(float(out[0]))
        state = out[1:]
    assert losses[-1] < losses[0] * 0.8
