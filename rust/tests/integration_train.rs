//! Integration: full Trainer loop over AOT artifacts — learning,
//! determinism, schedules, checkpoint resume, pruning.

mod common;

use lutq::params::export::QuantizedModel;
use lutq::{LrSchedule, TrainConfig, Trainer};

fn quiet() {
    lutq::util::set_log_level(1);
}

#[test]
fn training_reduces_loss_and_eval_error() {
    quiet();
    let Some(rt) = common::runtime() else { return };
    let cfg = TrainConfig::new("quickstart_mlp")
        .steps(80)
        .seed(3)
        .data_lens(1024, 256);
    let trainer = Trainer::new(&rt, cfg).expect("trainer");
    let res = trainer.run().expect("run");
    let first: f32 = res.loss_history[..5].iter().map(|(_, l)| l).sum::<f32>()
        / 5.0;
    let last: f32 = res.loss_history[res.loss_history.len() - 5..]
        .iter()
        .map(|(_, l)| l)
        .sum::<f32>()
        / 5.0;
    assert!(last < first * 0.5, "loss {first} -> {last}");
    // the flat-vector task is easy: a trained MLP must beat chance by far
    assert!(res.eval_error < 0.5, "eval error {}", res.eval_error);
}

#[test]
fn same_seed_same_losses() {
    quiet();
    let Some(rt) = common::runtime() else { return };
    let mk = || {
        TrainConfig::new("quickstart_mlp")
            .steps(10)
            .seed(11)
            .data_lens(512, 128)
            .workers(3) // prefetcher must preserve deterministic order
    };
    let r1 = Trainer::new(&rt, mk()).unwrap().run().unwrap();
    let r2 = Trainer::new(&rt, mk()).unwrap().run().unwrap();
    assert_eq!(r1.loss_history, r2.loss_history);

    let r3 = Trainer::new(&rt, mk().seed(12)).unwrap().run().unwrap();
    assert_ne!(r1.loss_history, r3.loss_history);
}

#[test]
fn workers_zero_matches_prefetched() {
    quiet();
    let Some(rt) = common::runtime() else { return };
    let mk = |w: usize| {
        TrainConfig::new("quickstart_mlp")
            .steps(6)
            .seed(5)
            .data_lens(256, 64)
            .workers(w)
    };
    let sync = Trainer::new(&rt, mk(0)).unwrap().run().unwrap();
    let pre = Trainer::new(&rt, mk(2)).unwrap().run().unwrap();
    // Synchronous Batcher and Prefetcher draw identical index orders only
    // on the first epoch; with 256 examples and 6x32 draws we stay inside
    // epoch 0, so losses must match exactly.
    assert_eq!(sync.loss_history, pre.loss_history);
}

#[test]
fn checkpoint_roundtrip_preserves_eval() {
    quiet();
    let Some(rt) = common::runtime() else { return };
    let dir = std::env::temp_dir()
        .join(format!("lutq_it_ckpt_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let mut cfg = TrainConfig::new("quickstart_mlp")
        .steps(40)
        .seed(4)
        .data_lens(512, 128);
    cfg.checkpoint_dir = Some(dir.clone());
    cfg.checkpoint_every = 20;
    let trainer = Trainer::new(&rt, cfg).expect("trainer");
    let res = trainer.run().expect("run");

    // find the newest checkpoint
    let ckpt = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().is_some_and(|e| e == "ckpt"))
        .max()
        .expect("checkpoint written");
    let (state, step) = trainer.state_from_checkpoint(&ckpt).expect("load");
    assert!(step > 0);
    let (loss, err) = trainer.evaluate(&state).expect("eval");
    assert!(loss.is_finite());
    assert!((0.0..=1.0).contains(&err));
    let _ = res;
    std::fs::remove_dir_all(dir).unwrap();
}

#[test]
fn pruning_schedule_reaches_target_sparsity() {
    quiet();
    let Some(rt) = common::runtime() else { return };
    if !common::have(&rt, "cifar_prune4") {
        return;
    }
    let cfg = TrainConfig::new("cifar_prune4")
        .steps(30)
        .seed(6)
        .data_lens(512, 128)
        .prune(0.6);
    let trainer = Trainer::new(&rt, cfg).expect("trainer");
    let res = trainer.run().expect("run");
    let model =
        QuantizedModel::from_state(&res.state, &res.manifest.qlayers);
    // zero entry pinned in every layer dictionary
    for l in &model.lut_layers {
        assert_eq!(l.dict[0], 0.0, "layer {}", l.name);
    }
    // overall sparsity reaches ~ the scheduled target (ramp completes at
    // steps/3 after warmup steps/10; by the end it's at 0.6)
    let total: f32 = model.lut_layers.iter().map(|l| l.n() as f32).sum();
    let sparsity: f32 = model
        .lut_layers
        .iter()
        .map(|l| l.sparsity() * l.n() as f32)
        .sum::<f32>()
        / total;
    assert!(sparsity > 0.55, "sparsity {sparsity}");
}

#[test]
fn lr_schedule_is_fed_to_artifact() {
    quiet();
    let Some(rt) = common::runtime() else { return };
    // lr=0 must freeze the full-precision shadow weights (Step 3 is a
    // no-op). The k-means Step 4 still updates (d, A) each minibatch —
    // that is the algorithm — so we assert on the *params*, not the loss.
    let cfg = TrainConfig::new("quickstart_mlp")
        .steps(6)
        .seed(9)
        .data_lens(64, 32)
        .lr(LrSchedule::constant(0.0));
    let trainer = Trainer::new(&rt, cfg).expect("trainer");
    let init = trainer.init_state().expect("init");
    let init_store =
        lutq::runtime::state_to_store(&init, &trainer.manifest.state)
            .unwrap();
    let res = trainer.run().expect("run");
    for e in &trainer.manifest.state {
        if e.role == "param" {
            assert_eq!(
                init_store.get(&e.name).unwrap().as_f32(),
                res.state.get(&e.name).unwrap().as_f32(),
                "param {} moved under lr=0",
                e.name
            );
        }
    }
    // and with a real lr they DO move
    let cfg2 = TrainConfig::new("quickstart_mlp")
        .steps(6)
        .seed(9)
        .data_lens(64, 32)
        .lr(LrSchedule::constant(0.05));
    let res2 = Trainer::new(&rt, cfg2).unwrap().run().unwrap();
    let moved = trainer.manifest.state.iter().any(|e| {
        e.role == "param"
            && init_store.get(&e.name).unwrap().as_f32()
                != res2.state.get(&e.name).unwrap().as_f32()
    });
    assert!(moved);
}

#[test]
fn detection_artifact_trains() {
    quiet();
    let Some(rt) = common::runtime() else { return };
    if !common::have(&rt, "voc_lutq4") {
        return;
    }
    let cfg = TrainConfig::new("voc_lutq4")
        .steps(25)
        .seed(2)
        .data_lens(512, 64);
    let trainer = Trainer::new(&rt, cfg).expect("trainer");
    let res = trainer.run().expect("run");
    let first = res.loss_history[0].1;
    let last = res.loss_history.last().unwrap().1;
    assert!(last < first, "yolo loss {first} -> {last}");
    assert!(res.eval_error.is_nan()); // detection: no classify error
}
