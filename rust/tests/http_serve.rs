//! End-to-end tests for the HTTP serving front: golden predict
//! round-trips against the direct plan reference, 4xx error mapping
//! that must never kill a worker, deadline-aware 429s, and the
//! models/healthz/metrics endpoints. Everything runs on the
//! deterministic testkit models over an ephemeral loopback port — no
//! trained artifacts, no network beyond 127.0.0.1.

use std::sync::Arc;
use std::time::Duration;

use lutq::infer::{ExecMode, KernelBackend, Plan, PlanOptions, Tensor};
use lutq::jsonic::{self, Json};
use lutq::serve::{
    HttpClient, HttpConfig, HttpFront, Registry, Server, ServerConfig,
};
use lutq::testkit::models::{synth_conv_model, synth_mlp_model};
use lutq::util::Rng;

/// Scalar-pinned plan so served-vs-direct comparisons are bit-exact by
/// the serve contract (no SIMD tolerance policy involved).
fn scalar_mlp_plan() -> Plan {
    let (graph, model) = synth_mlp_model(4);
    Plan::compile(
        &graph,
        &model,
        PlanOptions {
            mode: ExecMode::LutTrick,
            act_bits: 0,
            mlbn: false,
            threads: 1,
            kernel: KernelBackend::Scalar,
        },
        &[16],
    )
    .unwrap()
}

fn reference(plan: &Plan, sample: &[f32]) -> Vec<f32> {
    let mut scratch = plan.scratch();
    let x = Tensor::new(vec![1, 16], sample.to_vec());
    plan.run_into(&x, &mut scratch).unwrap();
    scratch.output().1.to_vec()
}

/// (front, server handle, shared plan) on an ephemeral port.
fn start_front() -> (HttpFront, Arc<Server>, Arc<Plan>) {
    let plan = Arc::new(scalar_mlp_plan());
    let mut reg = Registry::new();
    reg.register_shared("mlp", Arc::clone(&plan)).unwrap();
    let server = Arc::new(
        Server::start(
            reg,
            ServerConfig {
                workers: 2,
                max_batch: 4,
                linger: Duration::from_millis(1),
                queue_cap: 64,
                ..Default::default()
            },
        )
        .unwrap(),
    );
    let front = HttpFront::start(
        Arc::clone(&server),
        HttpConfig {
            addr: "127.0.0.1:0".to_string(),
            ..Default::default()
        },
    )
    .unwrap();
    (front, server, plan)
}

fn body_for(sample: &[f32]) -> String {
    format!("{{\"input\":{}}}", Json::from_f32s(sample))
}

#[test]
fn predict_roundtrip_matches_direct_plan_exactly() {
    let (front, server, plan) = start_front();
    let addr = front.addr().to_string();
    let mut client = HttpClient::connect(&addr).unwrap();
    let mut rng = Rng::new(11);
    for _ in 0..5 {
        let sample: Vec<f32> = rng.normals(16);
        let (status, body) =
            client.predict("mlp", &body_for(&sample), None).unwrap();
        assert_eq!(status, 200, "{body}");
        let j = jsonic::parse(&body).unwrap();
        assert_eq!(j.at("model").as_str(), Some("mlp"));
        let got = j.at("output").as_f32_vec().unwrap();
        // numbers survive serialize -> wire -> parse exactly, so the
        // network path is held to the same equality as in-process serve
        assert_eq!(got, reference(&plan, &sample));
    }
    drop(client);
    front.shutdown();
    let server = Arc::try_unwrap(server).ok().expect("clients are gone");
    let reports = server.shutdown();
    assert_eq!(reports[0].requests, 5);
    assert_eq!(reports[0].errors, 0);
}

#[test]
fn client_errors_map_to_4xx_and_never_kill_the_worker() {
    let (front, server, plan) = start_front();
    let addr = front.addr().to_string();
    let mut client = HttpClient::connect(&addr).unwrap();

    // malformed JSON body
    let (status, body) =
        client.predict("mlp", "{\"input\":[1,", None).unwrap();
    assert_eq!(status, 400, "{body}");
    assert!(body.contains("bad_input"), "{body}");

    // body without an input array
    let (status, _) =
        client.predict("mlp", "{\"x\": 3}", None).unwrap();
    assert_eq!(status, 400);

    // wrong input length
    let (status, body) =
        client.predict("mlp", &body_for(&[0.0; 5]), None).unwrap();
    assert_eq!(status, 400, "{body}");
    assert!(body.contains("expects 16"), "{body}");

    // non-finite input values: `1e999` overflows to +inf in any JSON
    // parser, and a NaN would arrive the same way — the predict
    // boundary rejects both (the int kernels would otherwise silently
    // quantize NaN to 0 and ±inf to ±127)
    let mut inf_body = String::from("{\"input\":[1e999");
    for _ in 1..16 {
        inf_body.push_str(",0");
    }
    inf_body.push_str("]}");
    let (status, body) =
        client.predict("mlp", &inf_body, None).unwrap();
    assert_eq!(status, 400, "{body}");
    assert!(body.contains("bad_input"), "{body}");
    assert!(body.contains("not finite"), "{body}");

    // unknown model
    let (status, body) =
        client.predict("nope", &body_for(&[0.0; 16]), None).unwrap();
    assert_eq!(status, 404, "{body}");
    assert!(body.contains("unknown_model"), "{body}");

    // wrong method on predict, unknown path, wrong method on healthz
    let (status, _) =
        client.get("/v1/models/mlp:predict").unwrap();
    assert_eq!(status, 405);
    let (status, _) = client.get("/v1/nothing").unwrap();
    assert_eq!(status, 404);
    let (status, _) = client
        .request("POST", "/healthz", Some("{}"), None)
        .unwrap();
    assert_eq!(status, 405);

    // an unparseable deadline header is a client error, not a panic
    let (status, body) = client
        .request(
            "POST",
            "/v1/models/mlp:predict",
            Some(&body_for(&[0.0; 16])),
            Some(f64::NAN),
        )
        .unwrap();
    assert_eq!(status, 400, "{body}");

    // after all that abuse the same connection still serves correctly
    let sample: Vec<f32> = Rng::new(3).normals(16);
    let (status, body) =
        client.predict("mlp", &body_for(&sample), None).unwrap();
    assert_eq!(status, 200, "{body}");
    let got = jsonic::parse(&body)
        .unwrap()
        .at("output")
        .as_f32_vec()
        .unwrap();
    assert_eq!(got, reference(&plan, &sample));

    drop(client);
    front.shutdown();
    drop(server);
}

#[test]
fn spent_deadline_returns_429_and_lands_in_metrics() {
    let (front, server, _plan) = start_front();
    let addr = front.addr().to_string();
    let mut client = HttpClient::connect(&addr).unwrap();

    // a deadline of 0 ms has no budget left at admission: the request
    // must be turned away with 429 before taking a queue slot
    let (status, body) = client
        .predict("mlp", &body_for(&[0.0; 16]), Some(0.0))
        .unwrap();
    assert_eq!(status, 429, "{body}");
    let j = jsonic::parse(&body).unwrap();
    assert_eq!(j.at("error").as_str(), Some("deadline_exceeded"));

    // the `deadline_ms` JSON field is an equivalent carrier
    let with_field = format!(
        "{{\"input\":{},\"deadline_ms\":0}}",
        Json::from_f32s(&[0.0; 16])
    );
    let (status, _) = client.predict("mlp", &with_field, None).unwrap();
    assert_eq!(status, 429);

    // a generous deadline is admitted and answered
    let (status, _) = client
        .predict("mlp", &body_for(&[0.0; 16]), Some(60_000.0))
        .unwrap();
    assert_eq!(status, 200);

    // both rejections are visible in the /metrics rows
    let (status, metrics) = client.get("/metrics").unwrap();
    assert_eq!(status, 200);
    let rows = jsonic::parse(&metrics).unwrap();
    let row = &rows.as_arr().unwrap()[0];
    assert_eq!(row.at("model").as_str(), Some("mlp"));
    assert_eq!(row.at("rejected").as_usize(), Some(2), "{metrics}");
    assert_eq!(row.at("requests").as_usize(), Some(1));
    // every /metrics row names the resolved kernel backend so operators
    // can tell which hot path a model is actually running on
    assert_eq!(row.at("backend").as_str(), Some("scalar"), "{metrics}");

    drop(client);
    front.shutdown();
    drop(server);
}

/// Overload path: one slow serial worker, a burst of short-deadline
/// requests from many connections. Latecomers must be turned away with
/// 429 (rejected at admission or shed in-queue) instead of being served
/// long past their deadline, and every 200 must still be correct.
#[test]
fn overload_with_deadlines_sheds_instead_of_queueing() {
    let (graph, model) = synth_conv_model(4, false);
    let plan = Arc::new(
        Plan::compile(
            &graph,
            &model,
            PlanOptions {
                mode: ExecMode::LutTrick,
                act_bits: 0,
                mlbn: false,
                threads: 1,
                kernel: KernelBackend::Scalar,
            },
            &[32, 32, 3],
        )
        .unwrap(),
    );
    let mut reg = Registry::new();
    reg.register_shared("conv", Arc::clone(&plan)).unwrap();
    let server = Arc::new(
        Server::start(
            reg,
            ServerConfig {
                workers: 1,
                max_batch: 1,
                linger: Duration::from_millis(0),
                queue_cap: 64,
                ..Default::default()
            },
        )
        .unwrap(),
    );
    let front = HttpFront::start(
        Arc::clone(&server),
        HttpConfig { addr: "127.0.0.1:0".to_string(),
                     ..Default::default() },
    )
    .unwrap();
    let addr = front.addr().to_string();

    let mut rng = Rng::new(9);
    let sample: Vec<f32> = rng.normals(32 * 32 * 3);
    let body = Arc::new(body_for(&sample));
    let n_clients = 8;
    let per_client = 5;
    let mut joins = Vec::new();
    for _ in 0..n_clients {
        let addr = addr.clone();
        let body = Arc::clone(&body);
        joins.push(std::thread::spawn(move || -> (u64, u64, u64) {
            let mut client = HttpClient::connect(&addr).unwrap();
            let (mut ok, mut shed, mut other) = (0, 0, 0);
            for _ in 0..per_client {
                // 3 ms deadline against a serial conv queue: the burst
                // cannot all make it
                let (status, _) =
                    client.predict("conv", &body, Some(3.0)).unwrap();
                match status {
                    200 => ok += 1,
                    429 => shed += 1,
                    _ => other += 1,
                }
            }
            (ok, shed, other)
        }));
    }
    let (mut ok, mut shed, mut other) = (0u64, 0u64, 0u64);
    for j in joins {
        let (o, s, x) = j.join().unwrap();
        ok += o;
        shed += s;
        other += x;
    }
    assert_eq!(other, 0, "only 200/429 are acceptable here");
    assert_eq!(ok + shed, (n_clients * per_client) as u64);
    assert!(shed > 0,
            "a serial worker cannot satisfy a 40-request burst within \
             3 ms each; some must be shed ({ok} ok / {shed} shed)");

    front.shutdown();
    let server = Arc::try_unwrap(server).ok().expect("clients are gone");
    let reports = server.shutdown();
    let r = &reports[0];
    assert_eq!(r.rejected + r.shed + r.requests,
               (n_clients * per_client) as u64,
               "{r:?}");
    assert_eq!(r.rejected + r.shed, shed, "{r:?}");
    assert_eq!(r.errors, 0, "{r:?}");
}

/// The harness `serve-bench --transport http` runs: keep-alive clients
/// driving the closed loop over the wire, every request answered.
#[test]
fn http_closed_loop_drives_the_full_network_path() {
    let (front, server, _plan) = start_front();
    let addr = front.addr().to_string();
    let mut rng = Rng::new(21);
    let pools: lutq::serve::load::SamplePools =
        Arc::new(vec![(0..4).map(|_| rng.normals(16)).collect()]);
    let names = vec!["mlp".to_string()];
    let (lat, secs, stats) = lutq::serve::load::closed_loop_http(
        &addr, &names, &[0], &pools, 20, 4, None)
        .unwrap();
    assert_eq!(stats.ok, 20, "{stats:?}");
    assert_eq!(stats.rejected + stats.failed, 0, "{stats:?}");
    assert_eq!(lat.len(), 20);
    assert!(secs > 0.0);
    assert_eq!(stats.shed_rate(), 0.0);
    front.shutdown();
    let server = Arc::try_unwrap(server).ok().expect("clients gone");
    assert_eq!(server.shutdown()[0].requests, 20);
}

#[test]
fn models_and_healthz_endpoints_describe_the_registry() {
    let (front, server, _plan) = start_front();
    let addr = front.addr().to_string();
    let mut client = HttpClient::connect(&addr).unwrap();

    let (status, body) = client.get("/healthz").unwrap();
    assert_eq!(status, 200);
    let j = jsonic::parse(&body).unwrap();
    assert_eq!(j.at("status").as_str(), Some("ok"));
    assert_eq!(j.at("models").as_usize(), Some(1));

    let (status, body) = client.get("/v1/models").unwrap();
    assert_eq!(status, 200, "{body}");
    let j = jsonic::parse(&body).unwrap();
    let models = j.at("models").as_arr().unwrap();
    assert_eq!(models.len(), 1);
    assert_eq!(models[0].at("name").as_str(), Some("mlp"));
    assert_eq!(models[0].at("input").as_shape(), Some(vec![16]));
    assert_eq!(models[0].at("output").as_shape(), Some(vec![10]));
    assert_eq!(models[0].at("backend").as_str(), Some("scalar"));

    drop(client);
    front.shutdown();
    drop(server);
}
