//! Integration: export path + cross-layer numerics parity — the compiled
//! plan engine must reproduce the AOT `infer` program's outputs on the
//! same trained state (LUT gather, conv SAME padding, BN fold, activation
//! quant all agree), the serve path must answer with per-sample
//! bit-identical logits on the same trained model, and the
//! multiplier-less claims must hold on real trained dictionaries.

mod common;

use std::sync::Arc;
use std::time::Duration;

use lutq::infer::{ExecMode, Plan, PlanOptions, Tensor};
use lutq::params::export::QuantizedModel;
use lutq::runtime::{self};
use lutq::serve::{Registry, Server, ServerConfig};
use lutq::util::stats::argmax;
use lutq::{TrainConfig, Trainer};

fn quiet() {
    lutq::util::set_log_level(1);
}

fn plan_opts(mode: ExecMode, act_bits: usize, mlbn: bool) -> PlanOptions {
    PlanOptions { mode, act_bits, mlbn, threads: 0,
                  ..PlanOptions::default() }
}

#[test]
fn plan_matches_aot_infer_on_trained_model() {
    quiet();
    let Some(rt) = common::runtime() else { return };
    if !common::have(&rt, "cifar_lutq4") {
        return;
    }
    let cfg = TrainConfig::new("cifar_lutq4")
        .steps(20)
        .seed(8)
        .data_lens(512, 128);
    let trainer = Trainer::new(&rt, cfg).expect("trainer");
    let res = trainer.run().expect("run");
    let man = &res.manifest;

    // AOT infer on a fixed eval batch
    let infer = rt.load_program(man, "infer").expect("infer");
    let xs = infer.spec.inputs[0].clone();
    let mut xdata = vec![0f32; xs.elems()];
    // deterministic pseudo-image batch
    for (i, v) in xdata.iter_mut().enumerate() {
        *v = ((i % 97) as f32 / 48.5 - 1.0) * 0.7;
    }
    let mut args = vec![runtime::literal_f32(&xs.shape, &xdata).unwrap()];
    for e in &man.state {
        args.push(
            runtime::host_to_literal(res.state.get(&e.name).unwrap())
                .unwrap(),
        );
    }
    let hlo_out = infer.run(&args).expect("infer run").f32_vec(0).unwrap();

    // compiled plan on the exported model: compile once, reuse scratch
    let model = QuantizedModel::from_state(&res.state, &man.qlayers);
    let plan = Arc::new(
        Plan::compile(
            &man.graph, &model,
            plan_opts(ExecMode::LutTrick, man.act_bits(), man.mlbn()),
            &xs.shape[1..],
        )
        .expect("compile plan"),
    );
    let mut scratch = plan.scratch();
    let x = Tensor::new(xs.shape.clone(), xdata);
    let (logits, counts) = plan.run(&x, &mut scratch).expect("plan run");
    assert_eq!(logits.data.len(), hlo_out.len());

    // numerics agree to float tolerance; argmax agrees everywhere
    let ncls = man.meta.num_classes;
    let mut max_abs = 0f32;
    for (a, b) in logits.data.iter().zip(&hlo_out) {
        max_abs = max_abs.max((a - b).abs());
    }
    assert!(max_abs < 2e-2, "plan vs HLO max abs diff {max_abs}");
    for b in 0..xs.shape[0] {
        let ea = argmax(&logits.data[b * ncls..(b + 1) * ncls]);
        let ha = argmax(&hlo_out[b * ncls..(b + 1) * ncls]);
        assert_eq!(ea, ha, "argmax mismatch at row {b}");
    }
    assert!(counts.lookups > 0);

    // a second run through the same scratch is bit-identical
    let (logits2, counts2) = plan.run(&x, &mut scratch).expect("rerun");
    assert_eq!(logits.data, logits2.data);
    assert_eq!(counts, counts2);

    // serve path on the same trained model: every single-image request
    // through the Server is bit-identical to a direct batch-1 run_into
    // of that image (act-quant plans are capped at batch 1, so batch
    // composition cannot perturb the per-tensor scale)
    let mut registry = Registry::new();
    registry
        .register_shared("trained", Arc::clone(&plan))
        .expect("register");
    let server = Server::start(registry, ServerConfig {
        workers: 2,
        ..Default::default()
    })
    .expect("server");
    let elems: usize = xs.shape[1..].iter().product();
    let tickets: Vec<_> = (0..xs.shape[0])
        .map(|b| {
            server
                .submit("trained", &x.data[b * elems..(b + 1) * elems])
                .expect("submit")
        })
        .collect();
    for (b, t) in tickets.into_iter().enumerate() {
        let got = t
            .wait_timeout(Duration::from_secs(60))
            .expect("served reply");
        let mut dims = vec![1usize];
        dims.extend_from_slice(&xs.shape[1..]);
        let x1 = Tensor::new(
            dims, x.data[b * elems..(b + 1) * elems].to_vec());
        plan.run_into(&x1, &mut scratch).expect("reference");
        assert_eq!(got, scratch.output().1, "served row {b} diverged");
    }
    let reports = server.shutdown();
    assert_eq!(reports[0].requests, xs.shape[0] as u64);
    assert_eq!(reports[0].errors, 0);
}

#[test]
fn trained_pow2_dictionaries_are_multiplierless() {
    quiet();
    let Some(rt) = common::runtime() else { return };
    if !common::have(&rt, "cifar_lutq4") {
        return;
    }
    let cfg = TrainConfig::new("cifar_lutq4")
        .steps(15)
        .seed(1)
        .data_lens(256, 64);
    let res = Trainer::new(&rt, cfg).unwrap().run().unwrap();
    let model = QuantizedModel::from_state(&res.state,
                                           &res.manifest.qlayers);
    // pow2 preset: every trained dictionary entry is 0 or +-2^k
    assert!(model.is_multiplierless());
    // shift-only execution on the REAL trained model: zero multiplies in
    // quantized layers (BN still multiplies unless mlbn artifact)
    let plan = Plan::compile(
        &res.manifest.graph, &model,
        plan_opts(ExecMode::ShiftOnly, 8, true), // force ML-BN folding
        &res.manifest.meta.input,
    )
    .expect("compile plan");
    let mut scratch = plan.scratch();
    let mut dims = vec![1usize];
    dims.extend_from_slice(&res.manifest.meta.input);
    let counts =
        plan.run_into(&Tensor::zeros(dims), &mut scratch).unwrap();
    assert!(counts.is_multiplierless(), "{counts}");
    assert!(counts.shifts > 0);
}

#[test]
fn export_file_roundtrip_preserves_inference() {
    quiet();
    let Some(rt) = common::runtime() else { return };
    let cfg = TrainConfig::new("quickstart_mlp")
        .steps(30)
        .seed(2)
        .data_lens(512, 128);
    let res = Trainer::new(&rt, cfg).unwrap().run().unwrap();
    let model = QuantizedModel::from_state(&res.state,
                                           &res.manifest.qlayers);
    let path = std::env::temp_dir()
        .join(format!("lutq_it_model_{}.bin", std::process::id()));
    model.save(&path).unwrap();
    let loaded = QuantizedModel::load(&path).unwrap();
    std::fs::remove_file(&path).unwrap();

    let input = res.manifest.meta.input[0];
    let x = Tensor::new(vec![2, input],
                        (0..2 * input)
                            .map(|i| (i as f32 * 0.37).sin())
                            .collect());
    let run = |m: &QuantizedModel| {
        let plan = Plan::compile(
            &res.manifest.graph, m,
            plan_opts(ExecMode::LutTrick, 0, false), &[input],
        )
        .expect("compile");
        let mut s = plan.scratch();
        plan.run(&x, &mut s).unwrap().0.data
    };
    assert_eq!(run(&model), run(&loaded));
}

#[test]
fn compression_matches_paper_formula_on_trained_model() {
    quiet();
    let Some(rt) = common::runtime() else { return };
    let cfg = TrainConfig::new("quickstart_mlp")
        .steps(5)
        .seed(3)
        .data_lens(128, 64);
    let res = Trainer::new(&rt, cfg).unwrap().run().unwrap();
    let man = &res.manifest;
    let model = QuantizedModel::from_state(&res.state, &man.qlayers);
    let k = man.dict_size();
    for l in &model.lut_layers {
        let expect_bits =
            k as u64 * 32 + l.n() as u64
                * lutq::quant::bitpack::bits_for(k) as u64;
        assert_eq!(l.stored_bits(), expect_bits, "layer {}", l.name);
    }
}
