//! Integration tests for the serve layer: multi-model bit-exactness
//! against the direct single-batch plan reference, batcher properties
//! under random arrival patterns, graceful shutdown draining, and the
//! manifest-to-registry path. None of these need trained artifacts —
//! they run on the deterministic testkit models.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use lutq::infer::{ExecMode, Plan, PlanOptions, Tensor};
use lutq::runtime::Manifest;
use lutq::serve::{Batcher, Registry, Server, ServerConfig};
use lutq::testkit::forall;
use lutq::testkit::models::{synth_conv_model, synth_mlp_model};
use lutq::util::Rng;

const WAIT: Duration = Duration::from_secs(60);

fn opts(threads: usize) -> PlanOptions {
    PlanOptions { mode: ExecMode::LutTrick, act_bits: 0, mlbn: false,
                  threads, ..PlanOptions::default() }
}

/// Direct single-sample reference: one batch-1 `run_into` per request —
/// the serve acceptance contract.
fn reference(plan: &Plan, sample: &[f32]) -> Vec<f32> {
    let mut dims = vec![1usize];
    dims.extend_from_slice(&plan.input_dims());
    let mut scratch = plan.scratch();
    let x = Tensor::new(dims, sample.to_vec());
    plan.run_into(&x, &mut scratch).unwrap();
    scratch.output().1.to_vec()
}

/// Acceptance: >= 2 registered models, >= 4 workers, every request's
/// logits bit-identical to a direct single-batch `Plan::run_into` of the
/// same input — with coalescing actually happening (all requests are
/// submitted before any reply is awaited).
#[test]
fn server_multi_model_bitwise_matches_single_sample_reference() {
    let (cg, cm) = synth_conv_model(4, false);
    let (mg, mm) = synth_mlp_model(4);
    let conv = Arc::new(Plan::compile(&cg, &cm, opts(1),
                                      &[32, 32, 3]).unwrap());
    let mlp = Arc::new(Plan::compile(&mg, &mm, opts(1), &[16]).unwrap());
    let mut reg = Registry::new();
    reg.register_shared("conv", Arc::clone(&conv)).unwrap();
    reg.register_shared("mlp", Arc::clone(&mlp)).unwrap();
    let server = Server::start(reg, ServerConfig {
        workers: 4,
        max_batch: 6,
        linger: Duration::from_millis(3),
        queue_cap: 256,
        ..Default::default()
    })
    .unwrap();

    let mut rng = Rng::new(42);
    let n_req = 40;
    let samples: Vec<(usize, Vec<f32>)> = (0..n_req)
        .map(|i| {
            if i % 2 == 0 {
                (0, rng.normals(32 * 32 * 3))
            } else {
                (1, rng.normals(16))
            }
        })
        .collect();
    let plans = [&conv, &mlp];
    let expected: Vec<Vec<f32>> = samples
        .iter()
        .map(|(m, s)| reference(plans[*m], s))
        .collect();

    let names = ["conv", "mlp"];
    let tickets: Vec<_> = samples
        .iter()
        .map(|(m, s)| server.submit(names[*m], s).unwrap())
        .collect();
    for (i, (ticket, expect)) in
        tickets.into_iter().zip(&expected).enumerate()
    {
        let got = ticket.wait_timeout(WAIT).unwrap();
        assert_eq!(&got, expect, "request {i} got someone else's logits");
    }
    let reports = server.shutdown();
    assert_eq!(reports.iter().map(|r| r.requests).sum::<u64>(),
               n_req as u64);
    for r in &reports {
        assert_eq!(r.errors, 0, "{r:?}");
        assert!(r.max_batch <= 6, "batch cap violated: {r:?}");
    }
}

/// Batcher property: under random batch caps, linger limits, consumer
/// counts and arrival patterns, every submitted request is answered
/// exactly once, the response matches a sequential `Plan::run_into`
/// reference bit-for-bit, and no batch exceeds the configured cap.
#[test]
fn prop_batcher_exactly_once_bitwise_capped() {
    let (mg, mm) = synth_mlp_model(4);
    let plan = Arc::new(Plan::compile(&mg, &mm, opts(1), &[16]).unwrap());
    let plan_outer = Arc::clone(&plan);
    forall(
        53,
        20,
        |r| {
            vec![1 + r.below(8),  // batch cap
                 r.below(4),      // linger ms
                 r.below(40),     // request count
                 r.below(3),      // arrival pattern
                 1 + r.below(3)]  // consumer threads
        },
        move |p| {
            if p.len() != 5 {
                return Ok(()); // shrunk out of the generator's domain
            }
            let (cap, linger, n, pattern, consumers) =
                (p[0].max(1), p[1], p[2], p[3], p[4].max(1));
            let batcher = Arc::new(Batcher::new(
                vec![cap],
                Duration::from_millis(linger as u64),
                64,
            ));
            let max_seen = Arc::new(AtomicUsize::new(0));
            let mut drains = Vec::new();
            for _ in 0..consumers {
                let bat = Arc::clone(&batcher);
                let plan = Arc::clone(&plan_outer);
                let max_seen = Arc::clone(&max_seen);
                drains.push(std::thread::spawn(move || {
                    let mut scratch = plan.scratch();
                    let mut buf: Vec<f32> = Vec::new();
                    while let Some(batch) = bat.next_batch() {
                        max_seen.fetch_max(batch.len(), Ordering::Relaxed);
                        batch.gather_into(&mut buf);
                        let x = Tensor::new(vec![batch.len(), 16],
                                            buf.clone());
                        plan.run_into(&x, &mut scratch).unwrap();
                        batch.complete(scratch.output().1);
                    }
                }));
            }

            // submit + verify; whatever happens, close the batcher and
            // join the consumers afterwards so nothing leaks blocked
            let plan_ref = Arc::clone(&plan_outer);
            let verdict = (|| -> Result<(), String> {
                let mut rng = Rng::new(7 + n as u64);
                let mut ref_scratch = plan_ref.scratch();
                let mut tickets = Vec::new();
                let mut expected = Vec::new();
                for i in 0..n {
                    let sample: Vec<f32> = rng.normals(16);
                    let x = Tensor::new(vec![1, 16], sample.clone());
                    plan_ref.run_into(&x, &mut ref_scratch).unwrap();
                    expected.push(ref_scratch.output().1.to_vec());
                    tickets.push(
                        batcher
                            .submit(0, sample, None)
                            .map_err(|e| e.to_string())?,
                    );
                    match pattern {
                        1 if i % 3 == 0 => std::thread::sleep(
                            Duration::from_micros(200)),
                        2 if i % 7 == 0 => std::thread::sleep(
                            Duration::from_millis(1)),
                        _ => {}
                    }
                }
                for (i, (t, e)) in
                    tickets.into_iter().zip(&expected).enumerate()
                {
                    let got =
                        t.wait_timeout(WAIT).map_err(|e| e.to_string())?;
                    if &got != e {
                        return Err(format!(
                            "request {i}: response differs from its \
                             sequential reference"
                        ));
                    }
                }
                Ok(())
            })();
            batcher.close();
            let mut consumer_panicked = false;
            for d in drains {
                consumer_panicked |= d.join().is_err();
            }
            verdict?;
            if consumer_panicked {
                return Err("consumer panicked".into());
            }
            let seen = max_seen.load(Ordering::Relaxed);
            if seen > cap {
                return Err(format!("batch of {seen} exceeded cap {cap}"));
            }
            if batcher.queued() != 0 {
                return Err("requests left queued after drain".into());
            }
            Ok(())
        },
    );
}

/// Graceful shutdown answers everything already accepted: requests
/// parked behind a long linger are drained, not dropped.
#[test]
fn shutdown_drains_queued_requests() {
    let (mg, mm) = synth_mlp_model(4);
    let mut reg = Registry::new();
    reg.register("mlp", Plan::compile(&mg, &mm, opts(1), &[16]).unwrap())
        .unwrap();
    // cap 64 + 5s linger: nothing is ripe until shutdown switches the
    // workers into drain mode
    let server = Server::start(reg, ServerConfig {
        workers: 2,
        max_batch: 64,
        linger: Duration::from_secs(5),
        queue_cap: 256,
        ..Default::default()
    })
    .unwrap();
    let mut rng = Rng::new(3);
    let samples: Vec<Vec<f32>> = (0..10).map(|_| rng.normals(16)).collect();
    let tickets: Vec<_> = samples
        .iter()
        .map(|s| server.submit("mlp", s).unwrap())
        .collect();
    let reports = server.shutdown();
    for t in tickets {
        t.wait_timeout(WAIT).expect("drained request must be answered");
    }
    assert_eq!(reports.iter().map(|r| r.requests).sum::<u64>(), 10);
}

/// Batch-coupled plans (per-tensor activation quant) must never
/// coalesce: responses stay bit-identical to the single-sample reference
/// no matter how requests overlap.
#[test]
fn act_quant_plans_are_capped_at_batch_one() {
    let (cg, cm) = synth_conv_model(4, false);
    let coupled = Arc::new(
        Plan::compile(
            &cg,
            &cm,
            PlanOptions { mode: ExecMode::LutTrick, act_bits: 8,
                          mlbn: false, threads: 1,
                          ..PlanOptions::default() },
            &[32, 32, 3],
        )
        .unwrap(),
    );
    assert!(!coupled.batch_invariant());
    let mut reg = Registry::new();
    reg.register_shared("conv8", Arc::clone(&coupled)).unwrap();
    let server = Server::start(reg, ServerConfig {
        workers: 3,
        max_batch: 8,
        linger: Duration::from_millis(2),
        queue_cap: 64,
        ..Default::default()
    })
    .unwrap();
    let mut rng = Rng::new(17);
    let samples: Vec<Vec<f32>> =
        (0..12).map(|_| rng.normals(32 * 32 * 3)).collect();
    let expected: Vec<Vec<f32>> =
        samples.iter().map(|s| reference(&coupled, s)).collect();
    let tickets: Vec<_> = samples
        .iter()
        .map(|s| server.submit("conv8", s).unwrap())
        .collect();
    for (t, e) in tickets.into_iter().zip(&expected) {
        assert_eq!(&t.wait_timeout(WAIT).unwrap(), e);
    }
    let reports = server.shutdown();
    assert_eq!(reports[0].max_batch, 1,
               "batch-variant plan must not coalesce: {:?}", reports[0]);
    assert_eq!(reports[0].requests, 12);
}

/// The manifest path: `Registry::register_manifest` compiles the graph
/// once and the server answers with the same logits as the direct plan.
#[test]
fn registry_serves_models_loaded_from_manifests() {
    let manifest_json = r#"{
      "name": "mlp_serve_test",
      "config": {"batch_size": 4, "quant": {"method":"lutq","bits":2,
                 "pow2":false,"act_bits":0,"mlbn":false}},
      "meta": {"arch": "mlp", "input": [16], "num_classes": 10,
               "head": "classify"},
      "qlayers": ["fc0", "fc1"],
      "graph": [
        {"op":"affine","name":"fc0","cin":16,"cout":32},
        {"op":"relu"},
        {"op":"affine","name":"fc1","cin":32,"cout":10}
      ],
      "state": [],
      "programs": {}
    }"#;
    let j = lutq::jsonic::parse(manifest_json).unwrap();
    let man =
        Manifest::from_json(&j, std::path::Path::new("/tmp/none")).unwrap();
    let (_graph, qmodel) = synth_mlp_model(4);
    let mut reg = Registry::new();
    reg.register_manifest(&man, &qmodel, ExecMode::LutTrick, 1).unwrap();
    assert_eq!(reg.names(), vec!["mlp_serve_test"]);
    let direct = reg.plan("mlp_serve_test").unwrap();
    // quant numerics come from the manifest (act_bits 0 here), so the
    // plan is batch-invariant and free to coalesce
    assert!(direct.batch_invariant());

    let server = Server::start(reg, ServerConfig {
        workers: 2,
        ..Default::default()
    })
    .unwrap();
    let mut rng = Rng::new(23);
    let sample: Vec<f32> = rng.normals(16);
    let got = server.infer("mlp_serve_test", &sample).unwrap();
    assert_eq!(got, reference(&direct, &sample));
    server.shutdown();
}
