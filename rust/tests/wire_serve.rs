//! End-to-end tests for the binary wire front: golden predict
//! round-trips (single and batched frames) against the direct plan
//! reference, malformed byte streams that must surface as typed error
//! frames without killing a worker, deadline-aware 429 frames, router
//! shard hops over `WireReplica` with failover, and the pooled-client
//! retry-once-on-stale-reuse regression for both remote transports.
//! Everything runs on the deterministic testkit models over ephemeral
//! loopback ports — no trained artifacts, no network beyond 127.0.0.1.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use lutq::infer::{ExecMode, KernelBackend, Plan, PlanOptions, Tensor};
use lutq::jsonic;
use lutq::serve::cluster::{Replica, ReplicaError};
use lutq::serve::wire::frame::{
    self, decode_predict, encode_predict_response, frame_bytes,
    read_frame, write_frame,
};
use lutq::serve::{
    HttpReplica, Registry, Router, RouterConfig, Server, ServerConfig,
    WireClient, WireConfig, WireReplica, WireReply, WireServer,
};
use lutq::testkit::forall;
use lutq::testkit::models::synth_mlp_model;
use lutq::util::Rng;

/// Scalar-pinned plan so served-vs-direct comparisons are bit-exact by
/// the serve contract (no SIMD tolerance policy involved).
fn scalar_mlp_plan() -> Plan {
    let (graph, model) = synth_mlp_model(4);
    Plan::compile(
        &graph,
        &model,
        PlanOptions {
            mode: ExecMode::LutTrick,
            act_bits: 0,
            mlbn: false,
            threads: 1,
            kernel: KernelBackend::Scalar,
        },
        &[16],
    )
    .unwrap()
}

fn reference(plan: &Plan, sample: &[f32]) -> Vec<f32> {
    let mut scratch = plan.scratch();
    let x = Tensor::new(vec![1, 16], sample.to_vec());
    plan.run_into(&x, &mut scratch).unwrap();
    scratch.output().1.to_vec()
}

fn mlp_server() -> (Arc<Server>, Arc<Plan>) {
    let plan = Arc::new(scalar_mlp_plan());
    let mut reg = Registry::new();
    reg.register_shared("mlp", Arc::clone(&plan)).unwrap();
    let server = Arc::new(
        Server::start(
            reg,
            ServerConfig {
                workers: 2,
                max_batch: 4,
                linger: Duration::from_millis(1),
                queue_cap: 64,
                ..Default::default()
            },
        )
        .unwrap(),
    );
    (server, plan)
}

/// (wire front, server handle, shared plan) on an ephemeral port.
fn start_front() -> (WireServer, Arc<Server>, Arc<Plan>) {
    let (server, plan) = mlp_server();
    let front = WireServer::start(
        Arc::clone(&server),
        WireConfig {
            addr: "127.0.0.1:0".to_string(),
            ..Default::default()
        },
    )
    .unwrap();
    (front, server, plan)
}

fn rows_of(reply: WireReply) -> Vec<Vec<f32>> {
    match reply {
        WireReply::Outputs(rows) => rows,
        WireReply::Refused(e) => {
            panic!("refused: {} {}: {}", e.status, e.code, e.message)
        }
    }
}

#[test]
fn wire_predict_roundtrip_matches_direct_plan_exactly() {
    let (front, server, plan) = start_front();
    let addr = front.addr().to_string();
    let mut client = WireClient::connect(&addr).unwrap();
    let mut rng = Rng::new(11);
    // single-sample frames: raw f32 bytes both ways, so the wire path
    // is held to bitwise equality with a direct run_into
    for _ in 0..5 {
        let sample: Vec<f32> = rng.normals(16);
        let rows =
            rows_of(client.predict("mlp", &sample, None).unwrap());
        assert_eq!(rows.len(), 1);
        let want = reference(&plan, &sample);
        assert_eq!(rows[0].len(), want.len());
        for (g, w) in rows[0].iter().zip(&want) {
            assert_eq!(g.to_bits(), w.to_bits());
        }
    }
    // one batched frame answers one row per sample, in request order
    let batch: Vec<Vec<f32>> = (0..3).map(|_| rng.normals(16)).collect();
    let refs: Vec<&[f32]> = batch.iter().map(|v| v.as_slice()).collect();
    let rows =
        rows_of(client.predict_batch("mlp", &refs, None).unwrap());
    assert_eq!(rows.len(), 3);
    for (i, row) in rows.iter().enumerate() {
        let want = reference(&plan, &batch[i]);
        for (g, w) in row.iter().zip(&want) {
            assert_eq!(g.to_bits(), w.to_bits(), "row {i}");
        }
    }
    // the JSON-carrying frames answer the HTTP endpoints' bodies
    let (status, body) = client.healthz().unwrap();
    assert_eq!(status, 200, "{body}");
    let j = jsonic::parse(&body).unwrap();
    assert_eq!(j.at("status").as_str(), Some("ok"));
    let (status, body) = client.models().unwrap();
    assert_eq!(status, 200, "{body}");
    let models =
        jsonic::parse(&body).unwrap().at("models").as_arr().unwrap().len();
    assert_eq!(models, 1);
    let (status, body) = client.metrics().unwrap();
    assert_eq!(status, 200, "{body}");
    let rows_json = jsonic::parse(&body).unwrap();
    assert_eq!(
        rows_json.as_arr().unwrap()[0].at("model").as_str(),
        Some("mlp")
    );
    drop(client);
    front.shutdown();
    let server = Arc::try_unwrap(server).ok().expect("clients are gone");
    let reports = server.shutdown();
    // 5 single frames + one 3-sample frame = 8 backend requests
    assert_eq!(reports[0].requests, 8);
    assert_eq!(reports[0].errors, 0);
}

/// The frame parser is total: arbitrary byte soup — random, or a valid
/// frame truncated/mutated — yields typed `WireError`s, never a panic.
#[test]
fn malformed_byte_streams_never_panic_the_parser() {
    forall(
        77,
        300,
        |rng: &mut Rng| -> Vec<u8> {
            match rng.below(3) {
                // pure noise
                0 => (0..rng.below(64))
                    .map(|_| (rng.next_u64() & 0xff) as u8)
                    .collect(),
                // a valid predict frame, severed at a random point
                1 => {
                    let sample: Vec<f32> = rng.normals(4);
                    let bytes = frame::predict_frame_bytes(
                        "mlp",
                        &[&sample],
                        None,
                    )
                    .unwrap();
                    let cut = rng.below(bytes.len() + 1);
                    bytes[..cut].to_vec()
                }
                // a valid predict frame with one byte flipped
                _ => {
                    let sample: Vec<f32> = rng.normals(4);
                    let mut bytes = frame::predict_frame_bytes(
                        "mlp",
                        &[&sample],
                        None,
                    )
                    .unwrap();
                    let at = rng.below(bytes.len());
                    bytes[at] ^= (rng.next_u64() & 0xff) as u8;
                    bytes
                }
            }
        },
        |bytes: &Vec<u8>| -> Result<(), String> {
            // drain the stream: every frame either parses or fails
            // with a typed error; decode any predict bodies too
            let mut r: &[u8] = bytes;
            for _ in 0..4 {
                match read_frame(&mut r) {
                    Ok(f) => {
                        let _ = decode_predict(&f.body);
                    }
                    Err(_) => break,
                }
            }
            Ok(())
        },
    );
}

/// Live-server leg of the fuzz story: garbage byte streams get one
/// `Error` frame (or a close), the worker survives, and a fresh client
/// still predicts correctly afterwards.
#[test]
fn malformed_streams_get_error_frames_and_leave_the_server_alive() {
    let (front, server, plan) = start_front();
    let addr = front.addr().to_string();

    // an HTTP request on the wire port: bad magic -> error frame, close
    let mut raw = TcpStream::connect(&addr).unwrap();
    raw.write_all(b"GET / HTTP/1.1\r\nhost: x\r\n\r\n").unwrap();
    let f = read_frame(&mut raw).unwrap();
    assert_eq!(f.ty, frame::FrameType::Error);
    let e = frame::decode_error(&f.body).unwrap();
    assert_eq!((e.status, e.code.as_str()), (400, "bad_frame"));
    assert!(matches!(
        read_frame(&mut raw),
        Err(frame::WireError::Eof)
    ));

    // a hostile 4 GiB length claim: rejected without allocation
    let mut raw = TcpStream::connect(&addr).unwrap();
    let mut hdr = frame_bytes(frame::FrameType::Health, &[]).unwrap();
    hdr[6..10].copy_from_slice(&u32::MAX.to_le_bytes());
    raw.write_all(&hdr).unwrap();
    let f = read_frame(&mut raw).unwrap();
    assert_eq!(f.ty, frame::FrameType::Error);
    assert_eq!(frame::decode_error(&f.body).unwrap().status, 400);

    // severed mid-body: the declared 64 bytes never arrive
    let mut raw = TcpStream::connect(&addr).unwrap();
    let full = frame_bytes(frame::FrameType::Predict, &[0u8; 64]).unwrap();
    raw.write_all(&full[..full.len() - 40]).unwrap();
    raw.shutdown(std::net::Shutdown::Write).unwrap();
    let f = read_frame(&mut raw).unwrap();
    assert_eq!(f.ty, frame::FrameType::Error);
    assert_eq!(frame::decode_error(&f.body).unwrap().status, 400);

    // a well-framed body that fails decode keeps the connection: the
    // same client follows up with a valid predict on the same socket
    let mut client = WireClient::connect(&addr).unwrap();
    let bad = frame_bytes(frame::FrameType::Predict, &[1, 2, 3]).unwrap();
    match client.request_frame(&bad).unwrap() {
        WireReply::Refused(e) => {
            assert_eq!((e.status, e.code.as_str()), (400, "bad_input"));
        }
        WireReply::Outputs(_) => panic!("garbage body must not predict"),
    }
    let sample: Vec<f32> = Rng::new(3).normals(16);
    let rows = rows_of(client.predict("mlp", &sample, None).unwrap());
    let want = reference(&plan, &sample);
    for (g, w) in rows[0].iter().zip(&want) {
        assert_eq!(g.to_bits(), w.to_bits());
    }

    drop(client);
    front.shutdown();
    drop(server);
}

#[test]
fn spent_deadline_is_refused_with_429_and_lands_in_metrics() {
    let (front, server, _plan) = start_front();
    let addr = front.addr().to_string();
    let mut client = WireClient::connect(&addr).unwrap();

    // a 0 ms deadline has no budget left at admission: the frame must
    // be turned away with the HTTP-equivalent 429 code
    let sample = vec![0.0f32; 16];
    match client.predict("mlp", &sample, Some(0.0)).unwrap() {
        WireReply::Refused(e) => {
            assert_eq!(e.status, 429, "{e:?}");
            assert_eq!(e.code, "deadline_exceeded");
        }
        WireReply::Outputs(_) => panic!("spent deadline must refuse"),
    }
    // a generous deadline is admitted and answered
    let rows =
        rows_of(client.predict("mlp", &sample, Some(60_000.0)).unwrap());
    assert_eq!(rows.len(), 1);

    // the rejection is visible in the metrics frame's rows
    let (status, metrics) = client.metrics().unwrap();
    assert_eq!(status, 200);
    let rows_json = jsonic::parse(&metrics).unwrap();
    let row = &rows_json.as_arr().unwrap()[0];
    assert_eq!(row.at("rejected").as_usize(), Some(1), "{metrics}");
    assert_eq!(row.at("requests").as_usize(), Some(1));

    drop(client);
    front.shutdown();
    drop(server);
}

/// Router shard hops over `WireReplica`: bitwise parity with the direct
/// plan through two real wire fronts, reconciling counters, and
/// failover when one replica's front is killed mid-test.
#[test]
fn two_replica_router_over_wire_hops_matches_reference_and_fails_over() {
    let (server_a, plan) = mlp_server();
    let (server_b, _) = mlp_server();
    let mut fronts: Vec<WireServer> = [&server_a, &server_b]
        .iter()
        .map(|s| {
            WireServer::start(
                Arc::clone(s),
                WireConfig {
                    addr: "127.0.0.1:0".to_string(),
                    // the mid-test kill below joins handlers while the
                    // router still pools idle connections to this
                    // front; a short io timeout bounds that join
                    io_timeout: Duration::from_millis(250),
                    ..Default::default()
                },
            )
            .unwrap()
        })
        .collect();
    let replicas: Vec<Box<dyn Replica>> = fronts
        .iter()
        .map(|f| {
            Box::new(WireReplica::new(&f.addr().to_string()))
                as Box<dyn Replica>
        })
        .collect();
    let router =
        Router::new(replicas, RouterConfig { max_shard: 2,
                                             ..RouterConfig::default() })
            .unwrap();

    let mut rng = Rng::new(29);
    let batch: Vec<Vec<f32>> = (0..5).map(|_| rng.normals(16)).collect();
    let refs: Vec<&[f32]> = batch.iter().map(|v| v.as_slice()).collect();
    let got = router.predict_batch("mlp", &refs, None);
    for (i, r) in got.iter().enumerate() {
        let out = r.as_ref().unwrap_or_else(|e| {
            panic!("sample {i} failed: {e}")
        });
        let want = reference(&plan, &batch[i]);
        assert_eq!(out.len(), want.len());
        for (g, w) in out.iter().zip(&want) {
            assert_eq!(g.to_bits(), w.to_bits(), "sample {i}");
        }
    }
    // a 5-sample batch over max_shard 2 used both replicas
    let reports = router.reports();
    assert!(reports.iter().filter(|r| r.samples > 0).count() == 2,
            "{reports:?}");

    // kill replica 0's wire front mid-test: its pooled connections go
    // stale AND fresh connects fail, so the router must fail the shard
    // over to the survivor — answers stay bit-identical
    fronts.remove(0).shutdown();
    let got = router.predict_batch("mlp", &refs, None);
    for (i, r) in got.iter().enumerate() {
        let out = r.as_ref().unwrap_or_else(|e| {
            panic!("post-kill sample {i} failed: {e}")
        });
        let want = reference(&plan, &batch[i]);
        for (g, w) in out.iter().zip(&want) {
            assert_eq!(g.to_bits(), w.to_bits(), "post-kill sample {i}");
        }
    }
    let t = router.totals();
    assert!(t.reconciles(), "{t:?}");
    assert_eq!(t.completed, 10, "{t:?}");

    drop(router);
    for f in fronts {
        f.shutdown();
    }
    drop(server_a);
    drop(server_b);
}

/// A wire backend that answers exactly one predict frame per
/// connection, then closes — the shape of a server-side idle close.
/// Returns (addr, accept counter); the listener thread is detached.
fn one_shot_wire_backend() -> (String, Arc<AtomicUsize>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let accepts = Arc::new(AtomicUsize::new(0));
    let counter = Arc::clone(&accepts);
    std::thread::spawn(move || {
        for stream in listener.incoming() {
            let Ok(mut s) = stream else { break };
            counter.fetch_add(1, Ordering::SeqCst);
            let Ok(f) = read_frame(&mut s) else { continue };
            let Ok(req) = decode_predict(&f.body) else { continue };
            let rows = vec![vec![1.0f32]; req.samples.len()];
            let body = encode_predict_response(&rows).unwrap();
            let _ = write_frame(
                &mut s,
                frame::FrameType::PredictResponse,
                &body,
            );
            // the connection drops here: any pooled reuse goes stale
        }
    });
    (addr, accepts)
}

/// Regression for the pooled-staleness fix: a transport error on a
/// REUSED pooled connection retries exactly once on a fresh one
/// instead of surfacing a failed shard.
#[test]
fn stale_pooled_wire_connection_is_retried_exactly_once() {
    let (addr, accepts) = one_shot_wire_backend();
    let rep = WireReplica::new(&addr);
    let sample = [0.5f32; 4];

    // first shard: fresh connection, served, then pooled
    let rows = rep.predict_shard("m", &[&sample], None).unwrap();
    assert_eq!(rows, vec![vec![1.0f32]]);
    assert_eq!(accepts.load(Ordering::SeqCst), 1);

    // second shard leases the pooled connection, which the backend has
    // already closed — the retry-once path must recover on a fresh
    // connect (exactly one extra accept), not fail the shard
    let rows = rep.predict_shard("m", &[&sample], None).unwrap();
    assert_eq!(rows, vec![vec![1.0f32]]);
    assert_eq!(accepts.load(Ordering::SeqCst), 2);
}

/// An HTTP backend that answers exactly one predict request per
/// connection, then closes — the `HttpReplica` analog of the wire
/// staleness test above.
fn one_shot_http_backend() -> (String, Arc<AtomicUsize>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let accepts = Arc::new(AtomicUsize::new(0));
    let counter = Arc::clone(&accepts);
    std::thread::spawn(move || {
        for stream in listener.incoming() {
            let Ok(s) = stream else { break };
            counter.fetch_add(1, Ordering::SeqCst);
            let mut reader = BufReader::new(match s.try_clone() {
                Ok(c) => c,
                Err(_) => continue,
            });
            let mut s = s;
            let mut content_len = 0usize;
            loop {
                let mut line = String::new();
                if reader.read_line(&mut line).unwrap_or(0) == 0 {
                    break;
                }
                let t = line.trim();
                if t.is_empty() {
                    break;
                }
                if let Some((k, v)) = t.split_once(':') {
                    if k.trim().eq_ignore_ascii_case("content-length") {
                        content_len = v.trim().parse().unwrap_or(0);
                    }
                }
            }
            let mut body = vec![0u8; content_len];
            let _ = reader.read_exact(&mut body);
            let reply = "{\"model\":\"m\",\"output\":[1.0]}";
            let _ = s.write_all(
                format!(
                    "HTTP/1.1 200 OK\r\ncontent-type: \
                     application/json\r\ncontent-length: {}\r\n\r\n{}",
                    reply.len(),
                    reply
                )
                .as_bytes(),
            );
            // the connection drops here: any pooled reuse goes stale
        }
    });
    (addr, accepts)
}

#[test]
fn stale_pooled_http_connection_is_retried_exactly_once() {
    let (addr, accepts) = one_shot_http_backend();
    let rep = HttpReplica::new(&addr);
    let sample = [0.5f32; 4];

    let rows = rep.predict_shard("m", &[&sample], None).unwrap();
    assert_eq!(rows, vec![vec![1.0f32]]);
    assert_eq!(accepts.load(Ordering::SeqCst), 1);

    let rows = rep.predict_shard("m", &[&sample], None).unwrap();
    assert_eq!(rows, vec![vec![1.0f32]]);
    assert_eq!(accepts.load(Ordering::SeqCst), 2);
}

/// The harness `serve-bench --transport binary` runs: keep-alive wire
/// clients driving the closed loop of pre-encoded frames, every
/// request answered.
#[test]
fn wire_closed_loop_drives_the_full_network_path() {
    let (front, server, _plan) = start_front();
    let addr = front.addr().to_string();
    let mut rng = Rng::new(21);
    let pools: lutq::serve::load::SamplePools =
        Arc::new(vec![(0..4).map(|_| rng.normals(16)).collect()]);
    let names = vec!["mlp".to_string()];
    let (lat, secs, stats) = lutq::serve::load::closed_loop_wire(
        &addr, &names, &[0], &pools, 20, 4, None)
        .unwrap();
    assert_eq!(stats.ok, 20, "{stats:?}");
    assert_eq!(stats.rejected + stats.failed, 0, "{stats:?}");
    assert_eq!(lat.len(), 20);
    assert!(secs > 0.0);
    assert_eq!(stats.shed_rate(), 0.0);
    front.shutdown();
    let server = Arc::try_unwrap(server).ok().expect("clients gone");
    assert_eq!(server.shutdown()[0].requests, 20);
}

/// `ReplicaError` classification through a real wire front: a spent
/// deadline is final (never failover bait), a bad request is the
/// client's fault.
#[test]
fn wire_replica_classifies_refusals_like_http() {
    let (front, server, _plan) = start_front();
    let rep = WireReplica::new(&front.addr().to_string());
    assert!(rep.check_health());
    let infos = rep.model_infos().unwrap();
    assert_eq!(infos.len(), 1);
    assert_eq!(infos[0].name, "mlp");
    assert_eq!(infos[0].input, vec![16]);

    let good = vec![0.0f32; 16];
    let short = vec![0.0f32; 3];
    assert!(matches!(
        rep.predict_shard("nope", &[good.as_slice()], None),
        Err(ReplicaError::BadRequest(_))
    ));
    assert!(matches!(
        rep.predict_shard("mlp", &[short.as_slice()], None),
        Err(ReplicaError::BadRequest(_))
    ));
    assert!(matches!(
        rep.predict_shard(
            "mlp",
            &[good.as_slice()],
            Some(std::time::Instant::now()),
        ),
        Err(ReplicaError::Deadline(_))
    ));
    let rows = rep
        .predict_shard("mlp", &[good.as_slice(), good.as_slice()], None)
        .unwrap();
    assert_eq!(rows.len(), 2);

    // drop the replica first: its pooled idle connections close, so the
    // front's handler threads join without waiting out the io timeout
    drop(rep);
    front.shutdown();
    drop(server);
}
