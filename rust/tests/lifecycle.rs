//! Model-lifecycle integration: zero-downtime version swap under
//! closed-loop load, typed unload refusals, version-qualified predict
//! over both network fronts (HTTP admin endpoints and wire admin
//! frames), and worker autoscaling. Everything runs on deterministic
//! testkit models — no trained artifacts, no network beyond loopback.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use lutq::infer::{ExecMode, KernelBackend, Plan, PlanOptions, Tensor};
use lutq::jsonic::{self, Json};
use lutq::serve::{
    HttpClient, HttpConfig, HttpFront, LifecycleError, Registry, Server,
    ServerConfig, WireClient, WireConfig, WireServer,
};
use lutq::testkit::models::synth_mlp_model;
use lutq::util::Rng;

const WAIT: Duration = Duration::from_secs(60);

/// Scalar-pinned MLP plan (16 -> 32 -> 10); different `k` gives the
/// same shapes with different weights — the version-swap vehicle.
fn mlp_plan(k: usize) -> Arc<Plan> {
    let (graph, model) = synth_mlp_model(k);
    Arc::new(
        Plan::compile(
            &graph,
            &model,
            PlanOptions {
                mode: ExecMode::LutTrick,
                act_bits: 0,
                mlbn: false,
                threads: 1,
                kernel: KernelBackend::Scalar,
            },
            &[16],
        )
        .unwrap(),
    )
}

/// Direct single-sample reference — the serve acceptance contract.
fn reference(plan: &Plan, sample: &[f32]) -> Vec<f32> {
    let mut scratch = plan.scratch();
    let x = Tensor::new(vec![1, 16], sample.to_vec());
    plan.run_into(&x, &mut scratch).unwrap();
    scratch.output().1.to_vec()
}

/// The tentpole acceptance: load `m@v2` and flip the default while a
/// closed loop of clients hammers unversioned `m`. Every response must
/// be bitwise-identical to the direct reference of *one* of the two
/// versions (no torn or mixed-plan batch can produce that), nothing is
/// dropped, and after the flip fresh submits answer v2 while `m@v1`
/// stays addressable.
#[test]
fn hot_swap_under_load_loses_nothing_and_never_mixes_versions() {
    let v1 = mlp_plan(4);
    let v2 = mlp_plan(8);
    let mut reg = Registry::new();
    reg.register_shared("m", Arc::clone(&v1)).unwrap();
    let server = Arc::new(
        Server::start(
            reg,
            ServerConfig {
                workers: 3,
                max_batch: 4,
                linger: Duration::from_millis(1),
                queue_cap: 1024,
                ..Default::default()
            },
        )
        .unwrap(),
    );

    // deterministic sample pool + both references, precomputed
    let mut rng = Rng::new(99);
    let pool: Arc<Vec<Vec<f32>>> =
        Arc::new((0..16).map(|_| rng.normals(16)).collect());
    let ref_v1: Arc<Vec<Vec<f32>>> =
        Arc::new(pool.iter().map(|s| reference(&v1, s)).collect());
    let ref_v2: Arc<Vec<Vec<f32>>> =
        Arc::new(pool.iter().map(|s| reference(&v2, s)).collect());
    for (a, b) in ref_v1.iter().zip(ref_v2.iter()) {
        assert_ne!(a, b, "v1 and v2 must be distinguishable");
    }

    let stop = Arc::new(AtomicBool::new(false));
    let submitted = Arc::new(AtomicU64::new(0));
    let served_v1 = Arc::new(AtomicU64::new(0));
    let served_v2 = Arc::new(AtomicU64::new(0));
    let mut clients = Vec::new();
    for c in 0..4u64 {
        let server = Arc::clone(&server);
        let (pool, ref_v1, ref_v2) =
            (Arc::clone(&pool), Arc::clone(&ref_v1), Arc::clone(&ref_v2));
        let stop = Arc::clone(&stop);
        let (submitted, served_v1, served_v2) = (
            Arc::clone(&submitted),
            Arc::clone(&served_v1),
            Arc::clone(&served_v2),
        );
        clients.push(std::thread::spawn(move || {
            let mut i = c as usize;
            while !stop.load(Ordering::Relaxed) {
                let s = i % pool.len();
                let ticket = server.submit("m", &pool[s]).unwrap();
                submitted.fetch_add(1, Ordering::Relaxed);
                let got = ticket.wait_timeout(WAIT).unwrap();
                if got == ref_v1[s] {
                    served_v1.fetch_add(1, Ordering::Relaxed);
                } else if got == ref_v2[s] {
                    served_v2.fetch_add(1, Ordering::Relaxed);
                } else {
                    panic!(
                        "sample {s}: response matches neither version's \
                         direct reference — torn or mixed-plan batch"
                    );
                }
                i += 1;
            }
        }));
    }

    // let v1 serve some traffic, then hot-load v2 and flip the default
    // mid-load — the blue-green cutover under fire
    let t0 = Instant::now();
    while served_v1.load(Ordering::Relaxed) < 20 {
        assert!(t0.elapsed() < WAIT, "v1 never served");
        std::thread::sleep(Duration::from_millis(1));
    }
    server.load_version("m", "v2", Arc::clone(&v2)).unwrap();
    server.set_default_version("m", "v2").unwrap();
    while served_v2.load(Ordering::Relaxed) < 20 {
        assert!(t0.elapsed() < WAIT, "v2 never took over after the flip");
        std::thread::sleep(Duration::from_millis(1));
    }
    stop.store(true, Ordering::Relaxed);
    for c in clients {
        c.join().expect("client saw a non-reference response");
    }

    // both versions actually served, and both stay addressable by
    // qualified name after the flip
    assert!(served_v1.load(Ordering::Relaxed) >= 20);
    assert!(served_v2.load(Ordering::Relaxed) >= 20);
    let got = server.infer("m@v1", &pool[0]).unwrap();
    assert_eq!(got, ref_v1[0], "m@v1 must keep answering v1 logits");
    let got = server.infer("m", &pool[0]).unwrap();
    assert_eq!(got, ref_v2[0], "unversioned m must answer v2 now");

    // the old default can be retired once it is no longer the default;
    // its qualified name then 404s while v2 keeps serving
    server.unload_version("m", "v1").unwrap();
    assert!(server.infer("m@v1", &pool[0]).is_err());
    assert_eq!(server.infer("m", &pool[1]).unwrap(), ref_v2[1]);

    // totals reconcile: nothing dropped, nothing double-answered (the
    // +3 covers the three direct infer() calls above)
    let total = submitted.load(Ordering::Relaxed) + 3;
    let answered = served_v1.load(Ordering::Relaxed)
        + served_v2.load(Ordering::Relaxed)
        + 3;
    assert_eq!(total, answered);
    let reports = server.shutdown();
    assert_eq!(
        reports.iter().map(|r| r.requests).sum::<u64>(),
        total,
        "per-slot counters must reconcile with the client-side count"
    );
    for r in &reports {
        assert_eq!(r.errors, 0, "{r:?}");
        assert!(!r.version.is_empty(), "reports must carry the version");
    }
}

/// Unloading the version that answers unversioned requests is refused
/// with the typed conflict, not a panic or a silent drop.
#[test]
fn unloading_the_default_version_is_a_typed_conflict() {
    let mut reg = Registry::new();
    reg.register_shared("m", mlp_plan(4)).unwrap();
    let server = Server::start(reg, ServerConfig {
        workers: 1,
        ..Default::default()
    })
    .unwrap();
    match server.unload_version("m", "v1") {
        Err(LifecycleError::DefaultInUse(msg)) => {
            assert!(msg.contains("default"), "{msg}");
        }
        other => panic!("expected DefaultInUse, got {other:?}"),
    }
    // unknowns stay typed too
    assert!(matches!(server.unload_version("nope", "v1"),
                     Err(LifecycleError::UnknownModel(_))));
    assert!(matches!(server.unload_version("m", "v9"),
                     Err(LifecycleError::UnknownVersion(_))));
    server.shutdown();
}

/// Version-qualified predict and the full admin lifecycle over both
/// network fronts: load v2 through the HTTP admin endpoint, flip the
/// default through a wire admin frame, and check both fronts serve
/// version-addressed requests bitwise-identically to the direct plans.
#[test]
fn admin_lifecycle_over_http_and_wire_fronts() {
    let v1 = mlp_plan(4);
    let v2 = mlp_plan(8);
    let mut reg = Registry::new();
    reg.register_shared("mlp", Arc::clone(&v1)).unwrap();
    let server = Arc::new(
        Server::start(
            reg,
            ServerConfig { workers: 2, ..Default::default() },
        )
        .unwrap(),
    );
    // the test loader compiles the spec's `k` — what `lutq serve`
    // installs from the CLI, minus the artifact-file paths
    server.set_loader(Box::new(|spec| {
        let k = spec
            .get("k")
            .and_then(|j| j.as_usize())
            .ok_or_else(|| anyhow::anyhow!("spec needs `k`"))?;
        let (graph, model) = synth_mlp_model(k);
        Ok(Arc::new(Plan::compile(
            &graph,
            &model,
            PlanOptions {
                mode: ExecMode::LutTrick,
                act_bits: 0,
                mlbn: false,
                threads: 1,
                kernel: KernelBackend::Scalar,
            },
            &[16],
        )?))
    }));
    let front = HttpFront::start(
        Arc::clone(&server),
        HttpConfig { addr: "127.0.0.1:0".to_string(),
                     ..Default::default() },
    )
    .unwrap();
    let wire = WireServer::start(
        Arc::clone(&server),
        WireConfig { addr: "127.0.0.1:0".to_string(),
                     ..Default::default() },
    )
    .unwrap();
    let mut hc = HttpClient::connect(&front.addr().to_string()).unwrap();
    let mut wc = WireClient::connect(&wire.addr().to_string()).unwrap();

    let mut rng = Rng::new(5);
    let sample: Vec<f32> = rng.normals(16);
    let body = format!("{{\"input\":{}}}", Json::from_f32s(&sample));
    let want_v1 = reference(&v1, &sample);
    let want_v2 = reference(&v2, &sample);

    // load v2 over the HTTP admin endpoint (version in the body)
    let (status, reply) = hc
        .request("POST", "/v1/models/mlp:load",
                 Some("{\"version\":\"v2\",\"k\":8}"), None)
        .unwrap();
    assert_eq!(status, 200, "{reply}");
    let j = jsonic::parse(&reply).unwrap();
    assert_eq!(j.at("version").as_str(), Some("v2"));

    // duplicate load -> 409; bad spec -> 500 with the loader's message
    let (status, reply) = hc
        .request("POST", "/v1/models/mlp@v2:load", Some("{\"k\":8}"),
                 None)
        .unwrap();
    assert_eq!(status, 409, "{reply}");
    let (status, reply) = hc
        .request("POST", "/v1/models/mlp:load",
                 Some("{\"version\":\"v3\"}"), None)
        .unwrap();
    assert_eq!(status, 500, "{reply}");
    assert!(reply.contains("needs `k`"), "{reply}");

    // version-qualified predict over both fronts, bitwise against the
    // direct plans; unversioned still answers the v1 default
    for (model, want) in
        [("mlp@v1", &want_v1), ("mlp@v2", &want_v2), ("mlp", &want_v1)]
    {
        let (status, reply) = hc.predict(model, &body, None).unwrap();
        assert_eq!(status, 200, "{model}: {reply}");
        let got = jsonic::parse(&reply)
            .unwrap()
            .at("output")
            .as_f32_vec()
            .unwrap();
        assert_eq!(&got, want, "http {model}");
        match wc.predict(model, &sample, None).unwrap() {
            lutq::serve::WireReply::Outputs(rows) => {
                assert_eq!(&rows[0], want, "wire {model}");
            }
            r => panic!("wire {model} refused: {r:?}"),
        }
    }

    // flip the default through a wire admin frame; both fronts follow
    let (status, reply) = wc
        .admin("{\"action\":\"setDefault\",\"name\":\"mlp\",\
                \"version\":\"v2\"}")
        .unwrap();
    assert_eq!(status, 200, "{reply}");
    let (status, reply) = hc.predict("mlp", &body, None).unwrap();
    assert_eq!(status, 200, "{reply}");
    let got = jsonic::parse(&reply)
        .unwrap()
        .at("output")
        .as_f32_vec()
        .unwrap();
    assert_eq!(got, want_v2, "http default must follow the flip");

    // unloading the new default -> 409 over both fronts; the catalog
    // lists both versions with exactly one default
    let (status, reply) = hc
        .request("POST", "/v1/models/mlp@v2:unload", None, None)
        .unwrap();
    assert_eq!(status, 409, "{reply}");
    assert!(reply.contains("conflict"), "{reply}");
    let (status, _) = wc
        .admin("{\"action\":\"unload\",\"name\":\"mlp\",\
                \"version\":\"v2\"}")
        .unwrap();
    assert_eq!(status, 409);
    let (status, listing) = hc.get("/v1/models").unwrap();
    assert_eq!(status, 200);
    let j = jsonic::parse(&listing).unwrap();
    let rows = j.at("models").as_arr().unwrap();
    assert_eq!(rows.len(), 2, "{listing}");
    let defaults: Vec<&str> = rows
        .iter()
        .filter(|r| r.at("default").as_bool() == Some(true))
        .filter_map(|r| r.at("version").as_str())
        .collect();
    assert_eq!(defaults, vec!["v2"], "{listing}");

    // retiring v1 works now and its qualified name 404s after
    let (status, _) = wc
        .admin("{\"action\":\"unload\",\"name\":\"mlp\",\
                \"version\":\"v1\"}")
        .unwrap();
    assert_eq!(status, 200);
    let (status, reply) = hc.predict("mlp@v1", &body, None).unwrap();
    assert_eq!(status, 404, "{reply}");

    drop(hc);
    drop(wc);
    front.shutdown();
    wire.shutdown();
    let server = Arc::try_unwrap(server).ok().expect("clients gone");
    server.shutdown();
}

/// The autoscaler grows the pool under a backlog and shrinks it back
/// once drained, with every decision visible through `scale_events`.
#[test]
fn autoscaler_grows_under_backlog_and_shrinks_when_idle() {
    let mut reg = Registry::new();
    reg.register_shared("m", mlp_plan(4)).unwrap();
    // a long linger with a high cap parks submissions in the queue, so
    // the backlog signal is deterministic while the batch ripens
    let server = Server::start(reg, ServerConfig {
        workers: 1,
        max_batch: 64,
        linger: Duration::from_millis(80),
        queue_cap: 1024,
        min_workers: 1,
        max_workers: 4,
        scale_up_queue: 2,
        scale_tick: Duration::from_millis(2),
        scale_cooldown: Duration::from_millis(8),
        ..Default::default()
    })
    .unwrap();
    assert_eq!(server.worker_count(), 1);

    let mut rng = Rng::new(31);
    let tickets: Vec<_> = (0..32)
        .map(|_| server.submit("m", &rng.normals(16)).unwrap())
        .collect();
    let t0 = Instant::now();
    while server.worker_count() < 2 {
        assert!(t0.elapsed() < WAIT,
                "autoscaler never grew past 1 worker under a backlog");
        std::thread::sleep(Duration::from_millis(2));
    }
    let peak = server.worker_count();
    assert!(peak >= 2 && peak <= 4, "peak {peak} outside 2..=4");
    for t in tickets {
        t.wait_timeout(WAIT).unwrap();
    }
    while server.worker_count() > 1 {
        assert!(t0.elapsed() < WAIT,
                "autoscaler never shrank back to the floor when idle");
        std::thread::sleep(Duration::from_millis(2));
    }
    let events = server.scale_events();
    let first_grow = events.iter().position(|e| e.action == "grow");
    let last_shrink = events.iter().rposition(|e| e.action == "shrink");
    match (first_grow, last_shrink) {
        (Some(g), Some(s)) => assert!(g < s, "{events:?}"),
        _ => panic!("expected grow and shrink decisions: {events:?}"),
    }
    for e in &events {
        assert!(e.workers >= 1 && e.workers <= 4, "{e:?}");
    }
    server.shutdown();
}
