//! Integration: PJRT runtime <-> AOT artifacts. Verifies the manifest
//! contract end to end: init produces the declared state layout,
//! train_step/eval_step/infer run with spec-shaped literals and return
//! spec-shaped outputs.

mod common;

use lutq::runtime::{self};

#[test]
fn manifest_loads_and_describes_programs() {
    let Some(rt) = common::runtime() else { return };
    let man = rt.manifest("quickstart_mlp").expect("manifest");
    assert_eq!(man.meta.head, "classify");
    assert!(man.batch_size > 0);
    let mut names = man.program_names();
    names.sort();
    assert_eq!(names, vec!["eval_step", "infer", "init", "train_step"]);
    // train_step ABI: x, t, lr, aux, pfrac, state...
    let ts = man.program("train_step").unwrap();
    assert_eq!(ts.inputs.len(), 5 + man.state.len());
    assert_eq!(ts.outputs.len(), 1 + man.state.len());
    for (i, e) in ts.inputs[5..].iter().zip(&man.state) {
        assert_eq!(i.shape, e.shape);
    }
}

#[test]
fn init_produces_declared_state() {
    let Some(rt) = common::runtime() else { return };
    let man = rt.manifest("quickstart_mlp").expect("manifest");
    let init = rt.load_program(&man, "init").expect("init");
    let state = runtime::executable::run_init(&init, 0).expect("run");
    assert_eq!(state.len(), man.state.len());
    for (lit, e) in state.iter().zip(&man.state) {
        assert_eq!(lit.element_count(), e.shape.iter().product::<usize>(),
                   "{}", e.name);
    }
    // dictionaries are sorted ascending at init (linspace) and assignments
    // are in range
    let store = runtime::state_to_store(&state, &man.state).expect("store");
    for e in &man.state {
        match e.role.as_str() {
            "dict" => {
                let d = store.get(&e.name).unwrap().as_f32().to_vec();
                let mut s = d.clone();
                s.sort_by(|a, b| a.partial_cmp(b).unwrap());
                assert_eq!(d, s, "dict not sorted: {}", e.name);
            }
            "assign" => {
                let a = store.get(&e.name).unwrap().as_i32();
                let k = man.dict_size() as i32;
                assert!(a.iter().all(|&x| x >= 0 && x < k));
            }
            _ => {}
        }
    }
}

#[test]
fn init_is_deterministic_per_seed() {
    let Some(rt) = common::runtime() else { return };
    let man = rt.manifest("quickstart_mlp").expect("manifest");
    let init = rt.load_program(&man, "init").expect("init");
    let s1 = runtime::executable::run_init(&init, 7).expect("run");
    let s2 = runtime::executable::run_init(&init, 7).expect("run");
    let s3 = runtime::executable::run_init(&init, 8).expect("run");
    let v1: Vec<f32> = s1[0].to_vec().unwrap();
    let v2: Vec<f32> = s2[0].to_vec().unwrap();
    let v3: Vec<f32> = s3[0].to_vec().unwrap();
    assert_eq!(v1, v2);
    assert_ne!(v1, v3);
}

#[test]
fn train_step_executes_and_returns_finite_loss() {
    let Some(rt) = common::runtime() else { return };
    let man = rt.manifest("quickstart_mlp").expect("manifest");
    let init = rt.load_program(&man, "init").expect("init");
    let ts = rt.load_program(&man, "train_step").expect("ts");
    let state = runtime::executable::run_init(&init, 1).expect("run");

    let xs = &ts.spec.inputs[0];
    let t_spec = &ts.spec.inputs[1];
    let mut args = vec![
        runtime::literal_f32(&xs.shape, &vec![0.1; xs.elems()]).unwrap(),
        runtime::literal_f32(&t_spec.shape,
                             &onehot_batch(t_spec.shape[0],
                                           t_spec.shape[1])).unwrap(),
        runtime::scalar_f32(0.05),
        runtime::scalar_f32(0.0),
        runtime::scalar_f32(0.0),
    ];
    args.extend(state);
    ts.check_args(&args).expect("args match spec");
    let out = ts.run(&args).expect("run");
    let loss = out.f32_scalar(0).expect("loss");
    assert!(loss.is_finite() && loss > 0.0, "loss {loss}");
    assert_eq!(out.parts.len(), 1 + man.state.len());
}

#[test]
fn eval_and_infer_shapes() {
    let Some(rt) = common::runtime() else { return };
    let man = rt.manifest("quickstart_mlp").expect("manifest");
    let init = rt.load_program(&man, "init").expect("init");
    let state = runtime::executable::run_init(&init, 2).expect("run");

    let ev = rt.load_program(&man, "eval_step").expect("eval");
    let xs = &ev.spec.inputs[0];
    let t_spec = &ev.spec.inputs[1];
    let mut args = vec![
        runtime::literal_f32(&xs.shape, &vec![0.0; xs.elems()]).unwrap(),
        runtime::literal_f32(&t_spec.shape,
                             &onehot_batch(t_spec.shape[0],
                                           t_spec.shape[1])).unwrap(),
    ];
    for lit in &state {
        // rebuild literals from host copies (no Clone on Literal)
        let v: Vec<f32> = match lit.ty().unwrap() {
            xla::ElementType::F32 => lit.to_vec().unwrap(),
            _ => {
                let vi: Vec<i32> = lit.to_vec().unwrap();
                args.push(
                    runtime::literal_i32(
                        &shape_of(lit), &vi).unwrap());
                continue;
            }
        };
        args.push(runtime::literal_f32(&shape_of(lit), &v).unwrap());
    }
    let out = ev.run(&args).expect("eval run");
    let loss_sum = out.f32_scalar(0).unwrap();
    let correct = out.f32_scalar(1).unwrap();
    assert!(loss_sum.is_finite());
    assert!((0.0..=xs.shape[0] as f32).contains(&correct));

    let inf = rt.load_program(&man, "infer").expect("infer");
    assert_eq!(inf.spec.outputs.len(), 1);
    assert_eq!(inf.spec.outputs[0].shape,
               vec![man.batch_size, man.meta.num_classes]);
}

#[test]
fn wrong_arity_is_rejected() {
    let Some(rt) = common::runtime() else { return };
    let man = rt.manifest("quickstart_mlp").expect("manifest");
    let ts = rt.load_program(&man, "train_step").expect("ts");
    let args = vec![runtime::scalar_f32(0.0)];
    assert!(ts.run(&args).is_err());
}

#[test]
fn missing_artifact_is_helpful_error() {
    let Some(rt) = common::runtime() else { return };
    let err = rt.manifest("no_such_artifact").unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("no_such_artifact"));
    assert!(msg.contains("make artifacts"));
}

fn onehot_batch(b: usize, c: usize) -> Vec<f32> {
    let mut v = vec![0f32; b * c];
    for i in 0..b {
        v[i * c + i % c] = 1.0;
    }
    v
}

fn shape_of(lit: &xla::Literal) -> Vec<usize> {
    lit.array_shape()
        .unwrap()
        .dims()
        .iter()
        .map(|&d| d as usize)
        .collect()
}
