//! Integration parity for the kernel backend seam: plans compiled with
//! `KernelBackend::Simd` must agree with `KernelBackend::Scalar` within
//! the ulp-scaled tolerance documented in `infer::kernels`, across
//! random shapes, dictionary sizes (K = 2..64), remainder lanes and all
//! three execution modes — end to end through `Plan::compile`/`run`,
//! including the im2col gather and the batch-parallel driver. Plans
//! compiled with `KernelBackend::Int` must agree with scalar within the
//! *absolute* quantization-error bound documented in `infer::kernels`
//! (activation + dictionary i8 rounding), and bit-exactly for pow-2
//! shift dictionaries on integer-grid activations. Between the integer
//! backends the contract is stricter still: `KernelBackend::Int` (the
//! auto-dispatched int-avx2 / int-portable kernels) must match
//! `KernelBackend::IntScalar` **bit-exactly** — `assert_eq!`, no
//! tolerance — across random shapes, remainder lanes and all execution
//! modes. Also holds the backend name plumbing (Plan -> serve
//! `ModelReport`) together.

use std::time::Duration;

use lutq::infer::{ExecMode, KernelBackend, Plan, PlanOptions, Tensor};
use lutq::jsonic;
use lutq::params::export::{LutLayer, QuantizedModel};
use lutq::params::HostTensor;
use lutq::quant::bitpack::pack_assignments;
use lutq::serve::{Registry, Server, ServerConfig};
use lutq::testkit::forall;
use lutq::testkit::models::synth_conv_model;
use lutq::util::Rng;

fn opts(mode: ExecMode, kernel: KernelBackend) -> PlanOptions {
    // act_bits 0: fake-quant rounding would amplify sub-ulp
    // accumulation differences into full quantization steps
    PlanOptions { mode, act_bits: 0, mlbn: false, threads: 1, kernel }
}

/// Loose elementwise bound for whole-net parity: backend differences are
/// a few ulps per accumulator; anything structural (wrong lane, wrong
/// bucket, bad remainder handling) lands far outside it.
fn close(a: f32, b: f32) -> bool {
    (a - b).abs() <= 1e-4 + 1e-4 * a.abs().max(b.abs())
}

fn run_both(graph: &jsonic::Json, model: &QuantizedModel,
            mode: ExecMode, dims: &[usize], x: &Tensor)
            -> Result<(Vec<f32>, Vec<f32>), String> {
    let mut out = Vec::new();
    for kernel in [KernelBackend::Scalar, KernelBackend::Simd] {
        let plan = Plan::compile(graph, model, opts(mode, kernel), dims)
            .map_err(|e| format!("compile {kernel:?}: {e}"))?;
        let mut s = plan.scratch();
        let (y, _) = plan
            .run(x, &mut s)
            .map_err(|e| format!("run {kernel:?}: {e}"))?;
        out.push(y.data);
    }
    let simd = out.pop().unwrap();
    let scalar = out.pop().unwrap();
    Ok((scalar, simd))
}

/// Random LUT affine layers: the direct lut_dot path, with fan sweeping
/// across vector-width remainders and K across 2..=64.
#[test]
fn affine_lut_parity_across_shapes_and_dict_sizes() {
    forall(41, 60, |r| (r.range(1, 230), r.range(2, 65)), |&(fan, k)| {
        let (fan, k) = (fan.max(1), k.clamp(2, 64));
        let mut rng = Rng::new((fan * 2029 + k) as u64);
        let cout = 1 + rng.below(11);
        let graph = jsonic::parse(&format!(
            r#"[{{"op":"affine","name":"fc","cin":{fan},"cout":{cout}}}]"#
        ))
        .map_err(|e| format!("graph: {e}"))?;
        let dict: Vec<f32> =
            (0..k).map(|_| rng.normal() * 0.5).collect();
        let assign: Vec<u32> =
            (0..fan * cout).map(|_| rng.below(k) as u32).collect();
        let mut model = QuantizedModel::default();
        model.lut_layers.push(LutLayer::new(
            "fc",
            dict,
            pack_assignments(&assign, k),
            vec![fan, cout],
        ));
        model.fp.insert("fc.b".into(),
                        HostTensor::f32(vec![cout], rng.normals(cout)));
        let b = 1 + rng.below(3);
        let x = Tensor::new(vec![b, fan], rng.normals(b * fan));
        for mode in [ExecMode::Dense, ExecMode::LutTrick] {
            let (ys, yv) = run_both(&graph, &model, mode, &[fan], &x)?;
            for (i, (a, b)) in ys.iter().zip(&yv).enumerate() {
                if !close(*a, *b) {
                    return Err(format!(
                        "{mode:?} out[{i}]: scalar {a} vs simd {b} \
                         (fan {fan}, K {k}, cout {cout})"
                    ));
                }
            }
        }
        Ok(())
    });
}

/// Shift-only execution: pow-2 dictionaries, scalar bit-shift combine vs
/// the SIMD exact-pow-2-multiply combine.
#[test]
fn affine_shift_parity() {
    forall(43, 40, |r| (r.range(1, 150), r.range(2, 33)), |&(fan, k)| {
        let (fan, k) = (fan.max(1), k.clamp(2, 64));
        let mut rng = Rng::new((fan * 389 + k) as u64);
        let cout = 1 + rng.below(7);
        let graph = jsonic::parse(&format!(
            r#"[{{"op":"affine","name":"fc","cin":{fan},"cout":{cout}}}]"#
        ))
        .map_err(|e| format!("graph: {e}"))?;
        // entries are 0 or ±2^e so ShiftOnly compiles
        let dict: Vec<f32> = (0..k)
            .map(|i| {
                if i == 0 {
                    0.0
                } else {
                    let e = (rng.below(9) as i32) - 4;
                    let s = if rng.bool(0.5) { 1.0f32 } else { -1.0 };
                    s * (e as f32).exp2()
                }
            })
            .collect();
        let assign: Vec<u32> =
            (0..fan * cout).map(|_| rng.below(k) as u32).collect();
        let mut model = QuantizedModel::default();
        model.lut_layers.push(LutLayer::new(
            "fc",
            dict,
            pack_assignments(&assign, k),
            vec![fan, cout],
        ));
        model.fp.insert("fc.b".into(),
                        HostTensor::f32(vec![cout], rng.normals(cout)));
        let x = Tensor::new(vec![2, fan], rng.normals(2 * fan));
        let (ys, yv) =
            run_both(&graph, &model, ExecMode::ShiftOnly, &[fan], &x)?;
        for (i, (a, b)) in ys.iter().zip(&yv).enumerate() {
            if !close(*a, *b) {
                return Err(format!(
                    "shift out[{i}]: scalar {a} vs simd {b} (fan {fan}, \
                     K {k})"
                ));
            }
        }
        Ok(())
    });
}

/// Random conv geometry (SAME padding, stride, channel remainders):
/// exercises the backend im2col gather + the channel-tiled bucket
/// scatter end to end.
#[test]
fn conv_parity_across_geometry() {
    forall(47, 30, |r| (r.range(4, 11), r.range(2, 65)), |&(h, k)| {
        let (h, k) = (h.max(2), k.clamp(2, 64));
        let mut rng = Rng::new((h * 947 + k) as u64);
        let cin = 1 + rng.below(4);
        let cout = 1 + rng.below(9);
        let kk = 1 + rng.below(3);
        let stride = 1 + rng.below(2);
        let graph = jsonic::parse(&format!(
            r#"[{{"op":"conv","name":"c0","cin":{cin},"cout":{cout},
                 "k":{kk},"stride":{stride}}}]"#
        ))
        .map_err(|e| format!("graph: {e}"))?;
        let n = kk * kk * cin * cout;
        let dict: Vec<f32> =
            (0..k).map(|_| rng.normal() * 0.4).collect();
        let assign: Vec<u32> =
            (0..n).map(|_| rng.below(k) as u32).collect();
        let mut model = QuantizedModel::default();
        model.lut_layers.push(LutLayer::new(
            "c0",
            dict,
            pack_assignments(&assign, k),
            vec![kk, kk, cin, cout],
        ));
        let b = 1 + rng.below(3);
        let x = Tensor::new(vec![b, h, h, cin],
                            rng.normals(b * h * h * cin));
        for mode in [ExecMode::Dense, ExecMode::LutTrick] {
            let (ys, yv) =
                run_both(&graph, &model, mode, &[h, h, cin], &x)?;
            for (i, (a, b)) in ys.iter().zip(&yv).enumerate() {
                if !close(*a, *b) {
                    return Err(format!(
                        "{mode:?} out[{i}]: scalar {a} vs simd {b} \
                         (h {h}, k {kk}, stride {stride}, cin {cin}, \
                         cout {cout}, K {k})"
                    ));
                }
            }
        }
        Ok(())
    });
}

/// Int backend vs scalar on random LUT affine layers, Dense and
/// LutTrick modes: the difference stays under the documented
/// quantization-error bound
/// `n/2·(s_a·Dmax + s_d·Amax) + n/4·s_a·s_d`
/// where `s_a`/`s_d` are the activation/dictionary i8 scales (×1.5
/// slack for the epilogue float rescale).
#[test]
fn affine_int_parity_within_quant_bound() {
    forall(53, 50, |r| (r.range(1, 160), r.range(2, 33)), |&(fan, k)| {
        let (fan, k) = (fan.max(1), k.clamp(2, 64));
        let mut rng = Rng::new((fan * 1543 + k) as u64);
        let cout = 1 + rng.below(7);
        let graph = jsonic::parse(&format!(
            r#"[{{"op":"affine","name":"fc","cin":{fan},"cout":{cout}}}]"#
        ))
        .map_err(|e| format!("graph: {e}"))?;
        let dict: Vec<f32> =
            (0..k).map(|_| rng.normal() * 0.5).collect();
        let assign: Vec<u32> =
            (0..fan * cout).map(|_| rng.below(k) as u32).collect();
        let mut model = QuantizedModel::default();
        model.lut_layers.push(LutLayer::new(
            "fc",
            dict.clone(),
            pack_assignments(&assign, k),
            vec![fan, cout],
        ));
        model.fp.insert("fc.b".into(),
                        HostTensor::f32(vec![cout], rng.normals(cout)));
        let b = 1 + rng.below(3);
        let x = Tensor::new(vec![b, fan], rng.normals(b * fan));
        // calibrate the plan with the measured activation absmax, like
        // a manifest act stat would
        let amax = x.data.iter().fold(1e-3f32, |m, v| m.max(v.abs()));
        model.fp.insert("fc.act_absmax".into(),
                        HostTensor::f32(vec![1], vec![amax]));
        let dmax = dict.iter().fold(0f32, |m, v| m.max(v.abs()));
        let (s_a, s_d) = (amax / 127.0, (dmax / 127.0).max(1e-12));
        let n = fan as f32;
        let tol = 1.5
            * (0.5 * n * (s_a * dmax + s_d * amax)
               + 0.25 * n * s_a * s_d)
            + 1e-5;
        for mode in [ExecMode::Dense, ExecMode::LutTrick] {
            let mut out = Vec::new();
            for kernel in [KernelBackend::Scalar, KernelBackend::Int] {
                let plan =
                    Plan::compile(&graph, &model, opts(mode, kernel),
                                  &[fan])
                        .map_err(|e| format!("compile {kernel:?}: {e}"))?;
                let mut s = plan.scratch();
                let (y, _) = plan
                    .run(&x, &mut s)
                    .map_err(|e| format!("run {kernel:?}: {e}"))?;
                out.push(y.data);
            }
            let (ys, yi) = (&out[0], &out[1]);
            for (i, (a, b)) in ys.iter().zip(yi).enumerate() {
                if (a - b).abs() > tol {
                    return Err(format!(
                        "{mode:?} out[{i}]: scalar {a} vs int {b} \
                         exceeds bound {tol} (fan {fan}, K {k}, \
                         cout {cout})"
                    ));
                }
            }
        }
        Ok(())
    });
}

/// Pure shift-dict models on the integer grid are *bit-exact* under the
/// int backend: with `act_absmax = 127` the activation scale is exactly
/// 1, pow-2 dictionary products are exact in both paths, and every
/// accumulator stays far below 2^24 — end to end through the conv
/// im2col gather.
#[test]
fn conv_int_shift_bit_exact_on_integer_grid() {
    forall(59, 30, |r| (r.range(4, 10), r.range(2, 9)), |&(h, k)| {
        let (h, k) = (h.max(2), k.clamp(2, 16));
        let mut rng = Rng::new((h * 769 + k) as u64);
        let cin = 1 + rng.below(3);
        let cout = 1 + rng.below(5);
        let kk = 1 + rng.below(3);
        let graph = jsonic::parse(&format!(
            r#"[{{"op":"conv","name":"c0","cin":{cin},"cout":{cout},
                 "k":{kk},"stride":1}}]"#
        ))
        .map_err(|e| format!("graph: {e}"))?;
        // 0 or ±2^e with e in [-4, 0] — all-negative spans included
        let dict: Vec<f32> = (0..k)
            .map(|i| {
                if i == 0 {
                    0.0
                } else {
                    let e = -(rng.below(5) as i32);
                    let s = if rng.bool(0.5) { 1.0f32 } else { -1.0 };
                    s * (e as f32).exp2()
                }
            })
            .collect();
        let n = kk * kk * cin * cout;
        let assign: Vec<u32> =
            (0..n).map(|_| rng.below(k) as u32).collect();
        let mut model = QuantizedModel::default();
        model.lut_layers.push(LutLayer::new(
            "c0",
            dict,
            pack_assignments(&assign, k),
            vec![kk, kk, cin, cout],
        ));
        // act scale exactly 1: activations already sit on the i8 grid
        model.fp.insert("c0.act_absmax".into(),
                        HostTensor::f32(vec![1], vec![127.0]));
        let b = 1 + rng.below(2);
        let xdata: Vec<f32> = (0..b * h * h * cin)
            .map(|_| (rng.below(17) as i32 - 8) as f32)
            .collect();
        let x = Tensor::new(vec![b, h, h, cin], xdata);
        let mut out = Vec::new();
        for kernel in [KernelBackend::Scalar, KernelBackend::Int] {
            let plan = Plan::compile(&graph, &model,
                                     opts(ExecMode::ShiftOnly, kernel),
                                     &[h, h, cin])
                .map_err(|e| format!("compile {kernel:?}: {e}"))?;
            let mut s = plan.scratch();
            let (y, _) = plan
                .run(&x, &mut s)
                .map_err(|e| format!("run {kernel:?}: {e}"))?;
            out.push(y.data);
        }
        if out[0] != out[1] {
            let i = out[0]
                .iter()
                .zip(&out[1])
                .position(|(a, b)| a != b)
                .unwrap();
            return Err(format!(
                "shift grid out[{i}]: scalar {} vs int {} (h {h}, \
                 k {kk}, cin {cin}, cout {cout}, K {k})",
                out[0][i], out[1][i]
            ));
        }
        Ok(())
    });
}

/// Run one model under the pinned integer reference and the
/// auto-dispatched integer backend; the outputs must be bit-identical.
fn run_int_pair(graph: &jsonic::Json, model: &QuantizedModel,
                mode: ExecMode, dims: &[usize], x: &Tensor)
                -> Result<(Vec<f32>, Vec<f32>), String> {
    let mut out = Vec::new();
    for kernel in [KernelBackend::IntScalar, KernelBackend::Int] {
        let plan = Plan::compile(graph, model, opts(mode, kernel), dims)
            .map_err(|e| format!("compile {kernel:?}: {e}"))?;
        let mut s = plan.scratch();
        let (y, _) = plan
            .run(x, &mut s)
            .map_err(|e| format!("run {kernel:?}: {e}"))?;
        out.push(y.data);
    }
    let simd = out.pop().unwrap();
    let scalar = out.pop().unwrap();
    Ok((scalar, simd))
}

fn first_mismatch(a: &[f32], b: &[f32]) -> Option<usize> {
    a.iter().zip(b).position(|(x, y)| x.to_bits() != y.to_bits())
}

/// int-simd vs int-scalar on random LUT affine layers, Dense and
/// LutTrick modes, ending in `relu` so the fused clipped-ReLU integer
/// epilogue runs end to end: **bit-exact**, no tolerance — integer
/// accumulation is order-invariant under the SIMD lane/tile reorders
/// and every integer backend finishes with the same scalar epilogue.
/// Fans sweep across the i16-lane remainders (16- and 32-wide chunks)
/// and K includes 1.
#[test]
fn affine_int_simd_bit_exact_vs_int_scalar() {
    forall(61, 50, |r| (r.range(1, 260), r.range(1, 65)), |&(fan, k)| {
        let (fan, k) = (fan.max(1), k.clamp(1, 64));
        let mut rng = Rng::new((fan * 733 + k) as u64);
        let cout = 1 + rng.below(9);
        let graph = jsonic::parse(&format!(
            r#"[{{"op":"affine","name":"fc","cin":{fan},
                 "cout":{cout}}},
                {{"op":"relu"}}]"#
        ))
        .map_err(|e| format!("graph: {e}"))?;
        let dict: Vec<f32> =
            (0..k).map(|_| rng.normal() * 0.5).collect();
        let assign: Vec<u32> =
            (0..fan * cout).map(|_| rng.below(k) as u32).collect();
        let mut model = QuantizedModel::default();
        model.lut_layers.push(LutLayer::new(
            "fc",
            dict,
            pack_assignments(&assign, k),
            vec![fan, cout],
        ));
        model.fp.insert("fc.b".into(),
                        HostTensor::f32(vec![cout], rng.normals(cout)));
        let b = 1 + rng.below(3);
        let x = Tensor::new(vec![b, fan], rng.normals(b * fan));
        let amax = x.data.iter().fold(1e-3f32, |m, v| m.max(v.abs()));
        model.fp.insert("fc.act_absmax".into(),
                        HostTensor::f32(vec![1], vec![amax]));
        for mode in [ExecMode::Dense, ExecMode::LutTrick] {
            let (yr, yv) = run_int_pair(&graph, &model, mode, &[fan], &x)?;
            if let Some(i) = first_mismatch(&yr, &yv) {
                return Err(format!(
                    "{mode:?} out[{i}]: int-scalar {} vs int-simd {} \
                     (fan {fan}, K {k}, cout {cout})",
                    yr[i], yv[i]
                ));
            }
        }
        Ok(())
    });
}

/// int-simd vs int-scalar through random conv geometry (SAME padding,
/// stride, channel remainders) in all three execution modes — the
/// dictionary is 0-or-pow-2 with **all-negative exponents** so
/// ShiftOnly compiles and the shift buckets see every remainder shape
/// the im2col gather can produce. Bit-exact, no tolerance.
#[test]
fn conv_int_simd_bit_exact_vs_int_scalar() {
    forall(67, 30, |r| (r.range(4, 11), r.range(1, 13)), |&(h, k)| {
        let (h, k) = (h.max(2), k.clamp(1, 16));
        let mut rng = Rng::new((h * 521 + k) as u64);
        let cin = 1 + rng.below(4);
        let cout = 1 + rng.below(9);
        let kk = 1 + rng.below(3);
        let stride = 1 + rng.below(2);
        let graph = jsonic::parse(&format!(
            r#"[{{"op":"conv","name":"c0","cin":{cin},"cout":{cout},
                 "k":{kk},"stride":{stride}}}]"#
        ))
        .map_err(|e| format!("graph: {e}"))?;
        // 0 or ±2^e with e in [-6, -1]: all-negative exponent spans
        let dict: Vec<f32> = (0..k)
            .map(|i| {
                if i == 0 && k > 1 {
                    0.0
                } else {
                    let e = -1 - (rng.below(6) as i32);
                    let s = if rng.bool(0.5) { 1.0f32 } else { -1.0 };
                    s * (e as f32).exp2()
                }
            })
            .collect();
        let n = kk * kk * cin * cout;
        let assign: Vec<u32> =
            (0..n).map(|_| rng.below(k) as u32).collect();
        let mut model = QuantizedModel::default();
        model.lut_layers.push(LutLayer::new(
            "c0",
            dict,
            pack_assignments(&assign, k),
            vec![kk, kk, cin, cout],
        ));
        let b = 1 + rng.below(3);
        let x = Tensor::new(vec![b, h, h, cin],
                            rng.normals(b * h * h * cin));
        let amax = x.data.iter().fold(1e-3f32, |m, v| m.max(v.abs()));
        model.fp.insert("c0.act_absmax".into(),
                        HostTensor::f32(vec![1], vec![amax]));
        for mode in [ExecMode::Dense, ExecMode::LutTrick,
                     ExecMode::ShiftOnly] {
            let (yr, yv) =
                run_int_pair(&graph, &model, mode, &[h, h, cin], &x)?;
            if let Some(i) = first_mismatch(&yr, &yv) {
                return Err(format!(
                    "{mode:?} out[{i}]: int-scalar {} vs int-simd {} \
                     (h {h}, k {kk}, stride {stride}, cin {cin}, \
                     cout {cout}, K {k})",
                    yr[i], yv[i]
                ));
            }
        }
        Ok(())
    });
}

/// The SIMD backend is deterministic run-to-run and thread-count
/// invariant (samples are the parallel unit), like scalar.
#[test]
fn simd_backend_is_deterministic_and_thread_invariant() {
    let (graph, model) = synth_conv_model(8, false);
    let mut rng = Rng::new(3);
    let x = Tensor::new(vec![5, 32, 32, 3], rng.normals(5 * 32 * 32 * 3));
    let p1 = Plan::compile(&graph, &model,
                           opts(ExecMode::LutTrick, KernelBackend::Simd),
                           &[32, 32, 3])
        .unwrap();
    let p4 = Plan::compile(
        &graph, &model,
        PlanOptions { threads: 4,
                      ..opts(ExecMode::LutTrick, KernelBackend::Simd) },
        &[32, 32, 3])
    .unwrap();
    let mut s1 = p1.scratch();
    let mut s4 = p4.scratch();
    let (a, _) = p1.run(&x, &mut s1).unwrap();
    let (b, _) = p1.run(&x, &mut s1).unwrap();
    let (c, _) = p4.run(&x, &mut s4).unwrap();
    assert_eq!(a.data, b.data, "simd backend must be run-deterministic");
    assert_eq!(a.data, c.data, "simd results must not depend on threads");
}

/// Backend names flow from the plan into serve's per-model reports.
#[test]
fn serve_report_carries_backend_name() {
    let (graph, model) = synth_conv_model(4, false);
    let mut reg = Registry::new();
    for (name, kernel) in [("conv-scalar", KernelBackend::Scalar),
                           ("conv-simd", KernelBackend::Simd),
                           ("conv-int", KernelBackend::Int)] {
        reg.register(
            name,
            Plan::compile(&graph, &model,
                          opts(ExecMode::LutTrick, kernel), &[32, 32, 3])
                .unwrap(),
        )
        .unwrap();
    }
    let server = Server::start(reg, ServerConfig {
        workers: 1,
        max_batch: 2,
        linger: Duration::from_millis(1),
        queue_cap: 16,
        ..Default::default()
    })
    .unwrap();
    let sample = vec![0.25f32; 32 * 32 * 3];
    server.infer("conv-scalar", &sample).unwrap();
    server.infer("conv-simd", &sample).unwrap();
    server.infer("conv-int", &sample).unwrap();
    let reports = server.shutdown();
    assert_eq!(reports[0].backend, "scalar");
    assert!(reports[1].backend.starts_with("simd"),
            "{}", reports[1].backend);
    // `int` auto-dispatches, so the resolved name is machine-dependent
    // (int-avx2 on x86-64 with AVX2, int-portable elsewhere)
    assert!(matches!(reports[2].backend.as_str(),
                     "int-avx2" | "int-portable"),
            "{}", reports[2].backend);
}
