//! Shared helpers for integration tests. Tests that need AOT artifacts
//! skip (pass vacuously with a notice) when `artifacts/` is absent so
//! `cargo test` stays green before `make artifacts`.

use lutq::runtime::Runtime;

pub fn runtime() -> Option<Runtime> {
    let dir = lutq::artifacts_dir();
    if !dir.join("quickstart_mlp").join("manifest.json").exists() {
        eprintln!("SKIP: artifacts missing under {} (run `make artifacts`)",
                  dir.display());
        return None;
    }
    Some(Runtime::new(&dir).expect("PJRT runtime"))
}

pub fn have(rt: &Runtime, name: &str) -> bool {
    let ok = rt.artifacts_root().join(name).join("manifest.json").exists();
    if !ok {
        eprintln!("SKIP: artifact {name} missing");
    }
    ok
}
