//! Cluster routing tests: bitwise parity against a single process,
//! fault-injected failover with reconciling counters, hedged dispatch
//! and circuit-breaker transitions under seeded faults, open-loop
//! accounting, and the shard-plan partition/merge property under the
//! shrinking harness.
//!
//! Everything runs on scalar-pinned plans over the deterministic
//! testkit models, so "identical" below means bit-identical: the
//! routed output of every sample must equal a direct `Plan::run_into`
//! of the same input, whatever the replica count, shard boundaries or
//! injected faults.

use std::sync::Arc;
use std::time::{Duration, Instant};

use lutq::infer::{ExecMode, KernelBackend, Plan, PlanOptions, Tensor};
use lutq::serve::cluster::{
    chunk, BreakerConfig, InProcessReplica, Replica, RouteError,
    Router, RouterConfig, Shard,
};
use lutq::serve::load::{open_loop_cluster, Arrival, SamplePools};
use lutq::serve::{Registry, Server, ServerConfig};
use lutq::testkit::flaky::{FaultPlan, FlakyReplica};
use lutq::testkit::models::synth_mlp_model;
use lutq::testkit::{forall, Shrink};
use lutq::util::Rng;

/// Scalar-pinned MLP plan (K-entry dictionary); `act_bits > 0` makes it
/// batch-coupled, which must force batch-1 sharding.
fn scalar_plan(k: usize, act_bits: usize) -> Arc<Plan> {
    let (graph, model) = synth_mlp_model(k);
    Arc::new(
        Plan::compile(
            &graph,
            &model,
            PlanOptions {
                mode: ExecMode::LutTrick,
                act_bits,
                mlbn: false,
                threads: 1,
                kernel: KernelBackend::Scalar,
            },
            &[16],
        )
        .unwrap(),
    )
}

/// One in-process replica server over shared plans.
fn replica_server(plans: &[(&str, Arc<Plan>)]) -> Arc<Server> {
    let mut reg = Registry::new();
    for (name, plan) in plans {
        reg.register_shared(name, Arc::clone(plan)).unwrap();
    }
    Arc::new(
        Server::start(
            reg,
            ServerConfig {
                workers: 2,
                max_batch: 4,
                linger: Duration::from_millis(1),
                queue_cap: 256,
                ..Default::default()
            },
        )
        .unwrap(),
    )
}

fn in_process(i: usize, server: &Arc<Server>) -> Box<dyn Replica> {
    Box::new(InProcessReplica::new(&format!("r{i}"), Arc::clone(server)))
}

/// Direct single-sample reference — the parity yardstick.
fn reference(plan: &Plan, sample: &[f32]) -> Vec<f32> {
    let mut scratch = plan.scratch();
    let x = Tensor::new(vec![1, 16], sample.to_vec());
    plan.run_into(&x, &mut scratch).unwrap();
    scratch.output().1.to_vec()
}

#[test]
fn three_replica_cluster_matches_single_process_bitwise() {
    let plan = scalar_plan(4, 0);
    assert!(plan.batch_invariant());
    let servers: Vec<Arc<Server>> = (0..3)
        .map(|_| replica_server(&[("mlp", Arc::clone(&plan))]))
        .collect();
    let replicas: Vec<Box<dyn Replica>> = servers
        .iter()
        .enumerate()
        .map(|(i, s)| in_process(i, s))
        .collect();
    let router =
        Router::new(replicas, RouterConfig { max_shard: 2, ..RouterConfig::default() }).unwrap();

    let mut rng = Rng::new(17);
    let mut total = 0u64;
    // batch % replicas != 0 on purpose: remainder shards must not drop
    // or duplicate samples
    for &b in &[1usize, 4, 7, 10] {
        let batch: Vec<Vec<f32>> =
            (0..b).map(|_| rng.normals(16)).collect();
        let refs: Vec<&[f32]> =
            batch.iter().map(|v| v.as_slice()).collect();
        let got = router.predict_batch("mlp", &refs, None);
        assert_eq!(got.len(), b);

        // per-sample parity with a direct run
        for (i, r) in got.iter().enumerate() {
            let out = r.as_ref().unwrap_or_else(|e| {
                panic!("sample {i} of batch {b} failed: {e}")
            });
            assert_eq!(out, &reference(&plan, &batch[i]),
                       "sample {i} of batch {b}");
        }

        // whole-batch parity: one run_into over the full batch equals
        // the sharded outputs row for row (the acceptance criterion)
        let mut scratch = plan.scratch_for(b);
        let flat: Vec<f32> =
            batch.iter().flat_map(|s| s.iter().copied()).collect();
        let x = Tensor::new(vec![b, 16], flat);
        plan.run_into(&x, &mut scratch).unwrap();
        let all = scratch.output().1.to_vec();
        let per = all.len() / b;
        for (i, r) in got.iter().enumerate() {
            assert_eq!(r.as_ref().unwrap().as_slice(),
                       &all[i * per..(i + 1) * per],
                       "row {i} of batch {b} vs single run_into");
        }
        total += b as u64;
    }

    let t = router.totals();
    assert!(t.reconciles(), "{t:?}");
    assert_eq!(t.completed, total);
    assert_eq!(t.rejected + t.shed + t.failed, 0, "{t:?}");
    // the batch dimension was actually sharded across the cluster
    let reports = router.reports();
    assert!(reports.iter().filter(|r| r.samples > 0).count() >= 2,
            "{reports:?}");
    assert_eq!(reports.iter().map(|r| r.samples).sum::<u64>(), total);
}

#[test]
fn act_quant_plans_shard_at_batch_one_and_stay_bitwise() {
    let plan = scalar_plan(4, 8);
    assert!(!plan.batch_invariant(),
            "act_bits > 0 must make the plan batch-coupled");
    let servers: Vec<Arc<Server>> = (0..3)
        .map(|_| replica_server(&[("aq", Arc::clone(&plan))]))
        .collect();
    let replicas: Vec<Box<dyn Replica>> = servers
        .iter()
        .enumerate()
        .map(|(i, s)| in_process(i, s))
        .collect();
    // max_shard 4 on the router, but the catalog knows the plan is
    // batch-coupled: every shard must still be a single sample
    let router =
        Router::new(replicas, RouterConfig { max_shard: 4, ..RouterConfig::default() }).unwrap();

    let mut rng = Rng::new(23);
    for &b in &[3usize, 5] {
        let batch: Vec<Vec<f32>> =
            (0..b).map(|_| rng.normals(16)).collect();
        let refs: Vec<&[f32]> =
            batch.iter().map(|v| v.as_slice()).collect();
        let got = router.predict_batch("aq", &refs, None);
        for (i, r) in got.iter().enumerate() {
            assert_eq!(r.as_ref().unwrap(),
                       &reference(&plan, &batch[i]),
                       "act-quant sample {i} of batch {b}");
        }
    }
    let t = router.totals();
    assert!(t.reconciles(), "{t:?}");
    assert_eq!(t.completed, 8);
}

#[test]
fn mixed_model_traffic_routes_each_request_to_its_model() {
    let p4 = scalar_plan(4, 0);
    let p16 = scalar_plan(16, 0);
    let plans =
        [("mlp4", Arc::clone(&p4)), ("mlp16", Arc::clone(&p16))];
    let servers: Vec<Arc<Server>> =
        (0..3).map(|_| replica_server(&plans)).collect();
    let replicas: Vec<Box<dyn Replica>> = servers
        .iter()
        .enumerate()
        .map(|(i, s)| in_process(i, s))
        .collect();
    let router =
        Router::new(replicas, RouterConfig { max_shard: 2, ..RouterConfig::default() }).unwrap();

    let mut rng = Rng::new(31);
    for i in 0..24 {
        let sample = rng.normals(16);
        let (name, plan) = if i % 2 == 0 {
            ("mlp4", &p4)
        } else {
            ("mlp16", &p16)
        };
        let got = router.predict_one(name, &sample, None).unwrap();
        assert_eq!(got, reference(plan, &sample), "request {i}");
    }
    let t = router.totals();
    assert!(t.reconciles(), "{t:?}");
    assert_eq!(t.completed, 24);
}

#[test]
fn failover_reroutes_around_an_always_failing_replica() {
    let plan = scalar_plan(4, 0);
    let servers: Vec<Arc<Server>> = (0..3)
        .map(|_| replica_server(&[("mlp", Arc::clone(&plan))]))
        .collect();
    let flaky = Arc::new(FlakyReplica::new(
        in_process(1, &servers[1]),
        7,
        FaultPlan::always_error(),
    ));
    let replicas: Vec<Box<dyn Replica>> = vec![
        in_process(0, &servers[0]),
        Box::new(Arc::clone(&flaky)),
        in_process(2, &servers[2]),
    ];
    let router =
        Router::new(replicas, RouterConfig { max_shard: 2, ..RouterConfig::default() }).unwrap();

    let mut rng = Rng::new(41);
    let total = 30u64;
    for i in 0..total {
        let sample = rng.normals(16);
        let got = router
            .predict_one("mlp", &sample, None)
            .unwrap_or_else(|e| panic!("request {i} failed: {e}"));
        assert_eq!(got, reference(&plan, &sample), "request {i}");
    }
    assert!(flaky.injected() > 0,
            "the flaky replica must have been tried at least once");
    let t = router.totals();
    assert!(t.reconciles(), "{t:?}");
    assert_eq!(t.completed, total);
    assert_eq!(t.failed, 0, "failover must absorb injected errors");

    // no request was double-completed: what the surviving servers
    // executed equals what the router answered, and the dead replica
    // executed nothing
    let executed: u64 = servers
        .iter()
        .flat_map(|s| s.reports())
        .map(|r| r.requests)
        .sum();
    assert_eq!(executed, total);
    assert_eq!(servers[1].reports()[0].requests, 0);
    // ...and none was leaked: every ticket a replica submitted was
    // waited on, so the batcher never reclaimed an abandoned request
    for s in &servers {
        assert_eq!(s.reports()[0].abandoned, 0, "leaked ticket");
    }

    let reports = router.reports();
    assert!(!reports[1].healthy, "failing replica leaves the rotation");
    assert!(reports[1].failed_shards > 0);
    assert!(reports[1].rerouted > 0);
    // the underlying server is fine, so a health probe restores it
    assert_eq!(router.check_health(), 3);
    assert!(router.reports()[1].healthy);
}

#[test]
fn replica_killed_mid_load_fails_over_without_loss() {
    let plan = scalar_plan(4, 0);
    let servers: Vec<Arc<Server>> = (0..3)
        .map(|_| replica_server(&[("mlp", Arc::clone(&plan))]))
        .collect();
    let replicas: Vec<Box<dyn Replica>> = servers
        .iter()
        .enumerate()
        .map(|(i, s)| in_process(i, s))
        .collect();
    let router =
        Router::new(replicas, RouterConfig { max_shard: 2, ..RouterConfig::default() }).unwrap();

    let mut rng = Rng::new(53);
    let total = 60u64;
    for i in 0..total {
        if i == 20 {
            // kill one replica mid-load: submits start failing Closed
            servers[0].close();
        }
        let sample = rng.normals(16);
        let got = router
            .predict_one("mlp", &sample, None)
            .unwrap_or_else(|e| panic!("request {i} failed: {e}"));
        assert_eq!(got, reference(&plan, &sample), "request {i}");
    }
    let t = router.totals();
    assert!(t.reconciles(), "{t:?}");
    assert_eq!(t.completed, total);
    assert_eq!(t.failed, 0);
    // the killed replica left the rotation after its first failure
    let reports = router.reports();
    assert!(!reports[0].healthy, "{reports:?}");
    assert!(reports[0].failed_shards >= 1, "{reports:?}");
    // every answered request was executed exactly once somewhere, and
    // no ticket was abandoned in any replica's queue
    let executed: u64 = servers
        .iter()
        .flat_map(|s| s.reports())
        .map(|r| r.requests)
        .sum();
    assert_eq!(executed, total);
    for s in &servers {
        assert_eq!(s.reports()[0].abandoned, 0, "leaked ticket");
    }
}

#[test]
fn delayed_replica_sheds_deadline_requests_deterministically() {
    let plan = scalar_plan(4, 0);
    let server = replica_server(&[("mlp", Arc::clone(&plan))]);
    let flaky = Arc::new(FlakyReplica::new(
        in_process(0, &server),
        11,
        FaultPlan::always_delay(Duration::from_millis(50)),
    ));
    let replicas: Vec<Box<dyn Replica>> =
        vec![Box::new(Arc::clone(&flaky))];
    let router =
        Router::new(replicas, RouterConfig { max_shard: 2, ..RouterConfig::default() }).unwrap();

    let sample = vec![0.5f32; 16];
    // the injected 50 ms stall outlives a 5 ms deadline: the replica's
    // own admission gate must shed, and shedding is final (failover
    // cannot conjure the budget back)
    let err = router
        .predict_one("mlp", &sample,
                     Some(Instant::now() + Duration::from_millis(5)))
        .unwrap_err();
    assert!(
        matches!(err,
                 RouteError::Rejected(_) | RouteError::Deadline(_)),
        "want a deadline-shaped refusal, got {err:?}"
    );
    // without a deadline the same slow replica still answers correctly
    let got = router.predict_one("mlp", &sample, None).unwrap();
    assert_eq!(got, reference(&plan, &sample));
    assert_eq!(flaky.injected(), 2);
    let t = router.totals();
    assert!(t.reconciles(), "{t:?}");
    assert_eq!(t.completed, 1);
    assert_eq!(t.rejected + t.shed, 1, "{t:?}");
    assert_eq!(t.failed, 0);
}

#[test]
fn all_replicas_down_is_a_typed_refusal_not_a_hang() {
    let plan = scalar_plan(4, 0);
    let server = replica_server(&[("mlp", Arc::clone(&plan))]);
    let replicas: Vec<Box<dyn Replica>> = vec![in_process(0, &server)];
    let router =
        Router::new(replicas, RouterConfig::default()).unwrap();
    server.close();
    let err = router
        .predict_one("mlp", &[0.0; 16], None)
        .unwrap_err();
    assert!(matches!(err, RouteError::AllReplicasDown(_)), "{err:?}");
    let t = router.totals();
    assert!(t.reconciles(), "{t:?}");
    assert_eq!(t.failed, 1);
}

#[test]
fn hedged_dispatch_duplicates_stragglers_and_first_completion_wins() {
    let plan = scalar_plan(4, 0);
    let servers: Vec<Arc<Server>> = (0..2)
        .map(|_| replica_server(&[("mlp", Arc::clone(&plan))]))
        .collect();
    // warm each server's admission EWMA so the replicas' inline hints
    // give the router a baseline expectation: hedging never triggers
    // without an estimate to call the primary a straggler against
    let mut rng = Rng::new(59);
    for s in &servers {
        for _ in 0..4 {
            s.infer("mlp", &rng.normals(16)).unwrap();
        }
    }
    // replica 1 answers correctly but stalls 80 ms first — far past
    // 2x its sub-millisecond expected shard time
    let slow = Arc::new(FlakyReplica::new(
        in_process(1, &servers[1]),
        19,
        FaultPlan::always_delay(Duration::from_millis(80)),
    ));
    let replicas: Vec<Box<dyn Replica>> = vec![
        in_process(0, &servers[0]),
        Box::new(Arc::clone(&slow)),
    ];
    let router = Router::new(
        replicas,
        RouterConfig {
            max_shard: 2,
            hedge_threshold: 2.0,
            hedge_min_ms: 5.0,
            ..RouterConfig::default()
        },
    )
    .unwrap();

    let total = 8u64;
    for i in 0..total {
        let sample = rng.normals(16);
        let got = router
            .predict_one("mlp", &sample, None)
            .unwrap_or_else(|e| panic!("request {i} failed: {e}"));
        // first-completion-wins must stay bitwise: whichever attempt
        // answered, the logits equal a direct single-sample run
        assert_eq!(got, reference(&plan, &sample), "request {i}");
    }

    let t = router.totals();
    assert!(t.reconciles(), "{t:?}");
    assert_eq!(t.completed, total);
    assert_eq!(t.failed, 0, "{t:?}");
    let reports = router.reports();
    let hedges: u64 = reports.iter().map(|r| r.hedges).sum();
    let wins: u64 = reports.iter().map(|r| r.hedge_wins).sum();
    assert!(hedges >= 1, "stalled shards must hedge: {reports:?}");
    assert!(wins >= 1,
            "the idle fast replica must win the race: {reports:?}");
    // exactly-once accounting under duplication: only winning
    // completions count samples — a discarded straggler counts nothing
    assert_eq!(reports.iter().map(|r| r.samples).sum::<u64>(), total,
               "{reports:?}");
    // let detached straggler attempts drain before the servers drop
    std::thread::sleep(Duration::from_millis(200));
}

#[test]
fn breaker_opens_backs_off_and_recloses_through_half_open_probe() {
    let plan = scalar_plan(4, 0);
    let good = replica_server(&[("mlp", Arc::clone(&plan))]);
    let bad_inner = replica_server(&[("mlp", Arc::clone(&plan))]);
    // predicts always fail, but health probes pass through to the
    // (live) inner server — so a half-open trial probe can succeed
    let flaky = Arc::new(FlakyReplica::new(
        in_process(1, &bad_inner),
        29,
        FaultPlan::always_error(),
    ));
    let replicas: Vec<Box<dyn Replica>> = vec![
        in_process(0, &good),
        Box::new(Arc::clone(&flaky)),
    ];
    let router = Router::new(
        replicas,
        RouterConfig {
            max_shard: 2,
            breaker: BreakerConfig { base_ms: 150.0, max_ms: 600.0 },
            ..RouterConfig::default()
        },
    )
    .unwrap();

    let mut rng = Rng::new(47);
    for i in 0..6 {
        let sample = rng.normals(16);
        let got = router
            .predict_one("mlp", &sample, None)
            .unwrap_or_else(|e| panic!("request {i} failed: {e}"));
        assert_eq!(got, reference(&plan, &sample), "request {i}");
    }
    // the first injected failure tripped the breaker open; requests
    // while open were excluded, so there is exactly one trip
    let reports = router.reports();
    assert_eq!(reports[1].breaker_state, "open", "{reports:?}");
    assert_eq!(reports[1].breaker_trips, 1, "{reports:?}");
    assert!(!reports[1].healthy);
    assert!(reports[1].failed_shards >= 1);
    // tick() honours the backoff window: the open replica is skipped
    assert_eq!(router.tick(), 1);
    assert_eq!(router.reports()[1].breaker_state, "open");
    // the window expires -> half-open admits a trial
    std::thread::sleep(Duration::from_millis(180));
    assert_eq!(router.reports()[1].breaker_state, "half-open");
    // the trial probe succeeds (health is not fault-injected), so the
    // breaker closes and the replica rejoins the rotation
    assert_eq!(router.tick(), 2);
    let reports = router.reports();
    assert_eq!(reports[1].breaker_state, "closed", "{reports:?}");
    assert!(reports[1].healthy);
    // the replica still fails predicts: traffic fails over as before,
    // answers stay correct, and the accounting still reconciles
    let sample = rng.normals(16);
    let got = router.predict_one("mlp", &sample, None).unwrap();
    assert_eq!(got, reference(&plan, &sample));
    let t = router.totals();
    assert!(t.reconciles(), "{t:?}");
    assert_eq!(t.completed, 7);
    assert_eq!(t.failed, 0, "{t:?}");
}

#[test]
fn open_loop_cluster_accounts_every_request_under_faults() {
    let plan = scalar_plan(4, 0);
    let servers: Vec<Arc<Server>> = (0..2)
        .map(|_| replica_server(&[("mlp", Arc::clone(&plan))]))
        .collect();
    // replica 0 randomly drops or errors shards; replica 1 is healthy,
    // so failover must absorb every injected fault
    let flaky = Arc::new(FlakyReplica::new(
        in_process(0, &servers[0]),
        13,
        FaultPlan {
            drop_p: 0.3,
            error_p: 0.2,
            delay_p: 0.0,
            delay: Duration::ZERO,
        },
    ));
    let replicas: Vec<Box<dyn Replica>> = vec![
        Box::new(Arc::clone(&flaky)),
        in_process(1, &servers[1]),
    ];
    let router = Arc::new(
        Router::new(
            replicas,
            RouterConfig {
                max_shard: 2,
                breaker: BreakerConfig { base_ms: 20.0, max_ms: 100.0 },
                ..RouterConfig::default()
            },
        )
        .unwrap(),
    );

    let mut rng = Rng::new(61);
    let pools: SamplePools =
        Arc::new(vec![(0..4).map(|_| rng.normals(16)).collect()]);
    let n = 60usize;
    let offsets = Arrival::Poisson { rps: 2000.0 }.offsets_ms(n, 7);
    let rep = open_loop_cluster(&router, &["mlp".into()], &[0], &pools,
                                &offsets, 4, None)
        .unwrap();

    // open-loop accounting: every scheduled request is issued and lands
    // in exactly one outcome bucket, faults or not
    assert_eq!(rep.total, n);
    assert_eq!(
        rep.stats.ok + rep.stats.rejected + rep.stats.failed,
        n as u64,
        "{:?}", rep.stats
    );
    // no deadline and a healthy survivor: failover answers everything
    assert_eq!(rep.stats.ok, n as u64, "{:?}", rep.stats);
    assert!(flaky.injected() > 0,
            "the fault injector must have fired at least once");
    let curve = rep.slo_curve(&[1e9f32]);
    assert!((curve[0].1 - 1.0).abs() < 1e-9,
            "all-ok run must meet an unbounded SLO: {curve:?}");
    let t = router.totals();
    assert!(t.reconciles(), "{t:?}");
    assert_eq!(t.completed, n as u64);
    assert_eq!(t.failed, 0, "{t:?}");
    let reports = router.reports();
    assert!(reports[0].failed_shards >= 1, "{reports:?}");
    assert!(reports[0].breaker_trips >= 1, "{reports:?}");
}

// ------------------------------------------------------------ proptest

/// A random shard plan: batch size, integer replica weights (0 = dead
/// replica), shard cap. Integer weights shrink cleanly.
#[derive(Debug, Clone)]
struct SplitCase {
    n: usize,
    weights: Vec<u32>,
    max_shard: usize,
}

impl Shrink for SplitCase {
    fn shrinks(&self) -> Vec<SplitCase> {
        let mut out = Vec::new();
        for n in self.n.shrinks() {
            out.push(SplitCase { n, ..self.clone() });
        }
        for weights in self.weights.shrinks() {
            if !weights.is_empty() {
                out.push(SplitCase { weights, ..self.clone() });
            }
        }
        for max_shard in self.max_shard.shrinks() {
            if max_shard > 0 {
                out.push(SplitCase { max_shard, ..self.clone() });
            }
        }
        out
    }
}

#[test]
fn prop_split_partitions_exactly_once_and_merge_restores_order() {
    forall(
        42,
        300,
        |rng| SplitCase {
            n: rng.below(64),
            weights: (0..1 + rng.below(6))
                .map(|_| rng.below(10) as u32)
                .collect(),
            max_shard: 1 + rng.below(9),
        },
        |case| {
            let w: Vec<f64> =
                case.weights.iter().map(|&x| x as f64).collect();
            let shards = chunk(&Router::split(case.n, &w),
                               case.max_shard);
            let alive = case.weights.iter().any(|&x| x > 0);
            if !alive || case.n == 0 {
                return if shards.is_empty() {
                    Ok(())
                } else {
                    Err(format!(
                        "no samples or no live replica, yet shards: \
                         {shards:?}"
                    ))
                };
            }
            for s in &shards {
                if s.len == 0 || s.len > case.max_shard {
                    return Err(format!(
                        "shard size out of (0, {}]: {s:?}",
                        case.max_shard
                    ));
                }
                match case.weights.get(s.replica) {
                    Some(&wt) if wt > 0 => {}
                    _ => {
                        return Err(format!(
                            "shard on dead/unknown replica: {s:?}"
                        ))
                    }
                }
            }
            // every sample of 0..n in exactly one shard
            let mut seen = vec![0u32; case.n];
            for s in &shards {
                for i in s.start..s.start + s.len {
                    match seen.get_mut(i) {
                        Some(c) => *c += 1,
                        None => {
                            return Err(format!(
                                "index {i} outside 0..{}",
                                case.n
                            ))
                        }
                    }
                }
            }
            if let Some(i) = seen.iter().position(|&c| c != 1) {
                return Err(format!(
                    "sample {i} covered {} times",
                    seen[i]
                ));
            }
            // merge restores request order from the shard outputs
            let parts: Vec<(Shard, Vec<usize>)> = shards
                .iter()
                .map(|s| (*s, (s.start..s.start + s.len).collect()))
                .collect();
            let merged = Router::merge(case.n, &parts)?;
            if merged != (0..case.n).collect::<Vec<_>>() {
                return Err(format!(
                    "merge scrambled the order: {merged:?}"
                ));
            }
            Ok(())
        },
    );
}
