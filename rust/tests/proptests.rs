//! Property-based tests over the coordinator substrates (testkit harness —
//! the offline proptest substitute): bit-packing, pow-2 rounding, k-means
//! invariants, pruning, schedules, detection metrics, checkpoint I/O, and
//! the plan/execute inference engine's cross-mode agreement.

use lutq::data::detection::GtBox;
use lutq::detect::{self, Detection};
use lutq::infer::{ExecMode, KernelBackend, OpCounts, Plan, PlanOptions,
                  Tensor};
use lutq::params::export::{LutLayer, QuantizedModel};
use lutq::params::{checkpoint, HostTensor, ParamStore};
use lutq::quant::bitpack::{bits_for, pack_assignments, unpack_assignments};
use lutq::quant::kmeans;
use lutq::quant::pow2::{is_pow2_or_zero, pow2_round};
use lutq::quant::pruning;
use lutq::testkit::{forall, gen};
use lutq::util::Rng;

#[test]
fn prop_bitpack_roundtrip() {
    forall(
        11,
        200,
        |r| {
            let k = [2usize, 3, 4, 5, 7, 8, 16, 100, 256][r.below(9)];
            let n = r.below(500);
            let a: Vec<u32> = (0..n).map(|_| r.below(k) as u32).collect();
            (a, k)
        },
        |(a, k)| {
            let packed = pack_assignments(a, *k);
            let expect_len =
                (a.len() as u64 * bits_for(*k) as u64).div_ceil(8);
            if packed.len() as u64 != expect_len {
                return Err(format!("packed len {} != {expect_len}",
                                   packed.len()));
            }
            let back = unpack_assignments(&packed, a.len(), *k);
            if &back != a {
                return Err("roundtrip mismatch".into());
            }
            Ok(())
        },
    );
}


#[test]
fn prop_pow2_output_is_pow2_and_nearest_side() {
    forall(
        13,
        500,
        |r| r.normal() * 8.0,
        |&x| {
            let q = pow2_round(x, -8, 8).to_f32();
            if !is_pow2_or_zero(q) {
                return Err(format!("{x} -> {q} not pow2"));
            }
            if x != 0.0 && q != 0.0 && (q < 0.0) != (x < 0.0) {
                return Err(format!("{x} -> {q} sign flip"));
            }
            // within clamp range the ratio |q|/|x| stays in [2^-0.5, 2^0.5]
            if q != 0.0 && x.abs() > 0.005 && x.abs() < 200.0 {
                let ratio = (q / x).abs();
                if !(0.70..=1.42).contains(&ratio) {
                    return Err(format!("{x} -> {q} ratio {ratio}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_kmeans_update_never_increases_mse() {
    forall(
        17,
        60,
        |r| {
            let vals = gen::f32_vec(r, 400, 1.0);
            let k = 1 + r.below(8);
            (vals, k)
        },
        |(vals, k)| {
            let mut rng = Rng::new(1);
            let mut centroids = kmeans::kmeanspp_init(vals, *k, &mut rng);
            let mut a = kmeans::assign(vals, &centroids);
            let mut prev = kmeans::tying_mse(vals, &a, &centroids);
            for _ in 0..5 {
                kmeans::update(vals, &a, &mut centroids);
                a = kmeans::assign(vals, &centroids);
                let mse = kmeans::tying_mse(vals, &a, &centroids);
                if mse > prev + 1e-5 {
                    return Err(format!("mse {prev} -> {mse}"));
                }
                prev = mse;
            }
            Ok(())
        },
    );
}


#[test]
fn prop_prune_mask_exact_fraction_and_smallest() {
    forall(
        19,
        100,
        |r| {
            let vals = gen::f32_vec(r, 300, 2.0);
            let frac = r.f32();
            (vals, frac)
        },
        |(vals, frac)| {
            let mask = pruning::keep_mask(vals, *frac);
            let kept_mags: Vec<f32> = vals
                .iter()
                .zip(&mask)
                .filter(|(_, &k)| k)
                .map(|(v, _)| v.abs())
                .collect();
            let pruned_mags: Vec<f32> = vals
                .iter()
                .zip(&mask)
                .filter(|(_, &k)| !k)
                .map(|(v, _)| v.abs())
                .collect();
            // every pruned magnitude <= every kept magnitude
            if let (Some(pmax), Some(kmin)) = (
                pruned_mags.iter().cloned().reduce(f32::max),
                kept_mags.iter().cloned().reduce(f32::min),
            ) {
                if pmax > kmin {
                    return Err(format!("pruned {pmax} > kept {kmin}"));
                }
            }
            // at least frac pruned (ties may prune slightly more)
            let pruned_frac = pruned_mags.len() as f32 / vals.len() as f32;
            if *frac > 0.0 && pruned_frac + 1e-6 < *frac - 1.0 / vals.len() as f32 {
                return Err(format!("pruned {pruned_frac} < {frac}"));
            }
            Ok(())
        },
    );
}


#[test]
fn prop_iou_bounds_and_symmetry() {
    forall(
        23,
        300,
        |r| {
            vec![r.f32(), r.f32(), 0.05 + 0.5 * r.f32(),
                 0.05 + 0.5 * r.f32(), r.f32(), r.f32(),
                 0.05 + 0.5 * r.f32(), 0.05 + 0.5 * r.f32()]
        },
        |v| {
            if v.len() != 8 {
                return Ok(()); // shrunk out of the generator's domain
            }
            let a = (v[0], v[1], v[2], v[3]);
            let b = (v[4], v[5], v[6], v[7]);
            let ab = detect::iou(a, b);
            let ba = detect::iou(b, a);
            if !(0.0..=1.0 + 1e-6).contains(&ab) {
                return Err(format!("iou {ab} out of [0,1]"));
            }
            if (ab - ba).abs() > 1e-6 {
                return Err(format!("asymmetric {ab} vs {ba}"));
            }
            if (detect::iou(a, a) - 1.0).abs() > 1e-6 {
                return Err("iou(a,a) != 1".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_nms_output_no_overlapping_same_class() {
    forall(
        29,
        100,
        |r| {
            let n = 1 + r.below(20);
            (0..n)
                .map(|_| {
                    vec![r.f32(), r.f32(), 0.05 + 0.3 * r.f32(),
                         0.05 + 0.3 * r.f32(), r.below(3) as f32, r.f32()]
                })
                .collect::<Vec<_>>()
        },
        |rows| {
            let dets: Vec<Detection> = rows
                .iter()
                .map(|v| Detection {
                    cx: v[0],
                    cy: v[1],
                    w: v[2],
                    h: v[3],
                    class: v[4] as usize,
                    score: v[5],
                })
                .collect();
            let kept = detect::nms(dets.clone(), 0.5);
            if kept.len() > dets.len() {
                return Err("nms grew".into());
            }
            for i in 0..kept.len() {
                for j in i + 1..kept.len() {
                    if kept[i].class == kept[j].class {
                        let v = detect::iou(
                            (kept[i].cx, kept[i].cy, kept[i].w, kept[i].h),
                            (kept[j].cx, kept[j].cy, kept[j].w, kept[j].h),
                        );
                        if v > 0.5 {
                            return Err(format!("kept overlap iou {v}"));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}


#[test]
fn prop_map_perfect_detector_is_one() {
    forall(
        31,
        50,
        |r| {
            (0..1 + r.below(8))
                .map(|_| {
                    vec![0.2 + 0.6 * r.f32(), 0.2 + 0.6 * r.f32(),
                         0.1 + 0.2 * r.f32(), 0.1 + 0.2 * r.f32(),
                         r.below(3) as f32]
                })
                .collect::<Vec<_>>()
        },
        |rows| {
            let images: Vec<detect::ImageEval> = rows
                .iter()
                .map(|v| {
                    let g = GtBox {
                        cx: v[0],
                        cy: v[1],
                        w: v[2],
                        h: v[3],
                        class: v[4] as usize,
                    };
                    detect::ImageEval {
                        dets: vec![Detection {
                            cx: g.cx,
                            cy: g.cy,
                            w: g.w,
                            h: g.h,
                            class: g.class,
                            score: 0.9,
                        }],
                        gts: vec![g],
                    }
                })
                .collect();
            let map = detect::mean_average_precision(&images, 3, 0.5);
            if (map - 1.0).abs() > 1e-5 {
                return Err(format!("perfect mAP {map}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_checkpoint_roundtrip_arbitrary_stores() {
    let dir = std::env::temp_dir()
        .join(format!("lutq_prop_ckpt_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    forall(
        37,
        25,
        |r| {
            let n_tensors = 1 + r.below(6);
            (0..n_tensors)
                .map(|i| {
                    let len = 1 + r.below(50);
                    let vals: Vec<f32> =
                        (0..len).map(|_| r.normal()).collect();
                    (format!("t{i}"), vals)
                })
                .collect::<Vec<_>>()
        },
        |tensors| {
            let mut store = ParamStore::new();
            for (name, vals) in tensors {
                store.push(name,
                           HostTensor::f32(vec![vals.len()], vals.clone()));
            }
            let path = dir.join("prop.ckpt");
            checkpoint::save(&store, 99, &path)
                .map_err(|e| e.to_string())?;
            let (loaded, step) =
                checkpoint::load(&path).map_err(|e| e.to_string())?;
            if step != 99 || loaded.len() != store.len() {
                return Err("meta mismatch".into());
            }
            for (name, t) in store.iter() {
                if loaded.get(name) != Some(t) {
                    return Err(format!("tensor {name} mismatch"));
                }
            }
            Ok(())
        },
    );
    std::fs::remove_dir_all(dir).unwrap();
}


/// Dense / LutTrick / ShiftOnly execution over random conv+bn+relu+affine
/// graphs (random strides and kernel sizes, pow-2 dictionary) must agree
/// within 1e-4, and shift-only execution must be multiplier-less.
#[test]
fn prop_plan_exec_modes_agree() {
    forall(
        47,
        60,
        |r| (0..7).map(|_| r.below(1000)).collect::<Vec<usize>>(),
        |p| {
            if p.len() != 7 {
                return Ok(()); // shrunk out of the generator's domain
            }
            let h = 3 + p[0] % 5;
            let cin = 1 + p[1] % 3;
            let cout = 1 + p[2] % 4;
            let k = [1usize, 3][p[3] % 2];
            let stride = 1 + p[4] % 2;
            let classes = 2 + p[5] % 3;
            let seed = p[6] as u64;
            let oh = h.div_ceil(stride); // SAME-pad output side
            let flat = oh * oh * cout;
            let graph = lutq::jsonic::parse(&format!(
                r#"[
                {{"op":"conv","name":"c0","cin":{cin},"cout":{cout},
                  "k":{k},"stride":{stride}}},
                {{"op":"bn","name":"b0"}},
                {{"op":"relu"}},
                {{"op":"flatten"}},
                {{"op":"affine","name":"fc","cin":{flat},
                  "cout":{classes}}}
            ]"#
            ))
            .map_err(|e| format!("graph parse: {e}"))?;

            let mut rng = Rng::new(seed.wrapping_add(1));
            let dict = vec![0.0f32, 0.5, -1.0, 0.25]; // all 0 or ±2^k
            let mut model = QuantizedModel::default();
            for (name, shape) in [("c0", vec![k, k, cin, cout]),
                                  ("fc", vec![flat, classes])] {
                let n: usize = shape.iter().product();
                let assign: Vec<u32> =
                    (0..n).map(|_| rng.below(4) as u32).collect();
                model.lut_layers.push(LutLayer::new(
                    name,
                    dict.clone(),
                    pack_assignments(&assign, 4),
                    shape,
                ));
            }
            let gamma: Vec<f32> =
                (0..cout).map(|_| 0.5 + rng.f32()).collect();
            let rvar: Vec<f32> =
                (0..cout).map(|_| 0.3 + rng.f32()).collect();
            for (s, v) in [("gamma", gamma), ("beta", rng.normals(cout)),
                           ("rmean", rng.normals(cout)), ("rvar", rvar)] {
                model.fp.insert(format!("b0.{s}"),
                                HostTensor::f32(vec![cout], v));
            }
            model.fp.insert("fc.b".into(),
                            HostTensor::f32(vec![classes],
                                            rng.normals(classes)));

            let b = 2;
            let xdata: Vec<f32> = rng
                .normals(b * h * h * cin)
                .iter()
                .map(|v| v * 0.5)
                .collect();
            let x = Tensor::new(vec![b, h, h, cin], xdata);
            // pin scalar: cross-mode agreement is a float-path
            // property — the int backend quantizes each mode's
            // operands differently (i8 weight grid vs product table vs
            // pow-2 shifts), so under LUTQ_KERNEL=int the modes
            // legitimately differ by quantization error, not 1e-4
            let run = |mode: ExecMode|
                       -> Result<(Tensor, OpCounts), String> {
                let plan = Plan::compile(
                    &graph, &model,
                    PlanOptions { mode, act_bits: 0, mlbn: true,
                                  threads: 1,
                                  kernel: KernelBackend::Scalar },
                    &[h, h, cin],
                )
                .map_err(|e| format!("compile {mode:?}: {e}"))?;
                let mut s = plan.scratch();
                plan.run(&x, &mut s)
                    .map_err(|e| format!("run {mode:?}: {e}"))
            };
            let (yd, _) = run(ExecMode::Dense)?;
            let (yl, _) = run(ExecMode::LutTrick)?;
            let (ys, cs) = run(ExecMode::ShiftOnly)?;
            if !cs.is_multiplierless() {
                return Err(format!("shift-only executed multiplies: {cs}"));
            }
            if cs.shifts == 0 {
                return Err("shift-only counted no shifts".into());
            }
            for i in 0..yd.data.len() {
                let (d, l, s_) = (yd.data[i], yl.data[i], ys.data[i]);
                let tol = 1e-4f32.max(d.abs() * 1e-4);
                if (d - l).abs() > tol {
                    return Err(format!("dense {d} vs lut {l} at {i}"));
                }
                if (l - s_).abs() > tol {
                    return Err(format!("lut {l} vs shift {s_} at {i}"));
                }
            }
            Ok(())
        },
    );
}

/// A dangling residual tag is a compile-time diagnostic, not a mid-run
/// failure.
#[test]
fn plan_compile_rejects_dangling_residual_tag() {
    let graph =
        lutq::jsonic::parse(r#"[{"op":"add","tag":"skip"}]"#).unwrap();
    let err = Plan::compile(&graph, &QuantizedModel::default(),
                            PlanOptions::default(), &[4])
        .unwrap_err()
        .to_string();
    assert!(err.contains("save tag `skip`"), "{err}");
}

#[test]
fn prop_lr_schedules_non_negative_and_bounded() {
    use lutq::LrSchedule;
    forall(
        41,
        100,
        |r| (0.001 + r.f32(), 10 + r.below(1000)),
        |(peak, total)| {
            let s = LrSchedule::cosine(*peak, *total, total / 10 + 1);
            for step in 0..*total {
                let lr = s.at(step);
                if lr < 0.0 || lr > *peak * 1.001 {
                    return Err(format!("lr {lr} at {step} (peak {peak})"));
                }
            }
            Ok(())
        },
    );
}

