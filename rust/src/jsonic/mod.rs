//! Minimal JSON parser + serializer (offline substitute for serde_json).
//!
//! Parses the artifact manifests emitted by `python/compile/aot.py`,
//! experiment configs, and serializes metrics/checkpoint metadata. Supports
//! the full JSON grammar except `\u` surrogate pairs beyond the BMP (the
//! manifests are plain ASCII).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---------------------------------------------------------------- access
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` chained access that errors with the path on miss.
    pub fn at(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("json: missing key `{key}` in {self:.0}"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Shape-style arrays: `[64, 32, 32, 3]` -> Vec<usize>.
    pub fn as_shape(&self) -> Option<Vec<usize>> {
        self.as_arr()?
            .iter()
            .map(|x| x.as_usize())
            .collect::<Option<Vec<_>>>()
    }

    /// Numeric arrays as f32 (predict request/response payloads). `None`
    /// if this is not an array or any element is not a number.
    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        self.as_arr()?
            .iter()
            .map(|x| x.as_f64().map(|v| v as f32))
            .collect::<Option<Vec<_>>>()
    }

    // ------------------------------------------------------------ construct
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }

    /// `[f32]` -> JSON number array. Each f32 widens to f64 exactly and
    /// the serializer prints round-trippable doubles, so values survive
    /// serialize -> parse -> `as_f32_vec` bit-for-bit.
    pub fn from_f32s(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    /// `[usize]` -> JSON number array (shape listings).
    pub fn from_usizes(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // ------------------------------------------------------------- serialize
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.to_string())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ------------------------------------------------------------------- parse

pub fn parse(src: &str) -> Result<Json, String> {
    let bytes = src.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != bytes.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

/// Parse a file into Json.
pub fn parse_file(path: &std::path::Path) -> Result<Json, String> {
    let src = std::fs::read_to_string(path)
        .map_err(|e| format!("read {}: {e}", path.display()))?;
    parse(&src).map_err(|e| format!("{}: {e}", path.display()))
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected `{}` at byte {} (found {:?})",
                c as char,
                self.i,
                self.peek().map(|x| x as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other, self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number `{s}`: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u")?,
                                16,
                            )
                            .map_err(|_| "bad \\u hex")?;
                            out.push(
                                char::from_u32(code).ok_or("bad codepoint")?,
                            );
                            self.i += 4;
                        }
                        other => {
                            return Err(format!("bad escape {other:?}"))
                        }
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // advance over one UTF-8 char
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|e| e.to_string())?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                other => return Err(format!("expected , or ] got {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                other => return Err(format!("expected , or }} got {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = parse(r#"{"a": [1, 2, {"b": "c"}], "d": false}"#).unwrap();
        assert_eq!(j.at("a").as_arr().unwrap().len(), 3);
        assert_eq!(
            j.at("a").as_arr().unwrap()[2].at("b").as_str(),
            Some("c")
        );
        assert_eq!(j.at("d").as_bool(), Some(false));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"x","shape":[64,32,32,3],"f":1.25,"n":null,"s":"q\"uo\\te"}"#;
        let j = parse(src).unwrap();
        let j2 = parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn as_shape() {
        let j = parse("[64, 10]").unwrap();
        assert_eq!(j.as_shape(), Some(vec![64, 10]));
    }

    #[test]
    fn f32_arrays_roundtrip_bitwise() {
        let xs = vec![0.1f32, -2.5e-8, 1.0, f32::MIN_POSITIVE, 3.25e7];
        let j = Json::from_f32s(&xs);
        let back = parse(&j.to_string()).unwrap().as_f32_vec().unwrap();
        assert_eq!(xs.len(), back.len());
        for (a, b) in xs.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits(), "{a} vs {b}");
        }
        assert!(parse(r#"["x"]"#).unwrap().as_f32_vec().is_none());
        assert!(parse("3").unwrap().as_f32_vec().is_none());
        assert_eq!(Json::from_usizes(&[4, 2]).as_shape(),
                   Some(vec![4, 2]));
    }

    #[test]
    fn errors_are_reported() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }
}
