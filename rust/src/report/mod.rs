//! Report generation: paper-style tables and figure series (markdown +
//! CSV), written under `reports/` by the benches and the `report` CLI
//! subcommand. EXPERIMENTS.md §results is assembled from these.

use std::io::Write;
use std::path::Path;

/// Version of the serialized report row formats (`BENCH_*.json` latency
/// rows and the `serve_*` metrics-JSONL events). Bump when a field is
/// renamed, removed, or changes meaning — *adding* fields is not a bump
/// (consumers parse by name and ignore unknowns). `bench-check` warns,
/// not fails, on version skew so mixed-vintage report files stay
/// comparable; see `rust/reports/README.md` for the bump policy.
///
/// History: 1 = implicit pre-versioned rows (PR 1-7); 2 = versioned
/// rows plus open-loop fields (`offered_rps`, `slo_curve`) and hedge /
/// breaker counters on cluster rows.
pub const SCHEMA_VERSION: u32 = 2;

/// A labelled series of (x, y) points — one line of a paper figure.
#[derive(Debug, Clone)]
pub struct Series {
    pub label: String,
    pub points: Vec<(f32, f32)>,
}

/// Render figure series as CSV (x, then one column per series).
pub fn series_to_csv(xlabel: &str, series: &[Series]) -> String {
    let mut s = String::new();
    s.push_str(xlabel);
    for sr in series {
        s.push(',');
        s.push_str(&sr.label);
    }
    s.push('\n');
    let xs: Vec<f32> = series
        .first()
        .map(|sr| sr.points.iter().map(|p| p.0).collect())
        .unwrap_or_default();
    for (i, x) in xs.iter().enumerate() {
        s.push_str(&format!("{x}"));
        for sr in series {
            match sr.points.get(i) {
                Some(&(_, y)) if y.is_finite() => {
                    s.push_str(&format!(",{y:.4}"))
                }
                _ => s.push(','),
            }
        }
        s.push('\n');
    }
    s
}

/// Render figure series as an ASCII plot (for bench stdout) — the Fig-2
/// style error-increase-vs-pruning curves are legible at terminal scale.
pub fn series_to_ascii(title: &str, xlabel: &str, ylabel: &str,
                       series: &[Series], width: usize,
                       height: usize) -> String {
    let all: Vec<(f32, f32)> = series
        .iter()
        .flat_map(|s| s.points.iter().cloned())
        .filter(|(x, y)| x.is_finite() && y.is_finite())
        .collect();
    if all.is_empty() {
        return format!("{title}: (no data)\n");
    }
    let (xmin, xmax) = all
        .iter()
        .fold((f32::MAX, f32::MIN), |(lo, hi), &(x, _)| {
            (lo.min(x), hi.max(x))
        });
    let (ymin, ymax) = all
        .iter()
        .fold((f32::MAX, f32::MIN), |(lo, hi), &(_, y)| {
            (lo.min(y), hi.max(y))
        });
    let xspan = (xmax - xmin).max(1e-9);
    let yspan = (ymax - ymin).max(1e-9);
    let mut grid = vec![vec![b' '; width]; height];
    let marks = [b'o', b'x', b'+', b'*', b'#', b'@'];
    for (si, sr) in series.iter().enumerate() {
        for &(x, y) in &sr.points {
            if !x.is_finite() || !y.is_finite() {
                continue;
            }
            let col = (((x - xmin) / xspan) * (width - 1) as f32).round()
                as usize;
            let row = height - 1
                - (((y - ymin) / yspan) * (height - 1) as f32).round()
                    as usize;
            grid[row][col] = marks[si % marks.len()];
        }
    }
    let mut s = format!("{title}\n  {ylabel} [{ymin:.3} .. {ymax:.3}]\n");
    for row in grid {
        s.push_str("  |");
        s.push_str(std::str::from_utf8(&row).unwrap());
        s.push('\n');
    }
    s.push_str(&format!("  +{}\n   {xlabel} [{xmin:.2} .. {xmax:.2}]\n",
                        "-".repeat(width)));
    for (si, sr) in series.iter().enumerate() {
        s.push_str(&format!("   {} = {}\n",
                            marks[si % marks.len()] as char, sr.label));
    }
    s
}

/// Write a report file under `reports/`, creating the directory.
pub fn write_report(dir: &Path, name: &str,
                    content: &str) -> std::io::Result<std::path::PathBuf> {
    std::fs::create_dir_all(dir)?;
    let path = dir.join(name);
    let mut f = std::fs::File::create(&path)?;
    f.write_all(content.as_bytes())?;
    Ok(path)
}

/// Latency summary of one serving configuration: the shared row format of
/// `lutq serve-bench` and the `infer_engine` bench (BENCH_*.json files
/// track the perf trajectory across PRs).
#[derive(Debug, Clone)]
pub struct LatencyReport {
    pub label: String,
    /// model name, so multi-model serving runs stay distinguishable
    /// ("" = single-model legacy row)
    pub model: String,
    /// kernel backend the row measured (`scalar` / `simd-avx2` /
    /// `simd-portable` / `int-scalar` / `int-avx2` / `int-portable`;
    /// "" = legacy row predating backends)
    pub backend: String,
    /// transport the row measured (`direct` / `inproc` / `http` /
    /// `binary` / `cluster` / `cluster-http` / `cluster-binary`;
    /// "" = legacy row predating the field). Self-describing, so
    /// consumers need not decode the label; `bench-check` treats it as
    /// informational.
    pub transport: String,
    pub batch: usize,
    pub iters: usize,
    pub threads: usize,
    /// serving replicas behind the row (cluster rows; 1 elsewhere)
    pub replicas: usize,
    /// legacy path: the graph was re-lowered on every request
    pub compile_per_call: bool,
    pub p50_ms: f32,
    pub p90_ms: f32,
    pub p99_ms: f32,
    pub p999_ms: f32,
    pub mean_ms: f32,
    pub images_per_sec: f64,
    /// fraction of requests answered 429 (`deadline_exceeded`) on
    /// deadline-carrying serving rows; 0.0 elsewhere
    pub shed_rate: f64,
    /// bytes of integer product-table / quantized-weight storage the
    /// measured plan carries (int-backend rows; 0 elsewhere)
    pub int_table_bytes: usize,
    /// open-loop rows: the offered arrival rate in requests/sec the
    /// generator scheduled (0.0 on closed-loop rows)
    pub offered_rps: f64,
    /// open-loop rows: latency-under-SLO curve — for each deadline
    /// bound in ms, the fraction of *all issued* requests answered OK
    /// within it (rejected and failed requests count against
    /// attainment). Empty on closed-loop rows.
    pub slo_curve: Vec<(f32, f64)>,
}

impl LatencyReport {
    /// Summarize per-request latencies (`lat_ms`) measured over
    /// `total_s` seconds of wall clock.
    pub fn from_latencies(label: impl Into<String>, batch: usize,
                          threads: usize, compile_per_call: bool,
                          lat_ms: &[f32], total_s: f64) -> Self {
        let iters = lat_ms.len();
        let mean =
            lat_ms.iter().sum::<f32>() / lat_ms.len().max(1) as f32;
        let q = |p: f64| if lat_ms.is_empty() {
            0.0
        } else {
            crate::util::stats::quantile(lat_ms, p)
        };
        LatencyReport {
            label: label.into(),
            model: String::new(),
            backend: String::new(),
            transport: String::new(),
            batch,
            iters,
            threads,
            replicas: 1,
            compile_per_call,
            p50_ms: q(0.50),
            p90_ms: q(0.90),
            p99_ms: q(0.99),
            p999_ms: q(0.999),
            mean_ms: mean,
            images_per_sec: (batch * iters) as f64 / total_s.max(1e-9),
            shed_rate: 0.0,
            int_table_bytes: 0,
            offered_rps: 0.0,
            slo_curve: Vec::new(),
        }
    }

    /// Tag the row with the model it measured (builder style).
    pub fn with_model(mut self, model: impl Into<String>) -> Self {
        self.model = model.into();
        self
    }

    /// Tag the row with the kernel backend it measured (builder style).
    pub fn with_backend(mut self, backend: impl Into<String>) -> Self {
        self.backend = backend.into();
        self
    }

    /// Tag the row with the transport it measured (builder style).
    pub fn with_transport(mut self, transport: impl Into<String>) -> Self {
        self.transport = transport.into();
        self
    }

    /// Tag the row with its deadline-shed fraction (builder style).
    pub fn with_shed_rate(mut self, rate: f64) -> Self {
        self.shed_rate = rate;
        self
    }

    /// Tag the row with the replica count it measured (builder style) —
    /// the 1-vs-N cluster scaling rows.
    pub fn with_replicas(mut self, replicas: usize) -> Self {
        self.replicas = replicas;
        self
    }

    /// Tag the row with the plan's integer product-table footprint
    /// (builder style) — nonzero only on int-backend rows.
    pub fn with_table_bytes(mut self, bytes: usize) -> Self {
        self.int_table_bytes = bytes;
        self
    }

    /// Tag the row as an open-loop measurement (builder style): the
    /// offered arrival rate and the latency-under-SLO curve.
    pub fn with_open_loop(mut self, offered_rps: f64,
                          slo_curve: Vec<(f32, f64)>) -> Self {
        self.offered_rps = offered_rps;
        self.slo_curve = slo_curve;
        self
    }

    pub fn to_json(&self) -> String {
        let slo = self
            .slo_curve
            .iter()
            .map(|&(b, f)| format!("[{b:.1},{f:.4}]"))
            .collect::<Vec<_>>()
            .join(",");
        format!(
            "{{\"schema_version\":{},\
             \"label\":\"{}\",\"model\":\"{}\",\"backend\":\"{}\",\
             \"transport\":\"{}\",\"batch\":{},\
             \"iters\":{},\"threads\":{},\"replicas\":{},\
             \"compile_per_call\":{},\"p50_ms\":{:.4},\"p90_ms\":{:.4},\
             \"p99_ms\":{:.4},\"p999_ms\":{:.4},\"mean_ms\":{:.4},\
             \"images_per_sec\":{:.2},\"shed_rate\":{:.4},\
             \"int_table_bytes\":{},\"offered_rps\":{:.2},\
             \"slo_curve\":[{}]}}",
            SCHEMA_VERSION,
            json_escape(&self.label),
            json_escape(&self.model),
            json_escape(&self.backend),
            json_escape(&self.transport),
            self.batch,
            self.iters,
            self.threads,
            self.replicas,
            self.compile_per_call,
            self.p50_ms,
            self.p90_ms,
            self.p99_ms,
            self.p999_ms,
            self.mean_ms,
            self.images_per_sec,
            self.shed_rate,
            self.int_table_bytes,
            self.offered_rps,
            slo
        )
    }
}

/// Canonical bench-label segment for a kernel backend name: the
/// auto-dispatched variants collapse to their family so row labels stay
/// machine-independent (`simd-avx2` on x86-64 CI and `simd-portable`
/// elsewhere measure the same dispatch seam, likewise `int-avx2` /
/// `int-portable` → `int`), while the pinned backends (`scalar`,
/// `int-scalar`) pass through as their own rows.
pub fn kernel_tag(backend: &str) -> &str {
    if backend.starts_with("simd") {
        "simd"
    } else if matches!(backend, "int-avx2" | "int-portable") {
        "int"
    } else {
        backend
    }
}

/// Minimal JSON string escaping (quotes, backslashes, control chars) for
/// labels built from user-supplied names.
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out
}

/// Render latency rows as a JSON array (the BENCH_*.json format).
pub fn latency_reports_json(rows: &[LatencyReport]) -> String {
    let mut s = String::from("[\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str("  ");
        s.push_str(&r.to_json());
        if i + 1 < rows.len() {
            s.push(',');
        }
        s.push('\n');
    }
    s.push(']');
    s.push('\n');
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<Series> {
        vec![
            Series {
                label: "2bit".into(),
                points: vec![(0.0, 0.1), (50.0, 0.5), (90.0, 3.0)],
            },
            Series {
                label: "4bit".into(),
                points: vec![(0.0, 0.0), (50.0, 0.2), (90.0, 1.0)],
            },
        ]
    }

    #[test]
    fn csv_has_header_and_rows() {
        let csv = series_to_csv("prune_pct", &sample());
        let lines: Vec<&str> = csv.trim().lines().collect();
        assert_eq!(lines[0], "prune_pct,2bit,4bit");
        assert_eq!(lines.len(), 4);
        assert!(lines[1].starts_with("0,0.1000,0.0000"));
    }

    #[test]
    fn ascii_plot_contains_marks_and_labels() {
        let plot = series_to_ascii("Fig 2", "prune %", "err incr",
                                   &sample(), 40, 10);
        assert!(plot.contains("Fig 2"));
        assert!(plot.contains("o = 2bit"));
        assert!(plot.contains("x = 4bit"));
        assert!(plot.matches('o').count() >= 3);
    }

    #[test]
    fn latency_report_percentiles_and_json() {
        let lat: Vec<f32> = (1..=1000).map(|i| i as f32 / 100.0).collect();
        let r = LatencyReport::from_latencies("m/lut/served", 1, 4, false,
                                              &lat, 2.0)
            .with_model("cifar_lutq4")
            .with_backend("simd-avx2")
            .with_transport("inproc")
            .with_table_bytes(6144);
        assert!(r.p50_ms <= r.p90_ms && r.p90_ms <= r.p99_ms
                && r.p99_ms <= r.p999_ms);
        assert!((r.p999_ms - 9.99).abs() < 0.02, "{}", r.p999_ms);
        assert!((r.images_per_sec - 500.0).abs() < 1e-6);
        let j = r.to_json();
        assert!(j.contains("\"model\":\"cifar_lutq4\""), "{j}");
        assert!(j.contains("\"backend\":\"simd-avx2\""), "{j}");
        assert!(j.contains("\"transport\":\"inproc\""), "{j}");
        assert!(j.contains("\"p999_ms\":"), "{j}");
        assert!(j.contains("\"shed_rate\":0.0000"), "{j}");
        assert!(j.contains("\"int_table_bytes\":6144"), "{j}");
        assert!(j.contains("\"offered_rps\":0.00"), "{j}");
        assert!(j.contains("\"slo_curve\":[]"), "{j}");
        // stays machine-parseable
        let parsed = crate::jsonic::parse(&j).unwrap();
        assert_eq!(parsed.at("model").as_str(), Some("cifar_lutq4"));
        assert_eq!(parsed.at("backend").as_str(), Some("simd-avx2"));
        assert_eq!(parsed.at("transport").as_str(), Some("inproc"));
        assert_eq!(parsed.at("int_table_bytes").as_usize(), Some(6144));
        assert_eq!(parsed.at("schema_version").as_usize(),
                   Some(SCHEMA_VERSION as usize));
    }

    #[test]
    fn open_loop_row_serializes_slo_curve() {
        let r = LatencyReport::from_latencies("m/open-loop", 1, 2, false,
                                              &[1.0, 2.0], 1.0)
            .with_open_loop(250.0,
                            vec![(5.0, 0.5), (20.0, 0.975), (50.0, 1.0)]);
        let j = r.to_json();
        assert!(j.contains("\"offered_rps\":250.00"), "{j}");
        assert!(j.contains("\"slo_curve\":[[5.0,0.5000],[20.0,0.9750],\
                            [50.0,1.0000]]"), "{j}");
        let parsed = crate::jsonic::parse(&j).unwrap();
        let curve = parsed.at("slo_curve").as_arr().unwrap();
        assert_eq!(curve.len(), 3);
        let mid = curve[1].as_arr().unwrap();
        assert_eq!(mid[0].as_f64(), Some(20.0));
        assert_eq!(mid[1].as_f64(), Some(0.975));
    }

    #[test]
    fn kernel_tag_collapses_simd_variants() {
        assert_eq!(kernel_tag("simd-avx2"), "simd");
        assert_eq!(kernel_tag("simd-portable"), "simd");
        assert_eq!(kernel_tag("scalar"), "scalar");
        assert_eq!(kernel_tag("int-avx2"), "int");
        assert_eq!(kernel_tag("int-portable"), "int");
        assert_eq!(kernel_tag("int-scalar"), "int-scalar");
        assert_eq!(kernel_tag("int"), "int");
    }

    #[test]
    fn write_report_creates_file() {
        let dir = std::env::temp_dir()
            .join(format!("lutq_report_{}", std::process::id()));
        let p = write_report(&dir, "t.md", "hello").unwrap();
        assert_eq!(std::fs::read_to_string(p).unwrap(), "hello");
        std::fs::remove_dir_all(dir).unwrap();
    }
}
