//! Memory-footprint and operation-count accounting — the paper's section-1
//! formulas, applied to whole models. These numbers are *exact* (they are
//! arithmetic over layer shapes), so the Table-2 memory claims and the VOC
//! footprint-reduction factors reproduce exactly at any model scale.
//!
//! Per layer with N weights, dictionary size K, float width B_float:
//!   dense  bits = N * B_float
//!   LUT-Q  bits = K * B_float + N * ceil(log2 K)
//! Multiplications per affine output: I dense vs K with the bucket trick.

use super::bitpack::bits_for;

pub const B_FLOAT: u64 = 32;

/// Shape summary of one quantizable layer.
#[derive(Debug, Clone)]
pub struct LayerShape {
    pub name: String,
    /// total weight count N
    pub n: u64,
    /// inner dimension I (fan-in per output: k*k*cin for conv, I for affine)
    pub fan_in: u64,
    /// number of output accumulators computed per forward (O * spatial)
    pub outputs: u64,
}

#[derive(Debug, Clone, Default)]
pub struct CompressionStats {
    pub dense_bits: u64,
    pub lutq_bits: u64,
    pub dense_mults: u64,
    pub lutq_mults: u64,
    /// multiplies that become bit-shifts when the dictionary is pow-2
    pub shift_eligible: u64,
}

impl CompressionStats {
    /// Paper formulas over a set of layers quantized with K entries each.
    pub fn compute(layers: &[LayerShape], k: usize) -> Self {
        let kbits = bits_for(k) as u64;
        let mut s = CompressionStats::default();
        for l in layers {
            s.dense_bits += l.n * B_FLOAT;
            s.lutq_bits += k as u64 * B_FLOAT + l.n * kbits;
            // dense: fan_in multiplications per output accumulator
            s.dense_mults += l.outputs * l.fan_in;
            // LUT-Q inference trick: K multiplications per accumulator
            s.lutq_mults += l.outputs * (k as u64);
            s.shift_eligible += l.outputs * (k as u64);
        }
        s
    }

    pub fn compression_ratio(&self) -> f64 {
        self.dense_bits as f64 / self.lutq_bits as f64
    }

    pub fn mult_reduction(&self) -> f64 {
        self.dense_mults as f64 / self.lutq_mults.max(1) as f64
    }

    pub fn dense_bytes(&self) -> u64 {
        self.dense_bits / 8
    }

    pub fn lutq_bytes(&self) -> u64 {
        self.lutq_bits / 8
    }
}

/// Activation memory at `act_bits` for a list of activation sizes
/// (the paper §4: with very low weight bitwidth, activations dominate —
/// hence their 8-bit activation experiments).
pub fn activation_bytes(act_elems: &[u64], act_bits: u64) -> u64 {
    act_elems.iter().sum::<u64>() * act_bits / 8
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layer(n: u64, fan_in: u64, outputs: u64) -> LayerShape {
        LayerShape { name: "l".into(), n, fan_in, outputs }
    }

    #[test]
    fn paper_formula_exact() {
        // one affine layer: N = 1000*500, I = 1000, O = 500, K = 16 (4-bit)
        let l = layer(500_000, 1000, 500);
        let s = CompressionStats::compute(std::slice::from_ref(&l), 16);
        assert_eq!(s.dense_bits, 500_000 * 32);
        assert_eq!(s.lutq_bits, 16 * 32 + 500_000 * 4);
        // ~8x compression at 4-bit
        assert!((s.compression_ratio() - 8.0).abs() < 0.01);
        // mults: I=1000 -> K=16 per output
        assert_eq!(s.dense_mults, 500 * 1000);
        assert_eq!(s.lutq_mults, 500 * 16);
        assert!((s.mult_reduction() - 62.5).abs() < 1e-9);
    }

    #[test]
    fn two_bit_ratio_near_16x() {
        let l = layer(1_000_000, 100, 10_000);
        let s = CompressionStats::compute(std::slice::from_ref(&l), 4);
        assert!((s.compression_ratio() - 16.0).abs() < 0.01);
    }

    #[test]
    fn resnet50_scale_matches_paper_magnitude() {
        // The paper: ResNet-50 2-bit weights + 8-bit activations = 7.4 MB
        // vs 97.5 MB fp32. ResNet-50 has ~25.5M params; at 2 bits thats
        // ~6.4MB params + activations. Check our formula gives the same
        // order: 25.5M * 32 bits = 102 MB dense, 25.5M * 2 bits = 6.4 MB.
        let l = layer(25_500_000, 576, 25_500_000 / 576);
        let s = CompressionStats::compute(std::slice::from_ref(&l), 4);
        assert!((s.dense_bytes() as f64 - 102e6).abs() < 3e6);
        assert!((s.lutq_bytes() as f64 - 6.4e6).abs() < 0.3e6);
    }

    #[test]
    fn activation_budget() {
        assert_eq!(activation_bytes(&[1000, 2000], 8), 3000);
        assert_eq!(activation_bytes(&[1000], 32), 4000);
    }
}
