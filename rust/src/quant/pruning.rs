//! Magnitude pruning masks — the LUT-Q pruning constraint (paper Fig. 2:
//! "constrain the assignment matrix and the dictionary to generate networks
//! with pruned weight matrices").
//!
//! The training-path pruning runs inside the AOT artifact; this host-side
//! mirror validates artifact outputs, drives export-time sparsity stats and
//! provides pruning schedules to the trainer.

/// Magnitude threshold such that ~`frac` of |values| fall at or below it.
pub fn magnitude_threshold(values: &[f32], frac: f32) -> f32 {
    if values.is_empty() || frac <= 0.0 {
        return -1.0; // below any |w|
    }
    let mut mags: Vec<f32> = values.iter().map(|v| v.abs()).collect();
    mags.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let frac = frac.clamp(0.0, 1.0);
    let idx = ((mags.len() as f32 * frac).ceil() as usize)
        .saturating_sub(1)
        .min(mags.len() - 1);
    mags[idx]
}

/// Boolean keep-mask: true = weight survives, false = pruned to zero.
pub fn keep_mask(values: &[f32], frac: f32) -> Vec<bool> {
    let thr = magnitude_threshold(values, frac);
    values.iter().map(|v| v.abs() > thr).collect()
}

/// Fraction of exact zeros in a tied-weight vector (measured sparsity).
pub fn sparsity(values: &[f32]) -> f32 {
    if values.is_empty() {
        return 0.0;
    }
    values.iter().filter(|v| **v == 0.0).count() as f32 / values.len() as f32
}

/// Pruning schedule: ramp the target fraction linearly from 0 to `target`
/// over `ramp_steps`, then hold. Gradual pruning avoids the accuracy cliff
/// of one-shot pruning at high fractions.
#[derive(Debug, Clone, Copy)]
pub struct PruneSchedule {
    pub target: f32,
    pub ramp_steps: usize,
    /// steps before pruning starts (let the dictionary settle first)
    pub warmup: usize,
}

impl PruneSchedule {
    pub fn at(&self, step: usize) -> f32 {
        if step < self.warmup || self.ramp_steps == 0 {
            if step >= self.warmup {
                return self.target;
            }
            return 0.0;
        }
        let p = (step - self.warmup) as f32 / self.ramp_steps as f32;
        (p.min(1.0)) * self.target
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn threshold_prunes_requested_fraction() {
        let mut r = Rng::new(1);
        let vals: Vec<f32> = (0..10_000).map(|_| r.normal()).collect();
        for &f in &[0.3f32, 0.5, 0.7, 0.9] {
            let mask = keep_mask(&vals, f);
            let pruned = mask.iter().filter(|k| !**k).count() as f32
                / vals.len() as f32;
            assert!((pruned - f).abs() < 0.01, "frac {f} got {pruned}");
        }
    }

    #[test]
    fn pruned_are_smallest() {
        let vals = vec![0.1f32, -0.5, 0.01, 2.0, -0.02];
        let mask = keep_mask(&vals, 0.4); // prune 2 of 5
        assert_eq!(mask, vec![true, true, false, true, false]);
    }

    #[test]
    fn frac_zero_keeps_all() {
        let vals = vec![0.0f32, 1.0, -1.0];
        // note: exact zeros survive frac=0 (threshold below any |w|)
        assert_eq!(keep_mask(&vals, 0.0), vec![true, true, true]);
    }

    #[test]
    fn sparsity_counts_zeros() {
        assert_eq!(sparsity(&[0.0, 1.0, 0.0, 2.0]), 0.5);
        assert_eq!(sparsity(&[]), 0.0);
    }

    #[test]
    fn schedule_ramps() {
        let s = PruneSchedule { target: 0.7, ramp_steps: 100, warmup: 50 };
        assert_eq!(s.at(0), 0.0);
        assert_eq!(s.at(49), 0.0);
        assert!((s.at(100) - 0.35).abs() < 1e-6);
        assert!((s.at(150) - 0.7).abs() < 1e-6);
        assert_eq!(s.at(1000), 0.7);
    }

    #[test]
    fn schedule_no_ramp_jumps() {
        let s = PruneSchedule { target: 0.5, ramp_steps: 0, warmup: 10 };
        assert_eq!(s.at(9), 0.0);
        assert_eq!(s.at(10), 0.5);
    }
}
