//! INQ baseline schedule (Zhou et al., ICLR 2017 [24]) — the paper's main
//! published comparator in Table 2.
//!
//! Incremental network quantization splits the weights into groups by
//! magnitude; at each scheduled milestone a further fraction of the largest
//! remaining weights is frozen at power-of-two values while the rest keeps
//! training at full precision. The L2 artifact implements the freeze +
//! pow-2 forward; this module owns the *schedule* the Rust trainer drives
//! through the `aux` scalar input.

/// The INQ accumulated-portion schedule. The INQ paper's default is
/// {0.5, 0.75, 0.875, 1.0} spread across retraining epochs.
#[derive(Debug, Clone)]
pub struct InqSchedule {
    /// (step, accumulated fraction) milestones, ascending.
    milestones: Vec<(usize, f32)>,
}

impl InqSchedule {
    /// Standard INQ portions spread uniformly over `total_steps`.
    pub fn standard(total_steps: usize) -> Self {
        Self::with_portions(total_steps, &[0.5, 0.75, 0.875, 1.0])
    }

    pub fn with_portions(total_steps: usize, portions: &[f32]) -> Self {
        assert!(!portions.is_empty());
        let n = portions.len();
        let milestones = portions
            .iter()
            .enumerate()
            .map(|(i, &p)| (total_steps * i / n, p))
            .collect();
        InqSchedule { milestones }
    }

    /// Accumulated frozen fraction at `step` (the artifact `aux` input).
    pub fn frac_at(&self, step: usize) -> f32 {
        let mut f = 0.0;
        for &(s, p) in &self.milestones {
            if step >= s {
                f = p;
            }
        }
        f
    }

    /// Final schedules always end fully quantized.
    pub fn is_fully_quantized_at(&self, step: usize) -> bool {
        self.frac_at(step) >= 1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standard_schedule_progression() {
        let s = InqSchedule::standard(400);
        assert_eq!(s.frac_at(0), 0.5);
        assert_eq!(s.frac_at(99), 0.5);
        assert_eq!(s.frac_at(100), 0.75);
        assert_eq!(s.frac_at(200), 0.875);
        assert_eq!(s.frac_at(300), 1.0);
        assert!(s.is_fully_quantized_at(399));
        assert!(!s.is_fully_quantized_at(299));
    }

    #[test]
    fn custom_portions() {
        let s = InqSchedule::with_portions(100, &[0.3, 1.0]);
        assert_eq!(s.frac_at(0), 0.3);
        assert_eq!(s.frac_at(49), 0.3);
        assert_eq!(s.frac_at(50), 1.0);
    }

    #[test]
    fn monotone() {
        let s = InqSchedule::standard(1000);
        let mut prev = 0.0;
        for step in 0..1000 {
            let f = s.frac_at(step);
            assert!(f >= prev);
            prev = f;
        }
    }
}
