//! Assignment-matrix bit-packing: N assignments at ceil(log2 K) bits each.
//!
//! This is what realizes the paper's memory formula
//! `K*B_float + N*ceil(log2 K)` bits per layer — the exported model stores
//! the dictionary in f32 plus this packed assignment stream.

/// Bits needed per assignment for a K-entry dictionary.
pub fn bits_for(k: usize) -> u32 {
    assert!(k >= 1);
    if k == 1 {
        1 // a single entry still needs a bit of addressing in the stream
    } else {
        (usize::BITS - (k - 1).leading_zeros()).max(1)
    }
}

/// Pack assignments (each < k) into a little-endian bit stream.
pub fn pack_assignments(assignments: &[u32], k: usize) -> Vec<u8> {
    let bits = bits_for(k) as u64;
    let total_bits = assignments.len() as u64 * bits;
    let mut out = vec![0u8; total_bits.div_ceil(8) as usize];
    let mut bitpos = 0u64;
    for &a in assignments {
        debug_assert!((a as usize) < k.max(2), "assignment {a} >= k {k}");
        let mut v = a as u64;
        let mut remaining = bits;
        while remaining > 0 {
            let byte = (bitpos / 8) as usize;
            let off = (bitpos % 8) as u32;
            let take = (8 - off as u64).min(remaining);
            out[byte] |= ((v & ((1 << take) - 1)) as u8) << off;
            v >>= take;
            bitpos += take;
            remaining -= take;
        }
    }
    out
}

/// Inverse of [`pack_assignments`].
pub fn unpack_assignments(packed: &[u8], n: usize, k: usize) -> Vec<u32> {
    let bits = bits_for(k) as u64;
    let mut out = Vec::with_capacity(n);
    let mut bitpos = 0u64;
    for _ in 0..n {
        let mut v = 0u64;
        let mut got = 0u64;
        while got < bits {
            let byte = (bitpos / 8) as usize;
            let off = (bitpos % 8) as u32;
            let take = (8 - off as u64).min(bits - got);
            let chunk = (packed[byte] >> off) as u64 & ((1 << take) - 1);
            v |= chunk << got;
            got += take;
            bitpos += take;
        }
        out.push(v as u32);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn bits_for_sizes() {
        assert_eq!(bits_for(2), 1);
        assert_eq!(bits_for(3), 2);
        assert_eq!(bits_for(4), 2);
        assert_eq!(bits_for(5), 3);
        assert_eq!(bits_for(16), 4);
        assert_eq!(bits_for(256), 8);
        assert_eq!(bits_for(257), 9);
    }

    #[test]
    fn roundtrip_various_k() {
        let mut r = Rng::new(11);
        for &k in &[2usize, 3, 4, 7, 16, 37, 256] {
            for &n in &[0usize, 1, 7, 8, 9, 1000] {
                let a: Vec<u32> =
                    (0..n).map(|_| r.below(k) as u32).collect();
                let packed = pack_assignments(&a, k);
                let back = unpack_assignments(&packed, n, k);
                assert_eq!(a, back, "k={k} n={n}");
            }
        }
    }

    #[test]
    fn packed_size_matches_formula() {
        let a = vec![3u32; 1000];
        let packed = pack_assignments(&a, 4); // 2 bits each
        assert_eq!(packed.len(), 250);
        let packed = pack_assignments(&a, 16); // 4 bits each
        assert_eq!(packed.len(), 500);
    }
}
