//! Quantization algorithms and accounting — the Rust mirror of the L1/L2
//! quantizer math, used for dictionary init, export, verification of
//! artifact outputs, the INQ baseline schedule, and the paper's memory /
//! multiplication bookkeeping.

pub mod bitpack;
pub mod inq;
pub mod kmeans;
pub mod pow2;
pub mod pruning;
pub mod stats;

pub use bitpack::{pack_assignments, unpack_assignments};
pub use kmeans::{kmeans_1d, KmeansResult};
pub use pow2::{pow2_round, Pow2};
pub use stats::{CompressionStats, LayerShape};
