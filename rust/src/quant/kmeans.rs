//! 1-D k-means (Lloyd) with k-means++ seeding — the paper's Step-4 update,
//! as a host-side reference implementation.
//!
//! Used for: dictionary re-derivation at export time, verification of the
//! L1 kernel outputs (integration tests compare against the artifact), and
//! the `kmeans` bench. The training-path k-means runs on-device inside the
//! AOT train_step artifact.

use crate::util::Rng;

#[derive(Debug, Clone)]
pub struct KmeansResult {
    pub centroids: Vec<f32>,
    pub assignments: Vec<u32>,
    /// Mean squared tying error sum|w - d[A]|^2 / n.
    pub mse: f32,
    pub iterations: usize,
}

/// Nearest-centroid assignment (paper Table 1 Step 4a).
pub fn assign(values: &[f32], centroids: &[f32]) -> Vec<u32> {
    values
        .iter()
        .map(|&v| nearest(v, centroids) as u32)
        .collect()
}

#[inline]
pub fn nearest(v: f32, centroids: &[f32]) -> usize {
    let mut best = 0usize;
    let mut bd = (v - centroids[0]).abs();
    for (i, &c) in centroids.iter().enumerate().skip(1) {
        let d = (v - c).abs();
        if d < bd {
            bd = d;
            best = i;
        }
    }
    best
}

/// Centroid mean update (Step 4b); empty clusters keep their old value.
pub fn update(values: &[f32], assignments: &[u32], centroids: &mut [f32]) {
    let k = centroids.len();
    let mut sums = vec![0f64; k];
    let mut counts = vec![0u64; k];
    for (&v, &a) in values.iter().zip(assignments) {
        sums[a as usize] += v as f64;
        counts[a as usize] += 1;
    }
    for i in 0..k {
        if counts[i] > 0 {
            centroids[i] = (sums[i] / counts[i] as f64) as f32;
        }
    }
}

pub fn tying_mse(values: &[f32], assignments: &[u32], centroids: &[f32]) -> f32 {
    if values.is_empty() {
        return 0.0;
    }
    let s: f64 = values
        .iter()
        .zip(assignments)
        .map(|(&v, &a)| {
            let d = (v - centroids[a as usize]) as f64;
            d * d
        })
        .sum();
    (s / values.len() as f64) as f32
}

/// k-means++ seeding (Arthur & Vassilvitskii 2007) over 1-D data.
pub fn kmeanspp_init(values: &[f32], k: usize, rng: &mut Rng) -> Vec<f32> {
    assert!(!values.is_empty() && k >= 1);
    let mut centroids = Vec::with_capacity(k);
    centroids.push(values[rng.below(values.len())]);
    let mut d2: Vec<f32> = values
        .iter()
        .map(|&v| {
            let d = v - centroids[0];
            d * d
        })
        .collect();
    while centroids.len() < k {
        let total: f64 = d2.iter().map(|&x| x as f64).sum();
        let next = if total <= 0.0 {
            values[rng.below(values.len())]
        } else {
            let mut target = rng.f32() as f64 * total;
            let mut idx = 0;
            for (i, &x) in d2.iter().enumerate() {
                target -= x as f64;
                if target <= 0.0 {
                    idx = i;
                    break;
                }
            }
            values[idx]
        };
        centroids.push(next);
        for (i, &v) in values.iter().enumerate() {
            let d = v - next;
            d2[i] = d2[i].min(d * d);
        }
    }
    centroids.sort_by(|a, b| a.partial_cmp(b).unwrap());
    centroids
}

/// Full Lloyd iteration to (near) convergence, capped at `max_iters`.
pub fn kmeans_1d(values: &[f32], k: usize, max_iters: usize,
                 rng: &mut Rng) -> KmeansResult {
    let mut centroids = kmeanspp_init(values, k, rng);
    let mut assignments = assign(values, &centroids);
    let mut prev_mse = tying_mse(values, &assignments, &centroids);
    let mut iters = 0;
    for _ in 0..max_iters {
        iters += 1;
        update(values, &assignments, &mut centroids);
        assignments = assign(values, &centroids);
        let mse = tying_mse(values, &assignments, &centroids);
        if (prev_mse - mse).abs() < 1e-9 {
            prev_mse = mse;
            break;
        }
        prev_mse = mse;
    }
    KmeansResult {
        centroids,
        assignments,
        mse: prev_mse,
        iterations: iters,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn data(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.normal()).collect()
    }

    #[test]
    fn assign_nearest() {
        let c = [-1.0, 0.0, 1.0];
        // -0.4 is nearer 0.0 (0.4) than -1.0 (0.6)
        assert_eq!(assign(&[-0.9, 0.1, 2.0, -0.4], &c), vec![0, 1, 2, 1]);
    }

    #[test]
    fn lloyd_monotone_mse() {
        let vals = data(5000, 1);
        let mut r = Rng::new(2);
        let mut centroids = kmeanspp_init(&vals, 8, &mut r);
        let mut a = assign(&vals, &centroids);
        let mut prev = tying_mse(&vals, &a, &centroids);
        for _ in 0..10 {
            update(&vals, &a, &mut centroids);
            a = assign(&vals, &centroids);
            let mse = tying_mse(&vals, &a, &centroids);
            assert!(mse <= prev + 1e-6, "mse went up: {prev} -> {mse}");
            prev = mse;
        }
    }

    #[test]
    fn more_clusters_less_error() {
        let vals = data(3000, 3);
        let mut r = Rng::new(4);
        let e2 = kmeans_1d(&vals, 2, 50, &mut r).mse;
        let mut r = Rng::new(4);
        let e8 = kmeans_1d(&vals, 8, 50, &mut r).mse;
        let mut r = Rng::new(4);
        let e32 = kmeans_1d(&vals, 32, 50, &mut r).mse;
        assert!(e8 < e2 && e32 < e8, "{e2} {e8} {e32}");
    }

    #[test]
    fn exact_clusters_recovered() {
        // three well-separated blobs -> near-zero mse, centroids near means
        let mut vals = Vec::new();
        let mut r = Rng::new(5);
        for &c in &[-10.0f32, 0.0, 10.0] {
            for _ in 0..500 {
                vals.push(c + 0.01 * r.normal());
            }
        }
        let res = kmeans_1d(&vals, 3, 50, &mut r);
        assert!(res.mse < 1e-3);
        let mut c = res.centroids.clone();
        c.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!((c[0] + 10.0).abs() < 0.1);
        assert!(c[1].abs() < 0.1);
        assert!((c[2] - 10.0).abs() < 0.1);
    }

    #[test]
    fn empty_cluster_keeps_centroid() {
        let vals = vec![5.0f32; 10];
        let mut c = vec![-100.0, 5.0, 100.0];
        let a = assign(&vals, &c);
        update(&vals, &a, &mut c);
        assert_eq!(c, vec![-100.0, 5.0, 100.0]);
    }

    #[test]
    fn single_cluster_is_mean() {
        let vals = vec![1.0f32, 2.0, 3.0, 4.0];
        let mut r = Rng::new(6);
        let res = kmeans_1d(&vals, 1, 10, &mut r);
        assert!((res.centroids[0] - 2.5).abs() < 1e-6);
    }
}
