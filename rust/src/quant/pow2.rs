//! Power-of-two quantization: the multiplier-less weight representation
//! (paper section 1) and the scale format of multiplier-less BN
//! (appendix A). Mirrors `python/compile/kernels/pow2.py` bit-for-bit in
//! behaviour (same rounding and underflow rules) so exported dictionaries
//! match the artifact state.

/// A signed power-of-two value: sign * 2^exp, or exact zero.
/// This is the storage form in quantized model exports: one sign bit plus a
/// small exponent — a multiplication by it is a bit-shift (+ negate).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Pow2 {
    Zero,
    Val { neg: bool, exp: i8 },
}

impl Pow2 {
    pub fn to_f32(self) -> f32 {
        match self {
            Pow2::Zero => 0.0,
            Pow2::Val { neg, exp } => {
                let m = (exp as f32).exp2();
                if neg {
                    -m
                } else {
                    m
                }
            }
        }
    }

    /// Apply as a shift: x * 2^exp (* sign). This is the multiplier-less
    /// execution path — the infer engine counts these as shifts, not mults.
    pub fn apply(self, x: f32) -> f32 {
        match self {
            Pow2::Zero => 0.0,
            Pow2::Val { neg, exp } => {
                let y = libm_scalbn(x, exp as i32);
                if neg {
                    -y
                } else {
                    y
                }
            }
        }
    }
}

/// x * 2^n via exponent manipulation (shift semantics on the f32 exponent
/// field) without a float multiply.
fn libm_scalbn(x: f32, n: i32) -> f32 {
    if x == 0.0 || !x.is_finite() {
        return x;
    }
    let bits = x.to_bits();
    let exp = ((bits >> 23) & 0xff) as i32;
    if exp == 0 {
        // subnormal: fall back (rare; inputs are normal activations)
        return x * (n as f32).exp2();
    }
    let new_exp = exp + n;
    if new_exp <= 0 || new_exp >= 0xff {
        return x * (n as f32).exp2(); // saturate via float path
    }
    f32::from_bits((bits & !(0xff << 23)) | ((new_exp as u32) << 23))
}

/// Round to the nearest signed power of two with exponent clamped to
/// [exp_min, exp_max]; |x| < 2^(exp_min-1) underflows to zero.
/// Identical semantics to `pow2_quant_ref` in python.
pub fn pow2_round(x: f32, exp_min: i32, exp_max: i32) -> Pow2 {
    if x == 0.0 {
        return Pow2::Zero;
    }
    let absx = x.abs();
    if absx < ((exp_min - 1) as f32).exp2() {
        return Pow2::Zero;
    }
    let e = absx.log2().round().clamp(exp_min as f32, exp_max as f32) as i8;
    Pow2::Val { neg: x < 0.0, exp: e }
}

/// Vector version returning plain f32 (for parity checks vs artifacts).
pub fn pow2_round_vec(xs: &[f32], exp_min: i32, exp_max: i32) -> Vec<f32> {
    xs.iter()
        .map(|&x| pow2_round(x, exp_min, exp_max).to_f32())
        .collect()
}

/// True if v is 0 or ±2^k for integer k (the multiplier-less predicate the
/// tests assert on exported dictionaries).
pub fn is_pow2_or_zero(v: f32) -> bool {
    if v == 0.0 {
        return true;
    }
    let l = v.abs().log2();
    (l - l.round()).abs() < 1e-6
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rounds_to_nearest_pow2() {
        assert_eq!(pow2_round(1.0, -8, 8).to_f32(), 1.0);
        assert_eq!(pow2_round(-1.0, -8, 8).to_f32(), -1.0);
        assert_eq!(pow2_round(3.0, -8, 8).to_f32(), 4.0);
        assert_eq!(pow2_round(0.75, -8, 8).to_f32(), 1.0); // log2(.75)=-0.415 -> 0
        assert_eq!(pow2_round(0.3, -8, 8).to_f32(), 0.25);
    }

    #[test]
    fn zero_and_underflow() {
        assert_eq!(pow2_round(0.0, -8, 8), Pow2::Zero);
        assert_eq!(pow2_round(1e-12, -8, 8), Pow2::Zero);
        // just above the underflow line 2^-9
        assert!(pow2_round(0.002, -8, 8).to_f32() != 0.0);
    }

    #[test]
    fn clamps_exponent() {
        assert_eq!(pow2_round(1e9, -8, 8).to_f32(), 256.0);
        assert_eq!(pow2_round(0.004, -8, 8).to_f32(), 0.00390625); // 2^-8
    }

    #[test]
    fn apply_is_shift() {
        let p = pow2_round(4.0, -8, 8);
        assert_eq!(p.apply(3.0), 12.0);
        let n = pow2_round(-0.5, -8, 8);
        assert_eq!(n.apply(10.0), -5.0);
        assert_eq!(Pow2::Zero.apply(123.0), 0.0);
    }

    #[test]
    fn scalbn_matches_multiply() {
        for &x in &[1.5f32, -2.25, 1000.0, 3.1e-3] {
            for n in -10..=10 {
                let a = libm_scalbn(x, n);
                let b = x * (n as f32).exp2();
                assert!((a - b).abs() <= b.abs() * 1e-6, "{x} {n}: {a} {b}");
            }
        }
    }

    #[test]
    fn predicate() {
        assert!(is_pow2_or_zero(0.0));
        assert!(is_pow2_or_zero(0.25));
        assert!(is_pow2_or_zero(-64.0));
        assert!(!is_pow2_or_zero(3.0));
    }

    #[test]
    fn matches_python_ref_semantics() {
        // Same set of probe values as python/tests/test_kernels.py
        let xs = [0.0f32, 1.0, -1.0, 0.75, 3.0, -0.126, 1e-12, 300.0];
        let q = pow2_round_vec(&xs, -8, 8);
        assert_eq!(q[0], 0.0);
        assert_eq!(q[1], 1.0);
        assert_eq!(q[2], -1.0);
        assert!(q[3] == 0.5 || q[3] == 1.0);
        assert_eq!(q[4], 4.0);
        assert_eq!(q[6], 0.0);
        assert_eq!(q[7], 256.0);
    }
}
