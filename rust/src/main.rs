//! `lutq` CLI — the launcher for training, evaluation, export, inference
//! and report generation over AOT artifacts.
//!
//! Subcommands:
//!   train       train an artifact (LUT-Q / baseline) on its synthetic task
//!   eval        evaluate a checkpoint
//!   export      convert a checkpoint to a packed quantized model
//!   infer       compile + run the plan engine on an exported model
//!   serve       HTTP serving front (predict/models/healthz/metrics);
//!               --replicas N shards batches over N in-process servers;
//!               --wire-addr adds the binary framed front next to HTTP
//!   route       sharding router over remote `lutq serve` replicas
//!               (HTTP or binary shard hops via --shard-transport)
//!   serve-bench latency percentiles over a compiled plan (serving proxy)
//!   wire-check  bitwise-compare one predict over HTTP vs the wire port
//!   bench-check gate a bench JSON against a committed baseline (CI)
//!   report      footprint/ops accounting table for an artifact
//!   list        list available artifacts
//!
//! `infer`, `serve`, `serve-bench`, `bench-check`, `report` and `list`
//! read manifests directly and run the pure-Rust plan engine — no PJRT
//! required. `train`, `eval` and `export` drive AOT programs through the
//! runtime.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, ensure, Context, Result};

use lutq::cli::Cli;
use lutq::data::Dataset;
use lutq::config::TrainConfig;
use lutq::coordinator::{LrSchedule, Trainer};
use lutq::infer::{ExecMode, KernelBackend, Plan, PlanOptions, Tensor};
use lutq::params::export::QuantizedModel;
use lutq::quant::stats::{CompressionStats, LayerShape};
use lutq::report::LatencyReport;
use lutq::runtime::Manifest;
use lutq::serve::{
    HttpClient, HttpConfig, HttpFront, HttpReplica, InProcessReplica,
    ModelReport, Registry, Replica, Router, RouterConfig, Server,
    ServerConfig, WireClient, WireConfig, WireReplica, WireReply,
    WireServer,
};
use lutq::util::{human_bytes, Rng, Timer};
use lutq::{info, Runtime};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        eprintln!("{}", usage());
        std::process::exit(2);
    }
    let sub = args[0].clone();
    let rest = args[1..].to_vec();
    let result = match sub.as_str() {
        "train" => cmd_train(&rest),
        "eval" => cmd_eval(&rest),
        "export" => cmd_export(&rest),
        "infer" => cmd_infer(&rest),
        "serve" => cmd_serve(&rest),
        "route" => cmd_route(&rest),
        "serve-bench" => cmd_serve_bench(&rest),
        "wire-check" => cmd_wire_check(&rest),
        "bench-check" => cmd_bench_check(&rest),
        "report" => cmd_report(&rest),
        "list" => cmd_list(),
        "--help" | "-h" | "help" => {
            println!("{}", usage());
            Ok(())
        }
        other => {
            eprintln!("unknown subcommand `{other}`\n{}", usage());
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() -> String {
    "lutq — LUT-Q training & inference coordinator\n\n\
     Subcommands:\n\
     \x20 train   --artifact <name> [--steps N] [--lr F] [--seed N]\n\
     \x20         [--prune F] [--inq] [--eval-every N] [--ckpt-dir D]\n\
     \x20 eval    --artifact <name> --ckpt <file>\n\
     \x20 export  --artifact <name> --ckpt <file> --out <model.bin>\n\
     \x20 infer   --artifact <name> --model <model.bin> [--mode dense|lut|shift]\n\
     \x20 serve   --artifact <a[,b,..]|synthetic> [--model <m[,n,..]>]\n\
     \x20         [--addr H:P] [--wire-addr H:P] [--batch N] [--workers N]\n\
     \x20         [--plan-threads N]\n\
     \x20         [--linger-ms N] [--queue-cap N] [--max-conns N]\n\
     \x20         [--mode dense|lut|shift] [--kernel auto|scalar|simd|int]\n\
     \x20         [--replicas N] [--max-seconds N] [--metrics-jsonl <file>]\n\
     \x20 route   --replicas <h:p[,h:p,..]> [--addr H:P] [--wire-addr H:P]\n\
     \x20         [--shard-transport http|binary] [--max-shard N]\n\
     \x20         [--max-conns N] [--health-every-ms N] [--max-seconds N]\n\
     \x20         [--metrics-jsonl <file>]\n\
     \x20 serve-bench --artifact <a[,b,..]|synthetic> [--model <m[,n,..]>]\n\
     \x20         [--batch N] [--iters N] [--threads N] [--workers N]\n\
     \x20         [--plan-threads N] [--linger-ms N] [--clients N]\n\
     \x20         [--mode dense|lut|shift] [--kernel auto|scalar|simd|int]\n\
     \x20         [--transport inproc|http|binary|cluster] [--replicas N]\n\
     \x20         [--shard-transport inproc|http|binary]\n\
     \x20         [--addr H:P] [--wire-addr H:P] [--deadline-ms N]\n\
     \x20         [--json <file>] [--compile-per-call] [--no-serve]\n\
     \x20 wire-check --http-addr H:P --wire-addr H:P --model <name>\n\
     \x20         --input-json <file> [--batch N]\n\
     \x20 bench-check [--current <json>] [--baseline <json>]\n\
     \x20         [--max-regress F]\n\
     \x20 report  --artifact <name>\n\
     \x20 list\n"
        .to_string()
}

fn cmd_train(argv: &[String]) -> Result<()> {
    let cli = Cli::new("lutq train", "train an artifact")
        .req("artifact", "artifact preset name (see `lutq list`)")
        .opt("steps", "300", "training steps")
        .opt("lr", "0.05", "peak learning rate (cosine schedule)")
        .opt("seed", "0", "rng seed")
        .opt("prune", "0", "target pruning fraction (pruning artifacts)")
        .opt("eval-every", "0", "evaluate every N steps")
        .opt("ckpt-dir", "", "checkpoint directory")
        .opt("ckpt-every", "0", "checkpoint every N steps")
        .opt("workers", "2", "prefetch worker threads")
        .opt("train-len", "4096", "synthetic train set size")
        .opt("eval-len", "1024", "synthetic eval set size")
        .flag("inq", "drive the INQ freeze schedule")
        .flag("quiet", "suppress progress logs");
    let a = match cli.parse_from(argv) {
        Ok(a) => a,
        Err(msg) => bail!("{msg}"),
    };
    if a.has_flag("quiet") {
        lutq::util::set_log_level(1);
    }
    let steps = a.get_usize("steps");
    let mut cfg = TrainConfig::new(a.get("artifact"))
        .steps(steps)
        .seed(a.get_u64("seed"))
        .lr(LrSchedule::cosine(a.get_f32("lr"), steps, steps / 10 + 1))
        .eval_every(a.get_usize("eval-every"))
        .data_lens(a.get_usize("train-len"), a.get_usize("eval-len"));
    cfg.workers = a.get_usize("workers");
    cfg.checkpoint_every = a.get_usize("ckpt-every");
    if !a.get("ckpt-dir").is_empty() {
        cfg.checkpoint_dir = Some(PathBuf::from(a.get("ckpt-dir")));
        if cfg.checkpoint_every == 0 {
            cfg.checkpoint_every = steps.max(2) / 2;
        }
    }
    let prune = a.get_f32("prune");
    if prune > 0.0 {
        cfg = cfg.prune(prune);
    }
    if a.has_flag("inq") {
        cfg = cfg.inq_standard();
    }

    let rt = Runtime::new(&lutq::artifacts_dir())?;
    let trainer = Trainer::new(&rt, cfg)?;
    let res = trainer.run()?;
    println!(
        "final: loss {:.4}, eval error {:.2}%, {:.2} steps/s",
        res.final_loss,
        res.eval_error * 100.0,
        res.steps_per_sec
    );
    Ok(())
}

fn cmd_eval(argv: &[String]) -> Result<()> {
    let cli = Cli::new("lutq eval", "evaluate a checkpoint")
        .req("artifact", "artifact preset name")
        .req("ckpt", "checkpoint file");
    let a = match cli.parse_from(argv) {
        Ok(a) => a,
        Err(msg) => bail!("{msg}"),
    };
    let rt = Runtime::new(&lutq::artifacts_dir())?;
    let trainer = Trainer::new(&rt, TrainConfig::new(a.get("artifact")))?;
    let (state, step) =
        trainer.state_from_checkpoint(&PathBuf::from(a.get("ckpt")))?;
    let (loss, err) = trainer.evaluate(&state)?;
    println!("checkpoint @ step {step}: eval loss {loss:.4}, error {:.2}%",
             err * 100.0);
    Ok(())
}

fn cmd_export(argv: &[String]) -> Result<()> {
    let cli = Cli::new("lutq export", "export a packed quantized model")
        .req("artifact", "artifact preset name")
        .req("ckpt", "checkpoint file")
        .req("out", "output model path");
    let a = match cli.parse_from(argv) {
        Ok(a) => a,
        Err(msg) => bail!("{msg}"),
    };
    let rt = Runtime::new(&lutq::artifacts_dir())?;
    let man = rt.manifest(a.get("artifact"))?;
    let (store, step) = lutq::params::checkpoint::load(
        &PathBuf::from(a.get("ckpt")))?;
    let model = QuantizedModel::from_state(&store, &man.qlayers);
    let out = PathBuf::from(a.get("out"));
    model.save(&out)?;
    println!(
        "exported step-{step} model: {} ({}; dense {} -> {:.2}x, \
         multiplier-less: {})",
        out.display(),
        human_bytes(model.stored_bytes()),
        human_bytes(model.dense_bytes()),
        model.compression_ratio(),
        model.is_multiplierless()
    );
    Ok(())
}

/// Load an artifact manifest without constructing a PJRT runtime: the
/// plan engine is pure Rust, so inference-side subcommands stay usable
/// even when the XLA backend is absent.
fn load_manifest(artifact: &str) -> Result<Manifest> {
    Manifest::load(&lutq::artifacts_dir().join(artifact)).with_context(|| {
        format!("load artifact `{artifact}` from {} (run `make \
                 artifacts`?)", lutq::artifacts_dir().display())
    })
}

fn parse_mode(s: &str) -> Result<ExecMode> {
    Ok(match s {
        "dense" => ExecMode::Dense,
        "lut" => ExecMode::LutTrick,
        "shift" => ExecMode::ShiftOnly,
        m => bail!("unknown mode {m}"),
    })
}

fn parse_kernel(s: &str) -> Result<KernelBackend> {
    s.parse::<KernelBackend>().map_err(|e| anyhow::anyhow!("{e}"))
}

/// Deterministic synthetic batch matching the artifact's input geometry.
fn synth_batch(man: &Manifest, b: usize) -> Tensor {
    let mut dims = vec![b];
    dims.extend_from_slice(&man.meta.input);
    let ds = lutq::data::SyntheticImages::new(
        man.meta.input[0].max(2), *man.meta.input.get(2).unwrap_or(&3),
        man.meta.num_classes, b, 7, 0.35);
    let mut x = Tensor::zeros(dims);
    if man.meta.arch != "mlp" {
        for i in 0..b {
            let e = ds.input_elems();
            ds.render(i, &mut x.data[i * e..(i + 1) * e]);
        }
    }
    x
}

fn cmd_infer(argv: &[String]) -> Result<()> {
    let cli = Cli::new("lutq infer", "compile + run the plan engine")
        .req("artifact", "artifact preset (for the graph + options)")
        .req("model", "exported model file")
        .opt("mode", "lut", "dense | lut | shift")
        .opt("kernel", "auto", "auto | scalar | simd | int")
        .opt("batch", "4", "batch size");
    let a = match cli.parse_from(argv) {
        Ok(a) => a,
        Err(msg) => bail!("{msg}"),
    };
    let man = load_manifest(a.get("artifact"))?;
    let model = QuantizedModel::load(&PathBuf::from(a.get("model")))?;
    let mode = parse_mode(a.get("mode"))?;
    let opts = PlanOptions { mode, act_bits: man.act_bits(),
                             mlbn: man.mlbn(), threads: 0,
                             kernel: parse_kernel(a.get("kernel"))? };
    let tc = lutq::util::Timer::start();
    let plan = Plan::compile(&man.graph, &model, opts, &man.meta.input)?;
    let compile_ms = tc.elapsed_ms();
    let mut scratch = plan.scratch();

    let x = synth_batch(&man, a.get_usize("batch"));
    let t = lutq::util::Timer::start();
    let counts = plan.run_into(&x, &mut scratch)?;
    let run_ms = t.elapsed_ms();
    let (dims, _) = scratch.output();
    info!("output dims {dims:?}");
    println!(
        "mode={mode:?} backend={}: {counts} (compile {compile_ms:.1} ms, \
         run {run_ms:.1} ms, multiplier-less: {})",
        plan.backend_name(),
        counts.is_multiplierless()
    );
    let tables = plan.int_table_report();
    if !tables.is_empty() {
        println!("int product tables: {} total",
                 human_bytes(plan.int_table_bytes() as u64));
        for (layer, bytes) in &tables {
            println!("  {layer}: {bytes} B");
        }
    }
    Ok(())
}

/// One model entry of a serve-bench run (artifact-loaded or synthetic).
struct BenchModel {
    name: String,
    graph: lutq::jsonic::Json,
    qmodel: QuantizedModel,
    input: Vec<usize>,
    act_bits: usize,
    mlbn: bool,
}

/// Resolve `--artifact`/`--model` into bench models. `synthetic` yields
/// two built-in LUT CNNs (K=4 and K=16) so the serving paths are
/// benchable with no trained artifacts on disk.
fn load_bench_models(artifact: &str,
                     model_files: &str) -> Result<Vec<BenchModel>> {
    if artifact == "synthetic" {
        let mut out = Vec::new();
        for (name, k) in [("synth_lut4", 4usize), ("synth_lut16", 16)] {
            let (graph, qmodel) =
                lutq::testkit::models::synth_conv_model(k, false);
            out.push(BenchModel {
                name: name.to_string(),
                graph,
                qmodel,
                input: lutq::testkit::models::CONV_INPUT.to_vec(),
                act_bits: 0,
                mlbn: false,
            });
        }
        return Ok(out);
    }
    let arts: Vec<&str> =
        artifact.split(',').filter(|s| !s.is_empty()).collect();
    let files: Vec<&str> =
        model_files.split(',').filter(|s| !s.is_empty()).collect();
    ensure!(!arts.is_empty(), "no artifact given");
    ensure!(
        arts.len() == files.len(),
        "--artifact lists {} name(s) but --model lists {} file(s)",
        arts.len(),
        files.len()
    );
    let mut out = Vec::new();
    for (art, file) in arts.iter().zip(&files) {
        let man = load_manifest(art)?;
        let qmodel = QuantizedModel::load(&PathBuf::from(file))?;
        out.push(BenchModel {
            name: man.name.clone(),
            graph: man.graph.clone(),
            qmodel,
            input: man.meta.input.clone(),
            act_bits: man.act_bits(),
            mlbn: man.mlbn(),
        });
    }
    Ok(out)
}

/// Deterministic per-model request pool (`n` single-image samples).
fn sample_pool(bm: &BenchModel, n: usize, seed: u64) -> Vec<Vec<f32>> {
    let elems: usize = bm.input.iter().product();
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.normals(elems)).collect()
}

/// `lutq serve`: stand up the HTTP front over a compiled registry and
/// serve until killed (or `--max-seconds`), then drain gracefully and
/// print/log the per-model reports.
fn cmd_serve(argv: &[String]) -> Result<()> {
    let cli = Cli::new("lutq serve",
                       "HTTP serving front over the coalescing Server")
        .req("artifact",
             "artifact preset(s), comma-separated; `synthetic` serves \
              two built-in models with no files")
        .opt("model", "",
             "exported model file(s), comma-separated (matched 1:1 with \
              --artifact)")
        .opt("addr", "127.0.0.1:8080",
             "bind address (port 0 picks an ephemeral port)")
        .opt("wire-addr", "",
             "also serve the binary framed wire protocol here \
              (empty = HTTP only; port 0 picks an ephemeral port)")
        .opt("mode", "lut", "dense | lut | shift")
        .opt("kernel", "auto", "auto | scalar | simd | int")
        .opt("batch", "8", "coalescing cap per batch")
        .opt("workers", "0", "server worker threads (0 = one per core)")
        .opt("plan-threads", "1", "intra-plan threads per server worker")
        .opt("linger-ms", "1",
             "max ms a partial batch waits to coalesce")
        .opt("queue-cap", "1024", "bounded per-model queue depth")
        .opt("max-conns", "256", "max concurrent http connections")
        .opt("replicas", "1",
             "in-process replica servers behind a sharding router \
              (>1 = cluster mode; workers are split across replicas)")
        .opt("max-seconds", "0",
             "serve for N seconds, then drain and exit (0 = forever)")
        .opt("metrics-jsonl", "",
             "write per-model serve_model JSONL rows here on shutdown \
              (cluster mode adds serve_cluster/serve_replica rows)");
    let a = match cli.parse_from(argv) {
        Ok(a) => a,
        Err(msg) => bail!("{msg}"),
    };
    let mode = parse_mode(a.get("mode"))?;
    let kernel = parse_kernel(a.get("kernel"))?;
    let replicas = a.get_usize("replicas").max(1);
    let batch = a.get_usize("batch").max(1);
    let models = load_bench_models(a.get("artifact"), a.get("model"))?;
    // compile each model once; replica registries share the Arc<Plan>
    let mut plans: Vec<(String, Arc<Plan>)> = Vec::new();
    for bm in &models {
        let opts = PlanOptions {
            mode,
            act_bits: bm.act_bits,
            mlbn: bm.mlbn,
            threads: a.get_usize("plan-threads").max(1),
            kernel,
        };
        let plan = Plan::compile(&bm.graph, &bm.qmodel, opts, &bm.input)?;
        plans.push((bm.name.clone(), Arc::new(plan)));
    }
    let workers_total = match a.get_usize("workers") {
        0 => std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1),
        w => w,
    };
    let mut servers: Vec<Arc<Server>> = Vec::with_capacity(replicas);
    for _ in 0..replicas {
        let mut registry = Registry::new();
        for (name, plan) in &plans {
            registry.register_shared(name, Arc::clone(plan))?;
        }
        servers.push(Arc::new(Server::start(registry, ServerConfig {
            workers: (workers_total / replicas).max(1),
            max_batch: batch,
            linger: Duration::from_millis(a.get_u64("linger-ms")),
            queue_cap: a.get_usize("queue-cap").max(1),
        })?));
    }
    let http_cfg = HttpConfig {
        addr: a.get("addr").to_string(),
        max_conns: a.get_usize("max-conns").max(1),
        ..Default::default()
    };
    let wire_cfg = if a.get("wire-addr").is_empty() {
        None
    } else {
        Some(WireConfig {
            addr: a.get("wire-addr").to_string(),
            max_conns: a.get_usize("max-conns").max(1),
            ..Default::default()
        })
    };
    // single server: fronts straight over it; cluster: fronts over a
    // router sharding across the in-process replicas. The optional
    // wire front serves the same backend as the HTTP front.
    let mut router: Option<Arc<Router>> = None;
    let mut wire_front: Option<WireServer> = None;
    let front = if replicas == 1 {
        if let Some(cfg) = wire_cfg {
            wire_front =
                Some(WireServer::start(Arc::clone(&servers[0]), cfg)?);
        }
        HttpFront::start(Arc::clone(&servers[0]), http_cfg)?
    } else {
        let backends: Vec<Box<dyn Replica>> = servers
            .iter()
            .enumerate()
            .map(|(i, s)| {
                Box::new(InProcessReplica::new(&format!("r{i}"),
                                               Arc::clone(s)))
                    as Box<dyn Replica>
            })
            .collect();
        let rt = Arc::new(Router::new(
            backends,
            RouterConfig { max_shard: batch },
        )?);
        if let Some(cfg) = wire_cfg {
            wire_front = Some(WireServer::start(Arc::clone(&rt), cfg)?);
        }
        let front = HttpFront::start(Arc::clone(&rt), http_cfg)?;
        router = Some(rt);
        front
    };
    println!("lutq serve: listening on http://{} ({} replica(s))",
             front.addr(), replicas);
    if let Some(w) = &wire_front {
        println!("lutq serve: wire protocol on {}", w.addr());
    }
    for i in servers[0].registry().infos() {
        println!("  model {:<20} input {:?} backend {} (coalesce: {})",
                 i.name, i.input, i.backend,
                 if i.batch_invariant { "yes" } else { "batch 1" });
    }
    let secs = a.get_u64("max-seconds");
    if secs == 0 {
        println!("serving until the process is killed \
                  (--max-seconds bounds the run)");
        loop {
            std::thread::sleep(Duration::from_secs(3600));
        }
    }
    std::thread::sleep(Duration::from_secs(secs));
    front.shutdown();
    if let Some(w) = wire_front {
        w.shutdown();
    }
    // drop the router first (it holds Arc<Server> clones through its
    // in-process replicas), then unwrap and drain each server
    let cluster_rows = router.map(|rt| (rt.totals(), rt.reports()));
    if let Some((totals, reps)) = &cluster_rows {
        println!(
            "route: {} submitted, {} completed, {} rejected, {} shed, \
             {} failed (reconciles: {})",
            totals.submitted, totals.completed, totals.rejected,
            totals.shed, totals.failed, totals.reconciles()
        );
        for r in reps {
            println!(
                "  replica {}: {} samples in {} shards, {} failed \
                 shards, {} rerouted (healthy: {})",
                r.replica, r.samples, r.shards, r.failed_shards,
                r.rerouted, r.healthy
            );
        }
    }
    let mut reports: Vec<ModelReport> = Vec::new();
    for (i, server) in servers.into_iter().enumerate() {
        let server = match Arc::try_unwrap(server) {
            Ok(s) => s,
            Err(_) => bail!("serve: a connection still referenced \
                             replica {i} after front shutdown"),
        };
        let mut rs = server.shutdown();
        if replicas > 1 {
            for r in &mut rs {
                r.replica = format!("r{i}");
            }
        }
        reports.extend(rs);
    }
    for r in &reports {
        println!(
            "serve {}{}: {} ok / {} err in {} batches; {} rejected, {} \
             shed, {} abandoned; mean exec {:.2} ms (ewma {:.2} ms)",
            r.model,
            if r.replica.is_empty() {
                String::new()
            } else {
                format!(" [{}]", r.replica)
            },
            r.requests, r.errors, r.batches, r.rejected,
            r.shed, r.abandoned, r.mean_batch_ms, r.ewma_batch_ms
        );
    }
    if !a.get("metrics-jsonl").is_empty() {
        let path = PathBuf::from(a.get("metrics-jsonl"));
        let mut metrics =
            lutq::coordinator::metrics::Metrics::new(Some(path.as_path()))?;
        for r in &reports {
            metrics.record_custom(r.to_json())?;
        }
        if let Some((totals, reps)) = &cluster_rows {
            metrics.record_custom(totals.to_json())?;
            for r in reps {
                metrics.record_custom(r.to_json())?;
            }
        }
        println!("wrote {}", path.display());
    }
    Ok(())
}

/// `lutq route`: a standalone sharding tier over remote `lutq serve`
/// replicas — the process/host-scale deployment shape. Start the
/// backends first (the router reads its model catalog from them), then
/// point clients at the router exactly as they would at a single serve
/// front: same API, same error codes, plus 503 `no_healthy_replicas`
/// when every backend is down.
fn cmd_route(argv: &[String]) -> Result<()> {
    let cli = Cli::new("lutq route",
                       "sharding router over remote replica fronts")
        .req("replicas",
             "comma-separated replica addresses (host:port) of running \
              `lutq serve` fronts")
        .opt("addr", "127.0.0.1:8080",
             "bind address (port 0 picks an ephemeral port)")
        .opt("wire-addr", "",
             "also serve the binary framed wire protocol here \
              (empty = HTTP only; port 0 picks an ephemeral port)")
        .opt("shard-transport", "http",
             "how shard hops reach the replicas: http (JSON, one \
              request per sample) | binary (one batched wire frame \
              per shard; replicas must expose --wire-addr ports)")
        .opt("max-shard", "8",
             "max samples handed to one replica as a single shard")
        .opt("max-conns", "256", "max concurrent http connections")
        .opt("health-every-ms", "1000",
             "re-probe replica health every N ms (0 = only on demand)")
        .opt("max-seconds", "0",
             "route for N seconds, then exit (0 = forever)")
        .opt("metrics-jsonl", "",
             "write serve_cluster/serve_replica JSONL rows on shutdown");
    let a = match cli.parse_from(argv) {
        Ok(a) => a,
        Err(msg) => bail!("{msg}"),
    };
    let addrs: Vec<&str> = a
        .get("replicas")
        .split(',')
        .filter(|s| !s.is_empty())
        .collect();
    ensure!(!addrs.is_empty(), "route: --replicas lists no addresses");
    let shard_transport = a.get("shard-transport");
    ensure!(shard_transport == "http" || shard_transport == "binary",
            "route: --shard-transport must be http or binary, got {}",
            shard_transport);
    let backends: Vec<Box<dyn Replica>> = addrs
        .iter()
        .map(|ad| {
            if shard_transport == "binary" {
                Box::new(WireReplica::new(ad)) as Box<dyn Replica>
            } else {
                Box::new(HttpReplica::new(ad)) as Box<dyn Replica>
            }
        })
        .collect();
    let router = Arc::new(Router::new(
        backends,
        RouterConfig { max_shard: a.get_usize("max-shard").max(1) },
    )?);
    let mut wire_front: Option<WireServer> = None;
    if !a.get("wire-addr").is_empty() {
        wire_front = Some(WireServer::start(
            Arc::clone(&router),
            WireConfig {
                addr: a.get("wire-addr").to_string(),
                max_conns: a.get_usize("max-conns").max(1),
                ..Default::default()
            },
        )?);
    }
    let front = HttpFront::start(Arc::clone(&router), HttpConfig {
        addr: a.get("addr").to_string(),
        max_conns: a.get_usize("max-conns").max(1),
        ..Default::default()
    })?;
    println!("lutq route: listening on http://{} over {} replica(s) \
              ({} shard hops)",
             front.addr(), addrs.len(), shard_transport);
    if let Some(w) = &wire_front {
        println!("lutq route: wire protocol on {}", w.addr());
    }
    for i in router.catalog() {
        println!("  model {:<20} input {:?}", i.name, i.input);
    }
    // periodic prober: killed replicas leave the rotation without a
    // request paying for the discovery, recovered ones rejoin
    let probe_ms = a.get_u64("health-every-ms");
    let stop = Arc::new(AtomicBool::new(false));
    let prober = if probe_ms > 0 {
        let rt = Arc::clone(&router);
        let stop = Arc::clone(&stop);
        Some(std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                std::thread::sleep(Duration::from_millis(probe_ms));
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                rt.check_health();
            }
        }))
    } else {
        None
    };
    let secs = a.get_u64("max-seconds");
    if secs == 0 {
        println!("routing until the process is killed \
                  (--max-seconds bounds the run)");
        loop {
            std::thread::sleep(Duration::from_secs(3600));
        }
    }
    std::thread::sleep(Duration::from_secs(secs));
    stop.store(true, Ordering::Relaxed);
    front.shutdown();
    if let Some(w) = wire_front {
        w.shutdown();
    }
    if let Some(h) = prober {
        let _ = h.join();
    }
    let totals = router.totals();
    println!(
        "route: {} submitted, {} completed, {} rejected, {} shed, {} \
         failed (reconciles: {})",
        totals.submitted, totals.completed, totals.rejected,
        totals.shed, totals.failed, totals.reconciles()
    );
    for r in router.reports() {
        println!(
            "  replica {}: {} samples in {} shards, {} failed shards, \
             {} rerouted (healthy: {})",
            r.replica, r.samples, r.shards, r.failed_shards,
            r.rerouted, r.healthy
        );
    }
    if !a.get("metrics-jsonl").is_empty() {
        let path = PathBuf::from(a.get("metrics-jsonl"));
        let mut metrics =
            lutq::coordinator::metrics::Metrics::new(Some(path.as_path()))?;
        router.log_to(&mut metrics)?;
        println!("wrote {}", path.display());
    }
    Ok(())
}

fn cmd_serve_bench(argv: &[String]) -> Result<()> {
    let cli = Cli::new("lutq serve-bench",
                       "serving benchmark: direct plan loop vs the \
                        coalescing Server path")
        .req("artifact",
             "artifact preset(s), comma-separated; `synthetic` benches \
              two built-in models with no files")
        .opt("model", "",
             "exported model file(s), comma-separated (matched 1:1 with \
              --artifact)")
        .opt("mode", "lut", "dense | lut | shift")
        .opt("kernel", "auto",
             "kernel backend: auto | scalar | simd | int (auto honours \
              the LUTQ_KERNEL env override) — A/B the backend seam")
        .opt("batch", "8",
             "direct-path batch size, also the server coalescing cap")
        .opt("iters", "200",
             "direct iterations per model; the server path answers \
              iters*batch single-image requests per model")
        .opt("warmup", "20", "warmup iterations (provision the arenas)")
        .opt("threads", "0",
             "direct-path plan threads (0 = one per core)")
        .opt("workers", "0", "server worker threads (0 = one per core)")
        .opt("plan-threads", "1", "intra-plan threads per server worker")
        .opt("linger-ms", "1",
             "server: max ms a partial batch waits to coalesce")
        .opt("clients", "0",
             "closed-loop client threads (0 = max(2x workers, 2x batch) \
              so coalesced batches can fill)")
        .opt("transport", "inproc",
             "serving path to bench: inproc (submit/wait in-process), \
              http (adds full-network-path rows through an HttpFront), \
              binary (http rows plus wire-protocol rows through a \
              WireServer) or cluster (1-vs-N replica scaling rows \
              through the sharding Router)")
        .opt("replicas", "3",
             "cluster transport: replica servers behind the router \
              (the bench runs both 1 and N for the scaling comparison)")
        .opt("shard-transport", "inproc",
             "cluster transport: how the router reaches its replicas: \
              inproc | http (per-replica HttpFront) | binary \
              (per-replica WireServer, one batched frame per shard)")
        .opt("addr", "127.0.0.1:0",
             "http transport: bind address (port 0 = ephemeral)")
        .opt("wire-addr", "127.0.0.1:0",
             "binary transport: wire bind address (port 0 = ephemeral)")
        .opt("deadline-ms", "0",
             "http/binary transport: client deadline per request; 0 = \
              none (429 sheds land in the shed-rate rows)")
        .opt("json", "", "also write the rows to this JSON file")
        .flag("compile-per-call",
              "add the legacy re-lower-per-request comparison row")
        .flag("no-serve", "direct rows only (skip the Server path)");
    let a = match cli.parse_from(argv) {
        Ok(a) => a,
        Err(msg) => bail!("{msg}"),
    };
    let mode = parse_mode(a.get("mode"))?;
    let kernel = parse_kernel(a.get("kernel"))?;
    let transport = a.get("transport");
    ensure!(
        transport == "inproc" || transport == "http"
            || transport == "binary" || transport == "cluster",
        "unknown --transport `{transport}` (inproc | http | binary | \
         cluster)"
    );
    ensure!(transport == "inproc" || !a.has_flag("no-serve"),
            "--transport {transport} needs the server path (drop \
             --no-serve)");
    let batch = a.get_usize("batch").max(1);
    let iters = a.get_usize("iters").max(1);
    let warmup = a.get_usize("warmup");
    let models = load_bench_models(a.get("artifact"), a.get("model"))?;
    let pool_n = batch.max(8);
    let pools: lutq::serve::load::SamplePools = Arc::new(
        models
            .iter()
            .enumerate()
            .map(|(i, bm)| sample_pool(bm, pool_n, 100 + i as u64))
            .collect(),
    );
    let mut rows: Vec<LatencyReport> = Vec::new();

    // --------- direct path: compile once, batched run_into loop
    for (mi, bm) in models.iter().enumerate() {
        let opts = PlanOptions { mode, act_bits: bm.act_bits,
                                 mlbn: bm.mlbn,
                                 threads: a.get_usize("threads"),
                                 kernel };
        let plan = Plan::compile(&bm.graph, &bm.qmodel, opts, &bm.input)?;
        if mi == 0 {
            println!("kernel backend: {}", plan.backend_name());
        }
        let ktag = lutq::report::kernel_tag(plan.backend_name());
        let tables = plan.int_table_report();
        if !tables.is_empty() {
            println!("{} int product tables: {} B total", bm.name,
                     plan.int_table_bytes());
            for (layer, bytes) in &tables {
                println!("  {layer}: {bytes} B");
            }
        }
        let mut scratch = plan.scratch_for(batch);
        let elems: usize = bm.input.iter().product();
        let mut dims = vec![batch];
        dims.extend_from_slice(&bm.input);
        let mut data = Vec::with_capacity(batch * elems);
        for s in 0..batch {
            data.extend_from_slice(&pools[mi][s % pool_n]);
        }
        let x = Tensor::new(dims, data);
        for _ in 0..warmup {
            plan.run_into(&x, &mut scratch)?;
        }
        let mut lat: Vec<f32> = Vec::with_capacity(iters);
        let wall = Timer::start();
        for _ in 0..iters {
            let t = Timer::start();
            plan.run_into(&x, &mut scratch)?;
            lat.push(t.elapsed_ms() as f32);
        }
        rows.push(
            LatencyReport::from_latencies(
                format!("{}/{mode:?}/kernel-{ktag}/direct", bm.name),
                batch, plan.threads(), false, &lat, wall.elapsed_s())
            .with_model(&bm.name)
            .with_backend(plan.backend_name())
            .with_transport("direct")
            .with_table_bytes(plan.int_table_bytes()),
        );

        if a.has_flag("compile-per-call") {
            let mut lat: Vec<f32> = Vec::with_capacity(iters);
            let wall = Timer::start();
            for _ in 0..iters {
                let t = Timer::start();
                let p = Plan::compile(&bm.graph, &bm.qmodel, opts,
                                      &bm.input)?;
                p.run_into(&x, &mut scratch)?;
                lat.push(t.elapsed_ms() as f32);
            }
            rows.push(
                LatencyReport::from_latencies(
                    format!("{}/{mode:?}/kernel-{ktag}/compile-per-call",
                            bm.name),
                    batch, plan.threads(), true, &lat, wall.elapsed_s())
                .with_model(&bm.name)
                .with_backend(plan.backend_name())
                .with_transport("direct")
                .with_table_bytes(plan.int_table_bytes()),
            );
        }
    }

    // --------- server path: registry + worker pool + coalescing queue
    if !a.has_flag("no-serve") && transport != "cluster" {
        let mut registry = Registry::new();
        for bm in &models {
            let opts = PlanOptions {
                mode,
                act_bits: bm.act_bits,
                mlbn: bm.mlbn,
                threads: a.get_usize("plan-threads").max(1),
                kernel,
            };
            let plan =
                Plan::compile(&bm.graph, &bm.qmodel, opts, &bm.input)?;
            registry.register(&bm.name, plan)?;
        }
        let workers = match a.get_usize("workers") {
            0 => std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
            w => w,
        };
        let server = Server::start(registry, ServerConfig {
            workers,
            max_batch: batch,
            linger: Duration::from_millis(a.get_u64("linger-ms")),
            queue_cap: 4096,
        })?;
        let server = Arc::new(server);
        let nmodels = models.len();
        // enough concurrent callers that coalesced batches can actually
        // fill to the cap (closed-loop clients bound the batch size)
        let clients = match a.get_usize("clients") {
            0 => (2 * workers).max(2 * batch),
            c => c,
        };
        // per-model phases: each phase's wall clock covers only this
        // model's requests, so its images/s compares 1:1 with the
        // model's direct row
        for (mi, bm) in models.iter().enumerate() {
            let (lat, secs) = lutq::serve::load::closed_loop(
                &server, &[mi], &pools, iters * batch, clients)?;
            let ms: Vec<f32> = lat.iter().map(|(_, v)| *v).collect();
            let plan = server.registry().plan_by_id(mi);
            let ktag = lutq::report::kernel_tag(plan.backend_name());
            rows.push(
                LatencyReport::from_latencies(
                    format!("{}/{mode:?}/kernel-{ktag}/served", bm.name),
                    1, workers, false, &ms, secs)
                .with_model(&bm.name)
                .with_backend(plan.backend_name())
                .with_transport("inproc")
                .with_table_bytes(plan.int_table_bytes()),
            );
        }
        // mixed phase: all models interleaved through the same pool
        // (the multi-model serving story; rates here are under mixed
        // load, hence the separate `served-mixed` label)
        if nmodels > 1 {
            let ids: Vec<usize> = (0..nmodels).collect();
            let (lat, secs) = lutq::serve::load::closed_loop(
                &server, &ids, &pools, nmodels * iters * batch,
                clients)?;
            let all: Vec<f32> = lat.iter().map(|(_, v)| *v).collect();
            let plan = server.registry().plan_by_id(0);
            let ktag = lutq::report::kernel_tag(plan.backend_name());
            rows.push(
                LatencyReport::from_latencies(
                    format!("all/{mode:?}/kernel-{ktag}/served-mixed"),
                    1, workers, false, &all, secs)
                .with_model("all")
                .with_backend(plan.backend_name())
                .with_transport("inproc"),
            );
        }
        // ------ http transport: the same closed loop through the
        // network front, so the full-path numbers sit next to the
        // in-process rows (plus shed-rate accounting under deadlines).
        // `binary` is a superset: it runs the http rows too, so the
        // wire-vs-json comparison lands in one JSON.
        if transport == "http" || transport == "binary" {
            let front = HttpFront::start(
                Arc::clone(&server),
                HttpConfig {
                    addr: a.get("addr").to_string(),
                    max_conns: (clients + 8).max(64),
                    ..Default::default()
                },
            )?;
            let addr = front.addr().to_string();
            println!("serve-bench: http front on {addr}");
            let names: Vec<String> =
                models.iter().map(|bm| bm.name.clone()).collect();
            let deadline_ms = match a.get_f32("deadline-ms") as f64 {
                v if v > 0.0 => Some(v),
                _ => None,
            };
            let mut shed_total = 0u64;
            let mut all_total = 0u64;
            for (mi, bm) in models.iter().enumerate() {
                let (lat, secs, stats) =
                    lutq::serve::load::closed_loop_http(
                        &addr, &names, &[mi], &pools, iters * batch,
                        clients, deadline_ms)?;
                let ms: Vec<f32> =
                    lat.iter().map(|(_, v)| *v).collect();
                let plan = server.registry().plan_by_id(mi);
                let ktag =
                    lutq::report::kernel_tag(plan.backend_name());
                rows.push(
                    LatencyReport::from_latencies(
                        format!("{}/{mode:?}/kernel-{ktag}/served-http",
                                bm.name),
                        1, workers, false, &ms, secs)
                    .with_model(&bm.name)
                    .with_backend(plan.backend_name())
                    .with_transport("http")
                    .with_table_bytes(plan.int_table_bytes())
                    .with_shed_rate(stats.shed_rate()),
                );
                println!(
                    "http {}: {} ok, {} rejected (429), {} failed",
                    bm.name, stats.ok, stats.rejected, stats.failed
                );
                ensure!(stats.failed == 0,
                        "serve-bench: {} http request(s) failed \
                         against {}", stats.failed, bm.name);
                shed_total += stats.rejected;
                all_total += stats.ok + stats.rejected + stats.failed;
            }
            // aggregate shed-rate row for the bench JSON trajectory
            let plan = server.registry().plan_by_id(0);
            let ktag = lutq::report::kernel_tag(plan.backend_name());
            rows.push(
                LatencyReport::from_latencies(
                    format!("all/{mode:?}/kernel-{ktag}/http-shed-rate"),
                    1, workers, false, &[], 0.0)
                .with_model("all")
                .with_backend(plan.backend_name())
                .with_transport("http")
                .with_shed_rate(
                    shed_total as f64 / all_total.max(1) as f64),
            );
            front.shutdown();
        }
        // ------ binary transport: the same closed loop through the
        // framed wire front. The requests are pre-encoded frames, so
        // these rows isolate the serialization cost the http rows pay
        // per request.
        if transport == "binary" {
            let wire = WireServer::start(
                Arc::clone(&server),
                WireConfig {
                    addr: a.get("wire-addr").to_string(),
                    max_conns: (clients + 8).max(64),
                    ..Default::default()
                },
            )?;
            let addr = wire.addr().to_string();
            println!("serve-bench: wire front on {addr}");
            let names: Vec<String> =
                models.iter().map(|bm| bm.name.clone()).collect();
            let deadline_ms = match a.get_f32("deadline-ms") as f64 {
                v if v > 0.0 => Some(v),
                _ => None,
            };
            let mut shed_total = 0u64;
            let mut all_total = 0u64;
            for (mi, bm) in models.iter().enumerate() {
                let (lat, secs, stats) =
                    lutq::serve::load::closed_loop_wire(
                        &addr, &names, &[mi], &pools, iters * batch,
                        clients, deadline_ms)?;
                let ms: Vec<f32> =
                    lat.iter().map(|(_, v)| *v).collect();
                let plan = server.registry().plan_by_id(mi);
                let ktag =
                    lutq::report::kernel_tag(plan.backend_name());
                rows.push(
                    LatencyReport::from_latencies(
                        format!("{}/{mode:?}/kernel-{ktag}/\
                                 served-binary",
                                bm.name),
                        1, workers, false, &ms, secs)
                    .with_model(&bm.name)
                    .with_backend(plan.backend_name())
                    .with_transport("binary")
                    .with_table_bytes(plan.int_table_bytes())
                    .with_shed_rate(stats.shed_rate()),
                );
                println!(
                    "wire {}: {} ok, {} rejected (429), {} failed",
                    bm.name, stats.ok, stats.rejected, stats.failed
                );
                ensure!(stats.failed == 0,
                        "serve-bench: {} wire request(s) failed \
                         against {}", stats.failed, bm.name);
                shed_total += stats.rejected;
                all_total += stats.ok + stats.rejected + stats.failed;
            }
            let plan = server.registry().plan_by_id(0);
            let ktag = lutq::report::kernel_tag(plan.backend_name());
            rows.push(
                LatencyReport::from_latencies(
                    format!("all/{mode:?}/kernel-{ktag}/\
                             binary-shed-rate"),
                    1, workers, false, &[], 0.0)
                .with_model("all")
                .with_backend(plan.backend_name())
                .with_transport("binary")
                .with_shed_rate(
                    shed_total as f64 / all_total.max(1) as f64),
            );
            wire.shutdown();
        }
        let server = match Arc::try_unwrap(server) {
            Ok(s) => s,
            Err(_) => bail!("serve-bench: server still referenced"),
        };
        let reports = server.shutdown();
        for r in &reports {
            println!(
                "serve {}: {} req in {} batches (mean batch {:.2}, max \
                 {}), mean exec {:.2} ms, mean queue wait {:.2} ms; {} \
                 rejected, {} shed",
                r.model, r.requests, r.batches, r.mean_batch,
                r.max_batch, r.mean_batch_ms, r.mean_wait_ms,
                r.rejected, r.shed
            );
        }
    }

    // --------- cluster path: the same closed loop through the sharding
    // Router over in-process replica servers, run at 1 and N replicas
    // so the bench JSON carries the scaling comparison
    if transport == "cluster" {
        let nrep = a.get_usize("replicas").max(1);
        let shard_transport = a.get("shard-transport");
        ensure!(
            shard_transport == "inproc" || shard_transport == "http"
                || shard_transport == "binary",
            "unknown --shard-transport `{shard_transport}` (inproc | \
             http | binary)"
        );
        // shard-hop transport lands in the row labels so inproc, http
        // and binary cluster runs coexist in one bench JSON
        let (shard_tag, cluster_transport) = match shard_transport {
            "http" => ("-http", "cluster-http"),
            "binary" => ("-binary", "cluster-binary"),
            _ => ("", "cluster"),
        };
        let workers_total = match a.get_usize("workers") {
            0 => std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
            w => w,
        };
        let clients = match a.get_usize("clients") {
            0 => (2 * workers_total).max(2 * batch),
            c => c,
        };
        // compile once; every replica registry shares the Arc<Plan>
        let mut shared: Vec<(String, Arc<Plan>)> = Vec::new();
        for bm in &models {
            let opts = PlanOptions {
                mode,
                act_bits: bm.act_bits,
                mlbn: bm.mlbn,
                threads: a.get_usize("plan-threads").max(1),
                kernel,
            };
            let plan =
                Plan::compile(&bm.graph, &bm.qmodel, opts, &bm.input)?;
            shared.push((bm.name.clone(), Arc::new(plan)));
        }
        let names: Vec<String> =
            models.iter().map(|bm| bm.name.clone()).collect();
        let ktag = lutq::report::kernel_tag(shared[0].1.backend_name());
        let mut rep_counts = vec![1usize];
        if nrep > 1 {
            rep_counts.push(nrep);
        }
        for &reps in &rep_counts {
            let mut servers: Vec<Arc<Server>> =
                Vec::with_capacity(reps);
            for _ in 0..reps {
                let mut registry = Registry::new();
                for (name, plan) in &shared {
                    registry.register_shared(name, Arc::clone(plan))?;
                }
                servers.push(Arc::new(Server::start(
                    registry,
                    ServerConfig {
                        workers: (workers_total / reps).max(1),
                        max_batch: batch,
                        linger: Duration::from_millis(
                            a.get_u64("linger-ms"),
                        ),
                        queue_cap: 4096,
                    },
                )?));
            }
            // remote shard hops get a real per-replica network front
            // on an ephemeral port; inproc skips the sockets entirely
            let mut http_fronts: Vec<HttpFront> = Vec::new();
            let mut wire_fronts: Vec<WireServer> = Vec::new();
            let mut backends: Vec<Box<dyn Replica>> =
                Vec::with_capacity(reps);
            for (i, s) in servers.iter().enumerate() {
                match shard_transport {
                    "http" => {
                        let front = HttpFront::start(
                            Arc::clone(s),
                            HttpConfig {
                                addr: "127.0.0.1:0".to_string(),
                                max_conns: (clients + 8).max(64),
                                ..Default::default()
                            },
                        )?;
                        backends.push(Box::new(HttpReplica::new(
                            &front.addr().to_string(),
                        )));
                        http_fronts.push(front);
                    }
                    "binary" => {
                        let front = WireServer::start(
                            Arc::clone(s),
                            WireConfig {
                                addr: "127.0.0.1:0".to_string(),
                                max_conns: (clients + 8).max(64),
                                ..Default::default()
                            },
                        )?;
                        backends.push(Box::new(WireReplica::new(
                            &front.addr().to_string(),
                        )));
                        wire_fronts.push(front);
                    }
                    _ => backends.push(Box::new(
                        InProcessReplica::new(&format!("r{i}"),
                                              Arc::clone(s)),
                    )),
                }
            }
            let router = Arc::new(Router::new(
                backends,
                RouterConfig { max_shard: batch },
            )?);
            for (mi, bm) in models.iter().enumerate() {
                let (lat, secs, stats) =
                    lutq::serve::load::closed_loop_cluster(
                        &router, &names, &[mi], &pools,
                        iters * batch, clients, None,
                    )?;
                ensure!(stats.failed == 0,
                        "serve-bench: {} cluster request(s) failed \
                         against {}", stats.failed, bm.name);
                let ms: Vec<f32> =
                    lat.iter().map(|(_, v)| *v).collect();
                rows.push(
                    LatencyReport::from_latencies(
                        format!("{}/{mode:?}/kernel-{ktag}/\
                                 cluster-{reps}r{shard_tag}",
                                bm.name),
                        1, workers_total, false, &ms, secs)
                    .with_model(&bm.name)
                    .with_backend(shared[mi].1.backend_name())
                    .with_transport(cluster_transport)
                    .with_table_bytes(shared[mi].1.int_table_bytes())
                    .with_replicas(reps),
                );
            }
            if models.len() > 1 {
                let ids: Vec<usize> = (0..models.len()).collect();
                let (lat, secs, stats) =
                    lutq::serve::load::closed_loop_cluster(
                        &router, &names, &ids, &pools,
                        models.len() * iters * batch, clients, None,
                    )?;
                ensure!(stats.failed == 0,
                        "serve-bench: {} cluster request(s) failed \
                         in the mixed phase", stats.failed);
                let ms: Vec<f32> =
                    lat.iter().map(|(_, v)| *v).collect();
                rows.push(
                    LatencyReport::from_latencies(
                        format!("all/{mode:?}/kernel-{ktag}/\
                                 cluster-{reps}r{shard_tag}-mixed"),
                        1, workers_total, false, &ms, secs)
                    .with_model("all")
                    .with_backend(shared[0].1.backend_name())
                    .with_transport(cluster_transport)
                    .with_replicas(reps),
                );
            }
            let totals = router.totals();
            println!(
                "cluster {reps}r: {}/{} completed ({} rejected, {} \
                 shed, {} failed; reconciles: {})",
                totals.completed, totals.submitted, totals.rejected,
                totals.shed, totals.failed, totals.reconciles()
            );
            for r in router.reports() {
                println!(
                    "  replica {}: {} samples in {} shards \
                     ({:.4} ms/sample ewma)",
                    r.replica, r.samples, r.shards, r.ewma_sample_ms
                );
            }
            // drop the router before its replicas' fronts shut down:
            // that closes its pooled shard-hop connections, so the
            // fronts' handler threads wake and join instead of waiting
            // out the io timeout. The replica servers then drain and
            // join on their own drop.
            drop(router);
            for f in http_fronts {
                f.shutdown();
            }
            for f in wire_fronts {
                f.shutdown();
            }
        }
        if nrep > 1 {
            for bm in &models {
                let one = rows.iter().find(|r| {
                    r.label
                        == format!("{}/{mode:?}/kernel-{ktag}/\
                                    cluster-1r{shard_tag}",
                                   bm.name)
                });
                let many = rows.iter().find(|r| {
                    r.label
                        == format!("{}/{mode:?}/kernel-{ktag}/\
                                    cluster-{nrep}r{shard_tag}",
                                   bm.name)
                });
                if let (Some(o), Some(m)) = (one, many) {
                    println!(
                        "{}: {nrep} replicas {:.1} images/s vs 1 \
                         replica {:.1} images/s ({:.2}x)",
                        bm.name, m.images_per_sec, o.images_per_sec,
                        m.images_per_sec / o.images_per_sec.max(1e-9)
                    );
                }
            }
        }
    }

    println!("| row | batch | p50 ms | p99 ms | p99.9 ms | images/s |");
    println!("|---|---|---|---|---|---|");
    for r in &rows {
        println!("| {} | {} | {:.2} | {:.2} | {:.2} | {:.1} |", r.label,
                 r.batch, r.p50_ms, r.p99_ms, r.p999_ms,
                 r.images_per_sec);
    }
    for bm in &models {
        let direct = rows.iter().find(|r| {
            r.model == bm.name && r.label.ends_with("/direct")
        });
        let served = rows.iter().find(|r| {
            r.model == bm.name && r.label.ends_with("/served")
        });
        if let (Some(d), Some(s)) = (direct, served) {
            println!(
                "{}: coalescing {:.1} images/s vs direct {:.1} images/s \
                 ({:.2}x)",
                bm.name, s.images_per_sec, d.images_per_sec,
                s.images_per_sec / d.images_per_sec.max(1e-9)
            );
        }
    }
    if !a.get("json").is_empty() {
        let path = PathBuf::from(a.get("json"));
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(&path, lutq::report::latency_reports_json(&rows))?;
        println!("wrote {}", path.display());
    }
    Ok(())
}

/// `lutq wire-check`: answer one predict over HTTP and over the binary
/// wire protocol and require the outputs bitwise-identical — the smoke
/// tests' substitute for a curl of the wire port (curl cannot speak the
/// framing). `--batch N` additionally sends one N-sample frame of the
/// same input and requires every row to equal the single-sample answer.
fn cmd_wire_check(argv: &[String]) -> Result<()> {
    let cli = Cli::new("lutq wire-check",
                       "bitwise-compare one predict over HTTP vs the \
                        binary wire protocol")
        .req("http-addr", "host:port of a running HTTP front")
        .req("wire-addr", "host:port of the matching wire front")
        .req("model", "model name to predict")
        .req("input-json",
             "file holding the HTTP predict body {\"input\":[...]}")
        .opt("batch", "1",
             "also send one N-sample batched frame and require each \
              row to equal the single-sample answer");
    let a = match cli.parse_from(argv) {
        Ok(a) => a,
        Err(msg) => bail!("{msg}"),
    };
    let body = std::fs::read_to_string(a.get("input-json"))
        .with_context(|| {
            format!("wire-check: read {}", a.get("input-json"))
        })?;
    let input = lutq::jsonic::parse(&body)
        .map_err(|e| anyhow::anyhow!("wire-check: parse input: {e}"))?
        .get("input")
        .and_then(|j| j.as_f32_vec())
        .ok_or_else(|| {
            anyhow::anyhow!("wire-check: input file needs a numeric \
                             `input` array")
        })?;
    let model = a.get("model");
    // http answer (jsonic's f32 formatting round-trips bit-exactly,
    // so parsing the JSON back loses nothing)
    let mut hc = HttpClient::connect(a.get("http-addr"))?;
    let (status, reply) = hc.predict(model, &body, None)?;
    ensure!(status == 200,
            "wire-check: http predict answered {status}: {reply}");
    let http_out = lutq::jsonic::parse(&reply)
        .map_err(|e| {
            anyhow::anyhow!("wire-check: parse http reply: {e}")
        })?
        .get("output")
        .and_then(|o| o.as_f32_vec())
        .ok_or_else(|| {
            anyhow::anyhow!("wire-check: http reply has no numeric \
                             `output` array")
        })?;
    // wire answer
    let mut wc = WireClient::connect(a.get("wire-addr"))?;
    let wire_out = match wc.predict(model, &input, None)? {
        WireReply::Outputs(mut rows) => {
            ensure!(rows.len() == 1,
                    "wire-check: wire answered {} rows for 1 sample",
                    rows.len());
            rows.remove(0)
        }
        WireReply::Refused(e) => bail!(
            "wire-check: wire predict refused: {} {}: {}",
            e.status, e.code, e.message
        ),
    };
    ensure!(http_out.len() == wire_out.len(),
            "wire-check: output length differs: http {} vs wire {}",
            http_out.len(), wire_out.len());
    for (i, (h, w)) in http_out.iter().zip(&wire_out).enumerate() {
        ensure!(h.to_bits() == w.to_bits(),
                "wire-check: output[{i}] differs: http {h} vs wire {w}");
    }
    let n = a.get_usize("batch").max(1);
    if n > 1 {
        let samples: Vec<&[f32]> =
            (0..n).map(|_| input.as_slice()).collect();
        match wc.predict_batch(model, &samples, None)? {
            WireReply::Outputs(rows) => {
                ensure!(rows.len() == n,
                        "wire-check: batched frame answered {} rows \
                         for {n} samples", rows.len());
                for (s, row) in rows.iter().enumerate() {
                    ensure!(
                        row.len() == wire_out.len()
                            && row
                                .iter()
                                .zip(&wire_out)
                                .all(|(a, b)| a.to_bits() == b.to_bits()),
                        "wire-check: batched row {s} differs from the \
                         single-sample answer"
                    );
                }
            }
            WireReply::Refused(e) => bail!(
                "wire-check: batched predict refused: {} {}: {}",
                e.status, e.code, e.message
            ),
        }
    }
    println!(
        "wire-check OK: {} element(s) bitwise-identical over http and \
         wire{}",
        http_out.len(),
        if n > 1 {
            format!(" (and across a {n}-sample batched frame)")
        } else {
            String::new()
        }
    );
    Ok(())
}

/// One gated row of a bench JSON: label + the throughput metric.
struct BenchRow {
    label: String,
    images_per_sec: f64,
}

fn load_bench_rows(path: &str) -> Result<Vec<BenchRow>> {
    let txt = std::fs::read_to_string(path)
        .with_context(|| format!("bench-check: read {path}"))?;
    let json = lutq::jsonic::parse(&txt)
        .map_err(|e| anyhow::anyhow!("bench-check: parse {path}: {e}"))?;
    let rows = json.as_arr().ok_or_else(|| {
        anyhow::anyhow!("bench-check: {path}: expected a JSON array of \
                         latency rows")
    })?;
    let mut out = Vec::with_capacity(rows.len());
    for (i, r) in rows.iter().enumerate() {
        let label = r.at("label").as_str().ok_or_else(|| {
            anyhow::anyhow!("bench-check: {path}: row {i} missing `label`")
        })?;
        let ips = r.at("images_per_sec").as_f64().ok_or_else(|| {
            anyhow::anyhow!("bench-check: {path}: row `{label}` missing \
                             `images_per_sec`")
        })?;
        out.push(BenchRow { label: label.to_string(),
                            images_per_sec: ips });
    }
    Ok(out)
}

/// CI perf gate: compare a freshly generated bench JSON against the
/// committed baseline and fail if any baseline row's images/s regressed
/// more than `--max-regress` (or went missing). Rows that exist only in
/// the current run are reported but never fail the gate, so new bench
/// rows can land before the baseline is refreshed. When the row sets
/// differ at all, the failure prints a symmetric row-name diff
/// (`- label (baseline only)` / `+ label (current only)`) so a renamed
/// label reads as one rename, not N opaque per-row failures.
fn cmd_bench_check(argv: &[String]) -> Result<()> {
    let cli = Cli::new("lutq bench-check",
                       "gate a bench JSON against a committed baseline")
        .opt("current", "reports/BENCH_infer_plan.json",
             "freshly generated bench rows")
        .opt("baseline", "reports/BENCH_baseline.json",
             "committed reference rows")
        .opt("max-regress", "0.15",
             "max tolerated fractional images/s regression per row");
    let a = match cli.parse_from(argv) {
        Ok(a) => a,
        Err(msg) => bail!("{msg}"),
    };
    let tol = a.get_f32("max-regress") as f64;
    ensure!((0.0..1.0).contains(&tol),
            "bench-check: --max-regress must be in [0, 1), got {tol}");
    let current = load_bench_rows(a.get("current"))?;
    let baseline = load_bench_rows(a.get("baseline"))?;
    ensure!(!baseline.is_empty(),
            "bench-check: baseline {} holds no rows", a.get("baseline"));

    println!("| row | baseline img/s | current img/s | delta |");
    println!("|---|---|---|---|");
    let mut failures: Vec<String> = Vec::new();
    for b in &baseline {
        match current.iter().find(|c| c.label == b.label) {
            None => {
                println!("| {} | {:.1} | MISSING | - |", b.label,
                         b.images_per_sec);
            }
            Some(c) => {
                let delta = if b.images_per_sec > 0.0 {
                    c.images_per_sec / b.images_per_sec - 1.0
                } else {
                    0.0
                };
                println!("| {} | {:.1} | {:.1} | {:+.1}% |", b.label,
                         b.images_per_sec, c.images_per_sec,
                         delta * 100.0);
                if delta < -tol {
                    failures.push(format!(
                        "row `{}`: images/s regressed {:.1}% (baseline \
                         {:.1} -> current {:.1}, tolerance {:.0}%)",
                        b.label, -delta * 100.0, b.images_per_sec,
                        c.images_per_sec, tol * 100.0
                    ));
                }
            }
        }
    }
    for c in &current {
        if !baseline.iter().any(|b| b.label == c.label) {
            println!("| {} (new, ungated) | - | {:.1} | - |", c.label,
                     c.images_per_sec);
        }
    }
    // symmetric row-name diff: missing baseline rows fail the gate,
    // current-only rows are informational, but both sides print so a
    // renamed label shows up as one `-`/`+` pair instead of N opaque
    // per-row failures
    let missing: Vec<&str> = baseline
        .iter()
        .filter(|b| !current.iter().any(|c| c.label == b.label))
        .map(|b| b.label.as_str())
        .collect();
    let extra: Vec<&str> = current
        .iter()
        .filter(|c| !baseline.iter().any(|b| b.label == c.label))
        .map(|c| c.label.as_str())
        .collect();
    if !missing.is_empty() || !extra.is_empty() {
        println!("\nrow-name diff (baseline vs current):");
        for m in &missing {
            println!("  - {m} (baseline only)");
        }
        for e in &extra {
            println!("  + {e} (current only)");
        }
    }
    if !missing.is_empty() {
        failures.push(format!(
            "{} baseline row(s) missing from the current run: {}{}",
            missing.len(),
            missing.join(", "),
            if extra.is_empty() {
                String::new()
            } else {
                format!(" (current run has {} unmatched new row(s): \
                         {} — renamed labels need a baseline refresh)",
                        extra.len(), extra.join(", "))
            }
        ));
    }
    if !failures.is_empty() {
        bail!("bench-check failed:\n  {}", failures.join("\n  "));
    }
    println!(
        "bench-check OK: {} row(s) within {:.0}% of baseline images/s",
        baseline.len(),
        tol * 100.0
    );
    Ok(())
}

fn cmd_report(argv: &[String]) -> Result<()> {
    let cli = Cli::new("lutq report", "footprint/ops accounting")
        .req("artifact", "artifact preset name");
    let a = match cli.parse_from(argv) {
        Ok(a) => a,
        Err(msg) => bail!("{msg}"),
    };
    let man = load_manifest(a.get("artifact"))?;
    let layers = manifest_layer_shapes(&man);
    let k = man.dict_size();
    let stats = CompressionStats::compute(&layers, k);
    println!("artifact {}: {} params over {} quantized layers, K={k}",
             man.name, man.param_count(), layers.len());
    println!("  dense:  {} / {} multiplications",
             human_bytes(stats.dense_bytes()), stats.dense_mults);
    println!("  lut-q:  {} / {} multiplications ({:.1}x memory, {:.1}x mults)",
             human_bytes(stats.lutq_bytes()), stats.lutq_mults,
             stats.compression_ratio(), stats.mult_reduction());
    Ok(())
}

/// Derive per-layer shapes from the manifest graph for the paper
/// formulas. Ops with missing fields are skipped rather than panicking —
/// full validation is the plan compiler's job.
pub fn manifest_layer_shapes(man: &lutq::runtime::Manifest)
                             -> Vec<LayerShape> {
    let mut out = Vec::new();
    let mut hw = man.meta.input.first().copied().unwrap_or(1);
    for op in man.graph.as_arr().unwrap_or(&[]) {
        let kind = op.at("op").as_str().unwrap_or("");
        match kind {
            "conv" => {
                let (Some(name), Some(k), Some(cin), Some(cout)) =
                    (op.at("name").as_str(), op.at("k").as_usize(),
                     op.at("cin").as_usize(), op.at("cout").as_usize())
                else {
                    continue;
                };
                let stride = op.get("stride").and_then(|s| s.as_usize())
                    .unwrap_or(1);
                hw = hw.div_ceil(stride.max(1));
                if !man.qlayers.iter().any(|q| q == name) {
                    continue;
                }
                out.push(LayerShape {
                    name: name.to_string(),
                    n: (k * k * cin * cout) as u64,
                    fan_in: (k * k * cin) as u64,
                    outputs: (hw * hw * cout) as u64,
                });
            }
            "maxpool" => {
                let stride = op.at("stride").as_usize().unwrap_or(2);
                hw /= stride.max(1);
            }
            "affine" => {
                let (Some(name), Some(cin), Some(cout)) =
                    (op.at("name").as_str(), op.at("cin").as_usize(),
                     op.at("cout").as_usize())
                else {
                    continue;
                };
                if !man.qlayers.iter().any(|q| q == name) {
                    continue;
                }
                out.push(LayerShape {
                    name: name.to_string(),
                    n: (cin * cout) as u64,
                    fan_in: cin as u64,
                    outputs: cout as u64,
                });
            }
            _ => {}
        }
    }
    out
}

fn cmd_list() -> Result<()> {
    let root = lutq::artifacts_dir();
    let mut found = false;
    if root.exists() {
        let mut names: Vec<String> = std::fs::read_dir(&root)?
            .filter_map(|e| e.ok())
            .filter(|e| e.path().join("manifest.json").exists())
            .map(|e| e.file_name().to_string_lossy().to_string())
            .collect();
        names.sort();
        for n in names {
            if let Ok(m) = Manifest::load(&root.join(&n)) {
                println!(
                    "{n:<24} {:>9} params  method={:<8} bits={:<2} act={} \
                     mlbn={}",
                    m.param_count(),
                    m.quant_method(),
                    m.quant_bits(),
                    m.act_bits(),
                    m.mlbn()
                );
                found = true;
            }
        }
    }
    if !found {
        println!(
            "no artifacts under {} — run `make artifacts` first",
            root.display()
        );
    }
    Ok(())
}
