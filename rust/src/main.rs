//! `lutq` CLI — the launcher for training, evaluation, export, inference
//! and report generation over AOT artifacts.
//!
//! Subcommands:
//!   train       train an artifact (LUT-Q / baseline) on its synthetic task
//!   eval        evaluate a checkpoint
//!   export      convert a checkpoint to a packed quantized model
//!   infer       compile + run the plan engine on an exported model
//!   serve-bench latency percentiles over a compiled plan (serving proxy)
//!   report      footprint/ops accounting table for an artifact
//!   list        list available artifacts
//!
//! `infer`, `serve-bench`, `report` and `list` read manifests directly and
//! run the pure-Rust plan engine — no PJRT required. `train`, `eval` and
//! `export` drive AOT programs through the runtime.

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use lutq::cli::Cli;
use lutq::data::Dataset;
use lutq::config::TrainConfig;
use lutq::coordinator::{LrSchedule, Trainer};
use lutq::infer::{ExecMode, Plan, PlanOptions, Tensor};
use lutq::params::export::QuantizedModel;
use lutq::quant::stats::{CompressionStats, LayerShape};
use lutq::runtime::Manifest;
use lutq::util::human_bytes;
use lutq::{info, Runtime};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        eprintln!("{}", usage());
        std::process::exit(2);
    }
    let sub = args[0].clone();
    let rest = args[1..].to_vec();
    let result = match sub.as_str() {
        "train" => cmd_train(&rest),
        "eval" => cmd_eval(&rest),
        "export" => cmd_export(&rest),
        "infer" => cmd_infer(&rest),
        "serve-bench" => cmd_serve_bench(&rest),
        "report" => cmd_report(&rest),
        "list" => cmd_list(),
        "--help" | "-h" | "help" => {
            println!("{}", usage());
            Ok(())
        }
        other => {
            eprintln!("unknown subcommand `{other}`\n{}", usage());
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() -> String {
    "lutq — LUT-Q training & inference coordinator\n\n\
     Subcommands:\n\
     \x20 train   --artifact <name> [--steps N] [--lr F] [--seed N]\n\
     \x20         [--prune F] [--inq] [--eval-every N] [--ckpt-dir D]\n\
     \x20 eval    --artifact <name> --ckpt <file>\n\
     \x20 export  --artifact <name> --ckpt <file> --out <model.bin>\n\
     \x20 infer   --artifact <name> --model <model.bin> [--mode dense|lut|shift]\n\
     \x20 serve-bench --artifact <name> --model <model.bin> [--batch N]\n\
     \x20         [--iters N] [--threads N] [--mode dense|lut|shift]\n\
     \x20         [--json <file>] [--compile-per-call]\n\
     \x20 report  --artifact <name>\n\
     \x20 list\n"
        .to_string()
}

fn cmd_train(argv: &[String]) -> Result<()> {
    let cli = Cli::new("lutq train", "train an artifact")
        .req("artifact", "artifact preset name (see `lutq list`)")
        .opt("steps", "300", "training steps")
        .opt("lr", "0.05", "peak learning rate (cosine schedule)")
        .opt("seed", "0", "rng seed")
        .opt("prune", "0", "target pruning fraction (pruning artifacts)")
        .opt("eval-every", "0", "evaluate every N steps")
        .opt("ckpt-dir", "", "checkpoint directory")
        .opt("ckpt-every", "0", "checkpoint every N steps")
        .opt("workers", "2", "prefetch worker threads")
        .opt("train-len", "4096", "synthetic train set size")
        .opt("eval-len", "1024", "synthetic eval set size")
        .flag("inq", "drive the INQ freeze schedule")
        .flag("quiet", "suppress progress logs");
    let a = match cli.parse_from(argv) {
        Ok(a) => a,
        Err(msg) => bail!("{msg}"),
    };
    if a.has_flag("quiet") {
        lutq::util::set_log_level(1);
    }
    let steps = a.get_usize("steps");
    let mut cfg = TrainConfig::new(a.get("artifact"))
        .steps(steps)
        .seed(a.get_u64("seed"))
        .lr(LrSchedule::cosine(a.get_f32("lr"), steps, steps / 10 + 1))
        .eval_every(a.get_usize("eval-every"))
        .data_lens(a.get_usize("train-len"), a.get_usize("eval-len"));
    cfg.workers = a.get_usize("workers");
    cfg.checkpoint_every = a.get_usize("ckpt-every");
    if !a.get("ckpt-dir").is_empty() {
        cfg.checkpoint_dir = Some(PathBuf::from(a.get("ckpt-dir")));
        if cfg.checkpoint_every == 0 {
            cfg.checkpoint_every = steps.max(2) / 2;
        }
    }
    let prune = a.get_f32("prune");
    if prune > 0.0 {
        cfg = cfg.prune(prune);
    }
    if a.has_flag("inq") {
        cfg = cfg.inq_standard();
    }

    let rt = Runtime::new(&lutq::artifacts_dir())?;
    let trainer = Trainer::new(&rt, cfg)?;
    let res = trainer.run()?;
    println!(
        "final: loss {:.4}, eval error {:.2}%, {:.2} steps/s",
        res.final_loss,
        res.eval_error * 100.0,
        res.steps_per_sec
    );
    Ok(())
}

fn cmd_eval(argv: &[String]) -> Result<()> {
    let cli = Cli::new("lutq eval", "evaluate a checkpoint")
        .req("artifact", "artifact preset name")
        .req("ckpt", "checkpoint file");
    let a = match cli.parse_from(argv) {
        Ok(a) => a,
        Err(msg) => bail!("{msg}"),
    };
    let rt = Runtime::new(&lutq::artifacts_dir())?;
    let trainer = Trainer::new(&rt, TrainConfig::new(a.get("artifact")))?;
    let (state, step) =
        trainer.state_from_checkpoint(&PathBuf::from(a.get("ckpt")))?;
    let (loss, err) = trainer.evaluate(&state)?;
    println!("checkpoint @ step {step}: eval loss {loss:.4}, error {:.2}%",
             err * 100.0);
    Ok(())
}

fn cmd_export(argv: &[String]) -> Result<()> {
    let cli = Cli::new("lutq export", "export a packed quantized model")
        .req("artifact", "artifact preset name")
        .req("ckpt", "checkpoint file")
        .req("out", "output model path");
    let a = match cli.parse_from(argv) {
        Ok(a) => a,
        Err(msg) => bail!("{msg}"),
    };
    let rt = Runtime::new(&lutq::artifacts_dir())?;
    let man = rt.manifest(a.get("artifact"))?;
    let (store, step) = lutq::params::checkpoint::load(
        &PathBuf::from(a.get("ckpt")))?;
    let model = QuantizedModel::from_state(&store, &man.qlayers);
    let out = PathBuf::from(a.get("out"));
    model.save(&out)?;
    println!(
        "exported step-{step} model: {} ({}; dense {} -> {:.2}x, \
         multiplier-less: {})",
        out.display(),
        human_bytes(model.stored_bytes()),
        human_bytes(model.dense_bytes()),
        model.compression_ratio(),
        model.is_multiplierless()
    );
    Ok(())
}

/// Load an artifact manifest without constructing a PJRT runtime: the
/// plan engine is pure Rust, so inference-side subcommands stay usable
/// even when the XLA backend is absent.
fn load_manifest(artifact: &str) -> Result<Manifest> {
    Manifest::load(&lutq::artifacts_dir().join(artifact)).with_context(|| {
        format!("load artifact `{artifact}` from {} (run `make \
                 artifacts`?)", lutq::artifacts_dir().display())
    })
}

fn parse_mode(s: &str) -> Result<ExecMode> {
    Ok(match s {
        "dense" => ExecMode::Dense,
        "lut" => ExecMode::LutTrick,
        "shift" => ExecMode::ShiftOnly,
        m => bail!("unknown mode {m}"),
    })
}

/// Deterministic synthetic batch matching the artifact's input geometry.
fn synth_batch(man: &Manifest, b: usize) -> Tensor {
    let mut dims = vec![b];
    dims.extend_from_slice(&man.meta.input);
    let ds = lutq::data::SyntheticImages::new(
        man.meta.input[0].max(2), *man.meta.input.get(2).unwrap_or(&3),
        man.meta.num_classes, b, 7, 0.35);
    let mut x = Tensor::zeros(dims);
    if man.meta.arch != "mlp" {
        for i in 0..b {
            let e = ds.input_elems();
            ds.render(i, &mut x.data[i * e..(i + 1) * e]);
        }
    }
    x
}

fn cmd_infer(argv: &[String]) -> Result<()> {
    let cli = Cli::new("lutq infer", "compile + run the plan engine")
        .req("artifact", "artifact preset (for the graph + options)")
        .req("model", "exported model file")
        .opt("mode", "lut", "dense | lut | shift")
        .opt("batch", "4", "batch size");
    let a = match cli.parse_from(argv) {
        Ok(a) => a,
        Err(msg) => bail!("{msg}"),
    };
    let man = load_manifest(a.get("artifact"))?;
    let model = QuantizedModel::load(&PathBuf::from(a.get("model")))?;
    let mode = parse_mode(a.get("mode"))?;
    let opts = PlanOptions { mode, act_bits: man.act_bits(),
                             mlbn: man.mlbn(), threads: 0 };
    let tc = lutq::util::Timer::start();
    let plan = Plan::compile(&man.graph, &model, opts, &man.meta.input)?;
    let compile_ms = tc.elapsed_ms();
    let mut scratch = plan.scratch();

    let x = synth_batch(&man, a.get_usize("batch"));
    let t = lutq::util::Timer::start();
    let counts = plan.run_into(&x, &mut scratch)?;
    let run_ms = t.elapsed_ms();
    let (dims, _) = scratch.output();
    info!("output dims {dims:?}");
    println!(
        "mode={mode:?}: {counts} (compile {compile_ms:.1} ms, run \
         {run_ms:.1} ms, multiplier-less: {})",
        counts.is_multiplierless()
    );
    Ok(())
}

fn cmd_serve_bench(argv: &[String]) -> Result<()> {
    let cli = Cli::new("lutq serve-bench",
                       "latency percentiles over a compiled plan")
        .req("artifact", "artifact preset (graph + quant options)")
        .req("model", "exported model file")
        .opt("mode", "lut", "dense | lut | shift")
        .opt("batch", "8", "batch size per request")
        .opt("iters", "200", "measured requests")
        .opt("warmup", "20", "warmup requests (provisions the arena)")
        .opt("threads", "0", "worker threads (0 = one per core)")
        .opt("json", "", "also write the results to this JSON file")
        .flag("compile-per-call",
              "re-lower the graph on every request (legacy interpreter \
               behaviour, for before/after comparison)");
    let a = match cli.parse_from(argv) {
        Ok(a) => a,
        Err(msg) => bail!("{msg}"),
    };
    let man = load_manifest(a.get("artifact"))?;
    let model = QuantizedModel::load(&PathBuf::from(a.get("model")))?;
    let mode = parse_mode(a.get("mode"))?;
    let batch = a.get_usize("batch").max(1);
    let iters = a.get_usize("iters").max(1);
    let warmup = a.get_usize("warmup");
    let per_call = a.has_flag("compile-per-call");
    let opts = PlanOptions { mode, act_bits: man.act_bits(),
                             mlbn: man.mlbn(),
                             threads: a.get_usize("threads") };
    let plan = Plan::compile(&man.graph, &model, opts, &man.meta.input)?;
    let mut scratch = plan.scratch();
    let x = synth_batch(&man, batch);

    for _ in 0..warmup {
        plan.run_into(&x, &mut scratch)?;
    }
    let mut lat_ms: Vec<f32> = Vec::with_capacity(iters);
    let wall = lutq::util::Timer::start();
    for _ in 0..iters {
        let t = lutq::util::Timer::start();
        if per_call {
            let p = Plan::compile(&man.graph, &model, opts,
                                  &man.meta.input)?;
            p.run_into(&x, &mut scratch)?;
        } else {
            plan.run_into(&x, &mut scratch)?;
        }
        lat_ms.push(t.elapsed_ms() as f32);
    }
    let total_s = wall.elapsed_s();
    let row = lutq::report::LatencyReport::from_latencies(
        format!("{}/{mode:?}", a.get("artifact")), batch, plan.threads(),
        per_call, &lat_ms, total_s);
    println!(
        "{} x{iters} batch={batch}: p50 {:.2} ms, p90 {:.2} ms, p99 \
         {:.2} ms, {:.1} images/s{}",
        a.get("artifact"),
        row.p50_ms,
        row.p90_ms,
        row.p99_ms,
        row.images_per_sec,
        if per_call { " (compile-per-call)" } else { "" }
    );
    if !a.get("json").is_empty() {
        let path = PathBuf::from(a.get("json"));
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(&path,
                       lutq::report::latency_reports_json(&[row]))?;
        println!("wrote {}", path.display());
    }
    Ok(())
}

fn cmd_report(argv: &[String]) -> Result<()> {
    let cli = Cli::new("lutq report", "footprint/ops accounting")
        .req("artifact", "artifact preset name");
    let a = match cli.parse_from(argv) {
        Ok(a) => a,
        Err(msg) => bail!("{msg}"),
    };
    let man = load_manifest(a.get("artifact"))?;
    let layers = manifest_layer_shapes(&man);
    let k = man.dict_size();
    let stats = CompressionStats::compute(&layers, k);
    println!("artifact {}: {} params over {} quantized layers, K={k}",
             man.name, man.param_count(), layers.len());
    println!("  dense:  {} / {} multiplications",
             human_bytes(stats.dense_bytes()), stats.dense_mults);
    println!("  lut-q:  {} / {} multiplications ({:.1}x memory, {:.1}x mults)",
             human_bytes(stats.lutq_bytes()), stats.lutq_mults,
             stats.compression_ratio(), stats.mult_reduction());
    Ok(())
}

/// Derive per-layer shapes from the manifest graph for the paper
/// formulas. Ops with missing fields are skipped rather than panicking —
/// full validation is the plan compiler's job.
pub fn manifest_layer_shapes(man: &lutq::runtime::Manifest)
                             -> Vec<LayerShape> {
    let mut out = Vec::new();
    let mut hw = man.meta.input.first().copied().unwrap_or(1);
    for op in man.graph.as_arr().unwrap_or(&[]) {
        let kind = op.at("op").as_str().unwrap_or("");
        match kind {
            "conv" => {
                let (Some(name), Some(k), Some(cin), Some(cout)) =
                    (op.at("name").as_str(), op.at("k").as_usize(),
                     op.at("cin").as_usize(), op.at("cout").as_usize())
                else {
                    continue;
                };
                let stride = op.get("stride").and_then(|s| s.as_usize())
                    .unwrap_or(1);
                hw = hw.div_ceil(stride.max(1));
                if !man.qlayers.iter().any(|q| q == name) {
                    continue;
                }
                out.push(LayerShape {
                    name: name.to_string(),
                    n: (k * k * cin * cout) as u64,
                    fan_in: (k * k * cin) as u64,
                    outputs: (hw * hw * cout) as u64,
                });
            }
            "maxpool" => {
                let stride = op.at("stride").as_usize().unwrap_or(2);
                hw /= stride.max(1);
            }
            "affine" => {
                let (Some(name), Some(cin), Some(cout)) =
                    (op.at("name").as_str(), op.at("cin").as_usize(),
                     op.at("cout").as_usize())
                else {
                    continue;
                };
                if !man.qlayers.iter().any(|q| q == name) {
                    continue;
                }
                out.push(LayerShape {
                    name: name.to_string(),
                    n: (cin * cout) as u64,
                    fan_in: cin as u64,
                    outputs: cout as u64,
                });
            }
            _ => {}
        }
    }
    out
}

fn cmd_list() -> Result<()> {
    let root = lutq::artifacts_dir();
    let mut found = false;
    if root.exists() {
        let mut names: Vec<String> = std::fs::read_dir(&root)?
            .filter_map(|e| e.ok())
            .filter(|e| e.path().join("manifest.json").exists())
            .map(|e| e.file_name().to_string_lossy().to_string())
            .collect();
        names.sort();
        for n in names {
            if let Ok(m) = Manifest::load(&root.join(&n)) {
                println!(
                    "{n:<24} {:>9} params  method={:<8} bits={:<2} act={} \
                     mlbn={}",
                    m.param_count(),
                    m.quant_method(),
                    m.quant_bits(),
                    m.act_bits(),
                    m.mlbn()
                );
                found = true;
            }
        }
    }
    if !found {
        println!(
            "no artifacts under {} — run `make artifacts` first",
            root.display()
        );
    }
    Ok(())
}
