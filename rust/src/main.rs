//! `lutq` CLI — the launcher for training, evaluation, export, inference
//! and report generation over AOT artifacts.
//!
//! Subcommands:
//!   train    train an artifact (LUT-Q / baseline) on its synthetic task
//!   eval     evaluate a checkpoint
//!   export   convert a checkpoint to a packed quantized model
//!   infer    run the pure-Rust engine on an exported model + op counts
//!   report   footprint/ops accounting table for an artifact
//!   list     list available artifacts

use std::path::PathBuf;

use anyhow::{bail, Result};

use lutq::cli::Cli;
use lutq::data::Dataset;
use lutq::config::TrainConfig;
use lutq::coordinator::{LrSchedule, Trainer};
use lutq::infer::{Engine, EngineOptions, ExecMode, Tensor};
use lutq::params::export::QuantizedModel;
use lutq::quant::stats::{CompressionStats, LayerShape};
use lutq::util::human_bytes;
use lutq::{info, Runtime};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        eprintln!("{}", usage());
        std::process::exit(2);
    }
    let sub = args[0].clone();
    let rest = args[1..].to_vec();
    let result = match sub.as_str() {
        "train" => cmd_train(&rest),
        "eval" => cmd_eval(&rest),
        "export" => cmd_export(&rest),
        "infer" => cmd_infer(&rest),
        "report" => cmd_report(&rest),
        "list" => cmd_list(),
        "--help" | "-h" | "help" => {
            println!("{}", usage());
            Ok(())
        }
        other => {
            eprintln!("unknown subcommand `{other}`\n{}", usage());
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() -> String {
    "lutq — LUT-Q training & inference coordinator\n\n\
     Subcommands:\n\
     \x20 train   --artifact <name> [--steps N] [--lr F] [--seed N]\n\
     \x20         [--prune F] [--inq] [--eval-every N] [--ckpt-dir D]\n\
     \x20 eval    --artifact <name> --ckpt <file>\n\
     \x20 export  --artifact <name> --ckpt <file> --out <model.bin>\n\
     \x20 infer   --artifact <name> --model <model.bin> [--mode dense|lut|shift]\n\
     \x20 report  --artifact <name>\n\
     \x20 list\n"
        .to_string()
}

fn cmd_train(argv: &[String]) -> Result<()> {
    let cli = Cli::new("lutq train", "train an artifact")
        .req("artifact", "artifact preset name (see `lutq list`)")
        .opt("steps", "300", "training steps")
        .opt("lr", "0.05", "peak learning rate (cosine schedule)")
        .opt("seed", "0", "rng seed")
        .opt("prune", "0", "target pruning fraction (pruning artifacts)")
        .opt("eval-every", "0", "evaluate every N steps")
        .opt("ckpt-dir", "", "checkpoint directory")
        .opt("ckpt-every", "0", "checkpoint every N steps")
        .opt("workers", "2", "prefetch worker threads")
        .opt("train-len", "4096", "synthetic train set size")
        .opt("eval-len", "1024", "synthetic eval set size")
        .flag("inq", "drive the INQ freeze schedule")
        .flag("quiet", "suppress progress logs");
    let a = match cli.parse_from(argv) {
        Ok(a) => a,
        Err(msg) => bail!("{msg}"),
    };
    if a.has_flag("quiet") {
        lutq::util::set_log_level(1);
    }
    let steps = a.get_usize("steps");
    let mut cfg = TrainConfig::new(a.get("artifact"))
        .steps(steps)
        .seed(a.get_u64("seed"))
        .lr(LrSchedule::cosine(a.get_f32("lr"), steps, steps / 10 + 1))
        .eval_every(a.get_usize("eval-every"))
        .data_lens(a.get_usize("train-len"), a.get_usize("eval-len"));
    cfg.workers = a.get_usize("workers");
    cfg.checkpoint_every = a.get_usize("ckpt-every");
    if !a.get("ckpt-dir").is_empty() {
        cfg.checkpoint_dir = Some(PathBuf::from(a.get("ckpt-dir")));
        if cfg.checkpoint_every == 0 {
            cfg.checkpoint_every = steps.max(2) / 2;
        }
    }
    let prune = a.get_f32("prune");
    if prune > 0.0 {
        cfg = cfg.prune(prune);
    }
    if a.has_flag("inq") {
        cfg = cfg.inq_standard();
    }

    let rt = Runtime::new(&lutq::artifacts_dir())?;
    let trainer = Trainer::new(&rt, cfg)?;
    let res = trainer.run()?;
    println!(
        "final: loss {:.4}, eval error {:.2}%, {:.2} steps/s",
        res.final_loss,
        res.eval_error * 100.0,
        res.steps_per_sec
    );
    Ok(())
}

fn cmd_eval(argv: &[String]) -> Result<()> {
    let cli = Cli::new("lutq eval", "evaluate a checkpoint")
        .req("artifact", "artifact preset name")
        .req("ckpt", "checkpoint file");
    let a = match cli.parse_from(argv) {
        Ok(a) => a,
        Err(msg) => bail!("{msg}"),
    };
    let rt = Runtime::new(&lutq::artifacts_dir())?;
    let trainer = Trainer::new(&rt, TrainConfig::new(a.get("artifact")))?;
    let (state, step) =
        trainer.state_from_checkpoint(&PathBuf::from(a.get("ckpt")))?;
    let (loss, err) = trainer.evaluate(&state)?;
    println!("checkpoint @ step {step}: eval loss {loss:.4}, error {:.2}%",
             err * 100.0);
    Ok(())
}

fn cmd_export(argv: &[String]) -> Result<()> {
    let cli = Cli::new("lutq export", "export a packed quantized model")
        .req("artifact", "artifact preset name")
        .req("ckpt", "checkpoint file")
        .req("out", "output model path");
    let a = match cli.parse_from(argv) {
        Ok(a) => a,
        Err(msg) => bail!("{msg}"),
    };
    let rt = Runtime::new(&lutq::artifacts_dir())?;
    let man = rt.manifest(a.get("artifact"))?;
    let (store, step) = lutq::params::checkpoint::load(
        &PathBuf::from(a.get("ckpt")))?;
    let model = QuantizedModel::from_state(&store, &man.qlayers);
    let out = PathBuf::from(a.get("out"));
    model.save(&out)?;
    println!(
        "exported step-{step} model: {} ({}; dense {} -> {:.2}x, \
         multiplier-less: {})",
        out.display(),
        human_bytes(model.stored_bytes()),
        human_bytes(model.dense_bytes()),
        model.compression_ratio(),
        model.is_multiplierless()
    );
    Ok(())
}

fn cmd_infer(argv: &[String]) -> Result<()> {
    let cli = Cli::new("lutq infer", "run the pure-Rust engine")
        .req("artifact", "artifact preset (for the graph + options)")
        .req("model", "exported model file")
        .opt("mode", "lut", "dense | lut | shift")
        .opt("batch", "4", "batch size");
    let a = match cli.parse_from(argv) {
        Ok(a) => a,
        Err(msg) => bail!("{msg}"),
    };
    let rt = Runtime::new(&lutq::artifacts_dir())?;
    let man = rt.manifest(a.get("artifact"))?;
    let model = QuantizedModel::load(&PathBuf::from(a.get("model")))?;
    let mode = match a.get("mode") {
        "dense" => ExecMode::Dense,
        "lut" => ExecMode::LutTrick,
        "shift" => ExecMode::ShiftOnly,
        m => bail!("unknown mode {m}"),
    };
    let opts = EngineOptions { mode, act_bits: man.act_bits(),
                               mlbn: man.mlbn() };
    let engine = Engine::new(&man.graph, &model, opts);

    let b = a.get_usize("batch");
    let mut dims = vec![b];
    dims.extend_from_slice(&man.meta.input);
    let ds = lutq::data::SyntheticImages::new(
        man.meta.input[0].max(2), *man.meta.input.get(2).unwrap_or(&3),
        man.meta.num_classes, b, 7, 0.35);
    let mut x = Tensor::zeros(dims.clone());
    if man.meta.arch != "mlp" {
        for i in 0..b {
            let e = ds.input_elems();
            ds.render(i, &mut x.data[i * e..(i + 1) * e]);
        }
    }
    let t = lutq::util::Timer::start();
    let (y, counts) = engine.run(&x)?;
    info!("output dims {:?}", y.dims);
    println!(
        "mode={:?}: {counts} ({:.1} ms, multiplier-less: {})",
        mode,
        t.elapsed_ms(),
        counts.is_multiplierless()
    );
    Ok(())
}

fn cmd_report(argv: &[String]) -> Result<()> {
    let cli = Cli::new("lutq report", "footprint/ops accounting")
        .req("artifact", "artifact preset name");
    let a = match cli.parse_from(argv) {
        Ok(a) => a,
        Err(msg) => bail!("{msg}"),
    };
    let rt = Runtime::new(&lutq::artifacts_dir())?;
    let man = rt.manifest(a.get("artifact"))?;
    let layers = manifest_layer_shapes(&man);
    let k = man.dict_size();
    let stats = CompressionStats::compute(&layers, k);
    println!("artifact {}: {} params over {} quantized layers, K={k}",
             man.name, man.param_count(), layers.len());
    println!("  dense:  {} / {} multiplications",
             human_bytes(stats.dense_bytes()), stats.dense_mults);
    println!("  lut-q:  {} / {} multiplications ({:.1}x memory, {:.1}x mults)",
             human_bytes(stats.lutq_bytes()), stats.lutq_mults,
             stats.compression_ratio(), stats.mult_reduction());
    Ok(())
}

/// Derive per-layer shapes from the manifest graph for the paper formulas.
pub fn manifest_layer_shapes(man: &lutq::runtime::Manifest)
                             -> Vec<LayerShape> {
    let mut out = Vec::new();
    let mut hw = man.meta.input.first().copied().unwrap_or(1);
    for op in man.graph.as_arr().unwrap_or(&[]) {
        let kind = op.at("op").as_str().unwrap_or("");
        match kind {
            "conv" => {
                let name = op.at("name").as_str().unwrap().to_string();
                if !man.qlayers.contains(&name) {
                    continue;
                }
                let k = op.at("k").as_usize().unwrap();
                let cin = op.at("cin").as_usize().unwrap();
                let cout = op.at("cout").as_usize().unwrap();
                let stride = op.get("stride").and_then(|s| s.as_usize())
                    .unwrap_or(1);
                hw = hw.div_ceil(stride);
                out.push(LayerShape {
                    name,
                    n: (k * k * cin * cout) as u64,
                    fan_in: (k * k * cin) as u64,
                    outputs: (hw * hw * cout) as u64,
                });
            }
            "maxpool" => {
                let stride = op.at("stride").as_usize().unwrap_or(2);
                hw /= stride;
            }
            "affine" => {
                let name = op.at("name").as_str().unwrap().to_string();
                if !man.qlayers.contains(&name) {
                    continue;
                }
                let cin = op.at("cin").as_usize().unwrap();
                let cout = op.at("cout").as_usize().unwrap();
                out.push(LayerShape {
                    name,
                    n: (cin * cout) as u64,
                    fan_in: cin as u64,
                    outputs: cout as u64,
                });
            }
            _ => {}
        }
    }
    out
}

fn cmd_list() -> Result<()> {
    let root = lutq::artifacts_dir();
    let mut found = false;
    if root.exists() {
        let mut names: Vec<String> = std::fs::read_dir(&root)?
            .filter_map(|e| e.ok())
            .filter(|e| e.path().join("manifest.json").exists())
            .map(|e| e.file_name().to_string_lossy().to_string())
            .collect();
        names.sort();
        for n in names {
            let rt = Runtime::new(&root)?;
            if let Ok(m) = rt.manifest(&n) {
                println!(
                    "{n:<24} {:>9} params  method={:<8} bits={:<2} act={} \
                     mlbn={}",
                    m.param_count(),
                    m.quant_method(),
                    m.quant_bits(),
                    m.act_bits(),
                    m.mlbn()
                );
                found = true;
            }
        }
    }
    if !found {
        println!(
            "no artifacts under {} — run `make artifacts` first",
            root.display()
        );
    }
    Ok(())
}
