//! `lutq` CLI — the launcher for training, evaluation, export, inference
//! and report generation over AOT artifacts.
//!
//! Subcommands:
//!   train       train an artifact (LUT-Q / baseline) on its synthetic task
//!   eval        evaluate a checkpoint
//!   export      convert a checkpoint to a packed quantized model
//!   infer       compile + run the plan engine on an exported model
//!   serve       HTTP serving front (predict/models/healthz/metrics);
//!               --replicas N shards batches over N in-process servers;
//!               --wire-addr adds the binary framed front next to HTTP
//!   route       sharding router over remote `lutq serve` replicas
//!               (replica specs host:port[@http|binary] pick the hop)
//!   serve-bench latency percentiles over a compiled plan (serving
//!               proxy); --arrival adds open-loop latency-under-SLO rows
//!   wire-check  bitwise-compare one predict over HTTP vs the wire port
//!   bench-check gate a bench JSON against a committed baseline (CI)
//!   report      footprint/ops accounting table for an artifact
//!   list        list available artifacts
//!
//! `infer`, `serve`, `serve-bench`, `bench-check`, `report` and `list`
//! read manifests directly and run the pure-Rust plan engine — no PJRT
//! required. `train`, `eval` and `export` drive AOT programs through the
//! runtime.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, ensure, Context, Result};

use lutq::cli::Cli;
use lutq::data::Dataset;
use lutq::config::TrainConfig;
use lutq::coordinator::{LrSchedule, Trainer};
use lutq::infer::{ExecMode, KernelBackend, Plan, PlanOptions, Tensor};
use lutq::params::export::QuantizedModel;
use lutq::quant::stats::{CompressionStats, LayerShape};
use lutq::report::LatencyReport;
use lutq::runtime::Manifest;
use lutq::serve::config::{
    resolve_workers, BenchTransport, FlakyKnobs, LoadConfig,
    RouteConfig, ServeConfig, ShardHop,
};
use lutq::serve::load::{open_loop_cluster, open_loop_server, Arrival};
use lutq::serve::{
    HttpClient, HttpConfig, HttpFront, HttpReplica, InProcessReplica,
    ModelReport, Registry, Replica, Router, Server, ServerConfig,
    WireClient, WireConfig, WireReplica, WireReply, WireServer,
};
use lutq::util::{human_bytes, Rng, Timer};
use lutq::{info, Runtime};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        eprintln!("{}", usage());
        std::process::exit(2);
    }
    let sub = args[0].clone();
    let rest = args[1..].to_vec();
    let result = match sub.as_str() {
        "train" => cmd_train(&rest),
        "eval" => cmd_eval(&rest),
        "export" => cmd_export(&rest),
        "infer" => cmd_infer(&rest),
        "serve" => cmd_serve(&rest),
        "route" => cmd_route(&rest),
        "serve-bench" => cmd_serve_bench(&rest),
        "wire-check" => cmd_wire_check(&rest),
        "bench-check" => cmd_bench_check(&rest),
        "report" => cmd_report(&rest),
        "list" => cmd_list(),
        "--help" | "-h" | "help" => {
            println!("{}", usage());
            Ok(())
        }
        other => {
            eprintln!("unknown subcommand `{other}`\n{}", usage());
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() -> String {
    "lutq — LUT-Q training & inference coordinator\n\n\
     Subcommands:\n\
     \x20 train   --artifact <name> [--steps N] [--lr F] [--seed N]\n\
     \x20         [--prune F] [--inq] [--eval-every N] [--ckpt-dir D]\n\
     \x20 eval    --artifact <name> --ckpt <file>\n\
     \x20 export  --artifact <name> --ckpt <file> --out <model.bin>\n\
     \x20 infer   --artifact <name> --model <model.bin> [--mode dense|lut|shift]\n\
     \x20 serve   --artifact <a[,b,..]|synthetic> [--model <m[,n,..]>]\n\
     \x20         [--addr H:P] [--wire-addr H:P] [--batch N] [--workers N]\n\
     \x20         [--min-workers N] [--max-workers N] [--plan-threads N]\n\
     \x20         [--linger-ms N] [--queue-cap N] [--max-conns N]\n\
     \x20         [--mode dense|lut|shift]\n\
     \x20         [--kernel auto|scalar|simd|int|int-scalar]\n\
     \x20         [--replicas N] [--max-seconds N] [--metrics-jsonl <file>]\n\
     \x20         [--admission-prior-ms F] [--hedge-threshold F]\n\
     \x20         [--hedge-min-ms F] [--breaker-base-ms F]\n\
     \x20         [--breaker-max-ms F] [--metrics-weights]\n\
     \x20 route   --replicas <h:p[@http|binary][,..]> [--addr H:P]\n\
     \x20         [--wire-addr H:P] [--max-shard N] [--max-conns N]\n\
     \x20         [--health-every-ms N] [--max-seconds N]\n\
     \x20         [--metrics-jsonl <file>] [--hedge-threshold F]\n\
     \x20         [--hedge-min-ms F] [--breaker-base-ms F]\n\
     \x20         [--breaker-max-ms F] [--metrics-weights]\n\
     \x20 serve-bench --artifact <a[,b,..]|synthetic> [--model <m[,n,..]>]\n\
     \x20         [--batch N] [--iters N] [--threads N] [--workers N]\n\
     \x20         [--plan-threads N] [--linger-ms N] [--clients N]\n\
     \x20         [--mode dense|lut|shift]\n\
     \x20         [--kernel auto|scalar|simd|int|int-scalar]\n\
     \x20         [--transport inproc|http|binary|cluster] [--replicas N]\n\
     \x20         [--shard-transport inproc|http|binary]\n\
     \x20         [--addr H:P] [--wire-addr H:P] [--deadline-ms N]\n\
     \x20         [--json <file>] [--compile-per-call] [--no-serve]\n\
     \x20         [--arrival poisson|bursty|trace] [--rate R[,R,..]]\n\
     \x20         [--open-requests N] [--slo-ms M[,M,..]] [--burst N]\n\
     \x20         [--burst-factor F] [--trace <file>] [--open-seed N]\n\
     \x20         [--open-workers N] [--flaky-replica I] [--flaky-drop-p F]\n\
     \x20         [--flaky-error-p F] [--flaky-delay-p F]\n\
     \x20         [--flaky-delay-ms N] [--flaky-seed N]\n\
     \x20         [--hedge-threshold F] [--hedge-min-ms F]\n\
     \x20         [--breaker-base-ms F] [--breaker-max-ms F]\n\
     \x20         [--metrics-weights]\n\
     \x20 wire-check --http-addr H:P --wire-addr H:P --model <name>\n\
     \x20         --input-json <file> [--batch N]\n\
     \x20 bench-check [--current <json>] [--baseline <json>]\n\
     \x20         [--max-regress F]\n\
     \x20 report  --artifact <name>\n\
     \x20 list\n"
        .to_string()
}

fn cmd_train(argv: &[String]) -> Result<()> {
    let cli = Cli::new("lutq train", "train an artifact")
        .req("artifact", "artifact preset name (see `lutq list`)")
        .opt("steps", "300", "training steps")
        .opt("lr", "0.05", "peak learning rate (cosine schedule)")
        .opt("seed", "0", "rng seed")
        .opt("prune", "0", "target pruning fraction (pruning artifacts)")
        .opt("eval-every", "0", "evaluate every N steps")
        .opt("ckpt-dir", "", "checkpoint directory")
        .opt("ckpt-every", "0", "checkpoint every N steps")
        .opt("workers", "2", "prefetch worker threads")
        .opt("train-len", "4096", "synthetic train set size")
        .opt("eval-len", "1024", "synthetic eval set size")
        .flag("inq", "drive the INQ freeze schedule")
        .flag("quiet", "suppress progress logs");
    let a = match cli.parse_from(argv) {
        Ok(a) => a,
        Err(msg) => bail!("{msg}"),
    };
    if a.has_flag("quiet") {
        lutq::util::set_log_level(1);
    }
    let steps = a.get_usize("steps");
    let mut cfg = TrainConfig::new(a.get("artifact"))
        .steps(steps)
        .seed(a.get_u64("seed"))
        .lr(LrSchedule::cosine(a.get_f32("lr"), steps, steps / 10 + 1))
        .eval_every(a.get_usize("eval-every"))
        .data_lens(a.get_usize("train-len"), a.get_usize("eval-len"));
    cfg.workers = a.get_usize("workers");
    cfg.checkpoint_every = a.get_usize("ckpt-every");
    if !a.get("ckpt-dir").is_empty() {
        cfg.checkpoint_dir = Some(PathBuf::from(a.get("ckpt-dir")));
        if cfg.checkpoint_every == 0 {
            cfg.checkpoint_every = steps.max(2) / 2;
        }
    }
    let prune = a.get_f32("prune");
    if prune > 0.0 {
        cfg = cfg.prune(prune);
    }
    if a.has_flag("inq") {
        cfg = cfg.inq_standard();
    }

    let rt = Runtime::new(&lutq::artifacts_dir())?;
    let trainer = Trainer::new(&rt, cfg)?;
    let res = trainer.run()?;
    println!(
        "final: loss {:.4}, eval error {:.2}%, {:.2} steps/s",
        res.final_loss,
        res.eval_error * 100.0,
        res.steps_per_sec
    );
    Ok(())
}

fn cmd_eval(argv: &[String]) -> Result<()> {
    let cli = Cli::new("lutq eval", "evaluate a checkpoint")
        .req("artifact", "artifact preset name")
        .req("ckpt", "checkpoint file");
    let a = match cli.parse_from(argv) {
        Ok(a) => a,
        Err(msg) => bail!("{msg}"),
    };
    let rt = Runtime::new(&lutq::artifacts_dir())?;
    let trainer = Trainer::new(&rt, TrainConfig::new(a.get("artifact")))?;
    let (state, step) =
        trainer.state_from_checkpoint(&PathBuf::from(a.get("ckpt")))?;
    let (loss, err) = trainer.evaluate(&state)?;
    println!("checkpoint @ step {step}: eval loss {loss:.4}, error {:.2}%",
             err * 100.0);
    Ok(())
}

fn cmd_export(argv: &[String]) -> Result<()> {
    let cli = Cli::new("lutq export", "export a packed quantized model")
        .req("artifact", "artifact preset name")
        .req("ckpt", "checkpoint file")
        .req("out", "output model path");
    let a = match cli.parse_from(argv) {
        Ok(a) => a,
        Err(msg) => bail!("{msg}"),
    };
    let rt = Runtime::new(&lutq::artifacts_dir())?;
    let man = rt.manifest(a.get("artifact"))?;
    let (store, step) = lutq::params::checkpoint::load(
        &PathBuf::from(a.get("ckpt")))?;
    let model = QuantizedModel::from_state(&store, &man.qlayers);
    let out = PathBuf::from(a.get("out"));
    model.save(&out)?;
    println!(
        "exported step-{step} model: {} ({}; dense {} -> {:.2}x, \
         multiplier-less: {})",
        out.display(),
        human_bytes(model.stored_bytes()),
        human_bytes(model.dense_bytes()),
        model.compression_ratio(),
        model.is_multiplierless()
    );
    Ok(())
}

/// Load an artifact manifest without constructing a PJRT runtime: the
/// plan engine is pure Rust, so inference-side subcommands stay usable
/// even when the XLA backend is absent.
fn load_manifest(artifact: &str) -> Result<Manifest> {
    Manifest::load(&lutq::artifacts_dir().join(artifact)).with_context(|| {
        format!("load artifact `{artifact}` from {} (run `make \
                 artifacts`?)", lutq::artifacts_dir().display())
    })
}

fn parse_mode(s: &str) -> Result<ExecMode> {
    Ok(match s {
        "dense" => ExecMode::Dense,
        "lut" => ExecMode::LutTrick,
        "shift" => ExecMode::ShiftOnly,
        m => bail!("unknown mode {m}"),
    })
}

fn parse_kernel(s: &str) -> Result<KernelBackend> {
    s.parse::<KernelBackend>().map_err(|e| anyhow::anyhow!("{e}"))
}

/// Deterministic synthetic batch matching the artifact's input geometry.
fn synth_batch(man: &Manifest, b: usize) -> Tensor {
    let mut dims = vec![b];
    dims.extend_from_slice(&man.meta.input);
    let ds = lutq::data::SyntheticImages::new(
        man.meta.input[0].max(2), *man.meta.input.get(2).unwrap_or(&3),
        man.meta.num_classes, b, 7, 0.35);
    let mut x = Tensor::zeros(dims);
    if man.meta.arch != "mlp" {
        for i in 0..b {
            let e = ds.input_elems();
            ds.render(i, &mut x.data[i * e..(i + 1) * e]);
        }
    }
    x
}

fn cmd_infer(argv: &[String]) -> Result<()> {
    let cli = Cli::new("lutq infer", "compile + run the plan engine")
        .req("artifact", "artifact preset (for the graph + options)")
        .req("model", "exported model file")
        .opt("mode", "lut", "dense | lut | shift")
        .opt("kernel", "auto", "auto | scalar | simd | int | int-scalar")
        .opt("batch", "4", "batch size");
    let a = match cli.parse_from(argv) {
        Ok(a) => a,
        Err(msg) => bail!("{msg}"),
    };
    let man = load_manifest(a.get("artifact"))?;
    let model = QuantizedModel::load(&PathBuf::from(a.get("model")))?;
    let mode = parse_mode(a.get("mode"))?;
    let opts = PlanOptions { mode, act_bits: man.act_bits(),
                             mlbn: man.mlbn(), threads: 0,
                             kernel: parse_kernel(a.get("kernel"))? };
    let tc = lutq::util::Timer::start();
    let plan = Plan::compile(&man.graph, &model, opts, &man.meta.input)?;
    let compile_ms = tc.elapsed_ms();
    let mut scratch = plan.scratch();

    let x = synth_batch(&man, a.get_usize("batch"));
    let t = lutq::util::Timer::start();
    let counts = plan.run_into(&x, &mut scratch)?;
    let run_ms = t.elapsed_ms();
    let (dims, _) = scratch.output();
    info!("output dims {dims:?}");
    println!(
        "mode={mode:?} backend={}: {counts} (compile {compile_ms:.1} ms, \
         run {run_ms:.1} ms, multiplier-less: {})",
        plan.backend_name(),
        counts.is_multiplierless()
    );
    let tables = plan.int_table_report();
    if !tables.is_empty() {
        println!("int product tables: {} total",
                 human_bytes(plan.int_table_bytes() as u64));
        for (layer, bytes) in &tables {
            println!("  {layer}: {bytes} B");
        }
    }
    Ok(())
}

/// One model entry of a serve-bench run (artifact-loaded or synthetic).
struct BenchModel {
    name: String,
    graph: lutq::jsonic::Json,
    qmodel: QuantizedModel,
    input: Vec<usize>,
    act_bits: usize,
    mlbn: bool,
}

/// Resolve `--artifact`/`--model` into bench models. `synthetic` yields
/// two built-in LUT CNNs (K=4 and K=16) so the serving paths are
/// benchable with no trained artifacts on disk.
fn load_bench_models(artifact: &str,
                     model_files: &str) -> Result<Vec<BenchModel>> {
    if artifact == "synthetic" {
        let mut out = Vec::new();
        for (name, k) in [("synth_lut4", 4usize), ("synth_lut16", 16)] {
            let (graph, qmodel) =
                lutq::testkit::models::synth_conv_model(k, false);
            out.push(BenchModel {
                name: name.to_string(),
                graph,
                qmodel,
                input: lutq::testkit::models::CONV_INPUT.to_vec(),
                act_bits: 0,
                mlbn: false,
            });
        }
        return Ok(out);
    }
    let arts: Vec<&str> =
        artifact.split(',').filter(|s| !s.is_empty()).collect();
    let files: Vec<&str> =
        model_files.split(',').filter(|s| !s.is_empty()).collect();
    ensure!(!arts.is_empty(), "no artifact given");
    ensure!(
        arts.len() == files.len(),
        "--artifact lists {} name(s) but --model lists {} file(s)",
        arts.len(),
        files.len()
    );
    let mut out = Vec::new();
    for (art, file) in arts.iter().zip(&files) {
        let man = load_manifest(art)?;
        let qmodel = QuantizedModel::load(&PathBuf::from(file))?;
        out.push(BenchModel {
            name: man.name.clone(),
            graph: man.graph.clone(),
            qmodel,
            input: man.meta.input.clone(),
            act_bits: man.act_bits(),
            mlbn: man.mlbn(),
        });
    }
    Ok(out)
}

/// Deterministic per-model request pool (`n` single-image samples).
fn sample_pool(bm: &BenchModel, n: usize, seed: u64) -> Vec<Vec<f32>> {
    let elems: usize = bm.input.iter().product();
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.normals(elems)).collect()
}

/// `lutq serve`: stand up the HTTP front over a compiled registry and
/// serve until killed (or `--max-seconds`), then drain gracefully and
/// print/log the per-model reports.
fn cmd_serve(argv: &[String]) -> Result<()> {
    let a = match ServeConfig::cli().parse_from(argv) {
        Ok(a) => a,
        Err(msg) => bail!("{msg}"),
    };
    let cfg = ServeConfig::from_args(&a)?;
    let replicas = cfg.replicas;
    let batch = cfg.batch;
    let models = load_bench_models(&cfg.artifact, &cfg.model)?;
    // compile each model once; replica registries share the Arc<Plan>
    let mut plans: Vec<(String, Arc<Plan>)> = Vec::new();
    for bm in &models {
        let opts = PlanOptions {
            mode: cfg.mode,
            act_bits: bm.act_bits,
            mlbn: bm.mlbn,
            threads: cfg.plan_threads,
            kernel: cfg.kernel,
        };
        let plan = Plan::compile(&bm.graph, &bm.qmodel, opts, &bm.input)?;
        plans.push((bm.name.clone(), Arc::new(plan)));
    }
    let workers_total = resolve_workers(cfg.workers);
    let mut servers: Vec<Arc<Server>> = Vec::with_capacity(replicas);
    for _ in 0..replicas {
        let mut registry = Registry::new();
        for (name, plan) in &plans {
            registry.register_shared(name, Arc::clone(plan))?;
        }
        let server = Arc::new(Server::start(registry, ServerConfig {
            workers: (workers_total / replicas).max(1),
            max_batch: batch,
            linger: cfg.linger,
            queue_cap: cfg.queue_cap,
            admission_prior_ms: cfg.admission_prior_ms,
            min_workers: cfg.min_workers,
            max_workers: cfg.max_workers,
            ..Default::default()
        })?);
        // admin `:load` requests compile through the same flags the
        // boot-time models used (mode/kernel/plan-threads), unless the
        // spec overrides them
        server.set_loader(serve_plan_loader(
            cfg.mode, cfg.kernel, cfg.plan_threads));
        servers.push(server);
    }
    let http_cfg = HttpConfig {
        addr: cfg.addr.clone(),
        max_conns: cfg.max_conns,
        ..Default::default()
    };
    let wire_cfg = if cfg.wire_addr.is_empty() {
        None
    } else {
        Some(WireConfig {
            addr: cfg.wire_addr.clone(),
            max_conns: cfg.max_conns,
            ..Default::default()
        })
    };
    // single server: fronts straight over it; cluster: fronts over a
    // router sharding across the in-process replicas. The optional
    // wire front serves the same backend as the HTTP front.
    let mut router: Option<Arc<Router>> = None;
    let mut wire_front: Option<WireServer> = None;
    let front = if replicas == 1 {
        if let Some(wcfg) = wire_cfg {
            wire_front =
                Some(WireServer::start(Arc::clone(&servers[0]), wcfg)?);
        }
        HttpFront::start(Arc::clone(&servers[0]), http_cfg)?
    } else {
        let backends: Vec<Box<dyn Replica>> = servers
            .iter()
            .enumerate()
            .map(|(i, s)| {
                Box::new(InProcessReplica::new(&format!("r{i}"),
                                               Arc::clone(s)))
                    as Box<dyn Replica>
            })
            .collect();
        let rt = Arc::new(Router::new(
            backends,
            cfg.knobs.router_config(batch),
        )?);
        if let Some(wcfg) = wire_cfg {
            wire_front = Some(WireServer::start(Arc::clone(&rt), wcfg)?);
        }
        let front = HttpFront::start(Arc::clone(&rt), http_cfg)?;
        router = Some(rt);
        front
    };
    println!("lutq serve: listening on http://{} ({} replica(s))",
             front.addr(), replicas);
    if let Some(w) = &wire_front {
        println!("lutq serve: wire protocol on {}", w.addr());
    }
    if cfg.max_workers > 0 {
        println!("lutq serve: autoscaling {}..{} workers per replica",
                 cfg.min_workers, cfg.max_workers);
    }
    for i in servers[0].registry().infos() {
        println!("  model {:<20} input {:?} backend {} (coalesce: {}){}",
                 i.qualified(), i.input, i.backend,
                 if i.batch_invariant { "yes" } else { "batch 1" },
                 if i.default { " [default]" } else { "" });
    }
    let secs = cfg.max_seconds;
    if secs == 0 {
        println!("serving until the process is killed \
                  (--max-seconds bounds the run)");
        loop {
            std::thread::sleep(Duration::from_secs(3600));
        }
    }
    std::thread::sleep(Duration::from_secs(secs));
    front.shutdown();
    if let Some(w) = wire_front {
        w.shutdown();
    }
    // drop the router first (it holds Arc<Server> clones through its
    // in-process replicas), then unwrap and drain each server
    let cluster_rows = router.map(|rt| (rt.totals(), rt.reports()));
    if let Some((totals, reps)) = &cluster_rows {
        print_cluster_report(totals, reps);
    }
    let mut reports: Vec<ModelReport> = Vec::new();
    let mut scale_rows: Vec<lutq::jsonic::Json> = Vec::new();
    for (i, server) in servers.into_iter().enumerate() {
        let server = match Arc::try_unwrap(server) {
            Ok(s) => s,
            Err(_) => bail!("serve: a connection still referenced \
                             replica {i} after front shutdown"),
        };
        // capture autoscaler decisions before shutdown consumes the
        // server — they belong in the metrics JSONL next to the model
        // rows
        let events = server.scale_events();
        if !events.is_empty() {
            println!(
                "serve replica {i}: {} autoscale decision(s), final \
                 pool {} worker(s)",
                events.len(),
                server.worker_count()
            );
        }
        scale_rows.extend(events.iter().map(|e| e.to_json()));
        let mut rs = server.shutdown();
        if replicas > 1 {
            for r in &mut rs {
                r.replica = format!("r{i}");
            }
        }
        reports.extend(rs);
    }
    for r in &reports {
        println!(
            "serve {}{}: {} ok / {} err in {} batches; {} rejected, {} \
             shed, {} abandoned; mean exec {:.2} ms (ewma {:.2} ms)",
            r.model,
            if r.replica.is_empty() {
                String::new()
            } else {
                format!(" [{}]", r.replica)
            },
            r.requests, r.errors, r.batches, r.rejected,
            r.shed, r.abandoned, r.mean_batch_ms, r.ewma_batch_ms
        );
    }
    if !cfg.metrics_jsonl.is_empty() {
        let path = PathBuf::from(&cfg.metrics_jsonl);
        let mut metrics =
            lutq::coordinator::metrics::Metrics::new(Some(path.as_path()))?;
        for r in &reports {
            metrics.record_custom(r.to_json())?;
        }
        for row in scale_rows {
            metrics.record_custom(row)?;
        }
        if let Some((totals, reps)) = &cluster_rows {
            metrics.record_custom(totals.to_json())?;
            for r in reps {
                metrics.record_custom(r.to_json())?;
            }
        }
        println!("wrote {}", path.display());
    }
    Ok(())
}

/// The admin-API plan compiler `lutq serve` installs: turns a
/// `POST /v1/models/{name}:load` spec into a compiled plan. Two spec
/// shapes are understood — `{"artifact":"synthetic","arch":"conv|mlp",
/// "k":N}` rebuilds a built-in testkit model with no files, and
/// `{"artifact":<preset>,"model":<file>}` compiles an exported model
/// against its artifact manifest. Both accept optional `"mode"` and
/// `"kernel"` overrides; everything else inherits the serve flags.
fn serve_plan_loader(mode: ExecMode, kernel: KernelBackend,
                     plan_threads: usize) -> lutq::serve::PlanLoader {
    Box::new(move |spec| {
        let mode = match spec.get("mode").and_then(|j| j.as_str()) {
            Some(m) => parse_mode(m)?,
            None => mode,
        };
        let kernel = match spec.get("kernel").and_then(|j| j.as_str()) {
            Some(k) => parse_kernel(k)?,
            None => kernel,
        };
        let artifact = spec
            .get("artifact")
            .and_then(|j| j.as_str())
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "load spec needs an `artifact` field (`synthetic` \
                     or an artifact preset name)"
                )
            })?;
        let (graph, qmodel, input, act_bits, mlbn) =
            if artifact == "synthetic" {
                let k = spec.get("k").and_then(|j| j.as_usize())
                    .unwrap_or(4);
                let arch = spec.get("arch").and_then(|j| j.as_str())
                    .unwrap_or("conv");
                let ((graph, qmodel), input) = match arch {
                    "conv" => (
                        lutq::testkit::models::synth_conv_model(k, false),
                        lutq::testkit::models::CONV_INPUT.to_vec(),
                    ),
                    "mlp" => (
                        lutq::testkit::models::synth_mlp_model(k),
                        lutq::testkit::models::MLP_INPUT.to_vec(),
                    ),
                    other => bail!("load spec: unknown arch `{other}` \
                                    (conv | mlp)"),
                };
                (graph, qmodel, input, 0, false)
            } else {
                let file = spec.get("model").and_then(|j| j.as_str())
                    .ok_or_else(|| {
                        anyhow::anyhow!(
                            "load spec for artifact `{artifact}` needs \
                             a `model` field (exported model file)"
                        )
                    })?;
                let man = load_manifest(artifact)?;
                let qmodel =
                    QuantizedModel::load(&PathBuf::from(file))?;
                (man.graph.clone(), qmodel, man.meta.input.clone(),
                 man.act_bits(), man.mlbn())
            };
        let opts = PlanOptions { mode, act_bits, mlbn,
                                 threads: plan_threads, kernel };
        Ok(Arc::new(Plan::compile(&graph, &qmodel, opts, &input)?))
    })
}

/// Shared stdout summary of a router's totals and per-replica counters
/// (serve's cluster mode, `lutq route`, and serve-bench's cluster legs
/// all print the same shape, hedge and breaker state included).
fn print_cluster_report(totals: &lutq::serve::cluster::ClusterTotals,
                        reps: &[lutq::serve::cluster::ReplicaReport]) {
    println!(
        "route: {} submitted, {} completed, {} rejected, {} shed, \
         {} failed (reconciles: {})",
        totals.submitted, totals.completed, totals.rejected,
        totals.shed, totals.failed, totals.reconciles()
    );
    for r in reps {
        println!(
            "  replica {}: {} samples in {} shards, {} failed shards, \
             {} rerouted; hedges {} (won {}, lost {}); breaker {} \
             ({} trips; healthy: {})",
            r.replica, r.samples, r.shards, r.failed_shards, r.rerouted,
            r.hedges, r.hedge_wins, r.hedge_losses, r.breaker_state,
            r.breaker_trips, r.healthy
        );
    }
}

/// `lutq route`: a standalone sharding tier over remote `lutq serve`
/// replicas — the process/host-scale deployment shape. Start the
/// backends first (the router reads its model catalog from them), then
/// point clients at the router exactly as they would at a single serve
/// front: same API, same error codes, plus 503 `no_healthy_replicas`
/// when every backend is down.
fn cmd_route(argv: &[String]) -> Result<()> {
    let a = match RouteConfig::cli().parse_from(argv) {
        Ok(a) => a,
        Err(msg) => bail!("{msg}"),
    };
    let cfg = RouteConfig::from_args(&a)?;
    let backends: Vec<Box<dyn Replica>> =
        cfg.replicas.iter().map(|spec| spec.connect()).collect();
    let router = Arc::new(Router::new(backends, cfg.router_config())?);
    let mut wire_front: Option<WireServer> = None;
    if !cfg.wire_addr.is_empty() {
        wire_front = Some(WireServer::start(
            Arc::clone(&router),
            WireConfig {
                addr: cfg.wire_addr.clone(),
                max_conns: cfg.max_conns,
                ..Default::default()
            },
        )?);
    }
    let front = HttpFront::start(Arc::clone(&router), HttpConfig {
        addr: cfg.addr.clone(),
        max_conns: cfg.max_conns,
        ..Default::default()
    })?;
    println!("lutq route: listening on http://{} over {} replica(s)",
             front.addr(), cfg.replicas.len());
    for spec in &cfg.replicas {
        println!("  replica {} ({} shard hops)", spec.addr,
                 spec.transport.tag());
    }
    if let Some(w) = &wire_front {
        println!("lutq route: wire protocol on {}", w.addr());
    }
    for i in router.catalog() {
        println!("  model {:<20} input {:?}{}", i.qualified(), i.input,
                 if i.default { " [default]" } else { "" });
    }
    // periodic prober: killed replicas leave the rotation without a
    // request paying for the discovery, recovered ones rejoin. tick()
    // honours each replica's breaker backoff, so a dead replica is
    // probed on a doubling schedule instead of every pass.
    let probe_ms = cfg.health_every_ms;
    let stop = Arc::new(AtomicBool::new(false));
    let prober = if probe_ms > 0 {
        let rt = Arc::clone(&router);
        let stop = Arc::clone(&stop);
        Some(std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                std::thread::sleep(Duration::from_millis(probe_ms));
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                rt.tick();
            }
        }))
    } else {
        None
    };
    let secs = cfg.max_seconds;
    if secs == 0 {
        println!("routing until the process is killed \
                  (--max-seconds bounds the run)");
        loop {
            std::thread::sleep(Duration::from_secs(3600));
        }
    }
    std::thread::sleep(Duration::from_secs(secs));
    stop.store(true, Ordering::Relaxed);
    front.shutdown();
    if let Some(w) = wire_front {
        w.shutdown();
    }
    if let Some(h) = prober {
        let _ = h.join();
    }
    print_cluster_report(&router.totals(), &router.reports());
    if !cfg.metrics_jsonl.is_empty() {
        let path = PathBuf::from(&cfg.metrics_jsonl);
        let mut metrics =
            lutq::coordinator::metrics::Metrics::new(Some(path.as_path()))?;
        router.log_to(&mut metrics)?;
        println!("wrote {}", path.display());
    }
    Ok(())
}

/// A set of in-process replica servers (plus any per-replica network
/// fronts) the cluster legs of `serve-bench` route over. All replicas
/// share the compiled `Arc<Plan>`s, so replica count never changes
/// compile cost.
struct ClusterRig {
    servers: Vec<Arc<Server>>,
    http_fronts: Vec<HttpFront>,
    wire_fronts: Vec<WireServer>,
    backends: Vec<Box<dyn Replica>>,
}

impl ClusterRig {
    fn build(shared: &[(String, Arc<Plan>)], reps: usize,
             workers_total: usize, batch: usize, linger: Duration,
             max_conns: usize, hop: ShardHop) -> Result<ClusterRig> {
        let mut servers: Vec<Arc<Server>> = Vec::with_capacity(reps);
        for _ in 0..reps {
            let mut registry = Registry::new();
            for (name, plan) in shared {
                registry.register_shared(name, Arc::clone(plan))?;
            }
            servers.push(Arc::new(Server::start(
                registry,
                ServerConfig {
                    workers: (workers_total / reps).max(1),
                    max_batch: batch,
                    linger,
                    queue_cap: 4096,
                    ..Default::default()
                },
            )?));
        }
        // remote shard hops get a real per-replica network front on an
        // ephemeral port; inproc skips the sockets entirely
        let mut http_fronts: Vec<HttpFront> = Vec::new();
        let mut wire_fronts: Vec<WireServer> = Vec::new();
        let mut backends: Vec<Box<dyn Replica>> =
            Vec::with_capacity(reps);
        for (i, s) in servers.iter().enumerate() {
            match hop {
                ShardHop::Http => {
                    let front = HttpFront::start(
                        Arc::clone(s),
                        HttpConfig {
                            addr: "127.0.0.1:0".to_string(),
                            max_conns,
                            ..Default::default()
                        },
                    )?;
                    backends.push(Box::new(HttpReplica::new(
                        &front.addr().to_string(),
                    )));
                    http_fronts.push(front);
                }
                ShardHop::Binary => {
                    let front = WireServer::start(
                        Arc::clone(s),
                        WireConfig {
                            addr: "127.0.0.1:0".to_string(),
                            max_conns,
                            ..Default::default()
                        },
                    )?;
                    backends.push(Box::new(WireReplica::new(
                        &front.addr().to_string(),
                    )));
                    wire_fronts.push(front);
                }
                ShardHop::Inproc => backends.push(Box::new(
                    InProcessReplica::new(&format!("r{i}"),
                                          Arc::clone(s)),
                )),
            }
        }
        Ok(ClusterRig { servers, http_fronts, wire_fronts, backends })
    }

    /// Move the backends out for `Router::new`, optionally wrapping one
    /// replica in a seeded fault-injection plan.
    fn take_backends(&mut self, flaky: Option<FlakyKnobs>)
                     -> Vec<Box<dyn Replica>> {
        use lutq::testkit::flaky::{FaultPlan, FlakyReplica};
        std::mem::take(&mut self.backends)
            .into_iter()
            .enumerate()
            .map(|(i, b)| match flaky {
                Some(f) if f.replica == i => {
                    let plan = FaultPlan {
                        drop_p: f.drop_p,
                        error_p: f.error_p,
                        delay_p: f.delay_p,
                        delay: Duration::from_millis(f.delay_ms),
                    };
                    Box::new(FlakyReplica::new(b, f.seed, plan))
                        as Box<dyn Replica>
                }
                _ => b,
            })
            .collect()
    }

    /// Shut the per-replica fronts down, then drop the servers (they
    /// drain and join on drop). Call only after dropping the Router, so
    /// its pooled shard-hop connections are already closed and the
    /// fronts' handler threads wake instead of waiting out the io
    /// timeout.
    fn teardown(self) {
        for f in self.http_fronts {
            f.shutdown();
        }
        for f in self.wire_fronts {
            f.shutdown();
        }
        drop(self.servers);
    }
}

/// Bench-row tag for one arrival schedule: the kind plus the offered
/// rate, so a `--rate` sweep yields distinct `*/open-loop/*` labels.
fn arrival_label(a: &Arrival) -> String {
    match a {
        Arrival::Poisson { rps } => format!("poisson-{rps:.0}rps"),
        Arrival::Bursty { rps, .. } => format!("bursty-{rps:.0}rps"),
        Arrival::Trace(_) => "trace".to_string(),
    }
}

fn print_open_loop_run(label: &str,
                       rep: &lutq::serve::load::OpenLoopReport,
                       curve: &[(f32, f64)]) {
    let curve_s = curve
        .iter()
        .map(|&(b, f)| format!("<={b:.0}ms {:.1}%", f * 100.0))
        .collect::<Vec<_>>()
        .join(", ");
    println!(
        "open-loop {label}: offered {:.0} rps, achieved {:.0} rps; \
         {} ok, {} rejected, {} failed; SLO [{curve_s}]",
        rep.offered_rps, rep.achieved_rps, rep.stats.ok,
        rep.stats.rejected, rep.stats.failed
    );
}

fn cmd_serve_bench(argv: &[String]) -> Result<()> {
    let a = match LoadConfig::cli().parse_from(argv) {
        Ok(a) => a,
        Err(msg) => bail!("{msg}"),
    };
    let cfg = LoadConfig::from_args(&a)?;
    let mode = cfg.mode;
    let kernel = cfg.kernel;
    let batch = cfg.batch;
    let iters = cfg.iters;
    let warmup = cfg.warmup;
    let deadline =
        cfg.deadline_ms.map(|ms| Duration::from_secs_f64(ms / 1e3));
    let models = load_bench_models(&cfg.artifact, &cfg.model)?;
    let names: Vec<String> =
        models.iter().map(|bm| bm.name.clone()).collect();
    let pool_n = batch.max(8);
    let pools: lutq::serve::load::SamplePools = Arc::new(
        models
            .iter()
            .enumerate()
            .map(|(i, bm)| sample_pool(bm, pool_n, 100 + i as u64))
            .collect(),
    );
    let mut rows: Vec<LatencyReport> = Vec::new();

    // --------- direct path: compile once, batched run_into loop
    for (mi, bm) in models.iter().enumerate() {
        let opts = PlanOptions { mode, act_bits: bm.act_bits,
                                 mlbn: bm.mlbn,
                                 threads: cfg.threads,
                                 kernel };
        let plan = Plan::compile(&bm.graph, &bm.qmodel, opts, &bm.input)?;
        if mi == 0 {
            println!("kernel backend: {}", plan.backend_name());
        }
        let ktag = lutq::report::kernel_tag(plan.backend_name());
        let tables = plan.int_table_report();
        if !tables.is_empty() {
            println!("{} int product tables: {} B total", bm.name,
                     plan.int_table_bytes());
            for (layer, bytes) in &tables {
                println!("  {layer}: {bytes} B");
            }
        }
        let mut scratch = plan.scratch_for(batch);
        let elems: usize = bm.input.iter().product();
        let mut dims = vec![batch];
        dims.extend_from_slice(&bm.input);
        let mut data = Vec::with_capacity(batch * elems);
        for s in 0..batch {
            data.extend_from_slice(&pools[mi][s % pool_n]);
        }
        let x = Tensor::new(dims, data);
        for _ in 0..warmup {
            plan.run_into(&x, &mut scratch)?;
        }
        let mut lat: Vec<f32> = Vec::with_capacity(iters);
        let wall = Timer::start();
        for _ in 0..iters {
            let t = Timer::start();
            plan.run_into(&x, &mut scratch)?;
            lat.push(t.elapsed_ms() as f32);
        }
        rows.push(
            LatencyReport::from_latencies(
                format!("{}/{mode:?}/kernel-{ktag}/direct", bm.name),
                batch, plan.threads(), false, &lat, wall.elapsed_s())
            .with_model(&bm.name)
            .with_backend(plan.backend_name())
            .with_transport("direct")
            .with_table_bytes(plan.int_table_bytes()),
        );

        if cfg.compile_per_call {
            let mut lat: Vec<f32> = Vec::with_capacity(iters);
            let wall = Timer::start();
            for _ in 0..iters {
                let t = Timer::start();
                let p = Plan::compile(&bm.graph, &bm.qmodel, opts,
                                      &bm.input)?;
                p.run_into(&x, &mut scratch)?;
                lat.push(t.elapsed_ms() as f32);
            }
            rows.push(
                LatencyReport::from_latencies(
                    format!("{}/{mode:?}/kernel-{ktag}/compile-per-call",
                            bm.name),
                    batch, plan.threads(), true, &lat, wall.elapsed_s())
                .with_model(&bm.name)
                .with_backend(plan.backend_name())
                .with_transport("direct")
                .with_table_bytes(plan.int_table_bytes()),
            );
        }
    }

    // --------- server path: registry + worker pool + coalescing queue
    if !cfg.no_serve && cfg.transport != BenchTransport::Cluster {
        let mut registry = Registry::new();
        for bm in &models {
            let opts = PlanOptions {
                mode,
                act_bits: bm.act_bits,
                mlbn: bm.mlbn,
                threads: cfg.plan_threads,
                kernel,
            };
            let plan =
                Plan::compile(&bm.graph, &bm.qmodel, opts, &bm.input)?;
            registry.register(&bm.name, plan)?;
        }
        let workers = resolve_workers(cfg.workers);
        let server = Server::start(registry, ServerConfig {
            workers,
            max_batch: batch,
            linger: cfg.linger,
            queue_cap: 4096,
            ..Default::default()
        })?;
        let server = Arc::new(server);
        let nmodels = models.len();
        // enough concurrent callers that coalesced batches can actually
        // fill to the cap (closed-loop clients bound the batch size)
        let clients = match cfg.clients {
            0 => (2 * workers).max(2 * batch),
            c => c,
        };
        // per-model phases: each phase's wall clock covers only this
        // model's requests, so its images/s compares 1:1 with the
        // model's direct row
        for (mi, bm) in models.iter().enumerate() {
            let (lat, secs) = lutq::serve::load::closed_loop(
                &server, &[mi], &pools, iters * batch, clients)?;
            let ms: Vec<f32> = lat.iter().map(|(_, v)| *v).collect();
            let plan = server
                .registry()
                .plan_by_id(mi)
                .context("serve-bench: bench model unloaded mid-run")?;
            let ktag = lutq::report::kernel_tag(plan.backend_name());
            rows.push(
                LatencyReport::from_latencies(
                    format!("{}/{mode:?}/kernel-{ktag}/served", bm.name),
                    1, workers, false, &ms, secs)
                .with_model(&bm.name)
                .with_backend(plan.backend_name())
                .with_transport("inproc")
                .with_table_bytes(plan.int_table_bytes()),
            );
        }
        // mixed phase: all models interleaved through the same pool
        // (the multi-model serving story; rates here are under mixed
        // load, hence the separate `served-mixed` label)
        if nmodels > 1 {
            let ids: Vec<usize> = (0..nmodels).collect();
            let (lat, secs) = lutq::serve::load::closed_loop(
                &server, &ids, &pools, nmodels * iters * batch,
                clients)?;
            let all: Vec<f32> = lat.iter().map(|(_, v)| *v).collect();
            let plan = server
                .registry()
                .plan_by_id(0)
                .context("serve-bench: bench model unloaded mid-run")?;
            let ktag = lutq::report::kernel_tag(plan.backend_name());
            rows.push(
                LatencyReport::from_latencies(
                    format!("all/{mode:?}/kernel-{ktag}/served-mixed"),
                    1, workers, false, &all, secs)
                .with_model("all")
                .with_backend(plan.backend_name())
                .with_transport("inproc"),
            );
        }
        // ------ http transport: the same closed loop through the
        // network front, so the full-path numbers sit next to the
        // in-process rows (plus shed-rate accounting under deadlines).
        // `binary` is a superset: it runs the http rows too, so the
        // wire-vs-json comparison lands in one JSON.
        if matches!(cfg.transport,
                    BenchTransport::Http | BenchTransport::Binary) {
            let front = HttpFront::start(
                Arc::clone(&server),
                HttpConfig {
                    addr: cfg.addr.clone(),
                    max_conns: (clients + 8).max(64),
                    ..Default::default()
                },
            )?;
            let addr = front.addr().to_string();
            println!("serve-bench: http front on {addr}");
            let deadline_ms = cfg.deadline_ms;
            let mut shed_total = 0u64;
            let mut all_total = 0u64;
            for (mi, bm) in models.iter().enumerate() {
                let (lat, secs, stats) =
                    lutq::serve::load::closed_loop_http(
                        &addr, &names, &[mi], &pools, iters * batch,
                        clients, deadline_ms)?;
                let ms: Vec<f32> =
                    lat.iter().map(|(_, v)| *v).collect();
                let plan = server
                .registry()
                .plan_by_id(mi)
                .context("serve-bench: bench model unloaded mid-run")?;
                let ktag =
                    lutq::report::kernel_tag(plan.backend_name());
                rows.push(
                    LatencyReport::from_latencies(
                        format!("{}/{mode:?}/kernel-{ktag}/served-http",
                                bm.name),
                        1, workers, false, &ms, secs)
                    .with_model(&bm.name)
                    .with_backend(plan.backend_name())
                    .with_transport("http")
                    .with_table_bytes(plan.int_table_bytes())
                    .with_shed_rate(stats.shed_rate()),
                );
                println!(
                    "http {}: {} ok, {} rejected (429), {} failed",
                    bm.name, stats.ok, stats.rejected, stats.failed
                );
                ensure!(stats.failed == 0,
                        "serve-bench: {} http request(s) failed \
                         against {}", stats.failed, bm.name);
                shed_total += stats.rejected;
                all_total += stats.ok + stats.rejected + stats.failed;
            }
            // aggregate shed-rate row for the bench JSON trajectory
            let plan = server
                .registry()
                .plan_by_id(0)
                .context("serve-bench: bench model unloaded mid-run")?;
            let ktag = lutq::report::kernel_tag(plan.backend_name());
            rows.push(
                LatencyReport::from_latencies(
                    format!("all/{mode:?}/kernel-{ktag}/http-shed-rate"),
                    1, workers, false, &[], 0.0)
                .with_model("all")
                .with_backend(plan.backend_name())
                .with_transport("http")
                .with_shed_rate(
                    shed_total as f64 / all_total.max(1) as f64),
            );
            front.shutdown();
        }
        // ------ binary transport: the same closed loop through the
        // framed wire front. The requests are pre-encoded frames, so
        // these rows isolate the serialization cost the http rows pay
        // per request.
        if cfg.transport == BenchTransport::Binary {
            let wire = WireServer::start(
                Arc::clone(&server),
                WireConfig {
                    addr: cfg.wire_addr.clone(),
                    max_conns: (clients + 8).max(64),
                    ..Default::default()
                },
            )?;
            let addr = wire.addr().to_string();
            println!("serve-bench: wire front on {addr}");
            let deadline_ms = cfg.deadline_ms;
            let mut shed_total = 0u64;
            let mut all_total = 0u64;
            for (mi, bm) in models.iter().enumerate() {
                let (lat, secs, stats) =
                    lutq::serve::load::closed_loop_wire(
                        &addr, &names, &[mi], &pools, iters * batch,
                        clients, deadline_ms)?;
                let ms: Vec<f32> =
                    lat.iter().map(|(_, v)| *v).collect();
                let plan = server
                .registry()
                .plan_by_id(mi)
                .context("serve-bench: bench model unloaded mid-run")?;
                let ktag =
                    lutq::report::kernel_tag(plan.backend_name());
                rows.push(
                    LatencyReport::from_latencies(
                        format!("{}/{mode:?}/kernel-{ktag}/\
                                 served-binary",
                                bm.name),
                        1, workers, false, &ms, secs)
                    .with_model(&bm.name)
                    .with_backend(plan.backend_name())
                    .with_transport("binary")
                    .with_table_bytes(plan.int_table_bytes())
                    .with_shed_rate(stats.shed_rate()),
                );
                println!(
                    "wire {}: {} ok, {} rejected (429), {} failed",
                    bm.name, stats.ok, stats.rejected, stats.failed
                );
                ensure!(stats.failed == 0,
                        "serve-bench: {} wire request(s) failed \
                         against {}", stats.failed, bm.name);
                shed_total += stats.rejected;
                all_total += stats.ok + stats.rejected + stats.failed;
            }
            let plan = server
                .registry()
                .plan_by_id(0)
                .context("serve-bench: bench model unloaded mid-run")?;
            let ktag = lutq::report::kernel_tag(plan.backend_name());
            rows.push(
                LatencyReport::from_latencies(
                    format!("all/{mode:?}/kernel-{ktag}/\
                             binary-shed-rate"),
                    1, workers, false, &[], 0.0)
                .with_model("all")
                .with_backend(plan.backend_name())
                .with_transport("binary")
                .with_shed_rate(
                    shed_total as f64 / all_total.max(1) as f64),
            );
            wire.shutdown();
        }
        // ------ open-loop leg: fire requests on an arrival schedule
        // instead of the closed loop, so queueing delay under overload
        // is measured instead of hidden (no coordinated omission). One
        // latency-under-SLO row per offered rate.
        if let Some(ol) = &cfg.open_loop {
            let ids: Vec<usize> = (0..nmodels).collect();
            let mlabel = if nmodels == 1 {
                models[0].name.clone()
            } else {
                "all".to_string()
            };
            let plan = server
                .registry()
                .plan_by_id(0)
                .context("serve-bench: bench model unloaded mid-run")?;
            let ktag = lutq::report::kernel_tag(plan.backend_name());
            for arrival in &ol.arrivals {
                let offsets = arrival.offsets_ms(ol.requests, ol.seed);
                let rep = open_loop_server(&server, &names, &ids, &pools,
                                           &offsets, ol.workers,
                                           deadline)?;
                let curve = rep.slo_curve(&ol.slo_ms);
                let label = format!(
                    "{mlabel}/{mode:?}/kernel-{ktag}/open-loop/{}",
                    arrival_label(arrival)
                );
                print_open_loop_run(&label, &rep, &curve);
                rows.push(
                    LatencyReport::from_latencies(
                        label, 1, ol.workers, false, &rep.lat_ms,
                        rep.wall_s)
                    .with_model(&mlabel)
                    .with_backend(plan.backend_name())
                    .with_transport("inproc")
                    .with_shed_rate(rep.stats.shed_rate())
                    .with_open_loop(rep.offered_rps, curve),
                );
            }
        }
        let server = match Arc::try_unwrap(server) {
            Ok(s) => s,
            Err(_) => bail!("serve-bench: server still referenced"),
        };
        let reports = server.shutdown();
        for r in &reports {
            println!(
                "serve {}: {} req in {} batches (mean batch {:.2}, max \
                 {}), mean exec {:.2} ms, mean queue wait {:.2} ms; {} \
                 rejected, {} shed",
                r.model, r.requests, r.batches, r.mean_batch,
                r.max_batch, r.mean_batch_ms, r.mean_wait_ms,
                r.rejected, r.shed
            );
        }
    }

    // --------- cluster path: the same closed loop through the sharding
    // Router over in-process replica servers, run at 1 and N replicas
    // so the bench JSON carries the scaling comparison
    if cfg.transport == BenchTransport::Cluster {
        let nrep = cfg.replicas;
        // shard-hop transport lands in the row labels so inproc, http
        // and binary cluster runs coexist in one bench JSON
        let (shard_tag, cluster_transport) = cfg.shard_hop.row_tags();
        let workers_total = resolve_workers(cfg.workers);
        let clients = match cfg.clients {
            0 => (2 * workers_total).max(2 * batch),
            c => c,
        };
        let max_conns = (clients + 8).max(64);
        // compile once; every replica registry shares the Arc<Plan>
        let mut shared: Vec<(String, Arc<Plan>)> = Vec::new();
        for bm in &models {
            let opts = PlanOptions {
                mode,
                act_bits: bm.act_bits,
                mlbn: bm.mlbn,
                threads: cfg.plan_threads,
                kernel,
            };
            let plan =
                Plan::compile(&bm.graph, &bm.qmodel, opts, &bm.input)?;
            shared.push((bm.name.clone(), Arc::new(plan)));
        }
        let ktag = lutq::report::kernel_tag(shared[0].1.backend_name());
        let mut rep_counts = vec![1usize];
        if nrep > 1 {
            rep_counts.push(nrep);
        }
        for &reps in &rep_counts {
            let mut rig = ClusterRig::build(
                &shared, reps, workers_total, batch, cfg.linger,
                max_conns, cfg.shard_hop)?;
            let backends = rig.take_backends(None);
            let router = Arc::new(Router::new(
                backends,
                cfg.knobs.router_config(batch),
            )?);
            for (mi, bm) in models.iter().enumerate() {
                let (lat, secs, stats) =
                    lutq::serve::load::closed_loop_cluster(
                        &router, &names, &[mi], &pools,
                        iters * batch, clients, None,
                    )?;
                ensure!(stats.failed == 0,
                        "serve-bench: {} cluster request(s) failed \
                         against {}", stats.failed, bm.name);
                let ms: Vec<f32> =
                    lat.iter().map(|(_, v)| *v).collect();
                rows.push(
                    LatencyReport::from_latencies(
                        format!("{}/{mode:?}/kernel-{ktag}/\
                                 cluster-{reps}r{shard_tag}",
                                bm.name),
                        1, workers_total, false, &ms, secs)
                    .with_model(&bm.name)
                    .with_backend(shared[mi].1.backend_name())
                    .with_transport(cluster_transport)
                    .with_table_bytes(shared[mi].1.int_table_bytes())
                    .with_replicas(reps),
                );
            }
            if models.len() > 1 {
                let ids: Vec<usize> = (0..models.len()).collect();
                let (lat, secs, stats) =
                    lutq::serve::load::closed_loop_cluster(
                        &router, &names, &ids, &pools,
                        models.len() * iters * batch, clients, None,
                    )?;
                ensure!(stats.failed == 0,
                        "serve-bench: {} cluster request(s) failed \
                         in the mixed phase", stats.failed);
                let ms: Vec<f32> =
                    lat.iter().map(|(_, v)| *v).collect();
                rows.push(
                    LatencyReport::from_latencies(
                        format!("all/{mode:?}/kernel-{ktag}/\
                                 cluster-{reps}r{shard_tag}-mixed"),
                        1, workers_total, false, &ms, secs)
                    .with_model("all")
                    .with_backend(shared[0].1.backend_name())
                    .with_transport(cluster_transport)
                    .with_replicas(reps),
                );
            }
            println!("cluster {reps}r:");
            print_cluster_report(&router.totals(), &router.reports());
            // drop the router before its replicas' fronts shut down:
            // that closes its pooled shard-hop connections, so the
            // fronts' handler threads wake and join instead of waiting
            // out the io timeout. The replica servers then drain and
            // join on their own drop.
            drop(router);
            rig.teardown();
        }
        if nrep > 1 {
            for bm in &models {
                let one = rows.iter().find(|r| {
                    r.label
                        == format!("{}/{mode:?}/kernel-{ktag}/\
                                    cluster-1r{shard_tag}",
                                   bm.name)
                });
                let many = rows.iter().find(|r| {
                    r.label
                        == format!("{}/{mode:?}/kernel-{ktag}/\
                                    cluster-{nrep}r{shard_tag}",
                                   bm.name)
                });
                if let (Some(o), Some(m)) = (one, many) {
                    println!(
                        "{}: {nrep} replicas {:.1} images/s vs 1 \
                         replica {:.1} images/s ({:.2}x)",
                        bm.name, m.images_per_sec, o.images_per_sec,
                        m.images_per_sec / o.images_per_sec.max(1e-9)
                    );
                }
            }
        }

        // ------ open-loop leg: the tail-latency story — an arrival
        // schedule over the full N-replica router, optionally with one
        // replica wrapped in injected faults so hedging and the circuit
        // breakers have something to do. One latency-under-SLO row per
        // offered rate, plus a greppable counters line for the smoke
        // scripts.
        if let Some(ol) = &cfg.open_loop {
            let mut rig = ClusterRig::build(
                &shared, nrep, workers_total, batch, cfg.linger,
                max_conns, cfg.shard_hop)?;
            let backends = rig.take_backends(cfg.flaky);
            if let Some(f) = &cfg.flaky {
                println!(
                    "open-loop: replica {} wrapped in injected faults \
                     (drop {:.2}, error {:.2}, delay {:.2} x {} ms)",
                    f.replica, f.drop_p, f.error_p, f.delay_p,
                    f.delay_ms
                );
            }
            let router = Arc::new(Router::new(
                backends,
                cfg.knobs.router_config(batch),
            )?);
            let ids: Vec<usize> = (0..models.len()).collect();
            let mlabel = if models.len() == 1 {
                models[0].name.clone()
            } else {
                "all".to_string()
            };
            for arrival in &ol.arrivals {
                let offsets = arrival.offsets_ms(ol.requests, ol.seed);
                let rep = open_loop_cluster(&router, &names, &ids,
                                            &pools, &offsets,
                                            ol.workers, deadline)?;
                let curve = rep.slo_curve(&ol.slo_ms);
                let label = format!(
                    "{mlabel}/{mode:?}/kernel-{ktag}/open-loop/\
                     {}-{nrep}r{shard_tag}",
                    arrival_label(arrival)
                );
                print_open_loop_run(&label, &rep, &curve);
                rows.push(
                    LatencyReport::from_latencies(
                        label, 1, ol.workers, false, &rep.lat_ms,
                        rep.wall_s)
                    .with_model(&mlabel)
                    .with_backend(shared[0].1.backend_name())
                    .with_transport(cluster_transport)
                    .with_replicas(nrep)
                    .with_shed_rate(rep.stats.shed_rate())
                    .with_open_loop(rep.offered_rps, curve),
                );
            }
            let totals = router.totals();
            let reports = router.reports();
            print_cluster_report(&totals, &reports);
            let hedges: u64 = reports.iter().map(|r| r.hedges).sum();
            let wins: u64 = reports.iter().map(|r| r.hedge_wins).sum();
            let losses: u64 =
                reports.iter().map(|r| r.hedge_losses).sum();
            let trips: u64 =
                reports.iter().map(|r| r.breaker_trips).sum();
            println!(
                "open-loop cluster counters: hedges={hedges} \
                 hedge_wins={wins} hedge_losses={losses} \
                 breaker_trips={trips} reconciles={}",
                totals.reconciles()
            );
            drop(router);
            rig.teardown();
        }
    }

    println!("| row | batch | p50 ms | p99 ms | p99.9 ms | images/s |");
    println!("|---|---|---|---|---|---|");
    for r in &rows {
        println!("| {} | {} | {:.2} | {:.2} | {:.2} | {:.1} |", r.label,
                 r.batch, r.p50_ms, r.p99_ms, r.p999_ms,
                 r.images_per_sec);
    }
    for bm in &models {
        let direct = rows.iter().find(|r| {
            r.model == bm.name && r.label.ends_with("/direct")
        });
        let served = rows.iter().find(|r| {
            r.model == bm.name && r.label.ends_with("/served")
        });
        if let (Some(d), Some(s)) = (direct, served) {
            println!(
                "{}: coalescing {:.1} images/s vs direct {:.1} images/s \
                 ({:.2}x)",
                bm.name, s.images_per_sec, d.images_per_sec,
                s.images_per_sec / d.images_per_sec.max(1e-9)
            );
        }
    }
    if !cfg.json.is_empty() {
        let path = PathBuf::from(&cfg.json);
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(&path, lutq::report::latency_reports_json(&rows))?;
        println!("wrote {}", path.display());
    }
    Ok(())
}

/// `lutq wire-check`: answer one predict over HTTP and over the binary
/// wire protocol and require the outputs bitwise-identical — the smoke
/// tests' substitute for a curl of the wire port (curl cannot speak the
/// framing). `--batch N` additionally sends one N-sample frame of the
/// same input and requires every row to equal the single-sample answer.
fn cmd_wire_check(argv: &[String]) -> Result<()> {
    let cli = Cli::new("lutq wire-check",
                       "bitwise-compare one predict over HTTP vs the \
                        binary wire protocol")
        .req("http-addr", "host:port of a running HTTP front")
        .req("wire-addr", "host:port of the matching wire front")
        .req("model", "model name to predict")
        .req("input-json",
             "file holding the HTTP predict body {\"input\":[...]}")
        .opt("batch", "1",
             "also send one N-sample batched frame and require each \
              row to equal the single-sample answer");
    let a = match cli.parse_from(argv) {
        Ok(a) => a,
        Err(msg) => bail!("{msg}"),
    };
    let body = std::fs::read_to_string(a.get("input-json"))
        .with_context(|| {
            format!("wire-check: read {}", a.get("input-json"))
        })?;
    let input = lutq::jsonic::parse(&body)
        .map_err(|e| anyhow::anyhow!("wire-check: parse input: {e}"))?
        .get("input")
        .and_then(|j| j.as_f32_vec())
        .ok_or_else(|| {
            anyhow::anyhow!("wire-check: input file needs a numeric \
                             `input` array")
        })?;
    let model = a.get("model");
    // http answer (jsonic's f32 formatting round-trips bit-exactly,
    // so parsing the JSON back loses nothing)
    let mut hc = HttpClient::connect(a.get("http-addr"))?;
    let (status, reply) = hc.predict(model, &body, None)?;
    ensure!(status == 200,
            "wire-check: http predict answered {status}: {reply}");
    let http_out = lutq::jsonic::parse(&reply)
        .map_err(|e| {
            anyhow::anyhow!("wire-check: parse http reply: {e}")
        })?
        .get("output")
        .and_then(|o| o.as_f32_vec())
        .ok_or_else(|| {
            anyhow::anyhow!("wire-check: http reply has no numeric \
                             `output` array")
        })?;
    // wire answer
    let mut wc = WireClient::connect(a.get("wire-addr"))?;
    let wire_out = match wc.predict(model, &input, None)? {
        WireReply::Outputs(mut rows) => {
            ensure!(rows.len() == 1,
                    "wire-check: wire answered {} rows for 1 sample",
                    rows.len());
            rows.remove(0)
        }
        WireReply::Refused(e) => bail!(
            "wire-check: wire predict refused: {} {}: {}",
            e.status, e.code, e.message
        ),
    };
    ensure!(http_out.len() == wire_out.len(),
            "wire-check: output length differs: http {} vs wire {}",
            http_out.len(), wire_out.len());
    for (i, (h, w)) in http_out.iter().zip(&wire_out).enumerate() {
        ensure!(h.to_bits() == w.to_bits(),
                "wire-check: output[{i}] differs: http {h} vs wire {w}");
    }
    let n = a.get_usize("batch").max(1);
    if n > 1 {
        let samples: Vec<&[f32]> =
            (0..n).map(|_| input.as_slice()).collect();
        match wc.predict_batch(model, &samples, None)? {
            WireReply::Outputs(rows) => {
                ensure!(rows.len() == n,
                        "wire-check: batched frame answered {} rows \
                         for {n} samples", rows.len());
                for (s, row) in rows.iter().enumerate() {
                    ensure!(
                        row.len() == wire_out.len()
                            && row
                                .iter()
                                .zip(&wire_out)
                                .all(|(a, b)| a.to_bits() == b.to_bits()),
                        "wire-check: batched row {s} differs from the \
                         single-sample answer"
                    );
                }
            }
            WireReply::Refused(e) => bail!(
                "wire-check: batched predict refused: {} {}: {}",
                e.status, e.code, e.message
            ),
        }
    }
    println!(
        "wire-check OK: {} element(s) bitwise-identical over http and \
         wire{}",
        http_out.len(),
        if n > 1 {
            format!(" (and across a {n}-sample batched frame)")
        } else {
            String::new()
        }
    );
    Ok(())
}

/// One gated row of a bench JSON: label + the throughput metric, plus
/// the latency-under-SLO curve on open-loop rows (empty elsewhere).
struct BenchRow {
    label: String,
    images_per_sec: f64,
    /// `(deadline bound ms, fraction attained)` pairs
    slo_curve: Vec<(f64, f64)>,
}

/// Load a bench JSON's gated rows plus the file's row schema version
/// (rows written before versioning carry none and read as 1).
fn load_bench_rows(path: &str) -> Result<(Vec<BenchRow>, u32)> {
    let txt = std::fs::read_to_string(path)
        .with_context(|| format!("bench-check: read {path}"))?;
    let json = lutq::jsonic::parse(&txt)
        .map_err(|e| anyhow::anyhow!("bench-check: parse {path}: {e}"))?;
    let rows = json.as_arr().ok_or_else(|| {
        anyhow::anyhow!("bench-check: {path}: expected a JSON array of \
                         latency rows")
    })?;
    let mut out = Vec::with_capacity(rows.len());
    let mut version = 1u32;
    for (i, r) in rows.iter().enumerate() {
        if let Some(v) =
            r.get("schema_version").and_then(|v| v.as_usize())
        {
            version = version.max(v as u32);
        }
        let label = r.at("label").as_str().ok_or_else(|| {
            anyhow::anyhow!("bench-check: {path}: row {i} missing `label`")
        })?;
        let ips = r.at("images_per_sec").as_f64().ok_or_else(|| {
            anyhow::anyhow!("bench-check: {path}: row `{label}` missing \
                             `images_per_sec`")
        })?;
        // open-loop rows carry [[bound_ms, fraction], ...]; rows
        // written before PR 8 (or closed-loop rows) have none
        let slo_curve = r
            .get("slo_curve")
            .and_then(|c| c.as_arr())
            .map(|pairs| {
                pairs
                    .iter()
                    .filter_map(|p| {
                        let p = p.as_arr()?;
                        Some((p.first()?.as_f64()?,
                              p.get(1)?.as_f64()?))
                    })
                    .collect()
            })
            .unwrap_or_default();
        out.push(BenchRow { label: label.to_string(),
                            images_per_sec: ips, slo_curve });
    }
    Ok((out, version))
}

/// CI perf gate: compare a freshly generated bench JSON against the
/// committed baseline and fail if any baseline row's images/s regressed
/// more than `--max-regress` (or went missing). Rows that exist only in
/// the current run are reported but never fail the gate, so new bench
/// rows can land before the baseline is refreshed. When the row sets
/// differ at all, the failure prints a symmetric row-name diff
/// (`- label (baseline only)` / `+ label (current only)`) so a renamed
/// label reads as one rename, not N opaque per-row failures.
fn cmd_bench_check(argv: &[String]) -> Result<()> {
    let cli = Cli::new("lutq bench-check",
                       "gate a bench JSON against a committed baseline")
        .opt("current", "reports/BENCH_infer_plan.json",
             "freshly generated bench rows")
        .opt("baseline", "reports/BENCH_baseline.json",
             "committed reference rows")
        .opt("max-regress", "0.15",
             "max tolerated fractional images/s regression per row");
    let a = match cli.parse_from(argv) {
        Ok(a) => a,
        Err(msg) => bail!("{msg}"),
    };
    let tol = a.get_f32("max-regress") as f64;
    ensure!((0.0..1.0).contains(&tol),
            "bench-check: --max-regress must be in [0, 1), got {tol}");
    let (current, cur_ver) = load_bench_rows(a.get("current"))?;
    let (baseline, base_ver) = load_bench_rows(a.get("baseline"))?;
    ensure!(!baseline.is_empty(),
            "bench-check: baseline {} holds no rows", a.get("baseline"));
    // version skew warns but never gates: additive fields parse by
    // name either way, and a baseline refresh should be a deliberate
    // commit, not a CI hostage (bump policy: rust/reports/README.md)
    if cur_ver != base_ver {
        println!(
            "bench-check: WARNING row schema skew — baseline v{base_ver} \
             vs current v{cur_ver}; gating on label/images_per_sec only \
             (refresh the baseline to clear this)"
        );
    }

    println!("| row | baseline img/s | current img/s | delta |");
    println!("|---|---|---|---|");
    let mut failures: Vec<String> = Vec::new();
    for b in &baseline {
        match current.iter().find(|c| c.label == b.label) {
            None => {
                println!("| {} | {:.1} | MISSING | - |", b.label,
                         b.images_per_sec);
            }
            Some(c) => {
                let delta = if b.images_per_sec > 0.0 {
                    c.images_per_sec / b.images_per_sec - 1.0
                } else {
                    0.0
                };
                println!("| {} | {:.1} | {:.1} | {:+.1}% |", b.label,
                         b.images_per_sec, c.images_per_sec,
                         delta * 100.0);
                if delta < -tol {
                    failures.push(format!(
                        "row `{}`: images/s regressed {:.1}% (baseline \
                         {:.1} -> current {:.1}, tolerance {:.0}%)",
                        b.label, -delta * 100.0, b.images_per_sec,
                        c.images_per_sec, tol * 100.0
                    ));
                }
                // open-loop rows additionally gate their SLO curve:
                // every baselined deadline bound must keep its
                // attainment within `tol` (absolute fraction) of the
                // baseline. Bounds only the current run has are
                // ungated, like new rows.
                for &(bound, bfrac) in &b.slo_curve {
                    let cur = c
                        .slo_curve
                        .iter()
                        .find(|(cb, _)| (cb - bound).abs() < 1e-6)
                        .map(|&(_, f)| f);
                    match cur {
                        None => failures.push(format!(
                            "row `{}`: SLO bound {bound:.0} ms present \
                             in the baseline but missing from the \
                             current run", b.label
                        )),
                        Some(cfrac) if bfrac - cfrac > tol => {
                            failures.push(format!(
                                "row `{}`: attainment at {bound:.0} ms \
                                 dropped {:.1}pp (baseline {:.1}% -> \
                                 current {:.1}%, tolerance {:.0}pp)",
                                b.label, (bfrac - cfrac) * 100.0,
                                bfrac * 100.0, cfrac * 100.0,
                                tol * 100.0
                            ));
                        }
                        Some(_) => {}
                    }
                }
            }
        }
    }
    for c in &current {
        if !baseline.iter().any(|b| b.label == c.label) {
            println!("| {} (new, ungated) | - | {:.1} | - |", c.label,
                     c.images_per_sec);
        }
    }
    // symmetric row-name diff: missing baseline rows fail the gate,
    // current-only rows are informational, but both sides print so a
    // renamed label shows up as one `-`/`+` pair instead of N opaque
    // per-row failures
    let missing: Vec<&str> = baseline
        .iter()
        .filter(|b| !current.iter().any(|c| c.label == b.label))
        .map(|b| b.label.as_str())
        .collect();
    let extra: Vec<&str> = current
        .iter()
        .filter(|c| !baseline.iter().any(|b| b.label == c.label))
        .map(|c| c.label.as_str())
        .collect();
    if !missing.is_empty() || !extra.is_empty() {
        println!("\nrow-name diff (baseline vs current):");
        for m in &missing {
            println!("  - {m} (baseline only)");
        }
        for e in &extra {
            println!("  + {e} (current only)");
        }
    }
    if !missing.is_empty() {
        failures.push(format!(
            "{} baseline row(s) missing from the current run: {}{}",
            missing.len(),
            missing.join(", "),
            if extra.is_empty() {
                String::new()
            } else {
                format!(" (current run has {} unmatched new row(s): \
                         {} — renamed labels need a baseline refresh)",
                        extra.len(), extra.join(", "))
            }
        ));
    }
    if !failures.is_empty() {
        bail!("bench-check failed:\n  {}", failures.join("\n  "));
    }
    println!(
        "bench-check OK: {} row(s) within {:.0}% of baseline images/s",
        baseline.len(),
        tol * 100.0
    );
    Ok(())
}

fn cmd_report(argv: &[String]) -> Result<()> {
    let cli = Cli::new("lutq report", "footprint/ops accounting")
        .req("artifact", "artifact preset name");
    let a = match cli.parse_from(argv) {
        Ok(a) => a,
        Err(msg) => bail!("{msg}"),
    };
    let man = load_manifest(a.get("artifact"))?;
    let layers = manifest_layer_shapes(&man);
    let k = man.dict_size();
    let stats = CompressionStats::compute(&layers, k);
    println!("artifact {}: {} params over {} quantized layers, K={k}",
             man.name, man.param_count(), layers.len());
    println!("  dense:  {} / {} multiplications",
             human_bytes(stats.dense_bytes()), stats.dense_mults);
    println!("  lut-q:  {} / {} multiplications ({:.1}x memory, {:.1}x mults)",
             human_bytes(stats.lutq_bytes()), stats.lutq_mults,
             stats.compression_ratio(), stats.mult_reduction());
    Ok(())
}

/// Derive per-layer shapes from the manifest graph for the paper
/// formulas. Ops with missing fields are skipped rather than panicking —
/// full validation is the plan compiler's job.
pub fn manifest_layer_shapes(man: &lutq::runtime::Manifest)
                             -> Vec<LayerShape> {
    let mut out = Vec::new();
    let mut hw = man.meta.input.first().copied().unwrap_or(1);
    for op in man.graph.as_arr().unwrap_or(&[]) {
        let kind = op.at("op").as_str().unwrap_or("");
        match kind {
            "conv" => {
                let (Some(name), Some(k), Some(cin), Some(cout)) =
                    (op.at("name").as_str(), op.at("k").as_usize(),
                     op.at("cin").as_usize(), op.at("cout").as_usize())
                else {
                    continue;
                };
                let stride = op.get("stride").and_then(|s| s.as_usize())
                    .unwrap_or(1);
                hw = hw.div_ceil(stride.max(1));
                if !man.qlayers.iter().any(|q| q == name) {
                    continue;
                }
                out.push(LayerShape {
                    name: name.to_string(),
                    n: (k * k * cin * cout) as u64,
                    fan_in: (k * k * cin) as u64,
                    outputs: (hw * hw * cout) as u64,
                });
            }
            "maxpool" => {
                let stride = op.at("stride").as_usize().unwrap_or(2);
                hw /= stride.max(1);
            }
            "affine" => {
                let (Some(name), Some(cin), Some(cout)) =
                    (op.at("name").as_str(), op.at("cin").as_usize(),
                     op.at("cout").as_usize())
                else {
                    continue;
                };
                if !man.qlayers.iter().any(|q| q == name) {
                    continue;
                }
                out.push(LayerShape {
                    name: name.to_string(),
                    n: (cin * cout) as u64,
                    fan_in: cin as u64,
                    outputs: cout as u64,
                });
            }
            _ => {}
        }
    }
    out
}

fn cmd_list() -> Result<()> {
    let root = lutq::artifacts_dir();
    let mut found = false;
    if root.exists() {
        let mut names: Vec<String> = std::fs::read_dir(&root)?
            .filter_map(|e| e.ok())
            .filter(|e| e.path().join("manifest.json").exists())
            .map(|e| e.file_name().to_string_lossy().to_string())
            .collect();
        names.sort();
        for n in names {
            if let Ok(m) = Manifest::load(&root.join(&n)) {
                println!(
                    "{n:<24} {:>9} params  method={:<8} bits={:<2} act={} \
                     mlbn={}",
                    m.param_count(),
                    m.quant_method(),
                    m.quant_bits(),
                    m.act_bits(),
                    m.mlbn()
                );
                found = true;
            }
        }
    }
    if !found {
        println!(
            "no artifacts under {} — run `make artifacts` first",
            root.display()
        );
    }
    Ok(())
}
