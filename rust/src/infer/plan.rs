//! Graph-IR compilation: lower the manifest's JSON layer graph into a
//! reusable execution [`Plan`].
//!
//! The legacy interpreter re-walked the JSON, re-validated op fields and
//! re-unpacked every layer's bit-packed assignments on *every* call. The
//! plan compiler does all of that exactly once:
//!
//! * **Validation** — every op field is checked with diagnostics carrying
//!   the op index and kind; dangling residual tags (`add` before `save`),
//!   shape mismatches and missing model tensors are compile errors, not
//!   mid-run panics.
//! * **Resolution** — LUT assignments are unpacked and transposed to
//!   output-channel-major, pow-2 shift dictionaries are pre-rounded,
//!   Dense-mode LUT layers are dequantized, BN folds are precomputed.
//! * **Shape inference** — per-sample shapes (and SAME-pad geometry) are
//!   computed statically, sizing the [`Scratch`] arena so steady-state
//!   execution never allocates.
//! * **Op accounting** — counts depend only on shapes, so they are
//!   computed per sample at compile time and scaled by the batch at run
//!   time, bit-identical to the interpreter's per-run tallies.
//!
//! `Plan::compile` once, then `run_into` per request — the amortization
//! that makes the LUT deployment story serveable.

use std::collections::HashMap;

use anyhow::{anyhow, bail, ensure, Result};

use crate::jsonic::Json;
use crate::params::export::QuantizedModel;
use crate::quant::pow2::{pow2_round, Pow2};

use super::arena::Scratch;
use super::counting::OpCounts;
use super::exec;
use super::kernels::int::ACT_LEVELS;
use super::kernels::{self, IntShift, KernelBackend, Kernels};
use super::ops::{same_pad, ExecMode};
use super::tensor::Tensor;

/// Compile-time execution options: the legacy engine knobs plus the
/// worker count for batch-parallel kernels and the inner-kernel backend.
#[derive(Debug, Clone, Copy)]
pub struct PlanOptions {
    pub mode: ExecMode,
    /// activation fake-quant bits after each relu (0 = off)
    pub act_bits: usize,
    /// fold BN scales to pow-2 shifts (multiplier-less BN, appendix A)
    pub mlbn: bool,
    /// worker threads for conv/affine batch parallelism (0 = one per core)
    pub threads: usize,
    /// inner-loop kernel backend; `Auto` honours the `LUTQ_KERNEL` env
    /// override, then prefers SIMD (see [`super::kernels`])
    pub kernel: KernelBackend,
}

impl Default for PlanOptions {
    fn default() -> Self {
        PlanOptions { mode: ExecMode::Dense, act_bits: 0, mlbn: false,
                      threads: 0, kernel: KernelBackend::Auto }
    }
}

/// Per-sample tensor shape (batch dim excluded): `[H, W, C]` after conv
/// ops, `[features]` after flatten/gap/affine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) struct Shape {
    dims: [usize; 3],
    ndim: usize,
}

impl Shape {
    pub(crate) fn from_dims(d: &[usize]) -> Result<Shape> {
        ensure!(
            !d.is_empty() && d.len() <= 3,
            "unsupported per-sample rank {} (dims {d:?})",
            d.len()
        );
        ensure!(d.iter().all(|&x| x > 0), "zero-sized dim in {d:?}");
        let mut dims = [1usize; 3];
        dims[..d.len()].copy_from_slice(d);
        Ok(Shape { dims, ndim: d.len() })
    }

    fn hwc(h: usize, w: usize, c: usize) -> Shape {
        Shape { dims: [h, w, c], ndim: 3 }
    }

    fn flat(n: usize) -> Shape {
        Shape { dims: [n, 1, 1], ndim: 1 }
    }

    pub(crate) fn dims(&self) -> &[usize] {
        &self.dims[..self.ndim]
    }

    pub(crate) fn elems(&self) -> usize {
        self.dims().iter().product()
    }

    fn as_hwc(&self) -> Option<(usize, usize, usize)> {
        if self.ndim == 3 {
            Some((self.dims[0], self.dims[1], self.dims[2]))
        } else {
            None
        }
    }

    fn last(&self) -> usize {
        self.dims[self.ndim - 1]
    }
}

/// Resolved weights of one matmul-like step, transposed to
/// output-channel-major (`[cout][fan]`) so kernel inner loops stream
/// contiguous memory.
#[derive(Debug, Clone)]
pub(crate) enum Kernel {
    /// dense multiply-accumulate weights
    Dense(Vec<f32>),
    /// LUT bucket trick: dictionary + assignment indices
    Lut { dict: Vec<f32>, assign: Vec<u32> },
    /// pre-rounded pow-2 dictionary: shift-only execution (`dict_f32`
    /// is the exact f32 view SIMD combines multiply by)
    Shift { dict: Vec<Pow2>, dict_f32: Vec<f32>, assign: Vec<u32> },
}

impl Kernel {
    fn k(&self) -> usize {
        match self {
            Kernel::Dense(_) => 0,
            Kernel::Lut { dict, .. } => dict.len(),
            Kernel::Shift { dict, .. } => dict.len(),
        }
    }
}

/// Fallback activation abs-max when the manifest carries no
/// `{name}.act_absmax` calibration stat: generous enough for normalized
/// inputs and post-BN activations at the cost of a coarser quantization
/// step (the int backend's error bound scales with it — see
/// `infer::kernels` docs).
pub(crate) const DEFAULT_ACT_ABSMAX: f32 = 8.0;

/// Integer lowering of one matmul-like step for the `int` backend,
/// built at plan compile: the activation quantizer constant, the
/// integer weight body, and the fused f32 epilogue (per-channel rescale
/// + bias, with an immediately-following multiplier-less BN absorbed).
#[derive(Debug, Clone)]
pub(crate) struct IntData {
    /// `1 / s_act`: multiply-then-round quantizer constant
    pub inv_act_scale: f32,
    pub body: IntBody,
    /// per-output-channel `i32 → f32` epilogue rescale
    /// (`s_act * s_dict`, × the folded BN pow-2 when fused)
    pub scale: Vec<f32>,
    /// per-output-channel epilogue bias (layer bias and/or folded BN)
    pub bias: Option<Vec<f32>>,
    /// fused clipped-ReLU: apply `max(0.0)` after the rescale (+ bias),
    /// replacing an immediately-following `relu` step so activations
    /// never take an extra float pass between fused steps
    pub relu: bool,
    /// bytes of integer table / quantized-weight storage, surfaced in
    /// the bench rows' memory column
    pub table_bytes: usize,
}

/// Integer weight form, always mirroring the step's [`Kernel`] variant.
#[derive(Debug, Clone)]
pub(crate) enum IntBody {
    /// i8-grid dense weights widened to i16, `[cout][fan]`
    Dense(Vec<i16>),
    /// K×[`ACT_LEVELS`] product table `dict_q[k] * q`
    Table(Vec<i16>),
    /// pow-2 dictionary as relative left shifts (no table needed)
    Shift(Vec<IntShift>),
}

/// A convolution with fully resolved SAME-pad geometry and weights.
#[derive(Debug, Clone)]
pub(crate) struct ConvStep {
    pub name: String,
    pub kh: usize,
    pub kw: usize,
    pub cin: usize,
    pub cout: usize,
    pub stride: usize,
    pub in_h: usize,
    pub in_w: usize,
    pub out_h: usize,
    pub out_w: usize,
    pub pad_y: usize,
    pub pad_x: usize,
    /// output rows per im2col block (sized to keep the patch area in L1)
    pub block_rows: usize,
    pub kernel: Kernel,
    /// integer lowering, present iff the plan's backend is `int`
    pub int_data: Option<IntData>,
}

impl ConvStep {
    pub(crate) fn fan(&self) -> usize {
        self.kh * self.kw * self.cin
    }

    pub(crate) fn patch_elems(&self) -> usize {
        self.block_rows * self.out_w * self.fan()
    }
}

#[derive(Debug, Clone)]
pub(crate) struct AffineStep {
    pub name: String,
    pub cin: usize,
    pub cout: usize,
    pub bias: Vec<f32>,
    pub kernel: Kernel,
    /// integer lowering, present iff the plan's backend is `int`
    pub int_data: Option<IntData>,
}

/// Precomputed inference BN fold: y = a*x + b (or shift-apply + b under
/// multiplier-less BN).
#[derive(Debug, Clone)]
pub(crate) struct BnStep {
    pub scale: Vec<f32>,
    pub bias: Vec<f32>,
    pub shifts: Option<Vec<Pow2>>,
}

#[derive(Debug, Clone)]
pub(crate) enum Step {
    Conv(ConvStep),
    Affine(AffineStep),
    Bn(BnStep),
    Relu,
    ActQuant { bits: usize },
    MaxPool { k: usize, stride: usize, in_h: usize, in_w: usize, c: usize,
              out_h: usize, out_w: usize },
    Gap { in_h: usize, in_w: usize, c: usize, shift: Option<Pow2> },
    Flatten,
    Save { slot: usize },
    Add { slot: usize, proj: Option<ConvStep> },
}

/// One lowered step plus its per-sample I/O sizes (the run loop's only
/// shape bookkeeping).
#[derive(Debug, Clone)]
pub(crate) struct PlannedStep {
    pub step: Step,
    pub in_elems: usize,
    pub out_elems: usize,
}

/// A compiled, immutable execution plan for one model graph at one
/// per-sample input shape. Compile once, run many; any batch size works
/// with the same plan.
#[derive(Debug, Clone)]
pub struct Plan {
    pub(crate) steps: Vec<PlannedStep>,
    input: Shape,
    output: Shape,
    /// per-sample elems of each residual save slot (max over re-saves)
    pub(crate) slot_elems: Vec<usize>,
    /// max per-sample activation elems across all steps (ping-pong size)
    pub(crate) max_elems: usize,
    /// max per-worker im2col patch elems across all convs
    pub(crate) patch_elems: usize,
    /// max dictionary size across all LUT/shift kernels
    pub(crate) k_max: usize,
    per_sample: OpCounts,
    threads: usize,
    /// inner-kernel backend resolved once at compile time
    backend: kernels::Resolved,
}

impl Plan {
    /// Lower `graph` over `model` at the given per-sample input dims
    /// (e.g. `[32, 32, 3]` for CIFAR NHWC, `[16]` for an MLP). All graph
    /// validation happens here; a plan that compiles cannot fail mid-run.
    pub fn compile(graph: &Json, model: &QuantizedModel, opts: PlanOptions,
                   sample_dims: &[usize]) -> Result<Plan> {
        let backend = kernels::resolve(opts.kernel)?;
        let ops_list = graph
            .as_arr()
            .ok_or_else(|| anyhow!("graph IR must be a JSON array of ops"))?;
        let input = Shape::from_dims(sample_dims)
            .map_err(|e| anyhow!("bad plan input shape: {e}"))?;

        let mut cur = input;
        let mut steps: Vec<PlannedStep> =
            Vec::with_capacity(ops_list.len() + 4);
        let mut counts = OpCounts::default();
        let mut slot_elems: Vec<usize> = Vec::new();
        let mut saved: HashMap<String, (usize, Shape)> = HashMap::new();
        let mut max_elems = input.elems();
        let mut patch_elems = 0usize;
        let mut k_max = 0usize;

        for (idx, op) in ops_list.iter().enumerate() {
            let kind = op
                .at("op")
                .as_str()
                .ok_or_else(|| anyhow!("op {idx}: missing string field `op`"))?;
            let in_elems = cur.elems();
            let step = match kind {
                "conv" => {
                    let c = compile_conv(op, idx, "conv", model, opts.mode,
                                         backend.is_int(), cur,
                                         &mut counts)?;
                    cur = Shape::hwc(c.out_h, c.out_w, c.cout);
                    patch_elems = patch_elems.max(c.patch_elems());
                    k_max = k_max.max(c.kernel.k());
                    Step::Conv(c)
                }
                "bn" => {
                    let bn = compile_bn(op, idx, model, opts.mlbn, cur,
                                        &mut counts)?;
                    // int backend: a multiplier-less BN directly after a
                    // conv folds into the conv's integer epilogue
                    // (per-channel pow-2 rescale + bias). The step
                    // disappears but its tally stays, keeping op
                    // accounting backend-invariant.
                    if backend.is_int() && bn.shifts.is_some() {
                        if let Some(PlannedStep {
                            step: Step::Conv(c), ..
                        }) = steps.last_mut()
                        {
                            // never fold *past* a fused ReLU: the BN
                            // must apply after the clamp, not inside
                            // the epilogue it clamps
                            if let Some(int) = c
                                .int_data
                                .as_mut()
                                .filter(|d| d.bias.is_none() && !d.relu)
                            {
                                let sh = bn.shifts.as_ref().unwrap();
                                for (s, p) in
                                    int.scale.iter_mut().zip(sh)
                                {
                                    *s *= p.to_f32();
                                }
                                int.bias = Some(bn.bias.clone());
                                continue;
                            }
                        }
                    }
                    Step::Bn(bn)
                }
                "relu" => {
                    // int backend: a ReLU directly after a conv/affine
                    // fuses into that step's integer epilogue —
                    // `max(0.0)` after the final rescale is
                    // bit-identical to the separate pass, and the
                    // activations skip a whole float traversal. The
                    // standalone step survives wherever the previous
                    // step isn't an integer matmul (after add/maxpool).
                    if backend.is_int() {
                        let fused = match steps.last_mut() {
                            Some(PlannedStep {
                                step: Step::Conv(c), ..
                            }) => c.int_data.as_mut(),
                            Some(PlannedStep {
                                step: Step::Affine(a), ..
                            }) => a.int_data.as_mut(),
                            _ => None,
                        };
                        if let Some(d) = fused {
                            d.relu = true;
                            if opts.act_bits > 0 {
                                ensure!(opts.act_bits < 31,
                                        "act_bits {} out of range",
                                        opts.act_bits);
                                steps.push(PlannedStep {
                                    step: Step::ActQuant {
                                        bits: opts.act_bits,
                                    },
                                    in_elems: cur.elems(),
                                    out_elems: cur.elems(),
                                });
                            }
                            continue;
                        }
                    }
                    Step::Relu
                }
                "maxpool" => {
                    let k = usize_field(op, idx, kind, "k")?;
                    let stride = usize_field(op, idx, kind, "stride")?;
                    ensure!(k >= 1 && stride >= 1,
                            "op {idx} (maxpool): k and stride must be >= 1");
                    let (h, w, c) = cur.as_hwc().ok_or_else(|| {
                        anyhow!("op {idx} (maxpool): needs (H, W, C) input, \
                                 got {:?}", cur.dims())
                    })?;
                    ensure!(h >= k && w >= k,
                            "op {idx} (maxpool): window {k} exceeds input \
                             {h}x{w}");
                    let (oh, ow) = ((h - k) / stride + 1, (w - k) / stride + 1);
                    cur = Shape::hwc(oh, ow, c);
                    Step::MaxPool { k, stride, in_h: h, in_w: w, c,
                                    out_h: oh, out_w: ow }
                }
                "gap" => {
                    let (h, w, c) = cur.as_hwc().ok_or_else(|| {
                        anyhow!("op {idx} (gap): needs (H, W, C) input, \
                                 got {:?}", cur.dims())
                    })?;
                    let shift = if (h * w).is_power_of_two() {
                        Some(pow2_round(1.0 / (h * w) as f32, -40, 40))
                    } else {
                        None
                    };
                    counts.adds += (c * h * w) as u64;
                    if shift.is_some() {
                        counts.shifts += c as u64;
                    } else {
                        counts.mults += c as u64;
                    }
                    cur = Shape::flat(c);
                    Step::Gap { in_h: h, in_w: w, c, shift }
                }
                "flatten" => {
                    cur = Shape::flat(cur.elems());
                    Step::Flatten
                }
                "affine" => {
                    let a = compile_affine(op, idx, model, opts.mode,
                                           backend.is_int(), cur,
                                           &mut counts)?;
                    cur = Shape::flat(a.cout);
                    k_max = k_max.max(a.kernel.k());
                    Step::Affine(a)
                }
                "save" => {
                    let tag = str_field(op, idx, kind, "tag")?;
                    let slot = match saved.get(tag) {
                        Some(&(slot, _)) => {
                            slot_elems[slot] =
                                slot_elems[slot].max(cur.elems());
                            slot
                        }
                        None => {
                            slot_elems.push(cur.elems());
                            slot_elems.len() - 1
                        }
                    };
                    saved.insert(tag.to_string(), (slot, cur));
                    Step::Save { slot }
                }
                "add" => {
                    let tag = str_field(op, idx, kind, "tag")?;
                    let &(slot, hshape) =
                        saved.get(tag).ok_or_else(|| {
                            anyhow!("op {idx} (add): references save tag \
                                     `{tag}` before any `save` defines it")
                        })?;
                    let proj = match op.get("proj") {
                        Some(p) if p != &Json::Null => {
                            let c = compile_conv(p, idx, "proj conv", model,
                                                 opts.mode,
                                                 backend.is_int(), hshape,
                                                 &mut counts)?;
                            let pshape = Shape::hwc(c.out_h, c.out_w,
                                                    c.cout);
                            ensure!(
                                pshape == cur,
                                "op {idx} (add `{tag}`): projection output \
                                 {:?} != current shape {:?}",
                                pshape.dims(), cur.dims()
                            );
                            patch_elems = patch_elems.max(c.patch_elems());
                            k_max = k_max.max(c.kernel.k());
                            Some(c)
                        }
                        _ => {
                            ensure!(
                                hshape == cur,
                                "op {idx} (add): saved `{tag}` shape {:?} \
                                 != current shape {:?}",
                                hshape.dims(), cur.dims()
                            );
                            None
                        }
                    };
                    counts.adds += cur.elems() as u64;
                    Step::Add { slot, proj }
                }
                other => bail!("op {idx}: unknown graph op `{other}`"),
            };
            max_elems = max_elems.max(cur.elems());
            let relu_with_quant =
                matches!(step, Step::Relu) && opts.act_bits > 0;
            steps.push(PlannedStep { step, in_elems, out_elems: cur.elems() });
            if relu_with_quant {
                ensure!(opts.act_bits < 31,
                        "act_bits {} out of range", opts.act_bits);
                steps.push(PlannedStep {
                    step: Step::ActQuant { bits: opts.act_bits },
                    in_elems: cur.elems(),
                    out_elems: cur.elems(),
                });
            }
        }

        let threads = if opts.threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            opts.threads
        };
        Ok(Plan {
            steps,
            input,
            output: cur,
            slot_elems,
            max_elems,
            patch_elems,
            k_max,
            per_sample: counts,
            threads,
            backend,
        })
    }

    /// Per-sample input dims the plan was compiled for.
    pub fn input_dims(&self) -> Vec<usize> {
        self.input.dims().to_vec()
    }

    /// Output dims for a batch of `b` samples.
    pub fn output_dims(&self, b: usize) -> Vec<usize> {
        let mut d = Vec::with_capacity(1 + self.output.ndim);
        d.push(b);
        d.extend_from_slice(self.output.dims());
        d
    }

    /// Exact op counts for a batch of `b` samples. Counts depend only on
    /// shapes, so this is a compile-time per-sample tally scaled by `b`.
    pub fn counts(&self, b: usize) -> OpCounts {
        let b = b as u64;
        OpCounts {
            mults: self.per_sample.mults * b,
            shifts: self.per_sample.shifts * b,
            adds: self.per_sample.adds * b,
            lookups: self.per_sample.lookups * b,
        }
    }

    /// Resolved worker count used for batch-parallel steps.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Name of the inner-kernel backend this plan compiled against
    /// (`"scalar"`, `"simd-avx2"`, `"simd-portable"`, `"int-scalar"`,
    /// `"int-avx2"`, `"int-portable"`) — surfaced in serve reports and
    /// bench rows.
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// The backend's kernel implementations (static dispatch table).
    pub(crate) fn kernels(&self) -> &'static dyn Kernels {
        self.backend.kernels()
    }

    /// Per-worker bucket-accumulator area: `OC_TILE` channel rows of
    /// `k_max` slots, so backends can tile output channels per patch
    /// read.
    pub(crate) fn bucket_elems(&self) -> usize {
        kernels::OC_TILE * self.k_max
    }

    /// Per-layer `(name, bytes)` breakdown of integer product-table /
    /// quantized-weight storage, in step order. Empty for float
    /// backends — the int backend's memory footprint, measured not
    /// asserted.
    pub fn int_table_report(&self) -> Vec<(String, usize)> {
        let mut v = Vec::new();
        let mut push = |name: &str, d: &Option<IntData>| {
            if let Some(d) = d {
                v.push((name.to_string(), d.table_bytes));
            }
        };
        for ps in &self.steps {
            match &ps.step {
                Step::Conv(c) => push(&c.name, &c.int_data),
                Step::Affine(a) => push(&a.name, &a.int_data),
                Step::Add { proj: Some(c), .. } =>
                    push(&c.name, &c.int_data),
                _ => {}
            }
        }
        v
    }

    /// Total bytes of integer table / quantized-weight storage across
    /// the plan (0 for float backends) — the bench rows' memory column.
    pub fn int_table_bytes(&self) -> usize {
        self.int_table_report().iter().map(|(_, b)| *b).sum()
    }

    /// Per-worker quantized-activation scratch elems (i16) for the int
    /// backend: covers the largest im2col patch block and the widest
    /// row an affine consumes. 0 for float backends, so they pay no
    /// arena cost.
    pub(crate) fn qpatch_elems(&self) -> usize {
        if self.backend.is_int() {
            self.patch_elems.max(self.max_elems)
        } else {
            0
        }
    }

    /// Per-worker i32 bucket accumulators for the int shift combine:
    /// `OC_TILE` channel rows of `k_max` slots, mirroring the float
    /// bucket area so the vectorized int backends can tile output
    /// channels per patch read (0 for float backends).
    pub(crate) fn ibucket_elems(&self) -> usize {
        if self.backend.is_int() {
            kernels::OC_TILE * self.k_max
        } else {
            0
        }
    }

    /// Override the worker count (0 = one per core).
    pub fn set_threads(&mut self, threads: usize) {
        self.threads = if threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            threads
        };
    }

    /// True when execution is per-sample independent, so results never
    /// depend on how requests are coalesced into batches. The only
    /// batch-coupled step is activation fake-quant (`act_bits > 0`),
    /// whose scale is per-tensor over the whole batch; servers cap such
    /// plans at batch 1.
    pub fn batch_invariant(&self) -> bool {
        !self
            .steps
            .iter()
            .any(|s| matches!(s.step, Step::ActQuant { .. }))
    }

    /// A fresh (empty) arena for this plan; buffers are provisioned on
    /// first `run_into` and reused afterwards.
    pub fn scratch(&self) -> Scratch {
        Scratch::new()
    }

    /// An arena pre-provisioned for batches of up to `max_batch` samples
    /// (0 keeps it lazy), so the first request pays no allocation.
    pub fn scratch_for(&self, max_batch: usize) -> Scratch {
        let mut s = Scratch::new();
        if max_batch > 0 {
            s.ensure(self, max_batch);
        }
        s
    }

    /// Pre-warmed per-worker arenas for a serving pool: `n` scratches,
    /// each sized for `max_batch`, sharing this plan's sizing logic
    /// instead of duplicating it at every call site.
    pub fn scratch_pool(&self, n: usize, max_batch: usize) -> Vec<Scratch> {
        (0..n).map(|_| self.scratch_for(max_batch)).collect()
    }

    /// Execute over a batch, leaving the output in the arena (read it via
    /// [`Scratch::output`]). Steady-state calls never allocate buffers;
    /// with `threads <= 1` they are fully allocation-free.
    pub fn run_into(&self, x: &Tensor, scratch: &mut Scratch)
                    -> Result<OpCounts> {
        ensure!(
            x.dims.len() == 1 + self.input.ndim
                && x.dims[1..] == *self.input.dims(),
            "input dims {:?} don't match plan input (batch, {:?})",
            x.dims, self.input.dims()
        );
        let b = x.dims[0];
        ensure!(b > 0, "empty batch");
        scratch.ensure(self, b);
        exec::run_plan(self, x, scratch);
        scratch.set_output(b, &self.output);
        Ok(self.counts(b))
    }

    /// Convenience wrapper: execute and copy the output into a fresh
    /// [`Tensor`] (one allocation; use `run_into` + `Scratch::output` on
    /// the serving hot path).
    pub fn run(&self, x: &Tensor, scratch: &mut Scratch)
               -> Result<(Tensor, OpCounts)> {
        let counts = self.run_into(x, scratch)?;
        let (dims, data) = scratch.output();
        Ok((Tensor::new(dims.to_vec(), data.to_vec()), counts))
    }
}

// ------------------------------------------------------------ field utils

fn str_field<'j>(op: &'j Json, idx: usize, kind: &str, key: &str)
                 -> Result<&'j str> {
    op.at(key).as_str().ok_or_else(|| {
        anyhow!("op {idx} ({kind}): missing string field `{key}`")
    })
}

fn usize_field(op: &Json, idx: usize, kind: &str, key: &str)
               -> Result<usize> {
    op.at(key).as_usize().ok_or_else(|| {
        anyhow!("op {idx} ({kind}): missing integer field `{key}`")
    })
}

fn fp_vec<'m>(model: &'m QuantizedModel, name: &str, idx: usize,
              kind: &str) -> Result<&'m [f32]> {
    model
        .fp
        .get(name)
        .map(|t| t.as_f32())
        .ok_or_else(|| {
            anyhow!("op {idx} ({kind}): model missing fp tensor `{name}`")
        })
}

// ---------------------------------------------------------- op compilers

/// Transpose `[fan][cout]`-flattened values to `[cout][fan]`.
fn transpose_to_oc<T: Copy + Default>(src: &[T], fan: usize, cout: usize)
                                      -> Vec<T> {
    let mut dst = vec![T::default(); src.len()];
    for j in 0..fan {
        for oc in 0..cout {
            dst[oc * fan + j] = src[j * cout + oc];
        }
    }
    dst
}

/// Resolve the weights of a conv/affine layer into an execution kernel:
/// LUT layers honour the execution mode (Dense dequantizes, LutTrick
/// unpacks + transposes, ShiftOnly pre-rounds the dictionary); fp layers
/// always run dense.
fn resolve_kernel(model: &QuantizedModel, name: &str, fan: usize,
                  cout: usize, mode: ExecMode, idx: usize, kind: &str)
                  -> Result<Kernel> {
    if let Some(l) = model.lut(name) {
        ensure!(
            l.n() == fan * cout,
            "op {idx} ({kind} `{name}`): LUT layer holds {} weights, graph \
             shape needs {}",
            l.n(), fan * cout
        );
        // kernels index the dictionary unchecked on the SIMD path; make
        // out-of-range assignments a compile diagnostic, not UB/panic
        let amax =
            l.assignments().iter().copied().max().unwrap_or(0) as usize;
        ensure!(
            amax < l.dict.len(),
            "op {idx} ({kind} `{name}`): assignment index {amax} out of \
             range for K={}",
            l.dict.len()
        );
        return Ok(match mode {
            ExecMode::Dense => {
                Kernel::Dense(transpose_to_oc(&l.dequantize(), fan, cout))
            }
            ExecMode::LutTrick => Kernel::Lut {
                dict: l.dict.clone(),
                assign: transpose_to_oc(l.assignments(), fan, cout),
            },
            ExecMode::ShiftOnly => {
                let sd = l.shift_dict().ok_or_else(|| {
                    anyhow!("op {idx} ({kind} `{name}`): ShiftOnly needs a \
                             pow-2 dictionary (an entry is not 0 or ±2^k)")
                })?;
                Kernel::Shift {
                    dict_f32: sd.iter().map(|p| p.to_f32()).collect(),
                    dict: sd.to_vec(),
                    assign: transpose_to_oc(l.assignments(), fan, cout),
                }
            }
        });
    }
    let w = fp_vec(model, &format!("{name}.w"), idx, kind)?;
    ensure!(
        w.len() == fan * cout,
        "op {idx} ({kind} `{name}`): fp weights hold {} values, graph \
         shape needs {}",
        w.len(), fan * cout
    );
    Ok(Kernel::Dense(transpose_to_oc(w, fan, cout)))
}

/// Quantization scale mapping `vals` onto the i8 grid (`absmax / 127`);
/// all-zero tensors get scale 1 so the grid stays well-defined.
fn i8_scale(vals: &[f32]) -> f32 {
    let m = vals.iter().fold(0f32, |m, v| m.max(v.abs()));
    if m > 0.0 { m / 127.0 } else { 1.0 }
}

/// Per-layer activation calibration for the int backend: the optional
/// 1-element `{name}.act_absmax` manifest stat, else the documented
/// default.
fn act_absmax(model: &QuantizedModel, name: &str) -> f32 {
    model
        .fp
        .get(&format!("{name}.act_absmax"))
        .and_then(|t| t.as_f32().first().copied())
        .unwrap_or(DEFAULT_ACT_ABSMAX)
}

/// Lower one resolved kernel to its integer form for the int backend:
/// quantize the dictionary/weights to the i8 grid, build the product
/// table (LUT) or relative-shift lowering (pow-2 dictionaries — no
/// table), and validate i32 accumulator headroom across the layer
/// fan-in at compile time, not mid-run.
fn build_int_data(kernel: &Kernel, name: &str, fan: usize, cout: usize,
                  bias: Option<&[f32]>, act_absmax: f32, idx: usize,
                  kind: &str) -> Result<IntData> {
    ensure!(
        act_absmax.is_finite() && act_absmax > 0.0,
        "op {idx} ({kind} `{name}`): act_absmax calibration must be \
         finite and > 0, got {act_absmax}"
    );
    let s_act = act_absmax / 127.0;
    // |q·w| <= 127² per term on the dense/table paths
    let dense_fits = (fan as i64) * 127 * 127 <= i32::MAX as i64;
    let (body, s_dict, table_bytes) = match kernel {
        Kernel::Dense(w) => {
            ensure!(dense_fits,
                    "op {idx} ({kind} `{name}`): fan-in {fan} overflows \
                     the int backend's i32 accumulator");
            let s_w = i8_scale(w);
            let wq: Vec<i16> =
                w.iter().map(|v| (v / s_w).round() as i16).collect();
            let bytes = wq.len() * std::mem::size_of::<i16>();
            (IntBody::Dense(wq), s_w, bytes)
        }
        Kernel::Lut { dict, .. } => {
            ensure!(dense_fits,
                    "op {idx} ({kind} `{name}`): fan-in {fan} overflows \
                     the int backend's i32 accumulator");
            let s_d = i8_scale(dict);
            let mut table = vec![0i16; dict.len() * ACT_LEVELS];
            for (k, d) in dict.iter().enumerate() {
                let dq = (d / s_d).round() as i32;
                for q in -128..128i32 {
                    table[k * ACT_LEVELS + (q + 128) as usize] =
                        (dq * q) as i16;
                }
            }
            let bytes = table.len() * std::mem::size_of::<i16>();
            (IntBody::Table(table), s_d, bytes)
        }
        Kernel::Shift { dict, .. } => {
            let e_min = dict
                .iter()
                .filter_map(|p| match p {
                    Pow2::Zero => None,
                    Pow2::Val { exp, .. } => Some(*exp as i32),
                })
                .min();
            let e_max = dict
                .iter()
                .filter_map(|p| match p {
                    Pow2::Zero => None,
                    Pow2::Val { exp, .. } => Some(*exp as i32),
                })
                .max();
            if let (Some(lo), Some(hi)) = (e_min, e_max) {
                // worst case |acc| <= fan · 127 · 2^span
                let span = (hi - lo) as u32;
                ensure!(
                    span <= 24
                        && (fan as i64) * 127 * (1i64 << span)
                            <= i32::MAX as i64,
                    "op {idx} ({kind} `{name}`): pow-2 dictionary \
                     exponent span {span} at fan-in {fan} can overflow \
                     the int backend's i32 accumulator; use the scalar \
                     or simd backend for this model"
                );
            }
            let shifts: Vec<IntShift> = dict
                .iter()
                .map(|p| match p {
                    Pow2::Zero =>
                        IntShift { zero: true, neg: false, sh: 0 },
                    Pow2::Val { neg, exp } => IntShift {
                        zero: false,
                        neg: *neg,
                        sh: (*exp as i32 - e_min.unwrap()) as u8,
                    },
                })
                .collect();
            // dictionary scale 2^e_min: every entry is ±2^(e−e_min)
            // times it, i.e. an exact integer left shift
            let s_d = match e_min {
                Some(e) =>
                    Pow2::Val { neg: false, exp: e as i8 }.to_f32(),
                None => 1.0,
            };
            let bytes = shifts.len() * std::mem::size_of::<IntShift>();
            (IntBody::Shift(shifts), s_d, bytes)
        }
    };
    Ok(IntData {
        inv_act_scale: 1.0 / s_act,
        body,
        scale: vec![s_act * s_dict; cout],
        bias: bias.map(|b| b.to_vec()),
        relu: false,
        table_bytes,
    })
}

/// Tally the per-sample cost of one matmul-like step, mirroring the
/// reference kernels' accounting exactly.
fn kernel_counts(counts: &mut OpCounts, kernel: &Kernel, out_elems: usize,
                 fan: usize) {
    let out = out_elems as u64;
    let fan = fan as u64;
    match kernel {
        Kernel::Dense(_) => {
            counts.mults += out * fan;
            counts.adds += out * fan;
        }
        Kernel::Lut { dict, .. } => {
            let k = dict.len() as u64;
            counts.adds += out * (fan + k);
            counts.lookups += out * fan;
            counts.mults += out * k;
        }
        Kernel::Shift { dict, .. } => {
            let k = dict.len() as u64;
            counts.adds += out * (fan + k);
            counts.lookups += out * fan;
            counts.shifts += out * k;
        }
    }
}

/// Target im2col block footprint: ~32 KB of f32 patches per worker.
const BLOCK_TARGET_ELEMS: usize = 8192;

#[allow(clippy::too_many_arguments)]
fn compile_conv(op: &Json, idx: usize, kind: &str, model: &QuantizedModel,
                mode: ExecMode, int_backend: bool, in_shape: Shape,
                counts: &mut OpCounts) -> Result<ConvStep> {
    let name = str_field(op, idx, kind, "name")?.to_string();
    let k = usize_field(op, idx, kind, "k")?;
    let cin = usize_field(op, idx, kind, "cin")?;
    let cout = usize_field(op, idx, kind, "cout")?;
    let stride = op.get("stride").and_then(|s| s.as_usize()).unwrap_or(1);
    ensure!(k >= 1 && stride >= 1 && cout >= 1,
            "op {idx} ({kind} `{name}`): k, stride and cout must be >= 1");
    let (h, w, c) = in_shape.as_hwc().ok_or_else(|| {
        anyhow!("op {idx} ({kind} `{name}`): needs (H, W, C) input, got \
                 {:?}", in_shape.dims())
    })?;
    ensure!(c == cin,
            "op {idx} ({kind} `{name}`): graph cin {cin} != incoming \
             channels {c}");
    let (out_h, pad_y) = same_pad(h, k, stride);
    let (out_w, pad_x) = same_pad(w, k, stride);
    let kernel = resolve_kernel(model, &name, k * k * cin, cout, mode, idx,
                                kind)?;
    kernel_counts(counts, &kernel, out_h * out_w * cout, k * k * cin);
    let fan = k * k * cin;
    let int_data = if int_backend {
        Some(build_int_data(&kernel, &name, fan, cout, None,
                            act_absmax(model, &name), idx, kind)?)
    } else {
        None
    };
    let block_rows =
        (BLOCK_TARGET_ELEMS / (out_w * fan).max(1)).clamp(1, out_h);
    Ok(ConvStep {
        name, kh: k, kw: k, cin, cout, stride,
        in_h: h, in_w: w, out_h, out_w, pad_y, pad_x, block_rows, kernel,
        int_data,
    })
}

fn compile_affine(op: &Json, idx: usize, model: &QuantizedModel,
                  mode: ExecMode, int_backend: bool, in_shape: Shape,
                  counts: &mut OpCounts) -> Result<AffineStep> {
    let name = str_field(op, idx, "affine", "name")?.to_string();
    let cin = usize_field(op, idx, "affine", "cin")?;
    let cout = usize_field(op, idx, "affine", "cout")?;
    ensure!(cin >= 1 && cout >= 1,
            "op {idx} (affine `{name}`): cin and cout must be >= 1");
    ensure!(
        in_shape.ndim == 1 && in_shape.elems() == cin,
        "op {idx} (affine `{name}`): needs flat input of {cin} features, \
         got {:?}",
        in_shape.dims()
    );
    let bias = fp_vec(model, &format!("{name}.b"), idx, "affine")?;
    ensure!(bias.len() == cout,
            "op {idx} (affine `{name}`): bias has {} entries, cout is \
             {cout}", bias.len());
    let kernel = resolve_kernel(model, &name, cin, cout, mode, idx,
                                "affine")?;
    // reference affine counts the bias add alongside the fan-in adds
    counts.adds += cout as u64;
    kernel_counts(counts, &kernel, cout, cin);
    let int_data = if int_backend {
        Some(build_int_data(&kernel, &name, cin, cout, Some(bias),
                            act_absmax(model, &name), idx, "affine")?)
    } else {
        None
    };
    Ok(AffineStep { name, cin, cout, bias: bias.to_vec(), kernel,
                    int_data })
}

fn compile_bn(op: &Json, idx: usize, model: &QuantizedModel, mlbn: bool,
              in_shape: Shape, counts: &mut OpCounts) -> Result<BnStep> {
    const EPS: f32 = 1e-5;
    let name = str_field(op, idx, "bn", "name")?;
    let c = in_shape.last();
    let gamma = fp_vec(model, &format!("{name}.gamma"), idx, "bn")?;
    let beta = fp_vec(model, &format!("{name}.beta"), idx, "bn")?;
    let rmean = fp_vec(model, &format!("{name}.rmean"), idx, "bn")?;
    let rvar = fp_vec(model, &format!("{name}.rvar"), idx, "bn")?;
    for (label, v) in [("gamma", gamma), ("beta", beta), ("rmean", rmean),
                       ("rvar", rvar)] {
        ensure!(v.len() == c,
                "op {idx} (bn `{name}`): {label} has {} entries, channels \
                 are {c}", v.len());
    }
    let mut scale: Vec<f32> =
        (0..c).map(|i| gamma[i] / (rvar[i] + EPS).sqrt()).collect();
    let shifts: Option<Vec<Pow2>> = if mlbn {
        let sh: Vec<Pow2> =
            scale.iter().map(|&v| pow2_round(v, -12, 12)).collect();
        for (v, s) in scale.iter_mut().zip(&sh) {
            *v = s.to_f32();
        }
        Some(sh)
    } else {
        None
    };
    let bias: Vec<f32> =
        (0..c).map(|i| beta[i] - scale[i] * rmean[i]).collect();
    let elems = in_shape.elems() as u64;
    if mlbn {
        counts.shifts += elems;
    } else {
        counts.mults += elems;
    }
    counts.adds += elems;
    Ok(BnStep { scale, bias, shifts })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::ops::{self, Weights};
    use crate::params::export::LutLayer;
    use crate::params::HostTensor;
    use crate::quant::bitpack::pack_assignments;
    use crate::util::Rng;

    // pin the scalar backend: these tests assert bit-identity against
    // the reference ops, which only the scalar backend guarantees (and
    // the pin must hold even under the CI matrix's LUTQ_KERNEL=simd)
    fn opts(mode: ExecMode, act_bits: usize, mlbn: bool,
            threads: usize) -> PlanOptions {
        PlanOptions { mode, act_bits, mlbn, threads,
                      kernel: KernelBackend::Scalar }
    }

    fn lut_layer(name: &str, dict: Vec<f32>, shape: Vec<usize>,
                 rng: &mut Rng) -> (LutLayer, Vec<u32>) {
        let n: usize = shape.iter().product();
        let assign: Vec<u32> =
            (0..n).map(|_| rng.below(dict.len()) as u32).collect();
        let l = LutLayer::new(name, dict.clone(),
                              pack_assignments(&assign, dict.len()), shape);
        (l, assign)
    }

    fn bn_params(model: &mut QuantizedModel, name: &str, c: usize,
                 rng: &mut Rng) {
        let gamma: Vec<f32> =
            (0..c).map(|_| 0.5 + rng.f32()).collect();
        let beta: Vec<f32> = rng.normals(c);
        let rmean: Vec<f32> = rng.normals(c);
        let rvar: Vec<f32> = (0..c).map(|_| 0.3 + rng.f32()).collect();
        for (suffix, v) in [("gamma", gamma), ("beta", beta),
                            ("rmean", rmean), ("rvar", rvar)] {
            model.fp.insert(format!("{name}.{suffix}"),
                            HostTensor::f32(vec![c], v));
        }
    }

    /// Residual conv net: conv + bn + relu(act8) + save/add + maxpool +
    /// gap + affine, all LUT layers. Returns the graph, the model, and
    /// the raw (untransposed) assignments for the reference path.
    fn residual_net() -> (Json, QuantizedModel, Vec<Vec<u32>>) {
        let graph = crate::jsonic::parse(
            r#"[
            {"op":"conv","name":"c0","cin":2,"cout":4,"k":3,"stride":1},
            {"op":"bn","name":"b0"},
            {"op":"relu"},
            {"op":"save","tag":"r"},
            {"op":"conv","name":"c1","cin":4,"cout":4,"k":3,"stride":1},
            {"op":"add","tag":"r"},
            {"op":"maxpool","k":2,"stride":2},
            {"op":"gap"},
            {"op":"affine","name":"fc","cin":4,"cout":3}
        ]"#,
        )
        .unwrap();
        let mut rng = Rng::new(21);
        let dict = vec![-0.5f32, 0.0, 0.25, 1.0];
        let mut model = QuantizedModel::default();
        let (l0, a0) = lut_layer("c0", dict.clone(), vec![3, 3, 2, 4],
                                 &mut rng);
        let (l1, a1) = lut_layer("c1", dict.clone(), vec![3, 3, 4, 4],
                                 &mut rng);
        let (lf, af) = lut_layer("fc", dict, vec![4, 3], &mut rng);
        model.lut_layers.extend([l0, l1, lf]);
        bn_params(&mut model, "b0", 4, &mut rng);
        model.fp.insert("fc.b".into(),
                        HostTensor::f32(vec![3], rng.normals(3)));
        (graph, model, vec![a0, a1, af])
    }

    /// The legacy interpreter's exact sequence for `residual_net`, built
    /// from the reference single-op kernels (Dense mode dequantizes, like
    /// the interpreter did).
    fn residual_reference(model: &QuantizedModel, assigns: &[Vec<u32>],
                          x: &Tensor, mode: ExecMode)
                          -> (Tensor, OpCounts) {
        let deq: Vec<Vec<f32>> = ["c0", "c1", "fc"]
            .iter()
            .map(|n| model.lut(n).unwrap().dequantize())
            .collect();
        let weights = |i: usize| {
            if mode == ExecMode::Dense {
                Weights::Dense { w: &deq[i] }
            } else {
                Weights::Lut {
                    dict: &model.lut(["c0", "c1", "fc"][i]).unwrap().dict,
                    assign: &assigns[i],
                }
            }
        };
        let mut counts = OpCounts::default();
        let mut cur =
            ops::conv2d(x, &weights(0), 3, 3, 2, 4, 1, mode, &mut counts);
        let g = model.fp.get("b0.gamma").unwrap().as_f32();
        let b = model.fp.get("b0.beta").unwrap().as_f32();
        let rm = model.fp.get("b0.rmean").unwrap().as_f32();
        let rv = model.fp.get("b0.rvar").unwrap().as_f32();
        cur = ops::batchnorm(&cur, g, b, rm, rv, false, &mut counts);
        cur = ops::relu(&cur);
        cur = ops::act_quant(&cur, 8);
        let saved = cur.clone();
        cur = ops::conv2d(&cur, &weights(1), 3, 3, 4, 4, 1, mode,
                          &mut counts);
        cur = ops::add_tensors(&cur, &saved, &mut counts);
        cur = ops::maxpool(&cur, 2, 2);
        cur = ops::gap(&cur, &mut counts);
        let bias = model.fp.get("fc.b").unwrap().as_f32();
        cur = ops::affine(&cur, &weights(2), bias, 4, 3, mode,
                          &mut counts);
        (cur, counts)
    }

    #[test]
    fn plan_matches_reference_ops_bitwise() {
        let (graph, model, assigns) = residual_net();
        let mut rng = Rng::new(5);
        let x = Tensor::new(vec![3, 6, 6, 2], rng.normals(3 * 6 * 6 * 2));
        for mode in [ExecMode::Dense, ExecMode::LutTrick,
                     ExecMode::ShiftOnly] {
            let (y_ref, c_ref) =
                residual_reference(&model, &assigns, &x, mode);
            let plan = Plan::compile(&graph, &model,
                                     opts(mode, 8, false, 1),
                                     &[6, 6, 2]).unwrap();
            let mut s = plan.scratch();
            let (y, c) = plan.run(&x, &mut s).unwrap();
            assert_eq!(y.dims, y_ref.dims);
            assert_eq!(y.data, y_ref.data, "mode {mode:?} diverged");
            assert_eq!(c, c_ref, "mode {mode:?} counts diverged");
        }
    }

    #[test]
    fn dense_mode_counts_no_lookups() {
        let (graph, model, _) = residual_net();
        let plan = Plan::compile(&graph, &model,
                                 opts(ExecMode::Dense, 8, false, 1),
                                 &[6, 6, 2]).unwrap();
        let c = plan.counts(2);
        assert_eq!(c.lookups, 0, "dense mode must not count lookups: {c}");
        assert!(c.mults > 0);
        assert_eq!(c.mults, plan.counts(1).mults * 2);
    }

    #[test]
    fn threads_do_not_change_bits() {
        let (graph, model, _) = residual_net();
        let mut rng = Rng::new(7);
        let x = Tensor::new(vec![5, 6, 6, 2], rng.normals(5 * 6 * 6 * 2));
        let p1 = Plan::compile(&graph, &model,
                               opts(ExecMode::LutTrick, 8, false, 1),
                               &[6, 6, 2]).unwrap();
        let p4 = Plan::compile(&graph, &model,
                               opts(ExecMode::LutTrick, 8, false, 4),
                               &[6, 6, 2]).unwrap();
        let mut s1 = p1.scratch();
        let mut s4 = p4.scratch();
        let (y1, c1) = p1.run(&x, &mut s1).unwrap();
        let (y4, c4) = p4.run(&x, &mut s4).unwrap();
        assert_eq!(y1.data, y4.data);
        assert_eq!(c1, c4);
    }

    #[test]
    fn scratch_reuse_across_batches() {
        let (graph, model, _) = residual_net();
        // act_bits 0: the activation-quant scale is per-tensor over the
        // whole batch, so only the unquantized path is prefix-stable
        // across batch sizes
        let plan = Plan::compile(&graph, &model,
                                 opts(ExecMode::LutTrick, 0, false, 2),
                                 &[6, 6, 2]).unwrap();
        let mut s = plan.scratch();
        let mut rng = Rng::new(8);
        let x4 = Tensor::new(vec![4, 6, 6, 2], rng.normals(4 * 6 * 6 * 2));
        let x2 = Tensor::new(vec![2, 6, 6, 2],
                             x4.data[..2 * 6 * 6 * 2].to_vec());
        let (y4, _) = plan.run(&x4, &mut s).unwrap();
        // shrink the batch with the same scratch: prefix must agree
        let (y2, _) = plan.run(&x2, &mut s).unwrap();
        assert_eq!(y2.data[..], y4.data[..y2.data.len()]);
        // and re-running the big batch reproduces the original bits
        let (y4b, _) = plan.run(&x4, &mut s).unwrap();
        assert_eq!(y4.data, y4b.data);
    }

    #[test]
    fn projection_shortcut_matches_reference() {
        let graph = crate::jsonic::parse(
            r#"[
            {"op":"save","tag":"in"},
            {"op":"conv","name":"c0","cin":2,"cout":3,"k":3,"stride":2},
            {"op":"add","tag":"in","proj":
              {"op":"conv","name":"p0","cin":2,"cout":3,"k":1,"stride":2}}
        ]"#,
        )
        .unwrap();
        let mut rng = Rng::new(31);
        let mut model = QuantizedModel::default();
        let dict = vec![-1.0f32, 0.0, 0.5, 2.0];
        let (l0, a0) = lut_layer("c0", dict, vec![3, 3, 2, 3], &mut rng);
        model.lut_layers.push(l0);
        let pw: Vec<f32> = rng.normals(2 * 3);
        model.fp.insert("p0.w".into(),
                        HostTensor::f32(vec![1, 1, 2, 3], pw.clone()));
        let x = Tensor::new(vec![2, 5, 5, 2], rng.normals(2 * 5 * 5 * 2));

        let mut c_ref = OpCounts::default();
        let d0 = &model.lut("c0").unwrap().dict;
        let main = ops::conv2d(
            &x, &Weights::Lut { dict: d0, assign: &a0 }, 3, 3, 2, 3, 2,
            ExecMode::LutTrick, &mut c_ref);
        let proj = ops::conv2d(&x, &Weights::Dense { w: &pw }, 1, 1, 2, 3,
                               2, ExecMode::Dense, &mut c_ref);
        let y_ref = ops::add_tensors(&main, &proj, &mut c_ref);

        let plan = Plan::compile(&graph, &model,
                                 opts(ExecMode::LutTrick, 0, false, 1),
                                 &[5, 5, 2]).unwrap();
        let mut s = plan.scratch();
        let (y, c) = plan.run(&x, &mut s).unwrap();
        assert_eq!(y.dims, y_ref.dims);
        assert_eq!(y.data, y_ref.data);
        assert_eq!(c, c_ref);
    }

    #[test]
    fn mlbn_plan_is_multiplierless_end_to_end() {
        let (graph, model, _) = residual_net();
        let plan = Plan::compile(&graph, &model,
                                 opts(ExecMode::ShiftOnly, 8, true, 1),
                                 &[6, 6, 2]).unwrap();
        let mut s = plan.scratch();
        let mut rng = Rng::new(9);
        let x = Tensor::new(vec![1, 6, 6, 2], rng.normals(6 * 6 * 2));
        let (_, c) = plan.run(&x, &mut s).unwrap();
        // gap over 3x3 (not a power of two) still multiplies; every
        // conv/affine/bn op must not
        let gap_mults = 4u64; // one per channel, batch 1
        assert_eq!(c.mults, gap_mults, "{c}");
        assert!(c.shifts > 0);
    }

    // ------------------------------------------------ compile rejection

    #[test]
    fn compile_rejects_dangling_add_tag() {
        let graph = crate::jsonic::parse(
            r#"[{"op":"add","tag":"skip"}]"#).unwrap();
        let model = QuantizedModel::default();
        let err = Plan::compile(&graph, &model, PlanOptions::default(),
                                &[4])
            .unwrap_err()
            .to_string();
        assert!(err.contains("save tag `skip`"), "{err}");
        assert!(err.contains("op 0"), "{err}");
    }

    #[test]
    fn compile_rejects_unknown_op_and_missing_fields() {
        let model = QuantizedModel::default();
        let err = Plan::compile(
            &crate::jsonic::parse(r#"[{"op":"warp"}]"#).unwrap(), &model,
            PlanOptions::default(), &[4]).unwrap_err().to_string();
        assert!(err.contains("unknown graph op `warp`"), "{err}");

        let err = Plan::compile(
            &crate::jsonic::parse(
                r#"[{"op":"conv","k":3,"cin":2,"cout":4}]"#).unwrap(),
            &model, PlanOptions::default(), &[6, 6, 2])
            .unwrap_err().to_string();
        assert!(err.contains("op 0 (conv)") && err.contains("`name`"),
                "{err}");
    }

    #[test]
    fn compile_rejects_shape_and_model_mismatches() {
        let (_, model, _) = residual_net();
        // wrong channel count
        let err = Plan::compile(
            &crate::jsonic::parse(
                r#"[{"op":"conv","name":"c0","cin":2,"cout":4,"k":3}]"#)
                .unwrap(),
            &model, PlanOptions::default(), &[6, 6, 5])
            .unwrap_err().to_string();
        assert!(err.contains("incoming channels"), "{err}");
        // missing bn tensors
        let err = Plan::compile(
            &crate::jsonic::parse(r#"[{"op":"bn","name":"nope"}]"#)
                .unwrap(),
            &model, PlanOptions::default(), &[6, 6, 2])
            .unwrap_err().to_string();
        assert!(err.contains("nope.gamma"), "{err}");
        // affine over unflattened input
        let err = Plan::compile(
            &crate::jsonic::parse(
                r#"[{"op":"affine","name":"fc","cin":4,"cout":3}]"#)
                .unwrap(),
            &model, PlanOptions::default(), &[2, 2, 1])
            .unwrap_err().to_string();
        assert!(err.contains("flat input"), "{err}");
    }

    #[test]
    fn compile_rejects_non_pow2_dict_in_shift_mode() {
        let graph = crate::jsonic::parse(
            r#"[{"op":"affine","name":"fc","cin":4,"cout":2}]"#).unwrap();
        let mut rng = Rng::new(3);
        let mut model = QuantizedModel::default();
        let (l, _) = lut_layer("fc", vec![0.3, 1.0], vec![4, 2], &mut rng);
        model.lut_layers.push(l);
        model.fp.insert("fc.b".into(),
                        HostTensor::f32(vec![2], vec![0.0, 0.0]));
        let err = Plan::compile(&graph, &model,
                                opts(ExecMode::ShiftOnly, 0, false, 1),
                                &[4])
            .unwrap_err()
            .to_string();
        assert!(err.contains("pow-2"), "{err}");
    }

    #[test]
    fn batch_invariance_and_scratch_pool() {
        let (graph, model, _) = residual_net();
        // act_bits > 0 inserts the per-tensor ActQuant step
        let coupled = Plan::compile(&graph, &model,
                                    opts(ExecMode::LutTrick, 8, false, 1),
                                    &[6, 6, 2]).unwrap();
        assert!(!coupled.batch_invariant());
        let invariant = Plan::compile(&graph, &model,
                                      opts(ExecMode::LutTrick, 0, false, 1),
                                      &[6, 6, 2]).unwrap();
        assert!(invariant.batch_invariant());

        // pre-warmed pool arenas execute without further provisioning
        let mut pool = invariant.scratch_pool(3, 4);
        assert_eq!(pool.len(), 3);
        let mut rng = Rng::new(12);
        let x = Tensor::new(vec![4, 6, 6, 2], rng.normals(4 * 6 * 6 * 2));
        let mut lazy = invariant.scratch();
        let (y_ref, _) = invariant.run(&x, &mut lazy).unwrap();
        for s in &mut pool {
            let (y, _) = invariant.run(&x, s).unwrap();
            assert_eq!(y.data, y_ref.data);
        }
    }

    #[test]
    fn kernel_backend_is_resolved_and_reported() {
        let (graph, model, _) = residual_net();
        let scalar = Plan::compile(&graph, &model,
                                   opts(ExecMode::LutTrick, 0, false, 1),
                                   &[6, 6, 2]).unwrap();
        assert_eq!(scalar.backend_name(), "scalar");
        let simd = Plan::compile(
            &graph, &model,
            PlanOptions { mode: ExecMode::LutTrick, act_bits: 0,
                          mlbn: false, threads: 1,
                          kernel: KernelBackend::Simd },
            &[6, 6, 2]).unwrap();
        assert!(simd.backend_name().starts_with("simd"),
                "{}", simd.backend_name());
        // bucket area always covers the channel tile
        assert!(simd.bucket_elems() >= simd.k_max);
        // `int` resolves to a vectorized integer backend, `int-scalar`
        // pins the reference; the integer bucket area is tiled like
        // the float one
        let int = Plan::compile(&graph, &model,
                                int_opts(ExecMode::LutTrick),
                                &[6, 6, 2]).unwrap();
        assert!(int.backend_name() == "int-avx2"
                    || int.backend_name() == "int-portable",
                "{}", int.backend_name());
        assert!(int.ibucket_elems() >= kernels::OC_TILE * int.k_max);
        let int_ref = Plan::compile(
            &graph, &model,
            PlanOptions { mode: ExecMode::LutTrick, act_bits: 0,
                          mlbn: false, threads: 1,
                          kernel: KernelBackend::IntScalar },
            &[6, 6, 2]).unwrap();
        assert_eq!(int_ref.backend_name(), "int-scalar");
    }

    #[test]
    fn run_rejects_mismatched_input_dims() {
        let (graph, model, _) = residual_net();
        let plan = Plan::compile(&graph, &model,
                                 opts(ExecMode::LutTrick, 0, false, 1),
                                 &[6, 6, 2]).unwrap();
        let mut s = plan.scratch();
        let bad = Tensor::zeros(vec![1, 5, 6, 2]);
        assert!(plan.run_into(&bad, &mut s).is_err());
        assert_eq!(plan.input_dims(), vec![6, 6, 2]);
        assert_eq!(plan.output_dims(7), vec![7, 3]);
    }

    fn int_opts(mode: ExecMode) -> PlanOptions {
        PlanOptions { mode, act_bits: 0, mlbn: false, threads: 1,
                      kernel: KernelBackend::Int }
    }

    #[test]
    fn compile_rejects_out_of_range_assignment() {
        // K=3 packs at 2 bits (bits_for(3) == 2), so a packed stream
        // can round-trip the value 3; the gather paths index the
        // dictionary unchecked, so compile must reject it as a
        // diagnostic, never reach the kernels
        let graph = crate::jsonic::parse(
            r#"[{"op":"affine","name":"fc","cin":4,"cout":2}]"#).unwrap();
        let mut model = QuantizedModel::default();
        let assign = vec![0u32, 1, 2, 3, 0, 1, 2, 3];
        // pack at k=4 — identical 2-bit layout, but admits the value 3
        model.lut_layers.push(LutLayer::new(
            "fc", vec![-1.0, 0.0, 1.0], pack_assignments(&assign, 4),
            vec![4, 2]));
        model.fp.insert("fc.b".into(),
                        HostTensor::f32(vec![2], vec![0.0, 0.0]));
        for mode in [ExecMode::LutTrick, ExecMode::ShiftOnly] {
            let err = Plan::compile(&graph, &model,
                                    opts(mode, 0, false, 1), &[4])
                .unwrap_err()
                .to_string();
            assert!(err.contains("assignment index 3"), "{err}");
            assert!(err.contains("K=3"), "{err}");
        }
    }

    #[test]
    fn int_backend_rejects_wide_pow2_exponent_span() {
        // exponent span 26 > 24: fan · 127 · 2^span would overflow the
        // i32 bucket combine, so the int backend refuses at compile
        let graph = crate::jsonic::parse(
            r#"[{"op":"affine","name":"fc","cin":4,"cout":2}]"#).unwrap();
        let mut rng = Rng::new(17);
        let mut model = QuantizedModel::default();
        let dict = vec![2f32.powi(-14), 2f32.powi(12)];
        let (l, _) = lut_layer("fc", dict, vec![4, 2], &mut rng);
        model.lut_layers.push(l);
        model.fp.insert("fc.b".into(),
                        HostTensor::f32(vec![2], vec![0.0, 0.0]));
        let err = Plan::compile(&graph, &model,
                                int_opts(ExecMode::ShiftOnly), &[4])
            .unwrap_err()
            .to_string();
        assert!(err.contains("exponent span 26"), "{err}");
        assert!(err.contains("i32 accumulator"), "{err}");
        // the float backends take the same dictionary without complaint
        Plan::compile(&graph, &model, opts(ExecMode::ShiftOnly, 0, false, 1),
                      &[4])
            .unwrap();
    }

    #[test]
    fn int_plan_reports_table_bytes_and_runs() {
        let (graph, model, _) = residual_net();
        let plan = Plan::compile(&graph, &model,
                                 int_opts(ExecMode::LutTrick),
                                 &[6, 6, 2]).unwrap();
        // three K=4 LUT layers, each a K x 256 i16 product table
        assert_eq!(plan.int_table_bytes(), 3 * 4 * 256 * 2);
        let report = plan.int_table_report();
        assert_eq!(report.len(), 3);
        assert!(report.iter().all(|(_, b)| *b == 4 * 256 * 2), "{report:?}");
        // float backends carry no integer tables
        let float = Plan::compile(&graph, &model,
                                  opts(ExecMode::LutTrick, 0, false, 1),
                                  &[6, 6, 2]).unwrap();
        assert_eq!(float.int_table_bytes(), 0);
        // op counts are compile-time properties, backend-invariant
        assert_eq!(plan.counts(2), float.counts(2));
        // and the int plan executes to finite outputs
        let mut rng = Rng::new(11);
        let x = Tensor::new(vec![2, 6, 6, 2], rng.normals(2 * 6 * 6 * 2));
        let mut s = plan.scratch();
        let (y, _) = plan.run(&x, &mut s).unwrap();
        assert_eq!(y.dims, vec![2, 3]);
        assert!(y.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn int_shift_plan_k1_all_negative_exponents_exact() {
        // K=1 dictionary {-2^-3}: the degenerate single-bucket shift
        // path, with an all-negative exponent lowering. On the integer
        // grid (act_absmax = 127 so the act scale is exactly 1) the int
        // backend is bit-identical to scalar.
        let graph = crate::jsonic::parse(
            r#"[{"op":"affine","name":"fc","cin":6,"cout":2}]"#).unwrap();
        let mut model = QuantizedModel::default();
        let assign = vec![0u32; 12];
        model.lut_layers.push(LutLayer::new(
            "fc", vec![-0.125], pack_assignments(&assign, 1),
            vec![6, 2]));
        model.fp.insert("fc.b".into(),
                        HostTensor::f32(vec![2], vec![2.0, -3.0]));
        model.fp.insert("fc.act_absmax".into(),
                        HostTensor::f32(vec![1], vec![127.0]));
        let x = Tensor::new(vec![2, 6],
                            (0..12).map(|i| (i as i32 - 6) as f32)
                                   .collect::<Vec<f32>>());
        let run = |kernel: KernelBackend| {
            let plan = Plan::compile(
                &graph, &model,
                PlanOptions { mode: ExecMode::ShiftOnly, act_bits: 0,
                              mlbn: false, threads: 1, kernel },
                &[6]).unwrap();
            let mut s = plan.scratch();
            plan.run(&x, &mut s).unwrap().0
        };
        let y_int = run(KernelBackend::Int);
        let y_scalar_int = run(KernelBackend::IntScalar);
        let y_ref = run(KernelBackend::Scalar);
        assert_eq!(y_int.data, y_ref.data);
        assert_eq!(y_scalar_int.data, y_ref.data);
    }

    #[test]
    fn int_backend_fuses_relu_into_epilogue() {
        // affine + relu on the integer grid: the int plans fuse the
        // ReLU into the integer epilogue (no standalone Step::Relu
        // survives) and stay bit-identical to the scalar reference,
        // which runs it as a separate pass.
        let graph = crate::jsonic::parse(
            r#"[{"op":"affine","name":"fc","cin":6,"cout":2},
                {"op":"relu"}]"#).unwrap();
        let mut model = QuantizedModel::default();
        let assign = vec![0u32; 12];
        model.lut_layers.push(LutLayer::new(
            "fc", vec![-0.125], pack_assignments(&assign, 1),
            vec![6, 2]));
        model.fp.insert("fc.b".into(),
                        HostTensor::f32(vec![2], vec![2.0, -3.0]));
        model.fp.insert("fc.act_absmax".into(),
                        HostTensor::f32(vec![1], vec![127.0]));
        let x = Tensor::new(vec![2, 6],
                            (0..12).map(|i| (i as i32 - 6) as f32)
                                   .collect::<Vec<f32>>());
        let run = |kernel: KernelBackend| {
            let plan = Plan::compile(
                &graph, &model,
                PlanOptions { mode: ExecMode::ShiftOnly, act_bits: 0,
                              mlbn: false, threads: 1, kernel },
                &[6]).unwrap();
            let has_relu = plan
                .steps
                .iter()
                .any(|s| matches!(s.step, Step::Relu));
            let mut s = plan.scratch();
            (plan.run(&x, &mut s).unwrap().0, has_relu)
        };
        let (y_ref, relu_ref) = run(KernelBackend::Scalar);
        assert!(relu_ref, "float plans keep the standalone relu step");
        assert!(y_ref.data.iter().all(|v| *v >= 0.0));
        assert!(y_ref.data.iter().any(|v| *v == 0.0),
                "test net must actually clamp a channel: {:?}",
                y_ref.data);
        for kernel in [KernelBackend::IntScalar, KernelBackend::Int] {
            let (y, has_relu) = run(kernel);
            assert!(!has_relu,
                    "int plans fuse relu into the epilogue");
            assert_eq!(y.data, y_ref.data, "{kernel:?}");
        }
    }

    #[test]
    fn relu_fusion_blocks_bn_fold() {
        // conv + relu + bn: the ReLU fuses into the conv's epilogue,
        // so the following multiplier-less BN must NOT fold into that
        // same epilogue (it would then rescale *inside* the clamp).
        // It survives as a standalone step and the output still
        // matches the scalar reference bit-for-bit on the integer
        // grid.
        let graph = crate::jsonic::parse(
            r#"[{"op":"conv","name":"c0","cin":2,"cout":4,"k":3,
                 "stride":1},
                {"op":"relu"},
                {"op":"bn","name":"b0"}]"#).unwrap();
        let mut rng = Rng::new(33);
        let dict = vec![-0.5f32, 0.0, 0.25, 1.0];
        let mut model = QuantizedModel::default();
        let (l0, _) = lut_layer("c0", dict, vec![3, 3, 2, 4], &mut rng);
        model.lut_layers.push(l0);
        bn_params(&mut model, "b0", 4, &mut rng);
        model.fp.insert("c0.act_absmax".into(),
                        HostTensor::f32(vec![1], vec![127.0]));
        let x = Tensor::new(
            vec![2, 6, 6, 2],
            (0..144).map(|i| ((i % 15) as i32 - 7) as f32)
                    .collect::<Vec<f32>>());
        let run = |kernel: KernelBackend| {
            let plan = Plan::compile(
                &graph, &model,
                PlanOptions { mode: ExecMode::ShiftOnly, act_bits: 0,
                              mlbn: true, threads: 1, kernel },
                &[6, 6, 2]).unwrap();
            let bn_steps = plan
                .steps
                .iter()
                .filter(|s| matches!(s.step, Step::Bn(_)))
                .count();
            let mut s = plan.scratch();
            (plan.run(&x, &mut s).unwrap().0, bn_steps)
        };
        let (y_ref, bn_ref) = run(KernelBackend::Scalar);
        assert_eq!(bn_ref, 1);
        for kernel in [KernelBackend::IntScalar, KernelBackend::Int] {
            let (y, bn) = run(kernel);
            assert_eq!(bn, 1, "bn must not fold past the fused relu");
            assert_eq!(y.data, y_ref.data, "{kernel:?}");
        }
    }

    #[test]
    fn int_shift_plan_boundary_span_and_fan_no_overflow() {
        // The exact compile-accepted boundary of the shift-dict
        // overflow check: span 16 at fan-in 258 gives
        // 258 · 127 · 2¹⁶ = 2 147 352 576 ≤ i32::MAX (fan 259 would
        // be rejected). All-±127 activations drive every bucket to its
        // extreme; the plan must run without panicking (debug builds
        // trap integer overflow) and every int backend must agree
        // bitwise. The f64 check pins the actual value, since an exact
        // f32 compare against the float backend would only test f32
        // rounding at 2³¹ magnitudes.
        let fan = 258usize;
        let graph = crate::jsonic::parse(
            r#"[{"op":"affine","name":"fc","cin":258,"cout":2}]"#)
            .unwrap();
        let mut model = QuantizedModel::default();
        // K=2: +2^12 and −2^-4 — exponent span exactly 16. The vector
        // is [fan][cout]-flattened (compile transposes): channel 0
        // (even flat indices) puts every weight on the max-shift entry
        // — the accumulator extreme — while channel 1 (odd indices)
        // splits 129/129 between the two entries.
        let assign: Vec<u32> = (0..2 * fan)
            .map(|i| if i < fan { 0 } else { (i % 2) as u32 })
            .collect();
        model.lut_layers.push(LutLayer::new(
            "fc", vec![4096.0, -0.0625],
            pack_assignments(&assign, 2), vec![fan, 2]));
        model.fp.insert("fc.b".into(),
                        HostTensor::f32(vec![2], vec![0.0, 0.0]));
        model.fp.insert("fc.act_absmax".into(),
                        HostTensor::f32(vec![1], vec![127.0]));
        let x = Tensor::new(vec![1, fan], vec![127.0f32; fan]);
        let mut outs = Vec::new();
        for kernel in [KernelBackend::IntScalar, KernelBackend::Int] {
            let plan = Plan::compile(
                &graph, &model,
                PlanOptions { mode: ExecMode::ShiftOnly, act_bits: 0,
                              mlbn: false, threads: 1, kernel },
                &[fan]).unwrap();
            let mut s = plan.scratch();
            outs.push(plan.run(&x, &mut s).unwrap().0.data);
        }
        assert_eq!(outs[0], outs[1]);
        // channel 0 hits the exact accumulator ceiling:
        // 258·127 = 32766 in bucket 0, shifted 16 → 2 147 352 576
        // (= the compile bound), rescaled by 2⁻⁴ → 32766·2¹² exactly,
        // a 14-bit mantissa — representable, so the compare is exact
        assert_eq!(outs[0][0], 134_209_536.0);
        // channel 1 splits 129/129 between the +2¹² and −2⁻⁴ entries
        let expect = 129.0f64 * 127.0 * 4096.0
            - 129.0 * 127.0 * 0.0625;
        let got = outs[0][1] as f64;
        assert!((got - expect).abs() / expect < 1e-6,
                "{got} vs {expect}");
    }
}

