//! Minimal NHWC f32 host tensor for the inference engine.

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(dims: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len(),
                   "dims {dims:?} vs data len {}", data.len());
        Tensor { dims, data }
    }

    pub fn zeros(dims: Vec<usize>) -> Self {
        let n = dims.iter().product();
        Tensor { dims, data: vec![0.0; n] }
    }

    pub fn elems(&self) -> usize {
        self.data.len()
    }

    /// NHWC accessor helpers (b, y, x, c)
    #[inline]
    pub fn at4(&self, b: usize, y: usize, x: usize, c: usize) -> f32 {
        let (_, h, w, ch) =
            (self.dims[0], self.dims[1], self.dims[2], self.dims[3]);
        self.data[((b * h + y) * w + x) * ch + c]
    }

    #[inline]
    pub fn set4(&mut self, b: usize, y: usize, x: usize, c: usize, v: f32) {
        let (_, h, w, ch) =
            (self.dims[0], self.dims[1], self.dims[2], self.dims[3]);
        self.data[((b * h + y) * w + x) * ch + c] = v;
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn at_set_roundtrip() {
        let mut t = Tensor::zeros(vec![2, 3, 3, 4]);
        t.set4(1, 2, 0, 3, 7.5);
        assert_eq!(t.at4(1, 2, 0, 3), 7.5);
        assert_eq!(t.at4(0, 0, 0, 0), 0.0);
    }

    #[test]
    #[should_panic]
    fn bad_dims_panics() {
        Tensor::new(vec![2, 2], vec![0.0; 3]);
    }

    #[test]
    fn max_abs() {
        let t = Tensor::new(vec![3], vec![-5.0, 1.0, 2.0]);
        assert_eq!(t.max_abs(), 5.0);
    }
}
