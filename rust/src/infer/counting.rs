//! Exact arithmetic-operation accounting for the inference engine — the
//! measurement side of the paper's "K multiplications instead of I" and
//! "fully multiplier-less" claims.

#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct OpCounts {
    pub mults: u64,
    pub shifts: u64,
    pub adds: u64,
    /// table lookups (dictionary reads) — free on real hardware, counted
    /// for completeness
    pub lookups: u64,
}

impl OpCounts {
    pub fn add(&mut self, other: OpCounts) {
        self.mults += other.mults;
        self.shifts += other.shifts;
        self.adds += other.adds;
        self.lookups += other.lookups;
    }

    pub fn total_arith(&self) -> u64 {
        self.mults + self.shifts + self.adds
    }

    /// The paper's multiplier-less predicate: zero float multiplies.
    pub fn is_multiplierless(&self) -> bool {
        self.mults == 0
    }
}

impl std::fmt::Display for OpCounts {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "mults={} shifts={} adds={} lookups={}",
            self.mults, self.shifts, self.adds, self.lookups
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulate() {
        let mut a = OpCounts { mults: 1, shifts: 2, adds: 3, lookups: 4 };
        a.add(OpCounts { mults: 10, shifts: 20, adds: 30, lookups: 40 });
        assert_eq!(a, OpCounts { mults: 11, shifts: 22, adds: 33,
                                 lookups: 44 });
        assert_eq!(a.total_arith(), 66);
        assert!(!a.is_multiplierless());
        assert!(OpCounts { mults: 0, shifts: 9, adds: 9, lookups: 0 }
            .is_multiplierless());
    }
}
