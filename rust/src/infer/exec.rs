//! Plan execution driver.
//!
//! The hot layer of the plan/execute split: cache-blocked im2col
//! convolution, the bucket-accumulate LUT matmul (K multiplications — or
//! shifts — per output accumulator instead of fan-in), and the elementwise
//! tail ops. The inner loops (dense dot, patch gather, bucket scatter,
//! K-term combine) live behind the [`Kernels`] backend trait
//! ([`super::kernels`]): the plan resolves a backend once at compile time
//! (scalar reference or runtime-dispatched SIMD) and this driver threads
//! it through every matmul-like step. Matmul-like steps are parallelized
//! across the batch dimension with `std::thread::scope`; every worker
//! gets disjoint slices of the preallocated [`Scratch`] arena, so the
//! kernels themselves never allocate. Single-threaded execution is fully
//! allocation-free; the parallel path's only per-call cost is spawning
//! scoped workers, and a work-size gate keeps small steps inline so that
//! overhead is only paid where it amortizes.
//!
//! Numerical contract: the **scalar** backend accumulates in exactly the
//! same term order as the reference implementations in [`super::ops`],
//! so its plan outputs are bit-identical to the legacy interpreter
//! (padding contributes exact-zero terms, which do not perturb IEEE-754
//! sums of the activations this engine sees). SIMD backends reorder the
//! same sums and match within the ulp-scaled tolerance documented in
//! [`super::kernels`]. The int backends quantize activations to the i8
//! grid per matmul and run the integer kernels (product-table gather /
//! shift-and-add / i16 dot) with a single f32 epilogue rescale — into
//! which the plan may fuse an immediately-following clipped ReLU
//! (`IntData::relu`), applied by the shared epilogue after the rescale
//! so it is bit-identical to the standalone `Step::Relu` it replaces.
//! They match scalar within the absolute quantization bound documented
//! in [`super::kernels`], and match *each other* (int-scalar vs the
//! vectorized int kernels) bit-exactly. Backend choice is per-plan, so
//! any two runs of one plan remain bit-identical to each other
//! regardless of threads or batch composition.

use crate::quant::pow2::Pow2;

use super::arena::Scratch;
use super::kernels::{IntEpilogue, Kernels};
use super::plan::{AffineStep, BnStep, ConvStep, IntBody, IntData, Kernel,
                  Plan, Step};
use super::tensor::Tensor;

/// Execute every step of `plan` over the batch in `x`, leaving the output
/// in the scratch arena's `cur` buffer. `scratch` must already be
/// provisioned via `Scratch::ensure`.
pub(crate) fn run_plan(plan: &Plan, x: &Tensor, s: &mut Scratch) {
    let b = x.dims[0];
    let threads = plan.threads();
    let kern = plan.kernels();
    let strides = Strides {
        patch: plan.patch_elems,
        bucket: plan.bucket_elems(),
        qpatch: plan.qpatch_elems(),
        ibucket: plan.ibucket_elems(),
    };
    let Scratch { cur, next, saves, patch, buckets, qpatch, ibuckets, .. } =
        s;
    cur[..x.data.len()].copy_from_slice(&x.data);

    for ps in &plan.steps {
        let n_in = b * ps.in_elems;
        let n_out = b * ps.out_elems;
        match &ps.step {
            Step::Conv(c) => {
                conv_batch(c, kern, &cur[..n_in], &mut next[..n_out],
                           patch, buckets, qpatch, ibuckets, b, threads,
                           &strides);
                std::mem::swap(cur, next);
            }
            Step::Affine(a) => {
                affine_batch(a, kern, &cur[..n_in], &mut next[..n_out],
                             buckets, qpatch, ibuckets, b, threads,
                             &strides);
                std::mem::swap(cur, next);
            }
            Step::Bn(bn) => batchnorm(bn, &mut cur[..n_in]),
            Step::Relu => relu(&mut cur[..n_in]),
            Step::ActQuant { bits } => act_quant(&mut cur[..n_in], *bits),
            Step::MaxPool { k, stride, in_h, in_w, c, out_h, out_w } => {
                maxpool(*k, *stride, *in_h, *in_w, *c, *out_h, *out_w,
                        &cur[..n_in], &mut next[..n_out], b);
                std::mem::swap(cur, next);
            }
            Step::Gap { in_h, in_w, c, shift } => {
                gap(*in_h, *in_w, *c, *shift, &cur[..n_in],
                    &mut next[..n_out], b);
                std::mem::swap(cur, next);
            }
            // packed batch-major layout: flatten is pure bookkeeping
            Step::Flatten => {}
            Step::Save { slot } => {
                saves[*slot][..n_in].copy_from_slice(&cur[..n_in]);
            }
            Step::Add { slot, proj } => match proj {
                Some(c) => {
                    let pin = b * c.in_h * c.in_w * c.cin;
                    conv_batch(c, kern, &saves[*slot][..pin],
                               &mut next[..n_out], patch, buckets, qpatch,
                               ibuckets, b, threads, &strides);
                    add_into(&mut cur[..n_out], &next[..n_out]);
                }
                None => add_into(&mut cur[..n_out], &saves[*slot][..n_out]),
            },
        }
    }
}

// ------------------------------------------------------------------ conv

/// Per-worker chunk sizes of the arena's scratch areas (the integer
/// strides are 0 for float backends, so their splits are no-ops).
#[derive(Clone, Copy)]
struct Strides {
    patch: usize,
    bucket: usize,
    qpatch: usize,
    ibucket: usize,
}

#[allow(clippy::too_many_arguments)]
fn conv_batch(c: &ConvStep, kern: &dyn Kernels, xin: &[f32],
              out: &mut [f32], patch: &mut [f32], buckets: &mut [f32],
              qpatch: &mut [i16], ibuckets: &mut [i32], b: usize,
              threads: usize, strides: &Strides) {
    let in_e = c.in_h * c.in_w * c.cin;
    let out_e = c.out_h * c.out_w * c.cout;
    let work = b * out_e * c.fan();
    par_samples(
        b, workers(threads, b, work), xin, in_e, out, out_e, patch,
        buckets, qpatch, ibuckets, strides,
        |x, o, p, bk, qp, ibk| conv_sample(c, kern, x, o, p, bk, qp, ibk),
    );
}

/// One sample: im2col a block of output rows into `patch`, then run the
/// backend kernel over the packed patches — all `cout` accumulators per
/// patch position in one call, so the backend can tile output channels
/// over its bucket area. The block height is chosen at compile time so
/// the patch area stays cache-resident. Steps carrying `IntData`
/// quantize the whole patch block once, then run the integer kernels.
#[allow(clippy::too_many_arguments)]
fn conv_sample(c: &ConvStep, kern: &dyn Kernels, x: &[f32],
               out: &mut [f32], patch: &mut [f32], buckets: &mut [f32],
               qpatch: &mut [i16], ibuckets: &mut [i32]) {
    let fan = c.kh * c.kw * c.cin;
    let mut oy0 = 0;
    while oy0 < c.out_h {
        let rows = c.block_rows.min(c.out_h - oy0);
        let npos = rows * c.out_w;
        for r in 0..rows {
            let oy = oy0 + r;
            for ox in 0..c.out_w {
                kern.im2col(c, x, oy, ox,
                            &mut patch[(r * c.out_w + ox) * fan..][..fan]);
            }
        }
        let out_base = oy0 * c.out_w * c.cout;
        if let Some(int) = &c.int_data {
            kern.quantize_row(&patch[..npos * fan], int.inv_act_scale,
                              &mut qpatch[..npos * fan]);
            for p in 0..npos {
                int_rows(kern, int, &c.kernel, &qpatch[p * fan..][..fan],
                         ibuckets,
                         &mut out[out_base + p * c.cout..][..c.cout]);
            }
            oy0 += rows;
            continue;
        }
        match &c.kernel {
            Kernel::Dense(wt) => {
                for p in 0..npos {
                    kern.dense_rows(
                        &patch[p * fan..][..fan], wt, None,
                        &mut out[out_base + p * c.cout..][..c.cout]);
                }
            }
            Kernel::Lut { dict, assign } => {
                for p in 0..npos {
                    kern.lut_rows(
                        &patch[p * fan..][..fan], assign, dict, None,
                        buckets,
                        &mut out[out_base + p * c.cout..][..c.cout]);
                }
            }
            Kernel::Shift { dict, dict_f32, assign } => {
                for p in 0..npos {
                    kern.shift_rows(
                        &patch[p * fan..][..fan], assign, dict, dict_f32,
                        None, buckets,
                        &mut out[out_base + p * c.cout..][..c.cout]);
                }
            }
        }
        oy0 += rows;
    }
}

/// Dispatch one quantized row through the integer kernel matching the
/// step's float kernel (the plan builds `IntBody` from the same
/// variant, so the pairing is structural).
fn int_rows(kern: &dyn Kernels, int: &IntData, kernel: &Kernel,
            q: &[i16], ibuckets: &mut [i32], out: &mut [f32]) {
    let epi = IntEpilogue { scale: &int.scale,
                            bias: int.bias.as_deref(),
                            relu: int.relu };
    match (&int.body, kernel) {
        (IntBody::Dense(wq), Kernel::Dense(_)) => {
            kern.int_dense_rows(q, wq, &epi, out);
        }
        (IntBody::Table(table), Kernel::Lut { assign, .. }) => {
            kern.int_lut_rows(q, assign, table, &epi, out);
        }
        (IntBody::Shift(shifts), Kernel::Shift { assign, .. }) => {
            kern.int_shift_rows(q, assign, shifts, ibuckets, &epi, out);
        }
        _ => unreachable!("IntBody always mirrors its Kernel variant"),
    }
}

// ---------------------------------------------------------------- affine

#[allow(clippy::too_many_arguments)]
fn affine_batch(a: &AffineStep, kern: &dyn Kernels, xin: &[f32],
                out: &mut [f32], buckets: &mut [f32], qpatch: &mut [i16],
                ibuckets: &mut [i32], b: usize, threads: usize,
                strides: &Strides) {
    let work = b * a.cout * a.cin;
    let strides = Strides { patch: 0, ..*strides };
    par_samples(
        b, workers(threads, b, work), xin, a.cin, out, a.cout, &mut [],
        buckets, qpatch, ibuckets, &strides,
        |x, o, _p, bk, qp, ibk| affine_sample(a, kern, x, o, bk, qp, ibk),
    );
}

fn affine_sample(a: &AffineStep, kern: &dyn Kernels, x: &[f32],
                 out: &mut [f32], buckets: &mut [f32],
                 qpatch: &mut [i16], ibuckets: &mut [i32]) {
    if let Some(int) = &a.int_data {
        kern.quantize_row(x, int.inv_act_scale, &mut qpatch[..a.cin]);
        int_rows(kern, int, &a.kernel, &qpatch[..a.cin], ibuckets, out);
        return;
    }
    match &a.kernel {
        Kernel::Dense(wt) => {
            kern.dense_rows(x, wt, Some(&a.bias), out);
        }
        Kernel::Lut { dict, assign } => {
            kern.lut_rows(x, assign, dict, Some(&a.bias), buckets, out);
        }
        Kernel::Shift { dict, dict_f32, assign } => {
            kern.shift_rows(x, assign, dict, dict_f32, Some(&a.bias),
                            buckets, out);
        }
    }
}

// ----------------------------------------------------- elementwise tail

fn batchnorm(bn: &BnStep, buf: &mut [f32]) {
    let c = bn.scale.len();
    match &bn.shifts {
        Some(sh) => {
            for row in buf.chunks_exact_mut(c) {
                for (ci, v) in row.iter_mut().enumerate() {
                    *v = sh[ci].apply(*v) + bn.bias[ci];
                }
            }
        }
        None => {
            for row in buf.chunks_exact_mut(c) {
                for (ci, v) in row.iter_mut().enumerate() {
                    *v = bn.scale[ci] * *v + bn.bias[ci];
                }
            }
        }
    }
}

fn relu(buf: &mut [f32]) {
    for v in buf {
        *v = v.max(0.0);
    }
}

/// Per-tensor (whole batch, matching the reference) max-abs fake-quant.
fn act_quant(buf: &mut [f32], bits: usize) {
    if bits == 0 {
        return;
    }
    let max_abs = buf.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
    let scale = (max_abs / ((1 << (bits - 1)) - 1) as f32).max(1e-12);
    let lo = -((1 << (bits - 1)) as f32);
    let hi = ((1 << (bits - 1)) - 1) as f32;
    for v in buf {
        *v = (*v / scale).round().clamp(lo, hi) * scale;
    }
}

#[allow(clippy::too_many_arguments)]
fn maxpool(k: usize, stride: usize, h: usize, w: usize, c: usize,
           oh: usize, ow: usize, xin: &[f32], out: &mut [f32], b: usize) {
    let in_e = h * w * c;
    let out_e = oh * ow * c;
    for bi in 0..b {
        let x = &xin[bi * in_e..][..in_e];
        let o = &mut out[bi * out_e..][..out_e];
        for oy in 0..oh {
            for ox in 0..ow {
                for ci in 0..c {
                    let mut m = f32::NEG_INFINITY;
                    for ky in 0..k {
                        for kx in 0..k {
                            m = m.max(x[((oy * stride + ky) * w
                                + (ox * stride + kx)) * c + ci]);
                        }
                    }
                    o[(oy * ow + ox) * c + ci] = m;
                }
            }
        }
    }
}

fn gap(h: usize, w: usize, c: usize, shift: Option<Pow2>, xin: &[f32],
       out: &mut [f32], b: usize) {
    let in_e = h * w * c;
    let hw = (h * w) as f32;
    for bi in 0..b {
        let x = &xin[bi * in_e..][..in_e];
        let o = &mut out[bi * c..][..c];
        for (ci, ov) in o.iter_mut().enumerate() {
            let mut s = 0f32;
            for y in 0..h {
                for xx in 0..w {
                    s += x[(y * w + xx) * c + ci];
                }
            }
            *ov = match shift {
                Some(p) => p.apply(s),
                None => s / hw,
            };
        }
    }
}

fn add_into(acc: &mut [f32], other: &[f32]) {
    for (a, &o) in acc.iter_mut().zip(other) {
        *a += o;
    }
}

// ------------------------------------------------- batch-parallel driver

/// Minimum accumulate-ops per worker before spawning threads is worth the
/// scoped-spawn overhead; smaller steps run inline.
const PAR_MIN_WORK_PER_WORKER: usize = 1 << 16;

/// Worker count for a step of the given total work: capped by the batch
/// (samples are the parallel unit) and gated so each worker has enough
/// work to amortize its spawn.
fn workers(threads: usize, b: usize, work: usize) -> usize {
    threads
        .min(b)
        .min((work / PAR_MIN_WORK_PER_WORKER).max(1))
        .max(1)
}

/// Run `f(sample_in, sample_out, patch, buckets, qpatch, ibuckets)` for
/// every sample, splitting the batch over up to `threads` scoped
/// workers. Each worker owns a disjoint stride-sized chunk of every
/// arena area, so the parallel path allocates nothing and results are
/// bit-identical to sequential execution (samples are independent).
#[allow(clippy::too_many_arguments)]
fn par_samples<F>(b: usize, threads: usize, xin: &[f32], in_e: usize,
                  out: &mut [f32], out_e: usize, patch: &mut [f32],
                  buckets: &mut [f32], qpatch: &mut [i16],
                  ibuckets: &mut [i32], strides: &Strides, f: F)
where
    F: Fn(&[f32], &mut [f32], &mut [f32], &mut [f32], &mut [i16],
          &mut [i32]) + Sync,
{
    let nw = threads.min(b).max(1);
    if nw == 1 {
        let p = &mut patch[..strides.patch];
        let bk = &mut buckets[..strides.bucket];
        let qp = &mut qpatch[..strides.qpatch];
        let ibk = &mut ibuckets[..strides.ibucket];
        for bi in 0..b {
            f(&xin[bi * in_e..][..in_e], &mut out[bi * out_e..][..out_e],
              &mut p[..], &mut bk[..], &mut qp[..], &mut ibk[..]);
        }
        return;
    }
    let fref = &f;
    std::thread::scope(|sc| {
        let mut out_rest = out;
        let mut patch_rest = patch;
        let mut buck_rest = buckets;
        let mut qpatch_rest = qpatch;
        let mut ibuck_rest = ibuckets;
        for w in 0..nw {
            let lo = b * w / nw;
            let hi = b * (w + 1) / nw;
            let (o, orest) =
                std::mem::take(&mut out_rest).split_at_mut((hi - lo) * out_e);
            out_rest = orest;
            let (p, prest) =
                std::mem::take(&mut patch_rest).split_at_mut(strides.patch);
            patch_rest = prest;
            let (bk, brest) =
                std::mem::take(&mut buck_rest).split_at_mut(strides.bucket);
            buck_rest = brest;
            let (qp, qrest) = std::mem::take(&mut qpatch_rest)
                .split_at_mut(strides.qpatch);
            qpatch_rest = qrest;
            let (ibk, irest) = std::mem::take(&mut ibuck_rest)
                .split_at_mut(strides.ibucket);
            ibuck_rest = irest;
            let xs = &xin[lo * in_e..hi * in_e];
            sc.spawn(move || {
                for i in 0..(hi - lo) {
                    fref(&xs[i * in_e..][..in_e],
                         &mut o[i * out_e..][..out_e], &mut p[..],
                         &mut bk[..], &mut qp[..], &mut ibk[..]);
                }
            });
        }
    });
}
