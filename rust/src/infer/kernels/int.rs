//! Multiplier-less integer backend — the scalar reference
//! (`int-scalar`).
//!
//! The vectorized integer backends in [`super::int_simd`] must match
//! this implementation **bit-exactly**; any change here is a change to
//! the integer semantics, not just a speed tweak.
//!
//! Runs every matmul on the i8 grid planned at compile time (see
//! `plan::IntData`): activations are quantized once per im2col block /
//! input row to `round(x / s_act)` clamped to ±127 and stored as i16,
//! then the inner loops are pure integer arithmetic —
//!
//! * **LUT layers** gather from a per-layer product table
//!   `table[k][q] = dict_q[k] * q` ([`ACT_LEVELS`] i16 entries per
//!   dictionary index), accumulating in i32: one lookup + add per
//!   weight, zero multiplies.
//! * **Shift layers** bucket-accumulate the quantized activations per
//!   dictionary index in i32, then combine with `±(bucket << sh)` —
//!   the paper's shift-and-add realized on integers, no table needed.
//! * **Dense weights** are quantized to the same i8 grid and run as an
//!   i16×i16→i32 dot (the i16 operands are what lets the
//!   autovectorizer pair lanes into widening multiply-adds).
//!
//! The single float multiply per output is the epilogue rescale
//! `acc as f32 * scale[oc] (+ bias[oc])`, into which plan compilation
//! folds an immediately-following multiplier-less BN shift. The trait's
//! f32 matmul entry points delegate to the scalar reference: under the
//! int backend every conv/affine step carries `IntData` (built
//! unconditionally at compile), so the executor never reaches them —
//! delegation keeps any future float-path caller correct rather than
//! aborting.

use crate::quant::pow2::Pow2;

use super::super::plan::ConvStep;
use super::scalar::ScalarKernels;
use super::{gather_with, IntEpilogue, IntShift, Kernels};

/// Slots per product-table row: one per i8 activation level. Quantized
/// activations live in ±127 and index the row at `q + 128`, so entry 0
/// (level −128) is populated but never addressed.
pub(crate) const ACT_LEVELS: usize = 256;

/// The scalar quantize step, shared by every integer backend's
/// remainder tail so the vectorized paths stay bit-identical: NaN casts
/// to 0 and ±inf clamp to ±127, exactly like the saturating `as i16`.
#[inline(always)]
pub(crate) fn quantize_one(v: f32, inv_scale: f32) -> i16 {
    (v * inv_scale).round().clamp(-127.0, 127.0) as i16
}

pub(crate) struct IntKernels;

impl Kernels for IntKernels {
    fn name(&self) -> &'static str {
        "int-scalar"
    }

    fn dense_rows(&self, x: &[f32], w: &[f32], bias: Option<&[f32]>,
                  out: &mut [f32]) {
        ScalarKernels.dense_rows(x, w, bias, out);
    }

    fn lut_rows(&self, x: &[f32], assign: &[u32], dict: &[f32],
                bias: Option<&[f32]>, buckets: &mut [f32],
                out: &mut [f32]) {
        ScalarKernels.lut_rows(x, assign, dict, bias, buckets, out);
    }

    fn shift_rows(&self, x: &[f32], assign: &[u32], dict: &[Pow2],
                  dict_f32: &[f32], bias: Option<&[f32]>,
                  buckets: &mut [f32], out: &mut [f32]) {
        ScalarKernels.shift_rows(x, assign, dict, dict_f32, bias, buckets,
                                 out);
    }

    fn im2col(&self, c: &ConvStep, x: &[f32], oy: usize, ox: usize,
              dst: &mut [f32]) {
        gather_with(c, x, oy, ox, dst, |s, d| d.copy_from_slice(s),
                    |d| d.fill(0.0));
    }

    fn uses_int_scratch(&self) -> bool {
        true
    }

    fn quantize_row(&self, x: &[f32], inv_scale: f32, q: &mut [i16]) {
        for (v, qv) in x.iter().zip(q.iter_mut()) {
            *qv = quantize_one(*v, inv_scale);
        }
    }

    fn int_dense_rows(&self, q: &[i16], wq: &[i16], epi: &IntEpilogue,
                      out: &mut [f32]) {
        let fan = q.len();
        for (r, ov) in out.iter_mut().enumerate() {
            let mut acc = 0i32;
            for (a, b) in q.iter().zip(&wq[r * fan..][..fan]) {
                acc += *a as i32 * *b as i32;
            }
            *ov = epi.apply(acc as i64, r);
        }
    }

    fn int_lut_rows(&self, q: &[i16], assign: &[u32], table: &[i16],
                    epi: &IntEpilogue, out: &mut [f32]) {
        let fan = q.len();
        for (r, ov) in out.iter_mut().enumerate() {
            let mut acc = 0i32;
            for (qv, &a) in q.iter().zip(&assign[r * fan..][..fan]) {
                acc += table[a as usize * ACT_LEVELS
                    + (*qv + 128) as usize] as i32;
            }
            *ov = epi.apply(acc as i64, r);
        }
    }

    fn int_shift_rows(&self, q: &[i16], assign: &[u32],
                      shifts: &[IntShift], ibuckets: &mut [i32],
                      epi: &IntEpilogue, out: &mut [f32]) {
        let fan = q.len();
        let bk = &mut ibuckets[..shifts.len()];
        for (r, ov) in out.iter_mut().enumerate() {
            bk.fill(0);
            for (qv, &a) in q.iter().zip(&assign[r * fan..][..fan]) {
                bk[a as usize] += *qv as i32;
            }
            // Combine in i64: plan compile caps each shifted term at
            // i32 (`fan·127·2^span <= i32::MAX`), but the trait itself
            // makes no such promise and an i32 `<<` wraps silently —
            // see `int_shift_combine_boundary_no_overflow`.
            let mut acc = 0i64;
            for (s, b) in shifts.iter().zip(bk.iter()) {
                if s.zero {
                    continue;
                }
                let t = (*b as i64) << s.sh;
                acc += if s.neg { -t } else { t };
            }
            *ov = epi.apply(acc, r);
        }
    }
}
