//! Pluggable inner-loop kernel backends for plan execution.
//!
//! The paper's deployment trick — bucket-accumulate activations per
//! dictionary index, then K multiplies (or K bit-shifts) combine the
//! buckets — lives in a handful of inner loops: the dense dot, the
//! im2col patch gather, the bucket scatter and the K-term dictionary
//! combine. This module puts those loops behind the [`Kernels`] trait so
//! the executor can swap implementations without touching the plan or
//! the arena:
//!
//! * [`scalar`] — the reference backend. Bit-identical to the original
//!   free functions in `exec.rs` (and therefore to the single-op
//!   reference kernels in [`crate::infer::ops`]).
//! * [`simd`] — the fast float backend. On x86-64 it uses AVX2/FMA
//!   intrinsics selected by `is_x86_feature_detected!` at plan compile
//!   time; on other targets (e.g. aarch64) it falls back to a portable
//!   chunked-accumulator formulation the autovectorizer maps onto the
//!   native vector unit.
//! * [`int`] — the multiplier-less integer **reference** backend
//!   (`int-scalar`). Activations are quantized to the i8 grid at
//!   compile-calibrated scales and every matmul runs on integers: LUT
//!   layers gather from a precomputed `dict[k] × act_level[q]` product
//!   table, pow-2 shift dictionaries degenerate to integer
//!   shift-and-add (no table), dense weights run as an i16×i16→i32
//!   dot. The only float multiply left is the final epilogue rescale.
//! * [`int_simd`] — the vectorized integer backends behind the same
//!   trait surface: `int-avx2` (i16×i16 `_mm256_madd_epi16` dense
//!   dots, 4-row unrolled product-table gathers, lane-wide bucket
//!   accumulation, vectorized f32→i16 quantize) when the host has
//!   AVX2, `int-portable` (chunked accumulators the autovectorizer
//!   can map) elsewhere.
//!
//! Selection happens **once**, at [`Plan::compile`](super::Plan::compile):
//! [`PlanOptions::kernel`](super::PlanOptions) picks `Auto` (the
//! default), `Scalar`, `Simd`, `Int` or `IntScalar`; `Auto` honours the
//! `LUTQ_KERNEL` environment override (`scalar` | `simd` | `int` |
//! `int-scalar`) so `lutq serve-bench` and CI can A/B the backends
//! without recompiling, and otherwise prefers the best SIMD
//! implementation for the host. `Int` auto-upgrades to the best
//! vectorized integer implementation (`int-avx2` when
//! `is_x86_feature_detected!("avx2")`, `int-portable` otherwise);
//! `IntScalar` / `LUTQ_KERNEL=int-scalar` pins the integer reference.
//!
//! ## Tolerance policy
//!
//! The scalar backend accumulates in exactly the reference term order, so
//! its outputs are bit-identical to the legacy interpreter. The SIMD
//! backends sum the *same terms* in lane-parallel order (and contract
//! multiply-adds through FMA), so their outputs agree with scalar only
//! within an ulp-scaled tolerance: for an accumulation of `n` terms of
//! total magnitude `S`, parity tests allow `~8 * n * EPSILON * S`.
//! Anything needing bit-exact reproducibility (the ops-parity unit
//! tests, golden-output comparisons) pins `KernelBackend::Scalar`;
//! serving correctness tests compare served-vs-direct outputs under the
//! *same* backend, which stays bit-exact because backend selection is
//! per-plan, not per-call. Shift kernels in SIMD realize the pow-2
//! dictionary as exact power-of-two f32 multiplies (equal to
//! `Pow2::apply` for every finite input); op accounting is computed at
//! compile time from the plan and is unaffected by backend choice.
//!
//! The **int** backend is different in kind: it introduces real
//! quantization error, not reordering error. For a layer with fan-in
//! `n`, activation scale `s_a = act_absmax / 127` and dictionary/weight
//! scale `s_d = dict_absmax / 127`, each term carries at most half a
//! quantization step from each operand, so outputs agree with the
//! scalar reference within the absolute bound
//!
//! ```text
//! |err| <= n/2 * (s_a * dict_absmax + s_d * act_absmax) + n/4 * s_a * s_d
//! ```
//!
//! (parity tests apply a small safety factor for the f32 reference's own
//! rounding). Two cases are *exact*: when every activation lies on the
//! i8 grid (integer-valued inputs with `act_absmax = 127`) and the
//! dictionary is pure pow-2, both paths compute the same dyadic rational
//! and the int backend is bit-identical to scalar — covered by
//! exact-match tests in `tests/kernel_parity.rs`.
//!
//! **Between integer backends** the policy is stricter: `int-avx2` and
//! `int-portable` must be **bit-identical** to `int-scalar`, not within
//! any tolerance. Integer accumulation is associative, so lane/tile
//! reordering cannot change the i32/i64 sums, and every backend applies
//! the identical scalar epilogue expression
//! `acc as f32 * scale[r] + bias[r]` (never contracted through FMA).
//! The vectorized `quantize_row` reproduces scalar
//! `(v * inv_scale).round()` semantics exactly, including
//! round-half-away-from-zero ties, NaN→0 and ±inf→±127 saturation
//! (non-finite inputs are additionally rejected at the serve boundary —
//! see `serve::SubmitError::BadInput`). Parity proptests below and in
//! `tests/kernel_parity.rs` assert `==`, not closeness.
//!
//! ## Integer overflow headroom
//!
//! Plan compilation admits a shift dictionary only when
//! `fan · 127 · 2^span <= i32::MAX` (span = max − min exponent), so
//! each i32 *bucket* (≤ fan·127) and each individual shifted term fit
//! i32. Partial sums of several shifted terms can still exceed i32 at
//! the admitted boundary (e.g. two terms of `fan·127·2^span` each), so
//! the K-term combine runs in **i64** and the epilogue takes an i64
//! accumulator; `int_shift_combine_boundary_no_overflow` pins the
//! exact compile-accepted boundary in every integer backend.

pub(crate) mod int;
pub(crate) mod int_simd;
pub(crate) mod scalar;
pub(crate) mod simd;

use anyhow::{bail, Result};

use crate::quant::pow2::Pow2;

use super::plan::ConvStep;

/// Output channels processed per pass over an input patch by the LUT
/// bucket scatter: the patch row streams once per tile while each
/// channel keeps its own bucket row (the arena provisions
/// `OC_TILE * k_max` bucket slots per worker).
pub(crate) const OC_TILE: usize = 4;

/// User-facing backend choice (see [`super::PlanOptions::kernel`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KernelBackend {
    /// `LUTQ_KERNEL` env override if set, otherwise the best SIMD
    /// implementation for this host.
    #[default]
    Auto,
    /// Reference backend, bit-identical to the legacy interpreter.
    Scalar,
    /// AVX2/FMA on x86-64 (runtime-detected), portable chunked
    /// accumulators elsewhere.
    Simd,
    /// Multiplier-less integer backend: i8-quantized activations,
    /// product-table / shift-and-add matmuls, integer accumulation.
    /// Auto-upgrades to the vectorized implementation (AVX2 when
    /// detected, portable chunked elsewhere).
    Int,
    /// The scalar integer reference — pins `int-scalar` so parity
    /// tests and CI can A/B it against the vectorized int path.
    IntScalar,
}

impl std::str::FromStr for KernelBackend {
    type Err = String;

    fn from_str(s: &str) -> std::result::Result<KernelBackend, String> {
        match s {
            "auto" => Ok(KernelBackend::Auto),
            "scalar" => Ok(KernelBackend::Scalar),
            "simd" => Ok(KernelBackend::Simd),
            "int" => Ok(KernelBackend::Int),
            "int-scalar" => Ok(KernelBackend::IntScalar),
            other => Err(format!(
                "unknown kernel backend `{other}` (expected auto | \
                 scalar | simd | int | int-scalar)"
            )),
        }
    }
}

/// A concrete backend picked for one compiled plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Resolved {
    Scalar,
    SimdAvx2,
    SimdPortable,
    IntScalar,
    IntAvx2,
    IntPortable,
}

impl Resolved {
    pub(crate) fn name(self) -> &'static str {
        match self {
            Resolved::Scalar => "scalar",
            Resolved::SimdAvx2 => "simd-avx2",
            Resolved::SimdPortable => "simd-portable",
            Resolved::IntScalar => "int-scalar",
            Resolved::IntAvx2 => "int-avx2",
            Resolved::IntPortable => "int-portable",
        }
    }

    /// True for the integer backends: plan compilation then lowers every
    /// matmul to `IntData` and the arena provisions integer scratch.
    pub(crate) fn is_int(self) -> bool {
        matches!(self,
                 Resolved::IntScalar | Resolved::IntAvx2
                 | Resolved::IntPortable)
    }

    pub(crate) fn kernels(self) -> &'static dyn Kernels {
        match self {
            Resolved::Scalar => &scalar::ScalarKernels,
            Resolved::SimdPortable => &simd::PortableKernels,
            Resolved::IntScalar => &int::IntKernels,
            Resolved::IntPortable => &int_simd::IntPortableKernels,
            #[cfg(target_arch = "x86_64")]
            Resolved::SimdAvx2 => &simd::x86::Avx2Kernels,
            #[cfg(target_arch = "x86_64")]
            Resolved::IntAvx2 => &int_simd::x86::IntAvx2Kernels,
            // The Avx2 variants are only ever constructed on x86-64;
            // keep the match total for other targets anyway.
            #[cfg(not(target_arch = "x86_64"))]
            Resolved::SimdAvx2 => &simd::PortableKernels,
            #[cfg(not(target_arch = "x86_64"))]
            Resolved::IntAvx2 => &int_simd::IntPortableKernels,
        }
    }
}

/// Best SIMD implementation available on this host.
fn best_simd() -> Resolved {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2")
            && is_x86_feature_detected!("fma")
        {
            return Resolved::SimdAvx2;
        }
    }
    Resolved::SimdPortable
}

/// Best vectorized integer implementation available on this host. The
/// AVX2 int kernels use only AVX2 integer ops (no FMA), so FMA is not
/// required — and must not be: the epilogue is a scalar expression
/// shared with `int-scalar` for bit-exactness.
fn best_int() -> Resolved {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            return Resolved::IntAvx2;
        }
    }
    Resolved::IntPortable
}

/// Resolve a [`KernelBackend`] choice to a concrete backend. `Auto`
/// honours the `LUTQ_KERNEL` env override; a malformed override is a
/// compile error, not a silent fallback.
pub(crate) fn resolve(choice: KernelBackend) -> Result<Resolved> {
    let choice = match choice {
        KernelBackend::Auto => match std::env::var("LUTQ_KERNEL") {
            Ok(v) => match v.parse::<KernelBackend>() {
                Ok(c) => c,
                Err(e) => bail!("LUTQ_KERNEL: {e}"),
            },
            Err(_) => KernelBackend::Simd,
        },
        pinned => pinned,
    };
    Ok(match choice {
        KernelBackend::Scalar => Resolved::Scalar,
        KernelBackend::Int => best_int(),
        KernelBackend::IntScalar => Resolved::IntScalar,
        KernelBackend::Auto | KernelBackend::Simd => best_simd(),
    })
}

/// One pow-2 dictionary entry lowered to an integer shift for the int
/// backend's combine: `acc += ±(bucket << sh)`. Shifts are relative to
/// the plan's `2^e_min` dictionary scale, so they are always left
/// shifts. Plan compile validates that each shifted *term* fits i32;
/// the K-term combine itself runs in i64 (see the module docs on
/// overflow headroom).
#[derive(Debug, Clone, Copy)]
pub(crate) struct IntShift {
    /// dictionary entry is exactly zero (contributes nothing)
    pub zero: bool,
    /// negated entry: subtract the shifted bucket
    pub neg: bool,
    /// left-shift amount (`exp - e_min`)
    pub sh: u8,
}

/// Final-rescale constants of one integer matmul: the only float math
/// left after integer accumulation. `scale[r]` is the per-output-channel
/// `int → f32` rescale (activation scale × dictionary/weight scale,
/// with a folded multiplier-less-BN shift absorbed when present); when
/// `relu` is set a clipped-ReLU epilogue is fused after the rescale so
/// activations never round-trip through a separate float pass.
///
/// `apply` is the single shared epilogue expression for *every* integer
/// backend — a plain scalar `as f32 * scale + bias` (no FMA
/// contraction) so `int-avx2`/`int-portable` stay bit-identical to
/// `int-scalar`. The accumulator is i64: dense/LUT paths accumulate in
/// i32 (bounded by `fan·127·127`) and widen at the call, the shift
/// combine is natively i64.
pub(crate) struct IntEpilogue<'a> {
    pub scale: &'a [f32],
    pub bias: Option<&'a [f32]>,
    pub relu: bool,
}

impl IntEpilogue<'_> {
    #[inline(always)]
    pub(crate) fn apply(&self, acc: i64, r: usize) -> f32 {
        let b = match self.bias {
            Some(b) => b[r],
            None => 0.0,
        };
        let y = acc as f32 * self.scale[r] + b;
        if self.relu { y.max(0.0) } else { y }
    }
}

/// The inner-loop surface of plan execution. One `&'static` instance per
/// backend; every method is allocation-free and safe to call from the
/// batch-parallel workers (implementations are stateless).
///
/// Contracts shared by all methods: `x` is one input row (`fan` elems);
/// `out` is `rows` output accumulators; weight/assignment rows are
/// output-channel-major (`[rows][fan]`, row-contiguous); `bias[r]` seeds
/// accumulator `r` when present (otherwise 0.0); `buckets` holds at
/// least `OC_TILE * dict.len()` scratch slots; every assignment index is
/// `< dict.len()` (validated at plan compile).
pub(crate) trait Kernels: Sync {
    /// Backend name, surfaced in `ModelReport` and bench rows.
    fn name(&self) -> &'static str;

    /// Dense rows: `out[r] = bias[r] + dot(x, w[r])`.
    fn dense_rows(&self, x: &[f32], w: &[f32], bias: Option<&[f32]>,
                  out: &mut [f32]);

    /// LUT rows: bucket-accumulate `x` per dictionary index, then the
    /// K-term combine `out[r] = bias[r] + sum_k dict[k] * bucket[r][k]`.
    fn lut_rows(&self, x: &[f32], assign: &[u32], dict: &[f32],
                bias: Option<&[f32]>, buckets: &mut [f32],
                out: &mut [f32]);

    /// Shift rows: like [`Kernels::lut_rows`] but the combine applies a
    /// pow-2 dictionary (bit-shifts on the scalar backend; `dict_f32`
    /// is the plan's precomputed exact f32 view for SIMD combines).
    #[allow(clippy::too_many_arguments)]
    fn shift_rows(&self, x: &[f32], assign: &[u32], dict: &[Pow2],
                  dict_f32: &[f32], bias: Option<&[f32]>,
                  buckets: &mut [f32], out: &mut [f32]);

    /// Gather one zero-padded im2col receptive field in (ky, kx, ci)
    /// order — the reference conv's accumulation order.
    fn im2col(&self, c: &ConvStep, x: &[f32], oy: usize, ox: usize,
              dst: &mut [f32]);

    // ---- integer extensions (overridden only by the int backend; the
    // executor calls them solely for steps carrying `IntData`, which
    // plan compilation builds only under `Resolved::Int`) ----

    /// True when this backend runs the integer hot path; such plans
    /// make the arena provision per-worker quantized-activation and
    /// i32 bucket scratch.
    fn uses_int_scratch(&self) -> bool {
        false
    }

    /// Quantize one f32 row onto the i8 grid — `round(x * inv_scale)`
    /// clamped to ±127 — widened to i16 for the integer kernels. All
    /// integer backends reproduce the scalar semantics bit-exactly,
    /// including round-half-away-from-zero ties and the saturating
    /// casts NaN→0 / ±inf→±127 (non-finite inputs are rejected
    /// upstream at the serve boundary; the kernel contract still pins
    /// what the cast does if one arrives).
    fn quantize_row(&self, _x: &[f32], _inv_scale: f32, _q: &mut [i16]) {
        unreachable!("quantize_row called on float backend {}", self.name())
    }

    /// Integer dense rows: i16×i16→i32 dot over i8-grid weights, then
    /// the f32 epilogue rescale.
    fn int_dense_rows(&self, _q: &[i16], _wq: &[i16], _epi: &IntEpilogue,
                      _out: &mut [f32]) {
        unreachable!("int_dense_rows called on float backend {}",
                     self.name())
    }

    /// Product-table rows: per-weight gather from the K×`ACT_LEVELS`
    /// i16 table `dict_q[k] * q`, i32 accumulate, f32 epilogue. No
    /// multiplies at all.
    fn int_lut_rows(&self, _q: &[i16], _assign: &[u32], _table: &[i16],
                    _epi: &IntEpilogue, _out: &mut [f32]) {
        unreachable!("int_lut_rows called on float backend {}", self.name())
    }

    /// Shift rows: bucket-accumulate quantized activations per
    /// dictionary index in i32, then combine with `±(bucket << sh)` in
    /// i64 — no table, no multiplies. `ibuckets` holds at least
    /// `OC_TILE * shifts.len()` slots (the vectorized backends keep one
    /// bucket row per tiled output channel).
    #[allow(clippy::too_many_arguments)]
    fn int_shift_rows(&self, _q: &[i16], _assign: &[u32],
                      _shifts: &[IntShift], _ibuckets: &mut [i32],
                      _epi: &IntEpilogue, _out: &mut [f32]) {
        unreachable!("int_shift_rows called on float backend {}",
                     self.name())
    }
}

/// Shared im2col geometry: walks the padded receptive field and delegates
/// the contiguous row copies / pad fills to the backend's primitives.
#[inline(always)]
pub(crate) fn gather_with<C, Z>(c: &ConvStep, x: &[f32], oy: usize,
                                ox: usize, dst: &mut [f32], copy: C,
                                zero: Z)
where
    C: Fn(&[f32], &mut [f32]),
    Z: Fn(&mut [f32]),
{
    let row_w = c.kw * c.cin;
    let mut d = 0;
    for ky in 0..c.kh {
        let iy = (oy * c.stride + ky) as isize - c.pad_y as isize;
        if iy < 0 || iy >= c.in_h as isize {
            zero(&mut dst[d..d + row_w]);
            d += row_w;
            continue;
        }
        let src_row = &x[iy as usize * c.in_w * c.cin..][..c.in_w * c.cin];
        for kx in 0..c.kw {
            let ix = (ox * c.stride + kx) as isize - c.pad_x as isize;
            if ix < 0 || ix >= c.in_w as isize {
                zero(&mut dst[d..d + c.cin]);
            } else {
                copy(&src_row[ix as usize * c.cin..][..c.cin],
                     &mut dst[d..d + c.cin]);
            }
            d += c.cin;
        }
    }
}

/// Every SIMD implementation runnable on this host (the portable
/// fallback always; AVX2 when the CPU supports it) — the parity tests
/// check each against the scalar reference.
#[cfg(test)]
pub(crate) fn simd_impls() -> Vec<&'static dyn Kernels> {
    let mut v: Vec<&'static dyn Kernels> = vec![&simd::PortableKernels];
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2")
            && is_x86_feature_detected!("fma")
        {
            v.push(&simd::x86::Avx2Kernels);
        }
    }
    v
}

/// Every vectorized integer implementation runnable on this host — the
/// parity tests check each **bit-exactly** against `int-scalar`.
#[cfg(test)]
pub(crate) fn int_simd_impls() -> Vec<&'static dyn Kernels> {
    let mut v: Vec<&'static dyn Kernels> =
        vec![&int_simd::IntPortableKernels];
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            v.push(&int_simd::x86::IntAvx2Kernels);
        }
    }
    v
}

#[cfg(test)]
mod tests {
    use super::int::IntKernels;
    use super::scalar::ScalarKernels;
    use super::*;
    use crate::infer::ops::same_pad;
    use crate::infer::plan::Kernel;
    use crate::quant::pow2::pow2_round;
    use crate::testkit::forall;
    use crate::util::Rng;

    /// Ulp-scaled bound for an accumulation of `terms` values of total
    /// magnitude `scale` (see the module tolerance policy).
    fn bound(scale: f32, terms: usize) -> f32 {
        8.0 * f32::EPSILON * scale * terms as f32 + 1e-30
    }

    #[test]
    fn backend_choice_parses_and_resolves() {
        assert_eq!("auto".parse::<KernelBackend>().unwrap(),
                   KernelBackend::Auto);
        assert_eq!("scalar".parse::<KernelBackend>().unwrap(),
                   KernelBackend::Scalar);
        assert_eq!("simd".parse::<KernelBackend>().unwrap(),
                   KernelBackend::Simd);
        assert_eq!("int".parse::<KernelBackend>().unwrap(),
                   KernelBackend::Int);
        assert_eq!("int-scalar".parse::<KernelBackend>().unwrap(),
                   KernelBackend::IntScalar);
        assert!("sse9".parse::<KernelBackend>().is_err());
        assert_eq!(resolve(KernelBackend::Scalar).unwrap(),
                   Resolved::Scalar);
        let s = resolve(KernelBackend::Simd).unwrap();
        assert!(s.name().starts_with("simd"), "{}", s.name());
        // `int` auto-upgrades to a vectorized integer backend …
        let i = resolve(KernelBackend::Int).unwrap();
        assert!(i.name() == "int-avx2" || i.name() == "int-portable",
                "{}", i.name());
        assert!(i.is_int() && i.kernels().uses_int_scratch());
        // … while `int-scalar` pins the reference
        let ir = resolve(KernelBackend::IntScalar).unwrap();
        assert_eq!(ir, Resolved::IntScalar);
        assert_eq!(ir.name(), "int-scalar");
        assert!(ir.is_int() && ir.kernels().uses_int_scratch());
        assert!(!Resolved::Scalar.kernels().uses_int_scratch());
        // every host exposes at least the portable implementations
        assert!(!simd_impls().is_empty());
        assert!(!int_simd_impls().is_empty());
    }

    /// proptest: SIMD dense dot matches scalar within 1-ulp-scaled
    /// tolerance across random shapes and remainder lanes.
    #[test]
    fn simd_dense_rows_match_scalar() {
        forall(11, 150, |r| (r.range(1, 300), r.range(1, 10)),
               |&(fan, rows)| {
            let (fan, rows) = (fan.max(1), rows.max(1));
            let mut rng = Rng::new((fan * 1009 + rows) as u64);
            let x = rng.normals(fan);
            let w = rng.normals(rows * fan);
            let bias = rng.normals(rows);
            let mut y_ref = vec![0f32; rows];
            ScalarKernels.dense_rows(&x, &w, Some(&bias), &mut y_ref);
            for kern in simd_impls() {
                let mut y = vec![0f32; rows];
                kern.dense_rows(&x, &w, Some(&bias), &mut y);
                for r in 0..rows {
                    let scale: f32 = x
                        .iter()
                        .zip(&w[r * fan..][..fan])
                        .map(|(a, b)| (a * b).abs())
                        .sum::<f32>()
                        + bias[r].abs();
                    let tol = bound(scale, fan + 1);
                    if (y[r] - y_ref[r]).abs() > tol {
                        return Err(format!(
                            "{} row {r}: {} vs scalar {} (tol {tol:e})",
                            kern.name(), y[r], y_ref[r]
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    /// proptest: SIMD lut_dot matches scalar across random shapes,
    /// dictionary sizes K = 2..64 and remainder lanes (fan and rows not
    /// multiples of the vector width / OC_TILE).
    #[test]
    fn simd_lut_rows_match_scalar() {
        forall(13, 150, |r| (r.range(1, 260), r.range(2, 65)),
               |&(fan, k)| {
            let (fan, k) = (fan.max(1), k.clamp(2, 64));
            let mut rng = Rng::new((fan * 131 + k) as u64);
            let rows = 1 + rng.below(9);
            let dict: Vec<f32> =
                (0..k).map(|_| rng.normal() * 0.5).collect();
            let assign: Vec<u32> =
                (0..rows * fan).map(|_| rng.below(k) as u32).collect();
            let x = rng.normals(fan);
            let bias = rng.normals(rows);
            let dmax = dict.iter().fold(0f32, |m, d| m.max(d.abs()));
            let sum_abs: f32 = x.iter().map(|v| v.abs()).sum();
            let mut bk = vec![0f32; OC_TILE * k];
            let mut y_ref = vec![0f32; rows];
            ScalarKernels.lut_rows(&x, &assign, &dict, Some(&bias),
                                   &mut bk, &mut y_ref);
            for kern in simd_impls() {
                let mut y = vec![0f32; rows];
                kern.lut_rows(&x, &assign, &dict, Some(&bias), &mut bk,
                              &mut y);
                for r in 0..rows {
                    let scale = sum_abs * dmax + bias[r].abs();
                    let tol = bound(scale, fan + k + 1);
                    if (y[r] - y_ref[r]).abs() > tol {
                        return Err(format!(
                            "{} row {r}: {} vs scalar {} (tol {tol:e})",
                            kern.name(), y[r], y_ref[r]
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    /// proptest: SIMD shift_dot (pow-2 dictionary combine) matches the
    /// scalar bit-shift path within the same tolerance.
    #[test]
    fn simd_shift_rows_match_scalar() {
        forall(17, 120, |r| (r.range(1, 200), r.range(2, 33)),
               |&(fan, k)| {
            let (fan, k) = (fan.max(1), k.clamp(2, 64));
            let mut rng = Rng::new((fan * 257 + k) as u64);
            let rows = 1 + rng.below(7);
            let dict: Vec<Pow2> = (0..k)
                .map(|i| {
                    if i == 0 {
                        Pow2::Zero
                    } else {
                        pow2_round(rng.normal() * 2.0, -6, 6)
                    }
                })
                .collect();
            let dict_f32: Vec<f32> =
                dict.iter().map(|p| p.to_f32()).collect();
            let assign: Vec<u32> =
                (0..rows * fan).map(|_| rng.below(k) as u32).collect();
            let x = rng.normals(fan);
            let bias = rng.normals(rows);
            let dmax =
                dict_f32.iter().fold(0f32, |m, d| m.max(d.abs()));
            let sum_abs: f32 = x.iter().map(|v| v.abs()).sum();
            let mut bk = vec![0f32; OC_TILE * k];
            let mut y_ref = vec![0f32; rows];
            ScalarKernels.shift_rows(&x, &assign, &dict, &dict_f32,
                                     Some(&bias), &mut bk, &mut y_ref);
            for kern in simd_impls() {
                let mut y = vec![0f32; rows];
                kern.shift_rows(&x, &assign, &dict, &dict_f32,
                                Some(&bias), &mut bk, &mut y);
                for r in 0..rows {
                    let scale = sum_abs * dmax + bias[r].abs();
                    let tol = bound(scale, fan + k + 1);
                    if (y[r] - y_ref[r]).abs() > tol {
                        return Err(format!(
                            "{} row {r}: {} vs scalar {} (tol {tol:e})",
                            kern.name(), y[r], y_ref[r]
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    /// The im2col gather is pure data movement: every backend must
    /// produce bit-identical patches, padding included.
    #[test]
    fn simd_im2col_is_bit_identical_to_scalar() {
        forall(23, 80, |r| (r.range(3, 12), r.range(1, 5)),
               |&(h, cin)| {
            let (h, cin) = (h.max(2), cin.max(1));
            let mut rng = Rng::new((h * 31 + cin) as u64);
            let kh = 1 + rng.below(3.min(h));
            let stride = 1 + rng.below(2);
            let (out_h, pad_y) = same_pad(h, kh, stride);
            let c = ConvStep {
                name: "t".into(),
                kh,
                kw: kh,
                cin,
                cout: 1,
                stride,
                in_h: h,
                in_w: h,
                out_h,
                out_w: out_h,
                pad_y,
                pad_x: pad_y,
                block_rows: 1,
                kernel: Kernel::Dense(vec![0.0; kh * kh * cin]),
                int_data: None,
            };
            let x = rng.normals(h * h * cin);
            let fan = kh * kh * cin;
            let mut p_ref = vec![0f32; fan];
            let mut p = vec![0f32; fan];
            for oy in 0..out_h {
                for ox in 0..out_h {
                    ScalarKernels.im2col(&c, &x, oy, ox, &mut p_ref);
                    for kern in simd_impls() {
                        p.iter_mut().for_each(|v| *v = -1.0);
                        kern.im2col(&c, &x, oy, ox, &mut p);
                        if p != p_ref {
                            return Err(format!(
                                "{} patch ({oy},{ox}) diverged",
                                kern.name()
                            ));
                        }
                    }
                    // the int backend shares the same gather geometry
                    p.iter_mut().for_each(|v| *v = -1.0);
                    IntKernels.im2col(&c, &x, oy, ox, &mut p);
                    if p != p_ref {
                        return Err(format!("int patch ({oy},{ox}) \
                                            diverged"));
                    }
                }
            }
            Ok(())
        });
    }

    /// proptest: the int product-table path matches the scalar float
    /// reference within the documented absolute quantization bound
    /// (see the module docs), quantizing at the exact measured absmax.
    #[test]
    fn int_lut_rows_match_scalar_within_quant_bound() {
        forall(29, 120, |r| (r.range(1, 200), r.range(2, 33)),
               |&(fan, k)| {
            let (fan, k) = (fan.max(1), k.clamp(2, 64));
            let mut rng = Rng::new((fan * 613 + k) as u64);
            let rows = 1 + rng.below(7);
            let dict: Vec<f32> =
                (0..k).map(|_| rng.normal() * 0.5).collect();
            let assign: Vec<u32> =
                (0..rows * fan).map(|_| rng.below(k) as u32).collect();
            let x = rng.normals(fan);
            let mut bk = vec![0f32; OC_TILE * k];
            let mut y_ref = vec![0f32; rows];
            ScalarKernels.lut_rows(&x, &assign, &dict, None, &mut bk,
                                   &mut y_ref);
            let amax =
                x.iter().fold(0f32, |m, v| m.max(v.abs())).max(1e-6);
            let dmax =
                dict.iter().fold(0f32, |m, d| m.max(d.abs())).max(1e-6);
            let (s_a, s_d) = (amax / 127.0, dmax / 127.0);
            let mut q = vec![0i16; fan];
            IntKernels.quantize_row(&x, 1.0 / s_a, &mut q);
            let mut table = vec![0i16; k * int::ACT_LEVELS];
            for (ki, d) in dict.iter().enumerate() {
                let dq = (d / s_d).round() as i32;
                for lv in -128..128i32 {
                    table[ki * int::ACT_LEVELS + (lv + 128) as usize] =
                        (dq * lv) as i16;
                }
            }
            let scale = vec![s_a * s_d; rows];
            let mut y = vec![0f32; rows];
            IntKernels.int_lut_rows(
                &q, &assign, &table,
                &IntEpilogue { scale: &scale, bias: None, relu: false },
                &mut y);
            // n/2*(s_a*Dmax + s_d*Amax) + n/4*s_a*s_d, ×1.5 for the f32
            // reference's own accumulation rounding
            let n = fan as f32;
            let tol = 1.5
                * (0.5 * n * (s_a * dmax + s_d * amax)
                    + 0.25 * n * s_a * s_d)
                + 1e-5;
            for r in 0..rows {
                if (y[r] - y_ref[r]).abs() > tol {
                    return Err(format!(
                        "row {r}: int {} vs scalar {} (tol {tol:e}, \
                         fan {fan}, K {k})",
                        y[r], y_ref[r]
                    ));
                }
            }
            Ok(())
        });
    }

    /// On-grid activations + pow-2 dictionary: the int shift path is
    /// bit-identical to the scalar reference — both compute the same
    /// exact dyadic rational. Covers K=1 dictionaries and all-negative
    /// shift exponents.
    #[test]
    fn int_shift_rows_exact_on_grid() {
        forall(31, 80, |r| (r.range(1, 120), r.range(1, 17)),
               |&(fan, k)| {
            let (fan, k) = (fan.max(1), k.max(1));
            let mut rng = Rng::new((fan * 809 + k) as u64);
            let rows = 1 + rng.below(5);
            // exponents all negative: sub-unit pow-2 entries
            let dict: Vec<Pow2> = (0..k)
                .map(|_| {
                    if rng.bool(0.2) {
                        Pow2::Zero
                    } else {
                        let e = -(1 + rng.below(6) as i32);
                        let s = if rng.bool(0.5) { 1.0f32 } else { -1.0 };
                        pow2_round(s * (e as f32).exp2(), -8, 8)
                    }
                })
                .collect();
            let dict_f32: Vec<f32> =
                dict.iter().map(|p| p.to_f32()).collect();
            let assign: Vec<u32> =
                (0..rows * fan).map(|_| rng.below(k) as u32).collect();
            // integer-valued activations on the i8 grid (s_a = 1)
            let x: Vec<f32> = (0..fan)
                .map(|_| (rng.below(17) as i32 - 8) as f32)
                .collect();
            let bias: Vec<f32> = (0..rows)
                .map(|_| (rng.below(9) as i32 - 4) as f32)
                .collect();
            let mut bk = vec![0f32; OC_TILE * k];
            let mut y_ref = vec![0f32; rows];
            ScalarKernels.shift_rows(&x, &assign, &dict, &dict_f32,
                                     Some(&bias), &mut bk, &mut y_ref);
            // lower the dictionary like the plan compiler does
            let e_min = dict
                .iter()
                .filter_map(|p| match p {
                    Pow2::Zero => None,
                    Pow2::Val { exp, .. } => Some(*exp as i32),
                })
                .min();
            let shifts: Vec<IntShift> = dict
                .iter()
                .map(|p| match p {
                    Pow2::Zero =>
                        IntShift { zero: true, neg: false, sh: 0 },
                    Pow2::Val { neg, exp } => IntShift {
                        zero: false,
                        neg: *neg,
                        sh: (*exp as i32 - e_min.unwrap()) as u8,
                    },
                })
                .collect();
            let s_d = match e_min {
                Some(e) =>
                    Pow2::Val { neg: false, exp: e as i8 }.to_f32(),
                None => 1.0,
            };
            let mut q = vec![0i16; fan];
            IntKernels.quantize_row(&x, 1.0, &mut q);
            let scale = vec![s_d; rows];
            let mut ibk = vec![0i32; OC_TILE * k];
            let mut y = vec![0f32; rows];
            IntKernels.int_shift_rows(
                &q, &assign, &shifts, &mut ibk,
                &IntEpilogue { scale: &scale, bias: Some(&bias),
                               relu: false },
                &mut y);
            if y != y_ref {
                return Err(format!(
                    "int shift diverged from scalar on the integer \
                     grid: {y:?} vs {y_ref:?} (fan {fan}, K {k})"
                ));
            }
            Ok(())
        });
    }

    /// proptest: every vectorized integer backend is **bit-identical**
    /// to `int-scalar` on the dense i16 dot, across random shapes
    /// including fan-in 0 and non-multiple-of-lane-width remainders,
    /// with and without the fused ReLU epilogue.
    #[test]
    fn int_simd_dense_rows_bit_exact_vs_int_scalar() {
        forall(37, 150, |r| (r.range(0, 300), r.range(1, 10)),
               |&(fan, rows)| {
            let rows = rows.max(1);
            let mut rng = Rng::new((fan * 1013 + rows) as u64);
            let q: Vec<i16> = (0..fan)
                .map(|_| rng.below(255) as i16 - 127)
                .collect();
            let wq: Vec<i16> = (0..rows * fan)
                .map(|_| rng.below(255) as i16 - 127)
                .collect();
            let scale: Vec<f32> =
                (0..rows).map(|_| rng.normal() * 0.01).collect();
            let bias = rng.normals(rows);
            for relu in [false, true] {
                let epi = IntEpilogue { scale: &scale,
                                        bias: Some(&bias), relu };
                let mut y_ref = vec![0f32; rows];
                IntKernels.int_dense_rows(&q, &wq, &epi, &mut y_ref);
                for kern in int_simd_impls() {
                    let mut y = vec![f32::NAN; rows];
                    kern.int_dense_rows(&q, &wq, &epi, &mut y);
                    if y.iter().map(|v| v.to_bits())
                        .ne(y_ref.iter().map(|v| v.to_bits()))
                    {
                        return Err(format!(
                            "{} diverged (fan {fan}, rows {rows}, \
                             relu {relu}): {y:?} vs {y_ref:?}",
                            kern.name()
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    /// proptest: vectorized product-table gather ≡ int-scalar bitwise,
    /// over K = 1..64 (K=1 included) and remainder fans.
    #[test]
    fn int_simd_lut_rows_bit_exact_vs_int_scalar() {
        forall(41, 150, |r| (r.range(0, 260), r.range(1, 65)),
               |&(fan, k)| {
            let k = k.clamp(1, 64);
            let mut rng = Rng::new((fan * 137 + k) as u64);
            let rows = 1 + rng.below(9);
            let q: Vec<i16> = (0..fan)
                .map(|_| rng.below(255) as i16 - 127)
                .collect();
            let assign: Vec<u32> =
                (0..rows * fan).map(|_| rng.below(k) as u32).collect();
            let mut table = vec![0i16; k * int::ACT_LEVELS];
            for ki in 0..k {
                let dq = rng.below(255) as i32 - 127;
                for lv in -128..128i32 {
                    table[ki * int::ACT_LEVELS + (lv + 128) as usize] =
                        (dq * lv) as i16;
                }
            }
            let scale: Vec<f32> =
                (0..rows).map(|_| rng.normal() * 0.01).collect();
            for relu in [false, true] {
                let epi =
                    IntEpilogue { scale: &scale, bias: None, relu };
                let mut y_ref = vec![0f32; rows];
                IntKernels.int_lut_rows(&q, &assign, &table, &epi,
                                        &mut y_ref);
                for kern in int_simd_impls() {
                    let mut y = vec![f32::NAN; rows];
                    kern.int_lut_rows(&q, &assign, &table, &epi,
                                      &mut y);
                    if y.iter().map(|v| v.to_bits())
                        .ne(y_ref.iter().map(|v| v.to_bits()))
                    {
                        return Err(format!(
                            "{} diverged (fan {fan}, K {k}, \
                             relu {relu}): {y:?} vs {y_ref:?}",
                            kern.name()
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    /// proptest: vectorized shift combine ≡ int-scalar bitwise, over
    /// K=1 dictionaries, all-negative exponent lowerings (every entry
    /// shifts by `exp - e_min ≥ 0`), zero entries and fan-in 0.
    #[test]
    fn int_simd_shift_rows_bit_exact_vs_int_scalar() {
        forall(43, 150, |r| (r.range(0, 200), r.range(1, 33)),
               |&(fan, k)| {
            let k = k.clamp(1, 64);
            let mut rng = Rng::new((fan * 263 + k) as u64);
            let rows = 1 + rng.below(7);
            let q: Vec<i16> = (0..fan)
                .map(|_| rng.below(255) as i16 - 127)
                .collect();
            let assign: Vec<u32> =
                (0..rows * fan).map(|_| rng.below(k) as u32).collect();
            let shifts: Vec<IntShift> = (0..k)
                .map(|_| {
                    if rng.bool(0.15) {
                        IntShift { zero: true, neg: false, sh: 0 }
                    } else {
                        IntShift {
                            zero: false,
                            neg: rng.bool(0.5),
                            sh: rng.below(13) as u8,
                        }
                    }
                })
                .collect();
            let scale: Vec<f32> =
                (0..rows).map(|_| rng.normal() * 0.001).collect();
            let bias = rng.normals(rows);
            for relu in [false, true] {
                let epi = IntEpilogue { scale: &scale,
                                        bias: Some(&bias), relu };
                let mut ibk = vec![0i32; OC_TILE * k];
                let mut y_ref = vec![0f32; rows];
                IntKernels.int_shift_rows(&q, &assign, &shifts,
                                          &mut ibk, &epi, &mut y_ref);
                for kern in int_simd_impls() {
                    let mut y = vec![f32::NAN; rows];
                    ibk.fill(7); // kernels must not read stale buckets
                    kern.int_shift_rows(&q, &assign, &shifts, &mut ibk,
                                        &epi, &mut y);
                    if y.iter().map(|v| v.to_bits())
                        .ne(y_ref.iter().map(|v| v.to_bits()))
                    {
                        return Err(format!(
                            "{} diverged (fan {fan}, K {k}, \
                             relu {relu}): {y:?} vs {y_ref:?}",
                            kern.name()
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    /// proptest: vectorized `quantize_row` ≡ scalar bitwise across
    /// random magnitudes, including values far outside the clamp range
    /// and remainder tails; ties are exercised explicitly below.
    #[test]
    fn int_simd_quantize_row_bit_exact_vs_int_scalar() {
        forall(47, 150, |r| (r.range(0, 300), r.range(1, 40)),
               |&(n, mag)| {
            let mut rng = Rng::new((n * 389 + mag) as u64);
            let x: Vec<f32> = (0..n)
                .map(|_| rng.normal() * mag as f32)
                .collect();
            let inv_scale = 0.05 + rng.below(100) as f32;
            let mut q_ref = vec![0i16; n];
            IntKernels.quantize_row(&x, inv_scale, &mut q_ref);
            for kern in int_simd_impls() {
                let mut q = vec![i16::MIN; n];
                kern.quantize_row(&x, inv_scale, &mut q);
                if q != q_ref {
                    return Err(format!(
                        "{} diverged (n {n}, inv_scale {inv_scale}): \
                         {q:?} vs {q_ref:?}",
                        kern.name()
                    ));
                }
            }
            Ok(())
        });
    }

    /// Adversarial quantize inputs: exact ties (round half away from
    /// zero), the largest float strictly below a tie, clamp edges and
    /// non-finite values. Every integer backend must agree bitwise with
    /// the scalar `(v * inv_scale).round().clamp(…) as i16` semantics.
    #[test]
    fn int_quantize_row_edge_values_agree() {
        let x = [
            0.5f32, -0.5, 1.5, -1.5, 2.5, -2.5, 126.5, -126.5,
            0.5 - 2f32.powi(-25), -(0.5 - 2f32.powi(-25)),
            0.499_999_97, -0.499_999_97, 127.49, -127.49, 500.0,
            -500.0, 0.0, -0.0, f32::NAN, f32::INFINITY,
            f32::NEG_INFINITY, f32::MIN_POSITIVE, -f32::MIN_POSITIVE,
        ];
        for inv_scale in [1.0f32, 0.125, 3.0] {
            let mut q_ref = vec![0i16; x.len()];
            IntKernels.quantize_row(&x, inv_scale, &mut q_ref);
            for kern in int_simd_impls() {
                let mut q = vec![i16::MIN; x.len()];
                kern.quantize_row(&x, inv_scale, &mut q);
                assert_eq!(q, q_ref, "{} at inv_scale {inv_scale}",
                           kern.name());
            }
        }
        // pin the scalar semantics themselves
        let mut q = vec![0i16; x.len()];
        IntKernels.quantize_row(&x, 1.0, &mut q);
        assert_eq!(&q[..8], &[1, -1, 2, -2, 3, -3, 127, -127]);
        assert_eq!(&q[8..12], &[0, 0, 0, 0]);
        assert_eq!(q[18], 0, "NaN quantizes to 0");
        assert_eq!((q[19], q[20]), (127, -127), "±inf saturate");
    }

    /// Regression (overflow bugfix): the kernel trait carries no
    /// fan/span precondition, and just past the compile-admitted bound
    /// a shifted term exceeds i32 — `fan=2, sh=24, q=[127,127]` makes
    /// one bucket of 254, and `254 << 24` is 4 261 412 864 >
    /// `i32::MAX`. Before the i64 widening, `i32 <<` wrapped silently
    /// (shl only checks the shift *amount*, even in debug builds) and
    /// this returned −2.0 instead of 254.0; mixed-sign combines could
    /// additionally panic in debug on the `+=`. Must hold in every
    /// integer backend. (Plan-compiled configs stay within the proven
    /// i32 bound — `tests/kernel_parity.rs` pins the exact
    /// compile-accepted boundary at plan level.)
    #[test]
    fn int_shift_combine_boundary_no_overflow() {
        // one dictionary entry at the span ceiling, all activations
        // +127: bucket = 254, term = 254 << 24 = 4 261 412 864
        let q = [127i16, 127];
        let assign = [0u32, 0];
        let shifts =
            [IntShift { zero: false, neg: false, sh: 24 }];
        let scale = [2f32.powi(-24)];
        let epi =
            IntEpilogue { scale: &scale, bias: None, relu: false };
        let mut ibk = vec![0i32; OC_TILE];
        let mut y_ref = [0f32];
        IntKernels.int_shift_rows(&q, &assign, &shifts, &mut ibk, &epi,
                                  &mut y_ref);
        // 254 · 2²⁴ · 2⁻²⁴ = 254 exactly
        assert_eq!(y_ref[0], 254.0);
        // and with a negated second entry the partial sums swing past
        // ±i32 range mid-combine
        let shifts2 = [
            IntShift { zero: false, neg: false, sh: 24 },
            IntShift { zero: false, neg: true, sh: 24 },
        ];
        let q2 = [127i16, 127, -127, -127];
        let assign2 = [0u32, 0, 1, 1];
        let mut ibk2 = vec![0i32; OC_TILE * 2];
        let mut y2 = [0f32];
        IntKernels.int_shift_rows(&q2, &assign2, &shifts2, &mut ibk2,
                                  &epi, &mut y2);
        assert_eq!(y2[0], 508.0); // 254·2²⁴ − (−254·2²⁴), rescaled
        for kern in int_simd_impls() {
            let mut y = [f32::NAN];
            kern.int_shift_rows(&q, &assign, &shifts, &mut ibk, &epi,
                                &mut y);
            assert_eq!(y[0], 254.0, "{}", kern.name());
            kern.int_shift_rows(&q2, &assign2, &shifts2, &mut ibk2,
                                &epi, &mut y);
            assert_eq!(y[0], 508.0, "{}", kern.name());
        }
    }
}
