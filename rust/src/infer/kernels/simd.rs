//! SIMD kernel backends.
//!
//! Two implementations sit behind the `KernelBackend::Simd` choice:
//!
//! * [`x86::Avx2Kernels`] — AVX2/FMA intrinsics, selected at plan
//!   compile time when `is_x86_feature_detected!` confirms the host
//!   supports them.
//! * [`PortableKernels`] — a chunked-accumulator formulation with no
//!   target-specific code (the fallback on aarch64 and pre-AVX2 x86):
//!   fixed-width lane arrays the autovectorizer maps onto whatever
//!   vector unit the target has.
//!
//! Both sum the same terms as the scalar reference in a different
//! association (lane-parallel accumulators, FMA contraction), so outputs
//! match scalar within the ulp-scaled tolerance documented in
//! [`super`]; the parity proptests in `kernels::tests` and
//! `tests/kernel_parity.rs` hold them to it. The pow-2 shift combine is
//! realized as multiplication by the plan's precomputed exact f32
//! dictionary view — equal to `Pow2::apply` for every finite bucket sum.

use crate::quant::pow2::Pow2;

use super::super::plan::ConvStep;
use super::{gather_with, Kernels, OC_TILE};

/// Portable "simd" backend: autovectorizer-friendly chunked loops.
pub(crate) struct PortableKernels;

const LANES: usize = 8;

/// Chunked dot product: LANES parallel accumulators, tree-reduced.
#[inline(always)]
fn dot_chunked(x: &[f32], w: &[f32]) -> f32 {
    let n = x.len();
    let mut acc = [0f32; LANES];
    let mut i = 0;
    while i + LANES <= n {
        for l in 0..LANES {
            acc[l] += x[i + l] * w[i + l];
        }
        i += LANES;
    }
    let mut s = ((acc[0] + acc[4]) + (acc[1] + acc[5]))
        + ((acc[2] + acc[6]) + (acc[3] + acc[7]));
    while i < n {
        s += x[i] * w[i];
        i += 1;
    }
    s
}

/// Bucket-accumulate + combine over `OC_TILE`-channel tiles, shared by
/// the portable LUT and shift paths (`dict_f` is the f32 dictionary).
/// Each pass streams `x` once while `t` assignment rows stream alongside
/// it, one bucket row per channel.
#[inline(always)]
fn lut_rows_chunked(x: &[f32], assign: &[u32], k: usize, dict_f: &[f32],
                    bias: Option<&[f32]>, buckets: &mut [f32],
                    out: &mut [f32]) {
    let fan = x.len();
    let rows = out.len();
    let mut r0 = 0;
    while r0 < rows {
        let t = OC_TILE.min(rows - r0);
        let bk = &mut buckets[..t * k];
        bk.fill(0.0);
        for (j, &v) in x.iter().enumerate() {
            for r in 0..t {
                bk[r * k + assign[(r0 + r) * fan + j] as usize] += v;
            }
        }
        for r in 0..t {
            let init = match bias {
                Some(b) => b[r0 + r],
                None => 0.0,
            };
            out[r0 + r] = init + dot_chunked(dict_f, &bk[r * k..][..k]);
        }
        r0 += t;
    }
}

impl Kernels for PortableKernels {
    fn name(&self) -> &'static str {
        "simd-portable"
    }

    fn dense_rows(&self, x: &[f32], w: &[f32], bias: Option<&[f32]>,
                  out: &mut [f32]) {
        let fan = x.len();
        for (r, ov) in out.iter_mut().enumerate() {
            let init = match bias {
                Some(b) => b[r],
                None => 0.0,
            };
            *ov = init + dot_chunked(x, &w[r * fan..][..fan]);
        }
    }

    fn lut_rows(&self, x: &[f32], assign: &[u32], dict: &[f32],
                bias: Option<&[f32]>, buckets: &mut [f32],
                out: &mut [f32]) {
        lut_rows_chunked(x, assign, dict.len(), dict, bias, buckets, out);
    }

    fn shift_rows(&self, x: &[f32], assign: &[u32], _dict: &[Pow2],
                  dict_f32: &[f32], bias: Option<&[f32]>,
                  buckets: &mut [f32], out: &mut [f32]) {
        lut_rows_chunked(x, assign, dict_f32.len(), dict_f32, bias,
                         buckets, out);
    }

    fn im2col(&self, c: &ConvStep, x: &[f32], oy: usize, ox: usize,
              dst: &mut [f32]) {
        gather_with(c, x, oy, ox, dst, |s, d| d.copy_from_slice(s),
                    |d| d.fill(0.0));
    }
}

#[cfg(target_arch = "x86_64")]
pub(crate) mod x86 {
    //! AVX2/FMA implementation. Every `unsafe` below relies on one
    //! invariant: `Avx2Kernels` is only ever selected after
    //! `is_x86_feature_detected!("avx2")` and `("fma")` both pass (see
    //! `kernels::best_simd`), plus the slice contracts documented on
    //! the [`Kernels`] trait (assignment indices `< dict.len()`,
    //! row-major weight/assignment layouts, bucket capacity) that the
    //! plan compiler validates once at compile time.

    use std::arch::x86_64::*;

    use crate::infer::kernels::{gather_with, Kernels, OC_TILE};
    use crate::infer::plan::ConvStep;
    use crate::quant::pow2::Pow2;

    pub(crate) struct Avx2Kernels;

    impl Kernels for Avx2Kernels {
        fn name(&self) -> &'static str {
            "simd-avx2"
        }

        fn dense_rows(&self, x: &[f32], w: &[f32], bias: Option<&[f32]>,
                      out: &mut [f32]) {
            // SAFETY: avx2+fma checked at backend selection; slice
            // layout contracts validated at plan compile.
            unsafe { dense_rows_avx2(x, w, bias, out) }
        }

        fn lut_rows(&self, x: &[f32], assign: &[u32], dict: &[f32],
                    bias: Option<&[f32]>, buckets: &mut [f32],
                    out: &mut [f32]) {
            // SAFETY: as above; assignment indices < dict.len().
            unsafe {
                lut_rows_avx2(x, assign, dict.len(), dict, bias, buckets,
                              out)
            }
        }

        fn shift_rows(&self, x: &[f32], assign: &[u32], _dict: &[Pow2],
                      dict_f32: &[f32], bias: Option<&[f32]>,
                      buckets: &mut [f32], out: &mut [f32]) {
            // SAFETY: as above; dict_f32 is the exact f32 view of the
            // pow-2 dictionary, same length.
            unsafe {
                lut_rows_avx2(x, assign, dict_f32.len(), dict_f32, bias,
                              buckets, out)
            }
        }

        fn im2col(&self, c: &ConvStep, x: &[f32], oy: usize, ox: usize,
                  dst: &mut [f32]) {
            // SAFETY: copy/fill primitives only touch the slices they
            // are handed; avx2 checked at backend selection.
            gather_with(c, x, oy, ox, dst,
                        |s, d| unsafe { copy_avx2(s, d) },
                        |d| unsafe { fill_zero_avx2(d) });
        }
    }

    /// 8-lane horizontal sum.
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn hsum8(v: __m256) -> f32 {
        let lo = _mm256_castps256_ps128(v);
        let hi = _mm256_extractf128_ps::<1>(v);
        let s = _mm_add_ps(lo, hi);
        let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
        let s = _mm_add_ss(s, _mm_movehdup_ps(s));
        _mm_cvtss_f32(s)
    }

    /// FMA dot product: two 8-lane accumulator chains, scalar tail for
    /// remainder lanes.
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn dot_avx2(x: &[f32], w: &[f32]) -> f32 {
        let n = x.len();
        let xp = x.as_ptr();
        let wp = w.as_ptr();
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 16 <= n {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(xp.add(i)),
                                   _mm256_loadu_ps(wp.add(i)), acc0);
            acc1 = _mm256_fmadd_ps(_mm256_loadu_ps(xp.add(i + 8)),
                                   _mm256_loadu_ps(wp.add(i + 8)), acc1);
            i += 16;
        }
        if i + 8 <= n {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(xp.add(i)),
                                   _mm256_loadu_ps(wp.add(i)), acc0);
            i += 8;
        }
        let mut acc = hsum8(_mm256_add_ps(acc0, acc1));
        while i < n {
            acc += *xp.add(i) * *wp.add(i);
            i += 1;
        }
        acc
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn fill_zero_avx2(dst: &mut [f32]) {
        let n = dst.len();
        let p = dst.as_mut_ptr();
        let z = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 8 <= n {
            _mm256_storeu_ps(p.add(i), z);
            i += 8;
        }
        while i < n {
            *p.add(i) = 0.0;
            i += 1;
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn copy_avx2(src: &[f32], dst: &mut [f32]) {
        let n = src.len();
        let sp = src.as_ptr();
        let dp = dst.as_mut_ptr();
        let mut i = 0usize;
        while i + 8 <= n {
            _mm256_storeu_ps(dp.add(i), _mm256_loadu_ps(sp.add(i)));
            i += 8;
        }
        while i < n {
            *dp.add(i) = *sp.add(i);
            i += 1;
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn dense_rows_avx2(x: &[f32], w: &[f32],
                              bias: Option<&[f32]>, out: &mut [f32]) {
        let fan = x.len();
        for r in 0..out.len() {
            let init = match bias {
                Some(b) => b[r],
                None => 0.0,
            };
            out[r] = init + dot_avx2(x, &w[r * fan..][..fan]);
        }
    }

    /// Bucket-accumulate over `OC_TILE`-channel tiles (the scatter
    /// itself is scalar — conflicting lanes can't be vector-added
    /// without AVX-512CD — but four independent accumulation chains per
    /// `x` load keep the ports busy and stream each assignment row
    /// exactly once), then an FMA-vectorized K-term combine.
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn lut_rows_avx2(x: &[f32], assign: &[u32], k: usize,
                            dict_f: &[f32], bias: Option<&[f32]>,
                            buckets: &mut [f32], out: &mut [f32]) {
        let fan = x.len();
        let rows = out.len();
        let mut r0 = 0usize;
        while r0 < rows {
            let t = OC_TILE.min(rows - r0);
            let bk = &mut buckets[..t * k];
            fill_zero_avx2(bk);
            if t == OC_TILE {
                let a0 = assign.as_ptr().add(r0 * fan);
                let a1 = a0.add(fan);
                let a2 = a0.add(2 * fan);
                let a3 = a0.add(3 * fan);
                let b0 = bk.as_mut_ptr();
                let b1 = b0.add(k);
                let b2 = b0.add(2 * k);
                let b3 = b0.add(3 * k);
                for j in 0..fan {
                    let v = *x.get_unchecked(j);
                    *b0.add(*a0.add(j) as usize) += v;
                    *b1.add(*a1.add(j) as usize) += v;
                    *b2.add(*a2.add(j) as usize) += v;
                    *b3.add(*a3.add(j) as usize) += v;
                }
            } else {
                for (j, &v) in x.iter().enumerate() {
                    for r in 0..t {
                        let a =
                            *assign.get_unchecked((r0 + r) * fan + j);
                        *bk.get_unchecked_mut(r * k + a as usize) += v;
                    }
                }
            }
            for r in 0..t {
                let init = match bias {
                    Some(b) => *b.get_unchecked(r0 + r),
                    None => 0.0,
                };
                *out.get_unchecked_mut(r0 + r) =
                    init + dot_avx2(dict_f, &bk[r * k..][..k]);
            }
            r0 += t;
        }
    }
}
