//! Vectorized integer kernel backends.
//!
//! Two implementations sit behind the `KernelBackend::Int` choice (the
//! scalar reference stays in [`super::int`] as `int-scalar`):
//!
//! * [`x86::IntAvx2Kernels`] — AVX2 integer intrinsics, selected at
//!   plan compile time when `is_x86_feature_detected!("avx2")` passes:
//!   the dense dot runs as i16×i16 `_mm256_madd_epi16` pairs with i32
//!   lane accumulators (the NNUE idiom — pairwise products are bounded
//!   by `2·127²`, so the madd itself can never overflow), the
//!   product-table and bucket paths unroll over `OC_TILE = 4` output
//!   channels (four independent gather/scatter chains per activation
//!   load, the same tile shape as the float scatter in
//!   [`super::simd`]), and `quantize_row` converts 16 floats per
//!   iteration.
//! * [`IntPortableKernels`] — chunked accumulators with no
//!   target-specific code, the fallback on aarch64 and pre-AVX2 x86.
//!
//! Unlike the float SIMD backends there is **no tolerance**: integer
//! addition is associative, so lane/tile reordering cannot change the
//! accumulated sums, and every backend finishes with the identical
//! scalar epilogue expression (`IntEpilogue::apply`, never FMA
//! contracted). Outputs must be bit-identical to `int-scalar`; the
//! parity proptests in `kernels::tests` and `tests/kernel_parity.rs`
//! assert `==`.
//!
//! The vectorized `quantize_row` reproduces the scalar
//! `(v * inv_scale).round().clamp(-127.0, 127.0) as i16` semantics
//! exactly — including round-half-away-from-zero ties (AVX2 only
//! rounds half-to-even, so exact ties are detected and corrected per
//! lane), NaN→0 and ±inf→±127 saturation. The shift combine runs in
//! i64 like the scalar reference (see the overflow-headroom notes in
//! [`super`]).

use crate::quant::pow2::Pow2;

use super::super::plan::ConvStep;
use super::int::{quantize_one, ACT_LEVELS};
use super::scalar::ScalarKernels;
use super::{gather_with, IntEpilogue, IntShift, Kernels, OC_TILE};

/// Portable vectorized integer backend: autovectorizer-friendly
/// chunked loops, bit-identical to `int-scalar` by construction.
pub(crate) struct IntPortableKernels;

/// i16 lanes per chunk of the portable integer dot.
const ILANES: usize = 16;

/// Chunked i16×i16→i32 dot. Integer adds are associative, so the
/// lane-parallel accumulation is bit-identical to the scalar order;
/// every lane's partial sum is a subset of the row's terms, so it obeys
/// the same `fan·127²` bound the plan compiler checks.
#[inline(always)]
fn int_dot_chunked(q: &[i16], w: &[i16]) -> i32 {
    let n = q.len();
    let mut acc = [0i32; ILANES];
    let mut i = 0;
    while i + ILANES <= n {
        for l in 0..ILANES {
            acc[l] += q[i + l] as i32 * w[i + l] as i32;
        }
        i += ILANES;
    }
    let mut s: i32 = acc.iter().sum();
    while i < n {
        s += q[i] as i32 * w[i] as i32;
        i += 1;
    }
    s
}

impl Kernels for IntPortableKernels {
    fn name(&self) -> &'static str {
        "int-portable"
    }

    fn dense_rows(&self, x: &[f32], w: &[f32], bias: Option<&[f32]>,
                  out: &mut [f32]) {
        ScalarKernels.dense_rows(x, w, bias, out);
    }

    fn lut_rows(&self, x: &[f32], assign: &[u32], dict: &[f32],
                bias: Option<&[f32]>, buckets: &mut [f32],
                out: &mut [f32]) {
        ScalarKernels.lut_rows(x, assign, dict, bias, buckets, out);
    }

    fn shift_rows(&self, x: &[f32], assign: &[u32], dict: &[Pow2],
                  dict_f32: &[f32], bias: Option<&[f32]>,
                  buckets: &mut [f32], out: &mut [f32]) {
        ScalarKernels.shift_rows(x, assign, dict, dict_f32, bias, buckets,
                                 out);
    }

    fn im2col(&self, c: &ConvStep, x: &[f32], oy: usize, ox: usize,
              dst: &mut [f32]) {
        gather_with(c, x, oy, ox, dst, |s, d| d.copy_from_slice(s),
                    |d| d.fill(0.0));
    }

    fn uses_int_scratch(&self) -> bool {
        true
    }

    fn quantize_row(&self, x: &[f32], inv_scale: f32, q: &mut [i16]) {
        for (v, qv) in x.iter().zip(q.iter_mut()) {
            *qv = quantize_one(*v, inv_scale);
        }
    }

    fn int_dense_rows(&self, q: &[i16], wq: &[i16], epi: &IntEpilogue,
                      out: &mut [f32]) {
        let fan = q.len();
        for (r, ov) in out.iter_mut().enumerate() {
            let acc = int_dot_chunked(q, &wq[r * fan..][..fan]);
            *ov = epi.apply(acc as i64, r);
        }
    }

    fn int_lut_rows(&self, q: &[i16], assign: &[u32], table: &[i16],
                    epi: &IntEpilogue, out: &mut [f32]) {
        let fan = q.len();
        let rows = out.len();
        let mut r0 = 0;
        while r0 < rows {
            let t = OC_TILE.min(rows - r0);
            let mut acc = [0i32; OC_TILE];
            for (j, qv) in q.iter().enumerate() {
                let idx = (*qv + 128) as usize;
                for r in 0..t {
                    let a = assign[(r0 + r) * fan + j] as usize;
                    acc[r] += table[a * ACT_LEVELS + idx] as i32;
                }
            }
            for r in 0..t {
                out[r0 + r] = epi.apply(acc[r] as i64, r0 + r);
            }
            r0 += t;
        }
    }

    fn int_shift_rows(&self, q: &[i16], assign: &[u32],
                      shifts: &[IntShift], ibuckets: &mut [i32],
                      epi: &IntEpilogue, out: &mut [f32]) {
        let fan = q.len();
        let rows = out.len();
        let k = shifts.len();
        let mut r0 = 0;
        while r0 < rows {
            let t = OC_TILE.min(rows - r0);
            let bk = &mut ibuckets[..t * k];
            bk.fill(0);
            for (j, qv) in q.iter().enumerate() {
                let v = *qv as i32;
                for r in 0..t {
                    bk[r * k + assign[(r0 + r) * fan + j] as usize] += v;
                }
            }
            for r in 0..t {
                let mut acc = 0i64;
                for (s, b) in shifts.iter().zip(&bk[r * k..][..k]) {
                    if s.zero {
                        continue;
                    }
                    let term = (*b as i64) << s.sh;
                    acc += if s.neg { -term } else { term };
                }
                out[r0 + r] = epi.apply(acc, r0 + r);
            }
            r0 += t;
        }
    }
}

#[cfg(target_arch = "x86_64")]
pub(crate) mod x86 {
    //! AVX2 integer implementation. Every `unsafe` below relies on one
    //! invariant: `IntAvx2Kernels` is only ever selected after
    //! `is_x86_feature_detected!("avx2")` passes (see
    //! `kernels::best_int`), plus the slice contracts documented on the
    //! [`Kernels`] trait (assignment indices `< dict.len()`, row-major
    //! weight/assignment layouts, `OC_TILE * K` integer bucket
    //! capacity) that the plan compiler validates once at compile time.
    //! FMA is deliberately *not* used anywhere: the epilogue is the
    //! scalar expression shared with `int-scalar`.

    use std::arch::x86_64::*;

    use crate::infer::kernels::int::{quantize_one, ACT_LEVELS};
    use crate::infer::kernels::scalar::ScalarKernels;
    use crate::infer::kernels::{gather_with, IntEpilogue, IntShift,
                                Kernels, OC_TILE};
    use crate::infer::plan::ConvStep;
    use crate::quant::pow2::Pow2;

    pub(crate) struct IntAvx2Kernels;

    impl Kernels for IntAvx2Kernels {
        fn name(&self) -> &'static str {
            "int-avx2"
        }

        fn dense_rows(&self, x: &[f32], w: &[f32], bias: Option<&[f32]>,
                      out: &mut [f32]) {
            ScalarKernels.dense_rows(x, w, bias, out);
        }

        fn lut_rows(&self, x: &[f32], assign: &[u32], dict: &[f32],
                    bias: Option<&[f32]>, buckets: &mut [f32],
                    out: &mut [f32]) {
            ScalarKernels.lut_rows(x, assign, dict, bias, buckets, out);
        }

        fn shift_rows(&self, x: &[f32], assign: &[u32], dict: &[Pow2],
                      dict_f32: &[f32], bias: Option<&[f32]>,
                      buckets: &mut [f32], out: &mut [f32]) {
            ScalarKernels.shift_rows(x, assign, dict, dict_f32, bias,
                                     buckets, out);
        }

        fn im2col(&self, c: &ConvStep, x: &[f32], oy: usize, ox: usize,
                  dst: &mut [f32]) {
            gather_with(c, x, oy, ox, dst, |s, d| d.copy_from_slice(s),
                        |d| d.fill(0.0));
        }

        fn uses_int_scratch(&self) -> bool {
            true
        }

        fn quantize_row(&self, x: &[f32], inv_scale: f32,
                        q: &mut [i16]) {
            // SAFETY: avx2 checked at backend selection; `q` is at
            // least as long as `x` per the trait contract.
            unsafe { quantize_row_avx2(x, inv_scale, q) }
        }

        fn int_dense_rows(&self, q: &[i16], wq: &[i16],
                          epi: &IntEpilogue, out: &mut [f32]) {
            // SAFETY: avx2 checked at backend selection; slice layout
            // contracts validated at plan compile.
            unsafe { int_dense_rows_avx2(q, wq, epi, out) }
        }

        fn int_lut_rows(&self, q: &[i16], assign: &[u32],
                        table: &[i16], epi: &IntEpilogue,
                        out: &mut [f32]) {
            // SAFETY: as above; assignment indices < K and `table`
            // holds K × ACT_LEVELS entries.
            unsafe { int_lut_rows_avx2(q, assign, table, epi, out) }
        }

        fn int_shift_rows(&self, q: &[i16], assign: &[u32],
                          shifts: &[IntShift], ibuckets: &mut [i32],
                          epi: &IntEpilogue, out: &mut [f32]) {
            // SAFETY: as above; `ibuckets` holds at least
            // OC_TILE * shifts.len() slots per the trait contract.
            unsafe {
                int_shift_rows_avx2(q, assign, shifts, ibuckets, epi,
                                    out)
            }
        }
    }

    /// 8-lane i32 horizontal sum.
    #[target_feature(enable = "avx2")]
    unsafe fn hsum8_epi32(v: __m256i) -> i32 {
        let lo = _mm256_castsi256_si128(v);
        let hi = _mm256_extracti128_si256::<1>(v);
        let s = _mm_add_epi32(lo, hi);
        let s = _mm_add_epi32(s, _mm_shuffle_epi32::<0b01_00_11_10>(s));
        let s = _mm_add_epi32(s, _mm_shuffle_epi32::<0b10_11_00_01>(s));
        _mm_cvtsi128_si32(s)
    }

    /// i16×i16→i32 dot: `_mm256_madd_epi16` multiplies 16 lane pairs
    /// and adds adjacent products (each pair ≤ 2·127², far inside
    /// i32), two accumulator chains, scalar tail for remainder lanes.
    #[target_feature(enable = "avx2")]
    unsafe fn int_dot_avx2(a: &[i16], b: &[i16]) -> i32 {
        let n = a.len();
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let mut acc0 = _mm256_setzero_si256();
        let mut acc1 = _mm256_setzero_si256();
        let mut i = 0usize;
        while i + 32 <= n {
            acc0 = _mm256_add_epi32(
                acc0,
                _mm256_madd_epi16(
                    _mm256_loadu_si256(ap.add(i) as *const __m256i),
                    _mm256_loadu_si256(bp.add(i) as *const __m256i),
                ),
            );
            acc1 = _mm256_add_epi32(
                acc1,
                _mm256_madd_epi16(
                    _mm256_loadu_si256(ap.add(i + 16) as *const __m256i),
                    _mm256_loadu_si256(bp.add(i + 16) as *const __m256i),
                ),
            );
            i += 32;
        }
        if i + 16 <= n {
            acc0 = _mm256_add_epi32(
                acc0,
                _mm256_madd_epi16(
                    _mm256_loadu_si256(ap.add(i) as *const __m256i),
                    _mm256_loadu_si256(bp.add(i) as *const __m256i),
                ),
            );
            i += 16;
        }
        let mut s = hsum8_epi32(_mm256_add_epi32(acc0, acc1));
        while i < n {
            s += *ap.add(i) as i32 * *bp.add(i) as i32;
            i += 1;
        }
        s
    }

    #[target_feature(enable = "avx2")]
    unsafe fn fill_zero_epi32(dst: &mut [i32]) {
        let n = dst.len();
        let p = dst.as_mut_ptr();
        let z = _mm256_setzero_si256();
        let mut i = 0usize;
        while i + 8 <= n {
            _mm256_storeu_si256(p.add(i) as *mut __m256i, z);
            i += 8;
        }
        while i < n {
            *p.add(i) = 0;
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn int_dense_rows_avx2(q: &[i16], wq: &[i16],
                                  epi: &IntEpilogue, out: &mut [f32]) {
        let fan = q.len();
        for r in 0..out.len() {
            let acc = int_dot_avx2(q, &wq[r * fan..][..fan]);
            *out.get_unchecked_mut(r) = epi.apply(acc as i64, r);
        }
    }

    /// Product-table gather over `OC_TILE`-channel tiles: the lookups
    /// are data-dependent (no AVX2 instruction gathers i16), so the
    /// win is four independent accumulation chains per quantized
    /// activation load — each `q[j] + 128` table column index is
    /// computed once and reused across the tile, mirroring the float
    /// scatter shape in `simd.rs`.
    #[target_feature(enable = "avx2")]
    unsafe fn int_lut_rows_avx2(q: &[i16], assign: &[u32],
                                table: &[i16], epi: &IntEpilogue,
                                out: &mut [f32]) {
        let fan = q.len();
        let rows = out.len();
        let tb = table.as_ptr();
        let mut r0 = 0usize;
        while r0 < rows {
            let t = OC_TILE.min(rows - r0);
            if t == OC_TILE {
                let a0 = assign.as_ptr().add(r0 * fan);
                let a1 = a0.add(fan);
                let a2 = a0.add(2 * fan);
                let a3 = a0.add(3 * fan);
                let (mut s0, mut s1, mut s2, mut s3) =
                    (0i32, 0i32, 0i32, 0i32);
                for j in 0..fan {
                    let idx = (*q.get_unchecked(j) + 128) as usize;
                    s0 += *tb
                        .add(*a0.add(j) as usize * ACT_LEVELS + idx)
                        as i32;
                    s1 += *tb
                        .add(*a1.add(j) as usize * ACT_LEVELS + idx)
                        as i32;
                    s2 += *tb
                        .add(*a2.add(j) as usize * ACT_LEVELS + idx)
                        as i32;
                    s3 += *tb
                        .add(*a3.add(j) as usize * ACT_LEVELS + idx)
                        as i32;
                }
                *out.get_unchecked_mut(r0) = epi.apply(s0 as i64, r0);
                *out.get_unchecked_mut(r0 + 1) =
                    epi.apply(s1 as i64, r0 + 1);
                *out.get_unchecked_mut(r0 + 2) =
                    epi.apply(s2 as i64, r0 + 2);
                *out.get_unchecked_mut(r0 + 3) =
                    epi.apply(s3 as i64, r0 + 3);
            } else {
                for r in 0..t {
                    let ar = assign.as_ptr().add((r0 + r) * fan);
                    let mut s = 0i32;
                    for j in 0..fan {
                        let idx = (*q.get_unchecked(j) + 128) as usize;
                        s += *tb
                            .add(*ar.add(j) as usize * ACT_LEVELS + idx)
                            as i32;
                    }
                    *out.get_unchecked_mut(r0 + r) =
                        epi.apply(s as i64, r0 + r);
                }
            }
            r0 += t;
        }
    }

    /// Bucket-accumulate quantized activations over `OC_TILE`-channel
    /// tiles (four independent scatter chains; the bucket zeroing is
    /// the vector part), then the exact i64 shift-and-add combine per
    /// row — identical to the scalar reference term order, which is
    /// irrelevant anyway: integer adds commute bit-exactly.
    #[target_feature(enable = "avx2")]
    unsafe fn int_shift_rows_avx2(q: &[i16], assign: &[u32],
                                  shifts: &[IntShift],
                                  ibuckets: &mut [i32],
                                  epi: &IntEpilogue, out: &mut [f32]) {
        let fan = q.len();
        let rows = out.len();
        let k = shifts.len();
        let mut r0 = 0usize;
        while r0 < rows {
            let t = OC_TILE.min(rows - r0);
            let bk = &mut ibuckets[..t * k];
            fill_zero_epi32(bk);
            if t == OC_TILE {
                let a0 = assign.as_ptr().add(r0 * fan);
                let a1 = a0.add(fan);
                let a2 = a0.add(2 * fan);
                let a3 = a0.add(3 * fan);
                let b0 = bk.as_mut_ptr();
                let b1 = b0.add(k);
                let b2 = b0.add(2 * k);
                let b3 = b0.add(3 * k);
                for j in 0..fan {
                    let v = *q.get_unchecked(j) as i32;
                    *b0.add(*a0.add(j) as usize) += v;
                    *b1.add(*a1.add(j) as usize) += v;
                    *b2.add(*a2.add(j) as usize) += v;
                    *b3.add(*a3.add(j) as usize) += v;
                }
            } else {
                for (j, qv) in q.iter().enumerate() {
                    let v = *qv as i32;
                    for r in 0..t {
                        let a =
                            *assign.get_unchecked((r0 + r) * fan + j);
                        *bk.get_unchecked_mut(r * k + a as usize) += v;
                    }
                }
            }
            for r in 0..t {
                let row = &bk[r * k..][..k];
                let mut acc = 0i64;
                for (s, b) in shifts.iter().zip(row) {
                    if s.zero {
                        continue;
                    }
                    let term = (*b as i64) << s.sh;
                    acc += if s.neg { -term } else { term };
                }
                *out.get_unchecked_mut(r0 + r) = epi.apply(acc, r0 + r);
            }
            r0 += t;
        }
    }

    /// Quantize 8 floats to 8 clamped i32 lanes, reproducing the
    /// scalar `(v * inv_scale).round().clamp(-127.0, 127.0) as i16`
    /// bit-exactly:
    ///
    /// * AVX2's only vector rounding is half-to-even, but `f32::round`
    ///   is half-away-from-zero. The two disagree **only** on exact
    ///   ties, and `d = t - round_half_even(t)` is computed exactly
    ///   (for `|t| < 2^24` the operands are close enough that the
    ///   subtraction is lossless — Sterbenz for `|t| ≥ 0.5`, trivial
    ///   below — and above `2^24` every float is already integral), so
    ///   `|d| == 0.5` detects ties precisely; those lanes take
    ///   `t + copysign(0.5, t)`, which is exact at a tie.
    /// * Clamp keeps the data operand second so a NaN propagates
    ///   through `max`/`min` (matching scalar `clamp`), ±inf saturate
    ///   to ±127.
    /// * `_mm256_cvtps_epi32` then converts already-integral values;
    ///   NaN lanes (which convert to the 0x80000000 indefinite) are
    ///   zeroed by the ordered-compare mask, matching `NaN as i16 == 0`.
    #[target_feature(enable = "avx2")]
    unsafe fn quant8(p: *const f32, vs: __m256) -> __m256i {
        let sign = _mm256_set1_ps(-0.0);
        let half = _mm256_set1_ps(0.5);
        let t = _mm256_mul_ps(_mm256_loadu_ps(p), vs);
        let he = _mm256_round_ps::<
            { _MM_FROUND_TO_NEAREST_INT | _MM_FROUND_NO_EXC },
        >(t);
        let d = _mm256_sub_ps(t, he);
        let tie = _mm256_cmp_ps::<_CMP_EQ_OQ>(
            _mm256_andnot_ps(sign, d), half);
        let away = _mm256_add_ps(
            t, _mm256_or_ps(_mm256_and_ps(sign, t), half));
        let r = _mm256_blendv_ps(he, away, tie);
        let c = _mm256_min_ps(
            _mm256_set1_ps(127.0),
            _mm256_max_ps(_mm256_set1_ps(-127.0), r),
        );
        let ord =
            _mm256_castps_si256(_mm256_cmp_ps::<_CMP_ORD_Q>(t, t));
        _mm256_and_si256(_mm256_cvtps_epi32(c), ord)
    }

    #[target_feature(enable = "avx2")]
    unsafe fn quantize_row_avx2(x: &[f32], inv_scale: f32,
                                q: &mut [i16]) {
        let n = x.len();
        let xp = x.as_ptr();
        let qp = q.as_mut_ptr();
        let vs = _mm256_set1_ps(inv_scale);
        let mut i = 0usize;
        while i + 16 <= n {
            let a = quant8(xp.add(i), vs);
            let b = quant8(xp.add(i + 8), vs);
            // packs interleaves 128-bit lanes: [a0..3, b0..3, a4..7,
            // b4..7] — permute the 64-bit chunks back in order. No
            // saturation can occur: every lane is already in ±127.
            let packed = _mm256_packs_epi32(a, b);
            let fixed = _mm256_permute4x64_epi64::<0b11_01_10_00>(packed);
            _mm256_storeu_si256(qp.add(i) as *mut __m256i, fixed);
            i += 16;
        }
        while i < n {
            *qp.add(i) = quantize_one(*xp.add(i), inv_scale);
            i += 1;
        }
    }
}
