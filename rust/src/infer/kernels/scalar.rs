//! Scalar reference backend.
//!
//! Accumulates in exactly the term order of the reference single-op
//! kernels in [`crate::infer::ops`], so plans compiled with
//! `KernelBackend::Scalar` stay bit-identical to the legacy interpreter.
//! This is the backend the SIMD parity proptests measure against.

use crate::quant::pow2::Pow2;

use super::super::plan::ConvStep;
use super::{gather_with, Kernels};

pub(crate) struct ScalarKernels;

impl Kernels for ScalarKernels {
    fn name(&self) -> &'static str {
        "scalar"
    }

    fn dense_rows(&self, x: &[f32], w: &[f32], bias: Option<&[f32]>,
                  out: &mut [f32]) {
        let fan = x.len();
        for (r, ov) in out.iter_mut().enumerate() {
            // accumulate starting FROM the bias — same association as
            // the reference affine, keeping outputs bit-identical
            let mut acc = match bias {
                Some(b) => b[r],
                None => 0.0,
            };
            for (v, wv) in x.iter().zip(&w[r * fan..][..fan]) {
                acc += v * wv;
            }
            *ov = acc;
        }
    }

    fn lut_rows(&self, x: &[f32], assign: &[u32], dict: &[f32],
                bias: Option<&[f32]>, buckets: &mut [f32],
                out: &mut [f32]) {
        let fan = x.len();
        let bk = &mut buckets[..dict.len()];
        for (r, ov) in out.iter_mut().enumerate() {
            bk.fill(0.0);
            for (v, &a) in x.iter().zip(&assign[r * fan..][..fan]) {
                bk[a as usize] += v;
            }
            let mut acc = match bias {
                Some(b) => b[r],
                None => 0.0,
            };
            for (d, s) in dict.iter().zip(bk.iter()) {
                acc += d * s;
            }
            *ov = acc;
        }
    }

    fn shift_rows(&self, x: &[f32], assign: &[u32], dict: &[Pow2],
                  _dict_f32: &[f32], bias: Option<&[f32]>,
                  buckets: &mut [f32], out: &mut [f32]) {
        let fan = x.len();
        let bk = &mut buckets[..dict.len()];
        for (r, ov) in out.iter_mut().enumerate() {
            bk.fill(0.0);
            for (v, &a) in x.iter().zip(&assign[r * fan..][..fan]) {
                bk[a as usize] += v;
            }
            let mut acc = match bias {
                Some(b) => b[r],
                None => 0.0,
            };
            for (d, s) in dict.iter().zip(bk.iter()) {
                acc += d.apply(*s);
            }
            *ov = acc;
        }
    }

    fn im2col(&self, c: &ConvStep, x: &[f32], oy: usize, ox: usize,
              dst: &mut [f32]) {
        gather_with(c, x, oy, ox, dst, |s, d| d.copy_from_slice(s),
                    |d| d.fill(0.0));
    }
}
