//! Pure-Rust quantized inference: a plan/execute engine for exported
//! LUT-Q models.
//!
//! The module is split along the compile/run boundary:
//!
//! * [`plan`] — lowers the manifest's JSON layer graph **once** into a
//!   typed [`Plan`]: validated ops with precomputed SAME-pad geometry,
//!   resolved weight/bias slices, pre-unpacked output-channel-major LUT
//!   assignments, pre-rounded pow-2 shift dictionaries and a static
//!   shape-inference pass that sizes the buffer arena. Compilation also
//!   resolves the inner-kernel backend (see [`kernels`]).
//! * [`exec`] — executes a plan: cache-blocked im2col convolution, the
//!   bucket-accumulate LUT matmul (K multiplications — or shifts — per
//!   accumulator instead of fan-in), batch-parallel via scoped threads,
//!   allocation-free after warmup.
//! * [`kernels`] — the swappable inner loops behind a `Kernels` backend
//!   trait: a `scalar` reference backend (bit-identical to the legacy
//!   interpreter), a `simd` backend (AVX2/FMA on x86-64 behind
//!   `is_x86_feature_detected!` runtime dispatch, portable chunked
//!   accumulators elsewhere) and the `int` backend family (i8-quantized
//!   activations, per-layer `dict × act_level` product tables or
//!   integer shift-and-add, i32 accumulation — no float multiply until
//!   the final rescale): `int` auto-upgrades to the AVX2 integer
//!   kernels (portable chunked fallback elsewhere) while `int-scalar`
//!   pins the scalar integer reference. [`PlanOptions::kernel`] picks
//!   the backend at compile time; `Auto` (the default) honours the
//!   **`LUTQ_KERNEL`** environment override (`scalar` | `simd` | `int`
//!   | `int-scalar`) so benches and CI can A/B without code changes,
//!   then prefers SIMD.
//! * [`arena`] — the reusable [`Scratch`] buffers a plan runs in;
//!   [`Plan::scratch_pool`] pre-warms one per worker for serving pools.
//! * [`ops`] — reference single-op kernels. These define the numerical
//!   contract: **scalar-backend** plan execution is bit-identical to
//!   them, and the tests hold both paths to that.
//! * [`counting`] — exact multiply/shift/add/lookup accounting, the
//!   deployment-side verification of the paper's computation claims.
//!   Counts are compile-time properties of a plan and do not depend on
//!   the kernel backend.
//!
//! ## Backend tolerance policy
//!
//! SIMD backends accumulate the same terms as scalar in lane-parallel
//! order (with FMA contraction), so their outputs match scalar within an
//! ulp-scaled tolerance — `~8 * n * EPSILON * |terms|` for an `n`-term
//! accumulation — rather than bit-exactly; the parity proptests
//! (`kernels::tests`, `tests/kernel_parity.rs`) enforce the bound
//! across random shapes, dictionary sizes and remainder lanes. The int
//! backends introduce real quantization error and match scalar within
//! the *absolute* bound documented in [`kernels`] (driven by the
//! per-layer `act_absmax` calibration stat, or its default); they are
//! bit-exact for on-grid activations with pow-2 shift dictionaries.
//! Between integer backends the contract is stricter: `int-avx2` and
//! `int-portable` are **bit-identical** to `int-scalar` — integer
//! accumulation is associative, and every variant finishes with the
//! same scalar epilogue — so the int parity tests assert equality, not
//! a tolerance.
//! Backend choice is per-plan and fixed at compile time, so repeated
//! runs of one plan (any thread count, any batch composition) remain
//! bit-identical to each other; anything requiring bit-exactness
//! against the reference ops pins [`KernelBackend::Scalar`].
//!
//! The legacy one-shot `Engine` facade (re-lower the graph on every call)
//! is gone; [`crate::serve`] is the serving layer on top of this module.
//!
//! Serving pattern — single model, hand-rolled loop:
//!
//! ```text
//! let plan = Plan::compile(&man.graph, &model, opts, &man.meta.input)?;
//! let mut scratch = plan.scratch_for(max_batch);       // pre-warmed
//! for batch in requests {
//!     let counts = plan.run_into(&batch, &mut scratch)?; // no allocs
//!     let (dims, logits) = scratch.output();
//!     ...
//! }
//! ```
//!
//! Serving pattern — production: register plans in a
//! [`crate::serve::Registry`] and front them with a
//! [`crate::serve::Server`], which adds dynamic batch coalescing, a
//! bounded queue, per-(model, worker) scratch and graceful shutdown.

pub mod arena;
pub mod counting;
pub mod exec;
pub mod kernels;
pub mod ops;
pub mod plan;
pub mod tensor;

pub use arena::Scratch;
pub use counting::OpCounts;
pub use kernels::KernelBackend;
pub use ops::ExecMode;
pub use plan::{Plan, PlanOptions};
pub use tensor::Tensor;
