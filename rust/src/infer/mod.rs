//! Pure-Rust quantized inference engine.
//!
//! Executes exported LUT-Q models (dictionary + packed assignments) over
//! the manifest's layer graph with exact multiply/shift/add accounting:
//! the deployment-side verification of the paper's computation claims.

pub mod counting;
pub mod engine;
pub mod ops;
pub mod tensor;

pub use counting::OpCounts;
pub use engine::{Engine, EngineOptions};
pub use ops::ExecMode;
pub use tensor::Tensor;
