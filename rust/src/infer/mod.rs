//! Pure-Rust quantized inference: a plan/execute engine for exported
//! LUT-Q models.
//!
//! The module is split along the compile/run boundary:
//!
//! * [`plan`] — lowers the manifest's JSON layer graph **once** into a
//!   typed [`Plan`]: validated ops with precomputed SAME-pad geometry,
//!   resolved weight/bias slices, pre-unpacked output-channel-major LUT
//!   assignments, pre-rounded pow-2 shift dictionaries and a static
//!   shape-inference pass that sizes the buffer arena.
//! * [`exec`] — executes a plan: cache-blocked im2col convolution, the
//!   bucket-accumulate LUT matmul (K multiplications — or shifts — per
//!   accumulator instead of fan-in), batch-parallel via scoped threads,
//!   allocation-free after warmup.
//! * [`arena`] — the reusable [`Scratch`] buffers a plan runs in.
//! * [`engine`] — the legacy one-shot [`Engine`] facade (compiles a plan
//!   per call), kept so existing callers and comparisons keep working.
//! * [`ops`] — reference single-op kernels. These define the numerical
//!   contract: plan execution is bit-identical to them, and the tests
//!   hold both paths to that.
//! * [`counting`] — exact multiply/shift/add/lookup accounting, the
//!   deployment-side verification of the paper's computation claims.
//!
//! Serving pattern:
//!
//! ```text
//! let plan = Plan::compile(&man.graph, &model, opts, &man.meta.input)?;
//! let mut scratch = plan.scratch();
//! for batch in requests {
//!     let counts = plan.run_into(&batch, &mut scratch)?; // no allocs
//!     let (dims, logits) = scratch.output();
//!     ...
//! }
//! ```

pub mod arena;
pub mod counting;
pub mod engine;
pub mod exec;
pub mod ops;
pub mod plan;
pub mod tensor;

pub use arena::Scratch;
pub use counting::OpCounts;
pub use engine::{Engine, EngineOptions};
pub use ops::ExecMode;
pub use plan::{Plan, PlanOptions};
pub use tensor::Tensor;
