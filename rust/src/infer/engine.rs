//! The graph-IR interpreter: executes an exported [`QuantizedModel`] over
//! the manifest's layer graph in any [`ExecMode`], returning outputs plus
//! exact op counts. This is the deployment-side proof of the paper's
//! claims: LutTrick shows the I -> K multiplication reduction, ShiftOnly
//! (pow-2 dictionaries + ML-BN) executes with *zero* float multiplies in
//! all quantized layers.

use anyhow::{anyhow, bail, Result};

use crate::jsonic::Json;
use crate::params::export::QuantizedModel;

use super::counting::OpCounts;
use super::ops::{self, ExecMode, Weights};
use super::tensor::Tensor;

#[derive(Debug, Clone, Copy)]
pub struct EngineOptions {
    pub mode: ExecMode,
    pub act_bits: usize,
    pub mlbn: bool,
}

impl Default for EngineOptions {
    fn default() -> Self {
        EngineOptions { mode: ExecMode::Dense, act_bits: 0, mlbn: false }
    }
}

pub struct Engine<'m> {
    graph: &'m Json,
    model: &'m QuantizedModel,
    pub opts: EngineOptions,
}

impl<'m> Engine<'m> {
    pub fn new(graph: &'m Json, model: &'m QuantizedModel,
               opts: EngineOptions) -> Self {
        Engine { graph, model, opts }
    }

    /// Run the graph on a batch input. Input dims: (B, H, W, C) for conv
    /// nets, (B, I) for MLPs.
    pub fn run(&self, x: &Tensor) -> Result<(Tensor, OpCounts)> {
        let mut counts = OpCounts::default();
        let mut cur = x.clone();
        let mut saved: std::collections::HashMap<String, Tensor> =
            std::collections::HashMap::new();
        let ops_list =
            self.graph.as_arr().ok_or_else(|| anyhow!("graph not array"))?;

        for op in ops_list {
            let kind = op.at("op").as_str().unwrap_or("");
            match kind {
                "conv" => {
                    cur = self.run_conv(op, &cur, &mut counts)?;
                }
                "bn" => {
                    let name = op.at("name").as_str().unwrap();
                    let g = self.fp(&format!("{name}.gamma"))?;
                    let b = self.fp(&format!("{name}.beta"))?;
                    let rm = self.fp(&format!("{name}.rmean"))?;
                    let rv = self.fp(&format!("{name}.rvar"))?;
                    cur = ops::batchnorm(&cur, g, b, rm, rv,
                                         self.opts.mlbn, &mut counts);
                }
                "relu" => {
                    cur = ops::relu(&cur);
                    if self.opts.act_bits > 0 {
                        cur = ops::act_quant(&cur, self.opts.act_bits);
                    }
                }
                "maxpool" => {
                    cur = ops::maxpool(
                        &cur,
                        op.at("k").as_usize().unwrap(),
                        op.at("stride").as_usize().unwrap(),
                    );
                }
                "gap" => {
                    cur = ops::gap(&cur, &mut counts);
                }
                "flatten" => {
                    let b = cur.dims[0];
                    let rest = cur.elems() / b;
                    cur = Tensor::new(vec![b, rest], cur.data.clone());
                }
                "affine" => {
                    let name = op.at("name").as_str().unwrap();
                    let i = op.at("cin").as_usize().unwrap();
                    let o = op.at("cout").as_usize().unwrap();
                    let bias = self.fp(&format!("{name}.b"))?;
                    cur = self.run_linear(name, &cur, bias, i, o,
                                          &mut counts)?;
                }
                "save" => {
                    saved.insert(
                        op.at("tag").as_str().unwrap().to_string(),
                        cur.clone(),
                    );
                }
                "add" => {
                    let tag = op.at("tag").as_str().unwrap();
                    let mut h = saved
                        .get(tag)
                        .ok_or_else(|| anyhow!("missing save `{tag}`"))?
                        .clone();
                    if let Some(proj) = op.get("proj") {
                        if proj != &Json::Null {
                            h = self.run_conv(proj, &h, &mut counts)?;
                        }
                    }
                    cur = ops::add_tensors(&cur, &h, &mut counts);
                }
                other => bail!("unknown graph op `{other}`"),
            }
        }
        Ok((cur, counts))
    }

    fn run_conv(&self, op: &Json, x: &Tensor,
                counts: &mut OpCounts) -> Result<Tensor> {
        let name = op.at("name").as_str().unwrap();
        let k = op.at("k").as_usize().unwrap();
        let cin = op.at("cin").as_usize().unwrap();
        let cout = op.at("cout").as_usize().unwrap();
        let stride = op
            .get("stride")
            .and_then(|s| s.as_usize())
            .unwrap_or(1);
        if let Some(l) = self.model.lut(name) {
            if self.opts.mode == ExecMode::Dense {
                // dequantize-and-MAC baseline (what conventional hardware
                // without LUT support would execute)
                let w = l.dequantize();
                return Ok(ops::conv2d(x, &Weights::Dense { w: &w }, k, k,
                                      cin, cout, stride, ExecMode::Dense,
                                      counts));
            }
            let assign = l.assignments();
            Ok(ops::conv2d(x,
                           &Weights::Lut { dict: &l.dict, assign: &assign },
                           k, k, cin, cout, stride, self.opts.mode, counts))
        } else {
            let w = self.fp(&format!("{name}.w"))?;
            Ok(ops::conv2d(x, &Weights::Dense { w }, k, k, cin, cout,
                           stride, ExecMode::Dense, counts))
        }
    }

    fn run_linear(&self, name: &str, x: &Tensor, bias: &[f32], i: usize,
                  o: usize, counts: &mut OpCounts) -> Result<Tensor> {
        if let Some(l) = self.model.lut(name) {
            if self.opts.mode == ExecMode::Dense {
                let w = l.dequantize();
                return Ok(ops::affine(x, &Weights::Dense { w: &w }, bias,
                                      i, o, ExecMode::Dense, counts));
            }
            let assign = l.assignments();
            Ok(ops::affine(x,
                           &Weights::Lut { dict: &l.dict, assign: &assign },
                           bias, i, o, self.opts.mode, counts))
        } else {
            let w = self.fp(&format!("{name}.w"))?;
            Ok(ops::affine(x, &Weights::Dense { w }, bias, i, o,
                           ExecMode::Dense, counts))
        }
    }

    fn fp(&self, name: &str) -> Result<&'m [f32]> {
        self.model
            .fp
            .get(name)
            .map(|t| t.as_f32())
            .ok_or_else(|| anyhow!("missing fp tensor `{name}`"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::export::LutLayer;
    use crate::params::HostTensor;
    use crate::quant::bitpack::pack_assignments;
    use crate::util::Rng;

    /// Hand-build a tiny MLP model: affine(4->3) with LUT weights.
    fn tiny_model() -> (Json, QuantizedModel) {
        let graph = crate::jsonic::parse(
            r#"[{"op":"affine","name":"fc","cin":4,"cout":3}]"#,
        )
        .unwrap();
        let dict = vec![-1.0f32, 0.0, 0.5, 2.0];
        let mut rng = Rng::new(1);
        let assign: Vec<u32> =
            (0..12).map(|_| rng.below(4) as u32).collect();
        let mut model = QuantizedModel::default();
        model.lut_layers.push(LutLayer {
            name: "fc".into(),
            packed: pack_assignments(&assign, 4),
            dict,
            shape: vec![4, 3],
        });
        model.fp.insert(
            "fc.b".into(),
            HostTensor::f32(vec![3], vec![0.1, -0.1, 0.0]),
        );
        (graph, model)
    }

    #[test]
    fn engine_runs_lut_mlp_and_counts() {
        let (graph, model) = tiny_model();
        let eng = Engine::new(&graph, &model, EngineOptions {
            mode: ExecMode::LutTrick,
            act_bits: 0,
            mlbn: false,
        });
        let x = Tensor::new(vec![2, 4], vec![1.0, 2.0, 3.0, 4.0,
                                             -1.0, 0.0, 1.0, 0.5]);
        let (y, counts) = eng.run(&x).unwrap();
        assert_eq!(y.dims, vec![2, 3]);
        // manual check of output[0][0]
        let l = model.lut(&"fc".to_string()).unwrap();
        let q = l.dequantize();
        let expect: f32 = (0..4).map(|i| x.data[i] * q[i * 3]).sum::<f32>()
            + 0.1;
        assert!((y.data[0] - expect).abs() < 1e-5);
        assert_eq!(counts.mults, (2 * 3 * 4) as u64); // K=4 per output
    }

    #[test]
    fn shift_only_zero_multiplies() {
        let (graph, model) = tiny_model();
        let eng = Engine::new(&graph, &model, EngineOptions {
            mode: ExecMode::ShiftOnly,
            act_bits: 0,
            mlbn: true,
        });
        let x = Tensor::new(vec![1, 4], vec![0.5, -2.0, 1.5, 3.0]);
        let (_, counts) = eng.run(&x).unwrap();
        assert!(counts.is_multiplierless(), "{counts}");
        assert!(counts.shifts > 0);
    }
}
