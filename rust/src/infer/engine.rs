//! Legacy interpreter facade over the plan/execute engine.
//!
//! [`Engine`] keeps the original one-shot API — hold a graph + model,
//! call [`Engine::run`] — but is now a thin shim: each call lowers the
//! graph with [`Plan::compile`] and executes the compiled plan. This
//! preserves every caller while the compiled path (plan once, run many)
//! is the one serving workloads should use:
//!
//! ```text
//! let plan = Plan::compile(&graph, &model, opts.into(), &dims)?;
//! let mut scratch = plan.scratch();
//! loop { plan.run_into(&batch, &mut scratch)?; }
//! ```

use anyhow::{ensure, Result};

use crate::jsonic::Json;
use crate::params::export::QuantizedModel;

use super::counting::OpCounts;
use super::ops::ExecMode;
use super::plan::{Plan, PlanOptions};
use super::tensor::Tensor;

#[derive(Debug, Clone, Copy)]
pub struct EngineOptions {
    pub mode: ExecMode,
    pub act_bits: usize,
    pub mlbn: bool,
}

impl Default for EngineOptions {
    fn default() -> Self {
        EngineOptions { mode: ExecMode::Dense, act_bits: 0, mlbn: false }
    }
}

impl From<EngineOptions> for PlanOptions {
    fn from(o: EngineOptions) -> PlanOptions {
        PlanOptions {
            mode: o.mode,
            act_bits: o.act_bits,
            mlbn: o.mlbn,
            threads: 0,
        }
    }
}

/// Compatibility interpreter: compiles a fresh [`Plan`] per `run` call.
pub struct Engine<'m> {
    graph: &'m Json,
    model: &'m QuantizedModel,
    pub opts: EngineOptions,
}

impl<'m> Engine<'m> {
    pub fn new(graph: &'m Json, model: &'m QuantizedModel,
               opts: EngineOptions) -> Self {
        Engine { graph, model, opts }
    }

    /// Run the graph on a batch input. Input dims: (B, H, W, C) for conv
    /// nets, (B, I) for MLPs. Compiles per call — amortize with
    /// [`Plan::compile`] directly on hot paths.
    pub fn run(&self, x: &Tensor) -> Result<(Tensor, OpCounts)> {
        ensure!(x.dims.len() >= 2,
                "engine input needs a leading batch dimension, got {:?}",
                x.dims);
        let plan = Plan::compile(self.graph, self.model, self.opts.into(),
                                 &x.dims[1..])?;
        let mut scratch = plan.scratch();
        plan.run(x, &mut scratch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::export::LutLayer;
    use crate::params::HostTensor;
    use crate::quant::bitpack::pack_assignments;
    use crate::util::Rng;

    /// Hand-build a tiny MLP model: affine(4->3) with LUT weights.
    fn tiny_model() -> (Json, QuantizedModel) {
        let graph = crate::jsonic::parse(
            r#"[{"op":"affine","name":"fc","cin":4,"cout":3}]"#,
        )
        .unwrap();
        let dict = vec![-1.0f32, 0.0, 0.5, 2.0];
        let mut rng = Rng::new(1);
        let assign: Vec<u32> =
            (0..12).map(|_| rng.below(4) as u32).collect();
        let mut model = QuantizedModel::default();
        model.lut_layers.push(LutLayer::new(
            "fc",
            dict,
            pack_assignments(&assign, 4),
            vec![4, 3],
        ));
        model.fp.insert(
            "fc.b".into(),
            HostTensor::f32(vec![3], vec![0.1, -0.1, 0.0]),
        );
        (graph, model)
    }

    #[test]
    fn engine_runs_lut_mlp_and_counts() {
        let (graph, model) = tiny_model();
        let eng = Engine::new(&graph, &model, EngineOptions {
            mode: ExecMode::LutTrick,
            act_bits: 0,
            mlbn: false,
        });
        let x = Tensor::new(vec![2, 4], vec![1.0, 2.0, 3.0, 4.0,
                                             -1.0, 0.0, 1.0, 0.5]);
        let (y, counts) = eng.run(&x).unwrap();
        assert_eq!(y.dims, vec![2, 3]);
        // manual check of output[0][0]
        let l = model.lut(&"fc".to_string()).unwrap();
        let q = l.dequantize();
        let expect: f32 = (0..4).map(|i| x.data[i] * q[i * 3]).sum::<f32>()
            + 0.1;
        assert!((y.data[0] - expect).abs() < 1e-5);
        assert_eq!(counts.mults, (2 * 3 * 4) as u64); // K=4 per output
    }

    #[test]
    fn shift_only_zero_multiplies() {
        let (graph, model) = tiny_model();
        let eng = Engine::new(&graph, &model, EngineOptions {
            mode: ExecMode::ShiftOnly,
            act_bits: 0,
            mlbn: true,
        });
        let x = Tensor::new(vec![1, 4], vec![0.5, -2.0, 1.5, 3.0]);
        let (_, counts) = eng.run(&x).unwrap();
        assert!(counts.is_multiplierless(), "{counts}");
        assert!(counts.shifts > 0);
    }

    #[test]
    fn shim_equals_direct_plan() {
        let (graph, model) = tiny_model();
        let opts = EngineOptions {
            mode: ExecMode::LutTrick,
            act_bits: 0,
            mlbn: false,
        };
        let x = Tensor::new(vec![3, 4],
                            (0..12).map(|i| (i as f32 * 0.31).sin())
                                .collect());
        let (y_shim, c_shim) =
            Engine::new(&graph, &model, opts).run(&x).unwrap();
        let plan =
            Plan::compile(&graph, &model, opts.into(), &[4]).unwrap();
        let mut s = plan.scratch();
        let (y_plan, c_plan) = plan.run(&x, &mut s).unwrap();
        assert_eq!(y_shim.data, y_plan.data);
        assert_eq!(y_shim.dims, y_plan.dims);
        assert_eq!(c_shim, c_plan);
    }
}
