//! Inference-engine primitive ops (NHWC), each in up to three execution
//! modes:
//!   Dense     — dequantized weights, conventional multiply-accumulate
//!   LutTrick  — LUT-Q bucket accumulation: K multiplications per output
//!               accumulator instead of fan-in (paper section 1)
//!   ShiftOnly — pow-2 dictionaries applied as bit-shifts; asserts the
//!               "fully multiplier-less" claim by construction
//!
//! Padding/stride semantics match XLA's SAME convolution so engine outputs
//! are comparable to the AOT `infer` program.

use crate::quant::pow2::{is_pow2_or_zero, pow2_round, Pow2};

use super::counting::OpCounts;
use super::tensor::Tensor;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    Dense,
    LutTrick,
    ShiftOnly,
}

/// Weights of one layer as the engine consumes them.
pub enum Weights<'a> {
    Dense { w: &'a [f32] },
    /// tied: dictionary + per-weight assignment indices
    Lut { dict: &'a [f32], assign: &'a [u32] },
}

/// SAME-padding geometry (matches XLA/TF SAME).
pub fn same_pad(in_dim: usize, k: usize, stride: usize) -> (usize, usize) {
    let out = in_dim.div_ceil(stride);
    let pad_total = ((out - 1) * stride + k).saturating_sub(in_dim);
    (out, pad_total / 2)
}

/// conv2d NHWC, HWIO weights, SAME padding.
pub fn conv2d(x: &Tensor, weights: &Weights, kh: usize, kw: usize,
              cin: usize, cout: usize, stride: usize, mode: ExecMode,
              counts: &mut OpCounts) -> Tensor {
    let (b, h, w) = (x.dims[0], x.dims[1], x.dims[2]);
    assert_eq!(x.dims[3], cin);
    let (oh, pad_y) = same_pad(h, kh, stride);
    let (ow, pad_x) = same_pad(w, kw, stride);
    let mut out = Tensor::zeros(vec![b, oh, ow, cout]);

    match (weights, mode) {
        (Weights::Dense { w: wt }, _) => {
            // conventional MAC loop
            for bi in 0..b {
                for oy in 0..oh {
                    for ox in 0..ow {
                        for oc in 0..cout {
                            let mut acc = 0f32;
                            for ky in 0..kh {
                                let iy = (oy * stride + ky) as isize
                                    - pad_y as isize;
                                if iy < 0 || iy >= h as isize {
                                    continue;
                                }
                                for kx in 0..kw {
                                    let ix = (ox * stride + kx) as isize
                                        - pad_x as isize;
                                    if ix < 0 || ix >= w as isize {
                                        continue;
                                    }
                                    for ci in 0..cin {
                                        let wv = wt[((ky * kw + kx) * cin
                                            + ci) * cout + oc];
                                        acc += x.at4(bi, iy as usize,
                                                     ix as usize, ci) * wv;
                                    }
                                }
                            }
                            out.set4(bi, oy, ox, oc, acc);
                        }
                    }
                }
            }
            let out_elems = (b * oh * ow * cout) as u64;
            let fan_in = (kh * kw * cin) as u64;
            counts.mults += out_elems * fan_in;
            counts.adds += out_elems * fan_in;
        }
        (Weights::Lut { dict, assign }, _) => {
            let k = dict.len();
            let shift_dict: Vec<Pow2> = if mode == ExecMode::ShiftOnly {
                dict.iter()
                    .map(|&d| {
                        assert!(is_pow2_or_zero(d),
                                "ShiftOnly needs a pow-2 dictionary");
                        pow2_round(d, -40, 40)
                    })
                    .collect()
            } else {
                Vec::new()
            };
            let mut buckets = vec![0f32; k];
            for bi in 0..b {
                for oy in 0..oh {
                    for ox in 0..ow {
                        for oc in 0..cout {
                            // bucket-accumulate inputs per dictionary index
                            buckets.iter_mut().for_each(|v| *v = 0.0);
                            for ky in 0..kh {
                                let iy = (oy * stride + ky) as isize
                                    - pad_y as isize;
                                if iy < 0 || iy >= h as isize {
                                    continue;
                                }
                                for kx in 0..kw {
                                    let ix = (ox * stride + kx) as isize
                                        - pad_x as isize;
                                    if ix < 0 || ix >= w as isize {
                                        continue;
                                    }
                                    for ci in 0..cin {
                                        let a = assign[((ky * kw + kx)
                                            * cin + ci) * cout + oc];
                                        buckets[a as usize] += x.at4(
                                            bi, iy as usize, ix as usize,
                                            ci);
                                    }
                                }
                            }
                            // K multiplications (or shifts) per accumulator
                            let mut acc = 0f32;
                            if mode == ExecMode::ShiftOnly {
                                for (kk, &s) in buckets.iter().enumerate() {
                                    acc += shift_dict[kk].apply(s);
                                }
                            } else {
                                for (kk, &s) in buckets.iter().enumerate() {
                                    acc += dict[kk] * s;
                                }
                            }
                            out.set4(bi, oy, ox, oc, acc);
                        }
                    }
                }
            }
            let out_elems = (b * oh * ow * cout) as u64;
            let fan_in = (kh * kw * cin) as u64;
            counts.adds += out_elems * (fan_in + k as u64);
            counts.lookups += out_elems * fan_in;
            if mode == ExecMode::ShiftOnly {
                counts.shifts += out_elems * k as u64;
            } else {
                counts.mults += out_elems * k as u64;
            }
        }
    }
    out
}

/// affine y = x @ w + bias; x (B, I), w (I, O).
pub fn affine(x: &Tensor, weights: &Weights, bias: &[f32], i: usize,
              o: usize, mode: ExecMode, counts: &mut OpCounts) -> Tensor {
    let b = x.dims[0];
    assert_eq!(x.dims[1], i);
    let mut out = Tensor::zeros(vec![b, o]);
    match (weights, mode) {
        (Weights::Dense { w }, _) => {
            for bi in 0..b {
                for oi in 0..o {
                    let mut acc = bias[oi];
                    for ii in 0..i {
                        acc += x.data[bi * i + ii] * w[ii * o + oi];
                    }
                    out.data[bi * o + oi] = acc;
                }
            }
            counts.mults += (b * o * i) as u64;
            counts.adds += (b * o * (i + 1)) as u64;
        }
        (Weights::Lut { dict, assign }, _) => {
            let k = dict.len();
            let shift_dict: Vec<Pow2> = if mode == ExecMode::ShiftOnly {
                dict.iter()
                    .map(|&d| {
                        assert!(is_pow2_or_zero(d),
                                "ShiftOnly needs a pow-2 dictionary");
                        pow2_round(d, -40, 40)
                    })
                    .collect()
            } else {
                Vec::new()
            };
            let mut buckets = vec![0f32; k];
            for bi in 0..b {
                for oi in 0..o {
                    buckets.iter_mut().for_each(|v| *v = 0.0);
                    for ii in 0..i {
                        buckets[assign[ii * o + oi] as usize] +=
                            x.data[bi * i + ii];
                    }
                    let mut acc = bias[oi];
                    if mode == ExecMode::ShiftOnly {
                        for (kk, &s) in buckets.iter().enumerate() {
                            acc += shift_dict[kk].apply(s);
                        }
                    } else {
                        for (kk, &s) in buckets.iter().enumerate() {
                            acc += dict[kk] * s;
                        }
                    }
                    out.data[bi * o + oi] = acc;
                }
            }
            counts.adds += (b * o * (i + k + 1)) as u64;
            counts.lookups += (b * o * i) as u64;
            if mode == ExecMode::ShiftOnly {
                counts.shifts += (b * o * k) as u64;
            } else {
                counts.mults += (b * o * k) as u64;
            }
        }
    }
    out
}

/// Inference batch-norm fold: y = a*x + b per channel with
/// a = gamma/sqrt(rvar+eps), b = beta - a*rmean. With `mlbn` the scale is
/// pow-2-rounded and applied as a shift (paper appendix A).
pub fn batchnorm(x: &Tensor, gamma: &[f32], beta: &[f32], rmean: &[f32],
                 rvar: &[f32], mlbn: bool, counts: &mut OpCounts) -> Tensor {
    const EPS: f32 = 1e-5;
    let c = *x.dims.last().unwrap();
    let mut a: Vec<f32> = (0..c)
        .map(|i| gamma[i] / (rvar[i] + EPS).sqrt())
        .collect();
    let shifts: Vec<Pow2> = if mlbn {
        a.iter().map(|&v| pow2_round(v, -12, 12)).collect()
    } else {
        Vec::new()
    };
    if mlbn {
        for (v, s) in a.iter_mut().zip(&shifts) {
            *v = s.to_f32();
        }
    }
    let b: Vec<f32> =
        (0..c).map(|i| beta[i] - a[i] * rmean[i]).collect();
    let mut out = x.clone();
    let rows = x.elems() / c;
    for r in 0..rows {
        for ci in 0..c {
            let v = out.data[r * c + ci];
            out.data[r * c + ci] = if mlbn {
                shifts[ci].apply(v) + b[ci]
            } else {
                a[ci] * v + b[ci]
            };
        }
    }
    let elems = x.elems() as u64;
    if mlbn {
        counts.shifts += elems;
    } else {
        counts.mults += elems;
    }
    counts.adds += elems;
    out
}

pub fn relu(x: &Tensor) -> Tensor {
    let mut out = x.clone();
    for v in &mut out.data {
        *v = v.max(0.0);
    }
    out
}

/// Dynamic symmetric uniform activation fake-quant (matches
/// layers.act_quant in python: per-tensor max-abs scale).
pub fn act_quant(x: &Tensor, bits: usize) -> Tensor {
    if bits == 0 {
        return x.clone();
    }
    let scale = (x.max_abs() / ((1 << (bits - 1)) - 1) as f32).max(1e-12);
    let lo = -((1 << (bits - 1)) as f32);
    let hi = ((1 << (bits - 1)) - 1) as f32;
    let mut out = x.clone();
    for v in &mut out.data {
        *v = (*v / scale).round().clamp(lo, hi) * scale;
    }
    out
}

pub fn maxpool(x: &Tensor, k: usize, stride: usize) -> Tensor {
    let (b, h, w, c) = (x.dims[0], x.dims[1], x.dims[2], x.dims[3]);
    // VALID pooling (matches jax reduce_window "VALID")
    let oh = (h - k) / stride + 1;
    let ow = (w - k) / stride + 1;
    let mut out = Tensor::zeros(vec![b, oh, ow, c]);
    for bi in 0..b {
        for oy in 0..oh {
            for ox in 0..ow {
                for ci in 0..c {
                    let mut m = f32::NEG_INFINITY;
                    for ky in 0..k {
                        for kx in 0..k {
                            m = m.max(x.at4(bi, oy * stride + ky,
                                            ox * stride + kx, ci));
                        }
                    }
                    out.set4(bi, oy, ox, ci, m);
                }
            }
        }
    }
    out
}

/// Global average pool NHWC -> (B, C). When h*w is a power of two (the
/// usual case for CIFAR/ImageNet geometries) the 1/(h*w) scale is applied
/// as a shift, keeping the fully-multiplier-less path multiply-free.
pub fn gap(x: &Tensor, counts: &mut OpCounts) -> Tensor {
    let (b, h, w, c) = (x.dims[0], x.dims[1], x.dims[2], x.dims[3]);
    let mut out = Tensor::zeros(vec![b, c]);
    let hw = (h * w) as f32;
    let shift = if (h * w).is_power_of_two() {
        Some(pow2_round(1.0 / hw, -40, 40))
    } else {
        None
    };
    for bi in 0..b {
        for ci in 0..c {
            let mut s = 0f32;
            for y in 0..h {
                for xx in 0..w {
                    s += x.at4(bi, y, xx, ci);
                }
            }
            out.data[bi * c + ci] = match shift {
                Some(p) => p.apply(s),
                None => s / hw,
            };
        }
    }
    counts.adds += (b * c * h * w) as u64;
    if shift.is_some() {
        counts.shifts += (b * c) as u64;
    } else {
        counts.mults += (b * c) as u64;
    }
    out
}

pub fn add_tensors(a: &Tensor, b: &Tensor, counts: &mut OpCounts) -> Tensor {
    assert_eq!(a.dims, b.dims);
    let mut out = a.clone();
    for (o, &bv) in out.data.iter_mut().zip(&b.data) {
        *o += bv;
    }
    counts.adds += a.elems() as u64;
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn randn(dims: Vec<usize>, seed: u64) -> Tensor {
        let mut r = Rng::new(seed);
        let n = dims.iter().product();
        Tensor::new(dims, r.normals(n))
    }

    #[test]
    fn same_pad_geometry() {
        assert_eq!(same_pad(32, 3, 1), (32, 1));
        // stride 2: pad_total = 15*2+3-32 = 1 -> pad_before = 0 (TF SAME)
        assert_eq!(same_pad(32, 3, 2), (16, 0));
        assert_eq!(same_pad(32, 1, 1), (32, 0));
        // 5 -> out 3: pad_total = 2*2+3-5 = 2 -> pad_before = 1
        assert_eq!(same_pad(5, 3, 2), (3, 1));
    }

    #[test]
    fn lut_conv_equals_dense_with_dequantized_weights() {
        let mut r = Rng::new(2);
        let (kh, kw, cin, cout) = (3, 3, 4, 5);
        let n = kh * kw * cin * cout;
        let dict = vec![-0.5f32, -0.1, 0.2, 0.8];
        let assign: Vec<u32> =
            (0..n).map(|_| r.below(4) as u32).collect();
        let dense: Vec<f32> =
            assign.iter().map(|&a| dict[a as usize]).collect();
        let x = randn(vec![2, 8, 8, cin], 3);

        let mut c1 = OpCounts::default();
        let y_dense = conv2d(&x, &Weights::Dense { w: &dense }, kh, kw, cin,
                             cout, 1, ExecMode::Dense, &mut c1);
        let mut c2 = OpCounts::default();
        let y_lut = conv2d(&x, &Weights::Lut { dict: &dict,
                                               assign: &assign },
                           kh, kw, cin, cout, 1, ExecMode::LutTrick,
                           &mut c2);
        for (a, b) in y_dense.data.iter().zip(&y_lut.data) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
        // the whole point: lut mults = K per accumulator, dense = fan_in
        let out_elems = (2 * 8 * 8 * cout) as u64;
        assert_eq!(c1.mults, out_elems * (kh * kw * cin) as u64);
        assert_eq!(c2.mults, out_elems * 4);
        assert!(c2.mults < c1.mults);
    }

    #[test]
    fn shift_only_conv_is_multiplierless_and_exact() {
        let mut r = Rng::new(5);
        let (kh, kw, cin, cout) = (3, 3, 3, 4);
        let n = kh * kw * cin * cout;
        let dict = vec![-0.5f32, 0.0, 0.25, 1.0]; // all pow2-or-zero
        let assign: Vec<u32> = (0..n).map(|_| r.below(4) as u32).collect();
        let dense: Vec<f32> =
            assign.iter().map(|&a| dict[a as usize]).collect();
        let x = randn(vec![1, 6, 6, cin], 7);

        let mut cd = OpCounts::default();
        let yd = conv2d(&x, &Weights::Dense { w: &dense }, kh, kw, cin,
                        cout, 2, ExecMode::Dense, &mut cd);
        let mut cs = OpCounts::default();
        let ys = conv2d(&x, &Weights::Lut { dict: &dict, assign: &assign },
                        kh, kw, cin, cout, 2, ExecMode::ShiftOnly, &mut cs);
        assert!(cs.is_multiplierless());
        assert!(cs.shifts > 0);
        for (a, b) in yd.data.iter().zip(&ys.data) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    #[should_panic(expected = "pow-2")]
    fn shift_only_rejects_non_pow2_dict() {
        let dict = vec![0.3f32, 1.0];
        let assign = vec![0u32; 4];
        let x = Tensor::zeros(vec![1, 2, 2, 1]);
        let mut c = OpCounts::default();
        conv2d(&x, &Weights::Lut { dict: &dict, assign: &assign }, 2, 2, 1,
               1, 1, ExecMode::ShiftOnly, &mut c);
    }

    #[test]
    fn affine_lut_equals_dense() {
        let mut r = Rng::new(8);
        let (i, o) = (16, 6);
        let dict = vec![-1.0f32, 0.5];
        let assign: Vec<u32> =
            (0..i * o).map(|_| r.below(2) as u32).collect();
        let dense: Vec<f32> =
            assign.iter().map(|&a| dict[a as usize]).collect();
        let bias: Vec<f32> = r.normals(o);
        let x = randn(vec![3, i], 9);
        let mut c1 = OpCounts::default();
        let y1 = affine(&x, &Weights::Dense { w: &dense }, &bias, i, o,
                        ExecMode::Dense, &mut c1);
        let mut c2 = OpCounts::default();
        let y2 = affine(&x, &Weights::Lut { dict: &dict, assign: &assign },
                        &bias, i, o, ExecMode::LutTrick, &mut c2);
        for (a, b) in y1.data.iter().zip(&y2.data) {
            assert!((a - b).abs() < 1e-4);
        }
        assert_eq!(c1.mults, (3 * o * i) as u64);
        assert_eq!(c2.mults, (3 * o * 2) as u64);
    }

    #[test]
    fn batchnorm_fold_and_mlbn() {
        let x = randn(vec![2, 4, 4, 3], 11);
        let gamma = vec![1.0f32, 2.0, 0.5];
        let beta = vec![0.1f32, -0.2, 0.0];
        let rmean = vec![0.5f32, -1.0, 0.0];
        let rvar = vec![1.0f32, 4.0, 0.25];
        let mut c = OpCounts::default();
        let y = batchnorm(&x, &gamma, &beta, &rmean, &rvar, false, &mut c);
        // check one element by hand
        let a0 = 1.0 / (1.0f32 + 1e-5).sqrt();
        let expect = a0 * (x.at4(0, 0, 0, 0) - 0.5) + 0.1;
        assert!((y.at4(0, 0, 0, 0) - expect).abs() < 1e-5);
        assert!(c.mults > 0);

        let mut cm = OpCounts::default();
        let ym = batchnorm(&x, &gamma, &beta, &rmean, &rvar, true, &mut cm);
        assert!(cm.is_multiplierless());
        assert!(cm.shifts == x.elems() as u64);
        // mlbn output close to standard bn (scale rounded to pow2)
        for (a, b) in y.data.iter().zip(&ym.data) {
            assert!((a - b).abs() < 1.0);
        }
    }

    #[test]
    fn maxpool_2x2() {
        let x = Tensor::new(vec![1, 2, 2, 1], vec![1.0, 3.0, 2.0, 0.5]);
        let y = maxpool(&x, 2, 2);
        assert_eq!(y.dims, vec![1, 1, 1, 1]);
        assert_eq!(y.data[0], 3.0);
    }

    #[test]
    fn gap_averages() {
        let x = Tensor::new(vec![1, 2, 2, 1], vec![1.0, 2.0, 3.0, 6.0]);
        let mut c = OpCounts::default();
        let y = gap(&x, &mut c);
        assert_eq!(y.dims, vec![1, 1]);
        assert_eq!(y.data[0], 3.0);
    }

    #[test]
    fn act_quant_snaps_to_grid() {
        let x = Tensor::new(vec![4], vec![-1.0, 0.3, 0.5, 1.0]);
        let y = act_quant(&x, 8);
        let scale = 1.0 / 127.0;
        for (&orig, &q) in x.data.iter().zip(&y.data) {
            assert!((q - orig).abs() <= scale / 2.0 + 1e-6);
            let g = q / scale;
            assert!((g - g.round()).abs() < 1e-4);
        }
        // bits=0 is identity
        assert_eq!(act_quant(&x, 0).data, x.data);
    }
}
