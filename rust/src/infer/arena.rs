//! Preallocated buffer arena for plan execution.
//!
//! A [`Scratch`] owns every byte the executor touches: the ping-pong
//! activation buffers, one buffer per residual `save` slot, and per-worker
//! im2col patch / bucket-accumulator areas. Buffers are sized once from
//! the plan's static shape-inference pass (growth-only, so re-running with
//! the same batch size never allocates) and reused across `run_into`
//! calls — the steady-state hot loop is allocation-free.

use super::plan::{Plan, Shape};

/// Reusable execution state for one [`Plan`] (or several plans, at the
/// cost of growing to the largest — buffers never shrink).
#[derive(Debug, Default)]
pub struct Scratch {
    /// current activations, packed `[batch][per-sample elems]`
    pub(crate) cur: Vec<f32>,
    /// destination buffer for shape-changing steps (swapped with `cur`)
    pub(crate) next: Vec<f32>,
    /// one full-batch buffer per residual `save` slot
    pub(crate) saves: Vec<Vec<f32>>,
    /// im2col patch area, `threads` chunks of `plan.patch_elems`
    pub(crate) patch: Vec<f32>,
    /// LUT bucket accumulators, `threads` chunks of
    /// `plan.bucket_elems()` (an `OC_TILE x k_max` tile per worker, so
    /// backends can bucket several output channels per patch read)
    pub(crate) buckets: Vec<f32>,
    /// quantized-activation area for the int backend, `threads` chunks
    /// of `plan.qpatch_elems()` (empty for float backends)
    pub(crate) qpatch: Vec<i16>,
    /// i32 bucket accumulators for the int backends' shift combine,
    /// `threads` chunks of `plan.ibucket_elems()` (`OC_TILE` rows of
    /// `k_max` so the tiled kernels bucket four output channels per
    /// pass over the quantized patch)
    pub(crate) ibuckets: Vec<i32>,
    out_dims: Vec<usize>,
    out_elems: usize,
}

impl Scratch {
    pub fn new() -> Scratch {
        Scratch { out_dims: Vec::with_capacity(4), ..Default::default() }
    }

    /// Provision every buffer for `batch` samples of `plan`. Growth-only:
    /// a second call with the same plan and batch is a no-op.
    pub(crate) fn ensure(&mut self, plan: &Plan, batch: usize) {
        let act = batch * plan.max_elems;
        grow(&mut self.cur, act);
        grow(&mut self.next, act);
        if self.saves.len() < plan.slot_elems.len() {
            self.saves.resize(plan.slot_elems.len(), Vec::new());
        }
        for (buf, &elems) in self.saves.iter_mut().zip(&plan.slot_elems) {
            grow(buf, batch * elems);
        }
        grow(&mut self.patch, plan.threads() * plan.patch_elems);
        grow(&mut self.buckets, plan.threads() * plan.bucket_elems());
        grow(&mut self.qpatch, plan.threads() * plan.qpatch_elems());
        grow(&mut self.ibuckets, plan.threads() * plan.ibucket_elems());
    }

    pub(crate) fn set_output(&mut self, batch: usize, shape: &Shape) {
        self.out_dims.clear();
        self.out_dims.push(batch);
        self.out_dims.extend_from_slice(shape.dims());
        self.out_elems = batch * shape.elems();
    }

    /// Dims and data of the last run's output (borrowed from the arena —
    /// valid until the next `run_into`).
    pub fn output(&self) -> (&[usize], &[f32]) {
        (&self.out_dims, &self.cur[..self.out_elems])
    }
}

fn grow<T: Copy + Default>(buf: &mut Vec<T>, n: usize) {
    if buf.len() < n {
        buf.resize(n, T::default());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grow_is_monotonic() {
        let mut v: Vec<f32> = Vec::new();
        grow(&mut v, 8);
        assert_eq!(v.len(), 8);
        let ptr = v.as_ptr();
        grow(&mut v, 4);
        assert_eq!(v.len(), 8);
        assert_eq!(v.as_ptr(), ptr);
    }
}
