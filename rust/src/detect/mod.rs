//! Detection evaluation substrate: boxes, IoU, NMS, YOLO grid decoding and
//! PASCAL-style average precision / mAP. Everything the VOC experiment
//! (paper §2, detection results) needs on the Rust side.

use crate::data::detection::GtBox;

/// A decoded detection in relative image coordinates.
#[derive(Debug, Clone, Copy)]
pub struct Detection {
    pub cx: f32,
    pub cy: f32,
    pub w: f32,
    pub h: f32,
    pub class: usize,
    pub score: f32,
}

/// Intersection-over-union of two center-format boxes.
pub fn iou(a: (f32, f32, f32, f32), b: (f32, f32, f32, f32)) -> f32 {
    let (ax0, ay0, ax1, ay1) =
        (a.0 - a.2 / 2.0, a.1 - a.3 / 2.0, a.0 + a.2 / 2.0, a.1 + a.3 / 2.0);
    let (bx0, by0, bx1, by1) =
        (b.0 - b.2 / 2.0, b.1 - b.3 / 2.0, b.0 + b.2 / 2.0, b.1 + b.3 / 2.0);
    let ix = (ax1.min(bx1) - ax0.max(bx0)).max(0.0);
    let iy = (ay1.min(by1) - ay0.max(by0)).max(0.0);
    let inter = ix * iy;
    // areas from the same computed corners so iou(a, a) == 1 exactly
    let union =
        (ax1 - ax0) * (ay1 - ay0) + (bx1 - bx0) * (by1 - by0) - inter;
    if union <= 0.0 {
        0.0
    } else {
        inter / union
    }
}

#[inline]
fn dbox(d: &Detection) -> (f32, f32, f32, f32) {
    (d.cx, d.cy, d.w, d.h)
}

#[inline]
fn gbox(g: &GtBox) -> (f32, f32, f32, f32) {
    (g.cx, g.cy, g.w, g.h)
}

/// Per-class greedy non-maximum suppression.
pub fn nms(mut dets: Vec<Detection>, iou_thr: f32) -> Vec<Detection> {
    dets.sort_by(|a, b| b.score.partial_cmp(&a.score).unwrap());
    let mut keep: Vec<Detection> = Vec::new();
    for d in dets {
        let suppressed = keep.iter().any(|k| {
            k.class == d.class && iou(dbox(k), dbox(&d)) > iou_thr
        });
        if !suppressed {
            keep.push(d);
        }
    }
    keep
}

/// Decode the tiny_yolo output grid (S,S,5+C) into detections.
/// Channels per cell: (tx, ty, tw, th, obj_logit, class_logits...).
pub fn decode_yolo(pred: &[f32], grid: usize, num_classes: usize,
                   conf_thr: f32) -> Vec<Detection> {
    let ch = 5 + num_classes;
    assert_eq!(pred.len(), grid * grid * ch);
    let mut out = Vec::new();
    for gy in 0..grid {
        for gx in 0..grid {
            let base = (gy * grid + gx) * ch;
            let obj = sigmoid(pred[base + 4]);
            if obj < conf_thr {
                continue;
            }
            let tx = sigmoid(pred[base]);
            let ty = sigmoid(pred[base + 1]);
            let tw = pred[base + 2].clamp(0.01, 1.0);
            let th = pred[base + 3].clamp(0.01, 1.0);
            let cls_logits = &pred[base + 5..base + 5 + num_classes];
            let class = crate::util::stats::argmax(cls_logits);
            let cls_prob = softmax_prob(cls_logits, class);
            out.push(Detection {
                cx: (gx as f32 + tx) / grid as f32,
                cy: (gy as f32 + ty) / grid as f32,
                w: tw,
                h: th,
                class,
                score: obj * cls_prob,
            });
        }
    }
    out
}

fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

fn softmax_prob(logits: &[f32], idx: usize) -> f32 {
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let z: f32 = logits.iter().map(|l| (l - m).exp()).sum();
    (logits[idx] - m).exp() / z
}

/// One evaluated image: its detections and ground truth.
pub struct ImageEval {
    pub dets: Vec<Detection>,
    pub gts: Vec<GtBox>,
}

/// PASCAL VOC-style AP for one class (all-point interpolation) at iou_thr.
pub fn average_precision(images: &[ImageEval], class: usize,
                         iou_thr: f32) -> f32 {
    // gather detections of this class with (image, score)
    let mut dets: Vec<(usize, Detection)> = Vec::new();
    let mut n_gt = 0usize;
    for (i, im) in images.iter().enumerate() {
        n_gt += im.gts.iter().filter(|g| g.class == class).count();
        for d in im.dets.iter().filter(|d| d.class == class) {
            dets.push((i, *d));
        }
    }
    if n_gt == 0 {
        return f32::NAN; // class absent: excluded from mAP
    }
    dets.sort_by(|a, b| b.1.score.partial_cmp(&a.1.score).unwrap());

    let mut matched: Vec<Vec<bool>> = images
        .iter()
        .map(|im| vec![false; im.gts.len()])
        .collect();
    let mut tp = Vec::with_capacity(dets.len());
    for (img_idx, d) in &dets {
        let im = &images[*img_idx];
        let mut best = -1isize;
        let mut best_iou = iou_thr;
        for (gi, g) in im.gts.iter().enumerate() {
            if g.class != class || matched[*img_idx][gi] {
                continue;
            }
            let v = iou(dbox(d), gbox(g));
            if v >= best_iou {
                best_iou = v;
                best = gi as isize;
            }
        }
        if best >= 0 {
            matched[*img_idx][best as usize] = true;
            tp.push(1.0f32);
        } else {
            tp.push(0.0);
        }
    }
    // precision-recall curve
    let mut cum_tp = 0.0f32;
    let mut prec = Vec::with_capacity(tp.len());
    let mut rec = Vec::with_capacity(tp.len());
    for (i, &t) in tp.iter().enumerate() {
        cum_tp += t;
        prec.push(cum_tp / (i + 1) as f32);
        rec.push(cum_tp / n_gt as f32);
    }
    // all-point interpolated AP
    let mut ap = 0.0f32;
    let mut prev_r = 0.0f32;
    for i in 0..prec.len() {
        let p_max = prec[i..].iter().cloned().fold(0.0f32, f32::max);
        ap += (rec[i] - prev_r) * p_max;
        prev_r = rec[i];
    }
    ap
}

/// Mean AP over classes present in the ground truth.
pub fn mean_average_precision(images: &[ImageEval], num_classes: usize,
                              iou_thr: f32) -> f32 {
    let mut sum = 0.0f32;
    let mut n = 0;
    for c in 0..num_classes {
        let ap = average_precision(images, c, iou_thr);
        if !ap.is_nan() {
            sum += ap;
            n += 1;
        }
    }
    if n == 0 {
        0.0
    } else {
        sum / n as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn det(cx: f32, cy: f32, w: f32, h: f32, class: usize,
           score: f32) -> Detection {
        Detection { cx, cy, w, h, class, score }
    }

    fn gt(cx: f32, cy: f32, w: f32, h: f32, class: usize) -> GtBox {
        GtBox { cx, cy, w, h, class }
    }

    #[test]
    fn iou_identity_and_disjoint() {
        let b = (0.5, 0.5, 0.2, 0.2);
        assert!((iou(b, b) - 1.0).abs() < 1e-6);
        assert_eq!(iou(b, (0.9, 0.9, 0.1, 0.1)), 0.0);
    }

    #[test]
    fn iou_half_overlap() {
        // two 0.2x0.2 boxes offset by half a width: inter = 0.1*0.2
        let a = (0.5, 0.5, 0.2, 0.2);
        let b = (0.6, 0.5, 0.2, 0.2);
        let expect = 0.02 / (0.04 + 0.04 - 0.02);
        assert!((iou(a, b) - expect).abs() < 1e-6);
    }

    #[test]
    fn nms_suppresses_same_class_only() {
        let dets = vec![
            det(0.5, 0.5, 0.2, 0.2, 0, 0.9),
            det(0.51, 0.5, 0.2, 0.2, 0, 0.8), // overlaps, same class
            det(0.51, 0.5, 0.2, 0.2, 1, 0.7), // overlaps, other class
            det(0.1, 0.1, 0.1, 0.1, 0, 0.6),  // far away
        ];
        let kept = nms(dets, 0.5);
        assert_eq!(kept.len(), 3);
        assert!(kept.iter().any(|d| d.class == 1));
    }

    #[test]
    fn perfect_detector_map_is_one() {
        let images: Vec<ImageEval> = (0..5)
            .map(|i| {
                let g = gt(0.3 + 0.05 * i as f32, 0.5, 0.2, 0.3, i % 2);
                ImageEval {
                    dets: vec![det(g.cx, g.cy, g.w, g.h, g.class, 0.9)],
                    gts: vec![g],
                }
            })
            .collect();
        let map = mean_average_precision(&images, 2, 0.5);
        assert!((map - 1.0).abs() < 1e-6);
    }

    #[test]
    fn false_positives_lower_ap() {
        let g = gt(0.5, 0.5, 0.2, 0.2, 0);
        let images = vec![ImageEval {
            dets: vec![
                det(0.9, 0.9, 0.05, 0.05, 0, 0.95), // FP ranked first
                det(0.5, 0.5, 0.2, 0.2, 0, 0.9),
            ],
            gts: vec![g],
        }];
        let ap = average_precision(&images, 0, 0.5);
        assert!((ap - 0.5).abs() < 1e-6);
    }

    #[test]
    fn missed_gt_lowers_recall() {
        let images = vec![ImageEval {
            dets: vec![det(0.5, 0.5, 0.2, 0.2, 0, 0.9)],
            gts: vec![gt(0.5, 0.5, 0.2, 0.2, 0), gt(0.1, 0.1, 0.1, 0.1, 0)],
        }];
        let ap = average_precision(&images, 0, 0.5);
        assert!((ap - 0.5).abs() < 1e-6);
    }

    #[test]
    fn duplicate_detections_count_once() {
        let g = gt(0.5, 0.5, 0.2, 0.2, 0);
        let images = vec![ImageEval {
            dets: vec![
                det(0.5, 0.5, 0.2, 0.2, 0, 0.9),
                det(0.5, 0.5, 0.2, 0.2, 0, 0.8), // duplicate -> FP
            ],
            gts: vec![g],
        }];
        let ap = average_precision(&images, 0, 0.5);
        assert!((ap - 1.0).abs() < 1e-6); // recall hits 1.0 at rank 1
    }

    #[test]
    fn absent_class_is_nan_and_excluded() {
        let images = vec![ImageEval { dets: vec![], gts: vec![gt(0.5, 0.5, 0.2, 0.2, 0)] }];
        assert!(average_precision(&images, 3, 0.5).is_nan());
        assert_eq!(mean_average_precision(&images, 4, 0.5), 0.0);
    }

    #[test]
    fn decode_yolo_positions() {
        let grid = 2;
        let nc = 2;
        let ch = 5 + nc;
        let mut pred = vec![0f32; grid * grid * ch];
        // put a confident detection in cell (1,0): gx=1, gy=0
        let base = (0 * grid + 1) * ch;
        pred[base] = 0.0; // tx -> sigmoid 0.5
        pred[base + 1] = 0.0;
        pred[base + 2] = 0.3;
        pred[base + 3] = 0.4;
        pred[base + 4] = 5.0; // obj
        pred[base + 5] = 3.0; // class 0
        let dets = decode_yolo(&pred, grid, nc, 0.5);
        // all other cells have obj logit 0 -> sigmoid 0.5 >= thr 0.5? use
        // strict: sigmoid(0)=0.5, conf_thr=0.5 -> passes (>=). Count >= 1
        let strong: Vec<_> =
            dets.iter().filter(|d| d.score > 0.6).collect();
        assert_eq!(strong.len(), 1);
        let d = strong[0];
        assert!((d.cx - 0.75).abs() < 1e-6);
        assert!((d.cy - 0.25).abs() < 1e-6);
        assert_eq!(d.class, 0);
        assert!((d.w - 0.3).abs() < 1e-6);
    }
}
