//! Multi-threaded batch prefetching: worker threads render/augment batches
//! ahead of the training loop so the PJRT execute never waits on data.
//!
//! Determinism: batch *order* is fixed by the batcher seed regardless of
//! worker count — workers are handed (sequence_number, index-list) jobs and
//! the consumer reassembles in sequence order.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;

use super::{Batch, Batcher, Dataset};
use crate::util::Rng;

struct Job {
    seq: u64,
    indices: Vec<usize>,
}

pub struct Prefetcher {
    rx: Receiver<(u64, Batch)>,
    pending: HashMap<u64, Batch>,
    next_seq: u64,
    stop: Arc<AtomicBool>,
    feeder: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl Prefetcher {
    /// Spawn `n_workers` render threads over a shareable dataset. `depth`
    /// bounds the number of in-flight batches (backpressure).
    pub fn new<D: Dataset + 'static>(ds: Arc<D>, batch_size: usize,
                                     seed: u64, n_workers: usize,
                                     depth: usize) -> Self {
        assert!(n_workers >= 1 && depth >= 1);
        let stop = Arc::new(AtomicBool::new(false));
        let (job_tx, job_rx) = sync_channel::<Job>(depth);
        let job_rx = Arc::new(std::sync::Mutex::new(job_rx));
        let (out_tx, out_rx) = sync_channel::<(u64, Batch)>(depth);

        // feeder: draws the deterministic index order from a Batcher-like
        // shuffler and queues jobs
        let feeder = {
            let ds = ds.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut order: Vec<usize> = (0..ds.len()).collect();
                let mut rng = Rng::new(seed);
                rng.shuffle(&mut order);
                let mut cursor = 0usize;
                let mut seq = 0u64;
                loop {
                    if stop.load(Ordering::Relaxed) {
                        return;
                    }
                    let mut indices = Vec::with_capacity(batch_size);
                    for _ in 0..batch_size {
                        if cursor >= order.len() {
                            cursor = 0;
                            rng.shuffle(&mut order);
                        }
                        indices.push(order[cursor]);
                        cursor += 1;
                    }
                    if job_tx.send(Job { seq, indices }).is_err() {
                        return;
                    }
                    seq += 1;
                }
            })
        };

        let workers = (0..n_workers)
            .map(|w| {
                let ds = ds.clone();
                let job_rx = job_rx.clone();
                let out_tx: SyncSender<(u64, Batch)> = out_tx.clone();
                let stop = stop.clone();
                std::thread::spawn(move || {
                    let ie = ds.input_elems();
                    let te = ds.target_elems();
                    loop {
                        if stop.load(Ordering::Relaxed) {
                            return;
                        }
                        let job = match job_rx.lock().unwrap().recv() {
                            Ok(j) => j,
                            Err(_) => return,
                        };
                        // augmentation rng: deterministic per (seed, seq)
                        let mut rng =
                            Rng::new(seed ^ 0xF00D).split(job.seq + 1);
                        let mut batch = Batch {
                            x: vec![0f32; job.indices.len() * ie],
                            t: vec![0f32; job.indices.len() * te],
                            size: job.indices.len(),
                            indices: job.indices.clone(),
                        };
                        for (i, &idx) in job.indices.iter().enumerate() {
                            ds.sample(
                                idx,
                                &mut batch.x[i * ie..(i + 1) * ie],
                                &mut batch.t[i * te..(i + 1) * te],
                                &mut rng,
                            );
                        }
                        if out_tx.send((job.seq, batch)).is_err() {
                            return;
                        }
                    }
                    #[allow(unreachable_code)]
                    {
                        let _ = w;
                    }
                })
            })
            .collect();

        Prefetcher {
            rx: out_rx,
            pending: HashMap::new(),
            next_seq: 0,
            stop,
            feeder: Some(feeder),
            workers,
        }
    }

    /// Blocking: next batch in deterministic sequence order.
    pub fn next_batch(&mut self) -> Batch {
        loop {
            if let Some(b) = self.pending.remove(&self.next_seq) {
                self.next_seq += 1;
                return b;
            }
            let (seq, batch) = self
                .rx
                .recv()
                .expect("prefetch workers died");
            self.pending.insert(seq, batch);
        }
    }
}

impl Drop for Prefetcher {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // drain so blocked senders can observe the closed channel
        while self.rx.try_recv().is_ok() {}
        drop(std::mem::replace(&mut self.rx, {
            let (_tx, rx) = sync_channel(1);
            rx
        }));
        if let Some(f) = self.feeder.take() {
            let _ = f.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Single-threaded fallback with the same deterministic order as
/// `Prefetcher` (used to verify determinism and by tiny examples).
pub fn sequential_batches(ds: &dyn Dataset, batch_size: usize, seed: u64,
                          n: usize) -> Vec<Batch> {
    let _ = Batcher::new(ds, batch_size, seed, true); // order parity check
    let mut order: Vec<usize> = (0..ds.len()).collect();
    let mut rng = Rng::new(seed);
    rng.shuffle(&mut order);
    let mut cursor = 0usize;
    let ie = ds.input_elems();
    let te = ds.target_elems();
    (0..n as u64)
        .map(|seq| {
            let mut indices = Vec::with_capacity(batch_size);
            for _ in 0..batch_size {
                if cursor >= order.len() {
                    cursor = 0;
                    rng.shuffle(&mut order);
                }
                indices.push(order[cursor]);
                cursor += 1;
            }
            let mut rng2 = Rng::new(seed ^ 0xF00D).split(seq + 1);
            let mut batch = Batch {
                x: vec![0f32; batch_size * ie],
                t: vec![0f32; batch_size * te],
                size: batch_size,
                indices: indices.clone(),
            };
            for (i, &idx) in indices.iter().enumerate() {
                ds.sample(
                    idx,
                    &mut batch.x[i * ie..(i + 1) * ie],
                    &mut batch.t[i * te..(i + 1) * te],
                    &mut rng2,
                );
            }
            batch
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticImages;

    #[test]
    fn prefetcher_matches_sequential_order() {
        let ds = Arc::new(SyntheticImages::cifar(64, 5));
        let seq = sequential_batches(ds.as_ref(), 8, 42, 6);
        let mut pf = Prefetcher::new(ds, 8, 42, 3, 4);
        for want in seq {
            let got = pf.next_batch();
            assert_eq!(got.indices, want.indices);
            assert_eq!(got.x, want.x);
        }
    }

    #[test]
    fn prefetcher_shuts_down_cleanly() {
        let ds = Arc::new(SyntheticImages::cifar(32, 1));
        let mut pf = Prefetcher::new(ds, 4, 1, 2, 2);
        let _ = pf.next_batch();
        drop(pf); // must not hang
    }
}
