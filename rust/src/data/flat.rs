//! Flat-vector classification dataset (for MLP artifacts): class-
//! conditional Gaussian clusters in R^d — each class owns a random mean
//! direction; samples add isotropic noise. Linearly separable at low noise,
//! which is exactly what the quickstart MLP needs.

use super::Dataset;
use crate::util::Rng;

#[derive(Debug, Clone)]
pub struct FlatVectors {
    pub dim: usize,
    pub num_classes: usize,
    len: usize,
    seed: u64,
    noise: f32,
    means: Vec<Vec<f32>>,
}

impl FlatVectors {
    pub fn new(dim: usize, num_classes: usize, len: usize, seed: u64,
               noise: f32) -> Self {
        let mut rng = Rng::new(seed ^ 0xF1A7);
        let means = (0..num_classes)
            .map(|_| (0..dim).map(|_| rng.normal()).collect())
            .collect();
        FlatVectors { dim, num_classes, len, seed, noise, means }
    }

    pub fn label(&self, idx: usize) -> usize {
        idx % self.num_classes
    }
}

impl Dataset for FlatVectors {
    fn len(&self) -> usize {
        self.len
    }

    fn input_elems(&self) -> usize {
        self.dim
    }

    fn target_elems(&self) -> usize {
        self.num_classes
    }

    fn sample(&self, idx: usize, x: &mut [f32], t: &mut [f32],
              _rng: &mut Rng) {
        let cls = self.label(idx);
        let mut srng = Rng::new(self.seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(idx as u64));
        for (xi, m) in x.iter_mut().zip(&self.means[cls]) {
            *xi = m + self.noise * srng.normal();
        }
        t.fill(0.0);
        t[cls] = 1.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_labeled() {
        let ds = FlatVectors::new(16, 4, 100, 3, 0.5);
        let mut a = vec![0f32; 16];
        let mut b = vec![0f32; 16];
        let mut t = vec![0f32; 4];
        let mut rng = Rng::new(0);
        ds.sample(7, &mut a, &mut t, &mut rng);
        ds.sample(7, &mut b, &mut t, &mut rng);
        assert_eq!(a, b);
        assert_eq!(t[7 % 4], 1.0);
    }

    #[test]
    fn classes_cluster() {
        let ds = FlatVectors::new(8, 2, 100, 1, 0.1);
        let mut x = vec![0f32; 8];
        let mut t = vec![0f32; 2];
        let mut rng = Rng::new(0);
        // samples of the same class are close; other class far
        ds.sample(0, &mut x, &mut t, &mut rng);
        let a0 = x.clone();
        ds.sample(2, &mut x, &mut t, &mut rng);
        let a1 = x.clone();
        ds.sample(1, &mut x, &mut t, &mut rng);
        let b0 = x.clone();
        let d_same: f32 = a0.iter().zip(&a1).map(|(p, q)| (p - q).powi(2))
            .sum();
        let d_diff: f32 = a0.iter().zip(&b0).map(|(p, q)| (p - q).powi(2))
            .sum();
        assert!(d_same < d_diff);
    }
}
