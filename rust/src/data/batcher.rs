//! Shuffling batcher: assembles fixed-size flat batches from a [`Dataset`].
//!
//! Artifacts have static shapes, so every batch has exactly `batch_size`
//! examples; a trailing remainder wraps around into the next epoch's order
//! (standard practice for steps-based training loops).

use super::Dataset;
use crate::util::Rng;

/// One training batch, NHWC-flattened inputs + flat targets.
#[derive(Debug, Clone)]
pub struct Batch {
    pub x: Vec<f32>,
    pub t: Vec<f32>,
    pub size: usize,
    /// dataset indices in this batch (for debugging / mAP matching)
    pub indices: Vec<usize>,
}

pub struct Batcher<'a> {
    ds: &'a dyn Dataset,
    batch_size: usize,
    order: Vec<usize>,
    cursor: usize,
    epoch: usize,
    shuffle: bool,
    rng: Rng,
    aug_rng: Rng,
}

impl<'a> Batcher<'a> {
    pub fn new(ds: &'a dyn Dataset, batch_size: usize, seed: u64,
               shuffle: bool) -> Self {
        assert!(batch_size > 0 && ds.len() > 0);
        let mut b = Batcher {
            ds,
            batch_size,
            order: (0..ds.len()).collect(),
            cursor: 0,
            epoch: 0,
            shuffle,
            rng: Rng::new(seed),
            aug_rng: Rng::new(seed ^ 0xAAAA_5555),
        };
        if shuffle {
            b.rng.shuffle(&mut b.order);
        }
        b
    }

    pub fn epoch(&self) -> usize {
        self.epoch
    }

    /// Produce the next batch (wraps across epochs, reshuffling).
    pub fn next_batch(&mut self) -> Batch {
        let ie = self.ds.input_elems();
        let te = self.ds.target_elems();
        let mut batch = Batch {
            x: vec![0f32; self.batch_size * ie],
            t: vec![0f32; self.batch_size * te],
            size: self.batch_size,
            indices: Vec::with_capacity(self.batch_size),
        };
        for i in 0..self.batch_size {
            if self.cursor >= self.order.len() {
                self.cursor = 0;
                self.epoch += 1;
                if self.shuffle {
                    self.rng.shuffle(&mut self.order);
                }
            }
            let idx = self.order[self.cursor];
            self.cursor += 1;
            batch.indices.push(idx);
            self.ds.sample(
                idx,
                &mut batch.x[i * ie..(i + 1) * ie],
                &mut batch.t[i * te..(i + 1) * te],
                &mut self.aug_rng,
            );
        }
        batch
    }

    /// Iterate the dataset once in index order (for evaluation), padding
    /// the final batch by repeating the last example; returns (batch,
    /// valid_count) pairs.
    pub fn eval_batches(ds: &'a dyn Dataset, batch_size: usize)
                        -> Vec<(Batch, usize)> {
        let ie = ds.input_elems();
        let te = ds.target_elems();
        let mut out = Vec::new();
        let mut rng = Rng::new(0); // eval: augmentation must be off in ds
        let mut i = 0;
        while i < ds.len() {
            let valid = batch_size.min(ds.len() - i);
            let mut batch = Batch {
                x: vec![0f32; batch_size * ie],
                t: vec![0f32; batch_size * te],
                size: batch_size,
                indices: Vec::with_capacity(batch_size),
            };
            for j in 0..batch_size {
                let idx = (i + j).min(ds.len() - 1);
                batch.indices.push(idx);
                ds.sample(
                    idx,
                    &mut batch.x[j * ie..(j + 1) * ie],
                    &mut batch.t[j * te..(j + 1) * te],
                    &mut rng,
                );
            }
            out.push((batch, valid));
            i += valid;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticImages;

    #[test]
    fn batches_have_static_shape() {
        let ds = SyntheticImages::cifar(10, 1);
        let mut b = Batcher::new(&ds, 4, 0, true);
        for _ in 0..5 {
            let batch = b.next_batch();
            assert_eq!(batch.x.len(), 4 * ds.input_elems());
            assert_eq!(batch.t.len(), 4 * 10);
            assert_eq!(batch.indices.len(), 4);
        }
        // 5 batches of 4 over 10 examples = 2 epochs done
        assert_eq!(b.epoch(), 1);
    }

    #[test]
    fn epoch_covers_every_index() {
        let ds = SyntheticImages::cifar(16, 1);
        let mut b = Batcher::new(&ds, 4, 7, true);
        let mut seen = vec![false; 16];
        for _ in 0..4 {
            for &i in &b.next_batch().indices {
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_changes_order_across_epochs() {
        let ds = SyntheticImages::cifar(32, 1);
        let mut b = Batcher::new(&ds, 32, 3, true);
        let e0 = b.next_batch().indices.clone();
        let e1 = b.next_batch().indices.clone();
        assert_ne!(e0, e1);
    }

    #[test]
    fn unshuffled_is_sequential() {
        let ds = SyntheticImages::cifar(8, 1);
        let mut b = Batcher::new(&ds, 4, 0, false);
        assert_eq!(b.next_batch().indices, vec![0, 1, 2, 3]);
        assert_eq!(b.next_batch().indices, vec![4, 5, 6, 7]);
    }

    #[test]
    fn eval_batches_cover_all_with_padding() {
        let ds = SyntheticImages::cifar(10, 1);
        let batches = Batcher::eval_batches(&ds, 4);
        assert_eq!(batches.len(), 3);
        assert_eq!(batches[0].1, 4);
        assert_eq!(batches[2].1, 2); // 2 valid in the padded final batch
        assert_eq!(batches[2].0.indices, vec![8, 9, 9, 9]);
    }
}
