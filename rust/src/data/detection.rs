//! Synthetic detection dataset — the Pascal VOC stand-in (DESIGN.md §2).
//!
//! Images contain 1..=3 solid axis-aligned rectangles ("objects") over a
//! textured background; the object class is its color prototype. Targets
//! are encoded YOLO-style on a (grid x grid) cell map:
//!   channel 0      objectness (1 if an object center falls in the cell)
//!   channels 1..3  (tx, ty) center offset within the cell, in [0, 1]
//!   channels 3..5  (tw, th) box size relative to the image, in (0, 1]
//!   channels 5..   one-hot class
//! matching the tiny_yolo head in python/compile/models.py.

use super::Dataset;
use crate::util::Rng;

/// Ground-truth box in relative [0,1] image coordinates.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GtBox {
    pub cx: f32,
    pub cy: f32,
    pub w: f32,
    pub h: f32,
    pub class: usize,
}

#[derive(Debug, Clone)]
pub struct SyntheticShapes {
    pub hw: usize,
    pub grid: usize,
    pub num_classes: usize,
    len: usize,
    seed: u64,
    class_colors: Vec<[f32; 3]>,
}

impl SyntheticShapes {
    pub fn new(len: usize, seed: u64) -> Self {
        Self::with_dims(len, seed, 32, 4, 4)
    }

    pub fn with_dims(len: usize, seed: u64, hw: usize, grid: usize,
                     num_classes: usize) -> Self {
        let mut rng = Rng::new(seed ^ 0xDE7EC7);
        let class_colors = (0..num_classes)
            .map(|_| {
                [rng.range_f32(-2.0, 2.0), rng.range_f32(-2.0, 2.0),
                 rng.range_f32(-2.0, 2.0)]
            })
            .collect();
        SyntheticShapes { hw, grid, num_classes, len, seed, class_colors }
    }

    fn sample_rng(&self, idx: usize) -> Rng {
        Rng::new(self.seed
            .wrapping_mul(0x2545F4914F6CDD1D)
            .wrapping_add(idx as u64))
    }

    /// Ground-truth boxes for example `idx` (pure function of the index).
    /// At most one object per grid cell (later objects that land in an
    /// occupied cell are dropped, matching the single-box target encoding).
    pub fn ground_truth(&self, idx: usize) -> Vec<GtBox> {
        let mut rng = self.sample_rng(idx);
        let n = 1 + rng.below(3);
        let mut boxes: Vec<GtBox> = Vec::new();
        let mut occupied = vec![false; self.grid * self.grid];
        for _ in 0..n {
            let w = rng.range_f32(0.2, 0.45);
            let h = rng.range_f32(0.2, 0.45);
            let cx = rng.range_f32(w / 2.0, 1.0 - w / 2.0);
            let cy = rng.range_f32(h / 2.0, 1.0 - h / 2.0);
            let class = rng.below(self.num_classes);
            let gx = ((cx * self.grid as f32) as usize).min(self.grid - 1);
            let gy = ((cy * self.grid as f32) as usize).min(self.grid - 1);
            if occupied[gy * self.grid + gx] {
                continue;
            }
            occupied[gy * self.grid + gx] = true;
            boxes.push(GtBox { cx, cy, w, h, class });
        }
        boxes
    }

    /// Render image `idx` (background texture + solid class-colored boxes).
    pub fn render(&self, idx: usize, out: &mut [f32]) {
        let mut rng = self.sample_rng(idx).split(77);
        let hw = self.hw;
        // low-frequency background
        let fx = rng.range_f32(0.5, 2.0);
        let fy = rng.range_f32(0.5, 2.0);
        let ph = rng.range_f32(0.0, std::f32::consts::TAU);
        for y in 0..hw {
            for x in 0..hw {
                let u = x as f32 / hw as f32;
                let v = y as f32 / hw as f32;
                let bg = 0.3
                    * (std::f32::consts::TAU * (fx * u + fy * v) + ph).sin();
                for c in 0..3 {
                    out[(y * hw + x) * 3 + c] = bg + 0.15 * rng.normal();
                }
            }
        }
        for b in self.ground_truth(idx) {
            let color = self.class_colors[b.class];
            let x0 = (((b.cx - b.w / 2.0) * hw as f32) as usize).min(hw - 1);
            let x1 = (((b.cx + b.w / 2.0) * hw as f32) as usize).min(hw - 1);
            let y0 = (((b.cy - b.h / 2.0) * hw as f32) as usize).min(hw - 1);
            let y1 = (((b.cy + b.h / 2.0) * hw as f32) as usize).min(hw - 1);
            for y in y0..=y1 {
                for x in x0..=x1 {
                    for c in 0..3 {
                        out[(y * hw + x) * 3 + c] = color[c];
                    }
                }
            }
        }
    }

    /// Encode the YOLO target grid for `idx` into `t`
    /// (grid*grid*(5+classes)).
    pub fn encode_target(&self, idx: usize, t: &mut [f32]) {
        t.fill(0.0);
        let s = self.grid;
        let ch = 5 + self.num_classes;
        for b in self.ground_truth(idx) {
            let gx = ((b.cx * s as f32) as usize).min(s - 1);
            let gy = ((b.cy * s as f32) as usize).min(s - 1);
            let base = (gy * s + gx) * ch;
            t[base] = 1.0;
            t[base + 1] = b.cx * s as f32 - gx as f32; // tx in [0,1)
            t[base + 2] = b.cy * s as f32 - gy as f32;
            t[base + 3] = b.w;
            t[base + 4] = b.h;
            t[base + 5 + b.class] = 1.0;
        }
    }
}

impl Dataset for SyntheticShapes {
    fn len(&self) -> usize {
        self.len
    }

    fn input_elems(&self) -> usize {
        self.hw * self.hw * 3
    }

    fn target_elems(&self) -> usize {
        self.grid * self.grid * (5 + self.num_classes)
    }

    fn sample(&self, idx: usize, x: &mut [f32], t: &mut [f32],
              _rng: &mut Rng) {
        self.render(idx, x);
        self.encode_target(idx, t);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ground_truth_deterministic_and_in_bounds() {
        let ds = SyntheticShapes::new(100, 3);
        for idx in 0..50 {
            let a = ds.ground_truth(idx);
            let b = ds.ground_truth(idx);
            assert_eq!(a, b);
            assert!(!a.is_empty() && a.len() <= 3);
            for g in &a {
                assert!(g.cx - g.w / 2.0 >= -1e-5);
                assert!(g.cx + g.w / 2.0 <= 1.0 + 1e-5);
                assert!(g.cy - g.h / 2.0 >= -1e-5);
                assert!(g.cy + g.h / 2.0 <= 1.0 + 1e-5);
                assert!(g.class < 4);
            }
        }
    }

    #[test]
    fn target_encoding_roundtrips_centers() {
        let ds = SyntheticShapes::new(100, 9);
        let mut t = vec![0f32; ds.target_elems()];
        for idx in 0..30 {
            ds.encode_target(idx, &mut t);
            let s = ds.grid;
            let ch = 5 + ds.num_classes;
            let gts = ds.ground_truth(idx);
            let mut found = 0;
            for gy in 0..s {
                for gx in 0..s {
                    let base = (gy * s + gx) * ch;
                    if t[base] > 0.5 {
                        found += 1;
                        let cx = (gx as f32 + t[base + 1]) / s as f32;
                        let cy = (gy as f32 + t[base + 2]) / s as f32;
                        // must match one ground-truth box
                        assert!(gts.iter().any(|g| (g.cx - cx).abs() < 1e-5
                            && (g.cy - cy).abs() < 1e-5));
                    }
                }
            }
            assert_eq!(found, gts.len());
        }
    }

    #[test]
    fn one_object_per_cell() {
        let ds = SyntheticShapes::new(500, 1);
        for idx in 0..200 {
            let gts = ds.ground_truth(idx);
            let mut cells = std::collections::HashSet::new();
            for g in gts {
                let gx = ((g.cx * 4.0) as usize).min(3);
                let gy = ((g.cy * 4.0) as usize).min(3);
                assert!(cells.insert((gx, gy)), "two objects in one cell");
            }
        }
    }

    #[test]
    fn boxes_are_visible_in_render() {
        let ds = SyntheticShapes::new(10, 4);
        let mut img = vec![0f32; ds.input_elems()];
        ds.render(0, &mut img);
        let g = ds.ground_truth(0)[0];
        let hw = ds.hw;
        let px = ((g.cx * hw as f32) as usize).min(hw - 1);
        let py = ((g.cy * hw as f32) as usize).min(hw - 1);
        let color = ds.class_colors[g.class];
        for c in 0..3 {
            assert_eq!(img[(py * hw + px) * 3 + c], color[c]);
        }
    }
}
