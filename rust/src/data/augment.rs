//! Training-time augmentation on NHWC-flattened images: the standard CIFAR
//! recipe (pad-4 + random crop, random horizontal flip).

use crate::util::Rng;

/// Random horizontal flip (p=0.5) + pad-`pad` random crop, in place.
pub fn random_flip_crop(img: &mut [f32], hw: usize, c: usize, pad: usize,
                        rng: &mut Rng) {
    if rng.bool(0.5) {
        hflip(img, hw, c);
    }
    let dx = rng.below(2 * pad + 1) as isize - pad as isize;
    let dy = rng.below(2 * pad + 1) as isize - pad as isize;
    shift(img, hw, c, dx, dy);
}

/// Horizontal mirror in place.
pub fn hflip(img: &mut [f32], hw: usize, c: usize) {
    for y in 0..hw {
        for x in 0..hw / 2 {
            let xr = hw - 1 - x;
            for ch in 0..c {
                img.swap((y * hw + x) * c + ch, (y * hw + xr) * c + ch);
            }
        }
    }
}

/// Translate by (dx, dy) with zero fill (equivalent to pad+crop).
pub fn shift(img: &mut [f32], hw: usize, c: usize, dx: isize, dy: isize) {
    if dx == 0 && dy == 0 {
        return;
    }
    let src = img.to_vec();
    img.fill(0.0);
    for y in 0..hw as isize {
        let sy = y + dy;
        if sy < 0 || sy >= hw as isize {
            continue;
        }
        for x in 0..hw as isize {
            let sx = x + dx;
            if sx < 0 || sx >= hw as isize {
                continue;
            }
            let di = ((y * hw as isize + x) * c as isize) as usize;
            let si = ((sy * hw as isize + sx) * c as isize) as usize;
            img[di..di + c].copy_from_slice(&src[si..si + c]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn img3x3() -> Vec<f32> {
        (0..9).map(|i| i as f32).collect()
    }

    #[test]
    fn hflip_involution() {
        let mut a = img3x3();
        let orig = a.clone();
        hflip(&mut a, 3, 1);
        assert_eq!(a, vec![2., 1., 0., 5., 4., 3., 8., 7., 6.]);
        hflip(&mut a, 3, 1);
        assert_eq!(a, orig);
    }

    #[test]
    fn shift_moves_and_zero_fills() {
        let mut a = img3x3();
        shift(&mut a, 3, 1, 1, 0); // sample from x+1: last col zero
        assert_eq!(a, vec![1., 2., 0., 4., 5., 0., 7., 8., 0.]);
    }

    #[test]
    fn zero_shift_noop() {
        let mut a = img3x3();
        shift(&mut a, 3, 1, 0, 0);
        assert_eq!(a, img3x3());
    }

    #[test]
    fn multichannel_flip_keeps_channels_together() {
        // 2x2, c=2: pixels [p00 p01; p10 p11], values (px, px+0.5)
        let mut a = vec![0.0, 0.5, 1.0, 1.5, 2.0, 2.5, 3.0, 3.5];
        hflip(&mut a, 2, 2);
        assert_eq!(a, vec![1.0, 1.5, 0.0, 0.5, 3.0, 3.5, 2.0, 2.5]);
    }
}
