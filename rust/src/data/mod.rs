//! Data substrate: synthetic dataset generators (the offline stand-ins for
//! CIFAR-10 / ImageNet / Pascal VOC — see DESIGN.md §2), augmentation, a
//! shuffling batcher and a multi-threaded prefetch pipeline.

pub mod augment;
pub mod batcher;
pub mod detection;
pub mod flat;
pub mod prefetch;
pub mod synthetic;

pub use batcher::{Batch, Batcher};
pub use detection::SyntheticShapes;
pub use flat::FlatVectors;
pub use prefetch::Prefetcher;
pub use synthetic::SyntheticImages;

use crate::util::Rng;

/// A deterministic, indexable dataset producing (input, target) pairs.
/// `sample` writes NHWC-flattened input and the flat target tensor; `rng`
/// drives augmentation only (the underlying example is a pure function of
/// the index, so epochs are reproducible and train/val splits are exact).
pub trait Dataset: Send + Sync {
    fn len(&self) -> usize;
    fn input_elems(&self) -> usize;
    fn target_elems(&self) -> usize;
    fn sample(&self, idx: usize, x: &mut [f32], t: &mut [f32], rng: &mut Rng);

    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A contiguous index window over another dataset — the train/val split
/// mechanism: both views share the same generative "world" (class
/// prototypes etc. derive from the inner dataset's seed) but cover
/// disjoint example indices.
pub struct Slice {
    inner: std::sync::Arc<dyn Dataset>,
    offset: usize,
    len: usize,
}

impl Slice {
    pub fn new(inner: std::sync::Arc<dyn Dataset>, offset: usize,
               len: usize) -> Self {
        assert!(offset + len <= inner.len(),
                "slice [{offset}, {}) out of range {}", offset + len,
                inner.len());
        Slice { inner, offset, len }
    }
}

impl Dataset for Slice {
    fn len(&self) -> usize {
        self.len
    }

    fn input_elems(&self) -> usize {
        self.inner.input_elems()
    }

    fn target_elems(&self) -> usize {
        self.inner.target_elems()
    }

    fn sample(&self, idx: usize, x: &mut [f32], t: &mut [f32],
              rng: &mut Rng) {
        assert!(idx < self.len);
        self.inner.sample(idx + self.offset, x, t, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn slice_windows_inner_indices() {
        let ds = Arc::new(SyntheticImages::cifar(100, 1));
        let train = Slice::new(ds.clone(), 0, 80);
        let eval = Slice::new(ds.clone(), 80, 20);
        assert_eq!(train.len(), 80);
        assert_eq!(eval.len(), 20);
        let mut a = vec![0f32; ds.input_elems()];
        let mut b = vec![0f32; ds.input_elems()];
        let mut t = vec![0f32; 10];
        let mut rng = Rng::new(0);
        eval.sample(0, &mut a, &mut t, &mut rng);
        ds.sample(80, &mut b, &mut t, &mut rng);
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn slice_bounds_checked() {
        let ds = Arc::new(SyntheticImages::cifar(10, 1));
        Slice::new(ds, 5, 6);
    }
}
