//! Procedural class-conditional image generator — the CIFAR-10/ImageNet
//! stand-in (DESIGN.md §2).
//!
//! Every class owns a random low-frequency "texture prototype" (a mixture
//! of 2-D sinusoids with class-specific frequencies, orientations and RGB
//! gains) plus a class-specific blob location. A sample = prototype
//! + per-sample phase jitter + blob position jitter + pixel noise. The
//! signal is learnable by a small CNN (translation-ish invariant texture
//! statistics) but not linearly separable from raw pixels, which is what a
//! quantization study needs: the error-rate *deltas* between quantized and
//! fp32 models track weight-representation fidelity.

use super::Dataset;
use crate::util::Rng;

#[derive(Debug, Clone, Copy)]
struct Wave {
    fx: f32,
    fy: f32,
    phase: f32,
    rgb: [f32; 3],
}

#[derive(Debug, Clone)]
struct ClassProto {
    waves: Vec<Wave>,
    blob_cx: f32,
    blob_cy: f32,
    blob_rgb: [f32; 3],
}

#[derive(Debug, Clone)]
pub struct SyntheticImages {
    pub hw: usize,
    pub channels: usize,
    pub num_classes: usize,
    len: usize,
    seed: u64,
    noise: f32,
    protos: Vec<ClassProto>,
    /// augmentation: pad-crop + flip (train) vs deterministic center (eval)
    pub augment: bool,
}

impl SyntheticImages {
    /// CIFAR-like: 10 classes, 32x32x3, moderate noise.
    pub fn cifar(len: usize, seed: u64) -> Self {
        Self::new(32, 3, 10, len, seed, 0.35)
    }

    /// ImageNet-like stand-in: more classes, higher intra-class noise.
    pub fn imagenet(len: usize, seed: u64) -> Self {
        Self::new(32, 3, 20, len, seed, 0.5)
    }

    pub fn new(hw: usize, channels: usize, num_classes: usize, len: usize,
               seed: u64, noise: f32) -> Self {
        let mut rng = Rng::new(seed ^ 0xC1A55E5);
        let protos = (0..num_classes)
            .map(|_| {
                let waves = (0..4)
                    .map(|_| Wave {
                        fx: rng.range_f32(0.5, 3.0),
                        fy: rng.range_f32(0.5, 3.0),
                        phase: rng.range_f32(0.0, std::f32::consts::TAU),
                        rgb: [rng.range_f32(-1.0, 1.0),
                              rng.range_f32(-1.0, 1.0),
                              rng.range_f32(-1.0, 1.0)],
                    })
                    .collect();
                ClassProto {
                    waves,
                    blob_cx: rng.range_f32(0.25, 0.75),
                    blob_cy: rng.range_f32(0.25, 0.75),
                    blob_rgb: [rng.range_f32(-1.5, 1.5),
                               rng.range_f32(-1.5, 1.5),
                               rng.range_f32(-1.5, 1.5)],
                }
            })
            .collect();
        SyntheticImages {
            hw,
            channels,
            num_classes,
            len,
            seed,
            noise,
            protos,
            augment: false,
        }
    }

    pub fn with_augment(mut self, on: bool) -> Self {
        self.augment = on;
        self
    }

    pub fn label(&self, idx: usize) -> usize {
        // fixed, balanced label assignment
        idx % self.num_classes
    }

    /// Render the un-augmented image for `idx` into `out` (hw*hw*c, NHWC).
    pub fn render(&self, idx: usize, out: &mut [f32]) {
        let cls = self.label(idx);
        let proto = &self.protos[cls];
        let mut srng = Rng::new(self.seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(idx as u64));
        // per-sample jitter
        let pj: Vec<f32> = (0..proto.waves.len())
            .map(|_| srng.range_f32(-0.6, 0.6))
            .collect();
        let bx = proto.blob_cx + srng.range_f32(-0.1, 0.1);
        let by = proto.blob_cy + srng.range_f32(-0.1, 0.1);
        let br = srng.range_f32(0.15, 0.25);
        let hw = self.hw;
        let c = self.channels;
        for y in 0..hw {
            for x in 0..hw {
                let u = x as f32 / hw as f32;
                let v = y as f32 / hw as f32;
                let mut px = [0f32; 3];
                for (w, &jit) in proto.waves.iter().zip(&pj) {
                    let s = (std::f32::consts::TAU
                        * (w.fx * u + w.fy * v)
                        + w.phase
                        + jit)
                        .sin();
                    for ch in 0..c.min(3) {
                        px[ch] += 0.4 * s * w.rgb[ch];
                    }
                }
                // class blob (soft disc)
                let d2 = (u - bx) * (u - bx) + (v - by) * (v - by);
                let blob = (-d2 / (br * br)).exp();
                for ch in 0..c.min(3) {
                    px[ch] += blob * proto.blob_rgb[ch];
                }
                for ch in 0..c {
                    let val = px[ch.min(2)] + self.noise * srng.normal();
                    out[(y * hw + x) * c + ch] = val.clamp(-3.0, 3.0);
                }
            }
        }
    }
}

impl Dataset for SyntheticImages {
    fn len(&self) -> usize {
        self.len
    }

    fn input_elems(&self) -> usize {
        self.hw * self.hw * self.channels
    }

    fn target_elems(&self) -> usize {
        self.num_classes
    }

    fn sample(&self, idx: usize, x: &mut [f32], t: &mut [f32],
              rng: &mut Rng) {
        self.render(idx, x);
        if self.augment {
            super::augment::random_flip_crop(x, self.hw, self.channels, 4,
                                             rng);
        }
        t.fill(0.0);
        t[self.label(idx)] = 1.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_render() {
        let ds = SyntheticImages::cifar(100, 7);
        let mut a = vec![0f32; ds.input_elems()];
        let mut b = vec![0f32; ds.input_elems()];
        ds.render(13, &mut a);
        ds.render(13, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn different_samples_differ() {
        let ds = SyntheticImages::cifar(100, 7);
        let mut a = vec![0f32; ds.input_elems()];
        let mut b = vec![0f32; ds.input_elems()];
        ds.render(0, &mut a);
        ds.render(10, &mut b); // same class (10 % 10 == 0), other sample
        assert_ne!(a, b);
    }

    #[test]
    fn classes_are_separable_by_template_matching() {
        // nearest-class-mean in pixel space should beat chance by a lot —
        // sanity that the class signal exists for a model to learn.
        let ds = SyntheticImages::cifar(2000, 3);
        let e = ds.input_elems();
        let k = ds.num_classes;
        let mut means = vec![vec![0f32; e]; k];
        let mut counts = vec![0usize; k];
        let mut buf = vec![0f32; e];
        for i in 0..1000 {
            ds.render(i, &mut buf);
            let c = ds.label(i);
            for (m, &v) in means[c].iter_mut().zip(&buf) {
                *m += v;
            }
            counts[c] += 1;
        }
        for (m, &n) in means.iter_mut().zip(&counts) {
            for v in m.iter_mut() {
                *v /= n as f32;
            }
        }
        let mut correct = 0;
        for i in 1000..1500 {
            ds.render(i, &mut buf);
            let mut best = 0;
            let mut bd = f32::INFINITY;
            for (c, m) in means.iter().enumerate() {
                let d: f32 = m
                    .iter()
                    .zip(&buf)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                if d < bd {
                    bd = d;
                    best = c;
                }
            }
            if best == ds.label(i) {
                correct += 1;
            }
        }
        let acc = correct as f32 / 500.0;
        assert!(acc > 0.5, "template-matching acc only {acc}");
    }

    #[test]
    fn balanced_labels() {
        let ds = SyntheticImages::cifar(1000, 1);
        let mut counts = vec![0usize; 10];
        for i in 0..1000 {
            counts[ds.label(i)] += 1;
        }
        assert!(counts.iter().all(|&c| c == 100));
    }

    #[test]
    fn dataset_trait_writes_onehot() {
        let ds = SyntheticImages::cifar(50, 2);
        let mut x = vec![0f32; ds.input_elems()];
        let mut t = vec![0f32; ds.target_elems()];
        let mut rng = Rng::new(0);
        ds.sample(23, &mut x, &mut t, &mut rng);
        assert_eq!(t.iter().sum::<f32>(), 1.0);
        assert_eq!(t[23 % 10], 1.0);
    }
}
