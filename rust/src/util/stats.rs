//! Streaming summary statistics (mean/var/min/max) and small helpers used
//! by metrics logging and the bench harness.

#[derive(Debug, Clone, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Welford online update.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Exact quantile of a copied, sorted slice (small n; used by reports).
pub fn quantile(xs: &[f32], q: f64) -> f32 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((v.len() - 1) as f64 * q.clamp(0.0, 1.0)).round() as usize;
    v[idx]
}

/// argmax over f32 slice.
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    let _ = xs[best];
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut s = Summary::new();
        for &x in &xs {
            s.push(x);
        }
        assert!((s.mean() - 4.0).abs() < 1e-12);
        let direct_var =
            xs.iter().map(|x| (x - 4.0) * (x - 4.0)).sum::<f64>() / 4.0;
        assert!((s.var() - direct_var).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 10.0);
    }

    #[test]
    fn quantile_endpoints() {
        let xs = [3.0_f32, 1.0, 2.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 3.0);
        assert_eq!(quantile(&xs, 0.5), 2.0);
    }

    #[test]
    fn argmax_first_max() {
        assert_eq!(argmax(&[1.0, 5.0, 5.0, 2.0]), 1);
    }
}
