//! Small self-contained utilities: deterministic RNG, timing, logging and
//! summary statistics.
//!
//! The build is fully offline (only the `xla` crate's vendored closure is
//! available), so these replace `rand`, `log`/`env_logger` and friends.

pub mod rng;
pub mod stats;
pub mod timer;

pub use rng::Rng;
pub use stats::Summary;
pub use timer::Timer;

use std::sync::atomic::{AtomicU8, Ordering};

static LOG_LEVEL: AtomicU8 = AtomicU8::new(2); // 0=off 1=error 2=info 3=debug

/// Set the global log verbosity (0 = off, 1 = error, 2 = info, 3 = debug).
pub fn set_log_level(level: u8) {
    LOG_LEVEL.store(level, Ordering::Relaxed);
}

pub fn log_enabled(level: u8) -> bool {
    LOG_LEVEL.load(Ordering::Relaxed) >= level
}

/// Wall-clock-stamped info line: `[   12.345s] msg`.
#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        if $crate::util::log_enabled(2) {
            eprintln!("[{:>9.3}s] {}", $crate::util::timer::since_start(),
                      format!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        if $crate::util::log_enabled(3) {
            eprintln!("[{:>9.3}s] DBG {}", $crate::util::timer::since_start(),
                      format!($($arg)*));
        }
    };
}

/// Human-readable byte size (paper-style memory footprints).
pub fn human_bytes(b: u64) -> String {
    const U: [&str; 5] = ["B", "KB", "MB", "GB", "TB"];
    let mut v = b as f64;
    let mut i = 0;
    while v >= 1024.0 && i < U.len() - 1 {
        v /= 1024.0;
        i += 1;
    }
    if i == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", U[i])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_bytes_formats() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KB");
        assert_eq!(human_bytes(7_400_000), "7.06 MB");
    }
}
