//! Wall-clock timing helpers for the coordinator and the bench harness.

use std::sync::OnceLock;
use std::time::Instant;

static START: OnceLock<Instant> = OnceLock::new();

/// Seconds since the process first asked for the time (lazy epoch).
pub fn since_start() -> f64 {
    START.get_or_init(Instant::now).elapsed().as_secs_f64()
}

/// Simple scoped timer.
pub struct Timer {
    t0: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { t0: Instant::now() }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.t0.elapsed().as_secs_f64() * 1e3
    }

    pub fn elapsed_ns(&self) -> u128 {
        self.t0.elapsed().as_nanos()
    }
}

/// Measure median/p10/p90 of `f` over `iters` runs after `warmup` runs.
/// This is the offline substitute for criterion used by rust/benches/.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut ns: Vec<u128> = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Timer::start();
        f();
        ns.push(t.elapsed_ns());
    }
    ns.sort();
    BenchResult {
        median_ns: ns[ns.len() / 2],
        p10_ns: ns[ns.len() / 10],
        p90_ns: ns[ns.len() * 9 / 10],
        iters,
    }
}

#[derive(Debug, Clone, Copy)]
pub struct BenchResult {
    pub median_ns: u128,
    pub p10_ns: u128,
    pub p90_ns: u128,
    pub iters: usize,
}

impl BenchResult {
    pub fn median_ms(&self) -> f64 {
        self.median_ns as f64 / 1e6
    }
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "median {:.3} ms (p10 {:.3}, p90 {:.3}, n={})",
            self.median_ns as f64 / 1e6,
            self.p10_ns as f64 / 1e6,
            self.p90_ns as f64 / 1e6,
            self.iters
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_orders_percentiles() {
        let r = bench(1, 20, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(r.p10_ns <= r.median_ns && r.median_ns <= r.p90_ns);
    }
}
