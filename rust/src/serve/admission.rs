//! Deadline-aware admission control: reject requests that provably
//! cannot meet their client deadline *before* they consume a queue slot.
//!
//! The only overload behavior the batcher itself offers is blocking
//! backpressure (bounded queues). Under sustained overload that turns
//! every caller into a latecomer: requests queue for longer than their
//! deadline, execute anyway, and the answer is thrown away by a client
//! that already timed out. This module adds the missing early rejection:
//!
//! * Each model slot tracks an **EWMA of its per-batch service time**,
//!   observed by the server's workers after every executed batch.
//! * At admission, the predicted queueing delay is
//!   `(queue_depth / batch_cap + 1) * ewma_batch_ms` — the number of
//!   batches ahead of this request (including the one it would ride)
//!   times the smoothed per-batch cost.
//! * A request carrying a deadline is rejected immediately
//!   ([`Rejection`], HTTP 429) when that prediction exceeds its
//!   remaining budget, or when the budget is already spent.
//!
//! Requests without a deadline are always admitted (blocking
//! backpressure still applies), so in-process callers see no behavior
//! change. Requests that are admitted but overstay their deadline in the
//! queue are shed at batch-formation time by the
//! [`Batcher`](super::Batcher) — see `ReplyError::DeadlineExceeded`.
//!
//! Gates are keyed by registry **slot id**, so two live versions of one
//! model keep separate EWMAs (a v2 compiled against a slower kernel
//! cannot poison v1's admission predictions). The gate set grows via
//! [`Admission::grow`] when a version is hot-loaded; growth only appends,
//! matching the registry's append-only slot ids.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;
use std::time::Duration;

/// EWMA smoothing factor: ~the last 5 batches dominate the estimate, so
/// the gate adapts within a few batches after a load or plan change.
const EWMA_ALPHA: f64 = 0.2;

/// Why a request was turned away at admission (HTTP 429).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Rejection {
    /// predicted queueing delay had the request been admitted
    pub predicted_ms: f64,
    /// what was left of the client deadline at admission time
    pub budget_ms: f64,
}

impl std::fmt::Display for Rejection {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "deadline_exceeded: predicted queue wait {:.1} ms exceeds \
             the {:.1} ms left of the client deadline",
            self.predicted_ms, self.budget_ms
        )
    }
}

impl std::error::Error for Rejection {}

struct ModelGate {
    /// EWMA of per-batch service time in ms, stored as f64 bits
    /// (0.0 until the first batch completes — optimistic start)
    ewma_ms: AtomicU64,
    /// requests rejected at admission
    rejected: AtomicU64,
}

impl ModelGate {
    fn new() -> ModelGate {
        ModelGate {
            ewma_ms: AtomicU64::new(0f64.to_bits()),
            rejected: AtomicU64::new(0),
        }
    }
}

/// Per-slot admission state: service-time EWMAs and rejection counters.
/// Hot-path operations take only a read lock and are otherwise
/// lock-free; the EWMA update is a racy read-modify-write by design (it
/// smooths a noisy signal, it is not an exact accumulator). The write
/// lock is taken only by [`Admission::grow`] during a model load.
pub struct Admission {
    models: RwLock<Vec<ModelGate>>,
    /// assumed per-batch service time in ms while a model has no
    /// observations yet (0.0 = legacy optimism: admit everything)
    prior_ms: f64,
}

impl Admission {
    pub fn new(models: usize) -> Admission {
        Admission::with_prior(models, 0.0)
    }

    /// An admission gate whose cold-start models predict `prior_ms`
    /// per batch instead of 0. Without a prior, a model that has never
    /// executed a batch predicts zero queue wait and admits *any*
    /// deadline no matter how deep its queue already is — the first
    /// traffic spike after a deploy queues blind and every latecomer
    /// times out in queue. A prior around the model's expected batch
    /// time makes cold models shed early instead; it stops mattering
    /// after the first real batch lands in the EWMA.
    pub fn with_prior(models: usize, prior_ms: f64) -> Admission {
        Admission {
            models: RwLock::new(
                (0..models).map(|_| ModelGate::new()).collect(),
            ),
            prior_ms: if prior_ms.is_finite() {
                prior_ms.max(0.0)
            } else {
                0.0
            },
        }
    }

    /// Append gates until at least `total` slots are covered (no-op if
    /// already that large). New gates start cold, so a freshly loaded
    /// version predicts from the configured prior until its first batch.
    pub fn grow(&self, total: usize) {
        let mut g = self.models.write().unwrap();
        while g.len() < total {
            g.push(ModelGate::new());
        }
    }

    /// Number of slots currently gated.
    pub fn len(&self) -> usize {
        self.models.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.models.read().unwrap().is_empty()
    }

    /// Fold one observed per-batch service time into `model`'s EWMA
    /// (called by the server workers after every executed batch).
    /// Out-of-range slots are ignored — never a panic.
    pub fn observe_batch_ms(&self, model: usize, ms: f64) {
        if !ms.is_finite() || ms < 0.0 {
            return;
        }
        let models = self.models.read().unwrap();
        let Some(g) = models.get(model) else { return };
        let prev = f64::from_bits(g.ewma_ms.load(Ordering::Relaxed));
        let next = if prev == 0.0 {
            ms
        } else {
            prev + EWMA_ALPHA * (ms - prev)
        };
        g.ewma_ms.store(next.to_bits(), Ordering::Relaxed);
    }

    /// Current smoothed per-batch service time (0.0 before any batch or
    /// for out-of-range slots).
    pub fn ewma_batch_ms(&self, model: usize) -> f64 {
        let models = self.models.read().unwrap();
        models
            .get(model)
            .map_or(0.0, |g| f64::from_bits(
                g.ewma_ms.load(Ordering::Relaxed)))
    }

    /// Requests turned away at admission so far (0 for out-of-range).
    pub fn rejected(&self, model: usize) -> u64 {
        let models = self.models.read().unwrap();
        models
            .get(model)
            .map_or(0, |g| g.rejected.load(Ordering::Relaxed))
    }

    /// Largest per-model EWMA across the server — the whole-server
    /// service-time hint the cluster router seeds its shard weighting
    /// from before it has observations of its own (0.0 before any
    /// batch anywhere).
    pub fn max_ewma_batch_ms(&self) -> f64 {
        let models = self.models.read().unwrap();
        models
            .iter()
            .map(|g| f64::from_bits(g.ewma_ms.load(Ordering::Relaxed)))
            .fold(0.0, f64::max)
    }

    /// Predicted queueing delay if one more request joined a queue of
    /// `queued` requests coalesced `cap` at a time. Models with no
    /// observed batch yet predict from the configured prior (see
    /// [`Admission::with_prior`]).
    pub fn predicted_wait_ms(&self, model: usize, queued: usize,
                             cap: usize) -> f64 {
        let batches_ahead = queued / cap.max(1) + 1;
        let ewma = self.ewma_batch_ms(model);
        let per_batch = if ewma > 0.0 { ewma } else { self.prior_ms };
        batches_ahead as f64 * per_batch
    }

    /// Gate one request: `budget` is what remains of its client deadline
    /// (`None` = no deadline, always admitted). On rejection the model's
    /// counter is bumped and the caller gets the prediction that doomed
    /// the request.
    pub fn check(&self, model: usize, queued: usize, cap: usize,
                 budget: Option<Duration>)
                 -> std::result::Result<(), Rejection> {
        let Some(budget) = budget else { return Ok(()) };
        let budget_ms = budget.as_secs_f64() * 1e3;
        let predicted_ms = self.predicted_wait_ms(model, queued, cap);
        if budget_ms <= 0.0 || predicted_ms > budget_ms {
            let models = self.models.read().unwrap();
            if let Some(g) = models.get(model) {
                g.rejected.fetch_add(1, Ordering::Relaxed);
            }
            return Err(Rejection { predicted_ms, budget_ms });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ewma_starts_at_first_observation_then_smooths() {
        let a = Admission::new(1);
        assert_eq!(a.ewma_batch_ms(0), 0.0);
        a.observe_batch_ms(0, 10.0);
        assert_eq!(a.ewma_batch_ms(0), 10.0);
        a.observe_batch_ms(0, 20.0);
        let e = a.ewma_batch_ms(0);
        assert!(e > 10.0 && e < 20.0, "{e}");
        // junk observations are ignored
        a.observe_batch_ms(0, f64::NAN);
        a.observe_batch_ms(0, -1.0);
        assert_eq!(a.ewma_batch_ms(0), e);
    }

    #[test]
    fn no_deadline_is_always_admitted() {
        let a = Admission::new(1);
        a.observe_batch_ms(0, 1e9);
        assert!(a.check(0, 10_000, 1, None).is_ok());
        assert_eq!(a.rejected(0), 0);
    }

    #[test]
    fn spent_budget_is_rejected_even_with_empty_queue() {
        let a = Admission::new(1);
        let r = a.check(0, 0, 8, Some(Duration::ZERO)).unwrap_err();
        assert_eq!(r.budget_ms, 0.0);
        assert_eq!(a.rejected(0), 1);
        assert!(r.to_string().contains("deadline_exceeded"));
    }

    #[test]
    fn deep_queue_times_ewma_rejects_short_deadlines() {
        let a = Admission::new(2);
        a.observe_batch_ms(1, 10.0);
        // 32 queued / cap 8 -> 5 batches ahead -> ~50 ms predicted
        assert_eq!(a.predicted_wait_ms(1, 32, 8), 50.0);
        assert!(a
            .check(1, 32, 8, Some(Duration::from_millis(20)))
            .is_err());
        assert!(a
            .check(1, 32, 8, Some(Duration::from_millis(100)))
            .is_ok());
        assert_eq!(a.rejected(1), 1);
        // optimistic before any observation: admitted
        assert!(a
            .check(0, 32, 8, Some(Duration::from_millis(1)))
            .is_ok());
    }

    #[test]
    fn cold_start_prior_sheds_instead_of_queueing_blind() {
        let a = Admission::with_prior(1, 10.0);
        // no batch has ever run, but the prior predicts 5 batches
        // ahead x 10 ms = 50 ms > a 20 ms budget
        assert_eq!(a.predicted_wait_ms(0, 32, 8), 50.0);
        assert!(a
            .check(0, 32, 8, Some(Duration::from_millis(20)))
            .is_err());
        assert!(a
            .check(0, 32, 8, Some(Duration::from_millis(100)))
            .is_ok());
        // a real observation supersedes the prior entirely
        a.observe_batch_ms(0, 1.0);
        assert_eq!(a.predicted_wait_ms(0, 32, 8), 5.0);
        assert!(a
            .check(0, 32, 8, Some(Duration::from_millis(20)))
            .is_ok());
        // and the hint the router reads stays observation-only
        assert_eq!(a.ewma_batch_ms(0), 1.0);
        // junk priors are clamped to the legacy optimism
        let b = Admission::with_prior(1, f64::NAN);
        assert_eq!(b.predicted_wait_ms(0, 32, 8), 0.0);
    }

    #[test]
    fn grows_in_place_and_tolerates_out_of_range_slots() {
        let a = Admission::with_prior(1, 10.0);
        // out-of-range slots are inert, never a panic
        assert_eq!(a.ewma_batch_ms(5), 0.0);
        assert_eq!(a.rejected(5), 0);
        a.observe_batch_ms(5, 123.0);
        assert_eq!(a.max_ewma_batch_ms(), 0.0);
        // a hot-loaded slot appears cold, inheriting the prior
        a.grow(2);
        assert_eq!(a.len(), 2);
        assert_eq!(a.predicted_wait_ms(1, 32, 8), 50.0);
        a.observe_batch_ms(1, 4.0);
        assert_eq!(a.ewma_batch_ms(1), 4.0);
        // grow never shrinks
        a.grow(1);
        assert_eq!(a.len(), 2);
    }
}
