//! Multi-model serving over compiled plans: the public inference API.
//!
//! [`crate::infer::Plan`] gives one model's compile-once/run-many story;
//! this module is the layer that turns it into a serving system:
//!
//! * [`Registry`] — an interior-mutable versioned store: each loaded
//!   `name@version` compiles to an immutable `Arc<Plan>` exactly once
//!   and owns a stable slot id; hot [`Registry::load`] /
//!   [`Registry::unload`] / [`Registry::set_default`] run against live
//!   traffic, and requests pin their plan `Arc` at submit time so a
//!   default flip is a blue-green cutover (in-flight batches drain on
//!   the old plan, new requests ride the new one).
//! * [`Batcher`] — a bounded submission queue that coalesces single-image
//!   requests into dynamic batches (fill up to `max_batch`, flush partial
//!   batches after a `linger` deadline), preserving request identity so
//!   every caller gets back exactly its logits.
//! * [`Server`] — a worker-thread pool draining coalesced batches
//!   through `Plan::run_into` against per-slot pools of
//!   [`crate::infer::Scratch`] arenas; hot model lifecycle
//!   ([`Server::load_version`] / [`Server::unload_version`] /
//!   [`Server::set_default_version`]) and, with `max_workers > 0`, a
//!   queue-depth + EWMA-driven autoscaler that grows and shrinks the
//!   pool (decisions logged as `serve_scale` JSONL events); graceful
//!   shutdown drains the queue and per-model-version latency/throughput
//!   counters stream into the `coordinator::metrics` JSONL format.
//! * [`Admission`] — deadline-aware admission control: per-model EWMAs
//!   of batch service time predict the queueing delay, and requests
//!   whose client deadline provably cannot be met are rejected up front
//!   (HTTP 429) instead of queueing to die; admitted requests that
//!   overstay their deadline are shed at batch formation.
//! * [`HttpFront`] — a dependency-free HTTP/1.1 network front
//!   (`POST /v1/models/{name}:predict`, `GET /v1/models`, `GET /healthz`,
//!   `GET /metrics`) with the client deadline carried in the
//!   `x-lutq-deadline-ms` header or `deadline_ms` body field.
//! * [`wire`] — the binary framed front next to HTTP: length-prefixed
//!   frames with raw little-endian f32/i8 tensor bodies and batched
//!   multi-sample predicts, served by a [`WireServer`] over the same
//!   [`ServeBackend`] `Arc` (same deadlines, admission 429s, shedding
//!   and metrics), with a pooled [`WireClient`] counterpart.
//! * [`load`] — the request harnesses `lutq serve-bench` and the perf
//!   bench share to measure the serving path: closed-loop, in-process
//!   ([`load::closed_loop`]), over HTTP ([`load::closed_loop_http`]),
//!   over the binary protocol ([`load::closed_loop_wire`]), or through
//!   the sharding router ([`load::closed_loop_cluster`]); and
//!   open-loop ([`load::open_loop`]) under seeded [`load::Arrival`]
//!   schedules (Poisson / bursty / trace replay) producing
//!   latency-under-SLO curves free of coordinated omission.
//! * [`config`] — the typed configuration behind the serving CLI:
//!   [`ServeConfig`] / [`RouteConfig`] / [`LoadConfig`] own parsing,
//!   defaults and validation in one place, and [`ReplicaSpec`] unifies
//!   replica addressing as `host:port[@http|binary]`.
//! * [`cluster`] — the scale-out tier: a [`Router`] shards a batch's
//!   sample dimension across [`Replica`] backends (in-process
//!   [`Server`] handles, remote HTTP fronts, or remote binary wire
//!   fronts), merges the outputs in request order, weights shard sizes
//!   by per-replica service-time EWMAs, and fails over around dead
//!   backends. `lutq route` runs it behind the same [`HttpFront`] as
//!   `lutq serve` (both implement [`ServeBackend`]).
//!
//! ```text
//! let mut registry = serve::Registry::new();
//! // compile once; act_bits/mlbn come from the manifest's quant config
//! registry.register_manifest(&manifest, &model, ExecMode::LutTrick, 1)?;
//! let server = serve::Server::start(registry, serve::ServerConfig {
//!     workers: 8, max_batch: 16, ..Default::default()
//! })?;
//! let logits = server.infer("cifar_lutq4", &image)?;       // coalesced
//! let reports = server.shutdown();                         // drains queue
//! ```
//!
//! Correctness contract: responses never depend on batch composition.
//! Batch-invariant plans (no cross-sample steps) coalesce freely; plans
//! with per-tensor activation quant are capped at batch 1 automatically.
//! Either way a response is bit-identical to a direct single-sample
//! `Plan::run_into` of the same input.

pub mod admission;
pub mod batcher;
pub mod cluster;
pub mod config;
pub mod http;
pub mod load;
pub mod registry;
pub mod server;
pub mod wire;

pub use admission::{Admission, Rejection};
pub use batcher::{Batch, Batcher, ReplyError, SubmitRefusal, Ticket};
pub use cluster::{
    BreakerConfig, BreakerState, CircuitBreaker, HttpReplica,
    InProcessReplica, Replica, ReplicaError, RouteError, Router,
    RouterConfig, WireReplica,
};
pub use config::{
    LoadConfig, ReplicaSpec, RouteConfig, RouterKnobs, ServeConfig,
    ShardTransport,
};
pub use http::{
    AdminAction, AdminError, HttpClient, HttpConfig, HttpFront,
    PredictError, ServeBackend, DEADLINE_HEADER,
};
pub use registry::{
    split_versioned, LifecycleError, ModelInfo, Registry,
    DEFAULT_VERSION,
};
pub use server::{
    ModelReport, PlanLoader, ScaleEvent, Server, ServerConfig,
    SubmitError,
};
pub use wire::{WireClient, WireConfig, WireReply, WireServer};
