//! Multi-model plan registry: compile each exported model **once**, share
//! the immutable [`Plan`] across every worker, address models by name.
//!
//! Plans are `Send + Sync`, so the registry hands out `Arc<Plan>` clones;
//! the only per-worker state a server needs is a [`crate::infer::Scratch`]
//! per (model, worker) pair, pre-warmed via [`Plan::scratch_pool`].

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use crate::infer::{ExecMode, Plan, PlanOptions};
use crate::params::export::QuantizedModel;
use crate::runtime::Manifest;

/// One model's public identity, as listed by `GET /v1/models`.
#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub name: String,
    /// kernel backend the plan compiled against
    pub backend: String,
    /// per-sample input dims
    pub input: Vec<usize>,
    /// per-sample output dims
    pub output: Vec<usize>,
    /// false = batch-coupled plan, served at batch 1
    pub batch_invariant: bool,
}

/// Name-addressed collection of compiled plans. Ids are dense (`0..len`)
/// in registration order and stable for the registry's lifetime.
#[derive(Default)]
pub struct Registry {
    names: Vec<String>,
    plans: Vec<Arc<Plan>>,
    by_name: HashMap<String, usize>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Register a compiled plan under `name`; returns the model id.
    pub fn register(&mut self, name: &str, plan: Plan) -> Result<usize> {
        self.register_shared(name, Arc::new(plan))
    }

    /// Register an already-shared plan (lets the caller keep a handle to
    /// the same compiled artifact the server executes).
    pub fn register_shared(&mut self, name: &str,
                           plan: Arc<Plan>) -> Result<usize> {
        ensure!(!name.is_empty(), "serve: model name must be non-empty");
        if self.by_name.contains_key(name) {
            bail!("serve: model `{name}` is already registered");
        }
        let id = self.plans.len();
        self.names.push(name.to_string());
        self.plans.push(plan);
        self.by_name.insert(name.to_string(), id);
        Ok(id)
    }

    /// Compile an exported manifest's graph over its quantized model and
    /// register the resulting plan under the manifest's name. This is the
    /// one-stop path from `lutq export` artifacts to a serveable model:
    /// the quantization numerics (`act_bits`, `mlbn`) come from the
    /// manifest's own quant config so served logits can't silently
    /// contradict the exported model — callers choose only the execution
    /// mode and thread count.
    pub fn register_manifest(&mut self, man: &Manifest,
                             model: &QuantizedModel, mode: ExecMode,
                             threads: usize) -> Result<usize> {
        let opts = PlanOptions {
            mode,
            act_bits: man.act_bits(),
            mlbn: man.mlbn(),
            threads,
            ..PlanOptions::default()
        };
        let plan =
            Plan::compile(&man.graph, model, opts, &man.meta.input)
                .with_context(|| {
                    format!("serve: compile plan for model `{}`", man.name)
                })?;
        self.register(&man.name, plan)
    }

    pub fn id(&self, name: &str) -> Option<usize> {
        self.by_name.get(name).copied()
    }

    pub fn name(&self, id: usize) -> &str {
        &self.names[id]
    }

    pub fn plan(&self, name: &str) -> Option<&Arc<Plan>> {
        self.id(name).map(|id| &self.plans[id])
    }

    pub fn plan_by_id(&self, id: usize) -> &Arc<Plan> {
        &self.plans[id]
    }

    /// All plans in id order.
    pub fn plans(&self) -> &[Arc<Plan>] {
        &self.plans
    }

    /// All model names in id order.
    pub fn names(&self) -> Vec<&str> {
        self.names.iter().map(|s| s.as_str()).collect()
    }

    /// Public identity of every registered model, in id order — the rows
    /// the HTTP front's `GET /v1/models` listing serves.
    pub fn infos(&self) -> Vec<ModelInfo> {
        self.names
            .iter()
            .zip(&self.plans)
            .map(|(name, plan)| ModelInfo {
                name: name.clone(),
                backend: plan.backend_name().to_string(),
                input: plan.input_dims(),
                // output_dims(1) is [batch, per-sample...]; strip batch
                output: plan.output_dims(1)[1..].to_vec(),
                batch_invariant: plan.batch_invariant(),
            })
            .collect()
    }

    pub fn len(&self) -> usize {
        self.plans.len()
    }

    pub fn is_empty(&self) -> bool {
        self.plans.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::ExecMode;
    use crate::testkit::models::synth_mlp_model;

    fn mlp_plan() -> Plan {
        let (graph, model) = synth_mlp_model(4);
        Plan::compile(
            &graph,
            &model,
            PlanOptions { mode: ExecMode::LutTrick, act_bits: 0,
                          mlbn: false, threads: 1,
                          ..PlanOptions::default() },
            &[16],
        )
        .unwrap()
    }

    #[test]
    fn registers_and_resolves_by_name_and_id() {
        let mut reg = Registry::new();
        let a = reg.register("alpha", mlp_plan()).unwrap();
        let b = reg.register("beta", mlp_plan()).unwrap();
        assert_eq!((a, b), (0, 1));
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.id("beta"), Some(1));
        assert_eq!(reg.name(0), "alpha");
        assert_eq!(reg.names(), vec!["alpha", "beta"]);
        assert!(reg.plan("alpha").is_some());
        assert!(reg.plan("gamma").is_none());
        assert_eq!(reg.plan_by_id(1).input_dims(), vec![16]);
        let infos = reg.infos();
        assert_eq!(infos.len(), 2);
        assert_eq!(infos[0].name, "alpha");
        assert_eq!(infos[0].input, vec![16]);
        assert_eq!(infos[0].output, vec![10]);
        assert!(infos[0].batch_invariant);
        assert!(!infos[0].backend.is_empty());
    }

    #[test]
    fn rejects_duplicate_and_empty_names() {
        let mut reg = Registry::new();
        reg.register("m", mlp_plan()).unwrap();
        let err = reg.register("m", mlp_plan()).unwrap_err().to_string();
        assert!(err.contains("already registered"), "{err}");
        assert!(reg.register("", mlp_plan()).is_err());
        assert_eq!(reg.len(), 1);
    }
}
