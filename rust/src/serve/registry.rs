//! Versioned multi-model plan registry: compile each exported model
//! **once**, share the immutable [`Plan`] across every worker, address
//! models by `name` or `name@version`.
//!
//! Every loaded `(name, version)` pair owns a dense **slot id** that is
//! append-only and never reused: the server keys its queues, admission
//! gates, scratch pools and report rows by slot, so two versions of one
//! model never share mutable state — and a batch formed for one slot can
//! never mix plans. The registry itself is interior-mutable behind an
//! `RwLock`: [`Registry::load`] / [`Registry::unload`] /
//! [`Registry::set_default`] run against live traffic, and the default
//! flip is one atomic `Arc<Plan>` swap under the write lock (blue-green:
//! requests submitted before the flip drain against the plan `Arc` they
//! pinned at submit time, requests after it pin the new one).
//!
//! Plans are `Send + Sync`, so the registry hands out `Arc<Plan>` clones;
//! the only per-slot state a server needs is a pool of
//! [`crate::infer::Scratch`] arenas per slot.

use std::collections::{BTreeMap, HashMap};
use std::sync::{Arc, RwLock};

use anyhow::{bail, ensure, Context, Result};

use crate::infer::{ExecMode, Plan, PlanOptions};
use crate::params::export::QuantizedModel;
use crate::runtime::Manifest;

/// Version assigned to models registered through the legacy unversioned
/// API ([`Registry::register`] and friends).
pub const DEFAULT_VERSION: &str = "v1";

/// Split a model reference into `(name, explicit version)`:
/// `"m@v2"` -> `("m", Some("v2"))`, `"m"` -> `("m", None)`.
pub fn split_versioned(model: &str) -> (&str, Option<&str>) {
    match model.split_once('@') {
        Some((name, version)) => (name, Some(version)),
        None => (model, None),
    }
}

/// Typed model-lifecycle failure, so both network fronts can map each
/// cause to its status code (404 / 409 / 400) without string matching.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum LifecycleError {
    /// no model loaded under that base name (404)
    UnknownModel(String),
    /// the model exists but not that version (404)
    UnknownVersion(String),
    /// refusing to unload the version that is the current default (409)
    DefaultInUse(String),
    /// that `(name, version)` pair is already loaded (409)
    Duplicate(String),
    /// malformed name or version (400)
    Invalid(String),
}

impl std::fmt::Display for LifecycleError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LifecycleError::UnknownModel(m)
            | LifecycleError::UnknownVersion(m)
            | LifecycleError::DefaultInUse(m)
            | LifecycleError::Duplicate(m)
            | LifecycleError::Invalid(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for LifecycleError {}

/// One model version's public identity, as listed by `GET /v1/models`.
#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub name: String,
    /// version label this row describes
    pub version: String,
    /// true when this version answers unversioned `name` requests
    pub default: bool,
    /// kernel backend the plan compiled against
    pub backend: String,
    /// per-sample input dims
    pub input: Vec<usize>,
    /// per-sample output dims
    pub output: Vec<usize>,
    /// false = batch-coupled plan, served at batch 1
    pub batch_invariant: bool,
}

impl ModelInfo {
    /// `name@version` — the fully qualified reference for this row.
    pub fn qualified(&self) -> String {
        format!("{}@{}", self.name, self.version)
    }
}

/// One loaded `(name, version)` pair. The slot id (its index) stays
/// valid forever; unloading drops the plan but never the slot, so
/// in-flight ids can't be re-bound to a different model.
struct Slot {
    name: String,
    version: String,
    plan: Option<Arc<Plan>>,
    published: bool,
}

struct ModelEntry {
    /// version label -> slot id, live versions only
    versions: BTreeMap<String, usize>,
    /// which version answers unversioned requests
    default: String,
}

#[derive(Default)]
struct Inner {
    slots: Vec<Slot>,
    models: HashMap<String, ModelEntry>,
}

/// Interior-mutable, versioned collection of compiled plans. Slot ids
/// are dense (`0..slot_count`) in load order and stable for the
/// registry's lifetime.
#[derive(Default)]
pub struct Registry {
    inner: RwLock<Inner>,
}

fn validate_ident(kind: &str, s: &str) -> Result<(), LifecycleError> {
    if s.is_empty() {
        return Err(LifecycleError::Invalid(format!(
            "serve: model {kind} must be non-empty"
        )));
    }
    if s.contains('@') {
        return Err(LifecycleError::Invalid(format!(
            "serve: model {kind} `{s}` must not contain '@' \
             (it separates name from version)"
        )));
    }
    Ok(())
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    // ------------------------------------------------- legacy wrappers

    /// Register a compiled plan under `name` at [`DEFAULT_VERSION`];
    /// returns the slot id.
    pub fn register(&mut self, name: &str, plan: Plan) -> Result<usize> {
        self.register_shared(name, Arc::new(plan))
    }

    /// Register an already-shared plan (lets the caller keep a handle to
    /// the same compiled artifact the server executes).
    pub fn register_shared(&mut self, name: &str,
                           plan: Arc<Plan>) -> Result<usize> {
        ensure!(!name.is_empty(), "serve: model name must be non-empty");
        match self.load(name, DEFAULT_VERSION, plan) {
            Ok(id) => Ok(id),
            Err(LifecycleError::Duplicate(_)) => {
                bail!("serve: model `{name}` is already registered")
            }
            Err(e) => bail!("{e}"),
        }
    }

    /// Compile an exported manifest's graph over its quantized model and
    /// register the resulting plan under the manifest's name. This is the
    /// one-stop path from `lutq export` artifacts to a serveable model:
    /// the quantization numerics (`act_bits`, `mlbn`) come from the
    /// manifest's own quant config so served logits can't silently
    /// contradict the exported model — callers choose only the execution
    /// mode and thread count.
    pub fn register_manifest(&mut self, man: &Manifest,
                             model: &QuantizedModel, mode: ExecMode,
                             threads: usize) -> Result<usize> {
        let opts = PlanOptions {
            mode,
            act_bits: man.act_bits(),
            mlbn: man.mlbn(),
            threads,
            ..PlanOptions::default()
        };
        let plan =
            Plan::compile(&man.graph, model, opts, &man.meta.input)
                .with_context(|| {
                    format!("serve: compile plan for model `{}`", man.name)
                })?;
        self.register(&man.name, plan)
    }

    // ------------------------------------------------------- lifecycle

    /// Load one `(name, version)` pair: stage + publish in one step. The
    /// first version loaded for a new name becomes its default.
    pub fn load(&self, name: &str, version: &str, plan: Arc<Plan>)
                -> Result<usize, LifecycleError> {
        let id = self.stage(name, version, plan)?;
        self.publish(id)?;
        Ok(id)
    }

    /// Reserve a slot for `(name, version)` without making it routable.
    /// A server grows its queues/gates/pools to cover the new slot id
    /// between `stage` and [`publish`](Registry::publish), so no request
    /// can resolve to a slot its infrastructure doesn't cover yet.
    pub fn stage(&self, name: &str, version: &str, plan: Arc<Plan>)
                 -> Result<usize, LifecycleError> {
        validate_ident("name", name)?;
        validate_ident("version", version)?;
        let mut inner = self.inner.write().unwrap();
        let live = inner
            .models
            .get(name)
            .is_some_and(|e| e.versions.contains_key(version));
        let staged = inner.slots.iter().any(|s| {
            s.name == name && s.version == version && !s.published
                && s.plan.is_some()
        });
        if live || staged {
            return Err(LifecycleError::Duplicate(format!(
                "serve: model `{name}@{version}` is already loaded"
            )));
        }
        let id = inner.slots.len();
        inner.slots.push(Slot {
            name: name.to_string(),
            version: version.to_string(),
            plan: Some(plan),
            published: false,
        });
        Ok(id)
    }

    /// Make a staged slot routable. Idempotent. The first published
    /// version of a name becomes that name's default.
    pub fn publish(&self, id: usize) -> Result<(), LifecycleError> {
        let mut inner = self.inner.write().unwrap();
        let Inner { slots, models } = &mut *inner;
        let Some(slot) = slots.get_mut(id) else {
            return Err(LifecycleError::Invalid(format!(
                "serve: slot {id} does not exist"
            )));
        };
        if slot.published {
            return Ok(());
        }
        if slot.plan.is_none() {
            return Err(LifecycleError::Invalid(format!(
                "serve: slot {id} (`{}@{}`) was unloaded",
                slot.name, slot.version
            )));
        }
        slot.published = true;
        let entry = models
            .entry(slot.name.clone())
            .or_insert_with(|| ModelEntry {
                versions: BTreeMap::new(),
                default: slot.version.clone(),
            });
        entry.versions.insert(slot.version.clone(), id);
        Ok(())
    }

    /// Atomically flip which version answers unversioned `name`
    /// requests. In-flight batches keep the plan `Arc` they pinned at
    /// submit time, so the cutover is blue-green by construction.
    pub fn set_default(&self, name: &str, version: &str)
                       -> Result<(), LifecycleError> {
        let mut inner = self.inner.write().unwrap();
        let names: Vec<String> = inner.models.keys().cloned().collect();
        let Some(entry) = inner.models.get_mut(name) else {
            return Err(LifecycleError::UnknownModel(format!(
                "serve: unknown model `{name}` (loaded: {names:?})"
            )));
        };
        if !entry.versions.contains_key(version) {
            let have: Vec<&String> = entry.versions.keys().collect();
            return Err(LifecycleError::UnknownVersion(format!(
                "serve: model `{name}` has no version `{version}` \
                 (loaded: {have:?})"
            )));
        }
        entry.default = version.to_string();
        Ok(())
    }

    /// Drop one version: it leaves the catalog and its plan `Arc` is
    /// released (queued requests drain against the clones they pinned).
    /// The current default is refused with
    /// [`LifecycleError::DefaultInUse`] — flip the default first.
    /// Returns the freed slot id so the server can release its pools.
    pub fn unload(&self, name: &str, version: &str)
                  -> Result<usize, LifecycleError> {
        let mut inner = self.inner.write().unwrap();
        let Inner { slots, models } = &mut *inner;
        let Some(entry) = models.get_mut(name) else {
            return Err(LifecycleError::UnknownModel(format!(
                "serve: unknown model `{name}`"
            )));
        };
        let Some(&id) = entry.versions.get(version) else {
            let have: Vec<&String> = entry.versions.keys().collect();
            return Err(LifecycleError::UnknownVersion(format!(
                "serve: model `{name}` has no version `{version}` \
                 (loaded: {have:?})"
            )));
        };
        if entry.default == version {
            return Err(LifecycleError::DefaultInUse(format!(
                "serve: `{name}@{version}` is the default version; \
                 set another default before unloading it"
            )));
        }
        entry.versions.remove(version);
        slots[id].plan = None;
        slots[id].published = false;
        Ok(id)
    }

    // ------------------------------------------------------ resolution

    /// Resolve `name` or `name@version` to `(slot id, pinned plan)`.
    /// Unversioned references go to the model's current default.
    pub fn resolve(&self, model: &str) -> Option<(usize, Arc<Plan>)> {
        let (name, explicit) = split_versioned(model);
        let inner = self.inner.read().unwrap();
        let entry = inner.models.get(name)?;
        let version = explicit.unwrap_or(entry.default.as_str());
        let &id = entry.versions.get(version)?;
        let plan = inner.slots[id].plan.clone()?;
        Some((id, plan))
    }

    /// Slot id a `name` / `name@version` reference resolves to.
    pub fn id(&self, model: &str) -> Option<usize> {
        self.resolve(model).map(|(id, _)| id)
    }

    /// Base name of a slot (`None` for out-of-range ids — never panics).
    pub fn name(&self, id: usize) -> Option<String> {
        let inner = self.inner.read().unwrap();
        inner.slots.get(id).map(|s| s.name.clone())
    }

    /// `(name, version)` of a slot, out-of-range safe.
    pub fn slot_label(&self, id: usize) -> Option<(String, String)> {
        let inner = self.inner.read().unwrap();
        inner
            .slots
            .get(id)
            .map(|s| (s.name.clone(), s.version.clone()))
    }

    /// Pinned plan a `name` / `name@version` reference resolves to.
    pub fn plan(&self, model: &str) -> Option<Arc<Plan>> {
        self.resolve(model).map(|(_, plan)| plan)
    }

    /// Plan of a slot: `None` for out-of-range ids or unloaded slots —
    /// never panics (regression: this used to index unchecked).
    pub fn plan_by_id(&self, id: usize) -> Option<Arc<Plan>> {
        let inner = self.inner.read().unwrap();
        inner.slots.get(id).and_then(|s| s.plan.clone())
    }

    /// Every live published slot as `(slot id, name, version, plan)`,
    /// in slot order — the server's startup snapshot.
    pub fn live_slots(&self)
                      -> Vec<(usize, String, String, Arc<Plan>)> {
        let inner = self.inner.read().unwrap();
        inner
            .slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.published)
            .filter_map(|(i, s)| {
                s.plan
                    .clone()
                    .map(|p| (i, s.name.clone(), s.version.clone(), p))
            })
            .collect()
    }

    /// Distinct base names in first-load order.
    pub fn names(&self) -> Vec<String> {
        let inner = self.inner.read().unwrap();
        let mut out: Vec<String> = Vec::new();
        for s in &inner.slots {
            if inner.models.contains_key(&s.name)
                && !out.iter().any(|n| n == &s.name)
            {
                out.push(s.name.clone());
            }
        }
        out
    }

    /// Public identity of every live model version, in slot order — the
    /// rows the HTTP front's `GET /v1/models` listing serves.
    pub fn infos(&self) -> Vec<ModelInfo> {
        let inner = self.inner.read().unwrap();
        inner
            .slots
            .iter()
            .filter(|s| s.published)
            .filter_map(|s| {
                let plan = s.plan.as_ref()?;
                let is_default = inner
                    .models
                    .get(&s.name)
                    .is_some_and(|e| e.default == s.version);
                Some(ModelInfo {
                    name: s.name.clone(),
                    version: s.version.clone(),
                    default: is_default,
                    backend: plan.backend_name().to_string(),
                    input: plan.input_dims(),
                    // output_dims(1) is [batch, per-sample...]; strip it
                    output: plan.output_dims(1)[1..].to_vec(),
                    batch_invariant: plan.batch_invariant(),
                })
            })
            .collect()
    }

    /// Total slots ever created (live and unloaded) — the bound on slot
    /// ids, not the live-model count (see [`Registry::infos`] for that).
    pub fn len(&self) -> usize {
        self.inner.read().unwrap().slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.inner.read().unwrap().slots.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::ExecMode;
    use crate::testkit::models::synth_mlp_model;

    fn mlp_plan() -> Plan {
        let (graph, model) = synth_mlp_model(4);
        Plan::compile(
            &graph,
            &model,
            PlanOptions { mode: ExecMode::LutTrick, act_bits: 0,
                          mlbn: false, threads: 1,
                          ..PlanOptions::default() },
            &[16],
        )
        .unwrap()
    }

    #[test]
    fn registers_and_resolves_by_name_and_id() {
        let mut reg = Registry::new();
        let a = reg.register("alpha", mlp_plan()).unwrap();
        let b = reg.register("beta", mlp_plan()).unwrap();
        assert_eq!((a, b), (0, 1));
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.id("beta"), Some(1));
        assert_eq!(reg.name(0).as_deref(), Some("alpha"));
        assert_eq!(reg.names(), vec!["alpha", "beta"]);
        assert!(reg.plan("alpha").is_some());
        assert!(reg.plan("gamma").is_none());
        assert_eq!(reg.plan_by_id(1).unwrap().input_dims(), vec![16]);
        let infos = reg.infos();
        assert_eq!(infos.len(), 2);
        assert_eq!(infos[0].name, "alpha");
        assert_eq!(infos[0].version, DEFAULT_VERSION);
        assert!(infos[0].default);
        assert_eq!(infos[0].input, vec![16]);
        assert_eq!(infos[0].output, vec![10]);
        assert!(infos[0].batch_invariant);
        assert!(!infos[0].backend.is_empty());
        // legacy registers resolve through their default version
        assert_eq!(reg.id("alpha@v1"), Some(0));
        assert_eq!(reg.slot_label(1),
                   Some(("beta".to_string(), "v1".to_string())));
    }

    #[test]
    fn rejects_duplicate_and_empty_names() {
        let mut reg = Registry::new();
        reg.register("m", mlp_plan()).unwrap();
        let err = reg.register("m", mlp_plan()).unwrap_err().to_string();
        assert!(err.contains("already registered"), "{err}");
        assert!(reg.register("", mlp_plan()).is_err());
        assert!(reg.register("a@b", mlp_plan()).is_err());
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn out_of_range_ids_are_none_not_panics() {
        let mut reg = Registry::new();
        reg.register("m", mlp_plan()).unwrap();
        // regression: plan_by_id / name used to index unchecked and
        // panic on out-of-range ids
        assert!(reg.plan_by_id(7).is_none());
        assert!(reg.name(7).is_none());
        assert!(reg.slot_label(7).is_none());
        assert!(reg.plan_by_id(0).is_some());
    }

    #[test]
    fn versioned_load_set_default_and_unload() {
        let reg = Registry::new();
        let v1 = reg.load("m", "v1", Arc::new(mlp_plan())).unwrap();
        let v2 = reg.load("m", "v2", Arc::new(mlp_plan())).unwrap();
        assert_eq!((v1, v2), (0, 1));
        // duplicate (name, version) is a typed conflict
        assert!(matches!(
            reg.load("m", "v2", Arc::new(mlp_plan())),
            Err(LifecycleError::Duplicate(_))
        ));
        // unversioned resolution follows the default (first load)
        assert_eq!(reg.id("m"), Some(v1));
        assert_eq!(reg.id("m@v2"), Some(v2));
        // the default version cannot be unloaded
        assert!(matches!(reg.unload("m", "v1"),
                         Err(LifecycleError::DefaultInUse(_))));
        // flip: unversioned traffic atomically re-pins to v2
        reg.set_default("m", "v2").unwrap();
        assert_eq!(reg.id("m"), Some(v2));
        assert_eq!(reg.id("m@v1"), Some(v1));
        // now v1 can go; its slot id stays dead, never re-bound
        assert_eq!(reg.unload("m", "v1").unwrap(), v1);
        assert!(reg.plan_by_id(v1).is_none());
        assert!(reg.id("m@v1").is_none());
        assert_eq!(reg.id("m"), Some(v2));
        // infos lists only live versions, with the default flagged
        let infos = reg.infos();
        assert_eq!(infos.len(), 1);
        assert_eq!(infos[0].version, "v2");
        assert!(infos[0].default);
        assert_eq!(infos[0].qualified(), "m@v2");
        // unknown names / versions are typed, not panics
        assert!(matches!(reg.set_default("x", "v1"),
                         Err(LifecycleError::UnknownModel(_))));
        assert!(matches!(reg.set_default("m", "v9"),
                         Err(LifecycleError::UnknownVersion(_))));
        assert!(matches!(reg.unload("m", "v9"),
                         Err(LifecycleError::UnknownVersion(_))));
    }

    #[test]
    fn stage_is_invisible_until_publish() {
        let reg = Registry::new();
        reg.load("m", "v1", Arc::new(mlp_plan())).unwrap();
        let staged = reg.stage("m", "v2", Arc::new(mlp_plan())).unwrap();
        // not routable yet: servers grow their queues before publish
        assert!(reg.id("m@v2").is_none());
        assert_eq!(reg.infos().len(), 1);
        assert_eq!(reg.len(), 2, "the slot itself exists");
        // double-stage of the same pair is refused
        assert!(matches!(
            reg.stage("m", "v2", Arc::new(mlp_plan())),
            Err(LifecycleError::Duplicate(_))
        ));
        reg.publish(staged).unwrap();
        assert_eq!(reg.id("m@v2"), Some(staged));
        // publish is idempotent
        reg.publish(staged).unwrap();
        assert_eq!(reg.infos().len(), 2);
    }

    #[test]
    fn split_versioned_parses_references() {
        assert_eq!(split_versioned("m"), ("m", None));
        assert_eq!(split_versioned("m@v2"), ("m", Some("v2")));
        assert_eq!(split_versioned("m@"), ("m", Some("")));
    }
}
