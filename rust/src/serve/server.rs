//! The serving front end: a worker-thread pool draining coalesced batches
//! through [`Plan::run_into`].
//!
//! Each registry **slot** (one loaded `name@version`) owns a pool of
//! pre-warmed [`Scratch`] arenas, its own batch queue, admission gate and
//! counters, so steady-state execution allocates nothing beyond the
//! response vectors and two versions of one model never share mutable
//! state. Batch composition never changes results: plans whose execution
//! is per-sample independent ([`Plan::batch_invariant`]) coalesce up to
//! `max_batch`, while batch-coupled plans (activation fake-quant computes
//! a per-tensor scale over the whole batch) are automatically capped at
//! batch 1 — every caller always receives logits bit-identical to a
//! direct single-sample `run_into` of its input.
//!
//! **Model lifecycle.** [`Server::load_version`] hot-loads a new version
//! while traffic flows: the slot is staged in the registry, the batcher
//! grows a queue for it, admission/stats/scratch state is installed, and
//! only then is it published (routable). Requests pin their `Arc<Plan>`
//! at submit time ([`super::Batcher::submit_pinned`]), so
//! [`Server::set_default_version`] is a blue-green cutover — in-flight
//! batches drain against the plan they were formed with, new requests
//! pin the new plan — and [`Server::unload_version`] frees a version's
//! plan and scratch memory immediately while queued requests finish
//! against their own pinned clones. Use these server methods (not the
//! registry's own lifecycle calls) on a served registry: the server
//! keeps its queues and pools in lockstep with the slot table.
//!
//! **Adaptive worker pool.** With `max_workers > 0` the fixed pool is
//! replaced by an autoscaler: a supervisor thread grows the pool one
//! worker at a time when queue depth (or queued-work-time predicted from
//! the admission EWMAs) outruns the live workers, and shrinks it after a
//! cooldown once the queue has stayed empty — hysteresis in both
//! directions. Decisions are recorded as [`ScaleEvent`]s and logged as
//! `serve_scale` JSONL events next to the per-model reports.
//!
//! Shutdown is graceful: [`Server::shutdown`] closes the submission queue,
//! lets the workers drain everything already accepted, joins them, and
//! returns the final per-model reports. Metrics follow the
//! [`crate::coordinator::metrics`] convention — one JSON object per model
//! via [`ModelReport::to_json`], streamable into a [`Metrics`] JSONL log.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::coordinator::metrics::Metrics;
use crate::infer::{Plan, Scratch, Tensor};
use crate::jsonic::Json;
use crate::util::{Summary, Timer};

use super::admission::{Admission, Rejection};
use super::batcher::{Batcher, Poll, SubmitRefusal, Ticket};
use super::registry::{LifecycleError, Registry};

/// Typed submission failure, so the HTTP front can map each cause to its
/// status code without string matching (404 / 400 / 429 / 503).
#[derive(Debug)]
pub enum SubmitError {
    /// no model registered under that name (HTTP 404)
    UnknownModel(String),
    /// sample length does not match the model's input dims (HTTP 400)
    BadInput(String),
    /// the admission gate predicts the deadline cannot be met (HTTP 429)
    Rejected(Rejection),
    /// the deadline expired while blocked on a full queue — the same
    /// client outcome as an in-queue shed (HTTP 429, counted as shed)
    QueueDeadline(String),
    /// the batcher is closed — server shutting down (HTTP 503)
    Closed(String),
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::UnknownModel(m)
            | SubmitError::BadInput(m)
            | SubmitError::QueueDeadline(m)
            | SubmitError::Closed(m) => write!(f, "{m}"),
            SubmitError::Rejected(r) => write!(f, "{r}"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Index of the first NaN/±inf in `sample`, if any. Every submission
/// path rejects non-finite values up front: the kernels' documented
/// quantization-error bound only holds on finite inputs (the int
/// backends' saturating f32→i16 cast would silently send NaN to 0 and
/// ±inf to ±127), so such a sample is a malformed request — a 4xx at
/// the HTTP/wire boundary — not a number to propagate.
fn first_non_finite(sample: &[f32]) -> Option<usize> {
    sample.iter().position(|v| !v.is_finite())
}

/// Compiles a plan from an admin-supplied load spec (e.g. a manifest
/// path or an inline description). Installed with
/// [`Server::set_loader`]; without one, admin `load` requests are
/// refused as unsupported.
pub type PlanLoader =
    Box<dyn Fn(&Json) -> Result<Arc<Plan>> + Send + Sync>;

/// Serving knobs: pool width (fixed or autoscaled), coalescing cap and
/// patience, queue bound.
#[derive(Debug, Clone, Copy)]
pub struct ServerConfig {
    /// fixed worker pool width (0 = one per core); ignored when
    /// `max_workers` enables autoscaling
    pub workers: usize,
    /// coalescing cap per batch (batch-variant models are capped at 1)
    pub max_batch: usize,
    /// max time a partial batch lingers waiting for more requests
    pub linger: Duration,
    /// bounded per-model submission queue (submit blocks when full)
    pub queue_cap: usize,
    /// assumed per-batch service time (ms) for models with no observed
    /// batch yet — lets cold-start models shed deadline-carrying
    /// traffic early instead of queueing blind (0.0 = legacy optimism;
    /// see [`Admission::with_prior`])
    pub admission_prior_ms: f64,
    /// autoscaler floor (clamped to >= 1 when autoscaling is on)
    pub min_workers: usize,
    /// autoscaler ceiling; 0 disables autoscaling (fixed `workers` pool)
    pub max_workers: usize,
    /// grow when total queue depth exceeds this many requests per live
    /// worker
    pub scale_up_queue: usize,
    /// how often the autoscaler samples its signals (also the idle poll
    /// bound of autoscaled workers)
    pub scale_tick: Duration,
    /// minimum spacing between consecutive scale decisions (hysteresis)
    pub scale_cooldown: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            workers: 0,
            max_batch: 8,
            linger: Duration::from_millis(2),
            queue_cap: 1024,
            admission_prior_ms: 0.0,
            min_workers: 1,
            max_workers: 0,
            scale_up_queue: 4,
            scale_tick: Duration::from_millis(20),
            scale_cooldown: Duration::from_millis(200),
        }
    }
}

/// Grow also when the EWMA-predicted time to drain the queue exceeds
/// this many ms per live worker — catches slow-model backlogs the raw
/// depth signal would call shallow.
const SCALE_UP_BACKLOG_MS: f64 = 100.0;

/// Consecutive idle supervisor ticks (queue empty) before one worker is
/// retired — the shrink half of the hysteresis.
const SCALE_IDLE_TICKS: u32 = 3;

/// One autoscaler decision, logged to metrics JSONL as a `serve_scale`
/// event.
#[derive(Debug, Clone)]
pub struct ScaleEvent {
    /// "grow" or "shrink"
    pub action: &'static str,
    /// live workers after the decision took effect
    pub workers: usize,
    /// total queued requests at decision time
    pub queued: usize,
    /// largest per-slot service-time EWMA at decision time
    pub ewma_batch_ms: f64,
    /// ms since the server started
    pub at_ms: f64,
}

impl ScaleEvent {
    /// One `coordinator::metrics`-style JSONL event.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("event", Json::str("serve_scale")),
            ("schema_version",
             Json::num(crate::report::SCHEMA_VERSION as f64)),
            ("action", Json::str(self.action)),
            ("workers", Json::num(self.workers as f64)),
            ("queued", Json::num(self.queued as f64)),
            ("ewma_batch_ms", Json::num(self.ewma_batch_ms)),
            ("at_ms", Json::num(self.at_ms)),
        ])
    }
}

/// Per-slot serving counters (behind one mutex per slot, touched once
/// per *batch*, not per request).
struct ModelCounters {
    requests: u64,
    batches: u64,
    errors: u64,
    max_batch: usize,
    batch_ms: Summary,
    wait_ms: Summary,
}

impl ModelCounters {
    fn new() -> ModelCounters {
        ModelCounters {
            requests: 0,
            batches: 0,
            errors: 0,
            max_batch: 0,
            batch_ms: Summary::new(),
            wait_ms: Summary::new(),
        }
    }
}

/// Everything the server keeps per slot besides the plan itself: the
/// identity for reports, the effective batch cap, a pool of reusable
/// scratch arenas, and the counters. Deliberately does NOT hold the
/// plan — workers execute the `Arc<Plan>` each request pinned at submit
/// time, and unloading a version frees its plan even while this runtime
/// row survives for final reporting.
struct SlotRuntime {
    model: String,
    version: String,
    backend: String,
    /// effective coalescing cap (1 for batch-coupled plans)
    cap: usize,
    scratches: Mutex<Vec<Scratch>>,
    counters: Mutex<ModelCounters>,
}

struct Stats {
    started: Instant,
    slots: RwLock<Vec<Arc<SlotRuntime>>>,
}

impl Stats {
    fn slot(&self, m: usize) -> Option<Arc<SlotRuntime>> {
        self.slots.read().unwrap().get(m).cloned()
    }

    fn record(&self, m: usize, batch: usize, ms: f64,
              waits_ms: &[f64], errored: bool) {
        let Some(slot) = self.slot(m) else { return };
        let mut c = slot.counters.lock().unwrap();
        c.batches += 1;
        if errored {
            c.errors += batch as u64;
        } else {
            c.requests += batch as u64;
        }
        c.max_batch = c.max_batch.max(batch);
        c.batch_ms.push(ms);
        for &w in waits_ms {
            c.wait_ms.push(w);
        }
    }
}

/// Autoscaler state shared between the supervisor, the workers and the
/// server handle.
struct ScaleState {
    /// workers currently alive (fixed pools maintain it too, for
    /// reporting)
    live: AtomicUsize,
    /// outstanding retire requests; an idle worker claims one and exits
    shrink_tokens: AtomicUsize,
    /// monotonically increasing spawn counter (thread names)
    spawned: AtomicUsize,
    /// tells the supervisor to exit
    stop: AtomicBool,
    events: Mutex<Vec<ScaleEvent>>,
}

/// Final (or live) per-model-version serving summary.
#[derive(Debug, Clone)]
pub struct ModelReport {
    pub model: String,
    /// version label of the slot this row describes
    pub version: String,
    /// replica tag when this server runs as one backend of a cluster
    /// (`lutq serve --replicas`); "" for a standalone server
    pub replica: String,
    /// inner-kernel backend the model's plan compiled against
    /// (`scalar` / `simd-avx2` / `simd-portable` / `int`)
    pub backend: String,
    /// worker threads live when the report was taken
    pub workers: usize,
    /// requests answered successfully
    pub requests: u64,
    /// coalesced batches executed
    pub batches: u64,
    /// requests answered with an error
    pub errors: u64,
    /// requests turned away at admission (predicted deadline miss)
    pub rejected: u64,
    /// admitted requests shed in-queue after their deadline expired
    pub shed: u64,
    /// queued requests dropped because the caller abandoned its ticket
    pub abandoned: u64,
    /// smoothed per-batch service time the admission gate predicts with
    pub ewma_batch_ms: f64,
    /// largest coalesced batch observed
    pub max_batch: usize,
    /// mean requests per batch (coalescing effectiveness)
    pub mean_batch: f64,
    pub mean_batch_ms: f64,
    pub max_batch_ms: f64,
    /// mean time a request waited in the queue before execution
    pub mean_wait_ms: f64,
    /// answered requests / server uptime
    pub images_per_sec: f64,
}

impl ModelReport {
    /// One `coordinator::metrics`-style JSONL event.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("event", Json::str("serve_model")),
            ("schema_version",
             Json::num(crate::report::SCHEMA_VERSION as f64)),
            ("model", Json::str(&self.model)),
            ("version", Json::str(&self.version)),
            ("replica", Json::str(&self.replica)),
            ("backend", Json::str(&self.backend)),
            ("workers", Json::num(self.workers as f64)),
            ("requests", Json::num(self.requests as f64)),
            ("batches", Json::num(self.batches as f64)),
            ("errors", Json::num(self.errors as f64)),
            ("rejected", Json::num(self.rejected as f64)),
            ("shed", Json::num(self.shed as f64)),
            ("abandoned", Json::num(self.abandoned as f64)),
            ("ewma_batch_ms", Json::num(self.ewma_batch_ms)),
            ("max_batch", Json::num(self.max_batch as f64)),
            ("mean_batch", Json::num(self.mean_batch)),
            ("mean_batch_ms", Json::num(self.mean_batch_ms)),
            ("max_batch_ms", Json::num(self.max_batch_ms)),
            ("mean_wait_ms", Json::num(self.mean_wait_ms)),
            ("images_per_sec", Json::num(self.images_per_sec)),
        ])
    }
}

/// What every thread of the server shares.
struct Shared {
    registry: Arc<Registry>,
    batcher: Batcher,
    stats: Stats,
    admission: Admission,
    scale: ScaleState,
}

/// Multi-model, multi-version inference server: shared plans, dynamic
/// batch coalescing, per-slot scratch pools, hot model lifecycle and an
/// optionally autoscaled worker pool.
pub struct Server {
    shared: Arc<Shared>,
    cfg: ServerConfig,
    /// poll bound workers use between lifecycle checks
    worker_poll: Duration,
    workers: Arc<Mutex<Vec<JoinHandle<()>>>>,
    supervisor: Mutex<Option<JoinHandle<()>>>,
    /// serializes load/unload/set-default so slot ids and queue ids
    /// stay in lockstep
    admin_lock: Mutex<()>,
    loader: RwLock<Option<PlanLoader>>,
}

impl Server {
    /// Spin up the worker pool over `registry`'s compiled plans.
    pub fn start(registry: Registry, cfg: ServerConfig) -> Result<Server> {
        ensure!(!registry.is_empty(), "serve: registry holds no models");
        ensure!(cfg.max_batch >= 1, "serve: max_batch must be >= 1");
        let autoscale = cfg.max_workers > 0;
        let floor = cfg.min_workers.max(1);
        let ceiling = cfg.max_workers.max(floor);
        let workers = if autoscale {
            floor
        } else if cfg.workers == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        } else {
            cfg.workers
        };
        // batch-coupled plans must not coalesce: their outputs would
        // depend on which requests happened to share a batch
        let live = registry.live_slots();
        let caps: Vec<usize> = live
            .iter()
            .map(|(_, _, _, p)| {
                if p.batch_invariant() { cfg.max_batch } else { 1 }
            })
            .collect();
        let batcher =
            Batcher::new(caps.clone(), cfg.linger, cfg.queue_cap);
        let admission = Admission::with_prior(
            registry.len(),
            cfg.admission_prior_ms,
        );
        // per-slot pools of scratch arenas, pre-warmed to the slot's
        // *effective* batch cap (capped plans never see more than one
        // sample, so don't size their buffers for max_batch)
        let slots: Vec<Arc<SlotRuntime>> = live
            .iter()
            .zip(&caps)
            .map(|((_, name, version, p), &cap)| {
                Arc::new(SlotRuntime {
                    model: name.clone(),
                    version: version.clone(),
                    backend: p.backend_name().to_string(),
                    cap,
                    scratches: Mutex::new(p.scratch_pool(workers, cap)),
                    counters: Mutex::new(ModelCounters::new()),
                })
            })
            .collect();
        let shared = Arc::new(Shared {
            registry: Arc::new(registry),
            batcher,
            stats: Stats {
                started: Instant::now(),
                slots: RwLock::new(slots),
            },
            admission,
            scale: ScaleState {
                live: AtomicUsize::new(0),
                shrink_tokens: AtomicUsize::new(0),
                spawned: AtomicUsize::new(0),
                stop: AtomicBool::new(false),
                events: Mutex::new(Vec::new()),
            },
        });
        let worker_poll = if autoscale {
            cfg.scale_tick.max(Duration::from_millis(1))
        } else {
            Duration::from_secs(3600)
        };
        let server = Server {
            shared,
            cfg,
            worker_poll,
            workers: Arc::new(Mutex::new(Vec::new())),
            supervisor: Mutex::new(None),
            admin_lock: Mutex::new(()),
            loader: RwLock::new(None),
        };
        for _ in 0..workers {
            if let Err(e) = spawn_worker(&server.shared,
                                         &server.workers, worker_poll) {
                server.stop();
                return Err(e).context("spawn serve worker");
            }
        }
        if autoscale {
            let shared = Arc::clone(&server.shared);
            let handles = Arc::clone(&server.workers);
            let cfg = server.cfg;
            let poll = worker_poll;
            let sup = std::thread::Builder::new()
                .name("lutq-serve-scale".to_string())
                .spawn(move || {
                    supervisor_loop(&shared, &handles, &cfg, poll,
                                    floor, ceiling)
                });
            match sup {
                Ok(h) => *server.supervisor.lock().unwrap() = Some(h),
                Err(e) => {
                    server.stop();
                    return Err(e).context("spawn serve autoscaler");
                }
            }
        }
        Ok(server)
    }

    pub fn registry(&self) -> &Registry {
        &self.shared.registry
    }

    /// The admission gate's live state (EWMAs, rejection counters).
    pub fn admission(&self) -> &Admission {
        &self.shared.admission
    }

    /// True while the server accepts new requests (false once
    /// [`close`](Server::close) or shutdown began) — the in-process
    /// replica's health probe.
    pub fn is_open(&self) -> bool {
        self.shared.batcher.is_open()
    }

    /// Stop accepting and let the workers drain, without consuming the
    /// handle (worker threads are joined by [`shutdown`](Server::shutdown)
    /// or drop). This is how the cluster tests kill one replica
    /// mid-load: subsequent submits fail as `Closed`, which the router
    /// treats as failover bait. Idempotent.
    pub fn close(&self) {
        self.shared.batcher.close();
    }

    /// Install the compiler admin `load` requests use to turn a load
    /// spec (manifest path or inline description) into a plan.
    pub fn set_loader(&self, loader: PlanLoader) {
        *self.loader.write().unwrap() = Some(loader);
    }

    /// Compile a plan from an admin load spec via the installed
    /// [`PlanLoader`]. `Err(None)` means no loader is installed.
    pub fn compile_spec(&self, spec: &Json)
                        -> std::result::Result<Arc<Plan>,
                                               Option<String>> {
        let loader = self.loader.read().unwrap();
        match loader.as_ref() {
            None => Err(None),
            Some(f) => f(spec).map_err(|e| Some(format!("{e:#}"))),
        }
    }

    // ---------------------------------------------------- lifecycle

    /// Hot-load `name@version` while traffic flows. The new slot gets
    /// its own queue, admission gate, counters and scratch pool before
    /// it becomes routable, so the first request it admits is already
    /// fully provisioned.
    pub fn load_version(&self, name: &str, version: &str,
                        plan: Arc<Plan>)
                        -> std::result::Result<usize, LifecycleError> {
        let _g = self.admin_lock.lock().unwrap();
        let slot = self.shared.registry.stage(name, version,
                                              Arc::clone(&plan))?;
        let cap = if plan.batch_invariant() {
            self.cfg.max_batch
        } else {
            1
        };
        let queue = self.shared.batcher.add_queue(cap);
        debug_assert_eq!(
            slot, queue,
            "slot and queue ids are both append-only and must agree"
        );
        self.shared.admission.grow(slot + 1);
        let warm = self.worker_count().max(1);
        self.shared.stats.slots.write().unwrap().push(Arc::new(
            SlotRuntime {
                model: name.to_string(),
                version: version.to_string(),
                backend: plan.backend_name().to_string(),
                cap,
                scratches: Mutex::new(plan.scratch_pool(warm, cap)),
                counters: Mutex::new(ModelCounters::new()),
            },
        ));
        self.shared.registry.publish(slot)?;
        Ok(slot)
    }

    /// Flip which version answers unversioned `name` requests
    /// (blue-green: already-queued requests keep their pinned plan).
    pub fn set_default_version(&self, name: &str, version: &str)
                               -> std::result::Result<(),
                                                      LifecycleError> {
        let _g = self.admin_lock.lock().unwrap();
        self.shared.registry.set_default(name, version)
    }

    /// Unload one version (the default is refused with a typed error)
    /// and free its scratch pool. Requests already queued for it drain
    /// against the plan they pinned at submit time.
    pub fn unload_version(&self, name: &str, version: &str)
                          -> std::result::Result<usize,
                                                 LifecycleError> {
        let _g = self.admin_lock.lock().unwrap();
        let slot = self.shared.registry.unload(name, version)?;
        if let Some(rt) = self.shared.stats.slot(slot) {
            rt.scratches.lock().unwrap().clear();
        }
        Ok(slot)
    }

    // --------------------------------------------------- submission

    /// Enqueue one sample for the named model (`name` or
    /// `name@version`); the [`Ticket`] resolves to exactly this
    /// request's logits.
    pub fn submit(&self, model: &str, sample: &[f32]) -> Result<Ticket> {
        let (id, plan) =
            self.shared.registry.resolve(model).ok_or_else(|| {
                anyhow!(
                    "serve: unknown model `{model}` (registered: {:?})",
                    self.shared.registry.names()
                )
            })?;
        let expect: usize = plan.input_dims().iter().product();
        ensure!(
            sample.len() == expect,
            "serve: sample holds {} values, model `{model}` expects \
             {expect} (input dims {:?})",
            sample.len(),
            plan.input_dims()
        );
        if let Some(i) = first_non_finite(sample) {
            bail!(
                "serve: sample value {} at index {i} is not finite",
                sample[i]
            );
        }
        Ok(self.shared.batcher.submit_pinned(
            id,
            sample.to_vec(),
            None,
            Some(plan),
        )?)
    }

    /// [`submit`](Server::submit) by dense slot id (hot paths that
    /// resolved the name once). Out-of-range and unloaded slots are
    /// typed errors, never panics.
    pub fn submit_by_id(&self, id: usize, sample: &[f32]) -> Result<Ticket> {
        let plan =
            self.shared.registry.plan_by_id(id).ok_or_else(|| {
                anyhow!(
                    "serve: model id {id} out of range or unloaded \
                     ({} slots)",
                    self.shared.registry.len()
                )
            })?;
        let expect: usize = plan.input_dims().iter().product();
        ensure!(
            sample.len() == expect,
            "serve: sample holds {} values, model `{}` expects {expect} \
             (input dims {:?})",
            sample.len(),
            self.shared
                .registry
                .name(id)
                .unwrap_or_else(|| format!("#{id}")),
            plan.input_dims()
        );
        if let Some(i) = first_non_finite(sample) {
            bail!(
                "serve: sample value {} at index {i} is not finite",
                sample[i]
            );
        }
        Ok(self.shared.batcher.submit_pinned(
            id,
            sample.to_vec(),
            None,
            Some(plan),
        )?)
    }

    /// Deadline-aware submission with typed failures: validates the
    /// model and sample, runs the admission gate against what is left of
    /// `deadline`, and enqueues the request carrying that deadline so
    /// the batcher can shed it if it overstays. This is the HTTP front's
    /// entry point; callers without a deadline are never rejected.
    pub fn try_submit(&self, model: &str, sample: &[f32],
                      deadline: Option<Instant>)
                      -> std::result::Result<Ticket, SubmitError> {
        let (id, plan) =
            self.shared.registry.resolve(model).ok_or_else(|| {
                SubmitError::UnknownModel(format!(
                    "unknown model `{model}` (registered: {:?})",
                    self.shared.registry.names()
                ))
            })?;
        let expect: usize = plan.input_dims().iter().product();
        if sample.len() != expect {
            return Err(SubmitError::BadInput(format!(
                "sample holds {} values, model `{model}` expects \
                 {expect} (input dims {:?})",
                sample.len(),
                plan.input_dims()
            )));
        }
        if let Some(i) = first_non_finite(sample) {
            return Err(SubmitError::BadInput(format!(
                "sample value {} at index {i} is not finite",
                sample[i]
            )));
        }
        if let Some(d) = deadline {
            let budget = d.saturating_duration_since(Instant::now());
            let cap = self
                .shared
                .stats
                .slot(id)
                .map_or(1, |s| s.cap);
            self.shared
                .admission
                .check(id, self.shared.batcher.depth(id), cap,
                       Some(budget))
                .map_err(SubmitError::Rejected)?;
        }
        self.shared
            .batcher
            .submit_pinned(id, sample.to_vec(), deadline, Some(plan))
            .map_err(|e| match e {
                SubmitRefusal::DeadlineExceeded => {
                    SubmitError::QueueDeadline(e.to_string())
                }
                other => SubmitError::Closed(other.to_string()),
            })
    }

    /// Submit + block for the reply: the one-call convenience path.
    pub fn infer(&self, model: &str, sample: &[f32]) -> Result<Vec<f32>> {
        self.submit(model, sample)?.wait()
    }

    // ---------------------------------------------------- reporting

    /// Worker threads currently live.
    pub fn worker_count(&self) -> usize {
        self.shared.scale.live.load(Ordering::Relaxed)
    }

    /// Every autoscaler decision so far, in order.
    pub fn scale_events(&self) -> Vec<ScaleEvent> {
        self.shared.scale.events.lock().unwrap().clone()
    }

    /// Live per-slot serving reports (slot-id order; unloaded versions
    /// keep their final row so totals still reconcile).
    pub fn reports(&self) -> Vec<ModelReport> {
        let elapsed =
            self.shared.stats.started.elapsed().as_secs_f64().max(1e-9);
        let workers = self.worker_count();
        let slots: Vec<Arc<SlotRuntime>> =
            self.shared.stats.slots.read().unwrap().clone();
        slots
            .iter()
            .enumerate()
            .map(|(i, slot)| {
                let c = slot.counters.lock().unwrap();
                let answered = c.requests + c.errors;
                let (shed, abandoned) =
                    self.shared.batcher.drop_stats(i);
                ModelReport {
                    model: slot.model.clone(),
                    version: slot.version.clone(),
                    replica: String::new(),
                    backend: slot.backend.clone(),
                    workers,
                    requests: c.requests,
                    batches: c.batches,
                    errors: c.errors,
                    rejected: self.shared.admission.rejected(i),
                    shed,
                    abandoned,
                    ewma_batch_ms:
                        self.shared.admission.ewma_batch_ms(i),
                    max_batch: c.max_batch,
                    mean_batch: if c.batches == 0 {
                        0.0
                    } else {
                        answered as f64 / c.batches as f64
                    },
                    mean_batch_ms: if c.batch_ms.count() == 0 {
                        0.0
                    } else {
                        c.batch_ms.mean()
                    },
                    max_batch_ms: if c.batch_ms.count() == 0 {
                        0.0
                    } else {
                        c.batch_ms.max()
                    },
                    mean_wait_ms: if c.wait_ms.count() == 0 {
                        0.0
                    } else {
                        c.wait_ms.mean()
                    },
                    images_per_sec: c.requests as f64 / elapsed,
                }
            })
            .collect()
    }

    /// Append one JSONL event per model slot — plus one per autoscaler
    /// decision — to a metrics log.
    pub fn log_to(&self, metrics: &mut Metrics) -> std::io::Result<()> {
        for r in self.reports() {
            metrics.record_custom(r.to_json())?;
        }
        for e in self.scale_events() {
            metrics.record_custom(e.to_json())?;
        }
        Ok(())
    }

    fn stop(&self) {
        // the supervisor goes first so it cannot spawn workers while
        // we join them
        self.shared.scale.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.supervisor.lock().unwrap().take() {
            let _ = h.join();
        }
        self.shared.batcher.close();
        let handles: Vec<JoinHandle<()>> =
            self.workers.lock().unwrap().drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
    }

    /// Graceful shutdown: refuse new requests, drain and answer every
    /// queued one, join the workers, return the final reports.
    pub fn shutdown(self) -> Vec<ModelReport> {
        self.stop();
        self.reports()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Spawn one worker thread and register its handle + live count.
fn spawn_worker(shared: &Arc<Shared>,
                handles: &Arc<Mutex<Vec<JoinHandle<()>>>>,
                poll: Duration) -> std::io::Result<()> {
    let n = shared.scale.spawned.fetch_add(1, Ordering::SeqCst);
    let sh = Arc::clone(shared);
    shared.scale.live.fetch_add(1, Ordering::SeqCst);
    let spawned = std::thread::Builder::new()
        .name(format!("lutq-serve-{n}"))
        .spawn(move || {
            worker_loop(&sh, poll);
            sh.scale.live.fetch_sub(1, Ordering::SeqCst);
        });
    match spawned {
        Ok(h) => {
            handles.lock().unwrap().push(h);
            Ok(())
        }
        Err(e) => {
            shared.scale.live.fetch_sub(1, Ordering::SeqCst);
            Err(e)
        }
    }
}

/// The autoscaler: grow one worker when the queue outruns the pool
/// (depth per worker, or EWMA-predicted backlog time), retire one after
/// the queue has stayed empty for a few ticks — both sides gated by the
/// cooldown so decisions can't flap.
fn supervisor_loop(shared: &Arc<Shared>,
                   handles: &Arc<Mutex<Vec<JoinHandle<()>>>>,
                   cfg: &ServerConfig, poll: Duration, floor: usize,
                   ceiling: usize) {
    let mut last_change: Option<Instant> = None;
    let mut idle_ticks: u32 = 0;
    while !shared.scale.stop.load(Ordering::SeqCst) {
        std::thread::sleep(cfg.scale_tick);
        if !shared.batcher.is_open() {
            break;
        }
        let cooled = match last_change {
            None => true,
            Some(t) => t.elapsed() >= cfg.scale_cooldown,
        };
        let queued = shared.batcher.queued();
        let live = shared.scale.live.load(Ordering::SeqCst).max(1);
        let ewma = shared.admission.max_ewma_batch_ms();
        let backlog_ms = queued as f64 * ewma / live as f64;
        let pressure = queued > cfg.scale_up_queue.max(1) * live
            || (ewma > 0.0 && backlog_ms > SCALE_UP_BACKLOG_MS);
        if pressure {
            idle_ticks = 0;
            if cooled && live < ceiling {
                if spawn_worker(shared, handles, poll).is_err() {
                    continue;
                }
                last_change = Some(Instant::now());
                record_scale(shared, "grow", queued, ewma);
            }
        } else if queued == 0 {
            idle_ticks = idle_ticks.saturating_add(1);
            let retiring =
                shared.scale.shrink_tokens.load(Ordering::SeqCst);
            if cooled
                && idle_ticks >= SCALE_IDLE_TICKS
                && live.saturating_sub(retiring) > floor
            {
                shared
                    .scale
                    .shrink_tokens
                    .fetch_add(1, Ordering::SeqCst);
                last_change = Some(Instant::now());
                idle_ticks = 0;
                record_scale(shared, "shrink", queued, ewma);
            }
        } else {
            idle_ticks = 0;
        }
    }
}

fn record_scale(shared: &Shared, action: &'static str, queued: usize,
                ewma: f64) {
    let event = ScaleEvent {
        action,
        workers: shared.scale.live.load(Ordering::SeqCst),
        queued,
        ewma_batch_ms: ewma,
        at_ms: shared.stats.started.elapsed().as_secs_f64() * 1e3,
    };
    shared.scale.events.lock().unwrap().push(event);
}

fn worker_loop(shared: &Shared, poll: Duration) {
    let mut inbuf: Vec<f32> = Vec::new();
    let mut waits: Vec<f64> = Vec::new();
    loop {
        // scale-down: claim one retire token between batches and exit
        let claimed = shared
            .scale
            .shrink_tokens
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |t| {
                t.checked_sub(1)
            });
        if claimed.is_ok() {
            return;
        }
        let batch = match shared.batcher.next_batch_or_idle(poll) {
            Poll::Batch(b) => b,
            Poll::Idle => continue,
            Poll::Closed => return,
        };
        let m = batch.model();
        // the plan pinned at submit time; a request submitted through a
        // raw batcher handle falls back to the slot's current plan
        let plan: Option<Arc<Plan>> = batch
            .plan()
            .cloned()
            .or_else(|| shared.registry.plan_by_id(m));
        let Some(plan) = plan else {
            batch.fail(&format!(
                "serve: model slot {m} holds no plan (unloaded)"
            ));
            continue;
        };
        let runtime = shared.stats.slot(m);
        let b = batch.len();
        let popped = Instant::now();
        waits.clear();
        for r in &batch.requests {
            waits.push(
                popped.duration_since(r.arrived).as_secs_f64() * 1e3,
            );
        }
        batch.gather_into(&mut inbuf);
        let input_dims = plan.input_dims();
        let mut dims = Vec::with_capacity(1 + input_dims.len());
        dims.push(b);
        dims.extend_from_slice(&input_dims);
        // check out a scratch arena from the slot's pool (pre-warmed at
        // load; grown on demand up to the number of workers that ever
        // execute this slot concurrently)
        let cap = runtime.as_ref().map_or(b, |r| r.cap).max(b);
        let mut scratch = runtime
            .as_ref()
            .and_then(|r| r.scratches.lock().unwrap().pop())
            .unwrap_or_else(|| plan.scratch_for(cap));
        let t = Timer::start();
        let x = Tensor::new(dims, std::mem::take(&mut inbuf));
        let result = plan.run_into(&x, &mut scratch);
        inbuf = x.data;
        let ms = t.elapsed_ms();
        // feed the admission gate's per-batch service-time EWMA
        shared.admission.observe_batch_ms(m, ms);
        match result {
            Ok(_) => {
                shared.stats.record(m, b, ms, &waits, false);
                let (_, out) = scratch.output();
                batch.complete(out);
            }
            Err(e) => {
                shared.stats.record(m, b, ms, &waits, true);
                batch.fail(&format!("{e:#}"));
            }
        }
        if let Some(r) = &runtime {
            r.scratches.lock().unwrap().push(scratch);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::{ExecMode, PlanOptions};
    use crate::testkit::models::synth_mlp_model;
    use crate::util::Rng;

    const WAIT: Duration = Duration::from_secs(30);

    fn mlp_plan() -> Plan {
        let (graph, model) = synth_mlp_model(4);
        Plan::compile(
            &graph,
            &model,
            PlanOptions { mode: ExecMode::LutTrick, act_bits: 0,
                          mlbn: false, threads: 1,
                          ..PlanOptions::default() },
            &[16],
        )
        .unwrap()
    }

    fn small_server(workers: usize) -> (Server, Arc<Plan>) {
        let plan = Arc::new(mlp_plan());
        let mut reg = Registry::new();
        reg.register_shared("mlp", Arc::clone(&plan)).unwrap();
        let server = Server::start(
            reg,
            ServerConfig {
                workers,
                max_batch: 4,
                linger: Duration::from_millis(1),
                queue_cap: 64,
                ..Default::default()
            },
        )
        .unwrap();
        (server, plan)
    }

    #[test]
    fn served_logits_match_direct_single_sample_run() {
        let (server, plan) = small_server(2);
        let mut rng = Rng::new(5);
        let mut scratch = plan.scratch();
        for _ in 0..6 {
            let sample: Vec<f32> = rng.normals(16);
            let x = Tensor::new(vec![1, 16], sample.clone());
            plan.run_into(&x, &mut scratch).unwrap();
            let expect = scratch.output().1.to_vec();
            let got = server
                .submit("mlp", &sample)
                .unwrap()
                .wait_timeout(WAIT)
                .unwrap();
            assert_eq!(got, expect);
        }
        assert_eq!(server.worker_count(), 2);
        let reports = server.shutdown();
        assert_eq!(reports.len(), 1);
        assert_eq!(reports[0].requests, 6);
        assert_eq!(reports[0].errors, 0);
        assert_eq!(reports[0].version, "v1");
        assert!(reports[0].batches >= 1);
        assert!(reports[0].images_per_sec > 0.0);
    }

    #[test]
    fn rejects_unknown_model_and_bad_sample_length() {
        let (server, _) = small_server(1);
        assert!(server.submit("nope", &[0.0; 16]).is_err());
        assert!(server.submit("mlp@v9", &[0.0; 16]).is_err(),
                "unknown version is unknown model");
        let err = server
            .submit("mlp", &[0.0; 5])
            .unwrap_err()
            .to_string();
        assert!(err.contains("expects 16"), "{err}");
        assert!(server.infer("mlp", &[0.0; 16]).is_ok());
        assert!(server.infer("mlp@v1", &[0.0; 16]).is_ok(),
                "version-qualified predict reaches the same slot");
        // out-of-range slot ids are typed errors, not panics
        assert!(server.submit_by_id(9, &[0.0; 16]).is_err());
    }

    #[test]
    fn report_json_follows_metrics_event_convention() {
        let (server, _) = small_server(1);
        server.infer("mlp", &[0.5; 16]).unwrap();
        let reports = server.shutdown();
        let j = reports[0].to_json();
        assert_eq!(j.at("event").as_str(), Some("serve_model"));
        assert_eq!(j.at("schema_version").as_usize(),
                   Some(crate::report::SCHEMA_VERSION as usize));
        assert_eq!(j.at("model").as_str(), Some("mlp"));
        assert_eq!(j.at("version").as_str(), Some("v1"));
        assert_eq!(j.at("workers").as_usize(), Some(0),
                   "post-shutdown report sees the drained pool");
        assert_eq!(j.at("requests").as_usize(), Some(1));
        // backend name travels with the report (scalar or simd-*)
        let backend = j.at("backend").as_str().unwrap();
        assert!(backend == "scalar" || backend.starts_with("simd"),
                "{backend}");
        // round-trips through the jsonl serializer
        let parsed = crate::jsonic::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.at("model").as_str(), Some("mlp"));
    }

    #[test]
    fn try_submit_maps_failure_causes() {
        let (server, _) = small_server(1);
        assert!(matches!(
            server.try_submit("nope", &[0.0; 16], None).unwrap_err(),
            SubmitError::UnknownModel(_)
        ));
        assert!(matches!(
            server.try_submit("mlp", &[0.0; 5], None).unwrap_err(),
            SubmitError::BadInput(_)
        ));
        // non-finite values are malformed input on every submit path,
        // not numbers to quantize (the int backends would silently
        // send NaN to 0 and ±inf to ±127)
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            let mut sample = [0.0f32; 16];
            sample[7] = bad;
            let err =
                server.try_submit("mlp", &sample, None).unwrap_err();
            assert!(matches!(err, SubmitError::BadInput(_)),
                    "{bad}: {err}");
            assert!(err.to_string().contains("index 7"), "{err}");
            assert!(server.submit("mlp", &sample).is_err());
            assert!(server.submit_by_id(0, &sample).is_err());
        }
        // a deadline with no budget left is rejected at admission
        assert!(matches!(
            server
                .try_submit("mlp", &[0.0; 16], Some(Instant::now()))
                .unwrap_err(),
            SubmitError::Rejected(_)
        ));
        // no deadline: always admitted
        let t = server.try_submit("mlp", &[0.0; 16], None).unwrap();
        assert!(t.wait_timeout(WAIT).is_ok());
        let reports = server.shutdown();
        assert_eq!(reports[0].rejected, 1);
        assert_eq!(reports[0].requests, 1);
        assert!(reports[0].ewma_batch_ms > 0.0,
                "workers must feed the admission EWMA");
    }

    #[test]
    fn empty_registry_is_rejected() {
        assert!(
            Server::start(Registry::new(), ServerConfig::default()).is_err()
        );
    }

    #[test]
    fn hot_loaded_version_serves_next_to_the_old_one() {
        let (server, plan1) = small_server(1);
        let plan2 = Arc::new({
            let (graph, model) = synth_mlp_model(8);
            Plan::compile(
                &graph,
                &model,
                PlanOptions { mode: ExecMode::LutTrick, act_bits: 0,
                              mlbn: false, threads: 1,
                              ..PlanOptions::default() },
                &[16],
            )
            .unwrap()
        });
        server
            .load_version("mlp", "v2", Arc::clone(&plan2))
            .unwrap();
        // same shapes, different weights: the two versions must answer
        // differently, each matching its own plan
        let sample = vec![0.25f32; 16];
        let expect = |p: &Plan| {
            let mut s = p.scratch();
            let x = Tensor::new(vec![1, 16], sample.clone());
            p.run_into(&x, &mut s).unwrap();
            s.output().1.to_vec()
        };
        let (e1, e2) = (expect(&plan1), expect(&plan2));
        assert_ne!(e1, e2, "synth weights must differ between versions");
        assert_eq!(server.infer("mlp", &sample).unwrap(), e1);
        assert_eq!(server.infer("mlp@v2", &sample).unwrap(), e2);
        // flip the default: unversioned traffic re-pins to v2
        server.set_default_version("mlp", "v2").unwrap();
        assert_eq!(server.infer("mlp", &sample).unwrap(), e2);
        assert_eq!(server.infer("mlp@v1", &sample).unwrap(), e1);
        // the default cannot be unloaded; the old version can
        assert!(matches!(server.unload_version("mlp", "v2"),
                         Err(LifecycleError::DefaultInUse(_))));
        server.unload_version("mlp", "v1").unwrap();
        assert!(server.infer("mlp@v1", &sample).is_err());
        let reports = server.shutdown();
        assert_eq!(reports.len(), 2, "unloaded slot keeps its row");
        assert_eq!(reports[0].version, "v1");
        assert_eq!(reports[1].version, "v2");
        assert_eq!(reports[0].requests + reports[1].requests, 5);
    }
}
