//! The serving front end: a worker-thread pool draining coalesced batches
//! through [`Plan::run_into`].
//!
//! Each worker owns one pre-warmed [`Scratch`] per registered model (the
//! per-(model, worker) arena the ROADMAP's multi-model serving item calls
//! for), so steady-state execution allocates nothing beyond the response
//! vectors. Batch composition never changes results: plans whose execution
//! is per-sample independent ([`Plan::batch_invariant`]) coalesce up to
//! `max_batch`, while batch-coupled plans (activation fake-quant computes
//! a per-tensor scale over the whole batch) are automatically capped at
//! batch 1 — every caller always receives logits bit-identical to a
//! direct single-sample `run_into` of its input.
//!
//! Shutdown is graceful: [`Server::shutdown`] closes the submission queue,
//! lets the workers drain everything already accepted, joins them, and
//! returns the final per-model reports. Metrics follow the
//! [`crate::coordinator::metrics`] convention — one JSON object per model
//! via [`ModelReport::to_json`], streamable into a [`Metrics`] JSONL log.

use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, ensure, Context, Result};

use crate::coordinator::metrics::Metrics;
use crate::infer::{Plan, Scratch, Tensor};
use crate::jsonic::Json;
use crate::util::{Summary, Timer};

use super::admission::{Admission, Rejection};
use super::batcher::{Batcher, SubmitRefusal, Ticket};
use super::registry::Registry;

/// Typed submission failure, so the HTTP front can map each cause to its
/// status code without string matching (404 / 400 / 429 / 503).
#[derive(Debug)]
pub enum SubmitError {
    /// no model registered under that name (HTTP 404)
    UnknownModel(String),
    /// sample length does not match the model's input dims (HTTP 400)
    BadInput(String),
    /// the admission gate predicts the deadline cannot be met (HTTP 429)
    Rejected(Rejection),
    /// the deadline expired while blocked on a full queue — the same
    /// client outcome as an in-queue shed (HTTP 429, counted as shed)
    QueueDeadline(String),
    /// the batcher is closed — server shutting down (HTTP 503)
    Closed(String),
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::UnknownModel(m)
            | SubmitError::BadInput(m)
            | SubmitError::QueueDeadline(m)
            | SubmitError::Closed(m) => write!(f, "{m}"),
            SubmitError::Rejected(r) => write!(f, "{r}"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Serving knobs: pool width, coalescing cap and patience, queue bound.
#[derive(Debug, Clone, Copy)]
pub struct ServerConfig {
    /// worker threads draining batches (0 = one per core)
    pub workers: usize,
    /// coalescing cap per batch (batch-variant models are capped at 1)
    pub max_batch: usize,
    /// max time a partial batch lingers waiting for more requests
    pub linger: Duration,
    /// bounded per-model submission queue (submit blocks when full)
    pub queue_cap: usize,
    /// assumed per-batch service time (ms) for models with no observed
    /// batch yet — lets cold-start models shed deadline-carrying
    /// traffic early instead of queueing blind (0.0 = legacy optimism;
    /// see [`Admission::with_prior`])
    pub admission_prior_ms: f64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            workers: 0,
            max_batch: 8,
            linger: Duration::from_millis(2),
            queue_cap: 1024,
            admission_prior_ms: 0.0,
        }
    }
}

/// Per-model serving counters (behind one mutex per model, touched once
/// per *batch*, not per request).
struct ModelCounters {
    requests: u64,
    batches: u64,
    errors: u64,
    max_batch: usize,
    batch_ms: Summary,
    wait_ms: Summary,
}

impl ModelCounters {
    fn new() -> ModelCounters {
        ModelCounters {
            requests: 0,
            batches: 0,
            errors: 0,
            max_batch: 0,
            batch_ms: Summary::new(),
            wait_ms: Summary::new(),
        }
    }
}

struct Stats {
    started: Instant,
    models: Vec<Mutex<ModelCounters>>,
}

impl Stats {
    fn record(&self, model: usize, batch: usize, ms: f64,
              waits_ms: &[f64], errored: bool) {
        let mut c = self.models[model].lock().unwrap();
        c.batches += 1;
        if errored {
            c.errors += batch as u64;
        } else {
            c.requests += batch as u64;
        }
        c.max_batch = c.max_batch.max(batch);
        c.batch_ms.push(ms);
        for &w in waits_ms {
            c.wait_ms.push(w);
        }
    }
}

/// Final (or live) per-model serving summary.
#[derive(Debug, Clone)]
pub struct ModelReport {
    pub model: String,
    /// replica tag when this server runs as one backend of a cluster
    /// (`lutq serve --replicas`); "" for a standalone server
    pub replica: String,
    /// inner-kernel backend the model's plan compiled against
    /// (`scalar` / `simd-avx2` / `simd-portable`)
    pub backend: String,
    /// requests answered successfully
    pub requests: u64,
    /// coalesced batches executed
    pub batches: u64,
    /// requests answered with an error
    pub errors: u64,
    /// requests turned away at admission (predicted deadline miss)
    pub rejected: u64,
    /// admitted requests shed in-queue after their deadline expired
    pub shed: u64,
    /// queued requests dropped because the caller abandoned its ticket
    pub abandoned: u64,
    /// smoothed per-batch service time the admission gate predicts with
    pub ewma_batch_ms: f64,
    /// largest coalesced batch observed
    pub max_batch: usize,
    /// mean requests per batch (coalescing effectiveness)
    pub mean_batch: f64,
    pub mean_batch_ms: f64,
    pub max_batch_ms: f64,
    /// mean time a request waited in the queue before execution
    pub mean_wait_ms: f64,
    /// answered requests / server uptime
    pub images_per_sec: f64,
}

impl ModelReport {
    /// One `coordinator::metrics`-style JSONL event.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("event", Json::str("serve_model")),
            ("schema_version",
             Json::num(crate::report::SCHEMA_VERSION as f64)),
            ("model", Json::str(&self.model)),
            ("replica", Json::str(&self.replica)),
            ("backend", Json::str(&self.backend)),
            ("requests", Json::num(self.requests as f64)),
            ("batches", Json::num(self.batches as f64)),
            ("errors", Json::num(self.errors as f64)),
            ("rejected", Json::num(self.rejected as f64)),
            ("shed", Json::num(self.shed as f64)),
            ("abandoned", Json::num(self.abandoned as f64)),
            ("ewma_batch_ms", Json::num(self.ewma_batch_ms)),
            ("max_batch", Json::num(self.max_batch as f64)),
            ("mean_batch", Json::num(self.mean_batch)),
            ("mean_batch_ms", Json::num(self.mean_batch_ms)),
            ("max_batch_ms", Json::num(self.max_batch_ms)),
            ("mean_wait_ms", Json::num(self.mean_wait_ms)),
            ("images_per_sec", Json::num(self.images_per_sec)),
        ])
    }
}

/// Multi-model inference server: shared plans, dynamic batch coalescing,
/// per-(model, worker) scratch arenas.
pub struct Server {
    registry: Arc<Registry>,
    batcher: Arc<Batcher>,
    stats: Arc<Stats>,
    admission: Arc<Admission>,
    /// effective per-model coalescing caps (batch-variant plans: 1)
    caps: Vec<usize>,
    handles: Vec<JoinHandle<()>>,
}

impl Server {
    /// Spin up the worker pool over `registry`'s compiled plans.
    pub fn start(registry: Registry, cfg: ServerConfig) -> Result<Server> {
        ensure!(!registry.is_empty(), "serve: registry holds no models");
        ensure!(cfg.max_batch >= 1, "serve: max_batch must be >= 1");
        let workers = if cfg.workers == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        } else {
            cfg.workers
        };
        // batch-coupled plans must not coalesce: their outputs would
        // depend on which requests happened to share a batch
        let caps: Vec<usize> = registry
            .plans()
            .iter()
            .map(|p| if p.batch_invariant() { cfg.max_batch } else { 1 })
            .collect();
        let batcher = Arc::new(Batcher::new(caps.clone(), cfg.linger,
                                            cfg.queue_cap));
        let admission = Arc::new(Admission::with_prior(
            registry.len(),
            cfg.admission_prior_ms,
        ));
        let stats = Arc::new(Stats {
            started: Instant::now(),
            models: (0..registry.len())
                .map(|_| Mutex::new(ModelCounters::new()))
                .collect(),
        });
        let registry = Arc::new(registry);
        // per-model pools of per-worker arenas, pre-warmed to the
        // model's *effective* batch cap (capped plans never see more
        // than one sample, so don't size their buffers for max_batch)
        let mut pools: Vec<Vec<Scratch>> = registry
            .plans()
            .iter()
            .zip(&caps)
            .map(|(p, &cap)| p.scratch_pool(workers, cap))
            .collect();
        let mut handles: Vec<JoinHandle<()>> =
            Vec::with_capacity(workers);
        for w in 0..workers {
            let scratches: Vec<Scratch> = pools
                .iter_mut()
                .map(|pool| pool.pop().expect("pool sized per worker"))
                .collect();
            let reg = Arc::clone(&registry);
            let bat = Arc::clone(&batcher);
            let st = Arc::clone(&stats);
            let adm = Arc::clone(&admission);
            let spawned = std::thread::Builder::new()
                .name(format!("lutq-serve-{w}"))
                .spawn(move || worker_loop(&reg, &bat, &st, &adm,
                                           scratches));
            match spawned {
                Ok(handle) => handles.push(handle),
                Err(e) => {
                    // don't leak the workers already running: close the
                    // queue so they drain and exit, then join them
                    batcher.close();
                    for h in handles.drain(..) {
                        let _ = h.join();
                    }
                    return Err(e)
                        .with_context(|| format!("spawn serve worker {w}"));
                }
            }
        }
        Ok(Server { registry, batcher, stats, admission, caps, handles })
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// The admission gate's live state (EWMAs, rejection counters).
    pub fn admission(&self) -> &Admission {
        &self.admission
    }

    /// True while the server accepts new requests (false once
    /// [`close`](Server::close) or shutdown began) — the in-process
    /// replica's health probe.
    pub fn is_open(&self) -> bool {
        self.batcher.is_open()
    }

    /// Stop accepting and let the workers drain, without consuming the
    /// handle (worker threads are joined by [`shutdown`](Server::shutdown)
    /// or drop). This is how the cluster tests kill one replica
    /// mid-load: subsequent submits fail as `Closed`, which the router
    /// treats as failover bait. Idempotent.
    pub fn close(&self) {
        self.batcher.close();
    }

    /// Enqueue one sample for the named model; the [`Ticket`] resolves to
    /// exactly this request's logits.
    pub fn submit(&self, model: &str, sample: &[f32]) -> Result<Ticket> {
        let id = self.registry.id(model).ok_or_else(|| {
            anyhow!("serve: unknown model `{model}` (registered: {:?})",
                    self.registry.names())
        })?;
        self.submit_by_id(id, sample)
    }

    /// [`submit`](Server::submit) by dense model id (hot paths that
    /// resolved the name once).
    pub fn submit_by_id(&self, id: usize, sample: &[f32]) -> Result<Ticket> {
        ensure!(id < self.registry.len(),
                "serve: model id {id} out of range");
        let plan = self.registry.plan_by_id(id);
        let expect: usize = plan.input_dims().iter().product();
        ensure!(
            sample.len() == expect,
            "serve: sample holds {} values, model `{}` expects {expect} \
             (input dims {:?})",
            sample.len(),
            self.registry.name(id),
            plan.input_dims()
        );
        Ok(self.batcher.submit(id, sample.to_vec(), None)?)
    }

    /// Deadline-aware submission with typed failures: validates the
    /// model and sample, runs the admission gate against what is left of
    /// `deadline`, and enqueues the request carrying that deadline so
    /// the batcher can shed it if it overstays. This is the HTTP front's
    /// entry point; callers without a deadline are never rejected.
    pub fn try_submit(&self, model: &str, sample: &[f32],
                      deadline: Option<Instant>)
                      -> std::result::Result<Ticket, SubmitError> {
        let id = self.registry.id(model).ok_or_else(|| {
            SubmitError::UnknownModel(format!(
                "unknown model `{model}` (registered: {:?})",
                self.registry.names()
            ))
        })?;
        let plan = self.registry.plan_by_id(id);
        let expect: usize = plan.input_dims().iter().product();
        if sample.len() != expect {
            return Err(SubmitError::BadInput(format!(
                "sample holds {} values, model `{model}` expects \
                 {expect} (input dims {:?})",
                sample.len(),
                plan.input_dims()
            )));
        }
        if let Some(d) = deadline {
            let budget = d.saturating_duration_since(Instant::now());
            self.admission
                .check(id, self.batcher.depth(id), self.caps[id],
                       Some(budget))
                .map_err(SubmitError::Rejected)?;
        }
        self.batcher
            .submit(id, sample.to_vec(), deadline)
            .map_err(|e| match e {
                SubmitRefusal::DeadlineExceeded => {
                    SubmitError::QueueDeadline(e.to_string())
                }
                other => SubmitError::Closed(other.to_string()),
            })
    }

    /// Submit + block for the reply: the one-call convenience path.
    pub fn infer(&self, model: &str, sample: &[f32]) -> Result<Vec<f32>> {
        self.submit(model, sample)?.wait()
    }

    /// Live per-model serving reports (id order).
    pub fn reports(&self) -> Vec<ModelReport> {
        let elapsed = self.stats.started.elapsed().as_secs_f64().max(1e-9);
        self.stats
            .models
            .iter()
            .enumerate()
            .map(|(i, m)| {
                let c = m.lock().unwrap();
                let answered = c.requests + c.errors;
                let (shed, abandoned) = self.batcher.drop_stats(i);
                ModelReport {
                    model: self.registry.name(i).to_string(),
                    replica: String::new(),
                    backend: self
                        .registry
                        .plan_by_id(i)
                        .backend_name()
                        .to_string(),
                    requests: c.requests,
                    batches: c.batches,
                    errors: c.errors,
                    rejected: self.admission.rejected(i),
                    shed,
                    abandoned,
                    ewma_batch_ms: self.admission.ewma_batch_ms(i),
                    max_batch: c.max_batch,
                    mean_batch: if c.batches == 0 {
                        0.0
                    } else {
                        answered as f64 / c.batches as f64
                    },
                    mean_batch_ms: if c.batch_ms.count() == 0 {
                        0.0
                    } else {
                        c.batch_ms.mean()
                    },
                    max_batch_ms: if c.batch_ms.count() == 0 {
                        0.0
                    } else {
                        c.batch_ms.max()
                    },
                    mean_wait_ms: if c.wait_ms.count() == 0 {
                        0.0
                    } else {
                        c.wait_ms.mean()
                    },
                    images_per_sec: c.requests as f64 / elapsed,
                }
            })
            .collect()
    }

    /// Append one JSONL event per model to a metrics log.
    pub fn log_to(&self, metrics: &mut Metrics) -> std::io::Result<()> {
        for r in self.reports() {
            metrics.record_custom(r.to_json())?;
        }
        Ok(())
    }

    /// Graceful shutdown: refuse new requests, drain and answer every
    /// queued one, join the workers, return the final reports.
    pub fn shutdown(mut self) -> Vec<ModelReport> {
        self.batcher.close();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        self.reports()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.batcher.close();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(reg: &Registry, bat: &Batcher, stats: &Stats,
               adm: &Admission, mut scratches: Vec<Scratch>) {
    let input_dims: Vec<Vec<usize>> = reg
        .plans()
        .iter()
        .map(|p| p.input_dims())
        .collect();
    let mut inbuf: Vec<f32> = Vec::new();
    let mut waits: Vec<f64> = Vec::new();
    while let Some(batch) = bat.next_batch() {
        let m = batch.model();
        let plan: &Plan = reg.plan_by_id(m);
        let b = batch.len();
        let popped = Instant::now();
        waits.clear();
        for r in &batch.requests {
            waits.push(
                popped.duration_since(r.arrived).as_secs_f64() * 1e3,
            );
        }
        batch.gather_into(&mut inbuf);
        let mut dims = Vec::with_capacity(1 + input_dims[m].len());
        dims.push(b);
        dims.extend_from_slice(&input_dims[m]);
        let t = Timer::start();
        let x = Tensor::new(dims, std::mem::take(&mut inbuf));
        let result = plan.run_into(&x, &mut scratches[m]);
        inbuf = x.data;
        let ms = t.elapsed_ms();
        // feed the admission gate's per-batch service-time EWMA
        adm.observe_batch_ms(m, ms);
        match result {
            Ok(_) => {
                stats.record(m, b, ms, &waits, false);
                let (_, out) = scratches[m].output();
                batch.complete(out);
            }
            Err(e) => {
                stats.record(m, b, ms, &waits, true);
                batch.fail(&format!("{e:#}"));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::{ExecMode, PlanOptions};
    use crate::testkit::models::synth_mlp_model;
    use crate::util::Rng;

    const WAIT: Duration = Duration::from_secs(30);

    fn mlp_plan() -> Plan {
        let (graph, model) = synth_mlp_model(4);
        Plan::compile(
            &graph,
            &model,
            PlanOptions { mode: ExecMode::LutTrick, act_bits: 0,
                          mlbn: false, threads: 1,
                          ..PlanOptions::default() },
            &[16],
        )
        .unwrap()
    }

    fn small_server(workers: usize) -> (Server, Arc<Plan>) {
        let plan = Arc::new(mlp_plan());
        let mut reg = Registry::new();
        reg.register_shared("mlp", Arc::clone(&plan)).unwrap();
        let server = Server::start(
            reg,
            ServerConfig {
                workers,
                max_batch: 4,
                linger: Duration::from_millis(1),
                queue_cap: 64,
                ..Default::default()
            },
        )
        .unwrap();
        (server, plan)
    }

    #[test]
    fn served_logits_match_direct_single_sample_run() {
        let (server, plan) = small_server(2);
        let mut rng = Rng::new(5);
        let mut scratch = plan.scratch();
        for _ in 0..6 {
            let sample: Vec<f32> = rng.normals(16);
            let x = Tensor::new(vec![1, 16], sample.clone());
            plan.run_into(&x, &mut scratch).unwrap();
            let expect = scratch.output().1.to_vec();
            let got = server
                .submit("mlp", &sample)
                .unwrap()
                .wait_timeout(WAIT)
                .unwrap();
            assert_eq!(got, expect);
        }
        let reports = server.shutdown();
        assert_eq!(reports.len(), 1);
        assert_eq!(reports[0].requests, 6);
        assert_eq!(reports[0].errors, 0);
        assert!(reports[0].batches >= 1);
        assert!(reports[0].images_per_sec > 0.0);
    }

    #[test]
    fn rejects_unknown_model_and_bad_sample_length() {
        let (server, _) = small_server(1);
        assert!(server.submit("nope", &[0.0; 16]).is_err());
        let err = server
            .submit("mlp", &[0.0; 5])
            .unwrap_err()
            .to_string();
        assert!(err.contains("expects 16"), "{err}");
        assert!(server.infer("mlp", &[0.0; 16]).is_ok());
    }

    #[test]
    fn report_json_follows_metrics_event_convention() {
        let (server, _) = small_server(1);
        server.infer("mlp", &[0.5; 16]).unwrap();
        let reports = server.shutdown();
        let j = reports[0].to_json();
        assert_eq!(j.at("event").as_str(), Some("serve_model"));
        assert_eq!(j.at("schema_version").as_usize(),
                   Some(crate::report::SCHEMA_VERSION as usize));
        assert_eq!(j.at("model").as_str(), Some("mlp"));
        assert_eq!(j.at("requests").as_usize(), Some(1));
        // backend name travels with the report (scalar or simd-*)
        let backend = j.at("backend").as_str().unwrap();
        assert!(backend == "scalar" || backend.starts_with("simd"),
                "{backend}");
        // round-trips through the jsonl serializer
        let parsed = crate::jsonic::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.at("model").as_str(), Some("mlp"));
    }

    #[test]
    fn try_submit_maps_failure_causes() {
        let (server, _) = small_server(1);
        assert!(matches!(
            server.try_submit("nope", &[0.0; 16], None).unwrap_err(),
            SubmitError::UnknownModel(_)
        ));
        assert!(matches!(
            server.try_submit("mlp", &[0.0; 5], None).unwrap_err(),
            SubmitError::BadInput(_)
        ));
        // a deadline with no budget left is rejected at admission
        assert!(matches!(
            server
                .try_submit("mlp", &[0.0; 16], Some(Instant::now()))
                .unwrap_err(),
            SubmitError::Rejected(_)
        ));
        // no deadline: always admitted
        let t = server.try_submit("mlp", &[0.0; 16], None).unwrap();
        assert!(t.wait_timeout(WAIT).is_ok());
        let reports = server.shutdown();
        assert_eq!(reports[0].rejected, 1);
        assert_eq!(reports[0].requests, 1);
        assert!(reports[0].ewma_batch_ms > 0.0,
                "workers must feed the admission EWMA");
    }

    #[test]
    fn empty_registry_is_rejected() {
        assert!(
            Server::start(Registry::new(), ServerConfig::default()).is_err()
        );
    }
}
