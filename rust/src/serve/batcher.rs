//! Dynamic batch coalescing: a bounded, multi-queue submission front
//! for compiled plans.
//!
//! Callers [`submit`](Batcher::submit) single-sample requests and get a
//! [`Ticket`] back; worker threads call [`next_batch`](Batcher::next_batch)
//! and receive a [`Batch`] of up to the per-model cap, formed by either
//!
//! * **fill** — a model's queue reached its batch cap, or
//! * **linger expiry** — the oldest queued request waited the configured
//!   maximum, so a partial batch is flushed rather than starving, or
//! * **drain** — the batcher was [`close`](Batcher::close)d; everything
//!   still queued is handed out (never dropped) so shutdown is graceful.
//!
//! Request identity is preserved end to end: each request carries its own
//! one-shot reply slot, and [`Batch::complete`] routes row `i` of the
//! batch output back to exactly the caller that submitted sample `i`. The
//! per-model queues are bounded; `submit` applies backpressure by blocking
//! until space frees (or the batcher closes).
//!
//! Two kinds of queued requests are dropped at batch-formation time
//! rather than wasting a batch slot and compute:
//!
//! * **expired** — the request carried a client deadline and sat in the
//!   queue past it; it is answered with
//!   [`ReplyError::DeadlineExceeded`] and counted as *shed* (the HTTP
//!   front maps this to 429), and
//! * **abandoned** — the caller dropped its [`Ticket`] (e.g. a
//!   `wait_timeout` expired), so nobody is listening; the request is
//!   dropped silently and counted as *abandoned*.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::infer::Plan;

/// Why a request was answered with an error instead of logits.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ReplyError {
    /// The request sat in the queue past its client deadline and was
    /// shed before execution (HTTP front: 429).
    DeadlineExceeded(String),
    /// Plan execution or response routing failed, or no reply arrived in
    /// time (HTTP front: 500).
    Failed(String),
}

impl std::fmt::Display for ReplyError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ReplyError::DeadlineExceeded(m) => {
                write!(f, "deadline_exceeded: {m}")
            }
            ReplyError::Failed(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for ReplyError {}

/// Why [`Batcher::submit`] refused a request (typed so the server can
/// map each cause to the right HTTP status instead of string-matching).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SubmitRefusal {
    /// model id out of range — a caller bug
    BadModel(String),
    /// the batcher is closed (server shutting down)
    Closed,
    /// the queue stayed full past the request's client deadline
    /// (counted as shed; maps to 429, not 503)
    DeadlineExceeded,
}

impl std::fmt::Display for SubmitRefusal {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitRefusal::BadModel(m) => write!(f, "serve: {m}"),
            SubmitRefusal::Closed => {
                write!(f, "serve: batcher is closed (server shutting \
                           down)")
            }
            SubmitRefusal::DeadlineExceeded => {
                write!(f, "serve: deadline_exceeded: queue stayed full \
                           past the client deadline")
            }
        }
    }
}

impl std::error::Error for SubmitRefusal {}

/// What lands in a request's private one-shot reply slot.
type Reply = std::result::Result<Vec<f32>, ReplyError>;

/// One-shot rendezvous between a request and its caller. The caller's
/// [`Ticket`] and the queued [`Request`] each hold one `Arc` strong
/// reference, so the batcher can detect an abandoned caller (dropped
/// ticket) from the strong count alone — `std::sync::mpsc` offers no
/// such check without sending.
struct ReplySlot {
    reply: Mutex<Option<Reply>>,
    cv: Condvar,
}

impl ReplySlot {
    fn new() -> Arc<ReplySlot> {
        Arc::new(ReplySlot { reply: Mutex::new(None), cv: Condvar::new() })
    }
}

/// One queued single-sample request.
pub(crate) struct Request {
    pub(crate) data: Vec<f32>,
    pub(crate) arrived: Instant,
    /// absolute client deadline; queued past it means shed, not served
    pub(crate) deadline: Option<Instant>,
    /// plan pinned at submit time (blue-green: a version swap after
    /// submission cannot change what this request executes against)
    pub(crate) plan: Option<Arc<Plan>>,
    slot: Arc<ReplySlot>,
}

impl Request {
    /// First write wins; later sends (including the `Drop` fallback) are
    /// no-ops.
    fn send(&self, reply: Reply) {
        let mut r = self.slot.reply.lock().unwrap();
        if r.is_none() {
            *r = Some(reply);
            self.slot.cv.notify_all();
        }
    }

    /// True once the caller dropped its [`Ticket`]: the slot's only other
    /// strong reference is gone, so a reply would never be read.
    fn abandoned(&self) -> bool {
        Arc::strong_count(&self.slot) == 1
    }

    fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }
}

impl Drop for Request {
    fn drop(&mut self) {
        // a request dropped without an explicit reply must still wake its
        // caller (e.g. a worker panicking between pop and complete)
        self.send(Err(ReplyError::Failed(
            "request dropped before a reply was produced".to_string(),
        )));
    }
}

/// The caller's handle to one in-flight request. Dropping the ticket
/// abandons the request: the batcher discards it at batch formation
/// instead of spending a slot and compute on an answer nobody reads.
pub struct Ticket {
    slot: Arc<ReplySlot>,
}

impl Ticket {
    /// Block until the reply lands (or `timeout` passes, if given) and
    /// return it with the error cause preserved — the HTTP front maps
    /// [`ReplyError::DeadlineExceeded`] to 429 and the rest to 500.
    pub fn wait_reply(
        self,
        timeout: Option<Duration>,
    ) -> std::result::Result<Vec<f32>, ReplyError> {
        let limit = timeout.map(|t| Instant::now() + t);
        let mut r = self.slot.reply.lock().unwrap();
        loop {
            if let Some(reply) = r.take() {
                return reply;
            }
            match limit {
                None => r = self.slot.cv.wait(r).unwrap(),
                Some(l) => {
                    let now = Instant::now();
                    if now >= l {
                        return Err(ReplyError::Failed(format!(
                            "no reply within {:?}",
                            timeout.unwrap_or_default()
                        )));
                    }
                    r = self.slot.cv.wait_timeout(r, l - now).unwrap().0;
                }
            }
        }
    }

    /// Block until the request's own logits arrive.
    pub fn wait(self) -> Result<Vec<f32>> {
        self.wait_reply(None).map_err(|e| anyhow!("serve: {e}"))
    }

    /// Like [`wait`](Ticket::wait) with an upper bound on the blocking
    /// time (tests and latency-sensitive callers).
    pub fn wait_timeout(self, timeout: Duration) -> Result<Vec<f32>> {
        self.wait_reply(Some(timeout))
            .map_err(|e| anyhow!("serve: {e}"))
    }
}

/// A coalesced batch of same-model requests, capped at the model's batch
/// limit. Consume it with [`complete`](Batch::complete) (row-per-request
/// responses) or [`fail`](Batch::fail).
pub struct Batch {
    model: usize,
    pub(crate) requests: Vec<Request>,
}

impl Batch {
    /// Registry id of the model every request in this batch targets.
    pub fn model(&self) -> usize {
        self.model
    }

    /// The plan every request in this batch pinned at submit time
    /// (`None` when requests were submitted unpinned). A batch only
    /// ever drains one slot's queue and a slot's plan never changes
    /// after staging, so all requests agree on this.
    pub fn plan(&self) -> Option<&Arc<Plan>> {
        self.requests.first().and_then(|r| r.plan.as_ref())
    }

    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Sample `i` as submitted by its caller.
    pub fn sample(&self, i: usize) -> &[f32] {
        &self.requests[i].data
    }

    /// Concatenate the samples batch-major into `buf` (cleared first) —
    /// the layout [`crate::infer::Plan::run_into`] expects.
    pub fn gather_into(&self, buf: &mut Vec<f32>) {
        buf.clear();
        for r in &self.requests {
            buf.extend_from_slice(&r.data);
        }
    }

    /// Split `output` into `len()` equal rows and send row `i` to the
    /// caller that submitted sample `i`. If the output length is not
    /// divisible by the request count the split would be garbage, so
    /// every caller gets a routed error instead of someone else's
    /// truncated logits.
    pub fn complete(self, output: &[f32]) {
        let n = self.requests.len();
        if n == 0 {
            return;
        }
        if output.len() % n != 0 {
            let msg = format!(
                "internal error: batch output of {} values is not \
                 divisible by the {} requests in the batch",
                output.len(),
                n
            );
            self.fail(&msg);
            return;
        }
        let per = output.len() / n;
        for (i, r) in self.requests.iter().enumerate() {
            r.send(Ok(output[i * per..(i + 1) * per].to_vec()));
        }
    }

    /// Reply the same error to every caller in the batch.
    pub fn fail(self, msg: &str) {
        for r in &self.requests {
            r.send(Err(ReplyError::Failed(msg.to_string())));
        }
    }
}

struct State {
    queues: Vec<VecDeque<Request>>,
    /// per-model batch cap (1 = never coalesce, e.g. batch-variant
    /// plans); lives inside the lock so queues can be added while
    /// workers are live
    caps: Vec<usize>,
    /// total queued requests across all models
    len: usize,
    open: bool,
    /// per-model requests answered `DeadlineExceeded` at batch formation
    shed: Vec<u64>,
    /// per-model requests discarded because their ticket was dropped
    abandoned: Vec<u64>,
    /// per-model count of *queued* requests that carry a deadline, so
    /// the wake-time scan in `next_batch` can skip deadline-free queues
    /// entirely (the common in-process case pays nothing)
    deadlined: Vec<usize>,
}

impl State {
    /// Drop expired and abandoned requests from every queue. Expired
    /// requests are answered with [`ReplyError::DeadlineExceeded`];
    /// abandoned ones have nobody listening and are dropped silently.
    /// Runs at batch-formation time so expiry is enforced against the
    /// clock *now*, not the clock at admission.
    fn prune(&mut self, now: Instant) -> usize {
        let State { queues, len, shed, abandoned, deadlined, .. } = self;
        let mut freed = 0usize;
        for (m, q) in queues.iter_mut().enumerate() {
            let before = q.len();
            q.retain(|r| {
                let keep = if r.abandoned() {
                    abandoned[m] += 1;
                    false
                } else if r.expired(now) {
                    r.send(Err(ReplyError::DeadlineExceeded(format!(
                        "request queued {:.1} ms, past its client \
                         deadline; shed before execution",
                        now.duration_since(r.arrived).as_secs_f64() * 1e3
                    ))));
                    shed[m] += 1;
                    false
                } else {
                    true
                };
                if !keep && r.deadline.is_some() {
                    deadlined[m] -= 1;
                }
                keep
            });
            freed += before - q.len();
        }
        *len -= freed;
        freed
    }
}

/// Bounded multi-model coalescing queue. `Send + Sync`; share it behind
/// an `Arc` between submitters and worker threads. Queues can be added
/// while workers are live ([`Batcher::add_queue`]) — queue ids are
/// append-only, mirroring the registry's slot ids.
pub struct Batcher {
    linger: Duration,
    queue_cap: usize,
    state: Mutex<State>,
    /// signalled when work arrives or the batcher closes
    ready: Condvar,
    /// signalled when queue space frees
    space: Condvar,
}

/// What one bounded poll of the batcher produced — see
/// [`Batcher::next_batch_or_idle`].
pub enum Poll {
    /// a coalesced batch, ready to execute
    Batch(Batch),
    /// nothing became ripe within the idle bound; the worker may
    /// re-check its own lifecycle (e.g. a scale-down token) and poll
    /// again
    Idle,
    /// closed and fully drained — the worker's signal to exit
    Closed,
}

impl Batcher {
    /// `caps[m]` is model `m`'s max coalesced batch; `linger` bounds how
    /// long a partial batch waits for company; `queue_cap` bounds each
    /// model's queue (submit blocks when full).
    pub fn new(caps: Vec<usize>, linger: Duration,
               queue_cap: usize) -> Batcher {
        let caps: Vec<usize> =
            caps.into_iter().map(|c| c.max(1)).collect();
        let n = caps.len();
        let queues = caps.iter().map(|_| VecDeque::new()).collect();
        Batcher {
            linger,
            queue_cap: queue_cap.max(1),
            state: Mutex::new(State {
                queues,
                caps,
                len: 0,
                open: true,
                shed: vec![0; n],
                abandoned: vec![0; n],
                deadlined: vec![0; n],
            }),
            ready: Condvar::new(),
            space: Condvar::new(),
        }
    }

    /// Append one queue (for a hot-loaded model version) and return its
    /// id. Safe while submitters and workers are live; existing queue
    /// ids are unaffected.
    pub fn add_queue(&self, cap: usize) -> usize {
        let mut st = self.state.lock().unwrap();
        let id = st.queues.len();
        st.queues.push(VecDeque::new());
        st.caps.push(cap.max(1));
        st.shed.push(0);
        st.abandoned.push(0);
        st.deadlined.push(0);
        id
    }

    /// Number of registered model queues.
    pub fn models(&self) -> usize {
        self.state.lock().unwrap().caps.len()
    }

    /// Total requests currently queued (all models).
    pub fn queued(&self) -> usize {
        self.state.lock().unwrap().len
    }

    /// Requests currently queued for one model (the admission layer's
    /// queue-depth input); 0 for out-of-range ids.
    pub fn depth(&self, model: usize) -> usize {
        let st = self.state.lock().unwrap();
        st.queues.get(model).map_or(0, |q| q.len())
    }

    /// `(shed, abandoned)` counters for one model: requests answered
    /// `DeadlineExceeded` at batch formation, and requests discarded
    /// because their caller dropped the ticket.
    pub fn drop_stats(&self, model: usize) -> (u64, u64) {
        let st = self.state.lock().unwrap();
        (
            st.shed.get(model).copied().unwrap_or(0),
            st.abandoned.get(model).copied().unwrap_or(0),
        )
    }

    pub fn is_open(&self) -> bool {
        self.state.lock().unwrap().open
    }

    /// Enqueue one sample for `model`, optionally carrying the client's
    /// absolute deadline. Blocks while the model's queue is full (but
    /// never past the deadline); refuses once the batcher has been
    /// closed. The refusal is typed so callers can map a deadline expiry
    /// while blocked to the same outcome as an in-queue shed (429).
    pub fn submit(&self, model: usize, data: Vec<f32>,
                  deadline: Option<Instant>)
                  -> std::result::Result<Ticket, SubmitRefusal> {
        self.submit_pinned(model, data, deadline, None)
    }

    /// Like [`submit`](Batcher::submit), but the request carries the
    /// `Arc<Plan>` it resolved at submit time. Workers execute the batch
    /// against this pinned plan (see [`Batch::plan`]), so a concurrent
    /// default flip or unload can never retarget an already-queued
    /// request — the blue-green half of a zero-downtime swap.
    pub fn submit_pinned(&self, model: usize, data: Vec<f32>,
                         deadline: Option<Instant>,
                         plan: Option<Arc<Plan>>)
                         -> std::result::Result<Ticket, SubmitRefusal> {
        let mut st = self.state.lock().unwrap();
        if model >= st.caps.len() {
            return Err(SubmitRefusal::BadModel(format!(
                "model id {model} out of range ({} registered)",
                st.caps.len()
            )));
        }
        while st.open && st.queues[model].len() >= self.queue_cap {
            match deadline {
                None => st = self.space.wait(st).unwrap(),
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        st.shed[model] += 1;
                        return Err(SubmitRefusal::DeadlineExceeded);
                    }
                    st = self.space.wait_timeout(st, d - now).unwrap().0;
                }
            }
        }
        if !st.open {
            return Err(SubmitRefusal::Closed);
        }
        let slot = ReplySlot::new();
        if deadline.is_some() {
            st.deadlined[model] += 1;
        }
        st.queues[model].push_back(Request {
            data,
            arrived: Instant::now(),
            deadline,
            plan,
            slot: Arc::clone(&slot),
        });
        st.len += 1;
        self.ready.notify_one();
        Ok(Ticket { slot })
    }

    /// Worker side: block until a batch is ready (fill, linger expiry or
    /// drain) and return it. Returns `None` once the batcher is closed
    /// *and* every queue is empty — the worker's signal to exit.
    pub fn next_batch(&self) -> Option<Batch> {
        loop {
            match self.next_batch_or_idle(Duration::from_secs(3600)) {
                Poll::Batch(b) => return Some(b),
                Poll::Idle => continue,
                Poll::Closed => return None,
            }
        }
    }

    /// Like [`next_batch`](Batcher::next_batch), but give up after
    /// `idle` without a batch and return [`Poll::Idle`] — autoscaled
    /// workers use the idle bound to periodically check for a
    /// scale-down token instead of parking forever on the condvar.
    ///
    /// Every pass through the loop re-reads the clock and re-evaluates
    /// ripeness from scratch, so a spurious condvar wakeup (or a notify
    /// meant for another model's queue) can never flush a partial batch
    /// before its linger deadline actually passed.
    pub fn next_batch_or_idle(&self, idle: Duration) -> Poll {
        let idle_by = Instant::now() + idle;
        let mut st = self.state.lock().unwrap();
        loop {
            // fresh clock on every wakeup: ripeness below is judged
            // against *now*, never against a pre-wait snapshot
            let now = Instant::now();
            if st.prune(now) > 0 {
                self.space.notify_all();
            }
            // eligible model whose head request has waited the longest
            let mut pick: Option<(usize, Instant)> = None;
            let mut next_deadline: Option<Instant> = None;
            let earliest = |dl: Instant, cur: &mut Option<Instant>| {
                *cur = Some(match *cur {
                    Some(e) => e.min(dl),
                    None => dl,
                });
            };
            for (m, q) in st.queues.iter().enumerate() {
                let Some(head) = q.front() else { continue };
                let ripe = q.len() >= st.caps[m]
                    || !st.open
                    || now.duration_since(head.arrived) >= self.linger;
                if ripe {
                    let older = match pick {
                        Some((_, t)) => head.arrived < t,
                        None => true,
                    };
                    if older {
                        pick = Some((m, head.arrived));
                    }
                } else {
                    earliest(head.arrived + self.linger,
                             &mut next_deadline);
                }
                // wake in time to shed a request whose client deadline
                // expires before any batch would otherwise form; the
                // `deadlined` counter keeps deadline-free queues (the
                // common in-process case) out of this O(queued) scan
                if st.deadlined[m] > 0 {
                    for r in q {
                        if let Some(d) = r.deadline {
                            earliest(d, &mut next_deadline);
                        }
                    }
                }
            }
            if let Some((m, _)) = pick {
                let take = st.queues[m].len().min(st.caps[m]);
                let requests: Vec<Request> =
                    st.queues[m].drain(..take).collect();
                st.len -= take;
                st.deadlined[m] -= requests
                    .iter()
                    .filter(|r| r.deadline.is_some())
                    .count();
                self.space.notify_all();
                return Poll::Batch(Batch { model: m, requests });
            }
            if !st.open && st.len == 0 {
                // wake sibling workers so they observe the drain too
                self.ready.notify_all();
                return Poll::Closed;
            }
            if now >= idle_by {
                return Poll::Idle;
            }
            let wake_at = match next_deadline {
                Some(dl) => dl.min(idle_by),
                None => idle_by,
            };
            let wait = wake_at.saturating_duration_since(now);
            st = self.ready.wait_timeout(st, wait).unwrap().0;
        }
    }

    /// Stop accepting new requests and switch workers into drain mode:
    /// everything already queued is still handed out and answered.
    pub fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.open = false;
        self.ready.notify_all();
        self.space.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const LONG: Duration = Duration::from_secs(5);

    fn sample(tag: f32) -> Vec<f32> {
        vec![tag, tag + 1.0]
    }

    #[test]
    fn full_queue_coalesces_up_to_cap() {
        let b = Batcher::new(vec![3], LONG, 64);
        let tickets: Vec<Ticket> = (0..5)
            .map(|i| b.submit(0, sample(i as f32), None).unwrap())
            .collect();
        // 5 queued, cap 3: first batch is full despite the long linger
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.model(), 0);
        assert_eq!(batch.len(), 3);
        assert_eq!(batch.sample(1), &[1.0, 2.0]);
        batch.complete(&[10.0, 11.0, 12.0]);
        let got = tickets
            .into_iter()
            .take(3)
            .map(|t| t.wait_timeout(LONG).unwrap())
            .collect::<Vec<_>>();
        assert_eq!(got, vec![vec![10.0], vec![11.0], vec![12.0]]);
        assert_eq!(b.queued(), 2);
    }

    #[test]
    fn linger_expiry_flushes_partial_batch() {
        let b = Batcher::new(vec![8], Duration::from_millis(5), 64);
        let _t0 = b.submit(0, sample(0.0), None).unwrap();
        let _t1 = b.submit(0, sample(1.0), None).unwrap();
        let t = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 2, "partial batch flushed at linger");
        assert!(t.elapsed() < Duration::from_secs(2));
        batch.fail("test");
    }

    #[test]
    fn close_drains_then_signals_exit() {
        let b = Batcher::new(vec![8], LONG, 64);
        let t0 = b.submit(0, sample(3.0), None).unwrap();
        b.close();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        batch.complete(&[7.0]);
        assert_eq!(t0.wait_timeout(LONG).unwrap(), vec![7.0]);
        assert!(b.next_batch().is_none(), "drained + closed means exit");
        assert!(b.submit(0, sample(0.0), None).is_err(),
                "closed rejects submits");
    }

    #[test]
    fn bounded_queue_applies_backpressure() {
        let b = Arc::new(Batcher::new(vec![1], Duration::ZERO, 2));
        let b2 = Arc::clone(&b);
        // 3 submits into a 2-slot queue: the third blocks until a pop
        let submitter = std::thread::spawn(move || {
            (0..3)
                .map(|i| b2.submit(0, sample(i as f32), None).unwrap())
                .collect::<Vec<Ticket>>()
        });
        for expect in 0..3 {
            let batch = b.next_batch().unwrap();
            assert_eq!(batch.len(), 1);
            assert_eq!(batch.sample(0)[0], expect as f32);
            batch.complete(&[expect as f32]);
        }
        let tickets = submitter.join().unwrap();
        for (i, t) in tickets.into_iter().enumerate() {
            assert_eq!(t.wait_timeout(LONG).unwrap(), vec![i as f32]);
        }
    }

    #[test]
    fn oldest_model_is_served_first() {
        let b = Batcher::new(vec![1, 1], LONG, 64);
        let _ta = b.submit(1, sample(1.0), None).unwrap();
        let _tb = b.submit(0, sample(0.0), None).unwrap();
        let first = b.next_batch().unwrap();
        assert_eq!(first.model(), 1, "model 1 queued first");
        first.fail("test");
        let second = b.next_batch().unwrap();
        assert_eq!(second.model(), 0);
        second.fail("test");
    }

    #[test]
    fn out_of_range_model_is_rejected() {
        let b = Batcher::new(vec![1], LONG, 4);
        assert!(b.submit(3, sample(0.0), None).is_err());
    }

    #[test]
    fn queues_grow_while_live_and_bounded_poll_goes_idle() {
        let b = Batcher::new(vec![1], Duration::ZERO, 4);
        // no work queued: a bounded poll reports Idle, not a batch
        assert!(matches!(
            b.next_batch_or_idle(Duration::from_millis(5)),
            Poll::Idle
        ));
        let q = b.add_queue(2);
        assert_eq!(q, 1);
        assert_eq!(b.models(), 2);
        let t = b.submit(q, sample(5.0), None).unwrap();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.model(), q);
        assert!(batch.plan().is_none(),
                "unpinned submit carries no plan");
        batch.complete(&[42.0]);
        assert_eq!(t.wait_timeout(LONG).unwrap(), vec![42.0]);
        assert_eq!(b.drop_stats(q), (0, 0));
        assert_eq!(b.depth(99), 0, "out-of-range depth is inert");
    }

    #[test]
    fn non_divisible_output_routes_errors_not_garbage() {
        let b = Batcher::new(vec![2], LONG, 8);
        let t0 = b.submit(0, sample(0.0), None).unwrap();
        let t1 = b.submit(0, sample(1.0), None).unwrap();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 2);
        // 3 output values over 2 requests: not divisible — nobody may
        // receive a truncated/mixed row
        batch.complete(&[1.0, 2.0, 3.0]);
        for t in [t0, t1] {
            let err = t.wait_timeout(LONG).unwrap_err().to_string();
            assert!(err.contains("not"), "{err}");
            assert!(err.contains("divisible"), "{err}");
        }
    }

    #[test]
    fn abandoned_ticket_is_dropped_at_batch_formation() {
        let b = Batcher::new(vec![4], Duration::from_millis(2), 8);
        let t0 = b.submit(0, sample(0.0), None).unwrap();
        let t1 = b.submit(0, sample(1.0), None).unwrap();
        let t2 = b.submit(0, sample(2.0), None).unwrap();
        drop(t1); // caller gave up before the batch formed
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 2, "dead request must not take a slot");
        assert_eq!(batch.sample(0), &[0.0, 1.0]);
        assert_eq!(batch.sample(1), &[2.0, 3.0]);
        batch.complete(&[10.0, 20.0]);
        assert_eq!(t0.wait_timeout(LONG).unwrap(), vec![10.0]);
        assert_eq!(t2.wait_timeout(LONG).unwrap(), vec![20.0]);
        assert_eq!(b.drop_stats(0), (0, 1));
    }

    #[test]
    fn expired_deadline_is_shed_with_deadline_error() {
        let b = Batcher::new(vec![8], Duration::from_millis(5), 8);
        let dead = b
            .submit(0, sample(0.0),
                    Some(Instant::now() + Duration::from_millis(1)))
            .unwrap();
        let live = b.submit(0, sample(1.0), None).unwrap();
        std::thread::sleep(Duration::from_millis(10));
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1, "expired request must be shed");
        assert_eq!(batch.sample(0), &[1.0, 2.0]);
        batch.complete(&[9.0]);
        assert_eq!(live.wait_timeout(LONG).unwrap(), vec![9.0]);
        match dead.wait_reply(Some(LONG)) {
            Err(ReplyError::DeadlineExceeded(_)) => {}
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
        assert_eq!(b.drop_stats(0), (1, 0));
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn full_queue_past_deadline_is_refused_as_deadline_exceeded() {
        // queue cap 1, no consumer: the second submit blocks on a full
        // queue until its deadline passes — that is a typed 429-shaped
        // refusal (and a shed), not a "closed" error
        let b = Batcher::new(vec![1], LONG, 1);
        let _parked = b.submit(0, sample(0.0), None).unwrap();
        let err = b
            .submit(0, sample(1.0),
                    Some(Instant::now() + Duration::from_millis(10)))
            .unwrap_err();
        assert_eq!(err, SubmitRefusal::DeadlineExceeded);
        assert_eq!(b.drop_stats(0), (1, 0));
    }

    #[test]
    fn worker_wakes_to_shed_before_linger() {
        // linger far longer than the deadline: the worker must wake at
        // the request's deadline to shed it, not sit out the linger
        let b = Arc::new(Batcher::new(vec![8], LONG, 8));
        let b2 = Arc::clone(&b);
        let worker = std::thread::spawn(move || {
            while b2.next_batch().is_some() {
                panic!("nothing should ever form a batch here");
            }
        });
        let t = b
            .submit(0, sample(0.0),
                    Some(Instant::now() + Duration::from_millis(20)))
            .unwrap();
        let reply = t.wait_reply(Some(LONG));
        assert!(matches!(reply, Err(ReplyError::DeadlineExceeded(_))),
                "{reply:?}");
        b.close();
        worker.join().unwrap();
        assert_eq!(b.drop_stats(0), (1, 0));
    }

    #[test]
    fn foreign_notify_does_not_flush_partial_batch_early() {
        // model 0 lingers; a submit to model 1 wakes the worker early.
        // That wakeup must re-evaluate model 0's linger against a fresh
        // clock and keep waiting, not flush the partial batch.
        let linger = Duration::from_millis(120);
        let b = Batcher::new(vec![4, 1], linger, 8);
        let t0 = Instant::now();
        let _a = b.submit(0, sample(0.0), None).unwrap();
        let _b = b.submit(1, sample(1.0), None).unwrap();
        let first = b.next_batch().unwrap();
        assert_eq!(first.model(), 1, "model 1 is at cap, ripe now");
        first.fail("test");
        let second = b.next_batch().unwrap();
        assert_eq!(second.model(), 0);
        assert!(
            t0.elapsed() >= linger - Duration::from_millis(10),
            "partial batch flushed {:?} after submit, before its \
             {linger:?} linger",
            t0.elapsed()
        );
        second.fail("test");
    }
}
