//! Dynamic batch coalescing: a bounded, multi-queue submission front
//! for compiled plans.
//!
//! Callers [`submit`](Batcher::submit) single-sample requests and get a
//! [`Ticket`] back; worker threads call [`next_batch`](Batcher::next_batch)
//! and receive a [`Batch`] of up to the per-model cap, formed by either
//!
//! * **fill** — a model's queue reached its batch cap, or
//! * **linger expiry** — the oldest queued request waited the configured
//!   maximum, so a partial batch is flushed rather than starving, or
//! * **drain** — the batcher was [`close`](Batcher::close)d; everything
//!   still queued is handed out (never dropped) so shutdown is graceful.
//!
//! Request identity is preserved end to end: each request carries its own
//! response channel, and [`Batch::complete`] routes row `i` of the batch
//! output back to exactly the caller that submitted sample `i`. The
//! per-model queues are bounded; `submit` applies backpressure by blocking
//! until space frees (or the batcher closes).

use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, ensure, Result};

/// What travels back over a request's private response channel.
type Reply = std::result::Result<Vec<f32>, String>;

/// One queued single-sample request.
pub(crate) struct Request {
    pub(crate) data: Vec<f32>,
    pub(crate) arrived: Instant,
    tx: mpsc::Sender<Reply>,
}

/// The caller's handle to one in-flight request.
pub struct Ticket {
    rx: mpsc::Receiver<Reply>,
}

impl Ticket {
    /// Block until the request's own logits arrive.
    pub fn wait(self) -> Result<Vec<f32>> {
        match self.rx.recv() {
            Ok(Ok(v)) => Ok(v),
            Ok(Err(e)) => Err(anyhow!("serve: {e}")),
            Err(_) => Err(anyhow!(
                "serve: response channel dropped before a reply arrived"
            )),
        }
    }

    /// Like [`wait`](Ticket::wait) with an upper bound on the blocking
    /// time (tests and latency-sensitive callers).
    pub fn wait_timeout(self, timeout: Duration) -> Result<Vec<f32>> {
        match self.rx.recv_timeout(timeout) {
            Ok(Ok(v)) => Ok(v),
            Ok(Err(e)) => Err(anyhow!("serve: {e}")),
            Err(mpsc::RecvTimeoutError::Timeout) => {
                Err(anyhow!("serve: no reply within {timeout:?}"))
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => Err(anyhow!(
                "serve: response channel dropped before a reply arrived"
            )),
        }
    }
}

/// A coalesced batch of same-model requests, capped at the model's batch
/// limit. Consume it with [`complete`](Batch::complete) (row-per-request
/// responses) or [`fail`](Batch::fail).
pub struct Batch {
    model: usize,
    pub(crate) requests: Vec<Request>,
}

impl Batch {
    /// Registry id of the model every request in this batch targets.
    pub fn model(&self) -> usize {
        self.model
    }

    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Sample `i` as submitted by its caller.
    pub fn sample(&self, i: usize) -> &[f32] {
        &self.requests[i].data
    }

    /// Concatenate the samples batch-major into `buf` (cleared first) —
    /// the layout [`crate::infer::Plan::run_into`] expects.
    pub fn gather_into(&self, buf: &mut Vec<f32>) {
        buf.clear();
        for r in &self.requests {
            buf.extend_from_slice(&r.data);
        }
    }

    /// Split `output` into `len()` equal rows and send row `i` to the
    /// caller that submitted sample `i`. Callers that gave up (dropped
    /// their ticket) are skipped silently.
    pub fn complete(self, output: &[f32]) {
        let n = self.requests.len();
        let per = output.len() / n.max(1);
        for (i, r) in self.requests.into_iter().enumerate() {
            let _ = r.tx.send(Ok(output[i * per..(i + 1) * per].to_vec()));
        }
    }

    /// Reply the same error to every caller in the batch.
    pub fn fail(self, msg: &str) {
        for r in self.requests {
            let _ = r.tx.send(Err(msg.to_string()));
        }
    }
}

struct State {
    queues: Vec<VecDeque<Request>>,
    /// total queued requests across all models
    len: usize,
    open: bool,
}

/// Bounded multi-model coalescing queue. `Send + Sync`; share it behind
/// an `Arc` between submitters and worker threads.
pub struct Batcher {
    /// per-model batch cap (1 = never coalesce, e.g. batch-variant plans)
    caps: Vec<usize>,
    linger: Duration,
    queue_cap: usize,
    state: Mutex<State>,
    /// signalled when work arrives or the batcher closes
    ready: Condvar,
    /// signalled when queue space frees
    space: Condvar,
}

impl Batcher {
    /// `caps[m]` is model `m`'s max coalesced batch; `linger` bounds how
    /// long a partial batch waits for company; `queue_cap` bounds each
    /// model's queue (submit blocks when full).
    pub fn new(caps: Vec<usize>, linger: Duration,
               queue_cap: usize) -> Batcher {
        let caps: Vec<usize> =
            caps.into_iter().map(|c| c.max(1)).collect();
        let queues = caps.iter().map(|_| VecDeque::new()).collect();
        Batcher {
            caps,
            linger,
            queue_cap: queue_cap.max(1),
            state: Mutex::new(State { queues, len: 0, open: true }),
            ready: Condvar::new(),
            space: Condvar::new(),
        }
    }

    /// Number of registered model queues.
    pub fn models(&self) -> usize {
        self.caps.len()
    }

    /// Total requests currently queued (all models).
    pub fn queued(&self) -> usize {
        self.state.lock().unwrap().len
    }

    pub fn is_open(&self) -> bool {
        self.state.lock().unwrap().open
    }

    /// Enqueue one sample for `model`. Blocks while the model's queue is
    /// full; errors once the batcher has been closed.
    pub fn submit(&self, model: usize, data: Vec<f32>) -> Result<Ticket> {
        ensure!(model < self.caps.len(),
                "serve: model id {model} out of range ({} registered)",
                self.caps.len());
        let (tx, rx) = mpsc::channel();
        let mut st = self.state.lock().unwrap();
        while st.open && st.queues[model].len() >= self.queue_cap {
            st = self.space.wait(st).unwrap();
        }
        ensure!(st.open, "serve: batcher is closed (server shutting down)");
        st.queues[model].push_back(Request {
            data,
            arrived: Instant::now(),
            tx,
        });
        st.len += 1;
        self.ready.notify_one();
        Ok(Ticket { rx })
    }

    /// Worker side: block until a batch is ready (fill, linger expiry or
    /// drain) and return it. Returns `None` once the batcher is closed
    /// *and* every queue is empty — the worker's signal to exit.
    pub fn next_batch(&self) -> Option<Batch> {
        let mut st = self.state.lock().unwrap();
        loop {
            let now = Instant::now();
            // eligible model whose head request has waited the longest
            let mut pick: Option<(usize, Instant)> = None;
            let mut next_deadline: Option<Instant> = None;
            for (m, q) in st.queues.iter().enumerate() {
                let Some(head) = q.front() else { continue };
                let ripe = q.len() >= self.caps[m]
                    || !st.open
                    || now.duration_since(head.arrived) >= self.linger;
                if ripe {
                    let older = match pick {
                        Some((_, t)) => head.arrived < t,
                        None => true,
                    };
                    if older {
                        pick = Some((m, head.arrived));
                    }
                } else {
                    let dl = head.arrived + self.linger;
                    next_deadline = Some(match next_deadline {
                        Some(e) => e.min(dl),
                        None => dl,
                    });
                }
            }
            if let Some((m, _)) = pick {
                let take = st.queues[m].len().min(self.caps[m]);
                let requests: Vec<Request> =
                    st.queues[m].drain(..take).collect();
                st.len -= take;
                self.space.notify_all();
                return Some(Batch { model: m, requests });
            }
            if !st.open && st.len == 0 {
                // wake sibling workers so they observe the drain too
                self.ready.notify_all();
                return None;
            }
            st = match next_deadline {
                Some(dl) => {
                    let wait = dl.saturating_duration_since(now);
                    self.ready.wait_timeout(st, wait).unwrap().0
                }
                None => self.ready.wait(st).unwrap(),
            };
        }
    }

    /// Stop accepting new requests and switch workers into drain mode:
    /// everything already queued is still handed out and answered.
    pub fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.open = false;
        self.ready.notify_all();
        self.space.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    const LONG: Duration = Duration::from_secs(5);

    fn sample(tag: f32) -> Vec<f32> {
        vec![tag, tag + 1.0]
    }

    #[test]
    fn full_queue_coalesces_up_to_cap() {
        let b = Batcher::new(vec![3], LONG, 64);
        let tickets: Vec<Ticket> = (0..5)
            .map(|i| b.submit(0, sample(i as f32)).unwrap())
            .collect();
        // 5 queued, cap 3: first batch is full despite the long linger
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.model(), 0);
        assert_eq!(batch.len(), 3);
        assert_eq!(batch.sample(1), &[1.0, 2.0]);
        batch.complete(&[10.0, 11.0, 12.0]);
        let got = tickets
            .into_iter()
            .take(3)
            .map(|t| t.wait_timeout(LONG).unwrap())
            .collect::<Vec<_>>();
        assert_eq!(got, vec![vec![10.0], vec![11.0], vec![12.0]]);
        assert_eq!(b.queued(), 2);
    }

    #[test]
    fn linger_expiry_flushes_partial_batch() {
        let b = Batcher::new(vec![8], Duration::from_millis(5), 64);
        let _t0 = b.submit(0, sample(0.0)).unwrap();
        let _t1 = b.submit(0, sample(1.0)).unwrap();
        let t = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 2, "partial batch flushed at linger");
        assert!(t.elapsed() < Duration::from_secs(2));
        batch.fail("test");
    }

    #[test]
    fn close_drains_then_signals_exit() {
        let b = Batcher::new(vec![8], LONG, 64);
        let t0 = b.submit(0, sample(3.0)).unwrap();
        b.close();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        batch.complete(&[7.0]);
        assert_eq!(t0.wait_timeout(LONG).unwrap(), vec![7.0]);
        assert!(b.next_batch().is_none(), "drained + closed means exit");
        assert!(b.submit(0, sample(0.0)).is_err(), "closed rejects submits");
    }

    #[test]
    fn bounded_queue_applies_backpressure() {
        let b = Arc::new(Batcher::new(vec![1], Duration::ZERO, 2));
        let b2 = Arc::clone(&b);
        // 3 submits into a 2-slot queue: the third blocks until a pop
        let submitter = std::thread::spawn(move || {
            (0..3)
                .map(|i| b2.submit(0, sample(i as f32)).unwrap())
                .collect::<Vec<Ticket>>()
        });
        for expect in 0..3 {
            let batch = b.next_batch().unwrap();
            assert_eq!(batch.len(), 1);
            assert_eq!(batch.sample(0)[0], expect as f32);
            batch.complete(&[expect as f32]);
        }
        let tickets = submitter.join().unwrap();
        for (i, t) in tickets.into_iter().enumerate() {
            assert_eq!(t.wait_timeout(LONG).unwrap(), vec![i as f32]);
        }
    }

    #[test]
    fn oldest_model_is_served_first() {
        let b = Batcher::new(vec![1, 1], LONG, 64);
        let _ta = b.submit(1, sample(1.0)).unwrap();
        let _tb = b.submit(0, sample(0.0)).unwrap();
        let first = b.next_batch().unwrap();
        assert_eq!(first.model(), 1, "model 1 queued first");
        first.fail("test");
        let second = b.next_batch().unwrap();
        assert_eq!(second.model(), 0);
        second.fail("test");
    }

    #[test]
    fn out_of_range_model_is_rejected() {
        let b = Batcher::new(vec![1], LONG, 4);
        assert!(b.submit(3, sample(0.0)).is_err());
    }
}
