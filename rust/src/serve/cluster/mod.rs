//! Scale-out tier over the serve stack: shard a batch's sample
//! dimension across replicas, merge the outputs back in request order.
//!
//! The single-process [`Server`](super::Server) tops out at one machine's
//! cores. This module adds the routing tier the ROADMAP's "sharding a
//! plan's batch dimension across processes/hosts" item calls for:
//!
//! * [`Replica`] — one backend that can serve a shard: an in-process
//!   [`Server`] handle ([`InProcessReplica`]), a remote HTTP front
//!   reached through [`HttpClient`](super::HttpClient)
//!   ([`HttpReplica`]), or a remote binary wire front reached through
//!   [`WireClient`](super::WireClient) as one batched predict frame
//!   per shard ([`WireReplica`]). Decorators compose — the
//!   fault-injection wrapper `testkit::flaky::FlakyReplica` wraps any
//!   of them.
//! * [`shard`] — the pure partition math: [`split`] carves `0..n` into
//!   contiguous per-replica ranges proportional to health-weighted
//!   speeds, [`chunk`] caps shard size, [`merge`] reassembles per-shard
//!   outputs into request order. Property-tested: every sample is
//!   served exactly once.
//! * [`Router`] — the orchestrator: health-checked replicas (reusing
//!   `/healthz` for HTTP backends), per-replica EWMA-weighted shard
//!   sizing seeded from the replicas' own admission stats, and failover
//!   that re-routes a shard to surviving replicas when a backend errors
//!   or dies mid-load. Implements the HTTP front's
//!   [`ServeBackend`](super::ServeBackend), so `lutq route` serves the
//!   same API as `lutq serve`.
//! * [`breaker`] — per-replica circuit breakers with exponential
//!   backoff: a tripped replica leaves the rotation, gets probed on a
//!   doubling schedule instead of every tick, and rejoins after one
//!   successful trial. The router's hedged dispatch (duplicate a slow
//!   shard to the fastest idle survivor, take the first completion)
//!   lives in [`router`]; both preserve the accounting contract below.
//!
//! Correctness contract (the cluster parity tests pin it): a routed
//! response is bit-identical to a direct single-sample `Plan::run_into`
//! of the same input, replica count and shard boundaries included.
//! Batch-invariant plans shard freely up to
//! [`RouterConfig::max_shard`]; batch-coupled plans (act-quant) shard
//! at batch 1 — the same [`Plan::batch_invariant`] seam the
//! single-process batcher caps on.
//!
//! Accounting contract (the fault-injection tests pin it): every sample
//! submitted to the router lands in exactly one of
//! `completed / rejected / shed / failed` —
//! [`ClusterTotals::reconciles`] — no double-completion, no leak.
//!
//! [`Plan::batch_invariant`]: crate::infer::Plan::batch_invariant
//! [`split`]: shard::split
//! [`chunk`]: shard::chunk
//! [`merge`]: shard::merge

pub mod breaker;
pub mod replica;
pub mod router;
pub mod shard;

pub use breaker::{BreakerConfig, BreakerState, CircuitBreaker};
pub use replica::{
    HttpReplica, InProcessReplica, Replica, ReplicaError, WireReplica,
};
pub use router::{
    ClusterTotals, ReplicaReport, RouteError, Router, RouterConfig,
};
pub use shard::{chunk, merge, split, Shard};
