//! Pure shard-plan math: partition a batch's sample range across
//! replicas and reassemble per-shard outputs in request order.
//!
//! Kept free of I/O and clocks so the properties are checkable in
//! isolation: for any replica count, weight vector and batch size,
//! [`split`] + [`chunk`] partition `0..n` exactly once (every sample in
//! exactly one shard, only on positive-weight replicas, no shard over
//! the cap) and [`merge`] restores request order. `tests/cluster.rs`
//! drives exactly that property through the shrinking harness.

/// One shard: a contiguous range of the batch's samples assigned to one
/// replica. `start` indexes the batch being split (for the router, the
/// *pending* subset of the original request order).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Shard {
    /// index into the router's replica set
    pub replica: usize,
    /// first sample of the range
    pub start: usize,
    /// samples in the range (never 0 for emitted shards)
    pub len: usize,
}

impl Shard {
    /// One-past-the-end sample index.
    pub fn end(&self) -> usize {
        self.start + self.len
    }
}

/// Partition `0..n` into contiguous per-replica ranges proportional to
/// `weights` (largest-remainder rounding, so the counts sum to exactly
/// `n`). Replicas with a non-positive or non-finite weight receive
/// nothing; replicas rounded down to zero samples emit no shard.
/// Returns an empty plan when `n == 0` or no weight is positive.
pub fn split(n: usize, weights: &[f64]) -> Vec<Shard> {
    let clean: Vec<f64> = weights
        .iter()
        .map(|&w| if w.is_finite() && w > 0.0 { w } else { 0.0 })
        .collect();
    let total: f64 = clean.iter().sum();
    if n == 0 || total <= 0.0 {
        return Vec::new();
    }
    let mut counts = vec![0usize; clean.len()];
    // (replica, fractional part) of each ideal share, for the remainder
    let mut fracs: Vec<(usize, f64)> = Vec::new();
    let mut assigned = 0usize;
    for (i, &w) in clean.iter().enumerate() {
        if w <= 0.0 {
            continue;
        }
        let ideal = n as f64 * w / total;
        // float error must never overshoot the batch
        let floor = (ideal.floor() as usize).min(n - assigned);
        counts[i] = floor;
        assigned += floor;
        fracs.push((i, ideal - floor as f64));
    }
    // hand the remainder to the largest fractional parts (ties: the
    // lower replica index, so plans are deterministic)
    fracs.sort_by(|a, b| {
        b.1.partial_cmp(&a.1)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.0.cmp(&b.0))
    });
    let mut rem = n - assigned;
    let mut it = fracs.iter().cycle();
    while rem > 0 {
        let (i, _) = it.next().expect("total > 0 implies a candidate");
        counts[*i] += 1;
        rem -= 1;
    }
    let mut out = Vec::new();
    let mut start = 0usize;
    for (i, &c) in counts.iter().enumerate() {
        if c == 0 {
            continue;
        }
        out.push(Shard { replica: i, start, len: c });
        start += c;
    }
    debug_assert_eq!(start, n, "split must cover the whole batch");
    out
}

/// Re-cut a shard plan so no shard exceeds `max_shard` samples. The
/// router uses `max_shard == 1` for batch-coupled (act-quant) plans so
/// every shard is a single sample — the cluster analogue of the
/// single-process batcher's batch-1 cap.
pub fn chunk(shards: &[Shard], max_shard: usize) -> Vec<Shard> {
    let cap = max_shard.max(1);
    let mut out = Vec::new();
    for s in shards {
        let mut off = 0usize;
        while off < s.len {
            let len = cap.min(s.len - off);
            out.push(Shard {
                replica: s.replica,
                start: s.start + off,
                len,
            });
            off += len;
        }
    }
    out
}

/// Reassemble per-shard outputs into request order: row `j` of a
/// shard's output is sample `start + j` of the original batch. Errors
/// (router bug, never the caller's fault) if a shard's row count does
/// not match its length, an index falls outside `0..n`, or any sample
/// is produced twice or never.
pub fn merge<T: Clone>(
    n: usize,
    parts: &[(Shard, Vec<T>)],
) -> Result<Vec<T>, String> {
    let mut slots: Vec<Option<T>> = vec![None; n];
    for (shard, rows) in parts {
        if rows.len() != shard.len {
            return Err(format!(
                "shard {shard:?} answered {} rows for {} samples",
                rows.len(),
                shard.len
            ));
        }
        for (j, row) in rows.iter().enumerate() {
            let i = shard.start + j;
            let slot = slots.get_mut(i).ok_or_else(|| {
                format!("shard {shard:?} writes sample {i} outside 0..{n}")
            })?;
            if slot.is_some() {
                return Err(format!("sample {i} produced twice"));
            }
            *slot = Some(row.clone());
        }
    }
    slots
        .into_iter()
        .enumerate()
        .map(|(i, s)| s.ok_or_else(|| format!("sample {i} never produced")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn coverage(n: usize, shards: &[Shard]) -> Vec<u32> {
        let mut seen = vec![0u32; n];
        for s in shards {
            for i in s.start..s.end() {
                seen[i] += 1;
            }
        }
        seen
    }

    #[test]
    fn split_is_exact_and_proportionalish() {
        let shards = split(10, &[1.0, 1.0]);
        assert_eq!(coverage(10, &shards), vec![1; 10]);
        let per: Vec<usize> = shards.iter().map(|s| s.len).collect();
        assert_eq!(per, vec![5, 5]);
        // remainder batches still partition exactly once
        let shards = split(7, &[1.0, 1.0, 1.0]);
        assert_eq!(coverage(7, &shards), vec![1; 7]);
        assert_eq!(shards.iter().map(|s| s.len).sum::<usize>(), 7);
    }

    #[test]
    fn split_skips_dead_and_junk_weights() {
        let shards = split(9, &[0.0, 3.0, f64::NAN, -1.0]);
        assert_eq!(shards.len(), 1);
        assert_eq!(shards[0], Shard { replica: 1, start: 0, len: 9 });
        assert!(split(9, &[0.0, 0.0]).is_empty());
        assert!(split(0, &[1.0]).is_empty());
    }

    #[test]
    fn faster_replicas_take_larger_shards() {
        // weight 3:1 over 8 samples -> 6 + 2
        let shards = split(8, &[3.0, 1.0]);
        let per: Vec<(usize, usize)> =
            shards.iter().map(|s| (s.replica, s.len)).collect();
        assert_eq!(per, vec![(0, 6), (1, 2)]);
    }

    #[test]
    fn chunk_caps_shard_size_without_losing_samples() {
        let shards = chunk(&split(10, &[4.0, 1.0]), 3);
        assert_eq!(coverage(10, &shards), vec![1; 10]);
        assert!(shards.iter().all(|s| s.len <= 3 && s.len > 0));
        // batch-1 chunking: one shard per sample
        let ones = chunk(&split(5, &[1.0, 1.0]), 1);
        assert_eq!(ones.len(), 5);
        assert!(ones.iter().all(|s| s.len == 1));
    }

    #[test]
    fn merge_restores_request_order() {
        let shards = chunk(&split(7, &[1.0, 2.0]), 2);
        let parts: Vec<(Shard, Vec<usize>)> = shards
            .iter()
            .map(|s| (*s, (s.start..s.end()).collect()))
            .collect();
        assert_eq!(merge(7, &parts).unwrap(),
                   (0..7).collect::<Vec<_>>());
    }

    #[test]
    fn merge_rejects_malformed_parts() {
        let s = Shard { replica: 0, start: 0, len: 2 };
        // wrong row count
        assert!(merge(2, &[(s, vec![1usize])]).is_err());
        // double production
        let err = merge(
            2,
            &[(s, vec![1usize, 2]),
              (Shard { replica: 1, start: 1, len: 1 }, vec![9usize])],
        )
        .unwrap_err();
        assert!(err.contains("twice"), "{err}");
        // gap
        let err =
            merge(3, &[(s, vec![1usize, 2])]).unwrap_err();
        assert!(err.contains("never"), "{err}");
        // out of range
        let oob = Shard { replica: 0, start: 2, len: 2 };
        assert!(merge(3, &[(oob, vec![1usize, 2])]).is_err());
    }
}
